#!/usr/bin/env python3
"""Sanity-check multi-node feasibility frontiers: the best achievable
context wall must be monotone non-decreasing in cluster size (more
aggregate HBM and smaller per-rank sequence shards can only move memory
walls outward).

Usage: check_frontier_monotonic.py <plan1.json> <plan2.json> [...]

Arguments are planner JSON artifacts (`repro plan --json` or
`repro plan --feasibility-only --json`) ordered by increasing GPU count.
Fails if the GPU counts are not strictly increasing, if any sweep is
empty, or if a larger cluster's best wall drops below a smaller one's.
Capped walls (max_context_capped) count at their reported lower bound,
which keeps the check conservative.
"""

import json
import sys


def best_wall(path: str) -> tuple[int, int]:
    with open(path) as f:
        doc = json.load(f)
    configs = doc.get("configs") or []
    if not configs:
        raise SystemExit(f"FAIL: {path} has no configurations")
    walls = [c.get("max_context") or 0 for c in configs]
    return int(doc.get("gpus") or 0), int(max(walls))


def main() -> int:
    if len(sys.argv) < 3:
        print(__doc__)
        return 2
    points = [best_wall(p) for p in sys.argv[1:]]
    for (path, (gpus, wall)) in zip(sys.argv[1:], points):
        print(f"{path}: {gpus} GPUs -> best wall {wall} tokens ({wall >> 20}M)")
    ok = True
    for (g0, w0), (g1, w1) in zip(points, points[1:]):
        if g1 <= g0:
            print(f"FAIL: artifacts out of order ({g0} -> {g1} GPUs)")
            ok = False
        if w1 < w0:
            print(
                f"FAIL: best wall shrank with cluster size: "
                f"{g0} GPUs -> {w0} tokens but {g1} GPUs -> {w1} tokens"
            )
            ok = False
    if ok:
        print("multi-node frontier monotonic in node count OK")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
