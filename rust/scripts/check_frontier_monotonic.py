#!/usr/bin/env python3
"""Sanity-check planner artifacts against hardware monotonicity.

Two modes, one invariant: more hardware can only move capacity outward.

Frontier mode (the original gate):

    check_frontier_monotonic.py <plan1.json> <plan2.json> [...]

Arguments are planner JSON artifacts (`repro plan --json` or
`repro plan --feasibility-only --json`) ordered by increasing GPU count.
Fails if the GPU counts are not strictly increasing, if any sweep is
empty, or if a larger cluster's best wall drops below a smaller one's.

Dominance mode (the fleet-placement gate):

    check_frontier_monotonic.py --placement <placement.json>

The argument is a `repro place --json` / `/v1/placement` artifact. Every
shape carries its per-rank hardware (the `hardware` object) and grid;
whenever shape A dominates shape B — same (nodes, gpus_per_node), every
hardware dimension >= B's — A's best wall must be >= B's. This is the
exact relation the planner's dominance pruning relies on, checked on
real evaluated output, so a model change that breaks the relation fails
CI instead of silently making pruning lossy. The gate also re-derives
every `pruned_by` edge from the hardware objects and fails if a recorded
dominator does not actually dominate. Run it on a `--no-prune` artifact
to compare walls for every dominated shape (pruned shapes in a pruning
artifact carry no plan, so only provenance is checkable there).

Capped walls (max_context_capped / `>=` labels) count at their reported
lower bound, which keeps both checks conservative.
"""

import json
import sys

HW_DIMS = (
    "hbm_gib",
    "hbm_usable_frac",
    "host_ram_gib",
    "nvlink_gbps",
    "ib_gbps",
    "pcie_gbps",
    "compute_scale",
)


def best_wall(path: str) -> tuple[int, int]:
    with open(path) as f:
        doc = json.load(f)
    configs = doc.get("configs") or []
    if not configs:
        raise SystemExit(f"FAIL: {path} has no configurations")
    walls = [c.get("max_context") or 0 for c in configs]
    return int(doc.get("gpus") or 0), int(max(walls))


def frontier_mode(paths: list[str]) -> int:
    points = [best_wall(p) for p in paths]
    for (path, (gpus, wall)) in zip(paths, points):
        print(f"{path}: {gpus} GPUs -> best wall {wall} tokens ({wall >> 20}M)")
    ok = True
    for (g0, w0), (g1, w1) in zip(points, points[1:]):
        if g1 <= g0:
            print(f"FAIL: artifacts out of order ({g0} -> {g1} GPUs)")
            ok = False
        if w1 < w0:
            print(
                f"FAIL: best wall shrank with cluster size: "
                f"{g0} GPUs -> {w0} tokens but {g1} GPUs -> {w1} tokens"
            )
            ok = False
    if ok:
        print("multi-node frontier monotonic in node count OK")
    return 0 if ok else 1


def dominates(a: dict, b: dict) -> bool:
    """A >= B in every per-rank hardware dimension at the same grid,
    strictly greater in at least one (identical hardware is handled by
    the caller: equal shapes trivially satisfy wall >= wall)."""
    if (a["nodes"], a["gpus_per_node"]) != (b["nodes"], b["gpus_per_node"]):
        return False
    ha, hb = a["hardware"], b["hardware"]
    if any(ha[d] < hb[d] for d in HW_DIMS):
        return False
    return any(ha[d] > hb[d] for d in HW_DIMS) or ha == hb


def placement_mode(path: str) -> int:
    with open(path) as f:
        doc = json.load(f)
    shapes = list(doc.get("placements") or []) + list(doc.get("pruned") or [])
    if not shapes:
        raise SystemExit(f"FAIL: {path} has no shapes")
    for s in shapes:
        missing = [d for d in HW_DIMS if d not in (s.get("hardware") or {})]
        if missing:
            raise SystemExit(f"FAIL: shape {s.get('label')} lacks hardware dims {missing}")
    by_label = {s["label"]: s for s in shapes}
    ok = True
    compared = 0
    for a in shapes:
        for b in shapes:
            if a is b or not dominates(a, b):
                continue
            wa, wb = a.get("best_wall"), b.get("best_wall")
            if wa is None or wb is None:
                continue  # pruned-without-plan: provenance-only below
            compared += 1
            if wa < wb:
                print(
                    f"FAIL: {a['label']} dominates {b['label']} in every hardware "
                    f"dimension but walls invert ({wa} < {wb} tokens) — dominance "
                    f"pruning would be lossy"
                )
                ok = False
    for p in doc.get("pruned") or []:
        dom_label = p.get("pruned_by")
        dom = by_label.get(dom_label)
        if dom is None:
            print(f"FAIL: {p['label']} pruned by unknown shape `{dom_label}`")
            ok = False
        elif not dominates(dom, p):
            print(
                f"FAIL: {p['label']} records dominator {dom_label}, but the "
                f"hardware objects do not dominate"
            )
            ok = False
    n_pruned = len(doc.get("pruned") or [])
    print(
        f"{path}: {len(shapes)} shapes, {n_pruned} dominated, "
        f"{compared} wall comparisons across dominating pairs"
    )
    if ok:
        print("fleet placement dominance OK")
    return 0 if ok else 1


def main() -> int:
    args = sys.argv[1:]
    if len(args) == 2 and args[0] == "--placement":
        return placement_mode(args[1])
    if len(args) < 2 or args[0].startswith("--"):
        print(__doc__)
        return 2
    return frontier_mode(args)


if __name__ == "__main__":
    sys.exit(main())
