#!/usr/bin/env python3
"""Cross-check the `repro frontier --at-lengths` artifact against the
`repro plan --json` artifact from the same CI run.

Usage: check_frontier_row.py <plan.json> <frontier_at_lengths.json>

The at-lengths artifact carries one deterministic plan core per requested
reference length; its row at the plan artifact's own reference length must
be byte-identical to that plan's deterministic core. Anything else means
symbolic pricing changed a ranking, a throughput, or a Pareto flag — the
exact regression the fitted step-time models must never introduce.

Both trees pass through the same json parse + dump here, so float
round-trip differences cancel and the comparison is about values, not
formatting. Run accounting (probe/sim counters, wall-clock) is stripped
from the plan artifact first: it describes one run, not the plan.
"""

import json
import sys

# Per-run accounting keys appended to the CLI plan JSON after the
# deterministic core (see report/planner.rs `accounting_pairs`).
ACCOUNTING_KEYS = (
    "simulations",
    "feasibility_probes",
    "priced_sims",
    "modeled_prices",
    "symbolic_models",
    "symbolic_fallbacks",
    "time_models",
    "time_fallbacks",
    "trace_cache",
    "wall_s",
)


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    plan = json.load(open(sys.argv[1]))
    frontier = json.load(open(sys.argv[2]))

    core = {k: v for k, v in plan.items() if k not in ACCOUNTING_KEYS}
    reference_s = core["reference_s"]

    rows = frontier.get("rows")
    if not rows:
        print("FAIL: at-lengths artifact has no rows")
        return 1
    row = next((r for r in rows if r.get("reference_s") == reference_s), None)
    if row is None:
        lengths = [r.get("reference_s") for r in rows]
        print(f"FAIL: no row at reference length {reference_s} (rows: {lengths})")
        return 1

    want = json.dumps(core, sort_keys=True)
    got = json.dumps(row["result"], sort_keys=True)
    if want != got:
        print(f"FAIL: at-lengths row at {reference_s} differs from the plan core")
        for key in core:
            a = json.dumps(core[key], sort_keys=True)
            b = json.dumps(row["result"].get(key), sort_keys=True)
            if a != b:
                print(f"  mismatched `{key}`:\n    plan:     {a[:400]}\n    frontier: {b[:400]}")
        return 1

    acct = frontier.get("accounting", {})
    print(
        f"at-lengths row at {reference_s} matches the plan core byte-for-byte "
        f"({len(rows)} rows; accounting: {json.dumps(acct)})"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
