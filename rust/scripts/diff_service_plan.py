#!/usr/bin/env python3
"""Compare a `repro plan --json` artifact against a `repro serve-plan`
`/v1/plan` response for the same preset.

Usage: diff_service_plan.py <cli_plan.json> <service_response.json>
       diff_service_plan.py <cli_plan.json> <http://host:port/v1/plan>
           [--body JSON] [--retries N]

The second argument is either a saved response file or the live
endpoint; with a URL the script POSTs `--body` (default: the 8x-H100
llama3-8b preset) itself, retrying transient connection resets
`--retries` times with backoff so a daemon mid-accept-loop hiccup does
not fail the lane.

The service's `result` is the CLI plan JSON minus run accounting
(`simulations`, `feasibility_probes`, `priced_sims`, `symbolic_models`,
`symbolic_fallbacks`, `trace_cache`, `wall_s`) — those describe one run,
not the plan, and a warm session legitimately reports different numbers.
Everything else must match exactly: same configs, same walls, same
ranking, same floats. Exits non-zero on any divergence — this is the CI
gate that the daemon and the CLI can never drift apart.
"""

import argparse
import json
import sys
import time
import urllib.error
import urllib.request

ACCOUNTING = (
    "simulations",
    "feasibility_probes",
    "priced_sims",
    "symbolic_models",
    "symbolic_fallbacks",
    "trace_cache",
    "wall_s",
)

DEFAULT_BODY = '{"model":"llama3-8b","gpus":8}'


def fetch(url: str, body: str, retries: int):
    delay = 0.2
    for attempt in range(1, retries + 1):
        try:
            req = urllib.request.Request(
                url, data=body.encode(), headers={"Content-Type": "application/json"}
            )
            with urllib.request.urlopen(req, timeout=60) as r:
                return json.loads(r.read().decode())
        except urllib.error.HTTPError as e:
            # A structured 4xx/5xx envelope is a real answer, not a
            # transient reset: surface it for the divergence report.
            return json.loads(e.read().decode())
        except (urllib.error.URLError, OSError, ValueError) as e:
            if attempt == retries:
                raise
            print(f"attempt {attempt}/{retries} failed ({e}); retrying")
            time.sleep(delay)
            delay = min(delay * 2, 2.0)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("cli_plan")
    ap.add_argument("service", help="response file, or the /v1/plan URL to POST")
    ap.add_argument("--body", default=DEFAULT_BODY)
    ap.add_argument("--retries", type=int, default=1)
    args = ap.parse_args()
    cli = json.load(open(args.cli_plan))
    if args.service.startswith(("http://", "https://")):
        resp = fetch(args.service, args.body, max(1, args.retries))
    else:
        resp = json.load(open(args.service))
    if resp.get("api_version") != 1:
        print(f"FAIL: service response api_version {resp.get('api_version')!r} != 1")
        return 1
    if "error" in resp:
        print(f"FAIL: service answered an error: {resp['error']}")
        return 1
    result = resp.get("result")
    if not isinstance(result, dict):
        print("FAIL: service response has no `result` object")
        return 1
    expected = {k: v for k, v in cli.items() if k not in ACCOUNTING}
    if result == expected:
        n = len(result.get("configs", []))
        print(f"service /v1/plan matches the CLI plan exactly ({n} configs)")
        return 0
    # Pinpoint every diverging field for the CI log.
    for k in sorted(set(expected) | set(result)):
        if expected.get(k) != result.get(k):
            print(f"FAIL: field `{k}` differs")
            print(f"  cli:     {json.dumps(expected.get(k))[:400]}")
            print(f"  service: {json.dumps(result.get(k))[:400]}")
    return 1


if __name__ == "__main__":
    sys.exit(main())
