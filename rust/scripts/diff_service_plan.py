#!/usr/bin/env python3
"""Compare a `repro plan --json` artifact against a `repro serve-plan`
`/v1/plan` response for the same preset.

Usage: diff_service_plan.py <cli_plan.json> <service_response.json>

The service's `result` is the CLI plan JSON minus run accounting
(`simulations`, `feasibility_probes`, `priced_sims`, `symbolic_models`,
`symbolic_fallbacks`, `trace_cache`, `wall_s`) — those describe one run,
not the plan, and a warm session legitimately reports different numbers.
Everything else must match exactly: same configs, same walls, same
ranking, same floats. Exits non-zero on any divergence — this is the CI
gate that the daemon and the CLI can never drift apart.
"""

import json
import sys

ACCOUNTING = (
    "simulations",
    "feasibility_probes",
    "priced_sims",
    "symbolic_models",
    "symbolic_fallbacks",
    "trace_cache",
    "wall_s",
)


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    cli = json.load(open(sys.argv[1]))
    resp = json.load(open(sys.argv[2]))
    if resp.get("api_version") != 1:
        print(f"FAIL: service response api_version {resp.get('api_version')!r} != 1")
        return 1
    if "error" in resp:
        print(f"FAIL: service answered an error: {resp['error']}")
        return 1
    result = resp.get("result")
    if not isinstance(result, dict):
        print("FAIL: service response has no `result` object")
        return 1
    expected = {k: v for k, v in cli.items() if k not in ACCOUNTING}
    if result == expected:
        n = len(result.get("configs", []))
        print(f"service /v1/plan matches the CLI plan exactly ({n} configs)")
        return 0
    # Pinpoint every diverging field for the CI log.
    for k in sorted(set(expected) | set(result)):
        if expected.get(k) != result.get(k):
            print(f"FAIL: field `{k}` differs")
            print(f"  cli:     {json.dumps(expected.get(k))[:400]}")
            print(f"  service: {json.dumps(result.get(k))[:400]}")
    return 1


if __name__ == "__main__":
    sys.exit(main())
