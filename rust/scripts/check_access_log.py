#!/usr/bin/env python3
"""Validate a serve-plan JSONL access log and cross-check it against a
`/metrics` scrape.

Schema: every line must parse as JSON and carry exactly the documented
fields — ts_ms, endpoint, status, ms, bytes, memo (hit|miss|none),
shed, deadline, quarantined, keep — with the right types.

Cross-check: for each endpoint, the number of non-shed log lines must
equal `repro_http_requests_total{endpoint="..."}` from the scrape.
Shed lines (queue-full / draining refusals) are excluded — they are
answered from the accept loop and never reach the request counters.
The `metrics` endpoint itself is allowed one extra log line: the scrape
that produced the metrics file is logged after its own text rendered.

Usage: check_access_log.py <access.jsonl> [--metrics metrics.txt]
"""

import argparse
import json
import re
import sys
from collections import Counter

SCHEMA = {
    "ts_ms": int,
    "endpoint": str,
    "status": int,
    "ms": (int, float),
    "bytes": int,
    "memo": str,
    "shed": bool,
    "deadline": bool,
    "quarantined": bool,
    "keep": bool,
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("log")
    ap.add_argument("--metrics", help="a /metrics text scrape to cross-check against")
    args = ap.parse_args()

    counts: Counter = Counter()
    sheds = 0
    with open(args.log) as f:
        for lineno, raw in enumerate(f, 1):
            raw = raw.strip()
            if not raw:
                continue
            try:
                line = json.loads(raw)
            except ValueError as e:
                print(f"FAIL: line {lineno} is not JSON ({e}): {raw[:200]}")
                return 1
            if set(line) != set(SCHEMA):
                print(f"FAIL: line {lineno} fields {sorted(line)} != {sorted(SCHEMA)}")
                return 1
            for key, want in SCHEMA.items():
                # bool is an int subclass: check it first and exactly.
                ok = (
                    isinstance(line[key], bool)
                    if want is bool
                    else not isinstance(line[key], bool) and isinstance(line[key], want)
                )
                if not ok:
                    print(f"FAIL: line {lineno} field `{key}` = {line[key]!r}: wrong type")
                    return 1
            if line["memo"] not in ("hit", "miss", "none"):
                print(f"FAIL: line {lineno} memo {line['memo']!r}")
                return 1
            if not 100 <= line["status"] <= 599:
                print(f"FAIL: line {lineno} status {line['status']}")
                return 1
            if line["shed"]:
                sheds += 1
            else:
                counts[line["endpoint"]] += 1

    total = sum(counts.values())
    print(f"{total + sheds} access-log lines valid ({total} served, {sheds} shed)")
    if not args.metrics:
        return 0

    metric: Counter = Counter()
    pat = re.compile(r'^repro_http_requests_total\{endpoint="(\w+)"\}\s+(\d+)$')
    with open(args.metrics) as f:
        for raw in f:
            m = pat.match(raw.strip())
            if m:
                metric[m.group(1)] = int(m.group(2))
    if not metric:
        print("FAIL: no repro_http_requests_total counters in the metrics scrape")
        return 1
    ok = True
    for ep in sorted(set(counts) | set(metric)):
        logged, scraped = counts[ep], metric[ep]
        slack = 1 if ep == "metrics" else 0
        if not scraped <= logged <= scraped + slack:
            print(f"FAIL: endpoint `{ep}`: {logged} log lines vs {scraped} in /metrics")
            ok = False
    if ok:
        print(f"access log agrees with /metrics across {len(set(counts) | set(metric))} endpoints")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
