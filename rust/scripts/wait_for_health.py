#!/usr/bin/env python3
"""Poll a serve-plan daemon's `/v1/health` until it answers 200 with
`"status": "ok"`, retrying with exponential backoff. Replaces the CI
fixed-sleep `for i in $(seq ...); do curl ...; sleep ...` boot loops:
fast when the daemon is fast, patient when the runner is slow, and a
loud non-zero exit when the daemon never comes up.

Usage: wait_for_health.py <health_url> [--retries N] [--backoff SECONDS]

`--backoff` is the first delay; it doubles per attempt, capped at 2s.
"""

import argparse
import json
import sys
import time
import urllib.error
import urllib.request


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("url", help="e.g. http://127.0.0.1:8077/v1/health")
    ap.add_argument("--retries", type=int, default=40)
    ap.add_argument("--backoff", type=float, default=0.1)
    args = ap.parse_args()
    delay = args.backoff
    last = "no attempt made"
    for attempt in range(1, args.retries + 1):
        try:
            with urllib.request.urlopen(args.url, timeout=5) as r:
                body = r.read().decode()
                if r.status == 200 and json.loads(body).get("status") == "ok":
                    print(f"healthy after {attempt} attempt(s)")
                    return 0
                last = f"status {r.status}"
        except (urllib.error.URLError, OSError, ValueError) as e:
            last = str(e)
        time.sleep(delay)
        delay = min(delay * 2, 2.0)
    print(
        f"FAIL: {args.url} not healthy after {args.retries} attempts (last: {last})",
        file=sys.stderr,
    )
    return 1


if __name__ == "__main__":
    sys.exit(main())
