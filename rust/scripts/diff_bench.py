#!/usr/bin/env python3
"""Diff two BENCH_planner.json files (previous CI artifact vs current run)
and fail on a large planner-throughput regression.

Usage: diff_bench.py <previous.json> <current.json> [max_regression]

`max_regression` is the allowed slowdown factor on configs/sec (default 3.0:
CI runners are noisy and the sweep space legitimately grows; the gate is for
order-of-magnitude engine regressions, not percent-level noise).
"""

import json
import sys


def main() -> int:
    if len(sys.argv) < 3:
        print(__doc__)
        return 2
    try:
        prev = json.load(open(sys.argv[1]))
    except (OSError, ValueError) as e:
        # A corrupt/truncated previous artifact is a baseline problem, not a
        # regression: treat it like a missing baseline and reset.
        print(f"previous artifact unreadable ({e}); baseline resets")
        prev = {}
    cur = json.load(open(sys.argv[2]))  # current must be readable — fail loudly
    max_regression = float(sys.argv[3]) if len(sys.argv) > 3 else 3.0

    for key in ("configs_per_sec", "sims_per_sec", "plan_wall_s_mean", "configs"):
        p, c = prev.get(key), cur.get(key)
        print(f"{key}: prev {p} -> cur {c}")

    c = float(cur.get("configs_per_sec") or 0.0)
    if c <= 0.0:
        # A missing/zero current value means the bench emitter broke — that
        # must fail the gate, not silently disable it.
        print("FAIL: current BENCH_planner.json has no usable configs_per_sec")
        return 1
    p = float(prev.get("configs_per_sec") or 0.0)
    if p <= 0.0:
        print("previous artifact has no usable configs_per_sec; baseline resets")
        return 0
    if c < p / max_regression:
        print(
            f"FAIL: planner throughput regressed more than {max_regression}x "
            f"({p:.1f} -> {c:.1f} configs/sec)"
        )
        return 1
    print("planner perf trajectory OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
