#!/usr/bin/env python3
"""Diff two BENCH_planner.json files (previous CI artifact vs current run)
and fail on a large planner-throughput regression.

Usage: diff_bench.py <previous.json> <current.json> [max_regression]

`max_regression` is the allowed slowdown factor (default 3.0: CI runners
are noisy and the sweep space legitimately grows; the gate is for
order-of-magnitude engine regressions, not percent-level noise).

Gated metrics — each phase of the two-phase evaluator fails independently:
- configs_per_sec            (whole-sweep throughput)
- walls_per_sec              (symbolic walls-only sweep: the
                              --feasibility-only multi-node frontier path)
- frontier_per_sec           (Pareto rows extracted per second of full
                              sweep: the symbolic-pricing payoff)
- modeled_prices_per_sec     (phase-2 cells priced by the streamed timing
                              kernel instead of a full simulation)
- warm_requests_per_sec      (planner-service warm path: repeated requests
                              answered from one session's plan memo)
- warm_http_requests_per_sec (the same warm request through the daemon over
                              one keep-alive connection: wire parse + memo
                              hit + response framing, no TCP handshake)
- feasibility_probes_per_sec (phase 1: streamed peak-only probes)
- priced_sims_per_sec        (phase 2: trace build + full pricing)
- placements_per_sec         (fleet placement sweep: shapes disposed of per
                              second — enumerate + dominance pruning + one
                              priced sweep on the surviving shape)
- observations_per_sec       (online-calibration ingest: telemetry records
                              inverted, MAD-gated and drift-checked per
                              second, steady state with no epoch publish)

A metric missing from the *previous* artifact resets its baseline (first
run after the metric landed); missing from the *current* file fails — the
bench emitter must not silently drop a gate.
"""

import json
import sys

GATED = (
    "configs_per_sec",
    "walls_per_sec",
    "frontier_per_sec",
    "modeled_prices_per_sec",
    "warm_requests_per_sec",
    "warm_http_requests_per_sec",
    "feasibility_probes_per_sec",
    "priced_sims_per_sec",
    "placements_per_sec",
    "observations_per_sec",
)
REPORTED = GATED + (
    "sims_per_sec",
    "plan_wall_s_mean",
    "configs",
    "feasibility_probes_per_plan",
    "symbolic_models",
    "symbolic_fallbacks",
    "shapes_pruned",
)


def main() -> int:
    if len(sys.argv) < 3:
        print(__doc__)
        return 2
    try:
        prev = json.load(open(sys.argv[1]))
    except (OSError, ValueError) as e:
        # A corrupt/truncated previous artifact is a baseline problem, not a
        # regression: treat it like a missing baseline and reset.
        print(f"previous artifact unreadable ({e}); baseline resets")
        prev = {}
    cur = json.load(open(sys.argv[2]))  # current must be readable — fail loudly
    max_regression = float(sys.argv[3]) if len(sys.argv) > 3 else 3.0

    for key in REPORTED:
        print(f"{key}: prev {prev.get(key)} -> cur {cur.get(key)}")

    failures = []
    for key in GATED:
        c = float(cur.get(key) or 0.0)
        if c <= 0.0:
            # A missing/zero current value means the bench emitter broke —
            # that must fail the gate, not silently disable it.
            failures.append(f"current BENCH_planner.json has no usable {key}")
            continue
        p = float(prev.get(key) or 0.0)
        if p <= 0.0:
            print(f"{key}: no previous baseline; resets")
            continue
        if c < p / max_regression:
            failures.append(
                f"{key} regressed more than {max_regression}x ({p:.1f} -> {c:.1f})"
            )
    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        return 1
    print("planner perf trajectory OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
