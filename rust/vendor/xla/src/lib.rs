//! Offline stub of the `xla` PJRT bindings.
//!
//! The real dependency (PJRT CPU client + HLO-text compilation) is a native
//! library that is not part of the offline vendor set. This stub mirrors the
//! exact API subset `untied_ulysses::runtime` uses so the whole crate —
//! simulator, planner, report generators, CLI — builds and tests without it.
//! Every entry point that would touch PJRT returns an error at *runtime*;
//! nothing panics. See README.md in this directory for how to swap in the
//! real bindings.

use std::fmt;
use std::path::Path;

/// Stub error: carries the message shown to users who hit the PJRT paths.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: xla PJRT bindings are not available in this build \
         (offline stub; see rust/vendor/xla/README.md)"
    )))
}

/// Element types moved across the PJRT boundary.
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for i32 {}

/// Host-side literal (stub: shapeless placeholder).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Parsed HLO module (stub: opaque).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation handle (stub: opaque).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by an execution (stub: opaque).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable (stub: opaque).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client (stub: construction always fails, so no other stub method is
/// reachable through the runtime layer).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pjrt_paths_error_instead_of_panicking() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
    }

    #[test]
    fn error_message_names_the_stub() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline stub"));
    }
}
