//! Minimal scoped worker pool (rayon is not in the offline vendor set):
//! an order-preserving parallel map over a slice, plus a blocking
//! [`JobQueue`] for long-lived worker threads. Map workers claim items
//! from a shared counter, so uneven per-item cost (a cheap Native
//! bisection vs an expensive FPDT π=64 one) balances automatically; queue
//! workers block on a condvar, so the `serve-plan` daemon's accept loop
//! can hand connections to however many handler threads are configured.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// Default worker count: the machine's parallelism, capped — planner items
/// are short and share memoization locks, so more threads only contend.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Map `f` over `items` on `threads` workers (0 = auto), preserving input
/// order in the returned vector. `f` receives `(index, &item)`.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = if threads == 0 { default_threads() } else { threads }.min(n);
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<R>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                slots.lock().unwrap()[i] = Some(r);
            });
        }
    });
    slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("pool worker dropped an item"))
        .collect()
}

/// Blocking multi-producer multi-consumer FIFO for long-lived workers
/// (the HTTP daemon's connection queue). `pop` parks the caller until an
/// item arrives or the queue is closed; closing wakes everyone, drains
/// the remaining items, then yields `None` — the worker-loop shutdown
/// signal.
pub struct JobQueue<T> {
    state: Mutex<(VecDeque<T>, bool)>,
    ready: Condvar,
}

impl<T> JobQueue<T> {
    pub fn new() -> Self {
        JobQueue { state: Mutex::new((VecDeque::new(), false)), ready: Condvar::new() }
    }

    /// Enqueue an item; `false` (item dropped) after `close`.
    pub fn push(&self, item: T) -> bool {
        let mut g = self.state.lock().unwrap();
        if g.1 {
            return false;
        }
        g.0.push_back(item);
        self.ready.notify_one();
        true
    }

    /// Dequeue, blocking while the queue is open and empty. `None` once
    /// the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.state.lock().unwrap();
        loop {
            if let Some(item) = g.0.pop_front() {
                return Some(item);
            }
            if g.1 {
                return None;
            }
            g = self.ready.wait(g).unwrap();
        }
    }

    /// Close the queue: pending items still drain, new pushes are
    /// refused, blocked and future `pop`s return `None` once empty.
    pub fn close(&self) {
        self.state.lock().unwrap().1 = true;
        self.ready.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Default for JobQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = parallel_map(&items, 4, |i, &x| x * 2 + i as u64);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, items[i] * 3);
        }
    }

    #[test]
    fn degenerate_inputs() {
        let empty: [u64; 0] = [];
        assert!(parallel_map(&empty, 4, |_, &x| x).is_empty());
        assert_eq!(parallel_map(&[7u64], 8, |_, &x| x + 1), vec![8]);
        assert_eq!(parallel_map(&[1u64, 2, 3], 1, |_, &x| x * x), vec![1, 4, 9]);
    }

    #[test]
    fn auto_thread_count_is_sane() {
        let t = default_threads();
        assert!((1..=16).contains(&t));
    }

    #[test]
    fn job_queue_fifo_and_close() {
        let q: JobQueue<u64> = JobQueue::new();
        assert!(q.is_empty());
        assert!(q.push(1));
        assert!(q.push(2));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        q.close();
        // Pending items drain after close; new pushes are refused.
        assert!(!q.push(3));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn job_queue_feeds_blocked_workers() {
        let q: JobQueue<u64> = JobQueue::new();
        let total = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..3 {
                scope.spawn(|| {
                    while let Some(v) = q.pop() {
                        total.fetch_add(v as usize, Ordering::Relaxed);
                    }
                });
            }
            // Workers are (or will be) parked on the condvar; feed them.
            for v in 1..=100u64 {
                assert!(q.push(v));
            }
            q.close();
        });
        assert_eq!(total.load(Ordering::Relaxed), 5050);
        assert_eq!(q.pop(), None, "closed and drained");
    }
}
