//! Minimal scoped worker pool (rayon is not in the offline vendor set):
//! an order-preserving parallel map over a slice. Workers claim items from
//! a shared counter, so uneven per-item cost (a cheap Native bisection vs
//! an expensive FPDT π=64 one) balances automatically.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Default worker count: the machine's parallelism, capped — planner items
/// are short and share memoization locks, so more threads only contend.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Map `f` over `items` on `threads` workers (0 = auto), preserving input
/// order in the returned vector. `f` receives `(index, &item)`.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = if threads == 0 { default_threads() } else { threads }.min(n);
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<R>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                slots.lock().unwrap()[i] = Some(r);
            });
        }
    });
    slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("pool worker dropped an item"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = parallel_map(&items, 4, |i, &x| x * 2 + i as u64);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, items[i] * 3);
        }
    }

    #[test]
    fn degenerate_inputs() {
        let empty: [u64; 0] = [];
        assert!(parallel_map(&empty, 4, |_, &x| x).is_empty());
        assert_eq!(parallel_map(&[7u64], 8, |_, &x| x + 1), vec![8]);
        assert_eq!(parallel_map(&[1u64, 2, 3], 1, |_, &x| x * x), vec![1, 4, 9]);
    }

    #[test]
    fn auto_thread_count_is_sane() {
        let t = default_threads();
        assert!((1..=16).contains(&t));
    }
}
