//! Minimal property-testing harness (proptest is not in the offline vendor
//! set). `check` runs a property over `n` random cases and, on failure,
//! retries with a simple halving shrink over the integer parameters so the
//! reported counterexample is small.

use super::rng::Rng;

/// Run `prop` over `n` random integer vectors drawn from `ranges`
/// (inclusive). Panics with the (shrunk) counterexample on failure.
pub fn check(name: &str, n: usize, ranges: &[(i64, i64)], prop: impl Fn(&[i64]) -> bool) {
    let mut rng = Rng::new(0xC0FFEE ^ name.len() as u64);
    for case in 0..n {
        let args: Vec<i64> = ranges.iter().map(|&(lo, hi)| rng.range(lo, hi)).collect();
        if !prop(&args) {
            let shrunk = shrink(&args, ranges, &prop);
            panic!("property `{name}` failed on case {case}: args={shrunk:?} (orig {args:?})");
        }
    }
}

/// Per-argument bisection shrink: for each argument find the smallest value
/// (others fixed) for which the property still fails; repeat until a fixed
/// point.
fn shrink(args: &[i64], ranges: &[(i64, i64)], prop: &impl Fn(&[i64]) -> bool) -> Vec<i64> {
    let mut cur = args.to_vec();
    loop {
        let mut improved = false;
        for i in 0..cur.len() {
            let range_lo = ranges[i].0;
            let mut cand = cur.clone();
            cand[i] = range_lo;
            if !prop(&cand) {
                // fails at the lower bound already
                if cur[i] != range_lo {
                    cur = cand;
                    improved = true;
                }
                continue;
            }
            // invariant: prop passes at `lo`, fails at `hi`
            let (mut lo, mut hi) = (range_lo, cur[i]);
            while hi - lo > 1 {
                let mid = lo + (hi - lo) / 2;
                cand[i] = mid;
                if prop(&cand) {
                    lo = mid;
                } else {
                    hi = mid;
                }
            }
            if hi != cur[i] {
                cur[i] = hi;
                improved = true;
            }
        }
        if !improved {
            return cur;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true() {
        check("true", 50, &[(0, 100), (0, 100)], |_| true);
    }

    #[test]
    #[should_panic(expected = "property `gt` failed")]
    fn fails_and_shrinks() {
        check("gt", 200, &[(0, 1000)], |a| a[0] < 500);
    }

    #[test]
    fn shrink_reaches_minimal() {
        // Failing iff x >= 500 must shrink to exactly 500.
        let s = shrink(&[987], &[(0, 1000)], &|a: &[i64]| a[0] < 500);
        assert_eq!(s, vec![500]);
    }

    #[test]
    fn shrink_multiarg() {
        // fails iff a+b >= 100; shrink should land on a minimal boundary.
        let s = shrink(&[90, 80], &[(0, 100), (0, 100)], &|a: &[i64]| {
            a[0] + a[1] < 100
        });
        assert_eq!(s[0] + s[1], 100);
    }
}
