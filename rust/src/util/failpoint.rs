//! Deterministic failpoints: named fault-injection sites planted at the
//! evaluator/cache/IO boundaries (streamed probe, model fit, pricing
//! sim, memo insert, socket write) so tests can prove the service
//! degrades predictably — and recovers byte-identically — under
//! injected faults.
//!
//! The registry is **zero-cost when disabled**: every [`fire`] call is
//! one relaxed atomic load until something configures a site, so the
//! layer can stay compiled into release builds (the bench suite gates
//! exactly this: `warm_http_requests_per_sec` with failpoints present
//! but off).
//!
//! Per-site policies, written `site=policy` and joined with `;`:
//!
//! | policy          | behavior at the site                               |
//! |-----------------|----------------------------------------------------|
//! | `off`           | no-op                                              |
//! | `err(n)`        | fail the next `n` passages, then disarm            |
//! | `panic(n)`      | panic on the next `n` passages, then disarm        |
//! | `delay(ms)`     | sleep `ms` on every passage (slow-path injection)   |
//! | `flaky(seed,p)` | fail each passage with probability `p`% drawn from  |
//! |                 | a per-site PRNG seeded with `seed` — a *seeded      |
//! |                 | schedule*: deterministic given seed and call order  |
//!
//! Activation: `REPRO_FAILPOINTS="planner.probe=err(2);http.write=delay(5)"`
//! in the environment (read once by [`init_from_env`], which the CLI
//! daemon calls at startup) or programmatically via [`configure`] /
//! [`set`] from tests. At sites inside infallible evaluator closures,
//! [`fire_or_panic`] escalates an injected error to a panic — the
//! service-level firewall catches it, quarantines the cell, and the
//! request answers a structured 500.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

use super::rng::Rng;

/// Fast-path gate: `false` means no site anywhere is armed and every
/// [`fire`] is a single relaxed load.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// What a site does when execution passes through it.
#[derive(Clone, Debug, PartialEq)]
pub enum Policy {
    Off,
    /// Fail the next `n` passages with an injected error, then disarm.
    Err(u64),
    /// Panic on the next `n` passages, then disarm.
    Panic(u64),
    /// Sleep this many milliseconds on every passage.
    Delay(u64),
    /// Seeded schedule: fail each passage with probability `percent`%
    /// drawn from a PRNG seeded with `seed` (deterministic given seed
    /// and call order).
    Flaky { seed: u64, percent: u64 },
}

struct SiteState {
    policy: Policy,
    /// Per-site deterministic stream for `Flaky` draws.
    rng: Rng,
    /// Times the policy actually fired (injected an error, panic, or
    /// delay) — test assertions read this via [`triggered`].
    triggered: u64,
}

fn registry() -> MutexGuard<'static, HashMap<String, SiteState>> {
    static REGISTRY: OnceLock<Mutex<HashMap<String, SiteState>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(HashMap::new())).lock().unwrap()
}

/// Arm one site. `Policy::Off` disarms it (the registry entry stays so
/// its `triggered` count survives for assertions).
pub fn set(site: &str, policy: Policy) {
    let mut reg = registry();
    let seed = match &policy {
        Policy::Flaky { seed, .. } => *seed,
        _ => 0,
    };
    let entry = reg.entry(site.to_string()).or_insert_with(|| SiteState {
        policy: Policy::Off,
        rng: Rng::new(seed),
        triggered: 0,
    });
    if let Policy::Flaky { seed, .. } = &policy {
        entry.rng = Rng::new(*seed);
    }
    entry.policy = policy;
    // Arming any site opens the fast-path gate; it closes again only on
    // `clear_all` — a disarmed-by-decrement site just takes the (cheap)
    // slow path to a no-op.
    if reg.values().any(|s| s.policy != Policy::Off) {
        ENABLED.store(true, Ordering::Relaxed);
    }
}

/// Parse and apply a spec: `site=policy[;site=policy...]`. Unknown
/// policies are loud errors — a typo must not silently disable a fault
/// schedule a test depends on.
pub fn configure(spec: &str) -> Result<(), String> {
    for part in spec.split(';') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (site, policy) = part
            .split_once('=')
            .ok_or_else(|| format!("failpoint spec `{part}` is not site=policy"))?;
        set(site.trim(), parse_policy(policy.trim())?);
    }
    Ok(())
}

fn parse_policy(s: &str) -> Result<Policy, String> {
    let (head, arg) = match s.split_once('(') {
        Some((h, rest)) => {
            let inner = rest
                .strip_suffix(')')
                .ok_or_else(|| format!("failpoint policy `{s}` is missing `)`"))?;
            (h, Some(inner))
        }
        None => (s, None),
    };
    let num = |a: Option<&str>, default: u64| -> Result<u64, String> {
        match a {
            None => Ok(default),
            Some(v) => {
                v.trim().parse().map_err(|_| format!("failpoint policy `{s}`: bad number"))
            }
        }
    };
    match head {
        "off" => Ok(Policy::Off),
        "err" => Ok(Policy::Err(num(arg, 1)?)),
        "panic" => Ok(Policy::Panic(num(arg, 1)?)),
        "delay" => Ok(Policy::Delay(num(arg, 1)?)),
        "flaky" => {
            let inner = arg.ok_or_else(|| format!("failpoint policy `{s}` needs (seed,pct)"))?;
            let (a, b) = inner
                .split_once(',')
                .ok_or_else(|| format!("failpoint policy `{s}` needs (seed,pct)"))?;
            Ok(Policy::Flaky { seed: num(Some(a), 0)?, percent: num(Some(b), 0)?.min(100) })
        }
        _ => Err(format!("unknown failpoint policy `{s}`")),
    }
}

/// Disarm every site, reset counters, and close the fast-path gate.
pub fn clear_all() {
    let mut reg = registry();
    reg.clear();
    ENABLED.store(false, Ordering::Relaxed);
}

/// Read `REPRO_FAILPOINTS` once at daemon startup. A malformed spec is
/// returned as an error so the CLI can refuse to start with a fault
/// schedule it did not understand.
pub fn init_from_env() -> Result<(), String> {
    match std::env::var("REPRO_FAILPOINTS") {
        Ok(spec) if !spec.trim().is_empty() => configure(&spec),
        _ => Ok(()),
    }
}

/// Whether any site has ever been armed this process (the gate is open).
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// How many times `site`'s policy actually fired.
pub fn triggered(site: &str) -> u64 {
    if !enabled() {
        return 0;
    }
    registry().get(site).map_or(0, |s| s.triggered)
}

enum Action {
    Pass,
    Fail,
    Panic,
    Sleep(Duration),
}

fn evaluate(site: &str) -> Action {
    let mut reg = registry();
    let Some(state) = reg.get_mut(site) else { return Action::Pass };
    match state.policy {
        Policy::Off => Action::Pass,
        Policy::Err(n) => {
            state.policy = if n > 1 { Policy::Err(n - 1) } else { Policy::Off };
            state.triggered += 1;
            Action::Fail
        }
        Policy::Panic(n) => {
            state.policy = if n > 1 { Policy::Panic(n - 1) } else { Policy::Off };
            state.triggered += 1;
            Action::Panic
        }
        Policy::Delay(ms) => {
            state.triggered += 1;
            Action::Sleep(Duration::from_millis(ms))
        }
        Policy::Flaky { percent, .. } => {
            if state.rng.below(100) < percent {
                state.triggered += 1;
                Action::Fail
            } else {
                Action::Pass
            }
        }
    }
}

/// Pass through the site named `site`. `Ok(())` when disarmed (the
/// common case: one relaxed load); an armed `err`/`flaky` policy
/// returns the injected error, `delay` sleeps, `panic` panics. The
/// registry lock is released before sleeping or panicking.
pub fn fire(site: &str) -> Result<(), String> {
    if !ENABLED.load(Ordering::Relaxed) {
        return Ok(());
    }
    match evaluate(site) {
        Action::Pass => Ok(()),
        Action::Fail => Err(format!("failpoint `{site}`: injected error")),
        Action::Panic => panic!("failpoint `{site}`: injected panic"),
        Action::Sleep(d) => {
            std::thread::sleep(d);
            Ok(())
        }
    }
}

/// [`fire`] for infallible contexts (evaluator closures that return
/// plain values): an injected *error* escalates to a panic too, so the
/// service-level firewall is the single recovery path for both.
pub fn fire_or_panic(site: &str) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    if let Err(e) = fire(site) {
        panic!("{e}");
    }
}

/// Failpoint state is process-global; every test that arms a site (in
/// this module, the service layer, or the HTTP layer — they share one
/// test binary) funnels through this lock so arming in one test never
/// leaks into another running concurrently.
#[cfg(test)]
pub(crate) fn test_serial() -> MutexGuard<'static, ()> {
    static GATE: OnceLock<Mutex<()>> = OnceLock::new();
    GATE.get_or_init(|| Mutex::new(())).lock().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_sites_pass_and_report_zero() {
        let _g = test_serial();
        clear_all();
        assert!(!enabled());
        assert!(fire("nowhere").is_ok());
        assert_eq!(triggered("nowhere"), 0);
    }

    #[test]
    fn err_policy_fires_n_times_then_disarms() {
        let _g = test_serial();
        clear_all();
        set("t.err", Policy::Err(2));
        assert!(enabled());
        assert!(fire("t.err").is_err());
        assert!(fire("t.err").is_err());
        assert!(fire("t.err").is_ok(), "err(2) disarms after two firings");
        assert_eq!(triggered("t.err"), 2);
        clear_all();
    }

    #[test]
    fn spec_round_trip_and_bad_specs_are_loud() {
        let _g = test_serial();
        clear_all();
        configure("a.b=err(1); c.d = delay(0) ;;e.f=flaky(7,50)").unwrap();
        assert!(fire("a.b").is_err());
        assert!(fire("a.b").is_ok());
        assert!(fire("c.d").is_ok(), "delay(0) sleeps zero and passes");
        assert_eq!(triggered("c.d"), 1);
        // flaky(seed,50): deterministic stream — same seed, same verdicts.
        let first: Vec<bool> = (0..16).map(|_| fire("e.f").is_err()).collect();
        set("e.f", Policy::Flaky { seed: 7, percent: 50 });
        let second: Vec<bool> = (0..16).map(|_| fire("e.f").is_err()).collect();
        assert_eq!(first, second, "seeded schedule replays identically");
        assert!(first.iter().any(|&b| b) && first.iter().any(|&b| !b));
        assert!(configure("a=b=c").is_err());
        assert!(configure("x.y=explode").is_err());
        assert!(configure("x.y=err(two)").is_err());
        clear_all();
    }

    #[test]
    fn panic_policy_panics_and_disarms() {
        let _g = test_serial();
        clear_all();
        set("t.panic", Policy::Panic(1));
        let caught = std::panic::catch_unwind(|| fire_or_panic("t.panic"));
        assert!(caught.is_err(), "panic(1) must panic");
        fire_or_panic("t.panic"); // disarmed: passes
        assert_eq!(triggered("t.panic"), 1);
        clear_all();
    }
}
