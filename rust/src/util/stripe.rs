//! N-way lock-striped concurrent hash map plus the fast deterministic
//! hasher behind every hashed-key cache. The planner's worker pool used to
//! serialize on two global `Mutex<HashMap<String, _>>`s (the trace cache
//! and the report memo); striping the key space over independent locks
//! lets workers probing different cells proceed concurrently, and hashed
//! struct keys replace the old `format!`-built Strings.
//!
//! The hash function is [`FxHasher`], an FxHash-style multiply-rotate
//! hasher, not the standard library's SipHash. SipHash buys DoS resistance
//! the planner does not need (keys are derived from enumerated sweep
//! cells, never attacker-controlled) and costs ~1ns/word of keyed setup
//! and rounds; once the symbolic wall solver collapses bisections to O(1)
//! streamed probes, the per-probe `CellKey` hash is a measurable slice of
//! the remaining cell cost. FxHash is deterministic across runs and
//! processes (no random keys), which the stripe assignment and the
//! `CellKey` model fingerprint both rely on.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hash, Hasher};
use std::sync::Mutex;

/// Multiplier from the FxHash scheme (rustc's `FxHasher`): a single
/// odd 64-bit constant with well-mixed high bits.
const FX_SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// FxHash-style hasher: `hash = (hash rotl 5 ^ word) * SEED` per 8-byte
/// word. Deterministic (no per-process keys), ~1 multiply per word — a
/// good fit for small `Copy` struct keys hashed on every probe.
#[derive(Debug, Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(FX_SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in chunks.by_ref() {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rest = chunks.remainder();
        if !rest.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rest.len()].copy_from_slice(rest);
            // Fold the length in so "ab" and "ab\0" cannot collide.
            self.add(u64::from_le_bytes(tail) ^ ((rest.len() as u64) << 56));
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u16(&mut self, n: u16) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_u128(&mut self, n: u128) {
        self.add(n as u64);
        self.add((n >> 64) as u64);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

/// `BuildHasher` for [`FxHasher`]: plugs into `HashMap` and friends.
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// Hash one value with [`FxHasher`] — the fingerprint helper used by
/// `CellKey` for model dims (stable within and across processes).
pub fn fx_hash_one<T: Hash>(value: &T) -> u64 {
    let mut h = FxHasher::default();
    value.hash(&mut h);
    h.finish()
}

/// Default stripe count: enough that 16 planner workers rarely collide,
/// small enough that `len()` stays cheap.
pub const DEFAULT_STRIPES: usize = 16;

/// A concurrent insert-once map: values are cloned out (use `Arc`/`Copy`
/// values for large payloads). First writer wins on a racing key, so
/// concurrent builders converge on one canonical entry.
pub struct StripedMap<K, V> {
    stripes: Vec<Mutex<HashMap<K, V, FxBuildHasher>>>,
}

impl<K: Hash + Eq, V: Clone> StripedMap<K, V> {
    pub fn new(stripes: usize) -> Self {
        StripedMap {
            stripes: (0..stripes.max(1)).map(|_| Mutex::new(HashMap::default())).collect(),
        }
    }

    fn stripe(&self, key: &K) -> &Mutex<HashMap<K, V, FxBuildHasher>> {
        // FxHasher is deterministic (unlike RandomState), so stripe
        // assignment is stable across runs; the inner maps re-hash with
        // the same cheap function.
        &self.stripes[(fx_hash_one(key) as usize) % self.stripes.len()]
    }

    pub fn get(&self, key: &K) -> Option<V> {
        self.stripe(key).lock().unwrap().get(key).cloned()
    }

    /// Insert if absent; returns the canonical value (the existing one if
    /// another worker won the race). Build values *outside* this call —
    /// the stripe lock is held only for the map operation.
    pub fn insert(&self, key: K, value: V) -> V {
        self.stripe(&key).lock().unwrap().entry(key).or_insert(value).clone()
    }

    pub fn len(&self) -> usize {
        self.stripes.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every entry (stripe by stripe — not an atomic snapshot under
    /// concurrent writers). The planner-service session API uses this to
    /// evict its cross-request memos without tearing down the session.
    pub fn clear(&self) {
        for s in &self.stripes {
            s.lock().unwrap().clear();
        }
    }

    /// Fold over a snapshot of every entry (stripe by stripe). Used for
    /// end-of-sweep accounting (e.g. counting fitted vs fallen-back
    /// symbolic models); not a consistent point-in-time view under
    /// concurrent writers.
    pub fn fold<A>(&self, init: A, mut f: impl FnMut(A, &K, &V) -> A) -> A {
        let mut acc = init;
        for s in &self.stripes {
            for (k, v) in s.lock().unwrap().iter() {
                acc = f(acc, k, v);
            }
        }
        acc
    }
}

impl<K: Hash + Eq, V: Clone> Default for StripedMap<K, V> {
    fn default() -> Self {
        Self::new(DEFAULT_STRIPES)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_roundtrip() {
        let m: StripedMap<u64, u64> = StripedMap::default();
        assert!(m.is_empty());
        assert_eq!(m.get(&7), None);
        assert_eq!(m.insert(7, 70), 70);
        assert_eq!(m.get(&7), Some(70));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn first_writer_wins() {
        let m: StripedMap<u64, u64> = StripedMap::new(4);
        assert_eq!(m.insert(1, 10), 10);
        assert_eq!(m.insert(1, 99), 10, "racing insert returns the canonical value");
        assert_eq!(m.get(&1), Some(10));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn keys_spread_over_stripes() {
        let m: StripedMap<u64, u64> = StripedMap::new(8);
        for k in 0..256 {
            m.insert(k, k);
        }
        assert_eq!(m.len(), 256);
        let used = m.stripes.iter().filter(|s| !s.lock().unwrap().is_empty()).count();
        assert!(used >= 4, "only {used}/8 stripes used");
    }

    #[test]
    fn concurrent_inserts_converge() {
        let m: StripedMap<u64, u64> = StripedMap::new(8);
        std::thread::scope(|scope| {
            for t in 0..4u64 {
                let m = &m;
                scope.spawn(move || {
                    for k in 0..100 {
                        m.insert(k, t * 1000 + k);
                    }
                });
            }
        });
        assert_eq!(m.len(), 100);
        for k in 0..100 {
            let v = m.get(&k).unwrap();
            assert_eq!(v % 1000, k, "value for {k} must come from one canonical insert");
        }
    }

    #[test]
    fn clear_empties_every_stripe() {
        let m: StripedMap<u64, u64> = StripedMap::new(4);
        for k in 0..64 {
            m.insert(k, k);
        }
        assert_eq!(m.len(), 64);
        m.clear();
        assert!(m.is_empty());
        assert_eq!(m.get(&7), None);
        // The map stays usable after eviction.
        m.insert(7, 70);
        assert_eq!(m.get(&7), Some(70));
    }

    #[test]
    fn fold_visits_every_entry() {
        let m: StripedMap<u64, u64> = StripedMap::new(4);
        for k in 0..32 {
            m.insert(k, 2 * k);
        }
        let (count, sum) = m.fold((0u64, 0u64), |(c, s), _, v| (c + 1, s + v));
        assert_eq!(count, 32);
        assert_eq!(sum, (0..32).map(|k| 2 * k).sum::<u64>());
    }

    #[test]
    fn fx_hash_is_deterministic_and_spreads() {
        // Same value, same hash — across hasher instances (no random keys).
        assert_eq!(fx_hash_one(&42u64), fx_hash_one(&42u64));
        assert_ne!(fx_hash_one(&42u64), fx_hash_one(&43u64));
        // Byte-stream hashing: length folding keeps prefixes distinct.
        let mut a = FxHasher::default();
        a.write(b"ab");
        let mut b = FxHasher::default();
        b.write(b"ab\0");
        assert_ne!(a.finish(), b.finish());
        // Sequential keys land in many distinct buckets of a 16-way split.
        let mut seen = std::collections::HashSet::new();
        for k in 0u64..64 {
            seen.insert(fx_hash_one(&k) % 16);
        }
        assert!(seen.len() >= 8, "only {} buckets hit", seen.len());
    }

    #[test]
    fn fx_hash_mixed_width_writes() {
        // Tuple keys (the planner's memo keys) exercise the width-specific
        // write paths; equal tuples must agree, unequal must (here) differ.
        let k1 = (7u64, true, 3u32);
        let k2 = (7u64, false, 3u32);
        assert_eq!(fx_hash_one(&k1), fx_hash_one(&k1));
        assert_ne!(fx_hash_one(&k1), fx_hash_one(&k2));
    }
}
