//! N-way lock-striped concurrent hash map plus the fast deterministic
//! hasher behind every hashed-key cache. The planner's worker pool used to
//! serialize on two global `Mutex<HashMap<String, _>>`s (the trace cache
//! and the report memo); striping the key space over independent locks
//! lets workers probing different cells proceed concurrently, and hashed
//! struct keys replace the old `format!`-built Strings.
//!
//! The hash function is [`FxHasher`], an FxHash-style multiply-rotate
//! hasher, not the standard library's SipHash. SipHash buys DoS resistance
//! the planner does not need (keys are derived from enumerated sweep
//! cells, never attacker-controlled) and costs ~1ns/word of keyed setup
//! and rounds; once the symbolic wall solver collapses bisections to O(1)
//! streamed probes, the per-probe `CellKey` hash is a measurable slice of
//! the remaining cell cost. FxHash is deterministic across runs and
//! processes (no random keys), which the stripe assignment and the
//! `CellKey` model fingerprint both rely on.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hash, Hasher};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Multiplier from the FxHash scheme (rustc's `FxHasher`): a single
/// odd 64-bit constant with well-mixed high bits.
const FX_SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// FxHash-style hasher: `hash = (hash rotl 5 ^ word) * SEED` per 8-byte
/// word. Deterministic (no per-process keys), ~1 multiply per word — a
/// good fit for small `Copy` struct keys hashed on every probe.
#[derive(Debug, Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(FX_SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in chunks.by_ref() {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rest = chunks.remainder();
        if !rest.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rest.len()].copy_from_slice(rest);
            // Fold the length in so "ab" and "ab\0" cannot collide.
            self.add(u64::from_le_bytes(tail) ^ ((rest.len() as u64) << 56));
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u16(&mut self, n: u16) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_u128(&mut self, n: u128) {
        self.add(n as u64);
        self.add((n >> 64) as u64);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

/// `BuildHasher` for [`FxHasher`]: plugs into `HashMap` and friends.
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// Hash one value with [`FxHasher`] — the fingerprint helper used by
/// `CellKey` for model dims (stable within and across processes).
pub fn fx_hash_one<T: Hash>(value: &T) -> u64 {
    let mut h = FxHasher::default();
    value.hash(&mut h);
    h.finish()
}

/// Default stripe count: enough that 16 planner workers rarely collide,
/// small enough that `len()` stays cheap.
pub const DEFAULT_STRIPES: usize = 16;

/// One stored value plus the accounting the byte-budgeted LRU needs: an
/// approximate weight (fixed at insert) and the last-access stamp from
/// the map-wide clock.
struct Slot<V> {
    value: V,
    weight: usize,
    stamp: u64,
}

/// A concurrent insert-once map: values are cloned out (use `Arc`/`Copy`
/// values for large payloads). First writer wins on a racing key, so
/// concurrent builders converge on one canonical entry.
///
/// Every entry carries an approximate byte weight (`size_of` the key and
/// value, plus whatever heap payload the caller declares via
/// [`StripedMap::insert_weighed`]) and a last-access stamp, so a
/// long-lived owner — the planner service's tiered caches — can ask for
/// the total footprint ([`StripedMap::bytes`]) and shed
/// least-recently-used entries down to a byte target
/// ([`StripedMap::evict_lru`]) without dropping the whole map.
pub struct StripedMap<K, V> {
    stripes: Vec<Mutex<HashMap<K, Slot<V>, FxBuildHasher>>>,
    /// Map-wide access clock; `get`/`insert` stamp entries from it.
    clock: AtomicU64,
    /// Sum of entry weights (approximate under racing evictions).
    bytes: AtomicUsize,
    /// Lifetime count of entries removed by [`StripedMap::evict_lru`].
    evicted: AtomicU64,
}

impl<K: Hash + Eq + Clone, V: Clone> StripedMap<K, V> {
    pub fn new(stripes: usize) -> Self {
        StripedMap {
            stripes: (0..stripes.max(1)).map(|_| Mutex::new(HashMap::default())).collect(),
            clock: AtomicU64::new(0),
            bytes: AtomicUsize::new(0),
            evicted: AtomicU64::new(0),
        }
    }

    fn stripe(&self, key: &K) -> &Mutex<HashMap<K, Slot<V>, FxBuildHasher>> {
        // FxHasher is deterministic (unlike RandomState), so stripe
        // assignment is stable across runs; the inner maps re-hash with
        // the same cheap function.
        &self.stripes[(fx_hash_one(key) as usize) % self.stripes.len()]
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    pub fn get(&self, key: &K) -> Option<V> {
        let stamp = self.tick();
        let mut g = self.stripe(key).lock().unwrap();
        g.get_mut(key).map(|slot| {
            slot.stamp = stamp;
            slot.value.clone()
        })
    }

    /// Insert if absent; returns the canonical value (the existing one if
    /// another worker won the race). Build values *outside* this call —
    /// the stripe lock is held only for the map operation. Weighs the
    /// entry at `size_of::<K>() + size_of::<V>()`; values with heap
    /// payloads should use [`StripedMap::insert_weighed`].
    pub fn insert(&self, key: K, value: V) -> V {
        self.insert_weighed(key, value, 0)
    }

    /// [`StripedMap::insert`] with `payload_bytes` of caller-declared heap
    /// payload added to the entry's weight (an `Arc<Vec<Op>>` value is 8
    /// inline bytes but megabytes of trace).
    pub fn insert_weighed(&self, key: K, value: V, payload_bytes: usize) -> V {
        let weight = std::mem::size_of::<K>() + std::mem::size_of::<V>() + payload_bytes;
        let stamp = self.tick();
        let mut g = self.stripe(&key).lock().unwrap();
        match g.entry(key) {
            std::collections::hash_map::Entry::Occupied(mut o) => {
                o.get_mut().stamp = stamp;
                o.get().value.clone()
            }
            std::collections::hash_map::Entry::Vacant(v) => {
                self.bytes.fetch_add(weight, Ordering::Relaxed);
                v.insert(Slot { value, weight, stamp }).value.clone()
            }
        }
    }

    pub fn len(&self) -> usize {
        self.stripes.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate resident bytes: the sum of entry weights.
    pub fn bytes(&self) -> usize {
        self.bytes.load(Ordering::Relaxed)
    }

    /// Lifetime count of entries dropped by [`StripedMap::evict_lru`]
    /// (full [`StripedMap::clear`]s are not evictions).
    pub fn evicted(&self) -> u64 {
        self.evicted.load(Ordering::Relaxed)
    }

    /// Shed least-recently-used entries until the map weighs at most
    /// `target_bytes`; returns how many entries were dropped. The stamp
    /// snapshot is taken stripe by stripe, so entries touched by racing
    /// readers mid-eviction may still be dropped — correctness is
    /// unaffected (only warmth), which is the same benign-race policy as
    /// the map's first-writer-wins inserts.
    pub fn evict_lru(&self, target_bytes: usize) -> u64 {
        if self.bytes() <= target_bytes {
            return 0;
        }
        let mut candidates: Vec<(u64, usize, K)> = Vec::new();
        for (i, s) in self.stripes.iter().enumerate() {
            for (k, slot) in s.lock().unwrap().iter() {
                candidates.push((slot.stamp, i, k.clone()));
            }
        }
        candidates.sort_by_key(|&(stamp, _, _)| stamp);
        let mut dropped = 0u64;
        for (_, i, key) in candidates {
            if self.bytes() <= target_bytes {
                break;
            }
            if let Some(slot) = self.stripes[i].lock().unwrap().remove(&key) {
                self.bytes.fetch_sub(slot.weight, Ordering::Relaxed);
                dropped += 1;
            }
        }
        self.evicted.fetch_add(dropped, Ordering::Relaxed);
        dropped
    }

    /// Drop exactly the entries whose key matches `pred`; returns how many
    /// were dropped. This is the surgical backend of calibration-epoch
    /// invalidation: a published epoch retires one fingerprint's entries
    /// while every other fingerprint's stay warm. Deliberately *not*
    /// counted in [`StripedMap::evicted`] — that counter means "shed for
    /// capacity", and invalidations are correctness drops the caller
    /// accounts separately.
    pub fn remove_if(&self, mut pred: impl FnMut(&K) -> bool) -> u64 {
        let mut dropped = 0u64;
        for s in &self.stripes {
            let mut g = s.lock().unwrap();
            let mut freed = 0usize;
            g.retain(|k, slot| {
                if pred(k) {
                    freed += slot.weight;
                    dropped += 1;
                    false
                } else {
                    true
                }
            });
            self.bytes.fetch_sub(freed, Ordering::Relaxed);
        }
        dropped
    }

    /// Drop every entry (stripe by stripe — not an atomic snapshot under
    /// concurrent writers). The planner-service session API uses this to
    /// evict its cross-request memos without tearing down the session.
    pub fn clear(&self) {
        for s in &self.stripes {
            let mut g = s.lock().unwrap();
            let freed: usize = g.values().map(|slot| slot.weight).sum();
            g.clear();
            self.bytes.fetch_sub(freed, Ordering::Relaxed);
        }
    }

    /// Fold over a snapshot of every entry (stripe by stripe). Used for
    /// end-of-sweep accounting (e.g. counting fitted vs fallen-back
    /// symbolic models); not a consistent point-in-time view under
    /// concurrent writers.
    pub fn fold<A>(&self, init: A, mut f: impl FnMut(A, &K, &V) -> A) -> A {
        let mut acc = init;
        for s in &self.stripes {
            for (k, slot) in s.lock().unwrap().iter() {
                acc = f(acc, k, &slot.value);
            }
        }
        acc
    }
}

impl<K: Hash + Eq + Clone, V: Clone> Default for StripedMap<K, V> {
    fn default() -> Self {
        Self::new(DEFAULT_STRIPES)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_roundtrip() {
        let m: StripedMap<u64, u64> = StripedMap::default();
        assert!(m.is_empty());
        assert_eq!(m.get(&7), None);
        assert_eq!(m.insert(7, 70), 70);
        assert_eq!(m.get(&7), Some(70));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn first_writer_wins() {
        let m: StripedMap<u64, u64> = StripedMap::new(4);
        assert_eq!(m.insert(1, 10), 10);
        assert_eq!(m.insert(1, 99), 10, "racing insert returns the canonical value");
        assert_eq!(m.get(&1), Some(10));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn keys_spread_over_stripes() {
        let m: StripedMap<u64, u64> = StripedMap::new(8);
        for k in 0..256 {
            m.insert(k, k);
        }
        assert_eq!(m.len(), 256);
        let used = m.stripes.iter().filter(|s| !s.lock().unwrap().is_empty()).count();
        assert!(used >= 4, "only {used}/8 stripes used");
    }

    #[test]
    fn concurrent_inserts_converge() {
        let m: StripedMap<u64, u64> = StripedMap::new(8);
        std::thread::scope(|scope| {
            for t in 0..4u64 {
                let m = &m;
                scope.spawn(move || {
                    for k in 0..100 {
                        m.insert(k, t * 1000 + k);
                    }
                });
            }
        });
        assert_eq!(m.len(), 100);
        for k in 0..100 {
            let v = m.get(&k).unwrap();
            assert_eq!(v % 1000, k, "value for {k} must come from one canonical insert");
        }
    }

    #[test]
    fn clear_empties_every_stripe() {
        let m: StripedMap<u64, u64> = StripedMap::new(4);
        for k in 0..64 {
            m.insert(k, k);
        }
        assert_eq!(m.len(), 64);
        m.clear();
        assert!(m.is_empty());
        assert_eq!(m.get(&7), None);
        // The map stays usable after eviction.
        m.insert(7, 70);
        assert_eq!(m.get(&7), Some(70));
    }

    #[test]
    fn fold_visits_every_entry() {
        let m: StripedMap<u64, u64> = StripedMap::new(4);
        for k in 0..32 {
            m.insert(k, 2 * k);
        }
        let (count, sum) = m.fold((0u64, 0u64), |(c, s), _, v| (c + 1, s + v));
        assert_eq!(count, 32);
        assert_eq!(sum, (0..32).map(|k| 2 * k).sum::<u64>());
    }

    #[test]
    fn weights_track_bytes_and_clear_resets() {
        let m: StripedMap<u64, u64> = StripedMap::new(4);
        assert_eq!(m.bytes(), 0);
        m.insert(1, 10);
        assert_eq!(m.bytes(), 16, "default weight is size_of K + size_of V");
        m.insert_weighed(2, 20, 1000);
        assert_eq!(m.bytes(), 16 + 1016);
        // A racing duplicate insert never double-counts.
        m.insert_weighed(2, 99, 5000);
        assert_eq!(m.bytes(), 16 + 1016);
        m.clear();
        assert_eq!(m.bytes(), 0);
        assert_eq!(m.evicted(), 0, "clear is not an eviction");
    }

    #[test]
    fn lru_eviction_drops_oldest_first() {
        let m: StripedMap<u64, u64> = StripedMap::new(4);
        for k in 0..32 {
            m.insert_weighed(k, k, 84); // 100 bytes per entry
        }
        assert_eq!(m.bytes(), 3200);
        // Touch the first 8 keys so they are the most recently used.
        for k in 0..8 {
            m.get(&k);
        }
        let dropped = m.evict_lru(1600);
        assert_eq!(dropped, 16);
        assert_eq!(m.bytes(), 1600);
        assert_eq!(m.len(), 16);
        assert_eq!(m.evicted(), 16);
        for k in 0..8 {
            assert!(m.get(&k).is_some(), "recently-touched key {k} must survive");
        }
        // Already under target: a no-op.
        assert_eq!(m.evict_lru(1600), 0);
        // The map stays usable after eviction.
        m.insert(100, 1);
        assert_eq!(m.get(&100), Some(1));
    }

    #[test]
    fn remove_if_is_surgical() {
        let m: StripedMap<(u64, u64), u64> = StripedMap::new(4);
        for fp in [1u64, 2] {
            for k in 0..16 {
                m.insert_weighed((fp, k), k, 84);
            }
        }
        let total = m.bytes();
        let dropped = m.remove_if(|&(fp, _)| fp == 1);
        assert_eq!(dropped, 16);
        assert_eq!(m.len(), 16);
        assert_eq!(m.bytes(), total / 2, "freed weight is returned to the budget");
        assert_eq!(m.evicted(), 0, "invalidation is not a capacity eviction");
        for k in 0..16 {
            assert_eq!(m.get(&(1, k)), None);
            assert_eq!(m.get(&(2, k)), Some(k), "other fingerprint survives");
        }
        assert_eq!(m.remove_if(|&(fp, _)| fp == 1), 0, "idempotent");
    }

    #[test]
    fn fx_hash_is_deterministic_and_spreads() {
        // Same value, same hash — across hasher instances (no random keys).
        assert_eq!(fx_hash_one(&42u64), fx_hash_one(&42u64));
        assert_ne!(fx_hash_one(&42u64), fx_hash_one(&43u64));
        // Byte-stream hashing: length folding keeps prefixes distinct.
        let mut a = FxHasher::default();
        a.write(b"ab");
        let mut b = FxHasher::default();
        b.write(b"ab\0");
        assert_ne!(a.finish(), b.finish());
        // Sequential keys land in many distinct buckets of a 16-way split.
        let mut seen = std::collections::HashSet::new();
        for k in 0u64..64 {
            seen.insert(fx_hash_one(&k) % 16);
        }
        assert!(seen.len() >= 8, "only {} buckets hit", seen.len());
    }

    #[test]
    fn fx_hash_mixed_width_writes() {
        // Tuple keys (the planner's memo keys) exercise the width-specific
        // write paths; equal tuples must agree, unequal must (here) differ.
        let k1 = (7u64, true, 3u32);
        let k2 = (7u64, false, 3u32);
        assert_eq!(fx_hash_one(&k1), fx_hash_one(&k1));
        assert_ne!(fx_hash_one(&k1), fx_hash_one(&k2));
    }
}
