//! N-way lock-striped concurrent hash map. The planner's worker pool used
//! to serialize on two global `Mutex<HashMap<String, _>>`s (the trace
//! cache and the report memo); striping the key space over independent
//! locks lets workers probing different cells proceed concurrently, and
//! hashed struct keys replace the old `format!`-built Strings.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::Mutex;

/// Default stripe count: enough that 16 planner workers rarely collide,
/// small enough that `len()` stays cheap.
pub const DEFAULT_STRIPES: usize = 16;

/// A concurrent insert-once map: values are cloned out (use `Arc`/`Copy`
/// values for large payloads). First writer wins on a racing key, so
/// concurrent builders converge on one canonical entry.
pub struct StripedMap<K, V> {
    stripes: Vec<Mutex<HashMap<K, V>>>,
}

impl<K: Hash + Eq, V: Clone> StripedMap<K, V> {
    pub fn new(stripes: usize) -> Self {
        StripedMap {
            stripes: (0..stripes.max(1)).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }

    fn stripe(&self, key: &K) -> &Mutex<HashMap<K, V>> {
        // DefaultHasher::new() is keyed deterministically (unlike
        // RandomState), so stripe assignment is stable across runs.
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.stripes[(h.finish() as usize) % self.stripes.len()]
    }

    pub fn get(&self, key: &K) -> Option<V> {
        self.stripe(key).lock().unwrap().get(key).cloned()
    }

    /// Insert if absent; returns the canonical value (the existing one if
    /// another worker won the race). Build values *outside* this call —
    /// the stripe lock is held only for the map operation.
    pub fn insert(&self, key: K, value: V) -> V {
        self.stripe(&key).lock().unwrap().entry(key).or_insert(value).clone()
    }

    pub fn len(&self) -> usize {
        self.stripes.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<K: Hash + Eq, V: Clone> Default for StripedMap<K, V> {
    fn default() -> Self {
        Self::new(DEFAULT_STRIPES)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_roundtrip() {
        let m: StripedMap<u64, u64> = StripedMap::default();
        assert!(m.is_empty());
        assert_eq!(m.get(&7), None);
        assert_eq!(m.insert(7, 70), 70);
        assert_eq!(m.get(&7), Some(70));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn first_writer_wins() {
        let m: StripedMap<u64, u64> = StripedMap::new(4);
        assert_eq!(m.insert(1, 10), 10);
        assert_eq!(m.insert(1, 99), 10, "racing insert returns the canonical value");
        assert_eq!(m.get(&1), Some(10));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn keys_spread_over_stripes() {
        let m: StripedMap<u64, u64> = StripedMap::new(8);
        for k in 0..256 {
            m.insert(k, k);
        }
        assert_eq!(m.len(), 256);
        let used = m.stripes.iter().filter(|s| !s.lock().unwrap().is_empty()).count();
        assert!(used >= 4, "only {used}/8 stripes used");
    }

    #[test]
    fn concurrent_inserts_converge() {
        let m: StripedMap<u64, u64> = StripedMap::new(8);
        std::thread::scope(|scope| {
            for t in 0..4u64 {
                let m = &m;
                scope.spawn(move || {
                    for k in 0..100 {
                        m.insert(k, t * 1000 + k);
                    }
                });
            }
        });
        assert_eq!(m.len(), 100);
        for k in 0..100 {
            let v = m.get(&k).unwrap();
            assert_eq!(v % 1000, k, "value for {k} must come from one canonical insert");
        }
    }
}
