//! Cooperative cancellation for evaluator loops: a [`CancelToken`] is a
//! deadline carried by value through `plan_with` / `walls_at` /
//! `place_with`, checked between cells (never mid-kernel — cells are
//! short, so cancellation latency is one cell's evaluation). A request
//! that observes its token expired stops computing, **publishes nothing
//! to any memo tier**, and reports `cancelled` so the service can
//! answer a structured 504 with partial accounting.

use std::time::{Duration, Instant};

/// A by-value deadline. `none()` never cancels — the default for every
/// request — so the evaluator checks cost one branch on the happy path.
#[derive(Clone, Copy, Debug)]
pub struct CancelToken {
    deadline: Option<Instant>,
}

impl Default for CancelToken {
    fn default() -> Self {
        CancelToken::none()
    }
}

impl CancelToken {
    /// A token that never expires.
    pub fn none() -> Self {
        CancelToken { deadline: None }
    }

    /// Expire this long from now. `Duration::ZERO` is already expired —
    /// the deterministic "immediate 504" used by tests.
    pub fn with_deadline(timeout: Duration) -> Self {
        CancelToken { deadline: Some(Instant::now() + timeout) }
    }

    /// Whether the deadline has passed.
    pub fn is_cancelled(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }

    /// Time left before expiry (`None` = unbounded).
    pub fn remaining(&self) -> Option<Duration> {
        self.deadline.map(|d| d.saturating_duration_since(Instant::now()))
    }

    /// The tighter of two tokens: a per-request `deadline_ms` combines
    /// with the server-wide `--request-timeout` by taking whichever
    /// expires first.
    pub fn earliest(a: CancelToken, b: CancelToken) -> CancelToken {
        match (a.deadline, b.deadline) {
            (Some(x), Some(y)) => CancelToken { deadline: Some(x.min(y)) },
            (Some(x), None) | (None, Some(x)) => CancelToken { deadline: Some(x) },
            (None, None) => CancelToken::none(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_cancels() {
        assert!(!CancelToken::none().is_cancelled());
        assert!(CancelToken::none().remaining().is_none());
    }

    #[test]
    fn zero_deadline_is_already_expired() {
        let t = CancelToken::with_deadline(Duration::ZERO);
        assert!(t.is_cancelled());
        assert_eq!(t.remaining(), Some(Duration::ZERO));
    }

    #[test]
    fn generous_deadline_is_live() {
        let t = CancelToken::with_deadline(Duration::from_secs(3600));
        assert!(!t.is_cancelled());
        assert!(t.remaining().unwrap() > Duration::from_secs(3000));
    }

    #[test]
    fn earliest_picks_the_tighter_deadline() {
        let slack = CancelToken::with_deadline(Duration::from_secs(3600));
        let tight = CancelToken::with_deadline(Duration::ZERO);
        assert!(CancelToken::earliest(slack, tight).is_cancelled());
        assert!(CancelToken::earliest(tight, slack).is_cancelled());
        assert!(!CancelToken::earliest(slack, CancelToken::none()).is_cancelled());
        assert!(!CancelToken::earliest(CancelToken::none(), CancelToken::none()).is_cancelled());
    }
}
