//! Human-friendly formatting of bytes / token counts / durations.

/// GiB as used throughout the paper's tables.
pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Format a byte count as GiB with two decimals (paper Table 4 style).
pub fn gib(bytes: f64) -> String {
    format!("{:.2}", bytes / GIB)
}

/// Format a token count the way the paper labels columns (128K, 1M, 5M).
pub fn tokens(n: u64) -> String {
    const K: u64 = 1024;
    const M: u64 = 1024 * 1024;
    if n % M == 0 {
        format!("{}M", n / M)
    } else if n % K == 0 {
        format!("{}K", n / K)
    } else {
        n.to_string()
    }
}

/// Format seconds with adaptive precision.
pub fn secs(s: f64) -> String {
    if s < 0.01 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 100.0 {
        format!("{s:.2}s")
    } else {
        format!("{s:.1}s")
    }
}

/// Parse a token-count label ("128K", "1M", "512k") to a count. The `G`
/// suffix exists for byte-sized flags that share this parser (the
/// daemon's `--cache-budget 2G`).
pub fn parse_tokens(s: &str) -> Option<u64> {
    let s = s.trim();
    let (num, mult) = match s.chars().last()? {
        'k' | 'K' => (&s[..s.len() - 1], 1024),
        'm' | 'M' => (&s[..s.len() - 1], 1024 * 1024),
        'g' | 'G' => (&s[..s.len() - 1], 1024 * 1024 * 1024),
        _ => (s, 1),
    };
    num.parse::<u64>().ok().map(|n| n * mult)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_labels_roundtrip() {
        for label in ["128K", "256K", "512K", "1M", "2M", "3M", "4M", "5M", "8M"] {
            assert_eq!(tokens(parse_tokens(label).unwrap()), label);
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert_eq!(parse_tokens("x1M"), None);
        assert_eq!(parse_tokens(""), None);
    }

    #[test]
    fn parse_gib_suffix() {
        assert_eq!(parse_tokens("1G"), Some(1 << 30));
        assert_eq!(parse_tokens("2g"), Some(2 << 30));
    }

    #[test]
    fn gib_formats() {
        assert_eq!(gib(GIB * 21.26), "21.26");
    }

    #[test]
    fn secs_formats() {
        assert_eq!(secs(0.001), "1.00ms");
        assert_eq!(secs(7.4), "7.40s");
        assert_eq!(secs(275.06), "275.1s");
    }
}
