//! Tiny criterion-style bench harness (criterion is not in the offline
//! vendor set). Used by everything under `rust/benches/`: warms up, runs
//! timed iterations until a wall-clock budget, reports mean / p50 / p95 and
//! throughput.

use std::time::{Duration, Instant};

pub struct Bench {
    name: String,
    budget: Duration,
    warmup: Duration,
}

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl Stats {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean.as_secs_f64()
    }
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Bench {
            name: name.to_string(),
            budget: Duration::from_millis(800),
            warmup: Duration::from_millis(100),
        }
    }

    pub fn budget_ms(mut self, ms: u64) -> Self {
        self.budget = Duration::from_millis(ms);
        self
    }

    /// Run `f` repeatedly; `black_box` its result to keep the optimizer
    /// honest. Prints and returns the stats.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Stats {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Timed.
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget || samples.len() < 10 {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
            if samples.len() >= 1_000_000 {
                break;
            }
        }
        samples.sort();
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        let stats = Stats {
            name: self.name.clone(),
            iters: samples.len(),
            mean,
            p50: samples[samples.len() / 2],
            p95: samples[samples.len() * 95 / 100],
        };
        println!(
            "bench {:<44} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p95  ({} iters)",
            stats.name, stats.mean, stats.p50, stats.p95, stats.iters
        );
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let s = Bench::new("noop").budget_ms(20).run(|| 1 + 1);
        assert!(s.iters >= 10);
        assert!(s.mean <= s.p95.max(Duration::from_nanos(1)) * 2);
    }
}
