//! Deterministic xoshiro256** PRNG — synthetic workloads, property tests and
//! the training-corpus generator all need reproducible randomness without an
//! external crate.

/// xoshiro256** by Blackman & Vigna (public domain reference implementation).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so any u64 seed gives a well-mixed state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Rejection sampling to avoid modulo bias.
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Pick a random element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        let mean: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }
}
