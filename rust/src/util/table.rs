//! Plain-text table rendering for the paper-table generators.

/// A simple column-aligned table with a title, header and footnote support —
/// the output format of every `repro tableN` / `repro figN` subcommand.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    notes: Vec<String>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn note(&mut self, s: &str) -> &mut Self {
        self.notes.push(s.to_string());
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("t", &["a", "long_col"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100".into(), "x".into()]);
        let r = t.render();
        assert!(r.contains("== t =="));
        assert!(r.lines().count() >= 4);
        // all data lines same width
        let lines: Vec<&str> = r.lines().skip(1).take(3).collect();
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        Table::new("t", &["a"]).row(vec!["1".into(), "2".into()]);
    }
}
