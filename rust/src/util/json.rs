//! Minimal JSON value + serializer + parser (serde is not in the offline
//! vendor set). Covers what the planner's `--json` output, the service
//! wire protocol, the bench emitters and the `--refit` measurement files
//! need: order-preserving objects, arrays, strings, finite numbers, bools
//! and null.
//!
//! The serializer is **canonical**: a given `Json` value always renders to
//! the same bytes, across runs and platforms. Object fields keep their
//! insertion order (builders fix the field order once), numbers have one
//! spelling each — integers in `(−2^53, 2^53)` render without a fraction,
//! every zero (including `-0.0`) renders as `0`, all other finite numbers
//! use Rust's shortest-roundtrip `Display` (pure-Rust Ryū-style, no
//! platform `printf` involved) — and non-finite numbers serialize as
//! `null`. The service's byte-for-byte response contract (a repeated
//! `/v1/plan` request compares equal with `cmp`) rests on this.

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object from (key, value) pairs, preserving order.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn string(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Integer-valued number (exact for |n| < 2^53 — token counts and
    /// counters all fit).
    pub fn int(n: u64) -> Json {
        Json::Num(n as f64)
    }

    /// Parse a JSON document (strict enough for measurement files and our
    /// own output: no comments, no trailing commas).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing content at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field lookup (first match; our objects never duplicate keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Whole non-negative number below 2^53 (counts, token lengths, GPU
    /// counts — everything the wire protocol carries as an integer).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x < 9.0e15 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items.as_slice()),
            _ => None,
        }
    }

    /// Compact one-line rendering.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty rendering with two-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => out.push_str(&fmt_num(*x)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

/// Parser recursion ceiling: `value` recurses per nesting level, so a
/// hostile `[[[[…` input must become a parse error, not a stack overflow.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", c as char, self.i))
        }
    }

    fn eat_lit(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        if self.depth >= MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} at byte {}", self.i));
        }
        self.depth += 1;
        let v = match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.eat_lit("true", Json::Bool(true)),
            Some(b'f') => self.eat_lit("false", Json::Bool(false)),
            Some(b'n') => self.eat_lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected input at byte {}", self.i)),
        };
        self.depth -= 1;
        v
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            pairs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(format!("expected `,` or `}}` at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected `,` or `]` at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| "unterminated string".to_string())?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| "unterminated escape".to_string())?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let code = if (0xD800..=0xDBFF).contains(&hi) {
                                // UTF-16 surrogate pair: a low surrogate
                                // escape must follow immediately.
                                if self.peek() != Some(b'\\')
                                    || self.b.get(self.i + 1) != Some(&b'u')
                                {
                                    return Err("unpaired surrogate in \\u escape".into());
                                }
                                self.i += 2;
                                let lo = self.hex4()?;
                                if !(0xDC00..=0xDFFF).contains(&lo) {
                                    return Err("unpaired surrogate in \\u escape".into());
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| "bad \\u codepoint".to_string())?,
                            );
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                _ => {
                    // Multi-byte UTF-8: copy the whole sequence through.
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len() && self.b[end] & 0xC0 == 0x80 {
                        end += 1;
                    }
                    let s = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| "invalid UTF-8 in string".to_string())?;
                    out.push_str(s);
                    self.i = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 > self.b.len() {
            return Err("truncated \\u escape".into());
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| "bad \\u escape".to_string())?;
        let code =
            u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape".to_string())?;
        self.i += 4;
        Ok(code)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).expect("ascii number");
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number `{s}` at byte {start}"))
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

/// Canonical number spelling (see the module docs): `-0.0` folds into
/// `0`, exact integers below 2^53 drop the fraction, everything else is
/// the shortest string that round-trips — so equal values always render
/// to equal bytes.
fn fmt_num(x: f64) -> String {
    if !x.is_finite() {
        "null".to_string()
    } else if x == 0.0 {
        "0".to_string()
    } else if x == x.trunc() && x.abs() < 9.0e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_render() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::Bool(true).render(), "true");
        assert_eq!(Json::int(5 << 20).render(), "5242880");
        assert_eq!(Json::Num(1.5).render(), "1.5");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }

    #[test]
    fn canonical_number_spelling() {
        // Every zero is `0`; integers drop the fraction; fractions use the
        // shortest round-trip spelling — one spelling per value.
        assert_eq!(Json::Num(-0.0).render(), "0");
        assert_eq!(Json::Num(0.0).render(), "0");
        assert_eq!(Json::Num(3.0).render(), "3");
        assert_eq!(Json::Num(-17.0).render(), "-17");
        assert_eq!(Json::Num(0.1).render(), "0.1");
        assert_eq!(Json::Num(4.25).render(), "4.25");
        assert_eq!(Json::Num(1.0 / 3.0).render(), "0.3333333333333333");
        // Canonical: parse(render(x)) renders to the same bytes again.
        for x in [0.1, -0.0, 2.5e-4, 123456789.125, 1.0e16] {
            let once = Json::Num(x).render();
            let twice = Json::parse(&once).unwrap().render();
            assert_eq!(once, twice, "{x}");
        }
    }

    #[test]
    fn integer_accessor() {
        assert_eq!(Json::int(8).as_u64(), Some(8));
        assert_eq!(Json::Num(8.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.0e16).as_u64(), None);
        assert_eq!(Json::string("8").as_u64(), None);
        assert_eq!(Json::Bool(true).as_bool(), Some(true));
        assert_eq!(Json::Null.as_bool(), None);
    }

    #[test]
    fn strings_escape() {
        assert_eq!(Json::string("a\"b\\c\nd").render(), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(Json::Str("\u{1}".to_string()).render(), "\"\\u0001\"");
    }

    #[test]
    fn compact_structure() {
        let j = Json::obj(vec![
            ("k", Json::Arr(vec![Json::int(1), Json::int(2)])),
            ("s", Json::string("x")),
        ]);
        assert_eq!(j.render(), "{\"k\":[1,2],\"s\":\"x\"}");
        assert_eq!(Json::Arr(vec![]).render(), "[]");
        assert_eq!(Json::obj(vec![]).render(), "{}");
    }

    #[test]
    fn pretty_indents() {
        let j = Json::obj(vec![("a", Json::Arr(vec![Json::int(1)]))]);
        assert_eq!(j.pretty(), "{\n  \"a\": [\n    1\n  ]\n}");
    }

    #[test]
    fn parse_roundtrips_own_output() {
        let j = Json::obj(vec![
            ("model", Json::string("llama3-8b")),
            ("gpus", Json::int(8)),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
            ("cells", Json::Arr(vec![Json::Num(4.93), Json::Num(-1.5e-3)])),
            ("esc", Json::string("a\"b\\c\nd")),
        ]);
        for text in [j.render(), j.pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), j, "{text}");
        }
    }

    #[test]
    fn parse_accessors() {
        let j = Json::parse(r#"{"seq": "1M", "t": 4.93, "cells": [1, 2]}"#).unwrap();
        assert_eq!(j.get("seq").and_then(Json::as_str), Some("1M"));
        assert_eq!(j.get("t").and_then(Json::as_f64), Some(4.93));
        assert_eq!(j.get("cells").and_then(Json::as_arr).map(|a| a.len()), Some(2));
        assert!(j.get("missing").is_none());
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "{\"a\":1} x", "nul", "\"unterminated"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn parse_depth_limited_not_stack_overflow() {
        // Hostile nesting must be a parse error, not a crash.
        let deep = "[".repeat(100_000);
        let err = Json::parse(&deep).unwrap_err();
        assert!(err.contains("nesting deeper"), "{err}");
        // Reasonable nesting still parses.
        let ok = format!("{}1{}", "[".repeat(64), "]".repeat(64));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn parse_unicode_escape() {
        let j = Json::parse(r#"{"s": "café — ünïcode"}"#).unwrap();
        assert_eq!(j.get("s").and_then(Json::as_str), Some("café — ünïcode"));
        // \u escapes, including a UTF-16 surrogate pair (emoji).
        let j = Json::parse(r#"{"s": "a\u00e9\ud83d\ude00b"}"#).unwrap();
        assert_eq!(j.get("s").and_then(Json::as_str), Some("a\u{e9}\u{1F600}b"));
        // Unpaired surrogates are rejected.
        assert!(Json::parse(r#""\ud83d""#).is_err());
        assert!(Json::parse(r#""\ud83dxy""#).is_err());
        assert!(Json::parse(r#""\ud83dA""#).is_err());
    }
}
