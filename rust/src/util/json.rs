//! Minimal JSON value + serializer (serde is not in the offline vendor
//! set). Covers what the planner's `--json` output and the bench emitters
//! need: order-preserving objects, arrays, strings, finite numbers, bools
//! and null. Non-finite numbers serialize as `null`.

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object from (key, value) pairs, preserving order.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn string(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Integer-valued number (exact for |n| < 2^53 — token counts and
    /// counters all fit).
    pub fn int(n: u64) -> Json {
        Json::Num(n as f64)
    }

    /// Compact one-line rendering.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty rendering with two-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => out.push_str(&fmt_num(*x)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn fmt_num(x: f64) -> String {
    if !x.is_finite() {
        "null".to_string()
    } else if x == x.trunc() && x.abs() < 9.0e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_render() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::Bool(true).render(), "true");
        assert_eq!(Json::int(5 << 20).render(), "5242880");
        assert_eq!(Json::Num(1.5).render(), "1.5");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }

    #[test]
    fn strings_escape() {
        assert_eq!(Json::string("a\"b\\c\nd").render(), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(Json::Str("\u{1}".to_string()).render(), "\"\\u0001\"");
    }

    #[test]
    fn compact_structure() {
        let j = Json::obj(vec![
            ("k", Json::Arr(vec![Json::int(1), Json::int(2)])),
            ("s", Json::string("x")),
        ]);
        assert_eq!(j.render(), "{\"k\":[1,2],\"s\":\"x\"}");
        assert_eq!(Json::Arr(vec![]).render(), "[]");
        assert_eq!(Json::obj(vec![]).render(), "{}");
    }

    #[test]
    fn pretty_indents() {
        let j = Json::obj(vec![("a", Json::Arr(vec![Json::int(1)]))]);
        assert_eq!(j.pretty(), "{\n  \"a\": [\n    1\n  ]\n}");
    }
}
