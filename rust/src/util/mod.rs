//! Small self-contained utilities (the offline vendor set has no serde /
//! criterion / proptest, so formatting, RNG, property testing and the bench
//! harness live here).

pub mod bench;
pub mod fmt;
pub mod prop;
pub mod rng;
pub mod table;
