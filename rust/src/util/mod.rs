//! Small self-contained utilities (the offline vendor set has no serde /
//! criterion / proptest / rayon, so formatting, RNG, property testing, JSON
//! emission, the bench harness and the worker pool live here).

pub mod bench;
pub mod cancel;
pub mod failpoint;
pub mod fmt;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stripe;
pub mod table;
