//! FLOPs model for one training step (forward unless noted), used by the
//! engine's cost model. Counts multiply-adds as 2 FLOPs, matches the
//! paper's causal setup (the S² terms are halved).

use super::dims::ModelDims;

/// Backward FLOPs of a matmul-dominated block relative to its forward
/// (dX and dW each cost one forward-equivalent).
pub const BWD_FACTOR: f64 = 2.0;

/// Flash-attention backward relative to forward: the bwd kernel recomputes
/// the S·Sᵀ logits and runs 5 matmuls vs 2 (FA2/FA3 analysis ⇒ ~2.5×).
pub const ATTN_BWD_FACTOR: f64 = 2.5;

/// Causal self-attention forward FLOPs for the whole model over a full
/// sequence of `s` tokens: 2 matmuls (QKᵀ, PV) · 2 FLOPs · S²/2 (causal)
/// · H·d_head · L. Uses q_width = H·d_head (≠ d_model for Qwen3).
pub fn attn_fwd(m: &ModelDims, s: u64) -> f64 {
    2.0 * (s as f64) * (s as f64) * m.q_width() as f64 * m.n_layers as f64
}

/// QKV + output projections, forward, whole model.
pub fn proj_fwd(m: &ModelDims, s: u64) -> f64 {
    let per_tok = 2.0
        * (m.d_model * (2 * m.q_width() + 2 * m.kv_width())) as f64;
    per_tok * s as f64 * m.n_layers as f64
}

/// SwiGLU FFN forward, whole model (three d_model×d_ff matmuls).
pub fn mlp_fwd(m: &ModelDims, s: u64) -> f64 {
    6.0 * (m.d_model * m.d_ff) as f64 * s as f64 * m.n_layers as f64
}

/// Final projection + cross-entropy forward.
pub fn logits_fwd(m: &ModelDims, s: u64) -> f64 {
    2.0 * (m.d_model * m.vocab) as f64 * s as f64
}

/// Total forward FLOPs for a step (no recompute).
pub fn total_fwd(m: &ModelDims, s: u64) -> f64 {
    attn_fwd(m, s) + proj_fwd(m, s) + mlp_fwd(m, s) + logits_fwd(m, s)
}

/// Total step FLOPs including backward and one full activation-
/// checkpointing recompute of the forward (the paper's AC setup).
pub fn total_step_with_ac(m: &ModelDims, s: u64) -> f64 {
    let fwd = total_fwd(m, s);
    let bwd = attn_fwd(m, s) * ATTN_BWD_FACTOR
        + (proj_fwd(m, s) + mlp_fwd(m, s) + logits_fwd(m, s)) * BWD_FACTOR;
    2.0 * fwd + bwd // fwd + recompute + bwd
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attention_dominates_at_long_context() {
        let m = ModelDims::llama3_8b();
        let s = 1 << 20; // 1M
        assert!(attn_fwd(&m, s) > 10.0 * mlp_fwd(&m, s));
        assert!(attn_fwd(&m, s) > 100.0 * logits_fwd(&m, s));
    }

    #[test]
    fn attn_flops_match_hand_calc() {
        // 2·S²·d_model·L for llama (q_width == d_model).
        let m = ModelDims::llama3_8b();
        let s = 1_000_000u64;
        let expect = 2.0 * 1e12 * 4096.0 * 32.0;
        assert!((attn_fwd(&m, s) - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn qwen_uses_q_width_not_d_model() {
        let m = ModelDims::qwen3_32b();
        let s = 1 << 17;
        let ratio = attn_fwd(&m, s) / (2.0 * (s as f64).powi(2) * 5120.0 * 64.0);
        assert!((ratio - 8192.0 / 5120.0).abs() < 1e-9);
    }

    #[test]
    fn step_flops_exceed_fwd() {
        let m = ModelDims::llama3_8b();
        let s = 1 << 17;
        assert!(total_step_with_ac(&m, s) > 3.0 * total_fwd(&m, s));
    }
}
