//! Tables 2 & 6 — peak activation memory inside the attention block, per
//! context-parallelism method and execution phase, under GQA.
//!
//! Coefficients are in the paper's units: multiples of one bf16
//! `[S/C, H·d_head]` tensor (the "S/C" unit with the hidden-size constant
//! omitted). `unit_bytes` converts. γ = 1+2/g is the combined Q,K,V size,
//! β = 4+4/g the eight backward tensors; π = FPDT sequence chunks,
//! ν = UPipe head chunks (ν = H/U).

use super::dims::ModelDims;

/// Context-parallel attention execution strategy (Table 2/6 rows).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AttnMethod {
    /// DeepSpeed-Ulysses without activation checkpointing: all L layer
    /// inputs stay resident.
    Ulysses,
    /// Ulysses + full activation checkpointing with CPU offloading
    /// (the ALST-like baseline the paper's "Ulysses" experiments run).
    UlyssesOffload,
    /// Fully Pipelined Distributed Transformer, π sequence chunks.
    Fpdt { pi: u32 },
    /// Untied Ulysses, ν head chunks (ν = H/U).
    Upipe { nu: u32 },
}

impl AttnMethod {
    pub fn label(&self) -> String {
        match self {
            AttnMethod::Ulysses => "Ulysses".into(),
            AttnMethod::UlyssesOffload => "Ulysses + offloading".into(),
            AttnMethod::Fpdt { pi } => format!("FPDT (pi={pi})"),
            AttnMethod::Upipe { nu } => format!("Untied Ulysses (nu={nu})"),
        }
    }
}

/// Forward-pass phases (Table 2 columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FwdPhase {
    BeforeAttn,
    InpAllToAll,
    AttnKernel,
    OutAllToAll,
}

pub const FWD_PHASES: [FwdPhase; 4] = [
    FwdPhase::BeforeAttn,
    FwdPhase::InpAllToAll,
    FwdPhase::AttnKernel,
    FwdPhase::OutAllToAll,
];

/// Backward-pass phases (Table 6 columns; the backward traverses the block
/// in reverse, so out_all_to_all comes first).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BwdPhase {
    BeforeBwdAttn,
    OutAllToAll,
    BwdAttnKernel,
    InpAllToAll,
}

pub const BWD_PHASES: [BwdPhase; 4] = [
    BwdPhase::BeforeBwdAttn,
    BwdPhase::OutAllToAll,
    BwdPhase::BwdAttnKernel,
    BwdPhase::InpAllToAll,
];

/// Table 2 entry: forward peak in S/C units.
pub fn fwd_units(m: &ModelDims, method: AttnMethod, phase: FwdPhase) -> f64 {
    let g = m.gamma();
    let l = m.n_layers as f64;
    match method {
        AttnMethod::Ulysses => match phase {
            FwdPhase::BeforeAttn => l,
            FwdPhase::InpAllToAll | FwdPhase::AttnKernel => l + (g + 1.0),
            FwdPhase::OutAllToAll => l + 2.0,
        },
        AttnMethod::UlyssesOffload => match phase {
            FwdPhase::BeforeAttn => 1.0,
            FwdPhase::InpAllToAll | FwdPhase::AttnKernel => 1.0 + (g + 1.0),
            FwdPhase::OutAllToAll => 3.0,
        },
        AttnMethod::Fpdt { pi } => {
            let p = pi as f64;
            match phase {
                FwdPhase::BeforeAttn => 1.0 / p,
                FwdPhase::InpAllToAll => (1.0 + g + 1.0) / p,
                FwdPhase::AttnKernel => (2.0 * g + 1.0) / p,
                FwdPhase::OutAllToAll => 2.0 / p,
            }
        }
        AttnMethod::Upipe { nu } => {
            let n = nu as f64;
            match phase {
                FwdPhase::BeforeAttn => 1.0,
                FwdPhase::InpAllToAll => 2.0 + (g + 1.0) / n,
                FwdPhase::AttnKernel => 2.0 + g / n,
                FwdPhase::OutAllToAll => 1.0 + 2.0 / n,
            }
        }
    }
}

/// Table 6 entry: backward peak in S/C units.
pub fn bwd_units(m: &ModelDims, method: AttnMethod, phase: BwdPhase) -> f64 {
    let g = m.gamma();
    let b = m.beta();
    let l = m.n_layers as f64;
    match method {
        AttnMethod::Ulysses => match phase {
            BwdPhase::BeforeBwdAttn => l + 1.0,
            BwdPhase::OutAllToAll => l + 2.0,
            BwdPhase::BwdAttnKernel => l + b + 1.0,
            BwdPhase::InpAllToAll => l + g + 1.0,
        },
        AttnMethod::UlyssesOffload => match phase {
            BwdPhase::BeforeBwdAttn => 2.0,
            BwdPhase::OutAllToAll => 3.0,
            BwdPhase::BwdAttnKernel => b + 2.0,
            BwdPhase::InpAllToAll => g + 2.0,
        },
        AttnMethod::Fpdt { pi } => {
            let p = pi as f64;
            match phase {
                BwdPhase::BeforeBwdAttn => 1.0 / p,
                BwdPhase::OutAllToAll => 3.0 / p,
                BwdPhase::BwdAttnKernel => (b + 2.0) / p,
                BwdPhase::InpAllToAll => (g + 2.0) / p,
            }
        }
        AttnMethod::Upipe { nu } => {
            let n = nu as f64;
            match phase {
                BwdPhase::BeforeBwdAttn => 2.0,
                BwdPhase::OutAllToAll => 2.0 + 2.0 / n,
                BwdPhase::BwdAttnKernel => 2.0 + (b + 1.0) / n,
                BwdPhase::InpAllToAll => 2.0 + 2.0 * (g + 1.0) / n,
            }
        }
    }
}

/// Peak over all fwd+bwd phases, in S/C units.
pub fn peak_units(m: &ModelDims, method: AttnMethod) -> f64 {
    let f = FWD_PHASES
        .iter()
        .map(|&p| fwd_units(m, method, p))
        .fold(0.0, f64::max);
    let b = BWD_PHASES
        .iter()
        .map(|&p| bwd_units(m, method, p))
        .fold(0.0, f64::max);
    f.max(b)
}

/// Bytes of one "S/C unit": a bf16 [S/C, H·d_head] tensor.
pub fn unit_bytes(m: &ModelDims, s: u64, c: u64) -> f64 {
    2.0 * (s as f64 / c as f64) * m.q_width() as f64
}

/// §3.4 headline: intermediate (QKV + all-to-all) tensor bytes during the
/// attention stage — `12·(S/C)·H·d_head` for Ulysses vs `12·(S/C)·U·d_head`
/// for UPipe (= `12·S·d_head` at U=C).
pub fn intermediate_bytes_ulysses(m: &ModelDims, s: u64, c: u64) -> f64 {
    12.0 * (s as f64 / c as f64) * (m.n_heads * m.d_head) as f64
}

pub fn intermediate_bytes_upipe(m: &ModelDims, s: u64, c: u64, u: u64) -> f64 {
    12.0 * (s as f64 / c as f64) * (u * m.d_head) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn upipe_reduction_is_87_5_percent_for_qwen_c8() {
        // §3.4: Qwen3-32B, H=64, C=8, U=C ⇒ 96·S·d_head vs 12·S·d_head.
        let m = ModelDims::qwen3_32b();
        let (s, c) = (1 << 20, 8);
        let ul = intermediate_bytes_ulysses(&m, s, c);
        let up = intermediate_bytes_upipe(&m, s, c, c);
        assert!((1.0 - up / ul - 0.875).abs() < 1e-12);
    }

    #[test]
    fn upipe_at_u_eq_c_is_head_count_independent() {
        let mut m = ModelDims::llama3_8b();
        let a = intermediate_bytes_upipe(&m, 1 << 20, 8, 8);
        m.n_heads = 128; // more heads must not change UPipe's peak
        let b = intermediate_bytes_upipe(&m, 1 << 20, 8, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn upipe_beats_ulysses_offload_peak() {
        // The *peak* over phases always favours UPipe for ν ≥ 2 (per-phase
        // the bwd inp_all_to_all can exceed at ν = 2: 2 + 2(γ+1)/2 > γ+2).
        let m = ModelDims::llama3_8b();
        for nu in [2u32, 4, 8, 16] {
            assert!(
                peak_units(&m, AttnMethod::Upipe { nu })
                    <= peak_units(&m, AttnMethod::UlyssesOffload) + 1e-12,
                "nu={nu}"
            );
        }
        // ...and per-phase from ν ≥ 4 on (the paper's operating points:
        // ν = 4 for Llama3-8B, ν = 8 for Qwen3-32B).
        for nu in [4u32, 8, 16] {
            for &ph in &FWD_PHASES {
                assert!(
                    fwd_units(&m, AttnMethod::Upipe { nu }, ph)
                        <= fwd_units(&m, AttnMethod::UlyssesOffload, ph) + 1e-12,
                    "fwd {ph:?} nu={nu}"
                );
            }
            for &ph in &BWD_PHASES {
                assert!(
                    bwd_units(&m, AttnMethod::Upipe { nu }, ph)
                        <= bwd_units(&m, AttnMethod::UlyssesOffload, ph) + 1e-12,
                    "bwd {ph:?} nu={nu}"
                );
            }
        }
    }

    #[test]
    fn peak_monotone_in_nu() {
        // More chunks ⇒ never more memory.
        let m = ModelDims::qwen3_32b();
        let mut prev = f64::INFINITY;
        for nu in [1u32, 2, 4, 8, 16, 32] {
            let p = peak_units(&m, AttnMethod::Upipe { nu });
            assert!(p <= prev + 1e-12);
            prev = p;
        }
    }

    #[test]
    fn ulysses_no_ac_dominated_by_layer_inputs() {
        let m = ModelDims::llama3_8b();
        let p = peak_units(&m, AttnMethod::Ulysses);
        assert!(p > m.n_layers as f64);
    }

    #[test]
    fn prop_upipe_peak_bounded_by_offload_peak() {
        // Random dims: UPipe peak ≤ Ulysses+offload peak whenever ν ≥ 2.
        prop::check(
            "upipe<=offload",
            300,
            &[(1, 16), (2, 32), (1, 64)],
            |a| {
                let g = a[0] as u64;
                let nu = a[1] as u32;
                let m = ModelDims {
                    name: "rand",
                    d_model: 1024,
                    n_layers: a[2] as u64,
                    n_heads: 8 * g,
                    n_kv_heads: 8,
                    d_head: 64,
                    d_ff: 4096,
                    vocab: 32000,
                };
                peak_units(&m, AttnMethod::Upipe { nu })
                    <= peak_units(&m, AttnMethod::UlyssesOffload) + 1e-9
            },
        );
    }

    #[test]
    fn fpdt_arbitrarily_small() {
        let m = ModelDims::llama3_8b();
        let p = peak_units(&m, AttnMethod::Fpdt { pi: 64 });
        assert!(p < 0.2);
    }
}
