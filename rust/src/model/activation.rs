//! Table 1 — theoretical peak memory usage breakdown across the forward
//! stages of a Transformer (paper §2.2).
//!
//! All entries are *bytes*, parameterized by sequence length S and the model
//! dims; bf16 activations (2 bytes) except the loss stage (fp32). The
//! "Total" column reproduces the paper's `k · S · d_model` coefficients for
//! the canonical ratios (H·d_head = d_model, d_ff ≈ 2.67·d_model,
//! V ≈ 30·d_model).

use super::dims::ModelDims;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FwdStage {
    Embedding,
    Attention,
    FeedForward,
    CrossEntropy,
}

pub const STAGES: [FwdStage; 4] = [
    FwdStage::Embedding,
    FwdStage::Attention,
    FwdStage::FeedForward,
    FwdStage::CrossEntropy,
];

#[derive(Debug, Clone)]
pub struct StageMemory {
    pub stage: FwdStage,
    /// bytes of stage inputs kept live
    pub inputs: f64,
    /// bytes of intermediate tensors
    pub intermediate: f64,
    /// bytes of stage outputs
    pub outputs: f64,
}

impl StageMemory {
    pub fn total(&self) -> f64 {
        self.inputs + self.intermediate + self.outputs
    }

    /// The paper's "k·S·d_model" coefficient for this stage.
    pub fn coeff(&self, m: &ModelDims, s: u64) -> f64 {
        self.total() / (s as f64 * m.d_model as f64)
    }
}

/// Table 1 row for one stage.
pub fn stage_memory(m: &ModelDims, s: u64, stage: FwdStage) -> StageMemory {
    let sf = s as f64;
    let dm = m.d_model as f64;
    let hidden = 2.0 * sf * dm; // one bf16 [S, d_model] tensor
    match stage {
        // ① int32 tokens in, bf16 embeddings out.
        FwdStage::Embedding => StageMemory {
            stage,
            inputs: 4.0 * sf,
            intermediate: 0.0,
            outputs: hidden,
        },
        // ② QKV (6·S·H·d_head bytes: Q,K,V bf16) + equal all-to-all
        // buffers; flash attention itself adds only Out (+LSE, folded into
        // outputs here like the paper's 2·S·d_model).
        FwdStage::Attention => {
            let qkv = 6.0 * sf * (m.n_heads * m.d_head) as f64;
            StageMemory {
                stage,
                inputs: hidden,
                intermediate: qkv + qkv, // QKV + all-to-all buffers
                outputs: hidden,
            }
        }
        // ③ four SwiGLU intermediates of size [S, d_ff] (gate, up,
        // silu(gate), product) in bf16 = 8·S·d_ff bytes.
        FwdStage::FeedForward => StageMemory {
            stage,
            inputs: hidden,
            intermediate: 8.0 * sf * m.d_ff as f64,
            outputs: hidden,
        },
        // ④ fp32 logits + fp32 log-softmax: 2 · 4·S·V = 8·S·V bytes.
        FwdStage::CrossEntropy => StageMemory {
            stage,
            inputs: hidden,
            intermediate: 8.0 * sf * m.vocab as f64,
            outputs: 4.0, // scalar fp32 loss
        },
    }
}

/// All four rows of Table 1.
pub fn table1(m: &ModelDims, s: u64) -> Vec<StageMemory> {
    STAGES.iter().map(|&st| stage_memory(m, s, st)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic model with the paper's canonical ratios:
    /// H·d_head = d_model, d_ff = 2.67·d_model, V = 30·d_model.
    fn canonical() -> ModelDims {
        ModelDims {
            name: "canonical",
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            n_kv_heads: 32,
            d_head: 128,
            d_ff: (2.67f64 * 4096.0) as u64,
            vocab: 30 * 4096,
        }
    }

    #[test]
    fn attention_total_is_16() {
        let m = canonical();
        let sm = stage_memory(&m, 1 << 20, FwdStage::Attention);
        assert!((sm.coeff(&m, 1 << 20) - 16.0).abs() < 0.01);
    }

    #[test]
    fn ffn_total_is_25() {
        let m = canonical();
        let sm = stage_memory(&m, 1 << 20, FwdStage::FeedForward);
        let c = sm.coeff(&m, 1 << 20);
        assert!((c - 25.0).abs() < 0.5, "ffn coeff {c}");
    }

    #[test]
    fn ce_total_is_240() {
        let m = canonical();
        let sm = stage_memory(&m, 1 << 20, FwdStage::CrossEntropy);
        let c = sm.coeff(&m, 1 << 20);
        assert!((c - 242.0).abs() < 1.0, "ce coeff {c}");
    }

    #[test]
    fn ce_dominates_everything() {
        // §2.2: the loss stage is the single largest consumer.
        let m = ModelDims::llama3_8b();
        let rows = table1(&m, 1 << 20);
        let ce = rows[3].total();
        for r in &rows[..3] {
            assert!(ce > 5.0 * r.total());
        }
    }

    #[test]
    fn embedding_scales_linearly() {
        let m = ModelDims::llama3_8b();
        let a = stage_memory(&m, 1000, FwdStage::Embedding).total();
        let b = stage_memory(&m, 2000, FwdStage::Embedding).total();
        assert!((b / a - 2.0).abs() < 1e-9);
    }
}
