//! Analytical transformer model: the paper's §2.2 memory taxonomy and the
//! FLOPs model the simulator prices against.
//!
//! - [`dims`] — model dimension presets (Llama3-8B, Qwen3-32B) and GQA
//!   factors γ = 1 + 2/g, β = 4 + 4/g.
//! - [`flops`] — forward/backward FLOPs per component.
//! - [`activation`] — Table 1: theoretical peak memory per forward stage.
//! - [`attn_memory`] — Tables 2 & 6: peak activation memory inside the
//!   attention block per method/phase, in units of (S/C)·hidden bytes.

pub mod activation;
pub mod attn_memory;
pub mod dims;
pub mod flops;

pub use attn_memory::{AttnMethod, BwdPhase, FwdPhase};
pub use dims::ModelDims;
