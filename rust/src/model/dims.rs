//! Model dimensions (kept in sync with `python/compile/configs.py`).

/// Dimensions of a decoder-only Transformer, following the paper's §2.2
/// notation: `L` layers, `H` query heads, GQA group size `g = H / Hkv`,
/// hidden `d_model`, per-head `d_head`, FFN `d_ff`, vocab `V`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ModelDims {
    pub name: &'static str,
    pub d_model: u64,
    pub n_layers: u64,
    pub n_heads: u64,
    pub n_kv_heads: u64,
    pub d_head: u64,
    pub d_ff: u64,
    pub vocab: u64,
}

impl ModelDims {
    /// Llama3-8B: H=32 query heads, 8 KV heads (g=4), d_head=128.
    pub fn llama3_8b() -> Self {
        ModelDims {
            name: "llama3-8b",
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            n_kv_heads: 8,
            d_head: 128,
            d_ff: 14336,
            vocab: 128_256,
        }
    }

    /// Qwen3-32B: H=64 query heads, 8 KV heads (g=8). Note Qwen3 fixes
    /// d_head=128 explicitly, so H·d_head = 8192 ≠ d_model = 5120 — this
    /// matters for both attention FLOPs and QKV buffer sizes.
    pub fn qwen3_32b() -> Self {
        ModelDims {
            name: "qwen3-32b",
            d_model: 5120,
            n_layers: 64,
            n_heads: 64,
            n_kv_heads: 8,
            d_head: 128,
            d_ff: 25600,
            vocab: 151_936,
        }
    }

    /// The functional-pipeline config the AOT artifacts are built for
    /// (python `TINY`).
    pub fn tiny() -> Self {
        ModelDims {
            name: "tiny",
            d_model: 128,
            n_layers: 2,
            n_heads: 8,
            n_kv_heads: 4,
            d_head: 16,
            d_ff: 352,
            vocab: 512,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "llama3-8b" => Some(Self::llama3_8b()),
            "qwen3-32b" => Some(Self::qwen3_32b()),
            "tiny" => Some(Self::tiny()),
            _ => None,
        }
    }

    /// GQA group size g = H / Hkv (queries per KV head).
    pub fn g(&self) -> u64 {
        self.n_heads / self.n_kv_heads
    }

    /// γ = 1 + 2/g — combined Q,K,V size relative to Q (paper §2.2).
    pub fn gamma(&self) -> f64 {
        1.0 + 2.0 / self.g() as f64
    }

    /// β = 4 + 4/g — the eight backward-pass attention tensors
    /// (Q, K, V, Out, dOut, dQ, dK, dV) relative to Q (paper §2.2).
    pub fn beta(&self) -> f64 {
        4.0 + 4.0 / self.g() as f64
    }

    /// Width of the concatenated query projection H·d_head.
    pub fn q_width(&self) -> u64 {
        self.n_heads * self.d_head
    }

    /// Width of the concatenated K (or V) projection Hkv·d_head.
    pub fn kv_width(&self) -> u64 {
        self.n_kv_heads * self.d_head
    }

    /// Approximate parameter count (embedding untied from the output head).
    pub fn params(&self) -> u64 {
        let (d, f, v) = (self.d_model, self.d_ff, self.vocab);
        let per_layer =
            d * self.q_width() * 2 + 2 * d * self.kv_width() + 3 * d * f + 2 * d;
        2 * v * d + self.n_layers * per_layer + d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama_dims() {
        let m = ModelDims::llama3_8b();
        assert_eq!(m.g(), 4);
        assert!((m.gamma() - 1.5).abs() < 1e-12);
        assert!((m.beta() - 5.0).abs() < 1e-12);
        assert_eq!(m.q_width(), m.d_model); // H·d_head == d_model for llama
        let b = m.params() as f64 / 1e9;
        assert!((b - 8.0).abs() < 0.35, "llama params {b}B");
    }

    #[test]
    fn qwen_dims() {
        let m = ModelDims::qwen3_32b();
        assert_eq!(m.g(), 8);
        assert_eq!(m.q_width(), 8192); // explicit d_head=128
        assert!((m.gamma() - 1.25).abs() < 1e-12);
        assert!((m.beta() - 4.5).abs() < 1e-12);
        let b = m.params() as f64 / 1e9;
        assert!((b - 32.8).abs() < 1.7, "qwen params {b}B");
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["llama3-8b", "qwen3-32b", "tiny"] {
            assert_eq!(ModelDims::by_name(n).unwrap().name, n);
        }
        assert!(ModelDims::by_name("nope").is_none());
    }
}
