//! Configuration planner: turn the calibrated simulator into a capacity-
//! planning tool. The paper's headline — UPipe unlocks 5M-token context on
//! one 8×H100 node — is one point in a large configuration space
//! (method × U × ulysses/ring × π × pinning × model × cluster × S); this
//! subsystem searches the whole space:
//!
//! - [`space`] enumerates every valid [`crate::config::ParallelConfig`]
//!   for a (model, cluster) pair — including per-method AC modes,
//!   micro-batch counts and TP×CP mixes ([`SweepDims`]) — generalizing
//!   the hand-picked §5.1 presets;
//! - [`search`] holds the galloping bisection that *verifies* each
//!   configuration's solved context wall (and finds it from scratch for
//!   fallback cells, warm-startable from a neighbour cell's wall) and
//!   the Pareto-frontier extractor;
//! - [`eval`] runs the two-phase sweep on a worker pool — walls solved
//!   in closed form from sampled-polynomial peak models
//!   ([`crate::engine::symbolic`]) and confirmed with two streamed
//!   probes each, full pricing for the final cells only — with
//!   hashed-key lock-striped memos, producing a ranked [`PlanOutcome`].
//!   `feasibility_only` skips pricing entirely, making multi-node
//!   walls-only frontier sweeps (N×8 H100) near-free.
//!
//! Since the fleet-placement work the *cluster itself* is a sweep
//! dimension: [`space::enumerate_shapes`] expands a heterogeneous
//! [`crate::config::FleetSpec`] into candidate shapes, and
//! [`eval::place_with`] evaluates a job against every non-dominated
//! shape — dominated shapes (≤ another shape in every per-rank hardware
//! dimension at the same grid) are skipped before any probe, and model
//! fits are shared across shapes of identical hardware via the
//! [`crate::config::ClusterConfig::hardware_fingerprint`] in every cache
//! key. Driven by `repro place --fleet` and `/v1/placement`.
//!
//! Driven by `repro plan` / `repro frontier` (`--json` for machine-readable
//! output, `--feasibility-only` for walls-only sweeps, `--cold` for the
//! probe-per-bisection reference path) and rendered by
//! [`crate::report::planner`].
//!
//! All evaluator memos live in a caller-owned [`PlannerCaches`]: [`plan`]
//! is the one-shot wrapper (fresh caches per call), [`plan_with`] the
//! session entry point [`crate::service::PlannerService`] keeps warm
//! across requests, [`walls_at`] answers point capacity queries from
//! a warm session's verified walls / fitted models with zero streamed
//! probes, and [`throughput_at`] is its pricing-side counterpart —
//! step time and throughput at an arbitrary length from memoized
//! reports, fitted step-time models, or one streamed timing pass.

pub mod eval;
pub mod search;
pub mod space;

pub use eval::{
    place, place_with, plan, plan_with, throughput_at, walls_at, CacheTier, ConfigPlan,
    PlacementOutcome, PlacementRequest, PlanOutcome, PlanRequest, PlannerCaches, PriceSource,
    ShapePlacement, ThroughputAt, ThroughputAtOutcome, WallAt, WallSource, WallsAtOutcome,
};
pub use search::{bisect_max, bisect_max_from, pareto_front};
pub use space::{enumerate_shapes, enumerate_space, ClusterShape, SweepDims};
