//! Configuration planner: turn the calibrated simulator into a capacity-
//! planning tool. The paper's headline — UPipe unlocks 5M-token context on
//! one 8×H100 node — is one point in a large configuration space
//! (method × U × ulysses/ring × π × pinning × model × cluster × S); this
//! subsystem searches the whole space:
//!
//! - [`space`] enumerates every valid [`crate::config::ParallelConfig`]
//!   for a (model, cluster) pair — including per-method AC modes,
//!   micro-batch counts and TP×CP mixes ([`SweepDims`]) — generalizing
//!   the hand-picked §5.1 presets;
//! - [`search`] holds the bisection that finds each configuration's
//!   maximum trainable context (warm-startable from a neighbour cell's
//!   wall) and the Pareto-frontier extractor;
//! - [`eval`] runs the two-phase sweep on a worker pool — streamed
//!   peak-only feasibility for bisection probes, full pricing for the
//!   final cells — with hashed-key lock-striped memos, producing a
//!   ranked [`PlanOutcome`].
//!
//! Driven by `repro plan` / `repro frontier` (`--json` for machine-readable
//! output) and rendered by [`crate::report::planner`].

pub mod eval;
pub mod search;
pub mod space;

pub use eval::{plan, ConfigPlan, PlanOutcome, PlanRequest};
pub use search::{bisect_max, bisect_max_from, pareto_front};
pub use space::{enumerate_space, SweepDims};
