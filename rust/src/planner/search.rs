//! Search primitives: bisection for the maximum trainable context of one
//! configuration (cold, warm-started from a neighbour cell's wall, or —
//! the default path — *verifying* a wall solved in closed form by the
//! symbolic peak model), and Pareto-frontier extraction over the
//! evaluated space.
//!
//! The symbolic solver's exactness guarantee lives here: a solved wall is
//! passed to [`bisect_max_from`] as the hint, which confirms it with
//! exactly two probes (hint feasible, hint + quantum infeasible) and
//! **gallops to the true wall if the model mispredicted** — so for any
//! monotone feasibility predicate the result is identical to
//! [`bisect_max`], whatever the model said. A predicted-infeasible cell
//! (hint = quantum) and a predicted-at-cap cell (hint = cap) each verify
//! with a single probe.

/// Largest multiple of `quantum` in `[quantum, cap]` for which `feasible`
/// holds, assuming monotone feasibility (peak memory grows with S).
/// Returns `None` when even one quantum of context is infeasible.
///
/// Probes O(log(cap/quantum)) points: a doubling ascent brackets the
/// memory wall, then bisection pins it to quantum granularity. `cap` must
/// be a multiple of `quantum`.
pub fn bisect_max(quantum: u64, cap: u64, mut feasible: impl FnMut(u64) -> bool) -> Option<u64> {
    assert!(quantum > 0 && cap >= quantum, "bad search bounds");
    assert!(cap % quantum == 0, "cap must be a multiple of quantum");
    if !feasible(quantum) {
        return None;
    }
    let mut lo = quantum; // feasible
    let mut hi = quantum;
    loop {
        if hi >= cap {
            return Some(lo);
        }
        hi = (hi * 2).min(cap);
        if feasible(hi) {
            lo = hi;
            if hi == cap {
                return Some(cap);
            }
        } else {
            break;
        }
    }
    Some(bisect_between(lo, hi, quantum, &mut feasible))
}

/// Pin the wall inside a bracket. Invariant on entry: `feasible(lo)`,
/// `!feasible(hi)`, both multiples of `quantum` — shared by the cold and
/// warm-started searches so their convergence can never diverge.
fn bisect_between(
    mut lo: u64,
    mut hi: u64,
    quantum: u64,
    feasible: &mut impl FnMut(u64) -> bool,
) -> u64 {
    while hi - lo > quantum {
        let mut mid = (lo + hi) / 2 / quantum * quantum;
        if mid <= lo {
            mid = lo + quantum;
        }
        if feasible(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

/// [`bisect_max`] warm-started from a neighbour cell's known wall.
///
/// Feasibility is monotone in S, and neighbouring configurations (pin
/// variants, AC-offload vs AC-GPU, adjacent micro-batch/TP cells of the
/// same method) hit walls near each other — so instead of always doubling
/// up from `quantum`, gallop outward from `hint` to bracket the wall, then
/// bisect. Under monotone feasibility the result is *identical* to the
/// cold search for any hint value; only the probe count changes (2 probes
/// when the hint is exactly the wall, vs O(log(cap/quantum)) cold).
pub fn bisect_max_from(
    quantum: u64,
    cap: u64,
    hint: Option<u64>,
    mut feasible: impl FnMut(u64) -> bool,
) -> Option<u64> {
    let Some(hint) = hint else { return bisect_max(quantum, cap, feasible) };
    assert!(quantum > 0 && cap >= quantum, "bad search bounds");
    assert!(cap % quantum == 0, "cap must be a multiple of quantum");
    // Snap the hint onto the search lattice.
    let h = ((hint / quantum).max(1) * quantum).min(cap);
    let (lo, hi) = if feasible(h) {
        if h == cap {
            return Some(cap);
        }
        // Gallop up for the first infeasible bound.
        let mut lo = h;
        let mut step = quantum;
        loop {
            let cand = lo.saturating_add(step).min(cap);
            if feasible(cand) {
                lo = cand;
                if cand == cap {
                    return Some(cap);
                }
                step = step.saturating_mul(2);
            } else {
                break (lo, cand);
            }
        }
    } else {
        if h == quantum {
            return None;
        }
        // Gallop down for a feasible lower bound.
        let mut hi = h;
        let mut step = quantum;
        loop {
            let cand = h.saturating_sub(step).max(quantum);
            if feasible(cand) {
                break (cand, hi);
            }
            hi = cand;
            if cand == quantum {
                return None;
            }
            step = step.saturating_mul(2);
        }
    };
    Some(bisect_between(lo, hi, quantum, &mut feasible))
}

/// Indices of the non-dominated points among `(cost, benefit)` pairs —
/// cost minimized (peak GiB), benefit maximized (tokens/s/GPU). A point is
/// dominated when another is no worse on both axes and strictly better on
/// at least one.
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<usize> {
    let mut front = Vec::new();
    'outer: for (i, &(ci, bi)) in points.iter().enumerate() {
        for (j, &(cj, bj)) in points.iter().enumerate() {
            if j != i && cj <= ci && bj >= bi && (cj < ci || bj > bi) {
                continue 'outer;
            }
        }
        front.push(i);
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn bisection_finds_exact_wall() {
        let q = 1u64 << 17; // 128K
        for wall_steps in [1u64, 2, 3, 37, 40, 255, 256] {
            let wall = wall_steps * q;
            let mut probes = 0;
            let got = bisect_max(q, 256 * q, |s| {
                probes += 1;
                s <= wall
            });
            assert_eq!(got, Some(wall), "wall_steps={wall_steps}");
            assert!(probes <= 20, "{probes} probes for wall_steps={wall_steps}");
        }
    }

    #[test]
    fn bisection_edge_cases() {
        let q = 1024u64;
        assert_eq!(bisect_max(q, 64 * q, |_| false), None);
        assert_eq!(bisect_max(q, 64 * q, |_| true), Some(64 * q));
        assert_eq!(bisect_max(q, q, |_| true), Some(q));
        assert_eq!(bisect_max(q, 64 * q, |s| s < 2 * q), Some(q));
    }

    #[test]
    fn prop_bisection_matches_linear_scan() {
        prop::check("bisect-vs-scan", 200, &[(0, 65), (1, 64)], |a| {
            let q = 512u64;
            let wall = a[0] as u64 * q; // 0 => infeasible everywhere
            let cap = a[1] as u64 * q;
            let got = bisect_max(q, cap, |s| s <= wall);
            let want = (1..=cap / q).map(|k| k * q).filter(|&s| s <= wall).max();
            got == want
        });
    }

    #[test]
    fn prop_hinted_bisection_matches_cold_for_any_hint() {
        // Any hint — exact, low, high, off-lattice, out of range — must
        // leave the result identical to the cold search.
        prop::check("bisect-hint-vs-cold", 300, &[(0, 65), (1, 64), (0, 70)], |a| {
            let q = 512u64;
            let wall = a[0] as u64 * q;
            let cap = a[1] as u64 * q;
            let hint = a[2] as u64 * q / 3; // deliberately off-lattice
            let cold = bisect_max(q, cap, |s| s <= wall);
            let warm = bisect_max_from(q, cap, Some(hint), |s| s <= wall);
            let none = bisect_max_from(q, cap, None, |s| s <= wall);
            cold == warm && cold == none
        });
    }

    #[test]
    fn exact_hint_costs_two_probes() {
        let q = 1u64 << 17;
        for wall_steps in [1u64, 7, 100, 255] {
            let wall = wall_steps * q;
            let mut probes = 0;
            let got = bisect_max_from(q, 256 * q, Some(wall), |s| {
                probes += 1;
                s <= wall
            });
            assert_eq!(got, Some(wall));
            assert!(probes <= 2, "{probes} probes with an exact hint (wall {wall_steps})");
        }
    }

    #[test]
    fn solved_wall_verification_probe_counts() {
        // The symbolic solver's probe budget, pinned: an exact solved
        // wall costs 2 probes, a wall at the cap costs 1 (cap feasible),
        // a predicted-infeasible cell costs 1 (quantum infeasible), and
        // an off-by-one prediction (the allocator's bucketed-reservation
        // slack) still costs only 2.
        let q = 1u64 << 17;
        let cap = 256 * q;
        let count = |wall: Option<u64>, hint: u64| {
            let mut probes = 0;
            let got = bisect_max_from(q, cap, Some(hint), |s| {
                probes += 1;
                wall.is_some_and(|w| s <= w)
            });
            assert_eq!(got, wall.filter(|&w| w >= q).map(|w| w.min(cap)));
            probes
        };
        assert_eq!(count(Some(40 * q), 40 * q), 2, "exact wall");
        assert_eq!(count(Some(cap), cap), 1, "wall at cap");
        assert_eq!(count(None, q), 1, "infeasible at one quantum");
        assert_eq!(count(Some(40 * q), 41 * q), 2, "hint one step high");
        assert!(count(Some(40 * q), 39 * q) <= 4, "hint one step low");
    }

    #[test]
    fn near_hint_beats_cold_probe_count() {
        let q = 1u64 << 17;
        let wall = 40 * q;
        let count = |hint: Option<u64>| {
            let mut probes = 0;
            let got = bisect_max_from(q, 256 * q, hint, |s| {
                probes += 1;
                s <= wall
            });
            assert_eq!(got, Some(wall));
            probes
        };
        let cold = count(None);
        // A hint one quantum off (the typical pin/AC neighbour distance).
        assert!(count(Some(wall + q)) < cold, "hint high");
        assert!(count(Some(wall - q)) < cold, "hint low");
    }

    #[test]
    fn hinted_edge_cases() {
        let q = 1024u64;
        // Infeasible everywhere: any hint still returns None.
        for hint in [q, 3 * q, 64 * q, 1_000_000 * q] {
            assert_eq!(bisect_max_from(q, 64 * q, Some(hint), |_| false), None);
        }
        // Feasible everywhere: any hint still returns cap.
        for hint in [0, q, 63 * q, 64 * q] {
            assert_eq!(bisect_max_from(q, 64 * q, Some(hint), |_| true), Some(64 * q));
        }
        // Single-point range.
        assert_eq!(bisect_max_from(q, q, Some(q), |_| true), Some(q));
        assert_eq!(bisect_max_from(q, q, Some(q), |_| false), None);
    }

    #[test]
    fn frontier_on_known_points() {
        // (cost, benefit): b dominates d; a, b, c are the frontier.
        let pts = [(1.0, 1.0), (2.0, 5.0), (4.0, 9.0), (3.0, 4.0)];
        assert_eq!(pareto_front(&pts), vec![0, 1, 2]);
        // Duplicates survive together (neither strictly better).
        let dup = [(1.0, 1.0), (1.0, 1.0)];
        assert_eq!(pareto_front(&dup), vec![0, 1]);
        assert!(pareto_front(&[]).is_empty());
    }

    #[test]
    fn prop_frontier_is_sound_and_complete() {
        prop::check("pareto-sound", 50, &[(1, 30), (0, 10_000)], |a| {
            let mut rng = Rng::new(a[1] as u64);
            let pts: Vec<(f64, f64)> = (0..a[0])
                .map(|_| (rng.f64() * 10.0, rng.f64() * 10.0))
                .collect();
            let front = pareto_front(&pts);
            let dominated = |i: usize| {
                pts.iter().enumerate().any(|(j, &(cj, bj))| {
                    let (ci, bi) = pts[i];
                    j != i && cj <= ci && bj >= bi && (cj < ci || bj > bi)
                })
            };
            // Sound: no frontier point is dominated. Complete: every
            // non-frontier point is dominated by someone.
            (0..pts.len()).all(|i| front.contains(&i) != dominated(i))
        });
    }
}
