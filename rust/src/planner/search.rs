//! Search primitives: bisection for the maximum trainable context of one
//! configuration, and Pareto-frontier extraction over the evaluated space.

/// Largest multiple of `quantum` in `[quantum, cap]` for which `feasible`
/// holds, assuming monotone feasibility (peak memory grows with S).
/// Returns `None` when even one quantum of context is infeasible.
///
/// Probes O(log(cap/quantum)) points: a doubling ascent brackets the
/// memory wall, then bisection pins it to quantum granularity. `cap` must
/// be a multiple of `quantum`.
pub fn bisect_max(quantum: u64, cap: u64, mut feasible: impl FnMut(u64) -> bool) -> Option<u64> {
    assert!(quantum > 0 && cap >= quantum, "bad search bounds");
    assert!(cap % quantum == 0, "cap must be a multiple of quantum");
    if !feasible(quantum) {
        return None;
    }
    let mut lo = quantum; // feasible
    let mut hi = quantum;
    loop {
        if hi >= cap {
            return Some(lo);
        }
        hi = (hi * 2).min(cap);
        if feasible(hi) {
            lo = hi;
            if hi == cap {
                return Some(cap);
            }
        } else {
            break;
        }
    }
    // Invariant: feasible(lo), !feasible(hi), both multiples of quantum.
    while hi - lo > quantum {
        let mut mid = (lo + hi) / 2 / quantum * quantum;
        if mid <= lo {
            mid = lo + quantum;
        }
        if feasible(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Some(lo)
}

/// Indices of the non-dominated points among `(cost, benefit)` pairs —
/// cost minimized (peak GiB), benefit maximized (tokens/s/GPU). A point is
/// dominated when another is no worse on both axes and strictly better on
/// at least one.
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<usize> {
    let mut front = Vec::new();
    'outer: for (i, &(ci, bi)) in points.iter().enumerate() {
        for (j, &(cj, bj)) in points.iter().enumerate() {
            if j != i && cj <= ci && bj >= bi && (cj < ci || bj > bi) {
                continue 'outer;
            }
        }
        front.push(i);
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn bisection_finds_exact_wall() {
        let q = 1u64 << 17; // 128K
        for wall_steps in [1u64, 2, 3, 37, 40, 255, 256] {
            let wall = wall_steps * q;
            let mut probes = 0;
            let got = bisect_max(q, 256 * q, |s| {
                probes += 1;
                s <= wall
            });
            assert_eq!(got, Some(wall), "wall_steps={wall_steps}");
            assert!(probes <= 20, "{probes} probes for wall_steps={wall_steps}");
        }
    }

    #[test]
    fn bisection_edge_cases() {
        let q = 1024u64;
        assert_eq!(bisect_max(q, 64 * q, |_| false), None);
        assert_eq!(bisect_max(q, 64 * q, |_| true), Some(64 * q));
        assert_eq!(bisect_max(q, q, |_| true), Some(q));
        assert_eq!(bisect_max(q, 64 * q, |s| s < 2 * q), Some(q));
    }

    #[test]
    fn prop_bisection_matches_linear_scan() {
        prop::check("bisect-vs-scan", 200, &[(0, 65), (1, 64)], |a| {
            let q = 512u64;
            let wall = a[0] as u64 * q; // 0 => infeasible everywhere
            let cap = a[1] as u64 * q;
            let got = bisect_max(q, cap, |s| s <= wall);
            let want = (1..=cap / q).map(|k| k * q).filter(|&s| s <= wall).max();
            got == want
        });
    }

    #[test]
    fn frontier_on_known_points() {
        // (cost, benefit): b dominates d; a, b, c are the frontier.
        let pts = [(1.0, 1.0), (2.0, 5.0), (4.0, 9.0), (3.0, 4.0)];
        assert_eq!(pareto_front(&pts), vec![0, 1, 2]);
        // Duplicates survive together (neither strictly better).
        let dup = [(1.0, 1.0), (1.0, 1.0)];
        assert_eq!(pareto_front(&dup), vec![0, 1]);
        assert!(pareto_front(&[]).is_empty());
    }

    #[test]
    fn prop_frontier_is_sound_and_complete() {
        prop::check("pareto-sound", 50, &[(1, 30), (0, 10_000)], |a| {
            let mut rng = Rng::new(a[1] as u64);
            let pts: Vec<(f64, f64)> = (0..a[0])
                .map(|_| (rng.f64() * 10.0, rng.f64() * 10.0))
                .collect();
            let front = pareto_front(&pts);
            let dominated = |i: usize| {
                pts.iter().enumerate().any(|(j, &(cj, bj))| {
                    let (ci, bi) = pts[i];
                    j != i && cj <= ci && bj >= bi && (cj < ci || bj > bi)
                })
            };
            // Sound: no frontier point is dominated. Complete: every
            // non-frontier point is dominated by someone.
            (0..pts.len()).all(|i| front.contains(&i) != dominated(i))
        });
    }
}
