//! Parallel plan evaluation: run the calibrated simulator across the
//! sweep space on a worker pool, find each configuration's maximum
//! trainable context, and extract the Pareto frontier at a reference
//! sequence length.
//!
//! Evaluation is two-phase. Context walls only need *feasibility* (peak
//! HBM / host RAM vs the limits), so phase 1 streams each schedule
//! straight into the peak-only `FeasibilityKernel` — no `Vec<Op>` trace,
//! no component timing, no memory timeline. Full pricing runs only for
//! the final cells (each configuration's max-context point and the
//! reference point), where traces are memoized in a [`TraceCache`] (pin
//! variants share them); `feasibility_only` skips phase 2 entirely,
//! which makes massive multi-node walls-only sweeps near-free.
//!
//! Phase 1 itself no longer bisects by default. Peak memory is a
//! degree-≤2 polynomial in `S/C` within a divisibility class (see
//! [`crate::engine::symbolic`]), so the planner *samples* the kernel at
//! a few small lattice lengths per cell family, fits the polynomial,
//! **solves** the HBM/host walls in closed form and verifies the solved
//! wall with exactly two streamed probes (wall feasible, wall + quantum
//! infeasible) via the galloping search — identical results to the
//! bisection path with O(samples + 2) instead of O(log S) probes per
//! cell. Fitted models are shared across a whole family: pin variants
//! (same trace, different host budget — one *pin-agnostic* probe with a
//! recorded host peak answers both) and micro-batch variants (identical
//! per-micro-batch alloc/free cycles leave both peaks unchanged). Cells
//! whose samples fail the drift check fall back to warm-started
//! bisection; `--cold` (`symbolic = false`, `warm_start = false`)
//! restores the exact PR 3 probe-per-bisection behaviour end to end.
//!
//! Phase 2 is symbolic too. Each *pricing* family — a [`FamilyKey`] plus
//! micro-batch and pinning, since step time moves with both — pays for
//! exactly one full engine simulation: the **anchor** (the family's
//! reference cell), which builds the family's trace, seeds the report
//! memo, and drift-verifies a [`TimeModel`] fitted from three streamed
//! [`crate::engine::TimingKernel`] samples at small lattice lengths.
//! Every other cell of the family is priced by streaming the schedule
//! through the timing kernel — the same pricing arithmetic *bitwise*,
//! with no `Vec<Op>` and no timeline — so `priced_sims` collapses to one
//! per family while rankings, throughputs and Pareto flags stay
//! identical to `--cold` by construction, not by tolerance. The fitted
//! models (`None` for drift-rejected families: pressure-penalized or
//! FPDT-stalled step times are not polynomial) never change a reported
//! number; they power the zero-work surfaces — warm `/v1/frontier`
//! replies and [`throughput_at`] point queries.
//! Both phases memoize results under hashed [`CellKey`]s in lock-striped
//! maps, so replayed cells cost a hash lookup and the worker pool never
//! serializes on a global mutex. The whole sweep prices against the
//! request's [`Calibration`] — default or `--refit`-fitted — whose
//! provenance rides along into the outcome.
//!
//! Since the service redesign, every memo lives in a [`PlannerCaches`]
//! owned by the *caller*: [`plan`] builds a fresh set per invocation (the
//! one-shot CLI behaviour, unchanged), while [`plan_with`] lets a
//! long-lived session — [`crate::service::PlannerService`], which backs
//! `repro serve-plan` — reuse traces, probes, fitted models and verified
//! walls across requests. A repeated request then replays entirely from
//! memos (zero streamed probes, zero priced sims, bitwise-identical
//! results), and [`walls_at`] answers point capacity questions ("can I
//! train S tokens on this config?") from the session's verified walls or
//! fitted polynomials without streaming anything.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::config::presets::RunPreset;
use crate::config::{ClusterConfig, CpMethod, ParallelConfig};
use crate::engine::symbolic::drift_ok;
use crate::engine::{
    Calibration, Feasibility, PeakModel, PeakProbe, PeakSample, RefitInfo, StepReport, TimeModel,
};
use crate::model::ModelDims;
use crate::schedule::{
    feasibility_with, method_seq_cap, peak_probe_with, simulate_cached, timing_sample_with,
    timing_with, CellKey, FamilyKey, Quantities, TraceCache,
};
use crate::util::cancel::CancelToken;
use crate::util::failpoint;
use crate::util::fmt::GIB;
use crate::util::pool::parallel_map;
use crate::util::stripe::StripedMap;

use super::search::{bisect_max_from, pareto_front};
use super::space::{enumerate_shapes, enumerate_space, ClusterShape, SweepDims};
use crate::config::FleetSpec;

/// What to sweep and how hard to search.
#[derive(Debug, Clone)]
pub struct PlanRequest {
    pub model: ModelDims,
    pub cluster: ClusterConfig,
    /// Reference sequence length for the throughput/frontier comparison.
    pub reference_s: u64,
    /// Context-search granularity, tokens.
    pub quantum: u64,
    /// Context-search ceiling, tokens.
    pub cap_s: u64,
    /// Which optional dimensions to sweep (AC modes, micro-batches, TP,
    /// the §5.3.2 compositions).
    pub dims: SweepDims,
    /// Calibration every cell is priced with (default, or refit from a
    /// measurements file).
    pub calibration: Calibration,
    /// Provenance when `calibration` came from `--refit`.
    pub refit: Option<RefitInfo>,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// Warm-start fallback bisections from already-evaluated neighbour
    /// cells. Results are identical either way (feasibility is monotone
    /// in S); kept as a switch so the equivalence is testable.
    pub warm_start: bool,
    /// Solve context walls from sampled-polynomial peak models (two
    /// verification probes per cell) instead of bisecting. Identical
    /// results by construction; `--cold` disables this *and*
    /// `warm_start`, restoring the probe-per-bisection behaviour.
    pub symbolic: bool,
    /// Walls only: skip all reference-length/max-context pricing
    /// (phase 2). Throughput, peak-GiB and Pareto fields stay `None`.
    pub feasibility_only: bool,
    /// Cooperative deadline, checked between cells. An expired token
    /// makes remaining cells return empty placeholders, suppresses every
    /// memo insert for cells evaluated after expiry (all-or-nothing:
    /// nothing partial is ever published), and sets
    /// [`PlanOutcome::cancelled`]. The default never cancels.
    pub cancel: CancelToken,
}

impl PlanRequest {
    pub fn new(model: ModelDims, cluster: ClusterConfig) -> Self {
        PlanRequest {
            model,
            cluster,
            reference_s: 1 << 20,
            quantum: 128 * 1024,
            cap_s: 32 << 20,
            dims: SweepDims::default(),
            calibration: Calibration::default(),
            refit: None,
            threads: 0,
            warm_start: true,
            symbolic: true,
            feasibility_only: false,
            cancel: CancelToken::none(),
        }
    }
}

/// One evaluated configuration.
#[derive(Debug, Clone)]
pub struct ConfigPlan {
    pub parallel: ParallelConfig,
    /// Largest trainable S at quantum granularity; `None` if the
    /// configuration cannot train even one quantum of context.
    pub max_context: Option<u64>,
    /// True when the search hit the request's `cap_s` while still
    /// feasible: `max_context` is then a lower bound, not a memory wall.
    pub hit_cap: bool,
    /// Peak GiB / tokens/s/GPU at the max trainable context (`None` in
    /// feasibility-only sweeps).
    pub max_ctx_peak_gib: Option<f64>,
    pub max_ctx_tok_s_gpu: Option<f64>,
    /// Peak GiB / tokens/s/GPU at the reference length (`None` when the
    /// configuration is infeasible there, or in feasibility-only sweeps).
    pub ref_peak_gib: Option<f64>,
    pub ref_tok_s_gpu: Option<f64>,
    /// On the (peak GiB, tokens/s/GPU) Pareto frontier at the reference
    /// length?
    pub pareto: bool,
}

/// The full plan: configurations ranked best-first, plus search accounting.
#[derive(Debug, Clone)]
pub struct PlanOutcome {
    pub model: ModelDims,
    pub cluster: ClusterConfig,
    pub reference_s: u64,
    pub quantum: u64,
    /// Ranked by max trainable context, then reference throughput.
    pub configs: Vec<ConfigPlan>,
    /// Provenance when the sweep priced against a refit calibration.
    pub refit: Option<RefitInfo>,
    /// Cells actually evaluated (streamed feasibility probes + fully
    /// priced simulations); memo hits are not counted.
    pub simulations: u64,
    /// Phase-1 streamed kernel runs (model samples + wall verification,
    /// or bisection probes under `--cold`).
    pub feasibility_probes: u64,
    /// Phase-2 fully priced simulations (0 in feasibility-only sweeps).
    /// Under symbolic pricing this collapses to at most one *anchor* sim
    /// per pricing family — the sim that builds the family's trace and
    /// drift-verifies its fitted step-time model.
    pub priced_sims: u64,
    /// Phase-2 cells priced by streaming the schedule through the timing
    /// kernel instead of fully simulating — bitwise-identical step times
    /// with no materialized trace or timeline (0 under `--cold` and in
    /// feasibility-only sweeps). The three streamed fit samples behind
    /// each fitted [`TimeModel`] are counted in neither this nor
    /// `feasibility_probes` — they are fit overhead, not cell pricing.
    pub modeled_prices: u64,
    /// Cell families whose sampled-polynomial model fit (walls solved in
    /// closed form) vs families that fell back to bisection.
    pub symbolic_models: u64,
    pub symbolic_fallbacks: u64,
    /// Pricing families whose fitted step-time model passed the anchor
    /// drift check vs families that fell back to streamed-exact pricing
    /// (session-wide, like `symbolic_models`; a fallback never changes a
    /// reported number — it only disables the O(1) prediction tier).
    pub time_models: u64,
    pub time_fallbacks: u64,
    /// Was this a walls-only sweep (no phase-2 pricing)?
    pub feasibility_only: bool,
    /// The request's deadline expired before the sweep finished: some
    /// configs are empty placeholders, nothing was memoized after
    /// expiry, and the caller must not publish or serialize this
    /// outcome as a plan (the service answers a structured 504).
    pub cancelled: bool,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub wall_s: f64,
}

impl PlanOutcome {
    /// The top-ranked configuration (the "what should I run" answer).
    pub fn best(&self) -> Option<&ConfigPlan> {
        self.configs.first()
    }

    /// Frontier configurations, cheapest peak first.
    pub fn frontier(&self) -> Vec<&ConfigPlan> {
        let mut f: Vec<&ConfigPlan> = self.configs.iter().filter(|c| c.pareto).collect();
        f.sort_by(|a, b| {
            let (pa, pb) = (a.ref_peak_gib, b.ref_peak_gib);
            pa.unwrap_or(f64::INFINITY).total_cmp(&pb.unwrap_or(f64::INFINITY))
        });
        f
    }
}

/// Neighbourhood key for warm-starting *fallback* bisections: every pin /
/// AC / micro-batch / TP variant of one method hits its wall near the
/// others'. Under the symbolic solver this only seeds cells whose model
/// fit failed; the hint is just a starting point either way — the
/// galloping search stays correct however far off it is. (Per-call, not
/// session state: hints are only meaningful between neighbours of one
/// sweep, and keeping them out of [`PlannerCaches`] keeps per-request
/// probe accounting reproducible.)
type WarmKey = CpMethod;

/// Verified-wall memo key: the cell family plus everything else the wall
/// depends on — micro-batch and pinning pick the exact sweep cell (so
/// within a single sweep every cell keys uniquely and per-call probe
/// accounting is unchanged by the memo), and the search lattice
/// (quantum, rounded cap) pins the granularity the wall was verified at.
type WallKey = (FamilyKey, u64, bool, u64, u64);

/// Pricing-family memo key: the cell family plus micro-batch and pinning.
/// Peaks are micro-batch-invariant (identical per-micro-batch alloc/free
/// cycles), but step *time* is not — every micro-batch adds a full
/// compute/comm cycle — and pinning changes the host budget the offload
/// stream prices against. Within one sweep the key identifies exactly one
/// configuration; across session requests it is what lets a new sweep or
/// point query reuse an already-anchored family.
type TimeKey = (FamilyKey, u64, bool);

/// Session-persistent evaluator state: every memo the sweep consults,
/// owned by the caller instead of one `plan()` invocation. The one-shot
/// [`plan`] wrapper builds a fresh set; the `PlannerService` session API
/// keeps one alive across requests, so repeated requests replay from
/// memos and new queries against already-swept families reuse fitted
/// models and verified walls. Sharing is always safe: every key embeds
/// the model and calibration fingerprints plus the full cell layout
/// ([`CellKey`] / [`FamilyKey`]), so refit calibrations and different
/// models/clusters never alias, and memoized walls are exact by the
/// solver's verification contract.
pub struct PlannerCaches {
    /// Priced op traces (phase 2); pin variants share entries.
    trace: TraceCache,
    /// Pin-agnostic streamed peak probes (symbolic phase 1 samples and
    /// wall verifications; also `walls_at`'s cold tier).
    probe_memo: StripedMap<CellKey, PeakProbe>,
    /// Budgeted feasibility probes (the `--cold` bisection path).
    feas_memo: StripedMap<(CellKey, bool), Feasibility>,
    /// Fully priced step reports (phase 2), keyed with pinning.
    report_memo: StripedMap<(CellKey, bool), StepReport>,
    /// Fitted symbolic peak models per cell family (`None` = the family's
    /// samples failed the drift check; it bisects instead).
    models: StripedMap<FamilyKey, Option<PeakModel>>,
    /// Fitted symbolic step-time models per pricing family (`None` = the
    /// family's samples or anchor failed the drift check; its cells are
    /// priced by streaming instead — same numbers, no O(1) prediction).
    time_models: StripedMap<TimeKey, Option<TimeModel>>,
    /// Verified context walls (`None` = infeasible at one quantum).
    walls: StripedMap<WallKey, Option<u64>>,
    /// Lifetime counts of entries dropped by calibration-epoch
    /// invalidation, per tier in [`PlannerCaches::sizes`] order (distinct
    /// from LRU `evictions`: invalidations are correctness drops).
    invalidations: [AtomicU64; 7],
}

impl PlannerCaches {
    pub fn new() -> Self {
        PlannerCaches {
            trace: TraceCache::new(),
            probe_memo: StripedMap::default(),
            feas_memo: StripedMap::default(),
            report_memo: StripedMap::default(),
            models: StripedMap::default(),
            time_models: StripedMap::default(),
            walls: StripedMap::default(),
            invalidations: Default::default(),
        }
    }

    /// Surgical calibration-epoch invalidation: drop exactly the entries
    /// keyed on the stale calibration fingerprint `fp` in **every** tier
    /// — including the precious fitted-model and verified-walls tiers,
    /// whose entries are exact only for the calibration they were fitted
    /// under — and leave entries under every other fingerprint (other
    /// fleet hardware pools, pinned-measurement requests) warm. Returns
    /// the dropped count per tier in [`PlannerCaches::sizes`] order.
    pub fn invalidate_fingerprint(&self, fp: u64) -> [(&'static str, u64); 7] {
        let dropped = [
            ("traces", self.trace.invalidate_fingerprint(fp)),
            ("peak_probes", self.probe_memo.remove_if(|k| k.cal_fp() == fp)),
            ("budgeted_probes", self.feas_memo.remove_if(|k| k.0.cal_fp() == fp)),
            ("priced_reports", self.report_memo.remove_if(|k| k.0.cal_fp() == fp)),
            ("models", self.models.remove_if(|k| k.cal_fp() == fp)),
            ("time_models", self.time_models.remove_if(|k| k.0.cal_fp() == fp)),
            ("walls", self.walls.remove_if(|k| k.0.cal_fp() == fp)),
        ];
        for (i, (_, n)) in dropped.iter().enumerate() {
            self.invalidations[i].fetch_add(*n, Ordering::Relaxed);
        }
        dropped
    }

    /// Lifetime entries dropped by [`PlannerCaches::invalidate_fingerprint`]
    /// across every tier.
    pub fn total_invalidated(&self) -> u64 {
        self.invalidations.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Entry counts for observability (`/v1/health`): traces, peak
    /// probes, budgeted probes, priced reports, fitted peak models,
    /// fitted step-time models, walls.
    pub fn sizes(&self) -> [usize; 7] {
        [
            self.trace.len(),
            self.probe_memo.len(),
            self.feas_memo.len(),
            self.report_memo.len(),
            self.models.len(),
            self.time_models.len(),
            self.walls.len(),
        ]
    }

    /// Approximate resident bytes across every tier.
    pub fn bytes(&self) -> usize {
        self.trace.bytes()
            + self.probe_memo.bytes()
            + self.feas_memo.bytes()
            + self.report_memo.bytes()
            + self.models.bytes()
            + self.time_models.bytes()
            + self.walls.bytes()
    }

    /// Per-tier observability snapshot (`/v1/health`'s byte sizes,
    /// eviction and invalidation counts), in [`PlannerCaches::sizes`]
    /// order.
    pub fn tiers(&self) -> [CacheTier; 7] {
        let inv = |i: usize| self.invalidations[i].load(Ordering::Relaxed);
        [
            CacheTier {
                name: "traces",
                entries: self.trace.len(),
                bytes: self.trace.bytes(),
                evictions: self.trace.evictions(),
                invalidations: inv(0),
            },
            CacheTier {
                name: "peak_probes",
                entries: self.probe_memo.len(),
                bytes: self.probe_memo.bytes(),
                evictions: self.probe_memo.evicted(),
                invalidations: inv(1),
            },
            CacheTier {
                name: "budgeted_probes",
                entries: self.feas_memo.len(),
                bytes: self.feas_memo.bytes(),
                evictions: self.feas_memo.evicted(),
                invalidations: inv(2),
            },
            CacheTier {
                name: "priced_reports",
                entries: self.report_memo.len(),
                bytes: self.report_memo.bytes(),
                evictions: self.report_memo.evicted(),
                invalidations: inv(3),
            },
            CacheTier {
                name: "models",
                entries: self.models.len(),
                bytes: self.models.bytes(),
                evictions: self.models.evicted(),
                invalidations: inv(4),
            },
            CacheTier {
                name: "time_models",
                entries: self.time_models.len(),
                bytes: self.time_models.bytes(),
                evictions: self.time_models.evicted(),
                invalidations: inv(5),
            },
            CacheTier {
                name: "walls",
                entries: self.walls.len(),
                bytes: self.walls.bytes(),
                evictions: self.walls.evicted(),
                invalidations: inv(6),
            },
        ]
    }

    /// Evict from the *bulk* tiers — cheapest to rebuild, biggest
    /// footprint first: traces, then priced reports, then budgeted
    /// probes, then peak probes — until the caches plus `extra_bytes` of
    /// caller-side state (the service's plan memo) fit `budget`. Returns
    /// entries dropped. Never touches the fitted-model or verified-walls
    /// tiers: those are tiny, expensive to refit, and exactly what keeps
    /// the warm walls path probe-free.
    pub fn evict_bulk_to_fit(&self, budget: usize, extra_bytes: usize) -> u64 {
        let excess = |c: &Self| (c.bytes() + extra_bytes).saturating_sub(budget);
        let mut dropped = 0u64;
        let e = excess(self);
        if e == 0 {
            return dropped;
        }
        dropped += self.trace.evict_lru(self.trace.bytes().saturating_sub(e));
        let e = excess(self);
        if e == 0 {
            return dropped;
        }
        dropped += self.report_memo.evict_lru(self.report_memo.bytes().saturating_sub(e));
        let e = excess(self);
        if e == 0 {
            return dropped;
        }
        dropped += self.feas_memo.evict_lru(self.feas_memo.bytes().saturating_sub(e));
        let e = excess(self);
        if e == 0 {
            return dropped;
        }
        dropped += self.probe_memo.evict_lru(self.probe_memo.bytes().saturating_sub(e));
        dropped
    }

    /// Last-resort eviction of the precious tiers (fitted peak models,
    /// then fitted step-time models, then verified walls) — only reached
    /// when a budget is set below the tiers' own floor after every bulk
    /// tier is already empty.
    pub fn evict_precious_to_fit(&self, budget: usize, extra_bytes: usize) -> u64 {
        let excess = |c: &Self| (c.bytes() + extra_bytes).saturating_sub(budget);
        let mut dropped = 0u64;
        let e = excess(self);
        if e == 0 {
            return dropped;
        }
        dropped += self.models.evict_lru(self.models.bytes().saturating_sub(e));
        let e = excess(self);
        if e == 0 {
            return dropped;
        }
        dropped += self.time_models.evict_lru(self.time_models.bytes().saturating_sub(e));
        let e = excess(self);
        if e == 0 {
            return dropped;
        }
        dropped += self.walls.evict_lru(self.walls.bytes().saturating_sub(e));
        dropped
    }

    /// Evict everything (a long-lived daemon's pressure valve); the
    /// session stays usable and simply re-evaluates on the next request.
    pub fn clear(&self) {
        self.trace.clear();
        self.probe_memo.clear();
        self.feas_memo.clear();
        self.report_memo.clear();
        self.models.clear();
        self.time_models.clear();
        self.walls.clear();
    }
}

/// One cache tier's observability snapshot (see [`PlannerCaches::tiers`]):
/// what `/v1/health` reports so operators can size `--cache-budget`.
#[derive(Debug, Clone, Copy)]
pub struct CacheTier {
    pub name: &'static str,
    pub entries: usize,
    pub bytes: usize,
    /// Entries dropped under memory pressure (LRU).
    pub evictions: u64,
    /// Entries dropped because their calibration fingerprint went stale
    /// when an online-calibration epoch published.
    pub invalidations: u64,
}

impl Default for PlannerCaches {
    fn default() -> Self {
        Self::new()
    }
}

/// Sweep the whole configuration space with a fresh set of caches — the
/// one-shot CLI path, byte-identical to the session path by construction.
pub fn plan(req: &PlanRequest) -> PlanOutcome {
    plan_with(req, &PlannerCaches::new())
}

/// Sweep the whole configuration space for the request, consulting (and
/// filling) the caller-owned session caches. All probe/simulation/cache
/// counters in the returned [`PlanOutcome`] are per-call deltas — a fully
/// warm replay reports zero everywhere — except `symbolic_models` /
/// `symbolic_fallbacks` and `time_models` / `time_fallbacks`, which count
/// the session's fitted families.
pub fn plan_with(req: &PlanRequest, caches: &PlannerCaches) -> PlanOutcome {
    let t0 = Instant::now();
    // `--cold` (symbolic and warm_start both off) is a measurement
    // switch: it must exercise the probe-per-bisection path end to end,
    // so it never reads a warm session's memos (a memoized wall or probe
    // would turn the "reference path" into memo lookups). It runs against
    // a private fresh cache set — exactly a one-shot CLI run — and leaves
    // the session state untouched.
    let fresh;
    let caches = if req.symbolic || req.warm_start {
        caches
    } else {
        fresh = PlannerCaches::new();
        &fresh
    };
    let space = enumerate_space(&req.model, &req.cluster, &req.dims);
    let cache = &caches.trace;
    let (trace_hits0, trace_misses0) = (cache.hits(), cache.misses());
    let calib = req.calibration.clone();
    let gpus = req.cluster.total_gpus();
    let probes = AtomicU64::new(0);
    let priced = AtomicU64::new(0);
    let modeled = AtomicU64::new(0);
    // Phase-specific memos, hashed keys + striped locks, owned by the
    // session. The symbolic probe memo is pin-agnostic (CellKey already
    // excludes pinning); the budgeted `--cold` memo and the pricing memo
    // append pin_memory, which changes the host budget but not the trace.
    let probe_memo = &caches.probe_memo;
    let feas_memo = &caches.feas_memo;
    let report_memo = &caches.report_memo;
    let models = &caches.models;
    let time_models = &caches.time_models;
    let warm: StripedMap<WarmKey, u64> = StripedMap::default();
    let quantum = req.quantum.max(1);
    let cap = (req.cap_s / quantum).max(1) * quantum;

    let preset_of = |parallel: &ParallelConfig, s: u64| RunPreset {
        model: req.model.clone(),
        cluster: req.cluster.clone(),
        parallel: parallel.clone(),
        seq_len: s,
    };
    // Phase 1a — pin-agnostic streamed probe (symbolic mode): one kernel
    // run answers every host budget and doubles as a polynomial sample.
    let probe = |parallel: &ParallelConfig, s: u64| -> PeakProbe {
        let preset = preset_of(parallel, s);
        let key = CellKey::new(&preset, &calib);
        match probe_memo.get(&key) {
            Some(p) => p,
            None => {
                failpoint::fire_or_panic("planner.probe");
                let p = peak_probe_with(&preset, &calib);
                probes.fetch_add(1, Ordering::Relaxed);
                probe_memo.insert(key, p)
            }
        }
    };
    // Phase 1b — budgeted probe (the `--cold` / PR 3 bisection path).
    let feasible = |parallel: &ParallelConfig, s: u64| -> bool {
        let preset = preset_of(parallel, s);
        let key = (CellKey::new(&preset, &calib), parallel.pin_memory);
        let f = match feas_memo.get(&key) {
            Some(f) => f,
            None => {
                let f = feasibility_with(&preset, &calib);
                probes.fetch_add(1, Ordering::Relaxed);
                feas_memo.insert(key, f)
            }
        };
        f.feasible()
    };
    // Fit one family's peak model from samples at small lattice lengths:
    // linear from 3 (the common case — every schedule's byte sizes are
    // affine in S/C), quadratic from 4 if the linear drift check fails.
    // The last sample is always held out; `None` (unclean samples or
    // drift) sends the family back to bisection.
    let fit_model = |parallel: &ParallelConfig| -> Option<PeakModel> {
        failpoint::fire_or_panic("planner.fit");
        let c = parallel.cp_degree.max(1);
        let sample = |i: u64| -> Option<PeakSample> {
            let pr = probe(parallel, i * quantum);
            pr.clean().then_some(PeakSample {
                k: i * quantum / c,
                peak_bytes: pr.peak_bytes,
                host_peak: pr.host_peak,
            })
        };
        let s123 = [sample(1)?, sample(2)?, sample(3)?];
        PeakModel::fit(&s123).or_else(|| {
            let s4 = sample(4)?;
            PeakModel::fit(&[s123[0], s123[1], s123[2], s4])
        })
    };
    // Fit one pricing family's step-time model from three streamed
    // timing-kernel samples at small lattice lengths (ample headroom:
    // the regime where step time genuinely is polynomial in S/C). The
    // anchor sim is the held-out drift check — `None` (unclean anchor,
    // unclean samples, or drift) keeps the family on streamed-exact
    // pricing, which changes nothing but the O(1) prediction tier.
    let fit_time = |parallel: &ParallelConfig, anchor_s: u64, anchor: &StepReport| {
        if anchor.oom || anchor.failed.is_some() {
            return None;
        }
        let c = parallel.cp_degree.max(1);
        let sample =
            |i: u64| timing_sample_with(&preset_of(parallel, i * quantum), &calib, i * quantum / c);
        let s123 = [sample(1)?, sample(2)?, sample(3)?];
        let m = TimeModel::fit(&s123)?;
        drift_ok(m.predict_step(anchor_s / c), anchor.step_time).then_some(m)
    };
    // Phase 2 — final cells only. `--cold` fully prices every cell
    // (trace + timeline). Symbolic mode fully prices one *anchor* cell
    // per pricing family — which also fits and drift-verifies the
    // family's step-time model — and prices every other cell by
    // streaming the schedule through the timing kernel: the same
    // `Engine::run` arithmetic bitwise, no trace, no timeline.
    let price = |parallel: &ParallelConfig, s: u64| -> StepReport {
        let preset = preset_of(parallel, s);
        let key = (CellKey::new(&preset, &calib), parallel.pin_memory);
        if let Some(r) = report_memo.get(&key) {
            return r;
        }
        failpoint::fire_or_panic("planner.price");
        let tkey: TimeKey = (key.0.family(), parallel.micro_batch, parallel.pin_memory);
        if req.symbolic && time_models.get(&tkey).is_some() {
            // Streamed-exact pricing, whether the family's model fitted
            // (`Some`) or drift-rejected (`None`) — the values are
            // `Engine::run` semantics either way.
            let r = timing_with(&preset, &calib);
            modeled.fetch_add(1, Ordering::Relaxed);
            return report_memo.insert_weighed(key, r, 0);
        }
        let r = simulate_cached(&preset, &calib, cache);
        priced.fetch_add(1, Ordering::Relaxed);
        if req.symbolic {
            time_models.insert(tkey, fit_time(parallel, s, &r));
        }
        // The timeline vector dominates a report's footprint; declare it
        // so the service's byte budget can rank this tier honestly.
        let payload = r.timeline.samples().len()
            * std::mem::size_of::<crate::memory::tracker::Sample>();
        report_memo.insert_weighed(key, r, payload)
    };
    let ok = |r: &StepReport| !r.oom && r.failed.is_none();

    let mut evaluated = parallel_map(&space, req.threads, |_, p| {
        // Cooperative deadline check between cells: past expiry the
        // remaining cells return empty placeholders and publish nothing
        // — the caller sees `cancelled` and never serializes them.
        if req.cancel.is_cancelled() {
            return ConfigPlan {
                parallel: p.clone(),
                max_context: None,
                hit_cap: false,
                max_ctx_peak_gib: None,
                max_ctx_tok_s_gpu: None,
                ref_peak_gib: None,
                ref_tok_s_gpu: None,
                pareto: false,
            };
        }
        let wkey: WarmKey = p.method;
        let fam = CellKey::new(&preset_of(p, quantum), &calib).family();
        let wall_key: WallKey = (fam, p.micro_batch, p.pin_memory, quantum, cap);
        // A wall verified by an earlier request in this session is exact
        // (the solver's verification contract), so recomputing could only
        // reproduce it — a warm replay of the whole sweep probes nothing.
        let memoized_wall = caches.walls.get(&wall_key);
        let max = if let Some(w) = memoized_wall {
            w
        } else if req.symbolic {
            // Budgets and limits for this cell (S-independent).
            let qd = Quantities::new(&preset_of(p, quantum));
            let host_budget = qd.host_ram_for_offload();
            let c = p.cp_degree.max(1);
            // Method-imposed sequence ceilings clamp the closed-form
            // solve only — the verified search range stays identical to
            // `--cold`'s, so results cannot diverge.
            let cap_m = match method_seq_cap(p.method) {
                Some(mc) => ((mc / quantum) * quantum).min(cap),
                None => cap,
            };
            // Check-then-act: workers racing on a cold family may fit it
            // more than once (first insert wins, extras are discarded) —
            // the same benign-race policy as the trace cache, chosen over
            // holding a stripe lock across streamed sample probes. Probe
            // counts are deterministic at `threads = 1`, which is what
            // the equivalence tests pin.
            let model = match models.get(&fam) {
                Some(m) => m,
                None => models.insert(fam, fit_model(p)),
            };
            // The solved wall is only a *hint*: `bisect_max_from` verifies
            // it with two probes (wall feasible, wall + quantum not) and
            // self-corrects by galloping if the model mispredicted. A
            // solved `None` (infeasible even at one quantum) verifies
            // with a single probe at `quantum`.
            let hint = if let Some(m) = model {
                let wall = m.solve_wall(qd.hbm_limit, host_budget, c, quantum, cap_m);
                Some(wall.unwrap_or(quantum))
            } else if req.warm_start {
                // Fit failed: fall back to the neighbour-wall warm start.
                warm.get(&wkey)
            } else {
                None
            };
            bisect_max_from(quantum, cap, hint, |s| probe(p, s).feasible_with_host(host_budget))
        } else {
            let hint = if req.warm_start { warm.get(&wkey) } else { None };
            bisect_max_from(quantum, cap, hint, |s| feasible(p, s))
        };
        // All-or-nothing publication: a deadline that expired while this
        // cell evaluated suppresses its memo inserts too, so a 504 can
        // never leave freshly-written session state behind.
        let expired = req.cancel.is_cancelled();
        if memoized_wall.is_none() && !expired {
            caches.walls.insert(wall_key, max);
        }
        if req.warm_start && !expired {
            // First finisher seeds the family; later fallback cells
            // gallop from it. An infeasible family still seeds the
            // bottom of the range.
            warm.insert(wkey, max.unwrap_or(quantum));
        }
        let (mut max_peak, mut max_tput) = (None, None);
        let mut ref_peak = None;
        let mut ref_tput = None;
        if !req.feasibility_only && !expired {
            // Reference cell first: a pricing family's first priced cell
            // is its anchor sim, and the reference length sits in ample
            // headroom where step time is polynomial — anchoring at the
            // near-wall max-context cell instead would drift-reject
            // nearly every family (pressure penalties are not).
            let rref = price(p, req.reference_s);
            if ok(&rref) {
                ref_peak = Some(rref.peak_bytes / GIB);
                ref_tput = rref.tokens_per_sec_per_gpu(p.micro_batch * req.reference_s, gpus);
            }
            if let Some(s) = max {
                let r = price(p, s);
                max_peak = Some(r.peak_bytes / GIB);
                // Throughput counts every micro-batch's tokens over the
                // whole (CP × TP) world.
                max_tput = r.tokens_per_sec_per_gpu(p.micro_batch * s, gpus);
            }
        }
        ConfigPlan {
            parallel: p.clone(),
            max_context: max,
            hit_cap: max == Some(cap),
            max_ctx_peak_gib: max_peak,
            max_ctx_tok_s_gpu: max_tput,
            ref_peak_gib: ref_peak,
            ref_tok_s_gpu: ref_tput,
            pareto: false,
        }
    });

    // Rank: longest max context first, then reference throughput, then
    // lowest reference peak; the sort is stable, so exact ties keep the
    // enumeration's paper-preset order (pinned before unpinned, smaller
    // micro-batch and TP first) — which is also the whole tiebreak in
    // feasibility-only sweeps, where no pricing exists.
    evaluated.sort_by(|a, b| {
        let by_ctx = b.max_context.unwrap_or(0).cmp(&a.max_context.unwrap_or(0));
        let (ta, tb) = (a.ref_tok_s_gpu.unwrap_or(0.0), b.ref_tok_s_gpu.unwrap_or(0.0));
        let (pa, pb) = (a.ref_peak_gib, b.ref_peak_gib);
        let by_peak = pa.unwrap_or(f64::INFINITY).total_cmp(&pb.unwrap_or(f64::INFINITY));
        by_ctx.then(tb.total_cmp(&ta)).then(by_peak)
    });

    // Pareto frontier over the reference-length (peak, throughput) points
    // (vacuously empty in feasibility-only sweeps).
    let pts: Vec<(usize, (f64, f64))> = evaluated
        .iter()
        .enumerate()
        .filter_map(|(i, cp)| match (cp.ref_peak_gib, cp.ref_tok_s_gpu) {
            (Some(m), Some(t)) => Some((i, (m, t))),
            _ => None,
        })
        .collect();
    let coords: Vec<(f64, f64)> = pts.iter().map(|&(_, p)| p).collect();
    for fi in pareto_front(&coords) {
        evaluated[pts[fi].0].pareto = true;
    }

    let (fitted, fallbacks) = models.fold((0u64, 0u64), |(f, fb), _, m| match m {
        Some(_) => (f + 1, fb),
        None => (f, fb + 1),
    });
    let (tfit, tfall) = time_models.fold((0u64, 0u64), |(f, fb), _, m| match m {
        Some(_) => (f + 1, fb),
        None => (f, fb + 1),
    });
    let n_probes = probes.load(Ordering::Relaxed);
    let n_priced = priced.load(Ordering::Relaxed);
    let n_modeled = modeled.load(Ordering::Relaxed);
    PlanOutcome {
        model: req.model.clone(),
        cluster: req.cluster.clone(),
        reference_s: req.reference_s,
        quantum,
        configs: evaluated,
        refit: req.refit.clone(),
        simulations: n_probes + n_priced + n_modeled,
        feasibility_probes: n_probes,
        priced_sims: n_priced,
        modeled_prices: n_modeled,
        symbolic_models: fitted,
        symbolic_fallbacks: fallbacks,
        time_models: tfit,
        time_fallbacks: tfall,
        feasibility_only: req.feasibility_only,
        cancelled: req.cancel.is_cancelled(),
        // Per-call deltas: the session's trace cache outlives the request.
        cache_hits: cache.hits() - trace_hits0,
        cache_misses: cache.misses() - trace_misses0,
        wall_s: t0.elapsed().as_secs_f64(),
    }
}

/// One configuration's answer to a point capacity query ([`walls_at`]).
#[derive(Debug, Clone)]
pub struct WallAt {
    pub parallel: ParallelConfig,
    /// Trainable at the query's lattice point?
    pub feasible: bool,
    /// Device-peak prediction at the lattice point, GiB — from the
    /// family's fitted model, or from the probe itself on the cold tier
    /// (`None` for fallback families answered by a memoized wall).
    pub predicted_peak_gib: Option<f64>,
    pub source: WallSource,
}

/// Which tier answered a point query — strongest first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WallSource {
    /// A wall verified by an earlier sweep in this session: exact.
    VerifiedWall,
    /// The family's fitted peak polynomial: zero probes, exact up to the
    /// drift contract plus the allocator's bucketed-reservation slack.
    Model,
    /// A streamed kernel probe (cold family; memoized for next time).
    Probe,
}

impl WallSource {
    pub fn label(&self) -> &'static str {
        match self {
            WallSource::VerifiedWall => "wall",
            WallSource::Model => "model",
            WallSource::Probe => "probe",
        }
    }
}

/// A point capacity query's full answer (one row per sweep configuration).
#[derive(Debug, Clone)]
pub struct WallsAtOutcome {
    pub model: ModelDims,
    pub cluster: ClusterConfig,
    /// The queried sequence length, verbatim.
    pub seq: u64,
    /// `seq` rounded up to the search lattice — walls are verified at
    /// quantum granularity, and feasibility is monotone in S, so the
    /// covering lattice point answers conservatively.
    pub seq_lattice: u64,
    pub quantum: u64,
    pub cells: Vec<WallAt>,
    /// Streamed kernel probes this query ran (0 once the session is warm
    /// for this model/calibration/lattice).
    pub probes: u64,
    pub from_walls: u64,
    pub from_models: u64,
    pub from_probes: u64,
    /// The request's deadline expired before every cell answered: cold
    /// cells were skipped without probing (and memoized nothing), so the
    /// caller must answer a structured 504 instead of serializing this.
    pub cancelled: bool,
}

/// Point capacity query: "is sequence length `seq` trainable?" for every
/// configuration in the request's sweep space — the session's warm-path
/// Q&A (`POST /v1/walls {"at": ...}`). Three answer tiers, strongest
/// first: a verified wall memoized by an earlier sweep on the same
/// lattice (exact, zero probes), the family's fitted peak polynomial
/// (zero probes, prediction), or a streamed kernel probe (cold family —
/// memoized under its [`CellKey`] for next time). After any full sweep
/// with the same model/calibration/lattice, every configuration answers
/// from tier 1.
pub fn walls_at(req: &PlanRequest, seq: u64, caches: &PlannerCaches) -> WallsAtOutcome {
    let space = enumerate_space(&req.model, &req.cluster, &req.dims);
    let calib = req.calibration.clone();
    let quantum = req.quantum.max(1);
    let cap = (req.cap_s / quantum).max(1) * quantum;
    let s_lat = seq.div_ceil(quantum).max(1) * quantum;
    let probes = AtomicU64::new(0);
    let preset_of = |parallel: &ParallelConfig, s: u64| RunPreset {
        model: req.model.clone(),
        cluster: req.cluster.clone(),
        parallel: parallel.clone(),
        seq_len: s,
    };
    let cells = parallel_map(&space, req.threads, |_, p| {
        let fam = CellKey::new(&preset_of(p, quantum), &calib).family();
        let c = p.cp_degree.max(1);
        let model = caches.models.get(&fam).flatten();
        let predicted = model.map(|m| m.predict_peak(s_lat / c) / GIB);
        let cell = |feasible: bool, peak: Option<f64>, source: WallSource| WallAt {
            parallel: p.clone(),
            feasible,
            predicted_peak_gib: peak,
            source,
        };
        if let Some(w) = caches.walls.get(&(fam, p.micro_batch, p.pin_memory, quantum, cap)) {
            match w {
                Some(wall) if s_lat <= wall => {
                    return cell(true, predicted, WallSource::VerifiedWall);
                }
                // A wall strictly below the cap is a real memory/method
                // wall; monotone feasibility answers any longer S.
                Some(wall) if wall < cap => {
                    return cell(false, predicted, WallSource::VerifiedWall);
                }
                None => return cell(false, predicted, WallSource::VerifiedWall),
                // The memoized search hit its cap while still feasible
                // and the query lies beyond it: the memo cannot answer.
                Some(_) => {}
            }
        }
        if let Some(m) = model {
            let qd = Quantities::new(&preset_of(p, s_lat));
            let beyond = method_seq_cap(p.method).is_some_and(|mc| s_lat > mc);
            let ok = !beyond
                && m.predict_feasible(s_lat / c, qd.hbm_limit, qd.host_ram_for_offload());
            return cell(ok, predicted, WallSource::Model);
        }
        // Cold tier: one streamed probe, memoized under its CellKey. An
        // expired deadline skips the probe (and the memo insert) — the
        // placeholder row is never serialized, the service answers 504.
        if req.cancel.is_cancelled() {
            return cell(false, predicted, WallSource::Probe);
        }
        let preset = preset_of(p, s_lat);
        let key = CellKey::new(&preset, &calib);
        let pr = match caches.probe_memo.get(&key) {
            Some(pr) => pr,
            None => {
                failpoint::fire_or_panic("planner.probe");
                probes.fetch_add(1, Ordering::Relaxed);
                caches.probe_memo.insert(key, peak_probe_with(&preset, &calib))
            }
        };
        let budget = Quantities::new(&preset).host_ram_for_offload();
        let peak = if pr.clean() { Some(pr.peak_bytes / GIB) } else { predicted };
        cell(pr.feasible_with_host(budget), peak, WallSource::Probe)
    });
    let mut from = [0u64; 3];
    for c in &cells {
        match c.source {
            WallSource::VerifiedWall => from[0] += 1,
            WallSource::Model => from[1] += 1,
            WallSource::Probe => from[2] += 1,
        }
    }
    WallsAtOutcome {
        model: req.model.clone(),
        cluster: req.cluster.clone(),
        seq,
        seq_lattice: s_lat,
        quantum,
        probes: probes.load(Ordering::Relaxed),
        from_walls: from[0],
        from_models: from[1],
        from_probes: from[2],
        cancelled: req.cancel.is_cancelled(),
        cells,
    }
}

/// One configuration's answer to a throughput point query
/// ([`throughput_at`]).
#[derive(Debug, Clone)]
pub struct ThroughputAt {
    pub parallel: ParallelConfig,
    /// Step time at the queried length, seconds (`None` when the cell is
    /// infeasible there).
    pub step_time: Option<f64>,
    /// Tokens/s/GPU at the queried length (`None` when infeasible).
    pub tok_s_gpu: Option<f64>,
    pub source: PriceSource,
}

/// Which tier priced a throughput point query's cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PriceSource {
    /// A memoized priced report — this exact cell was priced before
    /// (anchor sim or streamed): exact.
    Report,
    /// The family's fitted step-time polynomial, guarded by its peak
    /// model's feasibility prediction: zero streamed work, exact up to
    /// the drift contract (and the pressure penalties near the wall,
    /// which the drift contract deliberately excludes from this tier's
    /// fitted families).
    Model,
    /// A streamed timing-kernel run — exact `Engine::run` semantics,
    /// memoized under the cell's key for next time.
    Stream,
}

impl PriceSource {
    pub fn label(&self) -> &'static str {
        match self {
            PriceSource::Report => "report",
            PriceSource::Model => "model",
            PriceSource::Stream => "stream",
        }
    }
}

/// A throughput point query's full answer (one row per configuration).
#[derive(Debug, Clone)]
pub struct ThroughputAtOutcome {
    pub model: ModelDims,
    pub cluster: ClusterConfig,
    /// The queried sequence length, verbatim — throughput queries price
    /// at the *exact* length, no lattice rounding (step time is defined
    /// everywhere; only walls live on the search lattice).
    pub seq: u64,
    pub quantum: u64,
    pub cells: Vec<ThroughputAt>,
    /// Streamed timing-kernel runs this query cost (0 once the session
    /// has reports or fitted models covering every cell at this length).
    pub streamed: u64,
    pub from_reports: u64,
    pub from_models: u64,
    pub from_streams: u64,
}

/// Throughput point query: step time and tokens/s/GPU at sequence length
/// `seq` for every configuration in the request's sweep space — the
/// pricing counterpart of [`walls_at`]. Three answer tiers, cheapest
/// sufficient first: a memoized priced report (exact), the family's
/// fitted step-time model guarded by its peak model's feasibility
/// prediction (zero streamed work), or a streamed timing-kernel run
/// (exact `Engine::run` semantics, no trace or timeline, memoized for
/// next time). After a full priced sweep on the same model/calibration,
/// the sweep's own lengths answer entirely from tier 1 and fresh lengths
/// answer from tier 2 wherever the family's model fitted.
pub fn throughput_at(req: &PlanRequest, seq: u64, caches: &PlannerCaches) -> ThroughputAtOutcome {
    let space = enumerate_space(&req.model, &req.cluster, &req.dims);
    let calib = req.calibration.clone();
    let gpus = req.cluster.total_gpus();
    let streamed = AtomicU64::new(0);
    let preset_of = |parallel: &ParallelConfig, s: u64| RunPreset {
        model: req.model.clone(),
        cluster: req.cluster.clone(),
        parallel: parallel.clone(),
        seq_len: s,
    };
    let cells = parallel_map(&space, req.threads, |_, p| {
        let preset = preset_of(p, seq);
        let key = (CellKey::new(&preset, &calib), p.pin_memory);
        let cell = |r: &StepReport, source: PriceSource| ThroughputAt {
            parallel: p.clone(),
            step_time: (!r.oom && r.failed.is_none()).then_some(r.step_time),
            tok_s_gpu: r.tokens_per_sec_per_gpu(p.micro_batch * seq, gpus),
            source,
        };
        if let Some(r) = caches.report_memo.get(&key) {
            return cell(&r, PriceSource::Report);
        }
        let fam = key.0.family();
        let tkey: TimeKey = (fam, p.micro_batch, p.pin_memory);
        if let (Some(Some(tm)), Some(pm)) =
            (caches.time_models.get(&tkey), caches.models.get(&fam).flatten())
        {
            let c = p.cp_degree.max(1);
            let qd = Quantities::new(&preset);
            let beyond = method_seq_cap(p.method).is_some_and(|mc| seq > mc);
            let feasible = !beyond
                && pm.predict_feasible(seq / c, qd.hbm_limit, qd.host_ram_for_offload());
            let (st, tput) = if feasible {
                let st = tm.predict_step(seq / c);
                (Some(st), Some((p.micro_batch * seq) as f64 / (st * gpus as f64)))
            } else {
                (None, None)
            };
            return ThroughputAt {
                parallel: p.clone(),
                step_time: st,
                tok_s_gpu: tput,
                source: PriceSource::Model,
            };
        }
        // Cold tier: one streamed timing run, memoized under the cell key
        // (weightless: no timeline rides along).
        let r = timing_with(&preset, &calib);
        streamed.fetch_add(1, Ordering::Relaxed);
        let r = caches.report_memo.insert_weighed(key, r, 0);
        cell(&r, PriceSource::Stream)
    });
    let mut from = [0u64; 3];
    for c in &cells {
        match c.source {
            PriceSource::Report => from[0] += 1,
            PriceSource::Model => from[1] += 1,
            PriceSource::Stream => from[2] += 1,
        }
    }
    ThroughputAtOutcome {
        model: req.model.clone(),
        cluster: req.cluster.clone(),
        seq,
        quantum: req.quantum.max(1),
        streamed: streamed.load(Ordering::Relaxed),
        from_reports: from[0],
        from_models: from[1],
        from_streams: from[2],
        cells,
    }
}

/// Fleet placement request: which fleet to sweep and the job every
/// candidate cluster shape is evaluated against. The cluster stops being
/// a fixed input and becomes a sweep dimension — [`place_with`] expands
/// the fleet into shapes ([`enumerate_shapes`]), prunes dominated ones,
/// and runs the ordinary planner on each survivor.
#[derive(Debug, Clone)]
pub struct PlacementRequest {
    pub fleet: FleetSpec,
    pub model: ModelDims,
    pub reference_s: u64,
    pub quantum: u64,
    pub cap_s: u64,
    pub dims: SweepDims,
    /// Baseline calibration (measured on the paper's H100 testbed, or a
    /// `--refit`). Each shape prices against
    /// [`Calibration::scaled_for`]`(&shape.cluster)`, so H100 pools keep
    /// the exact baseline (and its cache entries) while faster hardware
    /// re-keys under a scaled fingerprint.
    pub calibration: Calibration,
    pub refit: Option<RefitInfo>,
    /// Worker threads for the shape-parallel sweep (0 = auto). Each
    /// shape's *inner* sweep runs at `threads = 1` — per-shape probe and
    /// anchor accounting stays deterministic — and parallelism comes
    /// from evaluating shapes concurrently on the shared caches.
    pub threads: usize,
    /// Skip dominated shapes before any probe (the default); `--no-prune`
    /// evaluates every shape. The ranked `placements` are identical
    /// either way by construction — only the `pruned` section's shapes
    /// switch between "skipped with provenance" and "evaluated".
    pub prune: bool,
    /// Walls-only placement: each shape's sweep skips phase-2 pricing.
    pub feasibility_only: bool,
    /// Cooperative deadline, copied into every shape's inner
    /// [`PlanRequest`]; see [`PlanRequest::cancel`].
    pub cancel: CancelToken,
}

impl PlacementRequest {
    pub fn new(model: ModelDims, fleet: FleetSpec) -> Self {
        PlacementRequest {
            fleet,
            model,
            reference_s: 1 << 20,
            quantum: 128 * 1024,
            cap_s: 32 << 20,
            dims: SweepDims::default(),
            calibration: Calibration::default(),
            refit: None,
            threads: 0,
            prune: true,
            feasibility_only: false,
            cancel: CancelToken::none(),
        }
    }
}

/// One fleet shape's placement verdict: the shape, its dominance status,
/// and (when evaluated) the full plan the job would run under.
#[derive(Debug, Clone)]
pub struct ShapePlacement {
    pub pool: String,
    pub device: String,
    pub cluster: ClusterConfig,
    /// `Some(label)` when another shape dominates this one (the first
    /// dominator in enumeration order). Dominance is computed in both
    /// modes; pruning only decides whether the shape still gets a plan.
    pub pruned_by: Option<String>,
    /// The shape's ranked sweep; `None` exactly when the shape was
    /// dominance-pruned before evaluation.
    pub plan: Option<PlanOutcome>,
}

impl ShapePlacement {
    pub fn gpus(&self) -> u64 {
        self.cluster.total_gpus()
    }

    /// Stable display / provenance label: `pool/nodes×gpus_per_node`.
    pub fn label(&self) -> String {
        format!("{}/{}x{}", self.pool, self.cluster.nodes, self.cluster.gpus_per_node)
    }

    /// The shape's best trainable context (its top-ranked config's wall).
    pub fn best_wall(&self) -> Option<u64> {
        self.plan.as_ref()?.best()?.max_context
    }

    /// The shape's best config's reference-length throughput (step-time
    /// rank proxy: more tokens/s/GPU = shorter step).
    pub fn best_ref_tput(&self) -> Option<f64> {
        self.plan.as_ref()?.best()?.ref_tok_s_gpu
    }
}

/// The fleet-wide answer: shapes ranked best-first plus the sweep's
/// reuse/pruning accounting.
#[derive(Debug, Clone)]
pub struct PlacementOutcome {
    pub model: ModelDims,
    pub fleet: FleetSpec,
    pub reference_s: u64,
    pub quantum: u64,
    /// Non-dominated shapes, each fully evaluated, ranked by best
    /// context wall, then reference throughput, then fewer GPUs.
    /// Identical bytes with pruning on or off.
    pub placements: Vec<ShapePlacement>,
    /// Dominated shapes in enumeration order, each naming its dominator;
    /// `plan` is `None` under pruning and populated under `--no-prune`.
    pub pruned: Vec<ShapePlacement>,
    pub shapes_total: u64,
    /// Shapes skipped before any probe. 0 under `--no-prune` even though
    /// `pruned` still records dominance provenance.
    pub shapes_pruned: u64,
    /// Evaluated shapes whose whole sweep ran zero streamed probes and
    /// zero priced sims — answered entirely by fits and memos shared
    /// from identical-hardware shapes (or a warm session).
    pub shapes_reused: u64,
    /// Distinct (hardware fingerprint, nodes, gpus_per_node) triples
    /// among the evaluated shapes — the unit model fits are keyed by.
    pub distinct_hardware: u64,
    /// Peak-model / step-time-model entries resident in the session
    /// caches after the sweep (fitted + drift-rejected). Anchor sims are
    /// bounded by `pricing_families`: one anchor per family, shared
    /// across every shape of identical hardware.
    pub peak_families: u64,
    pub pricing_families: u64,
    /// Per-call accounting summed over every evaluated shape.
    pub simulations: u64,
    pub feasibility_probes: u64,
    pub anchor_sims: u64,
    pub modeled_prices: u64,
    pub refit: Option<RefitInfo>,
    pub prune: bool,
    pub feasibility_only: bool,
    /// The request's deadline expired before every shape finished; see
    /// [`PlanOutcome::cancelled`].
    pub cancelled: bool,
    pub wall_s: f64,
}

impl PlacementOutcome {
    /// The top-ranked shape (the "where should I run this" answer).
    pub fn best(&self) -> Option<&ShapePlacement> {
        self.placements.first()
    }
}

/// Hardware dominance at equal shape: `a` dominates `b` when both slice
/// the same (nodes, gpus_per_node) grid and every per-rank hardware
/// dimension of `a` is ≥ `b`'s — any schedule feasible on `b` is then
/// feasible on `a` and runs at least as fast, so `b`'s best wall and
/// step time cannot beat `a`'s and probing `b` is wasted work. Shapes
/// with bitwise-identical hardware (duplicate pools of one device) tie;
/// enumeration order breaks the tie so exactly one survives. The
/// relation is a strict partial order, so every maximal shape survives
/// a full-set scan and pruning is lossless on the final ranking.
fn dominates(a: &ClusterShape, b: &ClusterShape, ia: usize, ib: usize) -> bool {
    let (ca, cb) = (&a.cluster, &b.cluster);
    if ca.nodes != cb.nodes || ca.gpus_per_node != cb.gpus_per_node {
        return false;
    }
    // Raw fields, not derived budgets: conservative against any future
    // change in how a dimension enters feasibility or pricing.
    let dims = [
        (ca.hbm_bytes, cb.hbm_bytes),
        (ca.hbm_usable_frac, cb.hbm_usable_frac),
        (ca.host_ram_bytes, cb.host_ram_bytes),
        (ca.nvlink_bps, cb.nvlink_bps),
        (ca.ib_bps, cb.ib_bps),
        (ca.pcie_bps, cb.pcie_bps),
        (ca.compute_scale, cb.compute_scale),
    ];
    if dims.iter().any(|(x, y)| x < y) {
        return false;
    }
    let strictly = dims.iter().any(|(x, y)| x > y);
    strictly || ia < ib
}

/// One-shot placement sweep with fresh caches (the CLI path).
pub fn place(req: &PlacementRequest) -> PlacementOutcome {
    place_with(req, &PlannerCaches::new())
}

/// Sweep every viable cluster shape of the fleet, consulting (and
/// filling) the caller-owned session caches shared across shapes: a
/// shape whose per-rank hardware and node count match an already-swept
/// shape — a different pool of the same device, or a warm session —
/// replays from memos and re-fits nothing.
pub fn place_with(req: &PlacementRequest, caches: &PlannerCaches) -> PlacementOutcome {
    let t0 = Instant::now();
    let shapes = enumerate_shapes(&req.fleet);
    // Full-set dominance scan: shape `j` is pruned when ANY other shape
    // dominates it. The dominator may appear later in declaration order
    // (an H100 pool listed before the H200 pool that dominates it), so a
    // sequential kept-only scan would be wrong; scanning the full set
    // keeps the surviving set = the partial order's maximal elements,
    // independent of pool order.
    let dominator: Vec<Option<usize>> = (0..shapes.len())
        .map(|j| (0..shapes.len()).find(|&i| i != j && dominates(&shapes[i], &shapes[j], i, j)))
        .collect();
    let plan_req = |shape: &ClusterShape| -> PlanRequest {
        let mut r = PlanRequest::new(req.model.clone(), shape.cluster.clone());
        r.reference_s = req.reference_s;
        r.quantum = req.quantum;
        r.cap_s = req.cap_s;
        r.dims = req.dims.clone();
        r.calibration = req.calibration.scaled_for(&shape.cluster);
        r.refit = req.refit.clone();
        r.threads = 1;
        r.feasibility_only = req.feasibility_only;
        r.cancel = req.cancel;
        r
    };
    let todo: Vec<usize> =
        (0..shapes.len()).filter(|&j| !req.prune || dominator[j].is_none()).collect();
    let plans =
        parallel_map(&todo, req.threads, |_, &j| (j, plan_with(&plan_req(&shapes[j]), caches)));

    let mut by_index: Vec<Option<PlanOutcome>> = vec![None; shapes.len()];
    let (mut probes, mut anchors, mut modeled, mut sims) = (0u64, 0u64, 0u64, 0u64);
    let mut reused = 0u64;
    let mut hw = std::collections::HashSet::new();
    for (j, p) in plans {
        hw.insert((
            shapes[j].cluster.hardware_fingerprint(),
            shapes[j].cluster.nodes,
            shapes[j].cluster.gpus_per_node,
        ));
        probes += p.feasibility_probes;
        anchors += p.priced_sims;
        modeled += p.modeled_prices;
        sims += p.simulations;
        if p.simulations == 0 {
            reused += 1;
        }
        by_index[j] = Some(p);
    }

    let mut placements = Vec::new();
    let mut pruned = Vec::new();
    for (j, shape) in shapes.iter().enumerate() {
        let sp = ShapePlacement {
            pool: shape.pool.clone(),
            device: shape.device.clone(),
            cluster: shape.cluster.clone(),
            pruned_by: dominator[j].map(|i| {
                format!(
                    "{}/{}x{}",
                    shapes[i].pool, shapes[i].cluster.nodes, shapes[i].cluster.gpus_per_node
                )
            }),
            plan: by_index[j].take(),
        };
        if dominator[j].is_some() {
            pruned.push(sp);
        } else {
            placements.push(sp);
        }
    }
    // Rank: longest trainable context first, then reference throughput
    // (shortest step), then fewer GPUs (cheapest allocation); the stable
    // sort keeps enumeration order on exact ties.
    placements.sort_by(|a, b| {
        let by_wall = b.best_wall().unwrap_or(0).cmp(&a.best_wall().unwrap_or(0));
        let (ta, tb) = (a.best_ref_tput().unwrap_or(0.0), b.best_ref_tput().unwrap_or(0.0));
        by_wall.then(tb.total_cmp(&ta)).then(a.gpus().cmp(&b.gpus()))
    });

    PlacementOutcome {
        model: req.model.clone(),
        fleet: req.fleet.clone(),
        reference_s: req.reference_s,
        quantum: req.quantum.max(1),
        shapes_total: shapes.len() as u64,
        shapes_pruned: if req.prune { pruned.len() as u64 } else { 0 },
        shapes_reused: reused,
        distinct_hardware: hw.len() as u64,
        peak_families: caches.models.len() as u64,
        pricing_families: caches.time_models.len() as u64,
        placements,
        pruned,
        simulations: sims,
        feasibility_probes: probes,
        anchor_sims: anchors,
        modeled_prices: modeled,
        refit: req.refit.clone(),
        prune: req.prune,
        feasibility_only: req.feasibility_only,
        cancelled: req.cancel.is_cancelled(),
        wall_s: t0.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AcMode, CpMethod};

    fn llama_plan() -> PlanOutcome {
        let mut req = PlanRequest::new(ModelDims::llama3_8b(), ClusterConfig::h100_node());
        req.quantum = 512 * 1024;
        req.cap_s = 8 << 20;
        req.threads = 2;
        plan(&req)
    }

    #[test]
    fn golden_llama_single_node_ranking() {
        let out = llama_plan();
        assert!(out.configs.len() >= 100, "space too small: {}", out.configs.len());

        // Paper Fig. 1 / Table 4: UPipe (U = C = 8) is the only method that
        // reaches 5M on one 8×H100 node, and 5M is the single-node max.
        let top = out.best().unwrap();
        assert_eq!(
            top.parallel.method,
            CpMethod::Upipe { u: 8, gqa_schedule: true },
            "top-ranked {:?}",
            top.parallel
        );
        let five_m = 5u64 << 20;
        let top_max = top.max_context.unwrap();
        assert!(top_max >= five_m, "UPipe max {top_max} < 5M");
        assert!(top_max < 6 << 20, "UPipe max {top_max} >= 6M");
        assert!(!top.hit_cap, "5M is a real memory wall, not the search cap");

        // Paper ordering below the winner: FPDT's 4M wall beats Ulysses'
        // 3M-ish OOM wall, which beats Ring/Native. Compare the paper's
        // own settings (pinned, batch 1, no TP, offloaded AC).
        let max_of = |m: CpMethod| {
            out.configs
                .iter()
                .find(|c| {
                    c.parallel.method == m
                        && c.parallel.pin_memory
                        && c.parallel.micro_batch == 1
                        && c.parallel.tp == 1
                        && c.parallel.ac_mode == AcMode::AcOffload
                })
                .and_then(|c| c.max_context)
                .unwrap_or(0)
        };
        assert_eq!(max_of(CpMethod::Fpdt { pi: 16 }), 4 << 20, "FPDT wall");
        assert!(max_of(CpMethod::Ulysses) < five_m, "Ulysses beyond paper wall");
        assert!(max_of(CpMethod::Ulysses) >= 3 << 20, "Ulysses under paper wall");
        assert!(max_of(CpMethod::NativePyTorch) < max_of(CpMethod::Ring));

        // The expanded dims actually ranked: AC-GPU variants exist but
        // never beat offloaded AC on max context for the same method.
        let best_by_ac = |m: CpMethod, ac: AcMode| {
            out.configs
                .iter()
                .filter(|c| c.parallel.method == m && c.parallel.ac_mode == ac)
                .filter_map(|c| c.max_context)
                .max()
                .unwrap_or(0)
        };
        let uly_gpu = best_by_ac(CpMethod::Ulysses, AcMode::AcGpu);
        let uly_off = best_by_ac(CpMethod::Ulysses, AcMode::AcOffload);
        assert!(uly_gpu > 0, "AC-GPU slice was swept");
        assert!(uly_gpu < uly_off, "GPU-resident checkpoints cost context");

        // The symbolic solver actually ran: models fitted for most
        // families, fallbacks the exception (walls below the sample range).
        assert!(out.symbolic_models > 0, "no peak models fitted");
        assert!(
            out.symbolic_models > out.symbolic_fallbacks,
            "models {} vs fallbacks {}",
            out.symbolic_models,
            out.symbolic_fallbacks
        );
    }

    #[test]
    fn frontier_is_nondominated_and_caching_works() {
        let out = llama_plan();
        let front = out.frontier();
        assert!(!front.is_empty());
        for a in &front {
            let (ca, ba) = (a.ref_peak_gib.unwrap(), a.ref_tok_s_gpu.unwrap());
            for b in &out.configs {
                if let (Some(cb), Some(bb)) = (b.ref_peak_gib, b.ref_tok_s_gpu) {
                    assert!(
                        !(cb <= ca && bb >= ba && (cb < ca || bb > ba)),
                        "{:?} dominated by {:?}",
                        a.parallel,
                        b.parallel
                    );
                }
            }
        }
        // The fastest feasible config is always on the frontier.
        let mut fastest: Option<&ConfigPlan> = None;
        for c in &out.configs {
            if let Some(t) = c.ref_tok_s_gpu {
                let better = match fastest.and_then(|f| f.ref_tok_s_gpu) {
                    Some(ft) => t > ft,
                    None => true,
                };
                if better {
                    fastest = Some(c);
                }
            }
        }
        assert!(fastest.unwrap().pareto, "fastest config must be on frontier");
        // Pin variants share traces at the priced cells, so the trace
        // cache must have hits, and the memos must have collapsed replays.
        assert!(out.cache_hits > 0, "no trace-cache hits");
        assert!(out.simulations > 0);
        assert_eq!(
            out.simulations,
            out.feasibility_probes + out.priced_sims + out.modeled_prices
        );
        assert!(out.modeled_prices > 0, "symbolic pricing never streamed");
        assert!(out.priced_sims >= out.cache_misses);
        assert!(out.refit.is_none(), "no refit requested");
    }

    #[test]
    fn symbolic_matches_cold_bisection_with_5x_fewer_probes() {
        // The tentpole gate: across the full default sweep at the default
        // (fine) quantum, the symbolic solver must return results
        // *identical* to cold per-cell bisection in every field — while
        // issuing at least 5× fewer streamed feasibility probes.
        let mut req = PlanRequest::new(ModelDims::llama3_8b(), ClusterConfig::h100_node());
        req.quantum = 128 * 1024;
        req.cap_s = 8 << 20;
        req.threads = 1; // deterministic probe accounting
        let sym = plan(&req);
        req.symbolic = false;
        req.warm_start = false; // the --cold configuration, end to end
        let cold = plan(&req);

        assert_eq!(sym.configs.len(), cold.configs.len());
        for (a, b) in sym.configs.iter().zip(&cold.configs) {
            assert_eq!(a.parallel, b.parallel, "ranking order must match");
            assert_eq!(a.max_context, b.max_context, "{:?}", a.parallel);
            assert_eq!(a.hit_cap, b.hit_cap, "{:?}", a.parallel);
            assert_eq!(a.max_ctx_peak_gib, b.max_ctx_peak_gib, "{:?}", a.parallel);
            assert_eq!(a.max_ctx_tok_s_gpu, b.max_ctx_tok_s_gpu, "{:?}", a.parallel);
            assert_eq!(a.ref_peak_gib, b.ref_peak_gib, "{:?}", a.parallel);
            assert_eq!(a.ref_tok_s_gpu, b.ref_tok_s_gpu, "{:?}", a.parallel);
            assert_eq!(a.pareto, b.pareto, "{:?}", a.parallel);
        }
        assert!(cold.symbolic_models == 0 && cold.symbolic_fallbacks == 0, "--cold fit models");
        assert!(sym.symbolic_models > 0);
        assert!(
            cold.feasibility_probes >= 5 * sym.feasibility_probes,
            "probe collapse below 5x: cold {} vs symbolic {}",
            cold.feasibility_probes,
            sym.feasibility_probes
        );
        // Pricing collapses too: at most one anchor sim per pricing
        // family, every other cell streamed through the timing kernel —
        // with results asserted bitwise-identical above.
        assert_eq!(cold.modeled_prices, 0, "--cold must never stream prices");
        assert_eq!(cold.time_models + cold.time_fallbacks, 0, "--cold fit time models");
        assert!(sym.modeled_prices > 0, "symbolic pricing never streamed");
        assert!(sym.time_models > 0, "no step-time models fitted");
        assert!(
            sym.priced_sims <= sym.time_models + sym.time_fallbacks,
            "more than one anchor sim per pricing family: {} anchors, {} families",
            sym.priced_sims,
            sym.time_models + sym.time_fallbacks
        );
        assert!(
            sym.priced_sims < cold.priced_sims,
            "pricing did not collapse: {} vs {}",
            sym.priced_sims,
            cold.priced_sims
        );
    }

    #[test]
    fn warm_start_fallback_matches_cold_and_probes_fewer_cells() {
        // The PR 3 property, preserved underneath the symbolic solver:
        // with `symbolic` off, warm-started bisection returns identical
        // results to cold bisection with strictly fewer streamed probes.
        let mut req = PlanRequest::new(ModelDims::llama3_8b(), ClusterConfig::h100_node());
        req.quantum = 1 << 20;
        req.cap_s = 8 << 20;
        req.threads = 1; // deterministic completion order maximizes reuse
        req.symbolic = false;
        let warm = plan(&req);
        req.warm_start = false;
        let cold = plan(&req);
        assert_eq!(warm.configs.len(), cold.configs.len());
        for (a, b) in warm.configs.iter().zip(&cold.configs) {
            assert_eq!(a.parallel, b.parallel, "ranking order must match");
            assert_eq!(a.max_context, b.max_context, "{:?}", a.parallel);
            assert_eq!(a.hit_cap, b.hit_cap, "{:?}", a.parallel);
            assert_eq!(a.ref_tok_s_gpu, b.ref_tok_s_gpu, "{:?}", a.parallel);
            assert_eq!(a.pareto, b.pareto, "{:?}", a.parallel);
        }
        assert!(
            warm.feasibility_probes < cold.feasibility_probes,
            "warm start must probe strictly fewer cells: {} vs {}",
            warm.feasibility_probes,
            cold.feasibility_probes
        );
    }

    #[test]
    fn feasibility_only_matches_walls_and_skips_pricing() {
        let mut req = PlanRequest::new(ModelDims::llama3_8b(), ClusterConfig::h100_node());
        req.quantum = 1 << 20;
        req.cap_s = 8 << 20;
        req.threads = 2;
        let full = plan(&req);
        req.feasibility_only = true;
        let walls = plan(&req);

        assert!(walls.feasibility_only && !full.feasibility_only);
        assert_eq!(walls.priced_sims, 0, "phase 2 must not run");
        assert_eq!(walls.modeled_prices, 0, "no streamed prices either");
        assert_eq!(walls.cache_misses, 0, "no traces built for pricing");
        assert_eq!(walls.configs.len(), full.configs.len());
        // Same walls for every configuration (matched by layout — the
        // ranking tiebreak differs without throughput).
        let wall_of = |out: &PlanOutcome, p: &ParallelConfig| {
            out.configs
                .iter()
                .find(|c| &c.parallel == p)
                .map(|c| (c.max_context, c.hit_cap))
                .unwrap()
        };
        for c in &full.configs {
            assert_eq!(wall_of(&walls, &c.parallel), (c.max_context, c.hit_cap));
        }
        for c in &walls.configs {
            assert!(c.ref_peak_gib.is_none() && c.ref_tok_s_gpu.is_none());
            assert!(c.max_ctx_peak_gib.is_none() && c.max_ctx_tok_s_gpu.is_none());
            assert!(!c.pareto, "no frontier without pricing");
        }
        assert!(walls.frontier().is_empty());
        // Ranked by wall: non-increasing max_context down the table.
        for w in walls.configs.windows(2) {
            assert!(w[0].max_context.unwrap_or(0) >= w[1].max_context.unwrap_or(0));
        }
    }

    #[test]
    fn multi_node_walls_scale_with_node_count() {
        // The Fig. 5 sanity the CI smoke also gates: adding nodes never
        // shrinks the best achievable context wall (more aggregate HBM,
        // smaller per-rank shards).
        let best_wall = |gpus: u64| {
            let cluster = ClusterConfig::h100_cluster(gpus).unwrap();
            let mut req = PlanRequest::new(ModelDims::llama3_8b(), cluster);
            req.quantum = 1 << 20;
            req.cap_s = 32 << 20;
            req.threads = 2;
            req.feasibility_only = true;
            let out = plan(&req);
            assert!(!out.configs.is_empty(), "{gpus} GPUs: empty space");
            out.configs.iter().filter_map(|c| c.max_context).max().unwrap_or(0)
        };
        let one = best_wall(8);
        let four = best_wall(32);
        let eight = best_wall(64);
        assert!(one >= 5 << 20, "single node must reach the 5M headline");
        assert!(four >= one, "4-node best wall {four} below single-node {one}");
        assert!(eight >= four, "8-node best wall {eight} below 4-node {four}");
    }

    fn assert_configs_bitwise_equal(a: &PlanOutcome, b: &PlanOutcome) {
        assert_eq!(a.configs.len(), b.configs.len());
        let bits = |v: Option<f64>| v.map(f64::to_bits);
        for (x, y) in a.configs.iter().zip(&b.configs) {
            assert_eq!(x.parallel, y.parallel, "ranking order must match");
            assert_eq!(x.max_context, y.max_context, "{:?}", x.parallel);
            assert_eq!(x.hit_cap, y.hit_cap, "{:?}", x.parallel);
            assert_eq!(bits(x.max_ctx_peak_gib), bits(y.max_ctx_peak_gib), "{:?}", x.parallel);
            assert_eq!(bits(x.max_ctx_tok_s_gpu), bits(y.max_ctx_tok_s_gpu), "{:?}", x.parallel);
            assert_eq!(bits(x.ref_peak_gib), bits(y.ref_peak_gib), "{:?}", x.parallel);
            assert_eq!(bits(x.ref_tok_s_gpu), bits(y.ref_tok_s_gpu), "{:?}", x.parallel);
            assert_eq!(x.pareto, y.pareto, "{:?}", x.parallel);
        }
    }

    #[test]
    fn session_caches_replay_bitwise_identical_and_probe_free() {
        // The service acceptance gate at the evaluator layer: a repeated
        // request against one cache set must be served entirely from
        // memos — zero streamed probes, zero priced sims, zero trace
        // builds — with every field bitwise-identical to both the cold
        // session pass and a fresh one-shot `plan()`.
        let caches = PlannerCaches::new();
        let mut req = PlanRequest::new(ModelDims::llama3_8b(), ClusterConfig::h100_node());
        req.quantum = 1 << 20;
        req.cap_s = 8 << 20;
        req.threads = 2;
        let cold = plan_with(&req, &caches);
        assert!(cold.feasibility_probes > 0 && cold.priced_sims > 0);
        let warm = plan_with(&req, &caches);
        assert_eq!(warm.feasibility_probes, 0, "verified walls must be memoized");
        assert_eq!(warm.priced_sims, 0, "priced reports must be memoized");
        assert_eq!(warm.modeled_prices, 0, "streamed prices must be memoized");
        assert_eq!(warm.cache_misses, 0, "no new traces on a warm replay");
        assert_configs_bitwise_equal(&warm, &cold);
        let one_shot = plan(&req);
        assert_configs_bitwise_equal(&warm, &one_shot);
        // Cache observability: the session actually accumulated state.
        let sizes = caches.sizes();
        assert!(sizes.iter().any(|&n| n > 0), "caches stayed empty: {sizes:?}");
        assert!(sizes[6] > 0, "no verified walls memoized");
        assert!(sizes[5] > 0, "no step-time models memoized");
        caches.clear();
        assert_eq!(caches.sizes(), [0; 7]);
        // A cleared session re-evaluates and still agrees.
        let refilled = plan_with(&req, &caches);
        assert!(refilled.feasibility_probes > 0);
        assert_configs_bitwise_equal(&refilled, &cold);
    }

    #[test]
    fn epoch_invalidation_is_surgical_across_fingerprints() {
        // The online-calibration acceptance gate at the evaluator layer:
        // invalidating one calibration fingerprint drops *exactly* that
        // fingerprint's entries in every tier — including the precious
        // fitted-model and verified-walls tiers — while a second
        // fingerprint's warm state (another fleet pool, or requests
        // pinned to a measurements file) survives untouched and keeps
        // answering probe-free, bitwise-identically.
        let caches = PlannerCaches::new();
        let mut req_a = PlanRequest::new(ModelDims::llama3_8b(), ClusterConfig::h100_node());
        req_a.quantum = 1 << 20;
        req_a.cap_s = 8 << 20;
        req_a.threads = 1; // deterministic per-tier entry counts
        let mut req_b = req_a.clone();
        req_b.calibration.fa3_fwd_flops *= 1.1; // a second pool's fitted rates
        let fp_a = req_a.calibration.fingerprint();
        let fp_b = req_b.calibration.fingerprint();
        assert_ne!(fp_a, fp_b);

        let out_a = plan_with(&req_a, &caches);
        let sizes_a = caches.sizes();
        let out_b = plan_with(&req_b, &caches);
        let sizes_ab = caches.sizes();
        // The sweeps are identical modulo calibration, so every tier
        // holds one key-set per fingerprint.
        for i in 0..7 {
            assert_eq!(sizes_ab[i], 2 * sizes_a[i], "tier {i} keys must not collide");
        }

        let dropped = caches.invalidate_fingerprint(fp_a);
        for (i, (name, n)) in dropped.iter().enumerate() {
            assert_eq!(*n as usize, sizes_a[i], "tier {name} dropped the wrong count");
        }
        assert!(dropped.iter().any(|(_, n)| *n > 0), "nothing invalidated");
        let sizes_after = caches.sizes();
        for i in 0..7 {
            assert_eq!(sizes_after[i], sizes_a[i], "tier {i} must keep exactly B's entries");
        }
        // Counters ride the observability surface, separate from LRU
        // evictions, and a replayed invalidation is a no-op.
        for (tier, (name, n)) in caches.tiers().iter().zip(dropped.iter()) {
            assert_eq!(tier.name, *name);
            assert_eq!(tier.invalidations, *n, "tier {name} counter");
            assert_eq!(tier.evictions, 0, "invalidations must not count as evictions");
        }
        let total: u64 = dropped.iter().map(|(_, n)| n).sum();
        assert_eq!(caches.total_invalidated(), total);
        let again = caches.invalidate_fingerprint(fp_a);
        assert!(again.iter().all(|(_, n)| *n == 0), "second invalidation must drop nothing");
        assert_eq!(caches.total_invalidated(), total);

        // B's warm state answers the replay with zero probes, zero priced
        // sims, zero trace builds — bitwise equal to its cold pass.
        let warm_b = plan_with(&req_b, &caches);
        assert_eq!(warm_b.feasibility_probes, 0, "B's verified walls must survive");
        assert_eq!(warm_b.priced_sims, 0, "B's priced reports must survive");
        assert_eq!(warm_b.modeled_prices, 0, "B's fitted time models must survive");
        assert_eq!(warm_b.cache_misses, 0, "B's traces must survive");
        assert_configs_bitwise_equal(&warm_b, &out_b);

        // A re-evaluates from scratch under its (re-published) calibration
        // and lands exactly where the cold pass did.
        let refilled_a = plan_with(&req_a, &caches);
        assert!(refilled_a.feasibility_probes > 0, "A's entries must be gone");
        assert_configs_bitwise_equal(&refilled_a, &out_a);
    }

    #[test]
    fn walls_at_answers_from_memos_after_a_sweep() {
        let caches = PlannerCaches::new();
        let mut req = PlanRequest::new(ModelDims::llama3_8b(), ClusterConfig::h100_node());
        req.quantum = 1 << 20;
        req.cap_s = 8 << 20;
        req.threads = 2;
        req.feasibility_only = true;
        // Cold point query: nothing is memoized, every family probes.
        let cold_q = walls_at(&req, 6 << 20, &caches);
        assert!(cold_q.probes > 0, "cold query must stream probes");
        assert_eq!(cold_q.from_walls, 0);
        assert_eq!(cold_q.from_probes, cold_q.cells.len() as u64);
        // Sweep, then requery: every configuration answers from its
        // verified wall with zero streamed probes — the warm-session
        // acceptance property.
        let out = plan_with(&req, &caches);
        let warm_q = walls_at(&req, 6 << 20, &caches);
        assert_eq!(warm_q.probes, 0, "warm query must not stream");
        assert_eq!(warm_q.from_probes, 0);
        assert_eq!(warm_q.from_walls, warm_q.cells.len() as u64);
        assert_eq!(warm_q.seq_lattice, 6 << 20);
        // Warm answers equal the swept walls *and* the cold probes.
        for cell in &warm_q.cells {
            let planned = out.configs.iter().find(|c| c.parallel == cell.parallel).unwrap();
            let want = planned.max_context.is_some_and(|w| warm_q.seq_lattice <= w);
            assert_eq!(cell.feasible, want, "{:?}", cell.parallel);
        }
        for (a, b) in cold_q.cells.iter().zip(&warm_q.cells) {
            assert_eq!(a.parallel, b.parallel);
            assert_eq!(a.feasible, b.feasible, "{:?}", a.parallel);
        }
    }

    #[test]
    fn throughput_at_answers_from_memos_and_models_after_a_sweep() {
        let caches = PlannerCaches::new();
        let mut req = PlanRequest::new(ModelDims::llama3_8b(), ClusterConfig::h100_node());
        req.quantum = 1 << 20;
        req.cap_s = 8 << 20;
        req.threads = 2;
        // The sweep runs first: it anchors and fits the step-time models
        // and memoizes every reference-cell report.
        let out = plan_with(&req, &caches);
        assert!(out.time_models > 0, "sweep fitted no step-time models");
        // Tier 1: the sweep's own reference length answers entirely from
        // memoized reports, bitwise equal to the planned throughput.
        let q0 = throughput_at(&req, req.reference_s, &caches);
        assert_eq!(q0.streamed, 0, "warm reference query must not stream");
        assert_eq!(q0.from_reports, q0.cells.len() as u64);
        for cell in &q0.cells {
            let planned = out.configs.iter().find(|c| c.parallel == cell.parallel).unwrap();
            assert_eq!(
                cell.tok_s_gpu.map(f64::to_bits),
                planned.ref_tok_s_gpu.map(f64::to_bits),
                "{:?}",
                cell.parallel
            );
        }
        // Tiers 2/3: a length the sweep never priced. Fitted families
        // answer from the polynomial with zero streamed work; the rest
        // stream exactly once and memoize.
        let fresh = (1 << 20) + (1 << 19);
        let q1 = throughput_at(&req, fresh, &caches);
        assert!(q1.from_models > 0, "no cell answered from a fitted model");
        assert_eq!(q1.streamed, q1.from_streams, "stream accounting drifted");
        for cell in q1.cells.iter().filter(|c| c.source == PriceSource::Model) {
            if let Some(st) = cell.step_time {
                assert!(st > 0.0, "{:?}", cell.parallel);
                assert!(cell.tok_s_gpu.unwrap() > 0.0, "{:?}", cell.parallel);
            }
        }
        // Streamed answers memoize: the requery streams nothing, the
        // model tier is unchanged, and every value is bitwise stable.
        let q2 = throughput_at(&req, fresh, &caches);
        assert_eq!(q2.streamed, 0, "streamed prices must be memoized");
        assert_eq!(q2.from_models, q1.from_models);
        for (a, b) in q1.cells.iter().zip(&q2.cells) {
            assert_eq!(a.parallel, b.parallel);
            assert_eq!(
                a.tok_s_gpu.map(f64::to_bits),
                b.tok_s_gpu.map(f64::to_bits),
                "{:?}",
                a.parallel
            );
        }
    }

    #[test]
    fn walls_at_model_tier_when_lattice_differs() {
        // A query on a *different* search lattice misses the wall memo but
        // still answers fitted families from their polynomials — the
        // "fitted polynomial path, zero streamed probes" tier.
        let caches = PlannerCaches::new();
        let mut req = PlanRequest::new(ModelDims::llama3_8b(), ClusterConfig::h100_node());
        req.quantum = 1 << 20;
        req.cap_s = 8 << 20;
        req.threads = 1;
        req.feasibility_only = true;
        plan_with(&req, &caches);
        let mut req2 = req.clone();
        req2.cap_s = 16 << 20; // new lattice cap: wall memo keys miss
        let q = walls_at(&req2, 2 << 20, &caches);
        assert_eq!(q.from_walls, 0, "different lattice must miss the wall memo");
        assert!(q.from_models > 0, "fitted families answer from the polynomial");
        for cell in q.cells.iter().filter(|c| c.source == WallSource::Model) {
            assert!(cell.predicted_peak_gib.is_some(), "{:?}", cell.parallel);
        }
        // Off-lattice query lengths quantize up.
        let q2 = walls_at(&req, (2 << 20) + 5, &caches);
        assert_eq!(q2.seq_lattice, 3 << 20);
    }

    #[test]
    fn refit_calibration_flows_through_the_plan() {
        // A uniformly faster machine keeps the ranking but raises absolute
        // throughput at the reference length.
        let mut req = PlanRequest::new(ModelDims::llama3_8b(), ClusterConfig::h100_node());
        req.quantum = 1 << 20;
        req.cap_s = 8 << 20;
        req.threads = 2;
        req.dims = SweepDims::paper();
        let base = plan(&req);
        req.calibration.fa3_fwd_flops *= 2.0;
        req.calibration.fa3_bwd_flops *= 2.0;
        let fast = plan(&req);
        let tput = |o: &PlanOutcome| {
            o.configs
                .iter()
                .find(|c| c.parallel.method == CpMethod::Upipe { u: 8, gqa_schedule: true })
                .and_then(|c| c.ref_tok_s_gpu)
                .unwrap()
        };
        assert!(tput(&fast) > 1.3 * tput(&base), "faster rates -> more tokens/s");
        // Memory walls are rate-independent: the top max context agrees.
        assert_eq!(base.best().unwrap().max_context, fast.best().unwrap().max_context);
    }

    fn placement_req(fleet_json: &str) -> PlacementRequest {
        let fleet = FleetSpec::parse(fleet_json, "test").unwrap();
        let mut req = PlacementRequest::new(ModelDims::llama3_8b(), fleet);
        req.quantum = 1 << 20;
        req.cap_s = 8 << 20;
        req.threads = 1; // deterministic per-shape accounting
        req.dims = SweepDims::paper();
        req
    }

    #[test]
    fn placement_pruning_is_lossless_on_heterogeneous_fleet() {
        // The tentpole acceptance gate: on a ≥3-shape heterogeneous
        // fleet, the pruned sweep's final ranking is *bitwise* identical
        // to `--no-prune` — each run on its own fresh caches, so the
        // equivalence is real work agreeing, not a memo replay.
        let fleet = r#"{"pools": [
            {"name": "old-h100", "device": "h100", "nodes": 2},
            {"name": "new-h200", "device": "h200", "nodes": 1}
        ]}"#;
        let req = placement_req(fleet);
        let pruned_run = place(&req);
        let mut no_prune = placement_req(fleet);
        no_prune.prune = false;
        let full_run = place(&no_prune);

        // Shapes: h100 1+2 nodes, h200 1 node. The 1-node H100 slice is
        // dominated by the 1-node H200 (same grid, ≥ everywhere, more
        // HBM + host RAM) — and the dominator appears *later* in pool
        // order, which is exactly what a sequential kept-only scan
        // would miss.
        assert_eq!(pruned_run.shapes_total, 3);
        assert_eq!(pruned_run.shapes_pruned, 1);
        assert_eq!(full_run.shapes_pruned, 0, "--no-prune skips nothing");
        assert_eq!(pruned_run.pruned.len(), 1);
        let skipped = &pruned_run.pruned[0];
        assert_eq!(skipped.label(), "old-h100/1x8");
        assert_eq!(skipped.pruned_by.as_deref(), Some("new-h200/1x8"));
        assert!(skipped.plan.is_none(), "pruned before any probe");

        // Identical ranked placements, bitwise: same shapes in the same
        // order, every per-config field agreeing to the bit.
        assert_eq!(pruned_run.placements.len(), full_run.placements.len());
        for (a, b) in pruned_run.placements.iter().zip(&full_run.placements) {
            assert_eq!(a.label(), b.label());
            assert_configs_bitwise_equal(a.plan.as_ref().unwrap(), b.plan.as_ref().unwrap());
        }

        // Pruning is *safe*: the evaluated dominated shape can't beat
        // its dominator on either ranking axis.
        let evaluated = &full_run.pruned[0];
        let dominator = full_run
            .placements
            .iter()
            .find(|p| p.label() == "new-h200/1x8")
            .unwrap();
        assert!(evaluated.plan.is_some(), "--no-prune evaluates dominated shapes");
        assert!(evaluated.best_wall().unwrap_or(0) <= dominator.best_wall().unwrap_or(0));
        assert!(
            evaluated.best_ref_tput().unwrap_or(0.0)
                <= dominator.best_ref_tput().unwrap_or(0.0) + 1e-12
        );

        // Reuse accounting: one anchor per pricing family, never more.
        assert!(pruned_run.anchor_sims >= 1);
        assert!(pruned_run.anchor_sims <= pruned_run.pricing_families);
        assert_eq!(pruned_run.distinct_hardware, 2, "h200/1, h100/2 survive");
        // The pruned run did strictly less work than the exhaustive one.
        assert!(pruned_run.simulations < full_run.simulations);
        // Ranking axes: walls descend, GPUs break exact ties.
        let walls: Vec<u64> =
            pruned_run.placements.iter().map(|p| p.best_wall().unwrap_or(0)).collect();
        assert!(walls.windows(2).all(|w| w[0] >= w[1]), "walls not ranked: {walls:?}");
    }

    #[test]
    fn duplicate_hardware_pool_refits_nothing() {
        // Cross-shape model reuse: two pools of bitwise-identical
        // hardware share every cache key, so the second pool's shape
        // replays entirely from the first's fits — zero probes, zero
        // anchors, zero streamed prices.
        let fleet = r#"{"pools": [
            {"name": "east", "device": "h100", "nodes": 1},
            {"name": "west", "device": "h100", "nodes": 1}
        ]}"#;
        let mut req = placement_req(fleet);
        req.prune = false; // evaluate the duplicate instead of pruning it
        let out = place(&req);
        assert_eq!(out.shapes_total, 2);
        assert_eq!(out.distinct_hardware, 1);
        assert_eq!(out.shapes_reused, 1, "the duplicate shape re-fit nothing");
        let west = out.placements.iter().find(|p| p.pool == "west").unwrap();
        let w = west.plan.as_ref().unwrap();
        assert_eq!(w.simulations, 0, "duplicate hardware must replay from memos");
        assert_eq!(w.feasibility_probes, 0);
        assert_eq!(w.priced_sims, 0);
        // Anchors stay at O(distinct hardware × pricing families): one
        // shape's worth, despite two shapes swept.
        assert!(out.anchor_sims <= out.pricing_families);
        let east = out.placements.iter().find(|p| p.pool == "east").unwrap();
        assert_eq!(out.anchor_sims, east.plan.as_ref().unwrap().priced_sims);
        assert_configs_bitwise_equal(east.plan.as_ref().unwrap(), w);

        // With pruning on, the identical-hardware tie breaks by
        // enumeration order: exactly one survives, skipped pre-probe.
        req.prune = true;
        let pruned_run = place(&req);
        assert_eq!(pruned_run.shapes_pruned, 1);
        assert_eq!(pruned_run.pruned[0].pool, "west");
        assert_eq!(pruned_run.pruned[0].pruned_by.as_deref(), Some("east/1x8"));
    }

    #[test]
    fn placement_scales_calibration_to_the_shape_hardware() {
        // A B200 pool prices against the compute/link-scaled calibration:
        // same model, same shape, strictly more tokens/s than H100 —
        // while H100 pools keep the baseline calibration fingerprint
        // (their cells alias the homogeneous planner's cache entries).
        let fleet = r#"{"pools": [
            {"name": "h100", "device": "h100", "nodes": 1},
            {"name": "b200", "device": "b200", "nodes": 1}
        ]}"#;
        let req = placement_req(fleet);
        let out = place(&req);
        // B200 ≥ H100 in every dimension at the same 1×8 grid, so the
        // H100 shape is pruned and B200 wins the ranking outright.
        assert_eq!(out.shapes_pruned, 1);
        assert_eq!(out.best().unwrap().device, "B200");
        let mut no_prune = placement_req(fleet);
        no_prune.prune = false;
        let full = place(&no_prune);
        let tput = |pool: &str| {
            let all: Vec<&ShapePlacement> =
                full.placements.iter().chain(&full.pruned).collect();
            all.iter().find(|p| p.pool == pool).unwrap().best_ref_tput().unwrap()
        };
        assert!(
            tput("b200") > 1.5 * tput("h100"),
            "B200 compute scale must show up in step time: {} vs {}",
            tput("b200"),
            tput("h100")
        );
        assert!(
            full.best().unwrap().best_wall().unwrap()
                >= full.pruned[0].best_wall().unwrap(),
            "dominance gate: more HBM can't shrink the wall"
        );
    }
}
