//! Parallel plan evaluation: run the calibrated simulator across the
//! sweep space on a worker pool, find each configuration's maximum
//! trainable context, and extract the Pareto frontier at a reference
//! sequence length.
//!
//! Evaluation is two-phase. Context walls only need *feasibility* (peak
//! HBM / host RAM vs the limits), so phase 1 streams each schedule
//! straight into the peak-only `FeasibilityKernel` — no `Vec<Op>` trace,
//! no component timing, no memory timeline. Full pricing runs only for
//! the final cells (each configuration's max-context point and the
//! reference point), where traces are memoized in a [`TraceCache`] (pin
//! variants share them); `feasibility_only` skips phase 2 entirely,
//! which makes massive multi-node walls-only sweeps near-free.
//!
//! Phase 1 itself no longer bisects by default. Peak memory is a
//! degree-≤2 polynomial in `S/C` within a divisibility class (see
//! [`crate::engine::symbolic`]), so the planner *samples* the kernel at
//! a few small lattice lengths per cell family, fits the polynomial,
//! **solves** the HBM/host walls in closed form and verifies the solved
//! wall with exactly two streamed probes (wall feasible, wall + quantum
//! infeasible) via the galloping search — identical results to the
//! bisection path with O(samples + 2) instead of O(log S) probes per
//! cell. Fitted models are shared across a whole family: pin variants
//! (same trace, different host budget — one *pin-agnostic* probe with a
//! recorded host peak answers both) and micro-batch variants (identical
//! per-micro-batch alloc/free cycles leave both peaks unchanged). Cells
//! whose samples fail the drift check fall back to warm-started
//! bisection; `--cold` (`symbolic = false`, `warm_start = false`)
//! restores the exact PR 3 probe-per-bisection behaviour end to end.
//! Both phases memoize results under hashed [`CellKey`]s in lock-striped
//! maps, so replayed cells cost a hash lookup and the worker pool never
//! serializes on a global mutex. The whole sweep prices against the
//! request's [`Calibration`] — default or `--refit`-fitted — whose
//! provenance rides along into the outcome.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::config::presets::RunPreset;
use crate::config::{ClusterConfig, CpMethod, ParallelConfig};
use crate::engine::{
    Calibration, Feasibility, PeakModel, PeakProbe, PeakSample, RefitInfo, StepReport,
};
use crate::model::ModelDims;
use crate::schedule::{
    feasibility_with, method_seq_cap, peak_probe_with, simulate_cached, CellKey, FamilyKey,
    Quantities, TraceCache,
};
use crate::util::fmt::GIB;
use crate::util::pool::parallel_map;
use crate::util::stripe::StripedMap;

use super::search::{bisect_max_from, pareto_front};
use super::space::{enumerate_space, SweepDims};

/// What to sweep and how hard to search.
#[derive(Debug, Clone)]
pub struct PlanRequest {
    pub model: ModelDims,
    pub cluster: ClusterConfig,
    /// Reference sequence length for the throughput/frontier comparison.
    pub reference_s: u64,
    /// Context-search granularity, tokens.
    pub quantum: u64,
    /// Context-search ceiling, tokens.
    pub cap_s: u64,
    /// Which optional dimensions to sweep (AC modes, micro-batches, TP,
    /// the §5.3.2 compositions).
    pub dims: SweepDims,
    /// Calibration every cell is priced with (default, or refit from a
    /// measurements file).
    pub calibration: Calibration,
    /// Provenance when `calibration` came from `--refit`.
    pub refit: Option<RefitInfo>,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// Warm-start fallback bisections from already-evaluated neighbour
    /// cells. Results are identical either way (feasibility is monotone
    /// in S); kept as a switch so the equivalence is testable.
    pub warm_start: bool,
    /// Solve context walls from sampled-polynomial peak models (two
    /// verification probes per cell) instead of bisecting. Identical
    /// results by construction; `--cold` disables this *and*
    /// `warm_start`, restoring the probe-per-bisection behaviour.
    pub symbolic: bool,
    /// Walls only: skip all reference-length/max-context pricing
    /// (phase 2). Throughput, peak-GiB and Pareto fields stay `None`.
    pub feasibility_only: bool,
}

impl PlanRequest {
    pub fn new(model: ModelDims, cluster: ClusterConfig) -> Self {
        PlanRequest {
            model,
            cluster,
            reference_s: 1 << 20,
            quantum: 128 * 1024,
            cap_s: 32 << 20,
            dims: SweepDims::default(),
            calibration: Calibration::default(),
            refit: None,
            threads: 0,
            warm_start: true,
            symbolic: true,
            feasibility_only: false,
        }
    }
}

/// One evaluated configuration.
#[derive(Debug, Clone)]
pub struct ConfigPlan {
    pub parallel: ParallelConfig,
    /// Largest trainable S at quantum granularity; `None` if the
    /// configuration cannot train even one quantum of context.
    pub max_context: Option<u64>,
    /// True when the search hit the request's `cap_s` while still
    /// feasible: `max_context` is then a lower bound, not a memory wall.
    pub hit_cap: bool,
    /// Peak GiB / tokens/s/GPU at the max trainable context (`None` in
    /// feasibility-only sweeps).
    pub max_ctx_peak_gib: Option<f64>,
    pub max_ctx_tok_s_gpu: Option<f64>,
    /// Peak GiB / tokens/s/GPU at the reference length (`None` when the
    /// configuration is infeasible there, or in feasibility-only sweeps).
    pub ref_peak_gib: Option<f64>,
    pub ref_tok_s_gpu: Option<f64>,
    /// On the (peak GiB, tokens/s/GPU) Pareto frontier at the reference
    /// length?
    pub pareto: bool,
}

/// The full plan: configurations ranked best-first, plus search accounting.
#[derive(Debug, Clone)]
pub struct PlanOutcome {
    pub model: ModelDims,
    pub cluster: ClusterConfig,
    pub reference_s: u64,
    pub quantum: u64,
    /// Ranked by max trainable context, then reference throughput.
    pub configs: Vec<ConfigPlan>,
    /// Provenance when the sweep priced against a refit calibration.
    pub refit: Option<RefitInfo>,
    /// Cells actually evaluated (streamed feasibility probes + fully
    /// priced simulations); memo hits are not counted.
    pub simulations: u64,
    /// Phase-1 streamed kernel runs (model samples + wall verification,
    /// or bisection probes under `--cold`).
    pub feasibility_probes: u64,
    /// Phase-2 fully priced simulations (0 in feasibility-only sweeps).
    pub priced_sims: u64,
    /// Cell families whose sampled-polynomial model fit (walls solved in
    /// closed form) vs families that fell back to bisection.
    pub symbolic_models: u64,
    pub symbolic_fallbacks: u64,
    /// Was this a walls-only sweep (no phase-2 pricing)?
    pub feasibility_only: bool,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub wall_s: f64,
}

impl PlanOutcome {
    /// The top-ranked configuration (the "what should I run" answer).
    pub fn best(&self) -> Option<&ConfigPlan> {
        self.configs.first()
    }

    /// Frontier configurations, cheapest peak first.
    pub fn frontier(&self) -> Vec<&ConfigPlan> {
        let mut f: Vec<&ConfigPlan> = self.configs.iter().filter(|c| c.pareto).collect();
        f.sort_by(|a, b| {
            let (pa, pb) = (a.ref_peak_gib, b.ref_peak_gib);
            pa.unwrap_or(f64::INFINITY).total_cmp(&pb.unwrap_or(f64::INFINITY))
        });
        f
    }
}

/// Neighbourhood key for warm-starting *fallback* bisections: every pin /
/// AC / micro-batch / TP variant of one method hits its wall near the
/// others'. Under the symbolic solver this only seeds cells whose model
/// fit failed; the hint is just a starting point either way — the
/// galloping search stays correct however far off it is.
type WarmKey = CpMethod;

/// Sweep the whole configuration space for the request.
pub fn plan(req: &PlanRequest) -> PlanOutcome {
    let t0 = Instant::now();
    let space = enumerate_space(&req.model, &req.cluster, &req.dims);
    let cache = TraceCache::new();
    let calib = req.calibration.clone();
    let gpus = req.cluster.total_gpus();
    let probes = AtomicU64::new(0);
    let priced = AtomicU64::new(0);
    // Phase-specific memos, hashed keys + striped locks. The symbolic
    // probe memo is pin-agnostic (CellKey already excludes pinning); the
    // budgeted `--cold` memo and the pricing memo append pin_memory,
    // which changes the host budget but not the trace.
    let probe_memo: StripedMap<CellKey, PeakProbe> = StripedMap::default();
    let feas_memo: StripedMap<(CellKey, bool), Feasibility> = StripedMap::default();
    let report_memo: StripedMap<(CellKey, bool), StepReport> = StripedMap::default();
    let models: StripedMap<FamilyKey, Option<PeakModel>> = StripedMap::default();
    let warm: StripedMap<WarmKey, u64> = StripedMap::default();
    let quantum = req.quantum.max(1);
    let cap = (req.cap_s / quantum).max(1) * quantum;

    let preset_of = |parallel: &ParallelConfig, s: u64| RunPreset {
        model: req.model.clone(),
        cluster: req.cluster.clone(),
        parallel: parallel.clone(),
        seq_len: s,
    };
    // Phase 1a — pin-agnostic streamed probe (symbolic mode): one kernel
    // run answers every host budget and doubles as a polynomial sample.
    let probe = |parallel: &ParallelConfig, s: u64| -> PeakProbe {
        let preset = preset_of(parallel, s);
        let key = CellKey::new(&preset, &calib);
        match probe_memo.get(&key) {
            Some(p) => p,
            None => {
                let p = peak_probe_with(&preset, &calib);
                probes.fetch_add(1, Ordering::Relaxed);
                probe_memo.insert(key, p)
            }
        }
    };
    // Phase 1b — budgeted probe (the `--cold` / PR 3 bisection path).
    let feasible = |parallel: &ParallelConfig, s: u64| -> bool {
        let preset = preset_of(parallel, s);
        let key = (CellKey::new(&preset, &calib), parallel.pin_memory);
        let f = match feas_memo.get(&key) {
            Some(f) => f,
            None => {
                let f = feasibility_with(&preset, &calib);
                probes.fetch_add(1, Ordering::Relaxed);
                feas_memo.insert(key, f)
            }
        };
        f.feasible()
    };
    // Fit one family's peak model from samples at small lattice lengths:
    // linear from 3 (the common case — every schedule's byte sizes are
    // affine in S/C), quadratic from 4 if the linear drift check fails.
    // The last sample is always held out; `None` (unclean samples or
    // drift) sends the family back to bisection.
    let fit_model = |parallel: &ParallelConfig| -> Option<PeakModel> {
        let c = parallel.cp_degree.max(1);
        let sample = |i: u64| -> Option<PeakSample> {
            let pr = probe(parallel, i * quantum);
            pr.clean().then_some(PeakSample {
                k: i * quantum / c,
                peak_bytes: pr.peak_bytes,
                host_peak: pr.host_peak,
            })
        };
        let s123 = [sample(1)?, sample(2)?, sample(3)?];
        PeakModel::fit(&s123).or_else(|| {
            let s4 = sample(4)?;
            PeakModel::fit(&[s123[0], s123[1], s123[2], s4])
        })
    };
    // Phase 2 — final cells only: full pricing with timeline/components.
    let price = |parallel: &ParallelConfig, s: u64| -> StepReport {
        let preset = preset_of(parallel, s);
        let key = (CellKey::new(&preset, &calib), parallel.pin_memory);
        if let Some(r) = report_memo.get(&key) {
            return r;
        }
        let r = simulate_cached(&preset, &calib, &cache);
        priced.fetch_add(1, Ordering::Relaxed);
        report_memo.insert(key, r)
    };
    let ok = |r: &StepReport| !r.oom && r.failed.is_none();

    let mut evaluated = parallel_map(&space, req.threads, |_, p| {
        let wkey: WarmKey = p.method;
        let max = if req.symbolic {
            // Budgets and limits for this cell (S-independent).
            let qd = Quantities::new(&preset_of(p, quantum));
            let host_budget = qd.host_ram_for_offload();
            let c = p.cp_degree.max(1);
            // Method-imposed sequence ceilings clamp the closed-form
            // solve only — the verified search range stays identical to
            // `--cold`'s, so results cannot diverge.
            let cap_m = match method_seq_cap(p.method) {
                Some(mc) => ((mc / quantum) * quantum).min(cap),
                None => cap,
            };
            let fam = CellKey::new(&preset_of(p, quantum), &calib).family();
            // Check-then-act: workers racing on a cold family may fit it
            // more than once (first insert wins, extras are discarded) —
            // the same benign-race policy as the trace cache, chosen over
            // holding a stripe lock across streamed sample probes. Probe
            // counts are deterministic at `threads = 1`, which is what
            // the equivalence tests pin.
            let model = match models.get(&fam) {
                Some(m) => m,
                None => models.insert(fam, fit_model(p)),
            };
            // The solved wall is only a *hint*: `bisect_max_from` verifies
            // it with two probes (wall feasible, wall + quantum not) and
            // self-corrects by galloping if the model mispredicted. A
            // solved `None` (infeasible even at one quantum) verifies
            // with a single probe at `quantum`.
            let hint = if let Some(m) = model {
                let wall = m.solve_wall(qd.hbm_limit, host_budget, c, quantum, cap_m);
                Some(wall.unwrap_or(quantum))
            } else if req.warm_start {
                // Fit failed: fall back to the neighbour-wall warm start.
                warm.get(&wkey)
            } else {
                None
            };
            bisect_max_from(quantum, cap, hint, |s| probe(p, s).feasible_with_host(host_budget))
        } else {
            let hint = if req.warm_start { warm.get(&wkey) } else { None };
            bisect_max_from(quantum, cap, hint, |s| feasible(p, s))
        };
        if req.warm_start {
            // First finisher seeds the family; later fallback cells
            // gallop from it. An infeasible family still seeds the
            // bottom of the range.
            warm.insert(wkey, max.unwrap_or(quantum));
        }
        let (mut max_peak, mut max_tput) = (None, None);
        let mut ref_peak = None;
        let mut ref_tput = None;
        if !req.feasibility_only {
            if let Some(s) = max {
                let r = price(p, s);
                max_peak = Some(r.peak_bytes / GIB);
                // Throughput counts every micro-batch's tokens over the
                // whole (CP × TP) world.
                max_tput = r.tokens_per_sec_per_gpu(p.micro_batch * s, gpus);
            }
            let rref = price(p, req.reference_s);
            if ok(&rref) {
                ref_peak = Some(rref.peak_bytes / GIB);
                ref_tput = rref.tokens_per_sec_per_gpu(p.micro_batch * req.reference_s, gpus);
            }
        }
        ConfigPlan {
            parallel: p.clone(),
            max_context: max,
            hit_cap: max == Some(cap),
            max_ctx_peak_gib: max_peak,
            max_ctx_tok_s_gpu: max_tput,
            ref_peak_gib: ref_peak,
            ref_tok_s_gpu: ref_tput,
            pareto: false,
        }
    });

    // Rank: longest max context first, then reference throughput, then
    // lowest reference peak; the sort is stable, so exact ties keep the
    // enumeration's paper-preset order (pinned before unpinned, smaller
    // micro-batch and TP first) — which is also the whole tiebreak in
    // feasibility-only sweeps, where no pricing exists.
    evaluated.sort_by(|a, b| {
        let by_ctx = b.max_context.unwrap_or(0).cmp(&a.max_context.unwrap_or(0));
        let (ta, tb) = (a.ref_tok_s_gpu.unwrap_or(0.0), b.ref_tok_s_gpu.unwrap_or(0.0));
        let (pa, pb) = (a.ref_peak_gib, b.ref_peak_gib);
        let by_peak = pa.unwrap_or(f64::INFINITY).total_cmp(&pb.unwrap_or(f64::INFINITY));
        by_ctx.then(tb.total_cmp(&ta)).then(by_peak)
    });

    // Pareto frontier over the reference-length (peak, throughput) points
    // (vacuously empty in feasibility-only sweeps).
    let pts: Vec<(usize, (f64, f64))> = evaluated
        .iter()
        .enumerate()
        .filter_map(|(i, cp)| match (cp.ref_peak_gib, cp.ref_tok_s_gpu) {
            (Some(m), Some(t)) => Some((i, (m, t))),
            _ => None,
        })
        .collect();
    let coords: Vec<(f64, f64)> = pts.iter().map(|&(_, p)| p).collect();
    for fi in pareto_front(&coords) {
        evaluated[pts[fi].0].pareto = true;
    }

    let (fitted, fallbacks) = models.fold((0u64, 0u64), |(f, fb), _, m| match m {
        Some(_) => (f + 1, fb),
        None => (f, fb + 1),
    });
    let n_probes = probes.load(Ordering::Relaxed);
    let n_priced = priced.load(Ordering::Relaxed);
    PlanOutcome {
        model: req.model.clone(),
        cluster: req.cluster.clone(),
        reference_s: req.reference_s,
        quantum,
        configs: evaluated,
        refit: req.refit.clone(),
        simulations: n_probes + n_priced,
        feasibility_probes: n_probes,
        priced_sims: n_priced,
        symbolic_models: fitted,
        symbolic_fallbacks: fallbacks,
        feasibility_only: req.feasibility_only,
        cache_hits: cache.hits(),
        cache_misses: cache.misses(),
        wall_s: t0.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AcMode, CpMethod};

    fn llama_plan() -> PlanOutcome {
        let mut req = PlanRequest::new(ModelDims::llama3_8b(), ClusterConfig::h100_node());
        req.quantum = 512 * 1024;
        req.cap_s = 8 << 20;
        req.threads = 2;
        plan(&req)
    }

    #[test]
    fn golden_llama_single_node_ranking() {
        let out = llama_plan();
        assert!(out.configs.len() >= 100, "space too small: {}", out.configs.len());

        // Paper Fig. 1 / Table 4: UPipe (U = C = 8) is the only method that
        // reaches 5M on one 8×H100 node, and 5M is the single-node max.
        let top = out.best().unwrap();
        assert_eq!(
            top.parallel.method,
            CpMethod::Upipe { u: 8, gqa_schedule: true },
            "top-ranked {:?}",
            top.parallel
        );
        let five_m = 5u64 << 20;
        let top_max = top.max_context.unwrap();
        assert!(top_max >= five_m, "UPipe max {top_max} < 5M");
        assert!(top_max < 6 << 20, "UPipe max {top_max} >= 6M");
        assert!(!top.hit_cap, "5M is a real memory wall, not the search cap");

        // Paper ordering below the winner: FPDT's 4M wall beats Ulysses'
        // 3M-ish OOM wall, which beats Ring/Native. Compare the paper's
        // own settings (pinned, batch 1, no TP, offloaded AC).
        let max_of = |m: CpMethod| {
            out.configs
                .iter()
                .find(|c| {
                    c.parallel.method == m
                        && c.parallel.pin_memory
                        && c.parallel.micro_batch == 1
                        && c.parallel.tp == 1
                        && c.parallel.ac_mode == AcMode::AcOffload
                })
                .and_then(|c| c.max_context)
                .unwrap_or(0)
        };
        assert_eq!(max_of(CpMethod::Fpdt { pi: 16 }), 4 << 20, "FPDT wall");
        assert!(max_of(CpMethod::Ulysses) < five_m, "Ulysses beyond paper wall");
        assert!(max_of(CpMethod::Ulysses) >= 3 << 20, "Ulysses under paper wall");
        assert!(max_of(CpMethod::NativePyTorch) < max_of(CpMethod::Ring));

        // The expanded dims actually ranked: AC-GPU variants exist but
        // never beat offloaded AC on max context for the same method.
        let best_by_ac = |m: CpMethod, ac: AcMode| {
            out.configs
                .iter()
                .filter(|c| c.parallel.method == m && c.parallel.ac_mode == ac)
                .filter_map(|c| c.max_context)
                .max()
                .unwrap_or(0)
        };
        let uly_gpu = best_by_ac(CpMethod::Ulysses, AcMode::AcGpu);
        let uly_off = best_by_ac(CpMethod::Ulysses, AcMode::AcOffload);
        assert!(uly_gpu > 0, "AC-GPU slice was swept");
        assert!(uly_gpu < uly_off, "GPU-resident checkpoints cost context");

        // The symbolic solver actually ran: models fitted for most
        // families, fallbacks the exception (walls below the sample range).
        assert!(out.symbolic_models > 0, "no peak models fitted");
        assert!(
            out.symbolic_models > out.symbolic_fallbacks,
            "models {} vs fallbacks {}",
            out.symbolic_models,
            out.symbolic_fallbacks
        );
    }

    #[test]
    fn frontier_is_nondominated_and_caching_works() {
        let out = llama_plan();
        let front = out.frontier();
        assert!(!front.is_empty());
        for a in &front {
            let (ca, ba) = (a.ref_peak_gib.unwrap(), a.ref_tok_s_gpu.unwrap());
            for b in &out.configs {
                if let (Some(cb), Some(bb)) = (b.ref_peak_gib, b.ref_tok_s_gpu) {
                    assert!(
                        !(cb <= ca && bb >= ba && (cb < ca || bb > ba)),
                        "{:?} dominated by {:?}",
                        a.parallel,
                        b.parallel
                    );
                }
            }
        }
        // The fastest feasible config is always on the frontier.
        let mut fastest: Option<&ConfigPlan> = None;
        for c in &out.configs {
            if let Some(t) = c.ref_tok_s_gpu {
                let better = match fastest.and_then(|f| f.ref_tok_s_gpu) {
                    Some(ft) => t > ft,
                    None => true,
                };
                if better {
                    fastest = Some(c);
                }
            }
        }
        assert!(fastest.unwrap().pareto, "fastest config must be on frontier");
        // Pin variants share traces at the priced cells, so the trace
        // cache must have hits, and the memos must have collapsed replays.
        assert!(out.cache_hits > 0, "no trace-cache hits");
        assert!(out.simulations > 0);
        assert_eq!(out.simulations, out.feasibility_probes + out.priced_sims);
        assert!(out.priced_sims >= out.cache_misses);
        assert!(out.refit.is_none(), "no refit requested");
    }

    #[test]
    fn symbolic_matches_cold_bisection_with_5x_fewer_probes() {
        // The tentpole gate: across the full default sweep at the default
        // (fine) quantum, the symbolic solver must return results
        // *identical* to cold per-cell bisection in every field — while
        // issuing at least 5× fewer streamed feasibility probes.
        let mut req = PlanRequest::new(ModelDims::llama3_8b(), ClusterConfig::h100_node());
        req.quantum = 128 * 1024;
        req.cap_s = 8 << 20;
        req.threads = 1; // deterministic probe accounting
        let sym = plan(&req);
        req.symbolic = false;
        req.warm_start = false; // the --cold configuration, end to end
        let cold = plan(&req);

        assert_eq!(sym.configs.len(), cold.configs.len());
        for (a, b) in sym.configs.iter().zip(&cold.configs) {
            assert_eq!(a.parallel, b.parallel, "ranking order must match");
            assert_eq!(a.max_context, b.max_context, "{:?}", a.parallel);
            assert_eq!(a.hit_cap, b.hit_cap, "{:?}", a.parallel);
            assert_eq!(a.max_ctx_peak_gib, b.max_ctx_peak_gib, "{:?}", a.parallel);
            assert_eq!(a.max_ctx_tok_s_gpu, b.max_ctx_tok_s_gpu, "{:?}", a.parallel);
            assert_eq!(a.ref_peak_gib, b.ref_peak_gib, "{:?}", a.parallel);
            assert_eq!(a.ref_tok_s_gpu, b.ref_tok_s_gpu, "{:?}", a.parallel);
            assert_eq!(a.pareto, b.pareto, "{:?}", a.parallel);
        }
        assert!(cold.symbolic_models == 0 && cold.symbolic_fallbacks == 0, "--cold fit models");
        assert!(sym.symbolic_models > 0);
        assert!(
            cold.feasibility_probes >= 5 * sym.feasibility_probes,
            "probe collapse below 5x: cold {} vs symbolic {}",
            cold.feasibility_probes,
            sym.feasibility_probes
        );
        // Pricing work is identical — the phases are independent.
        assert_eq!(sym.priced_sims, cold.priced_sims);
    }

    #[test]
    fn warm_start_fallback_matches_cold_and_probes_fewer_cells() {
        // The PR 3 property, preserved underneath the symbolic solver:
        // with `symbolic` off, warm-started bisection returns identical
        // results to cold bisection with strictly fewer streamed probes.
        let mut req = PlanRequest::new(ModelDims::llama3_8b(), ClusterConfig::h100_node());
        req.quantum = 1 << 20;
        req.cap_s = 8 << 20;
        req.threads = 1; // deterministic completion order maximizes reuse
        req.symbolic = false;
        let warm = plan(&req);
        req.warm_start = false;
        let cold = plan(&req);
        assert_eq!(warm.configs.len(), cold.configs.len());
        for (a, b) in warm.configs.iter().zip(&cold.configs) {
            assert_eq!(a.parallel, b.parallel, "ranking order must match");
            assert_eq!(a.max_context, b.max_context, "{:?}", a.parallel);
            assert_eq!(a.hit_cap, b.hit_cap, "{:?}", a.parallel);
            assert_eq!(a.ref_tok_s_gpu, b.ref_tok_s_gpu, "{:?}", a.parallel);
            assert_eq!(a.pareto, b.pareto, "{:?}", a.parallel);
        }
        assert!(
            warm.feasibility_probes < cold.feasibility_probes,
            "warm start must probe strictly fewer cells: {} vs {}",
            warm.feasibility_probes,
            cold.feasibility_probes
        );
    }

    #[test]
    fn feasibility_only_matches_walls_and_skips_pricing() {
        let mut req = PlanRequest::new(ModelDims::llama3_8b(), ClusterConfig::h100_node());
        req.quantum = 1 << 20;
        req.cap_s = 8 << 20;
        req.threads = 2;
        let full = plan(&req);
        req.feasibility_only = true;
        let walls = plan(&req);

        assert!(walls.feasibility_only && !full.feasibility_only);
        assert_eq!(walls.priced_sims, 0, "phase 2 must not run");
        assert_eq!(walls.cache_misses, 0, "no traces built for pricing");
        assert_eq!(walls.configs.len(), full.configs.len());
        // Same walls for every configuration (matched by layout — the
        // ranking tiebreak differs without throughput).
        let wall_of = |out: &PlanOutcome, p: &ParallelConfig| {
            out.configs
                .iter()
                .find(|c| &c.parallel == p)
                .map(|c| (c.max_context, c.hit_cap))
                .unwrap()
        };
        for c in &full.configs {
            assert_eq!(wall_of(&walls, &c.parallel), (c.max_context, c.hit_cap));
        }
        for c in &walls.configs {
            assert!(c.ref_peak_gib.is_none() && c.ref_tok_s_gpu.is_none());
            assert!(c.max_ctx_peak_gib.is_none() && c.max_ctx_tok_s_gpu.is_none());
            assert!(!c.pareto, "no frontier without pricing");
        }
        assert!(walls.frontier().is_empty());
        // Ranked by wall: non-increasing max_context down the table.
        for w in walls.configs.windows(2) {
            assert!(w[0].max_context.unwrap_or(0) >= w[1].max_context.unwrap_or(0));
        }
    }

    #[test]
    fn multi_node_walls_scale_with_node_count() {
        // The Fig. 5 sanity the CI smoke also gates: adding nodes never
        // shrinks the best achievable context wall (more aggregate HBM,
        // smaller per-rank shards).
        let best_wall = |gpus: u64| {
            let cluster = ClusterConfig::h100_cluster(gpus).unwrap();
            let mut req = PlanRequest::new(ModelDims::llama3_8b(), cluster);
            req.quantum = 1 << 20;
            req.cap_s = 32 << 20;
            req.threads = 2;
            req.feasibility_only = true;
            let out = plan(&req);
            assert!(!out.configs.is_empty(), "{gpus} GPUs: empty space");
            out.configs.iter().filter_map(|c| c.max_context).max().unwrap_or(0)
        };
        let one = best_wall(8);
        let four = best_wall(32);
        let eight = best_wall(64);
        assert!(one >= 5 << 20, "single node must reach the 5M headline");
        assert!(four >= one, "4-node best wall {four} below single-node {one}");
        assert!(eight >= four, "8-node best wall {eight} below 4-node {four}");
    }

    #[test]
    fn refit_calibration_flows_through_the_plan() {
        // A uniformly faster machine keeps the ranking but raises absolute
        // throughput at the reference length.
        let mut req = PlanRequest::new(ModelDims::llama3_8b(), ClusterConfig::h100_node());
        req.quantum = 1 << 20;
        req.cap_s = 8 << 20;
        req.threads = 2;
        req.dims = SweepDims::paper();
        let base = plan(&req);
        req.calibration.fa3_fwd_flops *= 2.0;
        req.calibration.fa3_bwd_flops *= 2.0;
        let fast = plan(&req);
        let tput = |o: &PlanOutcome| {
            o.configs
                .iter()
                .find(|c| c.parallel.method == CpMethod::Upipe { u: 8, gqa_schedule: true })
                .and_then(|c| c.ref_tok_s_gpu)
                .unwrap()
        };
        assert!(tput(&fast) > 1.3 * tput(&base), "faster rates -> more tokens/s");
        // Memory walls are rate-independent: the top max context agrees.
        assert_eq!(base.best().unwrap().max_context, fast.best().unwrap().max_context);
    }
}
