//! Sweep-space enumerator: expand (model, cluster) into every valid
//! context-parallel configuration — all U divisors of H, all ulysses×ring
//! factorizations of the CP degree, the FPDT π sweep, host-memory pinning,
//! and (via [`SweepDims`]) per-method AC modes, micro-batch counts and
//! TP×CP mixes — generalizing the paper's hand-picked presets (§5.1).
//! Everything emitted passes [`ParallelConfig::validate`]; hybrid families
//! are only emitted where they are physically meaningful (Ulysses inside a
//! node, ring across the rest; TP subdividing the node).

use crate::config::parallel::{divisors, factor_pairs};
use crate::config::{AcMode, ClusterConfig, CpMethod, FleetSpec, ParallelConfig};
use crate::model::ModelDims;

/// FPDT sequence-chunk counts swept (the paper evaluates π = 16).
pub const FPDT_PI: [u32; 5] = [4, 8, 16, 32, 64];

/// Which optional sweep dimensions to enumerate beyond the method space.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepDims {
    /// Include the §5.3.2 UPipe×FPDT composition family.
    pub compositions: bool,
    /// AC modes to sweep; intersected with each method's supported set.
    pub ac_modes: Vec<AcMode>,
    /// Micro-batch counts to sweep (gradient accumulation).
    pub micro_batches: Vec<u64>,
    /// TP degrees to sweep (1 = pure CP, the paper's setup). Each TP rank
    /// group subdivides a node, so tp must divide gpus_per_node, H and Hkv.
    pub tp_degrees: Vec<u64>,
}

impl Default for SweepDims {
    /// The expanded default space: two AC modes per applicable method
    /// (offload + GPU-resident; NoAc is opt-in — it loses by construction),
    /// batch sizes {1, 2, 4}, and TP ∈ {1, 2}.
    fn default() -> Self {
        SweepDims {
            compositions: false,
            ac_modes: vec![AcMode::AcOffload, AcMode::AcGpu],
            micro_batches: vec![1, 2, 4],
            tp_degrees: vec![1, 2],
        }
    }
}

impl SweepDims {
    /// The paper-faithful space: offloaded AC only, batch 1, no TP — the
    /// §5.1 setup the published tables were measured in.
    pub fn paper() -> Self {
        SweepDims {
            compositions: false,
            ac_modes: vec![AcMode::AcOffload],
            micro_batches: vec![1],
            tp_degrees: vec![1],
        }
    }
}

/// Enumerate every valid configuration for `model` on `cluster` across the
/// requested sweep dimensions.
pub fn enumerate_space(
    model: &ModelDims,
    cluster: &ClusterConfig,
    dims: &SweepDims,
) -> Vec<ParallelConfig> {
    let total = cluster.total_gpus();
    let h = model.n_heads;
    let mut out = Vec::new();

    for &tp in &dims.tp_degrees {
        // TP subdivides a node and shards heads: skip degrees that do not.
        if tp == 0
            || cluster.gpus_per_node % tp != 0
            || h % tp != 0
            || model.n_kv_heads % tp != 0
        {
            continue;
        }
        let c = total / tp;
        let per_node = cluster.gpus_per_node / tp;

        let mut methods = vec![CpMethod::NativePyTorch, CpMethod::Ring];
        if cluster.nodes == 1 {
            methods.push(CpMethod::Ulysses);
            // UPipe: U must be a multiple of C and a divisor of H (§3.3).
            for u in divisors(h) {
                if u % c == 0 {
                    for gqa in [true, false] {
                        methods.push(CpMethod::Upipe { u: u as u32, gqa_schedule: gqa });
                    }
                }
            }
        } else {
            // USP-Hybrid: Ulysses over a divisor of the node's CP ranks,
            // ring across the rest; 1-way factors degenerate into the pure
            // methods and are skipped.
            for (cu, cr) in factor_pairs(c) {
                if cu >= 2 && cr >= 2 && cu <= per_node && per_node % cu == 0 {
                    methods.push(CpMethod::UspHybrid { ulysses: cu as u32, ring: cr as u32 });
                }
            }
            // UPipe-Hybrid: stages all-to-all over the node's CP ranks (the
            // §5.1 "restrict Ulysses degree to 8" setup), so U must cover
            // them; ring spans the nodes.
            for u in divisors(h) {
                if per_node > 0 && u % per_node == 0 {
                    methods.push(CpMethod::UpipeHybrid {
                        u: u as u32,
                        ulysses: per_node as u32,
                        ring: cluster.nodes as u32,
                    });
                }
            }
        }
        for pi in FPDT_PI {
            methods.push(CpMethod::Fpdt { pi });
        }
        if dims.compositions {
            for u in divisors(h) {
                if u % c != 0 {
                    continue;
                }
                for pi in FPDT_PI {
                    methods.push(CpMethod::UpipeFpdt { u: u as u32, pi });
                }
            }
        }

        for m in methods {
            for &ac in &dims.ac_modes {
                if !m.supported_ac_modes().contains(&ac) {
                    continue;
                }
                for &mb in &dims.micro_batches {
                    if mb == 0 {
                        continue;
                    }
                    // §5.1: PIN_MEMORY is a real capacity knob — the paper
                    // flips it off at 5M so offloaded activations still
                    // fit in host RAM.
                    for pin in [true, false] {
                        let mut p = ParallelConfig::new(m, c);
                        p.ac_mode = ac;
                        p.micro_batch = mb;
                        p.tp = tp;
                        p.pin_memory = pin;
                        if p.validate_model(model).is_ok() {
                            out.push(p);
                        }
                    }
                }
            }
        }
    }
    out
}

/// One placement candidate: a homogeneous slice of one fleet pool,
/// evaluated by the planner as an ordinary cluster. The pool and device
/// names ride along for reporting; neither enters any cache key.
#[derive(Debug, Clone)]
pub struct ClusterShape {
    pub pool: String,
    pub device: String,
    pub cluster: ClusterConfig,
}

impl ClusterShape {
    pub fn gpus(&self) -> u64 {
        self.cluster.total_gpus()
    }
}

/// Expand a fleet into candidate cluster shapes: per pool, every
/// power-of-two node count up to the pool's size plus the full pool —
/// the allocation granularities a scheduler actually hands out. Order is
/// deterministic (pools in declaration order, node counts ascending), so
/// placement results are stable bytes. Shapes of identical hardware at
/// the same node count (a 4-node slice of an 8-node pool vs a 4-node
/// pool of the same device) intentionally produce identical cache keys:
/// the second one re-fits nothing.
pub fn enumerate_shapes(fleet: &FleetSpec) -> Vec<ClusterShape> {
    let mut out = Vec::new();
    for pool in &fleet.pools {
        let mut counts: Vec<u64> = Vec::new();
        let mut n = 1u64;
        while n < pool.nodes {
            counts.push(n);
            n *= 2;
        }
        counts.push(pool.nodes);
        for nodes in counts {
            out.push(ClusterShape {
                pool: pool.name.clone(),
                device: pool.device.name.clone(),
                cluster: pool.device.cluster(nodes, pool.device.gpus_per_node),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use std::collections::HashSet;

    fn llama8(dims: &SweepDims) -> Vec<ParallelConfig> {
        enumerate_space(&ModelDims::llama3_8b(), &ClusterConfig::h100_node(), dims)
    }

    #[test]
    fn llama_single_node_space_is_broad_and_valid() {
        let space = llama8(&SweepDims::default());
        assert!(space.len() >= 100, "only {} configs", space.len());
        for p in &space {
            assert!(p.validate(32).is_ok(), "{p:?}");
            assert_eq!(p.world(), 8, "CP×TP must cover the node: {p:?}");
        }
        let has = |m: CpMethod| space.iter().any(|p| p.method == m);
        assert!(has(CpMethod::Upipe { u: 8, gqa_schedule: true }));
        // No hybrids on a single node.
        for p in &space {
            assert!(!p.method.label().contains("Hybrid"), "{p:?}");
        }
        // The expanded dims are actually present: >=2 AC modes for the
        // AC-capable methods, batch sizes {1,2,4}, and a TP=2 slice.
        let ulysses_acs: HashSet<&str> = space
            .iter()
            .filter(|p| p.method == CpMethod::Ulysses)
            .map(|p| p.ac_mode.label())
            .collect();
        assert!(ulysses_acs.len() >= 2, "AC sweep missing: {ulysses_acs:?}");
        let mbs: HashSet<u64> = space.iter().map(|p| p.micro_batch).collect();
        assert_eq!(mbs, HashSet::from([1, 2, 4]));
        assert!(space.iter().any(|p| p.tp == 2 && p.cp_degree == 4), "TP slice");
        // FPDT only ever appears with offloaded AC.
        for p in &space {
            if matches!(p.method, CpMethod::Fpdt { .. }) {
                assert_eq!(p.ac_mode, AcMode::AcOffload, "{p:?}");
            }
        }
    }

    #[test]
    fn paper_dims_reproduce_the_original_space() {
        let space = llama8(&SweepDims::paper());
        assert!(space.len() >= 20, "only {} configs", space.len());
        for p in &space {
            assert_eq!(p.ac_mode, AcMode::AcOffload);
            assert_eq!(p.micro_batch, 1);
            assert_eq!(p.tp, 1);
            assert_eq!(p.cp_degree, 8);
        }
    }

    #[test]
    fn no_duplicate_configs() {
        for compose in [false, true] {
            let dims = SweepDims { compositions: compose, ..SweepDims::default() };
            let space = enumerate_space(
                &ModelDims::qwen3_32b(),
                &ClusterConfig::h100_2nodes(),
                &dims,
            );
            let keys: HashSet<String> = space
                .iter()
                .map(|p| {
                    format!(
                        "{:?}|{:?}|{}|{}|{}|{}",
                        p.method, p.ac_mode, p.pin_memory, p.micro_batch, p.tp, p.cp_degree
                    )
                })
                .collect();
            assert_eq!(keys.len(), space.len());
        }
    }

    #[test]
    fn multi_node_space_uses_hybrids() {
        let space = enumerate_space(
            &ModelDims::qwen3_32b(),
            &ClusterConfig::h100_2nodes(),
            &SweepDims::default(),
        );
        assert!(space.len() >= 100, "only {} configs", space.len());
        let has = |m: CpMethod| space.iter().any(|p| p.method == m);
        assert!(has(CpMethod::UspHybrid { ulysses: 8, ring: 2 }));
        assert!(has(CpMethod::UpipeHybrid { u: 8, ulysses: 8, ring: 2 }));
        // With TP=2, the per-node CP group shrinks to 4 ranks.
        assert!(has(CpMethod::UpipeHybrid { u: 8, ulysses: 4, ring: 2 }));
        // The single-node methods are replaced by their hybrid forms.
        for p in &space {
            let single = matches!(p.method, CpMethod::Ulysses | CpMethod::Upipe { .. });
            assert!(!single, "{p:?}");
        }
    }

    #[test]
    fn compositions_are_opt_in() {
        let base = llama8(&SweepDims::default()).len();
        let dims = SweepDims { compositions: true, ..SweepDims::default() };
        let with = llama8(&dims);
        assert!(with.len() > base);
        for p in &with {
            if matches!(p.method, CpMethod::UpipeFpdt { .. }) {
                assert_eq!(p.ac_mode, AcMode::AcOffload, "{p:?}");
            }
        }
    }

    #[test]
    fn shapes_enumerate_power_of_two_slices_per_pool() {
        let fleet = FleetSpec::parse(
            r#"{"pools": [
                {"name": "big-h100", "device": "h100", "nodes": 6},
                {"name": "new-h200", "device": "h200", "nodes": 2}
            ]}"#,
            "test",
        )
        .unwrap();
        let shapes = enumerate_shapes(&fleet);
        let rows: Vec<(String, u64)> =
            shapes.iter().map(|s| (s.pool.clone(), s.cluster.nodes)).collect();
        assert_eq!(
            rows,
            vec![
                ("big-h100".to_string(), 1),
                ("big-h100".to_string(), 2),
                ("big-h100".to_string(), 4),
                ("big-h100".to_string(), 6),
                ("new-h200".to_string(), 1),
                ("new-h200".to_string(), 2),
            ]
        );
        // H100 slices carry the paper testbed's exact hardware: their
        // cache keys alias the homogeneous planner's on purpose.
        assert_eq!(
            shapes[0].cluster.hardware_fingerprint(),
            ClusterConfig::h100_node().hardware_fingerprint()
        );
        assert_eq!(shapes[4].device, "H200");
        assert!(shapes[4].cluster.hbm_bytes > shapes[0].cluster.hbm_bytes);
    }

    #[test]
    fn prop_every_enumerated_config_validates() {
        let gpu_choices = [1u64, 2, 4, 8, 16, 24, 32];
        prop::check("space-validates", 40, &[(0, 6), (0, 1)], |a| {
            let cluster = ClusterConfig::h100_cluster(gpu_choices[a[0] as usize]).unwrap();
            let model = if a[1] == 0 {
                ModelDims::llama3_8b()
            } else {
                ModelDims::qwen3_32b()
            };
            let dims = SweepDims { compositions: true, ..SweepDims::default() };
            enumerate_space(&model, &cluster, &dims).iter().all(|p| {
                p.validate_model(&model).is_ok() && p.world() == cluster.total_gpus()
            })
        });
    }
}
