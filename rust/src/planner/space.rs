//! Sweep-space enumerator: expand (model, cluster) into every valid
//! context-parallel configuration — all U divisors of H, all ulysses×ring
//! factorizations of the CP degree, the FPDT π sweep, host-memory pinning
//! — generalizing the paper's hand-picked presets (§5.1). Everything
//! emitted passes [`ParallelConfig::validate`]; hybrid families are only
//! emitted where they are physically meaningful (Ulysses inside a node,
//! ring across the rest).

use crate::config::parallel::{divisors, factor_pairs};
use crate::config::{ClusterConfig, CpMethod, ParallelConfig};
use crate::model::ModelDims;

/// FPDT sequence-chunk counts swept (the paper evaluates π = 16).
pub const FPDT_PI: [u32; 5] = [4, 8, 16, 32, 64];

/// Enumerate every valid configuration for `model` on `cluster`.
///
/// `compositions` adds the §5.3.2 UPipe×FPDT composition — anticipated
/// future work in the paper, so it is excluded from the default
/// paper-faithful space (where the evaluated method families compete).
pub fn enumerate_space(
    model: &ModelDims,
    cluster: &ClusterConfig,
    compositions: bool,
) -> Vec<ParallelConfig> {
    let c = cluster.total_gpus();
    let h = model.n_heads;
    let mut methods = vec![CpMethod::NativePyTorch, CpMethod::Ring];
    if cluster.nodes == 1 {
        methods.push(CpMethod::Ulysses);
        // UPipe: U must be a multiple of C and a divisor of H (§3.3).
        for u in divisors(h) {
            if u % c == 0 {
                for gqa in [true, false] {
                    methods.push(CpMethod::Upipe { u: u as u32, gqa_schedule: gqa });
                }
            }
        }
    } else {
        // USP-Hybrid: Ulysses over a divisor of the node, ring across the
        // rest; 1-way factors degenerate into the pure methods and are
        // skipped.
        let per_node = cluster.gpus_per_node;
        for (cu, cr) in factor_pairs(c) {
            if cu >= 2 && cr >= 2 && cu <= per_node && per_node % cu == 0 {
                methods.push(CpMethod::UspHybrid { ulysses: cu as u32, ring: cr as u32 });
            }
        }
        // UPipe-Hybrid: stages all-to-all over the whole node (the §5.1
        // "restrict Ulysses degree to 8" setup), so U must cover a node's
        // ranks; ring spans the nodes.
        for u in divisors(h) {
            if u % cluster.gpus_per_node == 0 {
                methods.push(CpMethod::UpipeHybrid {
                    u: u as u32,
                    ulysses: cluster.gpus_per_node as u32,
                    ring: cluster.nodes as u32,
                });
            }
        }
    }
    for pi in FPDT_PI {
        methods.push(CpMethod::Fpdt { pi });
    }
    if compositions {
        for u in divisors(h) {
            if u % c != 0 {
                continue;
            }
            for pi in FPDT_PI {
                methods.push(CpMethod::UpipeFpdt { u: u as u32, pi });
            }
        }
    }

    let mut out = Vec::new();
    for m in methods {
        // §5.1: PIN_MEMORY is a real capacity knob — the paper flips it
        // off at 5M so offloaded activations still fit in host RAM.
        for pin in [true, false] {
            let mut p = ParallelConfig::new(m, c);
            p.pin_memory = pin;
            if p.validate(h).is_ok() {
                out.push(p);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use std::collections::HashSet;

    fn llama8() -> Vec<ParallelConfig> {
        enumerate_space(&ModelDims::llama3_8b(), &ClusterConfig::h100_node(), false)
    }

    #[test]
    fn llama_single_node_space_is_broad_and_valid() {
        let space = llama8();
        assert!(space.len() >= 20, "only {} configs", space.len());
        for p in &space {
            assert!(p.validate(32).is_ok(), "{p:?}");
            assert_eq!(p.cp_degree, 8);
        }
        let has = |m: CpMethod| space.iter().any(|p| p.method == m);
        assert!(has(CpMethod::Upipe { u: 8, gqa_schedule: true }));
        // No hybrids on a single node.
        for p in &space {
            assert!(!p.method.label().contains("Hybrid"), "{p:?}");
        }
    }

    #[test]
    fn no_duplicate_configs() {
        for compose in [false, true] {
            let space = enumerate_space(
                &ModelDims::qwen3_32b(),
                &ClusterConfig::h100_2nodes(),
                compose,
            );
            let keys: HashSet<String> = space
                .iter()
                .map(|p| format!("{:?}|{}", p.method, p.pin_memory))
                .collect();
            assert_eq!(keys.len(), space.len());
        }
    }

    #[test]
    fn multi_node_space_uses_hybrids() {
        let space = enumerate_space(&ModelDims::qwen3_32b(), &ClusterConfig::h100_2nodes(), false);
        assert!(space.len() >= 20, "only {} configs", space.len());
        let has = |m: CpMethod| space.iter().any(|p| p.method == m);
        assert!(has(CpMethod::UspHybrid { ulysses: 8, ring: 2 }));
        assert!(has(CpMethod::UpipeHybrid { u: 8, ulysses: 8, ring: 2 }));
        // The single-node methods are replaced by their hybrid forms.
        for p in &space {
            let single = matches!(p.method, CpMethod::Ulysses | CpMethod::Upipe { .. });
            assert!(!single, "{p:?}");
        }
    }

    #[test]
    fn compositions_are_opt_in() {
        let base = llama8().len();
        let with = enumerate_space(&ModelDims::llama3_8b(), &ClusterConfig::h100_node(), true);
        assert!(with.len() > base);
    }

    #[test]
    fn prop_every_enumerated_config_validates() {
        let gpu_choices = [1u64, 2, 4, 8, 16, 24, 32];
        prop::check("space-validates", 40, &[(0, 6), (0, 1)], |a| {
            let cluster = ClusterConfig::h100_cluster(gpu_choices[a[0] as usize]).unwrap();
            let model = if a[1] == 0 {
                ModelDims::llama3_8b()
            } else {
                ModelDims::qwen3_32b()
            };
            enumerate_space(&model, &cluster, true)
                .iter()
                .all(|p| p.validate(model.n_heads).is_ok() && p.cp_degree == cluster.total_gpus())
        });
    }
}
