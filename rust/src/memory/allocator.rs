//! Caching-allocator simulator.
//!
//! Models the behaviours that matter for the paper's claims:
//! - *Peak tracking* — `peak_allocated` is what Table 4 reports
//!   (`torch.cuda.max_memory_allocated` analogue).
//! - *Buffer reuse* — freeing a block returns it to a size-bucketed cache;
//!   a same-size alloc reuses it (UPipe's stage buffers hit this path, the
//!   mechanism behind "reuse the memory buffers from the previous stage").
//! - *Fragmentation & retries* — allocs that miss the cache grow reserved
//!   memory; when reserved would exceed the HBM limit the allocator first
//!   "flushes" the cache (a CUDA `cudaMalloc` retry, counted), and OOMs
//!   only if the block still does not fit. Retry counts feed the engine's
//!   memory-pressure throughput penalty (§5.3: UPipe "eliminating CUDA
//!   allocation retries").

use std::collections::HashMap;

pub type AllocId = u64;

#[derive(Debug, Clone)]
pub struct Allocator {
    limit: f64,
    allocated: f64,
    reserved: f64,
    peak_allocated: f64,
    peak_reserved: f64,
    retries: u64,
    oom: bool,
    /// Live block sizes, dense by `AllocId` (ids are sequential); freed
    /// slots hold a NaN tombstone. This keeps the planner's hot path off
    /// a per-block `HashMap`.
    live: Vec<f64>,
    live_count: usize,
    /// size-bucketed free cache: size -> count of cached blocks
    cache: HashMap<u64, u64>,
}

impl Allocator {
    pub fn new(limit_bytes: f64) -> Self {
        Allocator {
            limit: limit_bytes,
            allocated: 0.0,
            reserved: 0.0,
            peak_allocated: 0.0,
            peak_reserved: 0.0,
            retries: 0,
            oom: false,
            live: Vec::new(),
            live_count: 0,
            cache: HashMap::new(),
        }
    }

    /// Allocate `bytes`; returns None on OOM (the simulation records the
    /// OOM and refuses further allocs, mirroring a CUDA OOM abort).
    pub fn alloc(&mut self, bytes: f64) -> Option<AllocId> {
        if self.oom {
            return None;
        }
        let bucket = Self::bucket(bytes);
        if let Some(n) = self.cache.get_mut(&bucket) {
            // Cache hit: reuse a cached block; reserved unchanged.
            *n -= 1;
            if *n == 0 {
                self.cache.remove(&bucket);
            }
        } else {
            // Cache miss: grow reserved by the rounded block size (the
            // caching allocator reserves whole bins; a later same-bucket
            // alloc may be served by this block even if slightly larger
            // than the original request).
            let block = bucket as f64;
            if self.reserved + block > self.limit {
                // Allocation retry: flush the block cache and re-try —
                // the expensive path the paper's UPipe avoids.
                self.retries += 1;
                self.flush_cache();
                if self.reserved + block > self.limit {
                    self.oom = true;
                    return None;
                }
            }
            self.reserved += block;
            self.peak_reserved = self.peak_reserved.max(self.reserved);
        }
        self.allocated += bytes;
        self.peak_allocated = self.peak_allocated.max(self.allocated);
        let id = self.live.len() as AllocId;
        self.live.push(bytes);
        self.live_count += 1;
        Some(id)
    }

    /// Free a block back to the cache.
    pub fn free(&mut self, id: AllocId) {
        let slot = self
            .live
            .get_mut(id as usize)
            .filter(|b| !b.is_nan())
            .expect("double free or unknown id");
        let bytes = *slot;
        *slot = f64::NAN;
        self.live_count -= 1;
        self.allocated -= bytes;
        *self.cache.entry(Self::bucket(bytes)).or_insert(0) += 1;
    }

    fn flush_cache(&mut self) {
        let cached: f64 = self
            .cache
            .iter()
            .map(|(&b, &n)| b as f64 * n as f64)
            .sum();
        self.reserved -= cached;
        self.cache.clear();
    }

    /// Size bucket (pow2-ish rounding like the caching allocator's bins).
    fn bucket(bytes: f64) -> u64 {
        let b = bytes.max(1.0) as u64;
        if b < 1 << 20 {
            b.next_power_of_two()
        } else {
            // >=1MiB: round up to 2MiB granularity
            b.div_ceil(2 << 20) * (2 << 20)
        }
    }

    pub fn allocated(&self) -> f64 {
        self.allocated
    }
    pub fn reserved(&self) -> f64 {
        self.reserved
    }
    pub fn peak_allocated(&self) -> f64 {
        self.peak_allocated
    }
    pub fn peak_reserved(&self) -> f64 {
        self.peak_reserved
    }
    pub fn retries(&self) -> u64 {
        self.retries
    }
    pub fn is_oom(&self) -> bool {
        self.oom
    }
    pub fn live_blocks(&self) -> usize {
        self.live_count
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    const MB: f64 = 1024.0 * 1024.0;

    #[test]
    fn peak_tracks_high_water_mark() {
        let mut a = Allocator::new(100.0 * MB);
        let x = a.alloc(10.0 * MB).unwrap();
        let y = a.alloc(20.0 * MB).unwrap();
        a.free(x);
        assert_eq!(a.peak_allocated(), 30.0 * MB);
        assert_eq!(a.allocated(), 20.0 * MB);
        a.free(y);
        assert_eq!(a.allocated(), 0.0);
        assert_eq!(a.peak_allocated(), 30.0 * MB);
    }

    #[test]
    fn buffer_reuse_keeps_reserved_flat() {
        // UPipe's stage pattern: alloc/free the same-size chunk ν times.
        let mut a = Allocator::new(100.0 * MB);
        let mut reserved_after_first = 0.0;
        for stage in 0..8 {
            let q = a.alloc(4.0 * MB).unwrap();
            let k = a.alloc(2.0 * MB).unwrap();
            a.free(q);
            a.free(k);
            if stage == 0 {
                reserved_after_first = a.reserved();
            } else {
                assert_eq!(a.reserved(), reserved_after_first, "stage {stage}");
            }
        }
        assert_eq!(a.retries(), 0);
    }

    #[test]
    fn retry_then_oom() {
        let mut a = Allocator::new(10.0 * MB);
        let x = a.alloc(6.0 * MB).unwrap();
        a.free(x); // 6MB block now cached; reserved ~6MB
        // 7MB buckets to 8MB: cache miss; reserved would exceed 10MB ->
        // retry flushes the cache, then succeeds.
        let y = a.alloc(7.0 * MB);
        assert!(y.is_some());
        assert_eq!(a.retries(), 1);
        // now exceed outright
        assert!(a.alloc(20.0 * MB).is_none());
        assert!(a.is_oom());
    }

    #[test]
    fn same_bucket_reuse_is_a_cache_hit() {
        let mut a = Allocator::new(10.0 * MB);
        let x = a.alloc(6.0 * MB).unwrap();
        a.free(x);
        // 5MB buckets to 6MB too: reuses the cached block, no retry.
        let y = a.alloc(5.0 * MB);
        assert!(y.is_some());
        assert_eq!(a.retries(), 0);
    }

    #[test]
    fn bucket_rounding() {
        assert_eq!(Allocator::bucket(3.0), 4);
        assert_eq!(Allocator::bucket((3 << 20) as f64), 2 * (2 << 20));
    }

    #[test]
    fn prop_allocated_never_exceeds_peak_and_conserves() {
        prop::check("alloc-conserve", 50, &[(1, 64), (1, 100)], |args| {
            let n_ops = args[0] as usize * 4;
            let mut rng = Rng::new(args[1] as u64);
            let mut a = Allocator::new(1e12);
            let mut live = Vec::new();
            let mut expect = 0.0;
            for _ in 0..n_ops {
                if live.is_empty() || rng.f64() < 0.6 {
                    let sz = (rng.below(1000) + 1) as f64 * MB / 16.0;
                    live.push((a.alloc(sz).unwrap(), sz));
                    expect += sz;
                } else {
                    let i = rng.below(live.len() as u64) as usize;
                    let (id, sz) = live.swap_remove(i);
                    a.free(id);
                    expect -= sz;
                }
                if (a.allocated() - expect).abs() > 1.0 {
                    return false;
                }
                if a.allocated() > a.peak_allocated() + 1.0 {
                    return false;
                }
                if a.peak_allocated() > a.peak_reserved() + 1.0 {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = Allocator::new(MB);
        let x = a.alloc(1.0).unwrap();
        a.free(x);
        a.free(x);
    }
}
