//! GPU memory accounting: a CUDA-caching-allocator-style simulator with
//! peak/timeline tracking. UPipe's headline claim is about *peak allocated
//! memory* and the allocation retries the caching allocator performs under
//! pressure — this module makes both observable.

pub mod allocator;
pub mod tracker;

pub use allocator::{AllocId, Allocator};
pub use tracker::MemoryTimeline;
