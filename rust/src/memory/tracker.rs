//! Memory timeline: (time, allocated) samples recorded by the engine while
//! executing a schedule, with phase labels — this is what Fig. 2's
//! per-method breakdown and the OOM detection read.

/// One labelled segment of the memory timeline.
#[derive(Debug, Clone)]
pub struct Sample {
    pub t: f64,
    pub allocated: f64,
    pub label: &'static str,
}

#[derive(Debug, Clone, Default)]
pub struct MemoryTimeline {
    samples: Vec<Sample>,
}

impl MemoryTimeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, t: f64, allocated: f64, label: &'static str) {
        self.samples.push(Sample { t, allocated, label });
    }

    pub fn peak(&self) -> f64 {
        self.samples.iter().map(|s| s.allocated).fold(0.0, f64::max)
    }

    /// Label active at the peak (which phase is the bottleneck).
    pub fn peak_label(&self) -> Option<&'static str> {
        self.samples
            .iter()
            .max_by(|a, b| a.allocated.total_cmp(&b.allocated))
            .map(|s| s.label)
    }

    pub fn samples(&self) -> &[Sample] {
        &self.samples
    }

    /// Peak within a labelled phase.
    pub fn peak_in(&self, label: &str) -> f64 {
        self.samples
            .iter()
            .filter(|s| s.label == label)
            .map(|s| s.allocated)
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_and_label() {
        let mut t = MemoryTimeline::new();
        t.record(0.0, 10.0, "fwd");
        t.record(1.0, 30.0, "attn");
        t.record(2.0, 20.0, "bwd");
        assert_eq!(t.peak(), 30.0);
        assert_eq!(t.peak_label(), Some("attn"));
        assert_eq!(t.peak_in("bwd"), 20.0);
        assert_eq!(t.peak_in("missing"), 0.0);
    }

    #[test]
    fn empty_timeline() {
        let t = MemoryTimeline::new();
        assert_eq!(t.peak(), 0.0);
        assert_eq!(t.peak_label(), None);
    }
}
