//! Online calibration: telemetry-driven refit with drift detection and
//! surgical memo invalidation.
//!
//! `--refit` (see [`crate::engine::refit`]) is a one-shot batch inversion
//! of the DS-Ulysses anchor. This subsystem turns calibration into a
//! *live, versioned* object instead:
//!
//! - [`telemetry`] ingests per-method measurement records (Ulysses, UPipe,
//!   Ring and FPDT step-component times, optionally tagged with the HBM
//!   headroom they ran under so pressured samples de-penalize before
//!   inversion) through bounded per-method ring buffers with a MAD outlier
//!   gate.
//! - [`invert`] generalizes the rate inversion per [`crate::config::CpMethod`]:
//!   instead of the Ulysses-only closed forms in `engine/refit.rs`, it
//!   streams the method's *actual* op trace into a structural sink
//!   (volumes, calls, FLOPs, fixed floors) and inverts each fitted
//!   constant against those exact quantities — correct by construction
//!   for every schedule the trace builder knows.
//! - [`online`] folds accepted observations into exponentially-weighted
//!   rate estimates, tracks per-constant drift against the active
//!   [`crate::engine::Calibration`], and publishes a new **calibration
//!   epoch** (with full old→new provenance) only when drift exceeds a
//!   configurable relative threshold.
//! - [`epoch`] carries the provenance chain and renders the
//!   `/v1/calibration` snapshot.
//!
//! On epoch publish the service drops exactly the memo entries keyed on
//! the stale `Calibration::fingerprint()` (see
//! `PlannerCaches::invalidate_fingerprint`); entries under other
//! fingerprints — e.g. other fleet hardware pools — survive untouched.
//!
//! Everything here is deterministic: no wall-clock, epoch ids are
//! sequence numbers, and replaying the same telemetry yields a
//! byte-identical `/v1/calibration` snapshot.

pub mod epoch;
pub mod invert;
pub mod online;
pub mod telemetry;

pub use epoch::{CalibrationSnapshot, DriftEntry, EpochField, EpochRecord};
pub use invert::{capture_profile, invert_observation, FitConstant, StructuralProfile};
pub use online::{IngestReport, OnlineCalibrator, OnlineConfig, PublishedEpoch};
pub use telemetry::{Observation, TelemetryStore, OBSERVATION_FIELDS};
