//! Calibration epochs: versioned provenance for online refits.
//!
//! Epoch 0 is the calibration the service booted with. Every publish
//! bumps the epoch by one and records `RefitInfo`-style provenance —
//! old → new per constant, with the observation count behind each
//! update — so `/v1/calibration` can show the full chain from boot to
//! the active constants. Fingerprints are
//! [`crate::engine::Calibration::fingerprint`] values rendered as 16
//! hex digits, matching the cache keys they invalidate.

use super::invert::FitConstant;
use crate::engine::Calibration;
use crate::util::json::Json;

/// Render a calibration fingerprint the way cache diagnostics do.
pub fn fingerprint_hex(fp: u64) -> String {
    format!("{fp:016x}")
}

/// One constant's old → new update inside a published epoch.
#[derive(Debug, Clone)]
pub struct EpochField {
    pub constant: FitConstant,
    pub old: f64,
    pub new: f64,
    /// Accepted observations folded into the estimate at publish time.
    pub observations: u64,
}

impl EpochField {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("constant", Json::string(self.constant.name())),
            ("old", Json::Num(self.old)),
            ("new", Json::Num(self.new)),
            ("observations", Json::int(self.observations)),
        ])
    }
}

/// Provenance for one published epoch.
#[derive(Debug, Clone)]
pub struct EpochRecord {
    /// The epoch this publish created (1, 2, ...).
    pub epoch: u64,
    /// Fingerprint of the calibration this epoch replaced.
    pub old_fingerprint: u64,
    /// Fingerprint of the calibration this epoch activated.
    pub new_fingerprint: u64,
    /// Constants the publish moved: every sufficiently-observed constant
    /// whose estimate differed from the active value (the publish is
    /// *triggered* by one crossing the drift threshold, but adopts all of
    /// them so post-publish drift collapses to zero). Untouched constants
    /// are not listed.
    pub fields: Vec<EpochField>,
}

impl EpochRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("epoch", Json::int(self.epoch)),
            ("old_fingerprint", Json::string(&fingerprint_hex(self.old_fingerprint))),
            ("new_fingerprint", Json::string(&fingerprint_hex(self.new_fingerprint))),
            ("fields", Json::Arr(self.fields.iter().map(EpochField::to_json).collect())),
        ])
    }
}

/// Current drift of one fitted constant: the EW estimate from accepted
/// telemetry vs. the active calibration's value.
#[derive(Debug, Clone)]
pub struct DriftEntry {
    pub constant: FitConstant,
    /// Value in the active calibration.
    pub active: f64,
    /// Exponentially-weighted estimate from accepted observations.
    pub estimate: f64,
    /// `|estimate - active| / |active|`.
    pub rel_drift: f64,
    /// Accepted observations behind the estimate.
    pub observations: u64,
}

impl DriftEntry {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("constant", Json::string(self.constant.name())),
            ("active", Json::Num(self.active)),
            ("estimate", Json::Num(self.estimate)),
            ("rel_drift", Json::Num(self.rel_drift)),
            ("observations", Json::int(self.observations)),
        ])
    }
}

/// Everything `/v1/calibration` reports: the active epoch and constants,
/// the live drift vector, and the provenance chain.
#[derive(Debug, Clone)]
pub struct CalibrationSnapshot {
    pub epoch: u64,
    pub fingerprint: u64,
    pub constants: Vec<(&'static str, f64)>,
    pub drift: Vec<DriftEntry>,
    pub history: Vec<EpochRecord>,
}

impl CalibrationSnapshot {
    pub fn capture(
        epoch: u64,
        active: &Calibration,
        drift: Vec<DriftEntry>,
        history: &[EpochRecord],
    ) -> Self {
        CalibrationSnapshot {
            epoch,
            fingerprint: active.fingerprint(),
            constants: active.fields().to_vec(),
            drift,
            history: history.to_vec(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("epoch", Json::int(self.epoch)),
            ("fingerprint", Json::string(&fingerprint_hex(self.fingerprint))),
            (
                "constants",
                Json::Obj(
                    self.constants.iter().map(|(n, v)| (n.to_string(), Json::Num(*v))).collect(),
                ),
            ),
            ("drift", Json::Arr(self.drift.iter().map(DriftEntry::to_json).collect())),
            ("history", Json::Arr(self.history.iter().map(EpochRecord::to_json).collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_renders_deterministically() {
        let cal = Calibration::default();
        let drift = vec![DriftEntry {
            constant: FitConstant::OtherRate,
            active: 1.0e-12,
            estimate: 1.1e-12,
            rel_drift: 0.1,
            observations: 5,
        }];
        let history = vec![EpochRecord {
            epoch: 1,
            old_fingerprint: 0xdead_beef,
            new_fingerprint: cal.fingerprint(),
            fields: vec![EpochField {
                constant: FitConstant::OtherRate,
                old: 1.0e-12,
                new: 1.1e-12,
                observations: 5,
            }],
        }];
        let snap = CalibrationSnapshot::capture(1, &cal, drift, &history);
        let text = snap.to_json().render();
        assert_eq!(text, snap.to_json().render(), "render is deterministic");
        assert!(text.contains("\"epoch\":1"));
        assert!(text.contains(&fingerprint_hex(cal.fingerprint())));
        assert!(text.contains("\"old_fingerprint\":\"00000000deadbeef\""));
        assert!(text.contains("\"fa3_fwd_flops\""), "all constants listed");
        assert!(text.contains("\"rel_drift\""));
        assert_eq!(snap.constants.len(), 27, "every calibration field present");
    }
}
