//! Per-method rate inversion against *structural* trace quantities.
//!
//! `engine/refit.rs` inverts the DS-Ulysses anchor with closed-form
//! volume/FLOP formulas that are only valid for the Ulysses schedule.
//! This module generalizes the inversion to every [`CpMethod`] the trace
//! builder knows, without hand-deriving a formula per method: it streams
//! the method's actual op emission into a [`StructSink`] and collects the
//! exact quantities the pricing kernels charge against —
//!
//! - attention FLOPs per category (`Compute { Fa3Fwd / Fa3Bwd }`),
//! - message-size-weighted all-to-all volume and call counts,
//! - ring exchange bytes and per-step launch floors,
//! - non-overlapped PCIe traffic,
//! - per-category `Fixed` seconds.
//!
//! `Fixed` seconds are emitted by schedules *from* the calibration (bulk
//! "other" work, FPDT stalls), so a second pass streams the same trace
//! with the target constant doubled: the difference isolates the
//! constant's exact (linear) contribution, and the remainder is the
//! calibration-independent floor. An observed component time then inverts
//! to a rate by subtracting the floor and dividing the structural
//! quantity — identical arithmetic to `refit.rs` for Ulysses (pinned by a
//! test below) and correct by construction for UPipe, Ring and FPDT.

use super::telemetry::Observation;
use crate::config::presets::RunPreset;
use crate::config::CpMethod;
use crate::engine::{Calibration, Category, Op, OpSink};
use crate::schedule::stream_trace_with;
use crate::util::fmt::GIB;

/// The calibration constants the online path refits: the rates that
/// physically track the hardware (the same set `Calibration::scaled_for`
/// rescales across device generations). Structural constants (pressure
/// shape, message-size slope, framework bases) stay at their fitted
/// values.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum FitConstant {
    Fa3FwdFlops,
    Fa3BwdFlops,
    A2aEff0Bps,
    RingEffBps,
    FpdtStallPerToken,
    OtherRate,
}

impl FitConstant {
    pub const ALL: [FitConstant; 6] = [
        FitConstant::Fa3FwdFlops,
        FitConstant::Fa3BwdFlops,
        FitConstant::A2aEff0Bps,
        FitConstant::RingEffBps,
        FitConstant::FpdtStallPerToken,
        FitConstant::OtherRate,
    ];

    /// The `Calibration` field name (provenance / drift vectors use these).
    pub fn name(self) -> &'static str {
        match self {
            FitConstant::Fa3FwdFlops => "fa3_fwd_flops",
            FitConstant::Fa3BwdFlops => "fa3_bwd_flops",
            FitConstant::A2aEff0Bps => "a2a_eff0_bps",
            FitConstant::RingEffBps => "ring_eff_bps",
            FitConstant::FpdtStallPerToken => "fpdt_stall_per_token",
            FitConstant::OtherRate => "other_rate",
        }
    }

    pub fn get(self, c: &Calibration) -> f64 {
        match self {
            FitConstant::Fa3FwdFlops => c.fa3_fwd_flops,
            FitConstant::Fa3BwdFlops => c.fa3_bwd_flops,
            FitConstant::A2aEff0Bps => c.a2a_eff0_bps,
            FitConstant::RingEffBps => c.ring_eff_bps,
            FitConstant::FpdtStallPerToken => c.fpdt_stall_per_token,
            FitConstant::OtherRate => c.other_rate,
        }
    }

    pub fn set(self, c: &mut Calibration, v: f64) {
        match self {
            FitConstant::Fa3FwdFlops => c.fa3_fwd_flops = v,
            FitConstant::Fa3BwdFlops => c.fa3_bwd_flops = v,
            FitConstant::A2aEff0Bps => c.a2a_eff0_bps = v,
            FitConstant::RingEffBps => c.ring_eff_bps = v,
            FitConstant::FpdtStallPerToken => c.fpdt_stall_per_token = v,
            FitConstant::OtherRate => c.other_rate = v,
        }
    }
}

/// Ring per-step launch floors, mirrored from the pricing kernels
/// (`engine/timing.rs` / `engine/executor.rs` price
/// `steps * (alpha + bytes/bw)` with these alphas).
const RING_ALPHA_INTRA: f64 = 20e-6;
const RING_ALPHA_INTER: f64 = 60e-6;

const CAT_A2A: usize = 0;
const CAT_FWD: usize = 1;
const CAT_BWD: usize = 2;
const CAT_OTHER: usize = 3;

fn cat_idx(cat: Category) -> usize {
    match cat {
        Category::AllToAll => CAT_A2A,
        Category::Fa3Fwd => CAT_FWD,
        Category::Fa3Bwd => CAT_BWD,
        Category::Other => CAT_OTHER,
    }
}

/// Structural accumulator: everything the pricing kernels would charge,
/// grouped by what it divides by (a rate) versus what it adds (a floor).
#[derive(Debug, Clone, Default)]
pub struct StructSink {
    /// `a2a_eff` divides the per-op bytes by a message-size-degraded
    /// bandwidth, so bytes accumulate pre-weighted by `(1 + slope·s_M)`
    /// at the *base* slope (the slope itself is not refit online).
    msg_slope: f64,
    pub fwd_flops: f64,
    pub bwd_flops: f64,
    pub other_flops: f64,
    pub a2a_bytes: f64,
    pub a2a_weighted_bytes: f64,
    pub a2a_inter_bytes: f64,
    pub a2a_calls: u64,
    pub ring_bytes: f64,
    pub ring_inter_bytes: f64,
    pub ring_alpha_secs: f64,
    pub offload_main_bytes: f64,
    /// Per-category `Fixed` seconds (indexed by `cat_idx`).
    pub fixed: [f64; 4],
}

impl OpSink for StructSink {
    fn emit(&mut self, op: Op) {
        match op {
            Op::Compute { cat, flops } => match cat {
                Category::Fa3Fwd => self.fwd_flops += flops,
                Category::Fa3Bwd => self.bwd_flops += flops,
                _ => self.other_flops += flops,
            },
            Op::Fixed { cat, secs } => self.fixed[cat_idx(cat)] += secs,
            Op::AllToAll { bytes, intra, calls, s_tokens } => {
                if intra {
                    let s_m = s_tokens / (1024.0 * 1024.0);
                    self.a2a_bytes += bytes;
                    self.a2a_weighted_bytes += bytes * (1.0 + self.msg_slope * s_m);
                } else {
                    self.a2a_inter_bytes += bytes;
                }
                self.a2a_calls += calls;
            }
            Op::Ring { steps, bytes_per_step, inter } => {
                let alpha = if inter { RING_ALPHA_INTER } else { RING_ALPHA_INTRA };
                if inter {
                    self.ring_inter_bytes += steps as f64 * bytes_per_step;
                } else {
                    self.ring_bytes += steps as f64 * bytes_per_step;
                }
                self.ring_alpha_secs += steps as f64 * alpha;
            }
            Op::Offload { bytes, overlap } => {
                // Overlapped offload rides the offload stream — it shows
                // in step time, never in the Table-5 components telemetry
                // reports, so only the main-stream transfers matter here.
                if !overlap {
                    self.offload_main_bytes += bytes.abs();
                }
            }
            Op::Alloc { .. } | Op::Free { .. } | Op::Snapshot { .. } => {}
        }
    }
}

/// A method's structural quantities plus the sensitivity slopes of its
/// `Fixed` seconds with respect to the fitted constants.
#[derive(Debug, Clone)]
pub struct StructuralProfile {
    pub sink: StructSink,
    /// d(fixed Other secs) / d(`other_rate`) — the exact per-token unit
    /// count the schedule's bulk-"other" emission multiplies the rate by.
    pub other_rate_slope: f64,
    /// d(fixed Other secs) / d(`fpdt_stall_per_token`) — zero for
    /// non-FPDT methods.
    pub stall_slope: f64,
}

fn stream_struct(p: &RunPreset, calib: &Calibration) -> StructSink {
    let mut sink = StructSink { msg_slope: calib.a2a_msg_slope, ..StructSink::default() };
    stream_trace_with(p, calib, &mut sink);
    sink
}

/// Capture the structural profile of `p`'s schedule against `base`.
/// Streams the trace once at `base` and once per sensitivity slope with
/// the target constant doubled (the dependencies are linear — bulk
/// "other" is `fixed·L + rate·units`, the FPDT stall is
/// `per_token·tokens/(1+s_M/amortization)` — so one difference recovers
/// the exact slope).
pub fn capture_profile(p: &RunPreset, base: &Calibration) -> Result<StructuralProfile, String> {
    let s0 = stream_struct(p, base);
    if s0.a2a_inter_bytes > 0.0 || s0.ring_inter_bytes > 0.0 {
        return Err(format!(
            "{} telemetry crosses nodes; online inversion handles single-node records only",
            p.parallel.method.label()
        ));
    }
    let mut pr = base.clone();
    pr.other_rate *= 2.0;
    let s1 = stream_struct(p, &pr);
    let other_rate_slope = (s1.fixed[CAT_OTHER] - s0.fixed[CAT_OTHER]) / base.other_rate;
    let stall_slope = match p.parallel.method {
        CpMethod::Fpdt { .. } | CpMethod::UpipeFpdt { .. } => {
            let mut ps = base.clone();
            ps.fpdt_stall_per_token *= 2.0;
            let s2 = stream_struct(p, &ps);
            (s2.fixed[CAT_OTHER] - s0.fixed[CAT_OTHER]) / base.fpdt_stall_per_token
        }
        _ => 0.0,
    };
    Ok(StructuralProfile { sink: s0, other_rate_slope, stall_slope })
}

fn positive(rate: f64) -> Option<f64> {
    (rate.is_finite() && rate > 0.0).then_some(rate)
}

/// Invert one observation's component times into fitted-rate samples.
///
/// `base` is the active calibration (its values price the floors being
/// subtracted); `est` looks up the calibrator's current running estimate
/// for a constant (falling back to `base` when none exists yet) — the
/// "other" inversion needs the attention-forward and `other_rate`
/// estimates to strip cross-constant terms.
///
/// Returns the `(constant, rate)` samples plus human-readable skip notes
/// for components that sat at or below their modelled floors.
pub fn invert_observation(
    profile: &StructuralProfile,
    base: &Calibration,
    est: impl Fn(FitConstant) -> f64,
    obs: &Observation,
) -> (Vec<(FitConstant, f64)>, Vec<String>) {
    let s = &profile.sink;
    let mut out = Vec::new();
    let mut skips = Vec::new();
    let skip = |component: &str| {
        format!(
            "{} {}@{}: `{}` at or below the modelled overhead floor",
            obs.label,
            obs.model.name,
            crate::util::fmt::tokens(obs.seq),
            component
        )
    };
    // Pressured samples de-penalize with the base pressure model before
    // inversion, so memory-pressure stalls don't corrupt the clean rates.
    let headroom = obs.headroom_gib.map(|h| h * GIB);
    let compute_pen = headroom.map_or(1.0, |h| base.compute_penalty(h));
    let comm_pen = headroom.map_or(1.0, |h| base.comm_penalty(h));

    if let Some(t) = obs.attn_fwd {
        let net = t / compute_pen - s.fixed[CAT_FWD];
        match (net > 0.0, positive(s.fwd_flops / net)) {
            (true, Some(r)) => out.push((FitConstant::Fa3FwdFlops, r)),
            _ => skips.push(skip("attn_fwd")),
        }
    }
    if let Some(t) = obs.attn_bwd {
        let net = t - s.fixed[CAT_BWD];
        match (net > 0.0, positive(s.bwd_flops / net)) {
            (true, Some(r)) => out.push((FitConstant::Fa3BwdFlops, r)),
            _ => skips.push(skip("attn_bwd")),
        }
    }
    if let Some(t) = obs.all_to_all {
        let net = t / comm_pen
            - s.fixed[CAT_A2A]
            - s.ring_alpha_secs
            - s.a2a_calls as f64 * base.a2a_call_overhead;
        if s.a2a_bytes > 0.0 && s.ring_bytes == 0.0 {
            match (net > 0.0, positive(s.a2a_weighted_bytes / net)) {
                (true, Some(r)) => out.push((FitConstant::A2aEff0Bps, r)),
                _ => skips.push(skip("all_to_all")),
            }
        } else if s.ring_bytes > 0.0 && s.a2a_bytes == 0.0 {
            match (net > 0.0, positive(s.ring_bytes / net)) {
                (true, Some(r)) => out.push((FitConstant::RingEffBps, r)),
                _ => skips.push(skip("all_to_all")),
            }
        } else {
            skips.push(format!(
                "{} {}@{}: `all_to_all` mixes a2a and ring volume; not invertible",
                obs.label,
                obs.model.name,
                crate::util::fmt::tokens(obs.seq)
            ));
        }
    }
    if let Some(t) = obs.other {
        // The calibration-independent floor: measured Fixed seconds minus
        // the parts the fitted constants contributed at their base values.
        let floor = s.fixed[CAT_OTHER]
            - profile.other_rate_slope * base.other_rate
            - profile.stall_slope * base.fpdt_stall_per_token;
        let pre = s.other_flops / est(FitConstant::Fa3FwdFlops)
            + s.offload_main_bytes / base.pcie_eff_bps
            + floor;
        if profile.stall_slope > 0.0 {
            // FPDT: `other` observations target the stall constant, using
            // the running `other_rate` estimate for the bulk term.
            let net = t - pre - profile.other_rate_slope * est(FitConstant::OtherRate);
            match (net > 0.0, positive(net / profile.stall_slope)) {
                (true, Some(r)) => out.push((FitConstant::FpdtStallPerToken, r)),
                _ => skips.push(skip("other")),
            }
        } else if profile.other_rate_slope > 0.0 {
            let net = t - pre;
            match (net > 0.0, positive(net / profile.other_rate_slope)) {
                (true, Some(r)) => out.push((FitConstant::OtherRate, r)),
                _ => skips.push(skip("other")),
            }
        } else {
            skips.push(skip("other"));
        }
    }
    (out, skips)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::llama_single_node;
    use crate::engine::TimingKernel;
    use crate::model::ModelDims;

    fn obs(method: &str, seq: u64) -> Observation {
        let j = crate::util::json::Json::parse(&format!(
            r#"{{"method": "{method}", "model": "llama3-8b", "gpus": 8, "seq": {seq}}}"#
        ))
        .unwrap();
        Observation::from_json(&j).unwrap()
    }

    /// The structural profile of the Ulysses schedule must match the
    /// closed forms `engine/refit.rs` inverts with (the same quantities
    /// its trace-pinning test asserts).
    #[test]
    fn ulysses_profile_matches_refit_closed_forms() {
        let s = 1u64 << 20;
        let base = Calibration::default();
        let o = obs("ulysses", s);
        let p = capture_profile(&o.preset(), &base).unwrap();
        let dims = ModelDims::llama3_8b();
        let (l, c) = (dims.n_layers as f64, 8.0);

        let f_layer = crate::model::flops::attn_fwd(&dims, s) / (l * c);
        assert!((p.sink.fwd_flops - 2.0 * l * f_layer).abs() / p.sink.fwd_flops < 1e-9);
        assert!(p.sink.bwd_flops > p.sink.fwd_flops, "bwd factor > 2x fwd passes");

        let q_b = 2.0 * (s as f64 / c) * dims.q_width() as f64;
        let kv_b = 2.0 * (s as f64 / c) * dims.kv_width() as f64;
        let vol = 2.0 * l * (q_b + 2.0 * kv_b + q_b) * (c - 1.0) / c;
        let s_m = s as f64 / (1024.0 * 1024.0);
        let weighted = vol * (1.0 + base.a2a_msg_slope * s_m);
        assert!((p.sink.a2a_weighted_bytes - weighted).abs() / weighted < 1e-9);
        assert_eq!(p.sink.a2a_calls, 8 * dims.n_layers);
        assert_eq!(p.sink.ring_bytes, 0.0);

        // Bulk "other": fixed·L floor + rate·(S·d_model·L/C) slope.
        let units = s as f64 * dims.d_model as f64 * l / c;
        assert!((p.other_rate_slope - units).abs() / units < 1e-9);
        let floor = p.sink.fixed[CAT_OTHER] - p.other_rate_slope * base.other_rate;
        let expect_floor = base.other_fixed_per_layer * l;
        assert!((floor - expect_floor).abs() / expect_floor < 1e-9);
        assert_eq!(p.stall_slope, 0.0);
    }

    /// End-to-end inversion roundtrip: price a method's trace under a
    /// perturbed "true" calibration, feed the component times back as an
    /// observation, and require the inverted rates to recover the true
    /// constants (the profile was captured at the *default* calibration —
    /// the floors subtract exactly).
    #[test]
    fn inversion_roundtrips_per_method() {
        let base = Calibration::default();
        let mut truth = base.clone();
        truth.fa3_fwd_flops *= 0.93;
        truth.fa3_bwd_flops *= 1.07;
        truth.a2a_eff0_bps *= 1.11;
        truth.ring_eff_bps *= 0.89;
        truth.fpdt_stall_per_token *= 1.23;
        truth.other_rate *= 1.17;

        for method in ["ulysses", "upipe", "ring", "fpdt"] {
            let mut o = obs(method, 1 << 20);
            let preset = o.preset();
            // Price the schedule under the true calibration with
            // effectively unlimited HBM: unpressured components, exactly
            // what clean telemetry reports.
            let mut kernel = TimingKernel::new(truth.clone(), 1e18, 0.0, f64::INFINITY);
            stream_trace_with(&preset, &truth, &mut kernel);
            let report = kernel.finish();
            assert!(report.failed.is_none() && !report.oom, "{method}");
            o.attn_fwd = Some(report.components.fa3_fwd);
            o.attn_bwd = Some(report.components.fa3_bwd);
            o.all_to_all = Some(report.components.all_to_all);
            o.other = Some(report.components.other);

            let profile = capture_profile(&preset, &base).unwrap();
            // The cross-constant estimates the "other" inversion consumes
            // are exact here (as they are online once those constants have
            // been observed).
            let (samples, skips) =
                invert_observation(&profile, &base, |c| c.get(&truth), &o);
            assert!(skips.is_empty(), "{method}: {skips:?}");
            assert!(samples.len() >= 3, "{method}: {samples:?}");
            for (c, rate) in samples {
                let want = c.get(&truth);
                let rel = (rate - want).abs() / want;
                assert!(rel < 1e-6, "{method} {}: {rate} vs {want} (rel {rel:.2e})", c.name());
            }
        }
    }

    /// A time at or below the overhead floor must skip, not produce a
    /// garbage (negative/infinite) rate.
    #[test]
    fn floor_times_skip_instead_of_inverting() {
        let base = Calibration::default();
        let mut o = obs("ulysses", 1 << 20);
        let profile = capture_profile(&o.preset(), &base).unwrap();
        // Below the 8L call-overhead floor.
        o.all_to_all = Some(0.5 * 8.0 * 32.0 * base.a2a_call_overhead);
        // Below the fixed·L floor.
        o.other = Some(0.5 * base.other_fixed_per_layer * 32.0);
        let (samples, skips) = invert_observation(&profile, &base, |c| c.get(&base), &o);
        assert!(samples.is_empty(), "{samples:?}");
        assert_eq!(skips.len(), 2, "{skips:?}");
        assert!(skips[0].contains("overhead floor"));
    }

    /// Pressured telemetry de-penalizes with the base pressure model: a
    /// sample tagged with low headroom inverts to the same rate as the
    /// unpressured sample whose time is `penalty`× smaller.
    #[test]
    fn headroom_tag_depenalizes_before_inversion() {
        let base = Calibration::default();
        let o_clean = {
            let mut o = obs("ulysses", 1 << 20);
            o.all_to_all = Some(4.0);
            o.attn_fwd = Some(80.0);
            o
        };
        let headroom_gib = 2.0;
        let o_pressured = {
            let mut o = o_clean.clone();
            o.headroom_gib = Some(headroom_gib);
            o.all_to_all = Some(4.0 * base.comm_penalty(headroom_gib * GIB));
            o.attn_fwd = Some(80.0 * base.compute_penalty(headroom_gib * GIB));
            o
        };
        let profile = capture_profile(&o_clean.preset(), &base).unwrap();
        let est = |c: FitConstant| c.get(&base);
        let (clean, _) = invert_observation(&profile, &base, est, &o_clean);
        let (pressured, _) = invert_observation(&profile, &base, est, &o_pressured);
        assert_eq!(clean.len(), 2);
        for ((ca, ra), (cb, rb)) in clean.iter().zip(pressured.iter()) {
            assert_eq!(ca, cb);
            assert!((ra - rb).abs() / ra < 1e-12, "{}: {ra} vs {rb}", ca.name());
        }
    }

    #[test]
    fn two_node_profiles_are_rejected() {
        let base = Calibration::default();
        let p = crate::config::presets::llama_two_node(CpMethod::Ulysses, 1 << 20);
        let err = capture_profile(&p, &base).unwrap_err();
        assert!(err.contains("single-node"), "{err}");
        // And the single-node path stays fine for the same method.
        assert!(capture_profile(&llama_single_node(CpMethod::Ulysses, 1 << 20), &base).is_ok());
    }
}
