//! Telemetry ingestion: strict per-method measurement records, bounded
//! per-method ring buffers and a MAD outlier gate.
//!
//! A record is one measured training step of one method, reporting the
//! paper's Table-5 component times (seconds). Field whitelist and
//! unknown-field rejection follow the `service/wire.rs` contract: a
//! misspelled field is an error, never silently ignored.
//!
//! ```json
//! {"method": "upipe", "model": "llama3-8b", "gpus": 8, "seq": "1M",
//!  "all_to_all": 4.93, "attn_fwd": 103.0, "attn_bwd": 150.9, "other": 70.1}
//! ```
//!
//! - `method`: `ulysses` | `upipe` | `ring` | `fpdt`. UPipe takes an
//!   optional `u` (head-chunk size, default 8); FPDT an optional `pi`
//!   (sequence chunks, default 16). For `ring`, `all_to_all` carries the
//!   ring-exchange time (the same Table-5 comm column).
//! - `seq`: token count or label (`"1M"`), per-device measurement at
//!   CP = `gpus` on one NVLink node (`gpus` ≤ 8, dividing the model's
//!   heads — the same constraint `--refit` enforces).
//! - `headroom_gib` (optional): HBM headroom the step ran under; when
//!   present, comm/compute components are de-penalized with the active
//!   pressure model before rate inversion.
//!
//! Component times are each optional (a record reporting nothing simply
//! contributes no rate samples), but every time present must be a finite
//! positive number.

use std::collections::{BTreeMap, VecDeque};

use super::invert::FitConstant;
use crate::config::cluster::ClusterConfig;
use crate::config::presets::RunPreset;
use crate::config::{CpMethod, ParallelConfig};
use crate::model::ModelDims;
use crate::util::json::Json;

/// Whitelisted observation fields (anything else is an error).
pub const OBSERVATION_FIELDS: [&str; 11] = [
    "method",
    "model",
    "gpus",
    "seq",
    "all_to_all",
    "attn_fwd",
    "attn_bwd",
    "other",
    "headroom_gib",
    "u",
    "pi",
];

/// One parsed, validated measurement record.
#[derive(Debug, Clone)]
pub struct Observation {
    pub method: CpMethod,
    /// Ring-buffer key: the method family, ignoring its parameters.
    pub label: &'static str,
    pub model: ModelDims,
    pub gpus: u64,
    pub seq: u64,
    pub all_to_all: Option<f64>,
    pub attn_fwd: Option<f64>,
    pub attn_bwd: Option<f64>,
    pub other: Option<f64>,
    pub headroom_gib: Option<f64>,
}

fn opt_time(j: &Json, key: &str) -> Result<Option<f64>, String> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => {
            let t = v
                .as_f64()
                .ok_or_else(|| format!("field `{key}` must be a number (seconds)"))?;
            if !t.is_finite() || t <= 0.0 {
                return Err(format!("field `{key}` must be a positive finite time, got {t}"));
            }
            Ok(Some(t))
        }
    }
}

fn opt_u32(j: &Json, key: &str, default: u32) -> Result<u32, String> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => {
            let n = v
                .as_u64()
                .filter(|&n| n >= 1 && n <= u32::MAX as u64)
                .ok_or_else(|| format!("field `{key}` must be a whole number >= 1"))?;
            Ok(n as u32)
        }
    }
}

impl Observation {
    /// Strict parse of one record (see the module docs for the format).
    pub fn from_json(j: &Json) -> Result<Observation, String> {
        let Json::Obj(pairs) = j else {
            return Err("observation must be an object".into());
        };
        for (k, _) in pairs {
            if !OBSERVATION_FIELDS.contains(&k.as_str()) {
                return Err(format!("unknown observation field `{k}`"));
            }
        }
        let label = j
            .get("method")
            .and_then(Json::as_str)
            .ok_or("observation needs a `method` string")?;
        let method = match label {
            "ulysses" => CpMethod::Ulysses,
            "upipe" => CpMethod::Upipe { u: opt_u32(j, "u", 8)?, gqa_schedule: true },
            "ring" => CpMethod::Ring,
            "fpdt" => CpMethod::Fpdt { pi: opt_u32(j, "pi", 16)? },
            other => {
                return Err(format!(
                    "unknown method `{other}` (expected ulysses, upipe, ring or fpdt)"
                ))
            }
        };
        if !matches!(method, CpMethod::Upipe { .. }) && j.get("u").is_some() {
            return Err("field `u` only applies to method `upipe`".into());
        }
        if !matches!(method, CpMethod::Fpdt { .. }) && j.get("pi").is_some() {
            return Err("field `pi` only applies to method `fpdt`".into());
        }
        let model_name = j
            .get("model")
            .and_then(Json::as_str)
            .ok_or("observation needs a `model` string")?;
        let model = ModelDims::by_name(model_name)
            .ok_or_else(|| format!("unknown model `{model_name}`"))?;
        let gpus = j
            .get("gpus")
            .and_then(Json::as_u64)
            .filter(|&g| g >= 1)
            .ok_or("observation needs a whole `gpus` >= 1")?;
        if gpus > 8 {
            return Err(format!(
                "telemetry records are single-node: gpus = {gpus} exceeds one NVLink node (8)"
            ));
        }
        let seq = match j.get("seq") {
            Some(Json::Str(s)) => crate::util::fmt::parse_tokens(s)
                .ok_or_else(|| format!("bad `seq` label `{s}`"))?,
            Some(v) => v.as_u64().ok_or("field `seq` must be a token count or label")?,
            None => return Err("observation needs a `seq`".into()),
        };
        if seq == 0 {
            return Err("field `seq` must be >= 1 token".into());
        }
        let headroom_gib = match j.get("headroom_gib") {
            None | Some(Json::Null) => None,
            Some(v) => {
                let h = v
                    .as_f64()
                    .filter(|h| h.is_finite() && *h >= 0.0)
                    .ok_or("field `headroom_gib` must be a non-negative number")?;
                Some(h)
            }
        };
        let obs = Observation {
            method,
            label: canonical_label(method),
            model,
            gpus,
            seq,
            all_to_all: opt_time(j, "all_to_all")?,
            attn_fwd: opt_time(j, "attn_fwd")?,
            attn_bwd: opt_time(j, "attn_bwd")?,
            other: opt_time(j, "other")?,
            headroom_gib,
        };
        if obs.model.n_heads % obs.gpus != 0 {
            return Err(format!(
                "invalid layout for {} telemetry: C={} must divide H={} (heads shard across ranks)",
                obs.label, obs.gpus, obs.model.n_heads
            ));
        }
        obs.preset()
            .parallel
            .validate_model(&obs.model)
            .map_err(|e| format!("invalid layout for {} telemetry: {e}", obs.label))?;
        Ok(obs)
    }

    /// The run shape this record measured: CP = `gpus` on one NVLink node,
    /// paper-default AC/offload knobs — the same shape `--refit` inverts
    /// its anchor under.
    pub fn preset(&self) -> RunPreset {
        RunPreset {
            model: self.model.clone(),
            cluster: ClusterConfig::h100_gpus(self.gpus).expect("gpus validated <= 8"),
            parallel: ParallelConfig::new(self.method, self.gpus),
            seq_len: self.seq,
        }
    }

    /// Profile cache key: everything the structural profile depends on.
    pub fn profile_key(&self) -> (&'static str, u32, &'static str, u64, u64) {
        let param = match self.method {
            CpMethod::Upipe { u, .. } => u,
            CpMethod::Fpdt { pi } => pi,
            _ => 0,
        };
        (self.label, param, self.model.name, self.gpus, self.seq)
    }
}

fn canonical_label(method: CpMethod) -> &'static str {
    match method {
        CpMethod::Ulysses => "ulysses",
        CpMethod::Upipe { .. } => "upipe",
        CpMethod::Ring => "ring",
        CpMethod::Fpdt { .. } => "fpdt",
        // Unreachable from the wire (parse only admits the four above).
        other => other.label(),
    }
}

/// Buffers fill to this depth before the MAD gate arms — gating against
/// fewer samples would reject on noise.
pub const MAD_WARMUP: usize = 8;

/// MAD floor as a fraction of the median: with a degenerate spread
/// (identical repeated samples, MAD = 0) a genuinely drifted rate must
/// still be admittable, so the gate never cuts tighter than
/// `mad_k × 5%` of the median.
const MAD_FLOOR_REL: f64 = 0.05;

/// Bounded per-method ring buffers of accepted rate samples, keyed by
/// `(method family, fitted constant)`, plus the MAD admission gate.
/// `BTreeMap` keeps iteration (and therefore every derived report)
/// deterministic.
#[derive(Debug, Clone)]
pub struct TelemetryStore {
    capacity: usize,
    mad_k: f64,
    buffers: BTreeMap<(&'static str, FitConstant), VecDeque<f64>>,
}

impl TelemetryStore {
    pub fn new(capacity: usize, mad_k: f64) -> Self {
        TelemetryStore { capacity: capacity.max(1), mad_k, buffers: BTreeMap::new() }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Admit one inverted rate sample. `Err` names the gate that rejected
    /// it; `Ok` means the sample entered the ring buffer (evicting the
    /// oldest entry once the buffer is at capacity).
    pub fn admit(
        &mut self,
        method: &'static str,
        constant: FitConstant,
        rate: f64,
    ) -> Result<(), String> {
        let buf = self.buffers.entry((method, constant)).or_default();
        if buf.len() >= MAD_WARMUP {
            let mut v: Vec<f64> = buf.iter().copied().collect();
            v.sort_by(f64::total_cmp);
            let med = median_sorted(&v);
            let mut dev: Vec<f64> = v.iter().map(|x| (x - med).abs()).collect();
            dev.sort_by(f64::total_cmp);
            let mad = median_sorted(&dev);
            let scale = (1.4826 * mad).max(MAD_FLOOR_REL * med.abs());
            if (rate - med).abs() > self.mad_k * scale {
                return Err(format!(
                    "MAD outlier for {method}/{}: {rate:.4e} vs median {med:.4e}",
                    constant.name()
                ));
            }
        }
        if buf.len() == self.capacity {
            buf.pop_front();
        }
        buf.push_back(rate);
        Ok(())
    }

    /// Buffered sample count for one `(method, constant)` stream.
    pub fn len(&self, method: &'static str, constant: FitConstant) -> usize {
        self.buffers.get(&(method, constant)).map_or(0, VecDeque::len)
    }

    pub fn is_empty(&self) -> bool {
        self.buffers.values().all(VecDeque::is_empty)
    }

    /// Total buffered samples per method family, deterministic order.
    pub fn method_counts(&self) -> Vec<(&'static str, u64)> {
        let mut out: Vec<(&'static str, u64)> = Vec::new();
        for ((method, _), buf) in &self.buffers {
            match out.last_mut() {
                Some((m, n)) if *m == *method => *n += buf.len() as u64,
                _ => out.push((method, buf.len() as u64)),
            }
        }
        out
    }
}

fn median_sorted(v: &[f64]) -> f64 {
    let n = v.len();
    if n == 0 {
        return 0.0;
    }
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> Result<Observation, String> {
        Observation::from_json(&Json::parse(text).unwrap())
    }

    #[test]
    fn parses_a_full_record() {
        let o = parse(
            r#"{"method": "upipe", "model": "llama3-8b", "gpus": 8, "seq": "1M",
                "all_to_all": 4.93, "attn_fwd": 103.0, "attn_bwd": 150.9, "other": 70.1}"#,
        )
        .unwrap();
        assert_eq!(o.label, "upipe");
        assert_eq!(o.method, CpMethod::Upipe { u: 8, gqa_schedule: true });
        assert_eq!(o.seq, 1 << 20);
        assert_eq!(o.all_to_all, Some(4.93));
        assert_eq!(o.headroom_gib, None);
        assert_eq!(o.preset().parallel.cp_degree, 8);
    }

    #[test]
    fn rejects_unknown_fields_and_bad_values() {
        for (bad, needle) in [
            (r#"{"method": "upipe", "model": "llama3-8b", "gpus": 8, "seq": "1M", "oops": 1, "other": 1.0}"#, "unknown observation field"),
            (r#"{"method": "warp", "model": "llama3-8b", "gpus": 8, "seq": "1M", "other": 1.0}"#, "unknown method"),
            (r#"{"method": "ulysses", "model": "gpt-9", "gpus": 8, "seq": "1M", "other": 1.0}"#, "unknown model"),
            (r#"{"method": "ulysses", "model": "llama3-8b", "gpus": 16, "seq": "1M", "other": 1.0}"#, "single-node"),
            (r#"{"method": "ulysses", "model": "llama3-8b", "gpus": 3, "seq": "1M", "other": 1.0}"#, "invalid layout"),
            (r#"{"method": "ulysses", "model": "llama3-8b", "gpus": 8, "seq": "1M", "other": -2.0}"#, "positive finite"),
            (r#"{"method": "ulysses", "model": "llama3-8b", "gpus": 8, "seq": "huge", "other": 1.0}"#, "bad `seq`"),
            (r#"{"method": "ulysses", "model": "llama3-8b", "gpus": 8, "seq": "1M", "u": 4, "other": 1.0}"#, "only applies to method `upipe`"),
            (r#"{"method": "ring", "model": "llama3-8b", "gpus": 8, "seq": "1M", "pi": 4, "other": 1.0}"#, "only applies to method `fpdt`"),
        ] {
            let err = parse(bad).unwrap_err();
            assert!(err.contains(needle), "`{needle}` not in `{err}`");
        }
    }

    #[test]
    fn upipe_chunk_size_is_validated() {
        // u = 6 does not satisfy U % C == 0 for C = 8.
        let err = parse(
            r#"{"method": "upipe", "model": "llama3-8b", "gpus": 8, "seq": "1M", "u": 6, "other": 1.0}"#,
        )
        .unwrap_err();
        assert!(err.contains("invalid layout"), "{err}");
    }

    #[test]
    fn ring_buffer_is_bounded() {
        let mut store = TelemetryStore::new(4, 4.0);
        for i in 0..100 {
            // Slow ramp: every sample within the gate of its neighbours.
            store.admit("ulysses", FitConstant::OtherRate, 1.0 + i as f64 * 1e-3).unwrap();
            assert!(store.len("ulysses", FitConstant::OtherRate) <= 4);
        }
        assert_eq!(store.len("ulysses", FitConstant::OtherRate), 4);
        assert_eq!(store.method_counts(), vec![("ulysses", 4)]);
    }

    #[test]
    fn mad_gate_rejects_outliers_after_warmup() {
        let mut store = TelemetryStore::new(64, 4.0);
        // Warmup: everything admits, even a wild value.
        store.admit("upipe", FitConstant::A2aEff0Bps, 500.0).unwrap();
        for _ in 0..MAD_WARMUP {
            store.admit("upipe", FitConstant::A2aEff0Bps, 50.0).unwrap();
        }
        // Armed: a 10x outlier rejects…
        let err = store.admit("upipe", FitConstant::A2aEff0Bps, 500.0).unwrap_err();
        assert!(err.contains("MAD outlier"), "{err}");
        // …an identical repeat and a modest drift both admit (MAD floor).
        store.admit("upipe", FitConstant::A2aEff0Bps, 50.0).unwrap();
        store.admit("upipe", FitConstant::A2aEff0Bps, 55.0).unwrap();
        // Streams are independent per (method, constant).
        store.admit("upipe", FitConstant::OtherRate, 500.0).unwrap();
    }
}
