//! The online calibrator: fold accepted telemetry into exponentially-
//! weighted rate estimates, track drift against the active
//! [`Calibration`], and publish a new epoch when drift crosses the
//! threshold.
//!
//! Determinism: ingestion is strictly in record order, estimates are
//! plain f64 folds, epoch ids are sequence numbers, and every map is a
//! `BTreeMap` — replaying the same telemetry against a fresh calibrator
//! reproduces the exact epoch chain, byte for byte.

use std::collections::BTreeMap;

use super::epoch::{CalibrationSnapshot, DriftEntry, EpochField, EpochRecord};
use super::invert::{capture_profile, invert_observation, FitConstant, StructuralProfile};
use super::telemetry::{Observation, TelemetryStore};
use crate::engine::Calibration;

/// Knobs for the online refit loop. The defaults publish conservatively:
/// a constant must have at least [`Self::min_count`] accepted samples
/// *and* its EW estimate must sit ≥ [`Self::drift_threshold`] (relative)
/// away from the active value before an epoch goes out.
#[derive(Debug, Clone)]
pub struct OnlineConfig {
    /// Relative drift that triggers an epoch publish (default 5%).
    pub drift_threshold: f64,
    /// EW fold weight for each new accepted sample (default 0.25).
    pub ew_alpha: f64,
    /// Ring-buffer depth per (method, constant) stream (default 64).
    pub buffer_capacity: usize,
    /// MAD gate width in robust standard deviations (default 4).
    pub mad_k: f64,
    /// Accepted samples a constant needs before it may publish (default 4).
    pub min_count: u64,
}

impl Default for OnlineConfig {
    fn default() -> Self {
        OnlineConfig {
            drift_threshold: 0.05,
            ew_alpha: 0.25,
            buffer_capacity: 64,
            mad_k: 4.0,
            min_count: 4,
        }
    }
}

/// A freshly published epoch, returned to the caller so the service can
/// invalidate the stale fingerprint's memo entries.
#[derive(Debug, Clone)]
pub struct PublishedEpoch {
    pub epoch: u64,
    pub old_fingerprint: u64,
    pub new_fingerprint: u64,
    pub fields: Vec<EpochField>,
}

/// Result of one `ingest` call (one `/v1/observe` batch or one telemetry
/// file line in the CLI).
#[derive(Debug, Clone)]
pub struct IngestReport {
    /// Records that contributed at least one admitted rate sample.
    pub accepted: u64,
    /// Records that contributed none (floor skips, MAD rejections, or no
    /// invertible component).
    pub rejected: u64,
    /// Post-ingest drift vector (constants with at least one sample).
    pub drift: Vec<DriftEntry>,
    /// Set when this batch pushed some constant across the threshold.
    pub published: Option<PublishedEpoch>,
    /// Bounded reject/skip diagnostics (first [`IngestReport::MAX_NOTES`]).
    pub notes: Vec<String>,
}

impl IngestReport {
    pub const MAX_NOTES: usize = 8;
}

/// Provenance history depth kept for `/v1/calibration` (older epochs
/// fall off the front; the epoch counter itself never resets).
const MAX_HISTORY: usize = 16;

/// Structural profiles cached per run shape (label, method param, model,
/// gpus, seq). Capped; profiles are cheap to rebuild but not free (two
/// trace streams each).
const MAX_PROFILES: usize = 64;

type ProfileKey = (&'static str, u32, &'static str, u64, u64);

/// Live calibration state: the active constants, the telemetry buffers,
/// the EW estimates and the epoch provenance chain.
#[derive(Debug)]
pub struct OnlineCalibrator {
    config: OnlineConfig,
    active: Calibration,
    epoch: u64,
    store: TelemetryStore,
    /// EW estimate and accepted-sample count per fitted constant.
    estimates: BTreeMap<FitConstant, (f64, u64)>,
    /// Structural profiles are captured against `active` (their fixed
    /// floors embed its values), so this cache clears on every publish.
    profiles: BTreeMap<ProfileKey, StructuralProfile>,
    history: Vec<EpochRecord>,
}

impl OnlineCalibrator {
    pub fn new(active: Calibration, config: OnlineConfig) -> Self {
        let store = TelemetryStore::new(config.buffer_capacity, config.mad_k);
        OnlineCalibrator {
            config,
            active,
            epoch: 0,
            store,
            estimates: BTreeMap::new(),
            profiles: BTreeMap::new(),
            history: Vec::new(),
        }
    }

    pub fn active(&self) -> &Calibration {
        &self.active
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn fingerprint(&self) -> u64 {
        self.active.fingerprint()
    }

    pub fn store(&self) -> &TelemetryStore {
        &self.store
    }

    /// Ingest a batch of observations in order: invert each against the
    /// active calibration, gate the rate samples, fold survivors into the
    /// EW estimates, then publish an epoch if drift crossed the threshold.
    pub fn ingest(&mut self, observations: &[Observation]) -> IngestReport {
        let mut accepted = 0u64;
        let mut rejected = 0u64;
        let mut notes: Vec<String> = Vec::new();
        let mut note = |notes: &mut Vec<String>, n: String| {
            if notes.len() < IngestReport::MAX_NOTES {
                notes.push(n);
            }
        };
        for obs in observations {
            let key = obs.profile_key();
            if !self.profiles.contains_key(&key) {
                match capture_profile(&obs.preset(), &self.active) {
                    Ok(p) => {
                        if self.profiles.len() >= MAX_PROFILES {
                            self.profiles.pop_first();
                        }
                        self.profiles.insert(key, p);
                    }
                    Err(e) => {
                        rejected += 1;
                        note(&mut notes, format!("{} {}: {e}", obs.label, obs.model.name));
                        continue;
                    }
                }
            }
            let profile = &self.profiles[&key];
            // Estimates-so-far snapshot: the inversion of `other` needs the
            // current fa3_fwd / other_rate estimates, falling back to the
            // active calibration for constants with no samples yet.
            let est_now = self.estimates.clone();
            let active = self.active.clone();
            let est = |c: FitConstant| est_now.get(&c).map_or(c.get(&active), |(v, _)| *v);
            let (samples, skips) = invert_observation(profile, &self.active, est, obs);
            for s in skips {
                note(&mut notes, s);
            }
            let mut admitted = 0u64;
            for (constant, rate) in samples {
                match self.store.admit(obs.label, constant, rate) {
                    Ok(()) => {
                        admitted += 1;
                        let slot = self.estimates.entry(constant).or_insert((rate, 0));
                        if slot.1 > 0 {
                            slot.0 = self.config.ew_alpha * rate
                                + (1.0 - self.config.ew_alpha) * slot.0;
                        }
                        slot.1 += 1;
                    }
                    Err(e) => note(&mut notes, e),
                }
            }
            if admitted > 0 {
                accepted += 1;
            } else {
                rejected += 1;
            }
        }
        let published = self.maybe_publish();
        IngestReport { accepted, rejected, drift: self.drift(), published, notes }
    }

    /// Current drift vector: one entry per constant with accepted samples,
    /// in `FitConstant::ALL` order.
    pub fn drift(&self) -> Vec<DriftEntry> {
        FitConstant::ALL
            .iter()
            .filter_map(|&c| {
                let &(estimate, count) = self.estimates.get(&c)?;
                let active = c.get(&self.active);
                Some(DriftEntry {
                    constant: c,
                    active,
                    estimate,
                    rel_drift: (estimate - active).abs() / active.abs().max(f64::MIN_POSITIVE),
                    observations: count,
                })
            })
            .collect()
    }

    /// Publish when any sufficiently-observed constant drifted past the
    /// threshold. The new calibration adopts the EW estimate of *every*
    /// constant with `min_count` samples (not only the trigger), so the
    /// drift vector collapses to ~0 and the same telemetry cannot
    /// republish; structural constants are untouched.
    fn maybe_publish(&mut self) -> Option<PublishedEpoch> {
        let trigger = self.drift().iter().any(|d| {
            d.observations >= self.config.min_count && d.rel_drift >= self.config.drift_threshold
        });
        if !trigger {
            return None;
        }
        let old_fingerprint = self.active.fingerprint();
        let mut next = self.active.clone();
        let mut fields = Vec::new();
        for &c in &FitConstant::ALL {
            if let Some(&(estimate, count)) = self.estimates.get(&c) {
                let old = c.get(&self.active);
                if count >= self.config.min_count && estimate != old {
                    c.set(&mut next, estimate);
                    fields.push(EpochField { constant: c, old, new: estimate, observations: count });
                }
            }
        }
        if fields.is_empty() {
            return None;
        }
        self.epoch += 1;
        let new_fingerprint = next.fingerprint();
        self.active = next;
        // Profiles embed the replaced calibration's floors; rebuild lazily.
        self.profiles.clear();
        let record = EpochRecord {
            epoch: self.epoch,
            old_fingerprint,
            new_fingerprint,
            fields: fields.clone(),
        };
        self.history.push(record);
        if self.history.len() > MAX_HISTORY {
            self.history.remove(0);
        }
        Some(PublishedEpoch { epoch: self.epoch, old_fingerprint, new_fingerprint, fields })
    }

    /// The `/v1/calibration` snapshot: active epoch + constants, live
    /// drift, provenance chain.
    pub fn snapshot(&self) -> CalibrationSnapshot {
        CalibrationSnapshot::capture(self.epoch, &self.active, self.drift(), &self.history)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::TimingKernel;
    use crate::schedule::stream_trace_with;
    use crate::util::json::Json;

    /// Parse a telemetry record, then fill its component times from the
    /// step a `truth` calibration actually prices for that run shape.
    fn measured(line: &str, truth: &Calibration) -> Observation {
        let mut o = Observation::from_json(&Json::parse(line).unwrap()).unwrap();
        let mut kernel = TimingKernel::new(truth.clone(), 1e18, 0.0, f64::INFINITY);
        stream_trace_with(&o.preset(), truth, &mut kernel);
        let r = kernel.finish();
        assert!(r.failed.is_none() && !r.oom);
        o.attn_fwd = Some(r.components.fa3_fwd);
        o.attn_bwd = Some(r.components.fa3_bwd);
        o.all_to_all = Some(r.components.all_to_all);
        o.other = Some(r.components.other);
        o
    }

    fn drifted_truth() -> Calibration {
        let mut t = Calibration::default();
        t.fa3_fwd_flops *= 0.9;
        t.fa3_bwd_flops *= 1.1;
        t.a2a_eff0_bps *= 0.85;
        t.other_rate *= 1.2;
        t
    }

    const LINES: [&str; 3] = [
        r#"{"method": "ulysses", "model": "llama3-8b", "gpus": 8, "seq": 1048576}"#,
        r#"{"method": "upipe", "model": "llama3-8b", "gpus": 8, "seq": 1048576}"#,
        r#"{"method": "ring", "model": "llama3-8b", "gpus": 8, "seq": 1048576}"#,
    ];

    fn telemetry(truth: &Calibration, repeats: usize) -> Vec<Observation> {
        let mut v = Vec::new();
        for _ in 0..repeats {
            for line in LINES {
                v.push(measured(line, truth));
            }
        }
        v
    }

    #[test]
    fn drifted_telemetry_publishes_an_epoch_that_matches_truth() {
        let truth = drifted_truth();
        let mut cal = OnlineCalibrator::new(Calibration::default(), OnlineConfig::default());
        let report = cal.ingest(&telemetry(&truth, 4));
        assert_eq!(report.rejected, 0, "notes: {:?}", report.notes);
        assert_eq!(report.accepted, 12);
        let pubd = report.published.expect("20% drift must publish");
        assert_eq!(pubd.epoch, 1);
        assert_eq!(cal.epoch(), 1);
        assert_ne!(pubd.old_fingerprint, pubd.new_fingerprint);
        assert_eq!(cal.fingerprint(), pubd.new_fingerprint);
        // Identical repeated samples: the EW fold is a fixed point, so the
        // published constants equal the truth's values exactly-ish.
        for f in &pubd.fields {
            let want = f.constant.get(&truth);
            assert!(
                (f.new - want).abs() / want < 1e-6,
                "{}: published {} vs truth {want}",
                f.constant.name(),
                f.new
            );
            assert_eq!(f.old, f.constant.get(&Calibration::default()));
        }
        assert!(
            pubd.fields.iter().any(|f| f.constant == FitConstant::RingEffBps),
            "ring telemetry refit the ring rate too"
        );
        // Post-publish drift is ~0: replaying the same telemetry must not
        // publish again.
        let again = cal.ingest(&telemetry(&truth, 4));
        assert!(again.published.is_none(), "drift: {:?}", again.drift);
        assert_eq!(cal.epoch(), 1);
    }

    #[test]
    fn replay_is_deterministic() {
        let truth = drifted_truth();
        let batch = telemetry(&truth, 4);
        let run = |batch: &[Observation]| {
            let mut cal = OnlineCalibrator::new(Calibration::default(), OnlineConfig::default());
            cal.ingest(batch);
            cal.snapshot().to_json().render()
        };
        assert_eq!(run(&batch), run(&batch), "byte-identical snapshots");
    }

    #[test]
    fn sub_threshold_drift_publishes_nothing() {
        let mut truth = Calibration::default();
        truth.fa3_fwd_flops *= 1.01; // 1% << the 5% threshold
        let mut cal = OnlineCalibrator::new(Calibration::default(), OnlineConfig::default());
        let report = cal.ingest(&telemetry(&truth, 4));
        assert!(report.accepted > 0);
        assert!(report.published.is_none());
        assert_eq!(cal.epoch(), 0);
        assert_eq!(cal.fingerprint(), Calibration::default().fingerprint());
        for d in &report.drift {
            assert!(d.rel_drift < 0.05, "{}: {}", d.constant.name(), d.rel_drift);
        }
    }

    #[test]
    fn min_count_gates_publishing() {
        let truth = drifted_truth();
        let mut cal = OnlineCalibrator::new(Calibration::default(), OnlineConfig::default());
        // One record per method: every constant has < min_count samples.
        let report = cal.ingest(&telemetry(&truth, 1));
        assert!(report.published.is_none());
        assert!(report.drift.iter().all(|d| d.observations < 4));
    }

    #[test]
    fn buffers_respect_capacity() {
        let truth = drifted_truth();
        let config = OnlineConfig {
            buffer_capacity: 3,
            drift_threshold: f64::INFINITY, // ingest-only, no publishes
            ..OnlineConfig::default()
        };
        let mut cal = OnlineCalibrator::new(Calibration::default(), config);
        cal.ingest(&telemetry(&truth, 5));
        for &c in &FitConstant::ALL {
            for m in ["ulysses", "upipe", "ring"] {
                assert!(cal.store().len(m, c) <= 3, "{m}/{}", c.name());
            }
        }
    }

    #[test]
    fn second_epoch_chains_provenance() {
        let mut cal = OnlineCalibrator::new(Calibration::default(), OnlineConfig::default());
        let first = cal.ingest(&telemetry(&drifted_truth(), 4)).published.unwrap();
        // Fresh drift relative to the *new* active calibration. The EW
        // estimate trails (alpha 0.25 folds toward a moved target), so
        // drive enough repeats for the estimate to cross 5% again.
        let mut truth2 = drifted_truth();
        truth2.fa3_fwd_flops *= 0.5;
        let mut second = None;
        for _ in 0..6 {
            if let Some(p) = cal.ingest(&telemetry(&truth2, 4)).published {
                second = Some(p);
                break;
            }
        }
        let second = second.expect("50% drift must eventually publish");
        assert_eq!(second.epoch, 2);
        assert_eq!(second.old_fingerprint, first.new_fingerprint, "chain links");
        let snap = cal.snapshot();
        assert_eq!(snap.epoch, 2);
        assert_eq!(snap.history.len(), 2);
        assert_eq!(snap.history[0].epoch, 1);
        assert_eq!(snap.history[1].epoch, 2);
    }
}
