//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` (`make artifacts`) and executes them on the PJRT
//! CPU client via the `xla` crate. This is the only place rust touches XLA;
//! Python never runs at serve/train time.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes `HloModuleProto` with
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod client;
pub mod manifest;
pub mod tensor;

pub use client::{Executable, Runtime};
pub use manifest::{ArtifactSpec, Dtype, Manifest, TensorSpec};
pub use tensor::HostTensor;
