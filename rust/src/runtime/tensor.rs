//! Host-side tensors: the coordinator's working representation of rank
//! buffers (dense row-major f32/i32), converted to/from XLA literals at the
//! PJRT boundary.

use anyhow::{bail, Result};

use super::manifest::{Dtype, TensorSpec};

#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn zeros(spec: &TensorSpec) -> Self {
        match spec.dtype {
            Dtype::F32 => HostTensor::F32 {
                shape: spec.shape.clone(),
                data: vec![0.0; spec.elements()],
            },
            Dtype::I32 => HostTensor::I32 {
                shape: spec.shape.clone(),
                data: vec![0; spec.elements()],
            },
        }
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32 { .. } => Dtype::F32,
            HostTensor::I32 { .. } => Dtype::I32,
        }
    }

    pub fn elements(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elements() * 4
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Check shape+dtype against a manifest spec.
    pub fn matches(&self, spec: &TensorSpec) -> bool {
        self.dtype() == spec.dtype && self.shape() == spec.shape.as_slice()
    }

    /// Convert to an XLA literal.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        Ok(match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        })
    }

    /// Convert back from an XLA literal, shaped per `spec`.
    pub fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Self> {
        Ok(match spec.dtype {
            Dtype::F32 => HostTensor::F32 { shape: spec.shape.clone(), data: lit.to_vec::<f32>()? },
            Dtype::I32 => HostTensor::I32 { shape: spec.shape.clone(), data: lit.to_vec::<i32>()? },
        })
    }

    /// Max |a - b| against another f32 tensor (parity checks).
    pub fn max_abs_diff(&self, other: &HostTensor) -> Result<f32> {
        let (a, b) = (self.as_f32()?, other.as_f32()?);
        if a.len() != b.len() {
            bail!("length mismatch {} vs {}", a.len(), b.len());
        }
        Ok(a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max))
    }

    /// Elementwise in-place add (residual connections are done host-side).
    pub fn add_assign(&mut self, other: &HostTensor) -> Result<()> {
        let b = other.as_f32()?.to_vec();
        let a = self.as_f32_mut()?;
        if a.len() != b.len() {
            bail!("length mismatch");
        }
        for (x, y) in a.iter_mut().zip(b) {
            *x += y;
        }
        Ok(())
    }

    /// Row-slice [rows0, rows1) of a 2-D tensor.
    pub fn slice_rows(&self, rows0: usize, rows1: usize) -> Result<HostTensor> {
        let shape = self.shape();
        if shape.len() != 2 {
            bail!("slice_rows needs 2-D, got {shape:?}");
        }
        let cols = shape[1];
        let data = self.as_f32()?[rows0 * cols..rows1 * cols].to_vec();
        Ok(HostTensor::f32(&[rows1 - rows0, cols], data))
    }

    /// Column-slice [c0, c1) of a 2-D tensor (used to cut per-stage weight
    /// chunks W[:, c0:c1] out of full projection matrices).
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Result<HostTensor> {
        let shape = self.shape();
        if shape.len() != 2 {
            bail!("slice_cols needs 2-D, got {shape:?}");
        }
        let (rows, cols) = (shape[0], shape[1]);
        let src = self.as_f32()?;
        let w = c1 - c0;
        let mut data = Vec::with_capacity(rows * w);
        for r in 0..rows {
            data.extend_from_slice(&src[r * cols + c0..r * cols + c1]);
        }
        Ok(HostTensor::f32(&[rows, w], data))
    }

    /// Concatenate 2-D tensors along columns.
    pub fn concat_cols(parts: &[HostTensor]) -> Result<HostTensor> {
        let rows = parts[0].shape()[0];
        let total: usize = parts.iter().map(|p| p.shape()[1]).sum();
        let mut data = Vec::with_capacity(rows * total);
        for r in 0..rows {
            for p in parts {
                let cols = p.shape()[1];
                data.extend_from_slice(&p.as_f32()?[r * cols..(r + 1) * cols]);
            }
        }
        Ok(HostTensor::f32(&[rows, total], data))
    }

    /// Concatenate 2-D tensors along rows.
    pub fn concat_rows(parts: &[HostTensor]) -> Result<HostTensor> {
        let cols = parts[0].shape()[1];
        let mut data = Vec::new();
        for p in parts {
            if p.shape()[1] != cols {
                bail!("column mismatch in concat_rows");
            }
            data.extend_from_slice(p.as_f32()?);
        }
        let rows = data.len() / cols;
        Ok(HostTensor::f32(&[rows, cols], data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_and_concat_roundtrip() {
        let t = HostTensor::f32(&[2, 4], (0..8).map(|x| x as f32).collect());
        let a = t.slice_cols(0, 2).unwrap();
        let b = t.slice_cols(2, 4).unwrap();
        assert_eq!(a.as_f32().unwrap(), &[0.0, 1.0, 4.0, 5.0]);
        let back = HostTensor::concat_cols(&[a, b]).unwrap();
        assert_eq!(back, t);
        let r0 = t.slice_rows(0, 1).unwrap();
        let r1 = t.slice_rows(1, 2).unwrap();
        assert_eq!(HostTensor::concat_rows(&[r0, r1]).unwrap(), t);
    }

    #[test]
    fn add_and_diff() {
        let mut a = HostTensor::f32(&[3], vec![1.0, 2.0, 3.0]);
        let b = HostTensor::f32(&[3], vec![0.5, 0.5, 0.5]);
        a.add_assign(&b).unwrap();
        assert_eq!(a.as_f32().unwrap(), &[1.5, 2.5, 3.5]);
        let c = HostTensor::f32(&[3], vec![1.5, 2.5, 4.0]);
        assert_eq!(a.max_abs_diff(&c).unwrap(), 0.5);
    }

    #[test]
    fn spec_matching() {
        let spec = TensorSpec { name: "x".into(), dtype: Dtype::F32, shape: vec![2, 3] };
        assert!(HostTensor::zeros(&spec).matches(&spec));
        assert!(!HostTensor::scalar_i32(1).matches(&spec));
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_checked() {
        HostTensor::f32(&[2, 2], vec![1.0]);
    }
}
