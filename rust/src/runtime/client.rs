//! PJRT client wrapper: compile HLO-text artifacts once, execute many times.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, Manifest};
use super::tensor::HostTensor;

/// One compiled artifact.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host tensors; validates shapes against the manifest.
    /// Artifacts are lowered with `return_tuple=True`, so the single output
    /// is a tuple decomposed per the manifest's output specs.
    pub fn call(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} inputs, manifest says {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            if !t.matches(spec) {
                bail!(
                    "{}: input `{}` expects {:?} {:?}, got {:?} {:?}",
                    self.spec.name,
                    spec.name,
                    spec.dtype,
                    spec.shape,
                    t.dtype(),
                    t.shape()
                );
            }
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.spec.name,
                outs.len(),
                self.spec.outputs.len()
            );
        }
        outs.iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| HostTensor::from_literal(lit, spec))
            .collect()
    }
}

/// The artifact registry: PJRT CPU client + lazily compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    compiled: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// Load the manifest from `artifacts_dir` and start a PJRT CPU client.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("starting PJRT CPU client")?;
        Ok(Runtime { client, manifest, compiled: Mutex::new(HashMap::new()) })
    }

    /// Default artifacts directory (repo-root `artifacts/`, overridable via
    /// `UU_ARTIFACTS`).
    pub fn default_dir() -> std::path::PathBuf {
        std::env::var("UU_ARTIFACTS")
            .map(Into::into)
            .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling on first use) an executable by artifact name.
    pub fn get(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.compiled.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .with_context(|| format!("parsing HLO text {:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let e = std::sync::Arc::new(Executable { spec, exe });
        self.compiled.lock().unwrap().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Convenience: call an artifact by name.
    pub fn call(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.get(name)?.call(inputs)
    }
}
