//! Parser for `artifacts/manifest.txt` — the plain-text artifact index
//! written by `aot.py` (no serde in the offline vendor set, and the format
//! is trivial):
//!
//! ```text
//! const pipe_c 4
//! artifact qkv_chunk
//! file qkv_chunk.hlo.txt
//! in xn f32 64,128
//! out o0 f32 4,64,16
//! end
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unknown dtype {other}"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub consts: HashMap<String, String>,
    pub artifacts: HashMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut m = Manifest::default();
        let mut cur: Option<ArtifactSpec> = None;
        for (lineno, line) in text.lines().enumerate() {
            let parts: Vec<&str> = line.split_whitespace().collect();
            let err = |msg: &str| anyhow::anyhow!("manifest line {}: {msg}", lineno + 1);
            match parts.as_slice() {
                [] => {}
                ["const", key, value] => {
                    m.consts.insert(key.to_string(), value.to_string());
                }
                ["artifact", name] => {
                    if cur.is_some() {
                        bail!(err("nested artifact"));
                    }
                    cur = Some(ArtifactSpec {
                        name: name.to_string(),
                        file: PathBuf::new(),
                        inputs: vec![],
                        outputs: vec![],
                    });
                }
                ["file", f] => {
                    cur.as_mut().ok_or_else(|| err("file outside artifact"))?.file =
                        dir.join(f);
                }
                [io @ ("in" | "out"), name, dtype, shape] => {
                    let spec = TensorSpec {
                        name: name.to_string(),
                        dtype: Dtype::parse(dtype)?,
                        shape: if *shape == "scalar" {
                            vec![]
                        } else {
                            shape
                                .split(',')
                                .map(|d| d.parse::<usize>().map_err(|e| err(&e.to_string())))
                                .collect::<Result<_>>()?
                        },
                    };
                    let a = cur.as_mut().ok_or_else(|| err("io outside artifact"))?;
                    if *io == "in" {
                        a.inputs.push(spec);
                    } else {
                        a.outputs.push(spec);
                    }
                }
                ["end"] => {
                    let a = cur.take().ok_or_else(|| err("end without artifact"))?;
                    m.artifacts.insert(a.name.clone(), a);
                }
                _ => bail!(err(&format!("unparseable: {line}"))),
            }
        }
        if cur.is_some() {
            bail!("manifest truncated: artifact not closed");
        }
        Ok(m)
    }

    pub fn const_u64(&self, key: &str) -> Result<u64> {
        self.consts
            .get(key)
            .with_context(|| format!("missing const {key}"))?
            .parse()
            .with_context(|| format!("const {key} not an integer"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
const pipe_c 4
const pipe_s 256

artifact qkv_chunk
file qkv_chunk.hlo.txt
in xn f32 64,128
in wq_c f32 128,64
out o0 f32 4,64,16
end

artifact step
file step.hlo.txt
in s i32 scalar
out o0 f32 scalar
end
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!(m.const_u64("pipe_c").unwrap(), 4);
        let a = m.artifact("qkv_chunk").unwrap();
        assert_eq!(a.file, Path::new("/a/qkv_chunk.hlo.txt"));
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[1].shape, vec![128, 64]);
        assert_eq!(a.outputs[0].elements(), 4 * 64 * 16);
        let s = m.artifact("step").unwrap();
        assert_eq!(s.inputs[0].dtype, Dtype::I32);
        assert!(s.inputs[0].shape.is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("junk line", Path::new("/")).is_err());
        assert!(Manifest::parse("artifact a\nartifact b", Path::new("/")).is_err());
        assert!(Manifest::parse("artifact a\nfile f", Path::new("/")).is_err());
        assert!(Manifest::parse("in x f32 1", Path::new("/")).is_err());
    }

    #[test]
    fn missing_lookups_error() {
        let m = Manifest::parse(SAMPLE, Path::new("/")).unwrap();
        assert!(m.const_u64("nope").is_err());
        assert!(m.artifact("nope").is_err());
    }
}
