//! §3.4 headline: intermediate-tensor memory savings of UPipe vs
//! DS-Ulysses (87.5% for Qwen3-32B at C=8, U=C).

use crate::model::attn_memory::{intermediate_bytes_ulysses, intermediate_bytes_upipe};
use crate::model::ModelDims;
use crate::util::fmt::{tokens, GIB};
use crate::util::table::Table;

pub fn savings_report(s: u64) -> Table {
    let mut t = Table::new(
        &format!("§3.4 — attention intermediate tensors @S={} (GiB/device)", tokens(s)),
        &["Model", "C", "U", "Ulysses 12·(S/C)·H·dh", "UPipe 12·(S/C)·U·dh", "savings"],
    );
    for (m, c) in [
        (ModelDims::llama3_8b(), 8u64),
        (ModelDims::qwen3_32b(), 8),
        (ModelDims::qwen3_32b(), 16),
    ] {
        let u = c;
        let ul = intermediate_bytes_ulysses(&m, s, c);
        let up = intermediate_bytes_upipe(&m, s, c, u);
        t.row(vec![
            m.name.into(),
            c.to_string(),
            u.to_string(),
            format!("{:.2}", ul / GIB),
            format!("{:.2}", up / GIB),
            format!("{:.1}%", 100.0 * (1.0 - up / ul)),
        ]);
    }
    t.note("paper: 87.5% for Qwen3-32B (H=64) at C=8, U=C");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qwen_875_headline() {
        let r = savings_report(1 << 20).render();
        assert!(r.contains("87.5%"), "{r}");
    }

    #[test]
    fn llama_75_percent() {
        // H=32, U=C=8 ⇒ 1 - 8/32 = 75%
        let r = savings_report(1 << 20).render();
        assert!(r.contains("75.0%"), "{r}");
    }
}
