//! Paper table/figure regeneration: every table and figure of the
//! evaluation, printed side-by-side with the published numbers
//! ([`paper_data`]) so deviations are visible at a glance. Driven by the
//! `repro` CLI (`repro table3`, `repro fig5`, ...) and by the benches.

pub mod figures;
pub mod paper_data;
pub mod planner;
pub mod savings;
pub mod tables;
