//! Regenerate paper Figures 1, 2, 4, 5, 6 (as tables / ASCII series).

use crate::config::presets::{
    fig5_seq_lens, llama_ablation, llama_single_node, llama_single_node_methods,
    llama_two_node, table34_seq_lens,
};
use crate::config::CpMethod;
use crate::schedule::gqa::{comm_volume_heads, gqa_schedule, naive_schedule};
use crate::schedule::{build_trace, simulate, AcMode};
use crate::util::fmt::{tokens, GIB};
use crate::util::table::Table;

/// Fig. 1: max context length + throughput summary, Llama3-8B 8×H100.
pub fn fig1_report() -> Table {
    let mut t = Table::new(
        "Figure 1 — max context & throughput summary, Llama3-8B 8xH100",
        &["Method", "max context", "tokens/s/GPU @1M", "tokens/s/GPU @max"],
    );
    for method in llama_single_node_methods() {
        let mut max_s = 0u64;
        for s in table34_seq_lens() {
            let r = simulate(&llama_single_node(method, s));
            if !r.oom && r.failed.is_none() {
                max_s = s;
            }
        }
        let at_1m = simulate(&llama_single_node(method, 1 << 20))
            .tokens_per_sec_per_gpu(1 << 20, 8)
            .map(|v| format!("{v:.0}"))
            .unwrap_or_else(|| "OOM".into());
        let at_max = simulate(&llama_single_node(method, max_s))
            .tokens_per_sec_per_gpu(max_s, 8)
            .map(|v| format!("{v:.0}"))
            .unwrap_or_else(|| "-".into());
        t.row(vec![method.label().into(), tokens(max_s), at_1m, at_max]);
    }
    t.note("paper: UPipe 5M (+25% over FPDT 4M); Ulysses/Ring 3M; Native 1M");
    t
}

/// Fig. 2: memory breakdown at 3M tokens across methods (Llama3-8B,
/// 8×H100): Ulysses (no AC) / +AC / +AO / FPDT / UPipe.
pub fn fig2_report() -> Table {
    let s = 3 << 20;
    let mut t = Table::new(
        "Figure 2 — memory breakdown @3M, Llama3-8B 8xH100 (GiB)",
        &["Variant", "persistent", "transient peak", "total", "status"],
    );
    let cases: Vec<(&str, CpMethod, Option<AcMode>)> = vec![
        ("Ulysses (no AC)", CpMethod::Ulysses, Some(AcMode::NoAc)),
        ("Ulysses + AC", CpMethod::Ulysses, Some(AcMode::AcGpu)),
        ("Ulysses + AO", CpMethod::Ulysses, Some(AcMode::AcOffload)),
        ("FPDT", CpMethod::Fpdt { pi: 16 }, None),
        ("UPipe", CpMethod::Upipe { u: 8, gqa_schedule: true }, None),
    ];
    for (label, method, ac) in cases {
        let mut preset = llama_single_node(method, s);
        if let Some(mode) = ac {
            preset.parallel.ac_mode = mode;
        }
        let report = simulate(&preset);
        let status = if report.oom { "OOM" } else { "fits" };
        let transient = report.peak_bytes - report.persistent_bytes;
        t.row(vec![
            label.into(),
            format!("{:.1}", report.persistent_bytes / GIB),
            format!("{:.1}", transient.max(0.0) / GIB),
            format!("{:.1}", report.peak_bytes / GIB),
            status.into(),
        ]);
    }
    t.note("paper Fig. 2: no-AC OOMs; AO ≈ 64.6; FPDT ≈ 43.4; UPipe ≈ 51.1");
    t
}

/// Fig. 4: GQA schedule communication volume (head-sends per device).
pub fn fig4_report() -> Table {
    let mut t = Table::new(
        "Figure 4 — GQA scheduling comm volume (full-seq head-sends)",
        &["Config (H, Hkv, U)", "naive", "GQA-sched", "reduction"],
    );
    for (h, hkv, u) in [(16u64, 4u64, 4u64), (32, 8, 8), (64, 8, 8), (8, 4, 4)] {
        let n = comm_volume_heads(&naive_schedule(h, hkv, u));
        let g = comm_volume_heads(&gqa_schedule(h, hkv, u));
        t.row(vec![
            format!("H={h} Hkv={hkv} U={u}"),
            n.to_string(),
            g.to_string(),
            format!("{:.0}%", 100.0 * (1.0 - g as f64 / n as f64)),
        ]);
    }
    t.note("paper §4.1: naive O(3·H) vs GQA O((3+G-1)·H/G) per device");
    t
}

/// Fig. 5: multi-node (16×H100) UPipe-Hybrid vs USP-Hybrid, Llama3-8B.
pub fn fig5_report() -> Table {
    let mut t = Table::new(
        "Figure 5 — 16xH100 Llama3-8B: UPipe-Hybrid vs USP-Hybrid",
        &["S", "USP GiB", "UPipe GiB", "USP tok/s/gpu", "UPipe tok/s/gpu", "tput ratio"],
    );
    let usp = CpMethod::UspHybrid { ulysses: 8, ring: 2 };
    let upi = CpMethod::UpipeHybrid { u: 8, ulysses: 8, ring: 2 };
    for s in fig5_seq_lens() {
        let a = simulate(&llama_two_node(usp, s));
        let b = simulate(&llama_two_node(upi, s));
        let mem = |r: &crate::engine::StepReport| {
            if r.oom {
                "OOM".to_string()
            } else {
                format!("{:.1}", r.peak_bytes / GIB)
            }
        };
        let tput = |r: &crate::engine::StepReport| {
            r.tokens_per_sec_per_gpu(s, 16)
                .map(|v| format!("{v:.1}"))
                .unwrap_or_else(|| "-".into())
        };
        let ratio = match (
            a.tokens_per_sec_per_gpu(s, 16),
            b.tokens_per_sec_per_gpu(s, 16),
        ) {
            (Some(x), Some(y)) => format!("{:.3}", y / x),
            _ => "-".into(),
        };
        t.row(vec![tokens(s), mem(&a), mem(&b), tput(&a), tput(&b), ratio]);
    }
    t.note("paper: UPipe max 8M vs USP 6M (+33%), throughput comparable");
    t
}

/// Fig. 6: ablation on head-chunk size U (Llama3-8B, 4×H100, 512K).
pub fn fig6_report() -> Table {
    let mut t = Table::new(
        "Figure 6 — ablation on U (Llama3-8B, 4xH100, 512K)",
        &["U", "stages ν", "peak GiB", "step time (s)", "tokens/s/GPU"],
    );
    for u in [4u32, 8, 16, 32] {
        let preset = llama_ablation(u);
        let r = simulate(&preset);
        t.row(vec![
            u.to_string(),
            (32 / u).to_string(),
            format!("{:.2}", r.peak_bytes / GIB),
            format!("{:.2}", r.step_time),
            format!("{:.1}", r.tokens_per_sec_per_gpu(preset.seq_len, 4).unwrap()),
        ]);
    }
    t.note("smaller U: less memory, slightly lower throughput (launch overhead)");
    t
}

/// Count a trace's ops (used by benches to show trace sizes).
pub fn trace_len(method: CpMethod, s: u64) -> usize {
    build_trace(&llama_single_node(method, s)).len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_headline_matches_paper() {
        let r = fig1_report().render();
        assert!(r.contains("UPipe"));
        assert!(r.contains("5M"), "UPipe must reach 5M:\n{r}");
    }

    #[test]
    fn fig2_no_ac_ooms() {
        let r = fig2_report().render();
        assert!(r.contains("OOM"));
    }

    #[test]
    fn fig4_llama_reduction_50pct() {
        // g=4 ⇒ (3+g-1)/(3g) = 0.5
        let r = fig4_report().render();
        assert!(r.contains("50%"), "{r}");
    }

    #[test]
    fn fig5_upipe_reaches_8m() {
        let r = fig5_report().render();
        // the 8M row must show UPipe fitting while USP is OOM
        let line8m = r.lines().find(|l| l.starts_with("8M") || l.trim_start().starts_with("8M"))
            .expect("8M row");
        assert!(line8m.contains("OOM"), "USP should be OOM at 8M: {line8m}");
        // UPipe column value present (two numbers = usp OOM + upipe fits)
        assert!(line8m.matches("OOM").count() == 1, "UPipe must fit at 8M: {line8m}");
    }

    #[test]
    fn fig6_renders_four_rows() {
        let r = fig6_report().render();
        assert_eq!(r.lines().filter(|l| l.trim_start().chars().next().map(|c| c.is_ascii_digit()).unwrap_or(false)).count(), 4);
    }
}
