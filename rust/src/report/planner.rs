//! Planner output rendering: the ranked plan table, the Pareto-frontier
//! table, the walls-only table for `--feasibility-only` sweeps, the
//! multi-length frontier artifact for `repro frontier --at-lengths`, and
//! machine-readable JSON for CI artifacts / downstream tooling. Surfaces
//! every sweep dimension (AC mode, micro-batch, TP) and, for `--refit`
//! runs, the calibration provenance.

use crate::engine::RefitInfo;
use crate::planner::{ConfigPlan, PlacementOutcome, PlanOutcome, ShapePlacement, WallsAtOutcome};
use crate::util::fmt::{tokens, GIB};
use crate::util::json::Json;
use crate::util::table::Table;

const PLAN_HEADER: [&str; 12] = [
    "#", "Method", "Params", "AC", "b", "TP", "Host", "MaxCtx", "tok/s@max", "GiB@ref",
    "tok/s@ref", "Pareto",
];

/// Walls-only view: no pricing columns exist in a feasibility-only sweep.
const WALLS_HEADER: [&str; 8] = ["#", "Method", "Params", "AC", "b", "TP", "Host", "MaxCtx"];

fn fmt_opt(v: Option<f64>, prec: usize) -> String {
    match v {
        Some(x) => format!("{x:.prec$}"),
        None => "-".into(),
    }
}

fn max_ctx_label(c: &ConfigPlan) -> String {
    match c.max_context {
        // hit_cap: the search ceiling was still feasible, so this is a
        // lower bound, not a measured memory wall.
        Some(s) if c.hit_cap => format!(">={}", tokens(s)),
        Some(s) => tokens(s),
        None => "-".into(),
    }
}

fn config_cells(rank: usize, c: &ConfigPlan) -> Vec<String> {
    vec![
        rank.to_string(),
        c.parallel.method.label().to_string(),
        c.parallel.method.params(),
        c.parallel.ac_mode.label().to_string(),
        c.parallel.micro_batch.to_string(),
        c.parallel.tp.to_string(),
        if c.parallel.pin_memory { "pin" } else { "nopin" }.to_string(),
        max_ctx_label(c),
        fmt_opt(c.max_ctx_tok_s_gpu, 0),
        fmt_opt(c.ref_peak_gib, 1),
        fmt_opt(c.ref_tok_s_gpu, 0),
        if c.pareto { "*".into() } else { String::new() },
    ]
}

fn add_notes(t: &mut Table, out: &PlanOutcome) {
    t.note(&format!(
        "ref = {}; search granularity {}; {} sims ({} probes + {} priced + {} modeled), \
         trace cache {}/{} hits",
        tokens(out.reference_s),
        tokens(out.quantum),
        out.simulations,
        out.feasibility_probes,
        out.priced_sims,
        out.modeled_prices,
        out.cache_hits,
        out.cache_hits + out.cache_misses
    ));
    // Zero families means the symbolic solver never ran (`--cold`, or an
    // empty sweep) — saying "solved 0" would misread as a failed solver.
    if out.symbolic_models + out.symbolic_fallbacks > 0 {
        t.note(&format!(
            "walls solved symbolically for {} cell families ({} fell back to bisection)",
            out.symbolic_models, out.symbolic_fallbacks
        ));
    }
    if out.time_models + out.time_fallbacks > 0 {
        t.note(&format!(
            "step times fitted symbolically for {} pricing families \
             ({} fell back to streamed pricing)",
            out.time_models, out.time_fallbacks
        ));
    }
    if out.feasibility_only {
        t.note("Host = offload pinning");
    } else {
        t.note("Pareto * = non-dominated on (GiB@ref, tok/s@ref); Host = offload pinning");
    }
    t.note("AC = activation ckpt (ao=offload, ac=gpu, noac); b = micro-batches; TP = tensor-par.");
    if let Some(r) = &out.refit {
        t.note(&format!(
            "calibration refit from {} ({} cells, anchored at {})",
            r.source,
            r.cells,
            tokens(r.anchor_seq)
        ));
        if !r.skipped.is_empty() {
            t.note(&format!(
                "WARNING: refit kept defaults for {} (unusable measurements)",
                r.skipped.join(", ")
            ));
        }
        if r.pressured_anchor {
            t.note(
                "WARNING: refit anchor ran under memory pressure; fitted rates absorb \
                 the penalty",
            );
        }
    }
}

/// Walls-only table for feasibility-only sweeps: every configuration's
/// solved context wall, no pricing columns.
pub fn walls_table(out: &PlanOutcome) -> Table {
    let mut t = Table::new(
        &format!(
            "Context walls — {} on {} ({} GPUs), feasibility only",
            out.model.name,
            out.cluster.name,
            out.cluster.total_gpus()
        ),
        &WALLS_HEADER,
    );
    for (i, c) in out.configs.iter().enumerate() {
        t.row(config_cells(i + 1, c).into_iter().take(WALLS_HEADER.len()).collect());
    }
    add_notes(&mut t, out);
    t.note("feasibility-only sweep: reference-length pricing skipped (walls only)");
    t
}

/// Full ranked plan (the `repro plan` output); the walls-only view when
/// the sweep skipped pricing.
pub fn plan_table(out: &PlanOutcome) -> Table {
    if out.feasibility_only {
        return walls_table(out);
    }
    let mut t = Table::new(
        &format!(
            "Plan — {} on {} ({} GPUs), ranked by max trainable context",
            out.model.name,
            out.cluster.name,
            out.cluster.total_gpus()
        ),
        &PLAN_HEADER,
    );
    for (i, c) in out.configs.iter().enumerate() {
        t.row(config_cells(i + 1, c));
    }
    add_notes(&mut t, out);
    t
}

/// Frontier-only view (the `repro frontier` output), cheapest peak first.
/// A feasibility-only sweep has no priced frontier, so it degrades to the
/// walls table.
pub fn frontier_table(out: &PlanOutcome) -> Table {
    if out.feasibility_only {
        return walls_table(out);
    }
    let mut t = Table::new(
        &format!(
            "Pareto frontier — {} on {} ({} GPUs) at S = {}",
            out.model.name,
            out.cluster.name,
            out.cluster.total_gpus(),
            tokens(out.reference_s)
        ),
        &PLAN_HEADER,
    );
    for (i, c) in out.frontier().into_iter().enumerate() {
        t.row(config_cells(i + 1, c));
    }
    add_notes(&mut t, out);
    t
}

fn num_or_null(v: Option<f64>) -> Json {
    v.map(Json::Num).unwrap_or(Json::Null)
}

fn config_json(c: &ConfigPlan) -> Json {
    let ctx_label = match c.max_context {
        Some(s) => Json::string(&tokens(s)),
        None => Json::Null,
    };
    Json::obj(vec![
        ("method", Json::string(c.parallel.method.label())),
        ("params", Json::string(&c.parallel.method.params())),
        ("ac_mode", Json::string(c.parallel.ac_mode.label())),
        ("micro_batch", Json::int(c.parallel.micro_batch)),
        ("tp", Json::int(c.parallel.tp)),
        ("pin_memory", Json::Bool(c.parallel.pin_memory)),
        ("cp_degree", Json::int(c.parallel.cp_degree)),
        ("max_context", c.max_context.map(Json::int).unwrap_or(Json::Null)),
        ("max_context_label", ctx_label),
        ("max_context_capped", Json::Bool(c.hit_cap)),
        ("max_ctx_peak_gib", num_or_null(c.max_ctx_peak_gib)),
        ("max_ctx_tok_s_per_gpu", num_or_null(c.max_ctx_tok_s_gpu)),
        ("ref_peak_gib", num_or_null(c.ref_peak_gib)),
        ("ref_tok_s_per_gpu", num_or_null(c.ref_tok_s_gpu)),
        ("pareto", Json::Bool(c.pareto)),
    ])
}

/// Refit provenance as JSON (shared by the CLI plan output and the
/// service's `/v1/refit` response).
pub fn refit_json(r: &RefitInfo) -> Json {
    Json::obj(vec![
        ("source", Json::string(&r.source)),
        ("model", Json::string(&r.model)),
        ("cells", Json::int(r.cells as u64)),
        ("anchor_seq", Json::int(r.anchor_seq)),
        (
            "skipped",
            Json::Arr(r.skipped.iter().map(|s| Json::string(s)).collect()),
        ),
        ("pressured_anchor", Json::Bool(r.pressured_anchor)),
        (
            "fields",
            Json::Arr(
                r.fields
                    .iter()
                    .map(|f| {
                        Json::obj(vec![
                            ("name", Json::string(f.name)),
                            ("old", Json::Num(f.old)),
                            ("new", Json::Num(f.new)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// The deterministic plan core: every field a repeated request must
/// reproduce byte-for-byte — what the wire protocol serves as `result`.
/// Run accounting (probe/sim counters, cache hits, wall-clock) stays out
/// deliberately: a warm session answers from memos, so those numbers
/// describe one run, not the plan.
fn core_pairs(out: &PlanOutcome, configs: Vec<Json>) -> Vec<(&'static str, Json)> {
    vec![
        ("model", Json::string(out.model.name)),
        ("cluster", Json::string(out.cluster.name)),
        ("gpus", Json::int(out.cluster.total_gpus())),
        ("reference_s", Json::int(out.reference_s)),
        ("quantum", Json::int(out.quantum)),
        (
            "refit",
            out.refit.as_ref().map(refit_json).unwrap_or(Json::Null),
        ),
        ("feasibility_only", Json::Bool(out.feasibility_only)),
        ("configs", Json::Arr(configs)),
    ]
}

/// Per-run accounting: appended to the CLI JSON (whose consumers — the
/// bench diff, the CI artifacts — want the search cost), excluded from
/// the service `result` (whose contract is bitwise determinism).
fn accounting_pairs(out: &PlanOutcome) -> Vec<(&'static str, Json)> {
    let cache = Json::obj(vec![
        ("hits", Json::int(out.cache_hits)),
        ("misses", Json::int(out.cache_misses)),
    ]);
    vec![
        ("simulations", Json::int(out.simulations)),
        ("feasibility_probes", Json::int(out.feasibility_probes)),
        ("priced_sims", Json::int(out.priced_sims)),
        ("modeled_prices", Json::int(out.modeled_prices)),
        ("symbolic_models", Json::int(out.symbolic_models)),
        ("symbolic_fallbacks", Json::int(out.symbolic_fallbacks)),
        ("time_models", Json::int(out.time_models)),
        ("time_fallbacks", Json::int(out.time_fallbacks)),
        ("trace_cache", cache),
        ("wall_s", Json::Num(out.wall_s)),
    ]
}

fn outcome_json(out: &PlanOutcome, configs: Vec<Json>) -> Json {
    let mut pairs = core_pairs(out, configs);
    pairs.extend(accounting_pairs(out));
    Json::obj(pairs)
}

/// Machine-readable plan (`repro plan --json`): the deterministic core
/// plus this run's accounting.
pub fn plan_json(out: &PlanOutcome) -> Json {
    outcome_json(out, out.configs.iter().map(config_json).collect())
}

/// Machine-readable frontier (`repro frontier --json`). A feasibility-only
/// sweep has no priced frontier, so it degrades to the full walls list
/// (matching the table behaviour).
pub fn frontier_json(out: &PlanOutcome) -> Json {
    if out.feasibility_only {
        return plan_json(out);
    }
    outcome_json(out, out.frontier().into_iter().map(config_json).collect())
}

/// The deterministic plan core alone — the `result` field of a `/v1/plan`
/// (or walls-sweep `/v1/walls`) response. Identical requests must render
/// this byte-for-byte, warm or cold.
pub fn plan_result_json(out: &PlanOutcome) -> Json {
    Json::obj(core_pairs(out, out.configs.iter().map(config_json).collect()))
}

/// The deterministic frontier core — the `result` of `/v1/frontier`
/// (degrades like [`frontier_json`] for feasibility-only sweeps).
pub fn frontier_result_json(out: &PlanOutcome) -> Json {
    if out.feasibility_only {
        return plan_result_json(out);
    }
    Json::obj(core_pairs(out, out.frontier().into_iter().map(config_json).collect()))
}

/// The `repro frontier --at-lengths` artifact: one deterministic plan
/// core per requested reference length, re-priced on the same warm
/// session. The request's own reference length is always the first row,
/// so CI can strip the plan artifact's accounting and byte-compare that
/// row's `result` against it. `accounting` sums the per-row search cost
/// (priced/modeled are per-run deltas; the time-model counts are the
/// session-wide tally after the last row, not a sum).
pub fn frontier_at_lengths_json(rows: &[(u64, &PlanOutcome)]) -> Json {
    let sums = |f: fn(&PlanOutcome) -> u64| rows.iter().map(|&(_, o)| f(o)).sum::<u64>();
    let last = rows.last().map(|(_, o)| *o);
    Json::obj(vec![
        (
            "lengths",
            Json::Arr(rows.iter().map(|(s, _)| Json::int(*s)).collect()),
        ),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|(s, out)| {
                        Json::obj(vec![
                            ("reference_s", Json::int(*s)),
                            ("result", plan_result_json(out)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "accounting",
            Json::obj(vec![
                ("feasibility_probes", Json::int(sums(|o| o.feasibility_probes))),
                ("priced_sims", Json::int(sums(|o| o.priced_sims))),
                ("modeled_prices", Json::int(sums(|o| o.modeled_prices))),
                ("time_models", Json::int(last.map_or(0, |o| o.time_models))),
                ("time_fallbacks", Json::int(last.map_or(0, |o| o.time_fallbacks))),
            ]),
        ),
    ])
}

const PLACEMENT_HEADER: [&str; 9] =
    ["#", "Pool", "Device", "Nodes", "GPUs", "MaxCtx", "Method", "tok/s@ref", "Pruned by"];

fn shape_cells(rank: Option<usize>, sp: &ShapePlacement) -> Vec<String> {
    let best = sp.plan.as_ref().and_then(|p| p.best());
    let wall = match (sp.best_wall(), best) {
        (Some(s), Some(b)) if b.hit_cap => format!(">={}", tokens(s)),
        (Some(s), _) => tokens(s),
        _ => "-".into(),
    };
    vec![
        rank.map(|r| r.to_string()).unwrap_or_else(|| "-".into()),
        sp.pool.clone(),
        sp.device.clone(),
        sp.cluster.nodes.to_string(),
        sp.gpus().to_string(),
        wall,
        best.map(|b| b.parallel.method.label().to_string()).unwrap_or_else(|| "-".into()),
        fmt_opt(sp.best_ref_tput(), 0),
        sp.pruned_by.clone().unwrap_or_default(),
    ]
}

/// The `repro place` output: fleet shapes ranked best-first, with the
/// dominated shapes listed below the survivors (unranked; their MaxCtx
/// column is `-` when pruning skipped their evaluation).
pub fn placement_table(out: &PlacementOutcome) -> Table {
    let mut t = Table::new(
        &format!(
            "Placement — {} across {} fleet shapes, ranked by max trainable context",
            out.model.name, out.shapes_total
        ),
        &PLACEMENT_HEADER,
    );
    for (i, sp) in out.placements.iter().enumerate() {
        t.row(shape_cells(Some(i + 1), sp));
    }
    for sp in &out.pruned {
        t.row(shape_cells(None, sp));
    }
    t.note(&format!(
        "{} shapes: {} ranked, {} dominated ({} skipped before any probe)",
        out.shapes_total,
        out.placements.len(),
        out.pruned.len(),
        out.shapes_pruned
    ));
    t.note(&format!(
        "{} sims ({} probes + {} anchors + {} modeled); {} of {} evaluated shapes \
         replayed entirely from shared fits",
        out.simulations,
        out.feasibility_probes,
        out.anchor_sims,
        out.modeled_prices,
        out.shapes_reused,
        out.shapes_total - out.shapes_pruned
    ));
    t.note(&format!(
        "model fits shared across shapes: {} distinct hardware grids, {} peak families, \
         {} pricing families",
        out.distinct_hardware, out.peak_families, out.pricing_families
    ));
    if out.feasibility_only {
        t.note("feasibility-only placement: per-shape pricing skipped (walls only)");
    }
    // Refit provenance, mirroring `add_notes`: a placement ranked under a
    // refitted calibration must say so (and warn about the constants the
    // refit could not use) just like the per-cluster plan tables do.
    if let Some(r) = &out.refit {
        t.note(&format!(
            "calibration refit from {} ({} cells, anchored at {})",
            r.source,
            r.cells,
            tokens(r.anchor_seq)
        ));
        if !r.skipped.is_empty() {
            t.note(&format!(
                "WARNING: refit kept defaults for {} (unusable measurements)",
                r.skipped.join(", ")
            ));
        }
        if r.pressured_anchor {
            t.note(
                "WARNING: refit anchor ran under memory pressure; fitted rates absorb \
                 the penalty",
            );
        }
    }
    t
}

/// One fleet shape's JSON: identity, per-rank hardware (the fields the
/// CI dominance gate compares, in the fleet schema's GiB / GB/s units),
/// the best-config summary the ranking sorted on, and — when the shape
/// was evaluated — its full deterministic plan core.
fn shape_json(sp: &ShapePlacement) -> Json {
    let c = &sp.cluster;
    let best = sp.plan.as_ref().and_then(|p| p.best());
    Json::obj(vec![
        ("pool", Json::string(&sp.pool)),
        ("device", Json::string(&sp.device)),
        ("label", Json::string(&sp.label())),
        ("nodes", Json::int(c.nodes)),
        ("gpus_per_node", Json::int(c.gpus_per_node)),
        ("gpus", Json::int(c.total_gpus())),
        (
            "hardware",
            Json::obj(vec![
                ("hbm_gib", Json::Num(c.hbm_bytes / GIB)),
                ("hbm_usable_frac", Json::Num(c.hbm_usable_frac)),
                ("nvlink_gbps", Json::Num(c.nvlink_bps / 1e9)),
                ("ib_gbps", Json::Num(c.ib_bps / 1e9)),
                ("pcie_gbps", Json::Num(c.pcie_bps / 1e9)),
                ("host_ram_gib", Json::Num(c.host_ram_bytes / GIB)),
                ("compute_scale", Json::Num(c.compute_scale)),
            ]),
        ),
        ("best_wall", sp.best_wall().map(Json::int).unwrap_or(Json::Null)),
        (
            "best_wall_label",
            sp.best_wall().map(|s| Json::string(&tokens(s))).unwrap_or(Json::Null),
        ),
        (
            "best_method",
            best.map(|b| Json::string(b.parallel.method.label())).unwrap_or(Json::Null),
        ),
        ("best_ref_tok_s_per_gpu", num_or_null(sp.best_ref_tput())),
        ("pruned_by", sp.pruned_by.as_deref().map(Json::string).unwrap_or(Json::Null)),
        (
            "plan",
            sp.plan
                .as_ref()
                .map(|p| Json::obj(core_pairs(p, p.configs.iter().map(config_json).collect())))
                .unwrap_or(Json::Null),
        ),
    ])
}

/// The deterministic placement core — the `result` of `/v1/placement`.
/// Everything here must replay byte-for-byte on a warm session: shape
/// ranking, per-shape hardware, full plan cores, and the dominance
/// provenance (which is a pure function of the fleet, not of the run).
fn placement_core_pairs(out: &PlacementOutcome) -> Vec<(&'static str, Json)> {
    vec![
        ("model", Json::string(out.model.name)),
        ("reference_s", Json::int(out.reference_s)),
        ("quantum", Json::int(out.quantum)),
        ("feasibility_only", Json::Bool(out.feasibility_only)),
        ("prune", Json::Bool(out.prune)),
        ("refit", out.refit.as_ref().map(refit_json).unwrap_or(Json::Null)),
        ("fleet", out.fleet.canonical()),
        ("placements", Json::Arr(out.placements.iter().map(shape_json).collect())),
        ("pruned", Json::Arr(out.pruned.iter().map(shape_json).collect())),
        ("shapes_total", Json::int(out.shapes_total)),
        ("shapes_pruned", Json::int(out.shapes_pruned)),
    ]
}

/// The deterministic placement core alone (the service `result`).
pub fn placement_result_json(out: &PlacementOutcome) -> Json {
    Json::obj(placement_core_pairs(out))
}

/// Machine-readable placement (`repro place --json`): the deterministic
/// core plus this run's reuse/pruning accounting — what the CI dominance
/// gate and the bench diff consume.
pub fn placement_json(out: &PlacementOutcome) -> Json {
    let mut pairs = placement_core_pairs(out);
    pairs.extend(vec![
        ("shapes_reused", Json::int(out.shapes_reused)),
        ("distinct_hardware", Json::int(out.distinct_hardware)),
        ("peak_families", Json::int(out.peak_families)),
        ("pricing_families", Json::int(out.pricing_families)),
        ("simulations", Json::int(out.simulations)),
        ("feasibility_probes", Json::int(out.feasibility_probes)),
        ("anchor_sims", Json::int(out.anchor_sims)),
        ("modeled_prices", Json::int(out.modeled_prices)),
        ("wall_s", Json::Num(out.wall_s)),
    ]);
    Json::obj(pairs)
}

/// A point capacity query's answer — the `result` of `/v1/walls` with
/// `"at"`. `probes` is part of the payload on purpose: "zero streamed
/// probes on a warm session" is the service's observable contract, and
/// the CI smoke greps for it.
pub fn walls_at_json(q: &WallsAtOutcome) -> Json {
    let cells = q
        .cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("method", Json::string(c.parallel.method.label())),
                ("params", Json::string(&c.parallel.method.params())),
                ("ac_mode", Json::string(c.parallel.ac_mode.label())),
                ("micro_batch", Json::int(c.parallel.micro_batch)),
                ("tp", Json::int(c.parallel.tp)),
                ("pin_memory", Json::Bool(c.parallel.pin_memory)),
                ("cp_degree", Json::int(c.parallel.cp_degree)),
                ("feasible", Json::Bool(c.feasible)),
                (
                    "predicted_peak_gib",
                    c.predicted_peak_gib.map(Json::Num).unwrap_or(Json::Null),
                ),
                ("source", Json::string(c.source.label())),
            ])
        })
        .collect();
    let feasible = q.cells.iter().filter(|c| c.feasible).count() as u64;
    Json::obj(vec![
        ("model", Json::string(q.model.name)),
        ("cluster", Json::string(q.cluster.name)),
        ("gpus", Json::int(q.cluster.total_gpus())),
        ("seq", Json::int(q.seq)),
        ("seq_lattice", Json::int(q.seq_lattice)),
        ("quantum", Json::int(q.quantum)),
        ("feasible_configs", Json::int(feasible)),
        ("cells", Json::Arr(cells)),
        (
            "sources",
            Json::obj(vec![
                ("wall", Json::int(q.from_walls)),
                ("model", Json::int(q.from_models)),
                ("probe", Json::int(q.from_probes)),
            ]),
        ),
        ("probes", Json::int(q.probes)),
    ])
}

/// A batch point query's answer — the `result` of `/v1/walls` with an
/// `"at"` array: the per-point payloads in request order plus the total
/// streamed-probe count (still 0 on a warm session; the CI batch smoke
/// greps for it).
pub fn walls_batch_json(qs: &[WallsAtOutcome]) -> Json {
    let total: u64 = qs.iter().map(|q| q.probes).sum();
    Json::obj(vec![
        ("points", Json::Arr(qs.iter().map(walls_at_json).collect())),
        ("probes", Json::int(total)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::engine::RefitField;
    use crate::model::ModelDims;
    use crate::planner::{plan, PlanRequest, SweepDims};

    fn small_req() -> PlanRequest {
        let mut req = PlanRequest::new(ModelDims::llama3_8b(), ClusterConfig::h100_node());
        req.quantum = 1 << 20;
        req.cap_s = 8 << 20;
        req.threads = 2;
        req
    }

    fn small_plan() -> PlanOutcome {
        plan(&small_req())
    }

    #[test]
    fn tables_render() {
        let out = small_plan();
        let t = plan_table(&out).render();
        assert!(t.contains("UPipe"));
        assert!(t.contains("llama3-8b"));
        assert!(t.contains("AC"), "new dim column present");
        let f = frontier_table(&out).render();
        assert!(f.contains("Pareto frontier"));
    }

    #[test]
    fn capped_max_context_is_marked_as_lower_bound() {
        let mut req = small_req();
        req.cap_s = 4 << 20; // below UPipe's 5M wall: the cap binds
        req.dims = SweepDims::paper();
        let out = plan(&req);
        let top = out.configs.first().unwrap();
        assert!(top.hit_cap);
        assert_eq!(max_ctx_label(top), ">=4M");
        let j = plan_json(&out).render();
        assert!(j.contains("\"max_context_capped\":true"));
    }

    #[test]
    fn feasibility_only_renders_walls_view() {
        let mut req = small_req();
        req.feasibility_only = true;
        let out = plan(&req);
        let t = plan_table(&out).render();
        assert!(t.contains("Context walls"), "{t}");
        assert!(t.contains("feasibility-only sweep"), "{t}");
        assert!(!t.contains("tok/s@ref"), "pricing columns must not render");
        assert!(t.contains("5M"), "the 5M wall survives without pricing");
        // The frontier command degrades to the same walls view.
        let f = frontier_table(&out).render();
        assert!(f.contains("Context walls"));
        let j = plan_json(&out).render();
        assert!(j.contains("\"feasibility_only\":true"));
        assert!(j.contains("\"priced_sims\":0"));
        assert!(j.contains("\"modeled_prices\":0"));
        assert!(j.contains("\"max_context\":"));
        assert!(j.contains("\"ref_tok_s_per_gpu\":null"));
    }

    #[test]
    fn symbolic_accounting_lands_in_output() {
        let out = small_plan();
        let j = plan_json(&out).render();
        assert!(j.contains("\"feasibility_probes\":"));
        assert!(j.contains("\"symbolic_models\":"));
        assert!(j.contains("\"modeled_prices\":"));
        assert!(j.contains("\"time_models\":"));
        assert!(j.contains("\"time_fallbacks\":"));
        assert!(j.contains("\"feasibility_only\":false"));
        let t = plan_table(&out).render();
        assert!(t.contains("walls solved symbolically"), "{t}");
        assert!(t.contains("step times fitted symbolically"), "{t}");
        assert!(t.contains("probes"), "{t}");
    }

    #[test]
    fn at_lengths_rows_embed_the_plan_core() {
        use crate::planner::{plan_with, PlannerCaches};
        let caches = PlannerCaches::new();
        let mut req = small_req();
        let base = plan_with(&req, &caches);
        req.reference_s = 2 << 20;
        let extra = plan_with(&req, &caches);
        let rows = [(1u64 << 20, &base), (2u64 << 20, &extra)];
        let j = frontier_at_lengths_json(&rows);
        let rendered = j.render();
        assert!(rendered.contains("\"lengths\":[1048576,2097152]"), "{rendered}");
        // The reference row's result is exactly the plan artifact's core.
        let row0 = j.get("rows").and_then(|r| match r {
            Json::Arr(v) => v.first(),
            _ => None,
        });
        let result = row0.and_then(|r| r.get("result")).unwrap();
        assert_eq!(result.render(), plan_result_json(&base).render());
        assert!(rendered.contains("\"accounting\""), "{rendered}");
        assert!(rendered.contains("\"modeled_prices\":"), "{rendered}");
    }

    #[test]
    fn json_has_ranking_and_cells() {
        let out = small_plan();
        let j = plan_json(&out).pretty();
        assert!(j.contains("\"model\": \"llama3-8b\""));
        assert!(j.contains("\"method\": \"UPipe\""));
        assert!(j.contains("\"max_context_label\": \"5M\""));
        assert!(j.contains("\"ac_mode\": \"ao\""));
        assert!(j.contains("\"micro_batch\": 1"));
        assert!(j.contains("\"tp\": 2"), "TP slice swept and reported");
        assert!(j.contains("\"refit\": null"));
        assert!(j.starts_with('{') && j.ends_with('}'));
        let fj = frontier_json(&out).render();
        assert!(fj.contains("\"pareto\":true"));
    }

    #[test]
    fn result_core_is_deterministic_prefix_of_plan_json() {
        use crate::planner::{plan_with, walls_at, PlannerCaches};
        let req = small_req();
        let caches = PlannerCaches::new();
        let cold = plan_with(&req, &caches);
        let warm = plan_with(&req, &caches);
        // The deterministic core must not carry run accounting...
        let core = plan_result_json(&cold).render();
        assert!(!core.contains("\"wall_s\""), "{core}");
        assert!(!core.contains("\"simulations\""));
        assert!(!core.contains("\"trace_cache\""));
        assert!(core.contains("\"configs\""));
        // ...and renders byte-identically warm and cold, while the full
        // CLI JSON keeps the accounting fields (different between runs).
        assert_eq!(core, plan_result_json(&warm).render());
        let full = plan_json(&cold).render();
        assert!(full.contains("\"wall_s\""));
        assert!(full.starts_with(&core[..core.len() - 1]), "core must prefix the full JSON");
        // Frontier core: only Pareto rows.
        let fr = frontier_result_json(&cold).render();
        assert!(fr.contains("\"pareto\":true"));
        assert!(!fr.contains("\"pareto\":false"));
        assert!(!fr.contains("\"wall_s\""));
        // Point-query rendering carries sources and the probe count.
        let q = walls_at(&req, 2 << 20, &caches);
        let qj = walls_at_json(&q).render();
        assert!(qj.contains("\"seq_lattice\":2097152"), "{qj}");
        assert!(qj.contains("\"sources\""));
        assert!(qj.contains("\"probes\":"));
        assert!(qj.contains("\"feasible\":true"));
    }

    #[test]
    fn refit_provenance_lands_in_output() {
        let mut req = small_req();
        req.dims = SweepDims::paper();
        req.refit = Some(crate::engine::RefitInfo {
            source: "bench.json".into(),
            model: "llama3-8b".into(),
            cells: 4,
            anchor_seq: 1 << 20,
            fields: vec![RefitField { name: "fa3_fwd_flops", old: 696e12, new: 700e12 }],
            skipped: vec!["a2a_eff0_bps"],
            pressured_anchor: true,
        });
        let out = plan(&req);
        let j = plan_json(&out).render();
        assert!(j.contains("\"refit\":{"), "{j}");
        assert!(j.contains("bench.json"));
        assert!(j.contains("fa3_fwd_flops"));
        assert!(j.contains("\"skipped\":[\"a2a_eff0_bps\"]"));
        assert!(j.contains("\"pressured_anchor\":true"));
        let t = plan_table(&out).render();
        assert!(t.contains("calibration refit from bench.json"));
        assert!(t.contains("WARNING: refit kept defaults for a2a_eff0_bps"));
        assert!(t.contains("refit anchor ran under memory pressure"));
    }

    #[test]
    fn placement_rendering_carries_hardware_and_provenance() {
        use crate::config::FleetSpec;
        use crate::planner::{place, PlacementRequest};
        let fleet = FleetSpec::parse(
            r#"{"pools": [
                {"name": "old-h100", "device": "h100", "nodes": 1},
                {"name": "new-h200", "device": "h200", "nodes": 1}
            ]}"#,
            "test",
        )
        .unwrap();
        let mut req = PlacementRequest::new(ModelDims::llama3_8b(), fleet);
        req.quantum = 1 << 20;
        req.cap_s = 4 << 20;
        req.threads = 1;
        req.dims = SweepDims::paper();
        req.refit = Some(crate::engine::RefitInfo {
            source: "bench.json".into(),
            model: "llama3-8b".into(),
            cells: 4,
            anchor_seq: 1 << 20,
            fields: vec![RefitField { name: "fa3_fwd_flops", old: 696e12, new: 700e12 }],
            skipped: vec!["ring_eff_bps"],
            pressured_anchor: false,
        });
        let out = place(&req);

        let t = placement_table(&out).render();
        assert!(t.contains("new-h200"), "{t}");
        assert!(t.contains("Pruned by"), "{t}");
        assert!(t.contains("skipped before any probe"), "{t}");
        assert!(t.contains("pricing families"), "{t}");
        // Refit provenance rides the placement table exactly like the
        // plan tables: source line plus the skipped-fields warning.
        assert!(t.contains("calibration refit from bench.json"), "{t}");
        assert!(t.contains("WARNING: refit kept defaults for ring_eff_bps"), "{t}");

        // The CLI artifact: hardware fields for the dominance gate,
        // dominance provenance, plan cores, and reuse accounting.
        let j = placement_json(&out).render();
        assert!(j.contains("\"hbm_gib\":141"), "H200 hardware in artifact: {j}");
        assert!(j.contains("\"pruned_by\":\"new-h200/1x8\""), "{j}");
        assert!(j.contains("\"shapes_pruned\":1"), "{j}");
        assert!(j.contains("\"anchor_sims\":"), "{j}");
        assert!(j.contains("\"fleet\":{\"pools\":"), "{j}");
        assert!(j.contains("\"configs\":"), "plan cores ride along: {j}");

        // The service core carries no run accounting and the pruned
        // shape's plan is null (skipped before any probe).
        let core = placement_result_json(&out).render();
        assert!(!core.contains("\"wall_s\""), "{core}");
        assert!(!core.contains("\"anchor_sims\""), "{core}");
        assert!(core.contains("\"plan\":null"), "{core}");
        assert!(j.starts_with(&core[..core.len() - 1]), "core must prefix the full JSON");
    }
}
