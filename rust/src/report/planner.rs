//! Planner output rendering: the ranked plan table, the Pareto-frontier
//! table, and machine-readable JSON for CI artifacts / downstream tooling.

use crate::planner::{ConfigPlan, PlanOutcome};
use crate::util::fmt::tokens;
use crate::util::json::Json;
use crate::util::table::Table;

const PLAN_HEADER: [&str; 9] = [
    "#", "Method", "Params", "Host", "MaxCtx", "tok/s@max", "GiB@ref", "tok/s@ref", "Pareto",
];

fn fmt_opt(v: Option<f64>, prec: usize) -> String {
    match v {
        Some(x) => format!("{x:.prec$}"),
        None => "-".into(),
    }
}

fn max_ctx_label(c: &ConfigPlan) -> String {
    match c.max_context {
        // hit_cap: the search ceiling was still feasible, so this is a
        // lower bound, not a measured memory wall.
        Some(s) if c.hit_cap => format!(">={}", tokens(s)),
        Some(s) => tokens(s),
        None => "-".into(),
    }
}

fn config_cells(rank: usize, c: &ConfigPlan) -> Vec<String> {
    vec![
        rank.to_string(),
        c.parallel.method.label().to_string(),
        c.parallel.method.params(),
        if c.parallel.pin_memory { "pin" } else { "nopin" }.to_string(),
        max_ctx_label(c),
        fmt_opt(c.max_ctx_tok_s_gpu, 0),
        fmt_opt(c.ref_peak_gib, 1),
        fmt_opt(c.ref_tok_s_gpu, 0),
        if c.pareto { "*".into() } else { String::new() },
    ]
}

fn add_notes(t: &mut Table, out: &PlanOutcome) {
    t.note(&format!(
        "ref = {}; search granularity {}; {} sims, trace cache {}/{} hits",
        tokens(out.reference_s),
        tokens(out.quantum),
        out.simulations,
        out.cache_hits,
        out.cache_hits + out.cache_misses
    ));
    t.note("Pareto * = non-dominated on (GiB@ref, tok/s@ref); Host = offload pinning");
}

/// Full ranked plan (the `repro plan` output).
pub fn plan_table(out: &PlanOutcome) -> Table {
    let mut t = Table::new(
        &format!(
            "Plan — {} on {} ({} GPUs), ranked by max trainable context",
            out.model.name,
            out.cluster.name,
            out.cluster.total_gpus()
        ),
        &PLAN_HEADER,
    );
    for (i, c) in out.configs.iter().enumerate() {
        t.row(config_cells(i + 1, c));
    }
    add_notes(&mut t, out);
    t
}

/// Frontier-only view (the `repro frontier` output), cheapest peak first.
pub fn frontier_table(out: &PlanOutcome) -> Table {
    let mut t = Table::new(
        &format!(
            "Pareto frontier — {} on {} ({} GPUs) at S = {}",
            out.model.name,
            out.cluster.name,
            out.cluster.total_gpus(),
            tokens(out.reference_s)
        ),
        &PLAN_HEADER,
    );
    for (i, c) in out.frontier().into_iter().enumerate() {
        t.row(config_cells(i + 1, c));
    }
    add_notes(&mut t, out);
    t
}

fn num_or_null(v: Option<f64>) -> Json {
    v.map(Json::Num).unwrap_or(Json::Null)
}

fn config_json(c: &ConfigPlan) -> Json {
    let ctx_label = match c.max_context {
        Some(s) => Json::string(&tokens(s)),
        None => Json::Null,
    };
    Json::obj(vec![
        ("method", Json::string(c.parallel.method.label())),
        ("params", Json::string(&c.parallel.method.params())),
        ("pin_memory", Json::Bool(c.parallel.pin_memory)),
        ("cp_degree", Json::int(c.parallel.cp_degree)),
        ("max_context", c.max_context.map(Json::int).unwrap_or(Json::Null)),
        ("max_context_label", ctx_label),
        ("max_context_capped", Json::Bool(c.hit_cap)),
        ("max_ctx_peak_gib", num_or_null(c.max_ctx_peak_gib)),
        ("max_ctx_tok_s_per_gpu", num_or_null(c.max_ctx_tok_s_gpu)),
        ("ref_peak_gib", num_or_null(c.ref_peak_gib)),
        ("ref_tok_s_per_gpu", num_or_null(c.ref_tok_s_gpu)),
        ("pareto", Json::Bool(c.pareto)),
    ])
}

fn outcome_json(out: &PlanOutcome, configs: Vec<Json>) -> Json {
    let cache = Json::obj(vec![
        ("hits", Json::int(out.cache_hits)),
        ("misses", Json::int(out.cache_misses)),
    ]);
    Json::obj(vec![
        ("model", Json::string(out.model.name)),
        ("cluster", Json::string(out.cluster.name)),
        ("gpus", Json::int(out.cluster.total_gpus())),
        ("reference_s", Json::int(out.reference_s)),
        ("quantum", Json::int(out.quantum)),
        ("configs", Json::Arr(configs)),
        ("simulations", Json::int(out.simulations)),
        ("trace_cache", cache),
        ("wall_s", Json::Num(out.wall_s)),
    ])
}

/// Machine-readable plan (`repro plan --json`).
pub fn plan_json(out: &PlanOutcome) -> Json {
    outcome_json(out, out.configs.iter().map(config_json).collect())
}

/// Machine-readable frontier (`repro frontier --json`).
pub fn frontier_json(out: &PlanOutcome) -> Json {
    outcome_json(out, out.frontier().into_iter().map(config_json).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::model::ModelDims;
    use crate::planner::{plan, PlanRequest};

    fn small_plan() -> PlanOutcome {
        let mut req = PlanRequest::new(ModelDims::llama3_8b(), ClusterConfig::h100_node());
        req.quantum = 1 << 20;
        req.cap_s = 8 << 20;
        req.threads = 2;
        plan(&req)
    }

    #[test]
    fn tables_render() {
        let out = small_plan();
        let t = plan_table(&out).render();
        assert!(t.contains("UPipe"));
        assert!(t.contains("llama3-8b"));
        let f = frontier_table(&out).render();
        assert!(f.contains("Pareto frontier"));
    }

    #[test]
    fn capped_max_context_is_marked_as_lower_bound() {
        let mut req = PlanRequest::new(ModelDims::llama3_8b(), ClusterConfig::h100_node());
        req.quantum = 1 << 20;
        req.cap_s = 4 << 20; // below UPipe's 5M wall: the cap binds
        req.threads = 2;
        let out = plan(&req);
        let top = out.configs.first().unwrap();
        assert!(top.hit_cap);
        assert_eq!(max_ctx_label(top), ">=4M");
        let j = plan_json(&out).render();
        assert!(j.contains("\"max_context_capped\":true"));
    }

    #[test]
    fn json_has_ranking_and_cells() {
        let out = small_plan();
        let j = plan_json(&out).pretty();
        assert!(j.contains("\"model\": \"llama3-8b\""));
        assert!(j.contains("\"method\": \"UPipe\""));
        assert!(j.contains("\"max_context_label\": \"5M\""));
        assert!(j.starts_with('{') && j.ends_with('}'));
        let fj = frontier_json(&out).render();
        assert!(fj.contains("\"pareto\":true"));
    }
}
