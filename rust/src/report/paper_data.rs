//! The published numbers (Tables 3, 4, 5 of the paper), used as reference
//! columns in the regenerated tables and for the EXPERIMENTS.md deltas.
//! `None` = OOM / failure / not reported.

/// Table 3/4 column sequence lengths: 128K..5M (binary).
pub const SEQ_LABELS: [&str; 8] = ["128K", "256K", "512K", "1M", "2M", "3M", "4M", "5M"];

/// Method row order of Tables 3 and 4.
pub const METHOD_LABELS: [&str; 5] = ["Native PyTorch", "Ring", "Ulysses", "FPDT", "UPipe"];

/// Table 3 (top): Llama3-8B tokens/s/GPU on 8×H100.
pub const T3_LLAMA: [[Option<f64>; 8]; 5] = [
    [Some(1373.87), Some(845.99), Some(474.30), Some(249.85), None, None, None, None],
    [Some(2064.90), Some(1387.67), Some(841.05), Some(458.51), Some(237.99), Some(159.96), None, None],
    [Some(2320.47), Some(1503.80), Some(878.63), Some(475.33), Some(246.05), Some(162.41), None, None],
    [Some(1171.68), Some(884.75), Some(621.20), Some(382.42), Some(219.53), Some(153.48), Some(119.76), None],
    [Some(2281.05), Some(1487.29), Some(867.17), Some(472.53), Some(246.07), Some(166.32), Some(125.56), Some(98.25)],
];

/// Table 3 (bottom): Qwen3-32B tokens/s/GPU on 16×H100.
pub const T3_QWEN: [[Option<f64>; 8]; 5] = [
    [Some(127.03), Some(112.20), Some(91.39), None, None, None, None, None],
    [Some(418.39), Some(308.88), Some(194.44), Some(110.27), Some(58.45), None, None, None],
    [Some(545.29), Some(370.70), Some(217.04), Some(117.02), Some(59.98), None, None, None],
    [Some(286.40), Some(217.85), Some(151.91), Some(95.88), Some(55.41), Some(38.86), Some(27.66), None],
    [Some(483.29), Some(339.56), Some(204.46), Some(113.26), Some(59.56), Some(40.42), Some(29.97), None],
];

/// Table 4 (top): Llama3-8B peak GiB on 8×H100.
pub const T4_LLAMA: [[Option<f64>; 8]; 5] = [
    [Some(25.32), Some(31.40), Some(43.55), Some(67.86), None, None, None, None],
    [Some(21.32), Some(23.40), Some(27.58), Some(35.86), Some(52.49), Some(69.11), None, None],
    [Some(21.26), Some(23.02), Some(26.80), Some(34.35), Some(49.49), Some(64.55), None, None],
    [Some(21.73), Some(22.50), Some(24.03), Some(27.09), Some(35.17), Some(43.35), Some(51.42), None],
    [Some(21.10), Some(22.30), Some(24.70), Some(29.90), Some(40.50), Some(51.10), Some(61.70), Some(72.30)],
];

/// Table 4 (bottom): Qwen3-32B peak GiB on 16×H100.
pub const T4_QWEN: [[Option<f64>; 8]; 5] = [
    [Some(45.81), Some(53.69), Some(69.47), None, None, None, None, None],
    [Some(40.14), Some(41.16), Some(44.22), Some(50.51), Some(63.11), None, None, None],
    [Some(40.13), Some(41.16), Some(44.10), Some(50.27), Some(62.60), None, None, None],
    [Some(38.94), Some(39.47), Some(40.54), Some(42.66), Some(46.91), Some(52.27), Some(57.77), None],
    [Some(39.98), Some(40.84), Some(42.72), Some(46.84), Some(55.65), Some(64.47), Some(73.28), None],
];

/// Table 5 sequence lengths (128K..3M) and component rows
/// (All-to-All, FA3-Fwd, FA3-Bwd, Other, Total) for DS-Ulysses and UPipe.
pub const T5_SEQ_LABELS: [&str; 6] = ["128K", "256K", "512K", "1M", "2M", "3M"];
pub const T5_COMPONENTS: [&str; 5] = ["All-to-All", "FA3-Fwd", "FA3-Bwd", "Other", "Total"];

pub const T5_ULYSSES: [[f64; 6]; 5] = [
    [0.40, 0.90, 1.68, 4.93, 16.30, 42.21],
    [1.58, 6.35, 25.71, 103.49, 421.67, 995.92],
    [2.40, 9.13, 36.74, 146.86, 588.73, 1324.71],
    [3.03, 5.33, 10.08, 19.78, 41.30, 56.31],
    [7.40, 21.72, 74.21, 275.06, 1068.00, 2419.14],
];

pub const T5_UPIPE: [[f64; 6]; 5] = [
    [0.46, 1.10, 2.43, 5.52, 17.12, 34.34],
    [1.51, 6.38, 25.93, 103.92, 417.55, 940.62],
    [2.41, 9.25, 36.99, 147.37, 590.79, 1330.76],
    [2.82, 5.23, 10.10, 19.58, 37.76, 55.52],
    [7.20, 21.96, 75.45, 276.39, 1063.23, 2361.24],
];

/// Headline claims (Fig. 1 / abstract).
pub const MAX_CTX_LLAMA_UPIPE: &str = "5M";
pub const MAX_CTX_LLAMA_FPDT: &str = "4M";
pub const MAX_CTX_2NODE_UPIPE: &str = "8M";
pub const MAX_CTX_2NODE_USP: &str = "6M";
pub const QWEN_INTERMEDIATE_SAVINGS: f64 = 0.875;

/// Format a paper cell for table printing.
pub fn cell(v: Option<f64>) -> String {
    match v {
        Some(x) => format!("{x:.2}"),
        None => "OOM/-".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_totals_are_component_sums() {
        for col in 0..6 {
            for t in [&T5_ULYSSES, &T5_UPIPE] {
                let sum: f64 = (0..4).map(|r| t[r][col]).sum();
                assert!((sum - t[4][col]).abs() / t[4][col] < 0.01, "col {col}");
            }
        }
    }

    #[test]
    fn tables_have_consistent_oom_patterns() {
        // once a method OOMs it stays OOM at longer contexts
        for t in [&T3_LLAMA, &T3_QWEN, &T4_LLAMA, &T4_QWEN] {
            for row in t.iter() {
                let mut seen_none = false;
                for c in row {
                    if c.is_none() {
                        seen_none = true;
                    } else {
                        assert!(!seen_none, "non-OOM after OOM");
                    }
                }
            }
        }
    }

    #[test]
    fn t3_t4_oom_patterns_agree() {
        for (a, b) in [(&T3_LLAMA, &T4_LLAMA), (&T3_QWEN, &T4_QWEN)] {
            for (ra, rb) in a.iter().zip(b.iter()) {
                for (ca, cb) in ra.iter().zip(rb.iter()) {
                    assert_eq!(ca.is_some(), cb.is_some());
                }
            }
        }
    }
}
