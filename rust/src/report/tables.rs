//! Regenerate paper Tables 1–6.

use crate::config::presets::{
    llama_single_node, llama_single_node_methods, qwen_two_node, qwen_two_node_methods,
    table34_seq_lens,
};
use crate::config::CpMethod;
use crate::model::activation::{table1, FwdStage};
use crate::model::attn_memory::{
    bwd_units, fwd_units, AttnMethod, BWD_PHASES, FWD_PHASES,
};
use crate::model::ModelDims;
use crate::schedule::simulate;
use crate::util::fmt::{gib, tokens, GIB};
use crate::util::table::Table;

use super::paper_data as paper;

/// Table 1: theoretical peak memory by forward stage, as `k·S·d_model`
/// coefficients (paper's canonical ratios) for a given model.
pub fn table1_report(m: &ModelDims, s: u64) -> Table {
    let mut t = Table::new(
        &format!("Table 1 — fwd-stage memory, {} @ S={}", m.name, tokens(s)),
        &["Stage", "Inputs", "Intermediate", "Outputs", "Total", "k·S·d_model"],
    );
    for row in table1(m, s) {
        let name = match row.stage {
            FwdStage::Embedding => "Embedding",
            FwdStage::Attention => "Attention",
            FwdStage::FeedForward => "Feed-forward",
            FwdStage::CrossEntropy => "Cross-Entropy",
        };
        t.row(vec![
            name.into(),
            gib(row.inputs),
            gib(row.intermediate),
            gib(row.outputs),
            gib(row.total()),
            format!("{:.1}", row.coeff(m, s)),
        ]);
    }
    t.note("bytes columns in GiB; paper coefficients 2/16/25/240 hold at the canonical ratios");
    t
}

fn attn_methods(m: &ModelDims, c: u64) -> Vec<AttnMethod> {
    vec![
        AttnMethod::Ulysses,
        AttnMethod::UlyssesOffload,
        AttnMethod::Fpdt { pi: 16 },
        AttnMethod::Upipe { nu: (m.n_heads / c) as u32 },
    ]
}

/// Table 2: forward attention-block peak by method/phase in S/C units.
pub fn table2_report(m: &ModelDims, c: u64) -> Table {
    let mut t = Table::new(
        &format!("Table 2 — fwd attention peak (S/C units), {} C={c}", m.name),
        &["Method", "before", "inp_a2a", "attn", "out_a2a"],
    );
    for meth in attn_methods(m, c) {
        let mut row = vec![meth.label()];
        for ph in FWD_PHASES {
            row.push(format!("{:.2}", fwd_units(m, meth, ph)));
        }
        t.row(row);
    }
    t.note(&format!("γ = {:.2}, ν = H/U = {}, π = 16", m.gamma(), m.n_heads / c));
    t
}

/// Table 6: backward attention-block peak by method/phase in S/C units.
pub fn table6_report(m: &ModelDims, c: u64) -> Table {
    let mut t = Table::new(
        &format!("Table 6 — bwd attention peak (S/C units), {} C={c}", m.name),
        &["Method", "before", "out_a2a", "bwd attn", "inp_a2a"],
    );
    for meth in attn_methods(m, c) {
        let mut row = vec![meth.label()];
        for ph in BWD_PHASES {
            row.push(format!("{:.2}", bwd_units(m, meth, ph)));
        }
        t.row(row);
    }
    t.note(&format!("β = {:.2}", m.beta()));
    t
}

fn grid_methods(qwen: bool) -> Vec<CpMethod> {
    if qwen {
        qwen_two_node_methods()
    } else {
        llama_single_node_methods()
    }
}

fn grid_cell(qwen: bool, method: CpMethod, s: u64) -> crate::engine::StepReport {
    if qwen {
        simulate(&qwen_two_node(method, s))
    } else {
        simulate(&llama_single_node(method, s))
    }
}

/// Table 3: throughput (tokens/s/GPU) grid, simulated vs paper.
pub fn table3_report(qwen: bool) -> Table {
    let (name, gpus, paper_t) = if qwen {
        ("Qwen3-32B 16xH100", 16, &paper::T3_QWEN)
    } else {
        ("Llama3-8B 8xH100", 8, &paper::T3_LLAMA)
    };
    let mut header = vec!["Method".to_string()];
    for l in paper::SEQ_LABELS {
        header.push(l.to_string());
    }
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        &format!("Table 3 — tokens/s/GPU, {name} (sim | paper)"),
        &hdr,
    );
    for (mi, method) in grid_methods(qwen).into_iter().enumerate() {
        let mut row = vec![method.label().to_string()];
        for (si, &s) in table34_seq_lens().iter().enumerate() {
            let r = grid_cell(qwen, method, s);
            let sim = r
                .tokens_per_sec_per_gpu(s, gpus)
                .map(|v| format!("{v:.0}"))
                .unwrap_or_else(|| "OOM".into());
            row.push(format!("{sim}|{}", paper::cell(paper_t[mi][si])));
        }
        t.row(row);
    }
    t.note("cell = simulated | paper; OOM/- = out of memory or failure");
    t
}

/// Table 4: peak memory (GiB) grid, simulated vs paper.
pub fn table4_report(qwen: bool) -> Table {
    let (name, paper_t) = if qwen {
        ("Qwen3-32B 16xH100", &paper::T4_QWEN)
    } else {
        ("Llama3-8B 8xH100", &paper::T4_LLAMA)
    };
    let mut header = vec!["Method".to_string()];
    for l in paper::SEQ_LABELS {
        header.push(l.to_string());
    }
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&format!("Table 4 — peak GiB, {name} (sim | paper)"), &hdr);
    for (mi, method) in grid_methods(qwen).into_iter().enumerate() {
        let mut row = vec![method.label().to_string()];
        for (si, &s) in table34_seq_lens().iter().enumerate() {
            let r = grid_cell(qwen, method, s);
            let sim = if r.oom || r.failed.is_some() {
                "OOM".to_string()
            } else {
                format!("{:.1}", r.peak_bytes / GIB)
            };
            row.push(format!("{sim}|{}", paper::cell(paper_t[mi][si])));
        }
        t.row(row);
    }
    t
}

/// Table 5: runtime component breakdown, Ulysses vs UPipe, Llama3-8B.
pub fn table5_report() -> Table {
    let mut header = vec!["Method/Component".to_string()];
    for l in paper::T5_SEQ_LABELS {
        header.push(l.to_string());
    }
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Table 5 — step-time breakdown (s), Llama3-8B 8xH100 (sim | paper)",
        &hdr,
    );
    let seqs: Vec<u64> = paper::T5_SEQ_LABELS
        .iter()
        .map(|l| crate::util::fmt::parse_tokens(l).unwrap())
        .collect();
    for (method, paper_t, label) in [
        (CpMethod::Ulysses, &paper::T5_ULYSSES, "DS-Ulysses"),
        (CpMethod::Upipe { u: 8, gqa_schedule: true }, &paper::T5_UPIPE, "UPipe"),
    ] {
        let reports: Vec<_> = seqs
            .iter()
            .map(|&s| simulate(&llama_single_node(method, s)))
            .collect();
        for (ci, comp) in paper::T5_COMPONENTS.iter().enumerate() {
            let mut row = vec![format!("{label} {comp}")];
            for (si, r) in reports.iter().enumerate() {
                let sim = match ci {
                    0 => r.components.all_to_all,
                    1 => r.components.fa3_fwd,
                    2 => r.components.fa3_bwd,
                    3 => r.components.other,
                    _ => r.step_time,
                };
                row.push(format!("{sim:.2}|{:.2}", paper_t[ci][si]));
            }
            t.row(row);
        }
    }
    t
}

/// Mean absolute relative deviation vs paper over all non-OOM cells of
/// Tables 3+4 (quality metric for EXPERIMENTS.md).
pub fn grid_deviation(qwen: bool) -> (f64, usize) {
    let (gpus, t3, t4) = if qwen {
        (16, &paper::T3_QWEN, &paper::T4_QWEN)
    } else {
        (8, &paper::T3_LLAMA, &paper::T4_LLAMA)
    };
    let mut total = 0.0;
    let mut n = 0;
    for (mi, method) in grid_methods(qwen).into_iter().enumerate() {
        for (si, &s) in table34_seq_lens().iter().enumerate() {
            let r = grid_cell(qwen, method, s);
            if let (Some(p), Some(sim)) = (t3[mi][si], r.tokens_per_sec_per_gpu(s, gpus)) {
                total += (sim - p).abs() / p;
                n += 1;
            }
            if let Some(p) = t4[mi][si] {
                if !r.oom && r.failed.is_none() {
                    total += (r.peak_bytes / GIB - p).abs() / p;
                    n += 1;
                }
            }
        }
    }
    (total / n as f64, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_renders() {
        let t = table1_report(&ModelDims::llama3_8b(), 1 << 20);
        assert!(t.render().contains("Cross-Entropy"));
    }

    #[test]
    fn table2_upipe_row_small() {
        let r = table2_report(&ModelDims::qwen3_32b(), 8).render();
        assert!(r.contains("Untied Ulysses"));
    }

    #[test]
    fn llama_grid_deviation_under_10_percent() {
        let (dev, n) = grid_deviation(false);
        assert!(n > 50, "n={n}");
        assert!(dev < 0.10, "mean deviation {dev:.3}");
    }

    #[test]
    fn qwen_grid_deviation_under_12_percent() {
        let (dev, n) = grid_deviation(true);
        assert!(n > 40, "n={n}");
        assert!(dev < 0.12, "mean deviation {dev:.3}");
    }
}
