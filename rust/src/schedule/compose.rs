//! UPipe × FPDT composition (paper §5.3.2: "our method should be
//! composable with FPDT due to orthogonal chunking dimensions, allowing
//! benefits from both the methods").
//!
//! Heads are untied into ν = H/U stages (UPipe) *and* each stage's
//! attention is chunked along the sequence into π pieces whose KV is
//! offloaded to host between uses (FPDT). Peak attention transients become
//! O(U/ν·π⁻¹)-ish — effectively bounded by the misc set — at the cost of
//! FPDT's stall behaviour. This is the paper's future-work point, built as
//! a first-class schedule (`CpMethod::UpipeFpdt`, `repro compose`).

use super::common::ScheduleCtx;
use super::gqa::gqa_schedule;
use crate::engine::{Category, Op, OpSink, TraceBuilder};
use crate::model::flops;

/// Collect one training step as a `Vec<Op>` (the priced path).
pub fn trace(ctx: &ScheduleCtx, u: u32, pi: u32) -> Vec<Op> {
    let mut b = TraceBuilder::new();
    emit(ctx, &mut b, u, pi);
    b.finish()
}

/// Emit one training step into any sink.
pub fn emit<S: OpSink>(ctx: &ScheduleCtx, b: &mut TraceBuilder<S>, u: u32, pi: u32) {
    let q = &ctx.q;
    let cal = &ctx.cal;
    let m = &q.m;
    let stages = gqa_schedule(m.n_heads, m.n_kv_heads, u as u64);
    let nu = stages.len() as f64;
    let p = pi as f64;
    let f = cal.attn_transient_factor;
    let attn_fwd = q.attn_flops_layer_fwd();
    let a2a_frac = (q.c - 1) as f64 / q.c as f64;
    // TP ranks each own 1/tp of every stage's heads (see upipe.rs).
    let head_bytes = 2.0 * q.sc as f64 * m.d_head as f64 / q.tp as f64;
    let l = m.n_layers;
    // FPDT-style residual-stream chunking: the misc set shrinks to the
    // chunked variant, plus FPDT's offload engine + staging.
    let misc = q.emit_misc_chunked(b);
    let engine = b.alloc("fpdt_offload_engine", cal.fpdt_extra_base);
    let staging = b.alloc("fpdt_pinned_staging", 1.3 * q.x_bytes / p);

    for _ in 0..ctx.mb {
        let mut ac = ctx.ac_emitter();

        for _ in 0..l {
            if b.done() {
                return;
            }
            b.snapshot("before_attn");
            // out buffer also sequence-chunked and offloaded per piece
            let out_buf = b.alloc("compose_out_chunk", q.q_bytes / p);
            for st in &stages {
                let qb = st.q_heads.len() as f64 * head_bytes;
                let kvb = 2.0 * st.new_kv_heads.len() as f64 * head_bytes;
                let calls = if st.new_kv_heads.is_empty() { 1 } else { 3 };
                for _ in 0..pi {
                    if b.done() {
                        return;
                    }
                    let chunk = b.alloc("compose_qkv_chunk", (qb + kvb) / p * f);
                    b.all_to_all((qb + kvb) / p * a2a_frac, q.nodes == 1, calls, q.s as f64);
                    b.snapshot("inp_all_to_all");
                    b.compute(Category::Fa3Fwd, attn_fwd / nu / p);
                    b.all_to_all(qb / p * a2a_frac, q.nodes == 1, 1, q.s as f64);
                    b.offload(2.0 * kvb / p, true); // KV chunk to host
                    b.free(chunk);
                }
            }
            b.free(out_buf);
            ctx.emit_tp_allreduce(b);
            ac.store(b);
        }

        let beta_extra = m.beta() - m.gamma();
        for _ in 0..l {
            if b.done() {
                return;
            }
            ac.fetch(b);
            if ac.recompute() {
                b.compute(Category::Fa3Fwd, attn_fwd); // AC recompute
            }
            b.snapshot("before_bwd_attn");
            let dout_buf = b.alloc("compose_recomputed_out_chunk", q.q_bytes / p * f);
            for st in &stages {
                let qb = st.q_heads.len() as f64 * head_bytes;
                let kvb = 2.0 * st.new_kv_heads.len() as f64 * head_bytes;
                let calls = if st.new_kv_heads.is_empty() { 1 } else { 3 };
                for _ in 0..pi {
                    if b.done() {
                        return;
                    }
                    b.offload(-(2.0 * kvb) / p, true); // fetch KV chunk
                    let chunk = b.alloc(
                        "compose_bwd_chunk",
                        ((qb + kvb) + beta_extra / nu * q.q_bytes) / p * f,
                    );
                    b.all_to_all(qb / p * a2a_frac, q.nodes == 1, 1, q.s as f64);
                    b.compute(Category::Fa3Bwd, attn_fwd * flops::ATTN_BWD_FACTOR / nu / p);
                    b.snapshot("bwd_attn_kernel");
                    b.all_to_all((qb + kvb) / p * a2a_frac, q.nodes == 1, calls, q.s as f64);
                    b.free(chunk);
                }
            }
            b.free(dout_buf);
            ctx.emit_tp_allreduce(b);
        }
        ac.finish(b);
    }

    // both overheads: UPipe's extra launches are inside the a2a calls;
    // FPDT's CPU stall applies to the sequence chunking.
    b.fixed(
        Category::Other,
        cal.fpdt_stall(q.s as f64, m.n_layers) * ctx.mb as f64,
    );
    ctx.emit_other(b, 1.0);
    b.free(staging);
    b.free(engine);
    b.free_all(misc);
}

#[cfg(test)]
mod tests {
    use crate::config::presets::llama_single_node;
    use crate::config::CpMethod;
    use crate::engine::ops::validate_trace;
    use crate::schedule::{build_trace, simulate};

    fn run(s: u64) -> crate::engine::StepReport {
        let p = llama_single_node(CpMethod::UpipeFpdt { u: 8, pi: 16 }, s);
        validate_trace(&build_trace(&p)).unwrap();
        simulate(&p)
    }

    #[test]
    fn composition_uses_less_memory_than_either_parent() {
        let s = 3 << 20;
        let comp = run(s);
        let upipe = simulate(&llama_single_node(
            CpMethod::Upipe { u: 8, gqa_schedule: true },
            s,
        ));
        let fpdt = simulate(&llama_single_node(CpMethod::Fpdt { pi: 16 }, s));
        assert!(comp.peak_bytes < upipe.peak_bytes);
        assert!(comp.peak_bytes < fpdt.peak_bytes);
    }

    #[test]
    fn composition_extends_context_beyond_5m() {
        // the benefit the paper anticipates: past UPipe's single-node wall
        assert!(!run(5 << 20).oom);
        assert!(!run(6 << 20).oom, "composed method should pass 5M");
        assert!(!run(8 << 20).oom);
    }

    #[test]
    fn composition_pays_fpdt_throughput() {
        let s = 1 << 20;
        let comp = run(s);
        let upipe = simulate(&llama_single_node(
            CpMethod::Upipe { u: 8, gqa_schedule: true },
            s,
        ));
        assert!(comp.step_time > upipe.step_time, "stalls cost throughput");
    }
}
