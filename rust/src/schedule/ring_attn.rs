//! Ring Attention schedule (Liu et al. 2023; USP zigzag-load-balanced
//! implementation). KV shards circulate the ring in C−1 P2P rounds per
//! attention; no all-to-all, but O(C) communication calls (§2.1).

use super::common::ScheduleCtx;
use crate::engine::{Category, Op, OpSink, TraceBuilder};
use crate::model::flops;

pub fn trace(ctx: &ScheduleCtx) -> Vec<Op> {
    trace_with(ctx, ctx.q.c, ctx.q.nodes > 1)
}

pub fn emit<S: OpSink>(ctx: &ScheduleCtx, b: &mut TraceBuilder<S>) {
    emit_with(ctx, b, ctx.q.c, ctx.q.nodes > 1)
}

/// `ring_c` ranks participate in the ring; `inter` if it crosses nodes.
/// (USP-Hybrid reuses this for its ring dimension.)
pub fn trace_with(ctx: &ScheduleCtx, ring_c: u64, inter: bool) -> Vec<Op> {
    let mut b = TraceBuilder::new();
    emit_with(ctx, &mut b, ring_c, inter);
    b.finish()
}

/// Streaming form of [`trace_with`].
pub fn emit_with<S: OpSink>(ctx: &ScheduleCtx, b: &mut TraceBuilder<S>, ring_c: u64, inter: bool) {
    let q = &ctx.q;
    let cal = &ctx.cal;
    let f = cal.attn_transient_factor;
    let attn_fwd = q.attn_flops_layer_fwd();
    let l = q.m.n_layers;
    let steps = ring_c - 1;
    let misc = q.emit_misc(b);
    // Inter-node rings keep per-peer IB-transport staging buffers pinned
    // for the whole step (fit to the Qwen Ring column, see calibration).
    let staging = inter.then(|| {
        let peers = (ring_c.min(8) - 1) as f64;
        b.alloc("ring_ib_staging", peers * 2.0 * q.kv_bytes * f)
    });

    for _ in 0..ctx.mb {
        let mut ac = ctx.ac_emitter();

        for _ in 0..l {
            if b.done() {
                return;
            }
            b.snapshot("before_attn");
            // local QKV + two in-flight KV blocks (send/recv double buffer)
            let qkv = b.alloc("ring_qkv_local", q.qkv_bytes() * f);
            let inflight = b.alloc("ring_kv_inflight", 2.0 * 2.0 * q.kv_bytes * f);
            // online-softmax rescale state (out accumulator + lse)
            let lse = b.alloc("ring_lse_out", 0.2 * q.q_bytes);
            b.ring(steps, 2.0 * q.kv_bytes, inter);
            b.snapshot("ring_exchange");
            b.compute(Category::Fa3Fwd, attn_fwd);
            b.snapshot("attn_kernel");
            b.free(lse);
            b.free(inflight);
            b.free(qkv);
            ctx.emit_tp_allreduce(b);
            ac.store(b);
        }

        let beta_extra = (q.m.beta() - q.m.gamma()) * q.q_bytes;
        for _ in 0..l {
            if b.done() {
                return;
            }
            ac.fetch(b);
            if ac.recompute() {
                b.compute(Category::Fa3Fwd, attn_fwd);
            }
            b.snapshot("before_bwd_attn");
            let qkv = b.alloc("ring_qkv_local_bwd", q.qkv_bytes() * f);
            let grads = b.alloc("ring_bwd_set", beta_extra * f);
            // dKV accumulators travel the ring in fp32 (2× bf16 size)
            let dkv = b.alloc("ring_dkv_fp32", 2.0 * 2.0 * q.kv_bytes * f);
            let inflight = b.alloc("ring_kv_inflight_bwd", 2.0 * 2.0 * q.kv_bytes * f);
            // bwd ring: KV forward again + dKV backward
            b.ring(steps, 2.0 * 2.0 * q.kv_bytes, inter);
            b.snapshot("bwd_ring_exchange");
            b.compute(Category::Fa3Bwd, attn_fwd * flops::ATTN_BWD_FACTOR);
            b.snapshot("bwd_attn_kernel");
            b.free(inflight);
            b.free(dkv);
            b.free(grads);
            b.free(qkv);
            ctx.emit_tp_allreduce(b);
        }
        ac.finish(b);
    }

    ctx.emit_other(b, 1.0);
    if let Some(st) = staging {
        b.free(st);
    }
    b.free_all(misc);
}

#[cfg(test)]
mod tests {
    use crate::config::presets::llama_single_node;
    use crate::config::CpMethod;
    use crate::engine::ops::validate_trace;
    use crate::schedule::{build_trace, simulate};

    const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

    fn run(s: u64) -> crate::engine::StepReport {
        let p = llama_single_node(CpMethod::Ring, s);
        validate_trace(&build_trace(&p)).unwrap();
        simulate(&p)
    }

    #[test]
    fn table4_ring_memory_anchors() {
        // Paper Table 4 Ring row: 21.32 @128K, 35.86 @1M, 69.11 @3M.
        for (s, expect) in [(1u64 << 17, 21.32), (1 << 20, 35.86), (3 << 20, 69.11)] {
            let got = run(s).peak_bytes / GIB;
            assert!(
                (got - expect).abs() / expect < 0.08,
                "S={s}: got {got:.2} want {expect}"
            );
        }
    }

    #[test]
    fn ring_ooms_at_4m() {
        assert!(!run(3 << 20).oom);
        assert!(run(4 << 20).oom);
    }

    #[test]
    fn table3_ring_throughput_1m() {
        // Paper: 458.51 tokens/s/GPU @1M.
        let t = run(1 << 20).tokens_per_sec_per_gpu(1 << 20, 8).unwrap();
        assert!((t - 458.51).abs() / 458.51 < 0.08, "tput {t}");
    }

    #[test]
    fn ring_slower_than_ulysses() {
        // §2.1/§5.3: O(C) p2p rounds cost more than one all-to-all.
        let ul = simulate(&llama_single_node(CpMethod::Ulysses, 1 << 20));
        assert!(run(1 << 20).step_time > ul.step_time);
    }
}
