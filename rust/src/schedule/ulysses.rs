//! DeepSpeed-Ulysses schedule (paper §3.1) with the §2.3 mitigations the
//! paper's "Ulysses" baseline uses: tiled MLP/CE (so they contribute no
//! transients here), full AC with CPU offload, and the *sequential*
//! (non-QKVPacked) all-to-all variant — one Q-sized comm buffer at a time.

use super::common::ScheduleCtx;
use crate::engine::{Category, Op, OpSink, TraceBuilder};
use crate::model::flops;

/// Collect one training step as a `Vec<Op>` (the priced path).
pub fn trace(ctx: &ScheduleCtx) -> Vec<Op> {
    let mut b = TraceBuilder::new();
    emit(ctx, &mut b);
    b.finish()
}

/// Emit one training step into any sink. Peak behaviour reproduces Table
/// 2/6 rows 1–2: full-head QKV (γ·q_bytes) plus a comm buffer live through
/// the attention phase; backward adds the β-set. The AC mode, micro-batch
/// count and calibration all come from the [`ScheduleCtx`].
pub fn emit<S: OpSink>(ctx: &ScheduleCtx, b: &mut TraceBuilder<S>) {
    let q = &ctx.q;
    let cal = &ctx.cal;
    let l = q.m.n_layers;
    let f = cal.attn_transient_factor;
    let attn_fwd = q.attn_flops_layer_fwd();
    let a2a_frac = (q.c - 1) as f64 / q.c as f64;
    let misc = q.emit_misc(b);

    for _ in 0..ctx.mb {
        let mut ac = ctx.ac_emitter();

        // ---------------- forward ----------------
        for _ in 0..l {
            if b.done() {
                return;
            }
            b.snapshot("before_attn");
            // project into full-head QKV (+ FA3 workspace factor)
            let qkv = b.alloc("qkv_fullhead", q.qkv_bytes() * f);
            let comm = b.alloc("a2a_buffer", q.q_bytes * f);
            // sequential Q, K, V all-to-alls (3 calls)
            b.all_to_all(q.qkv_bytes() * a2a_frac, true, 3, q.s as f64);
            b.snapshot("inp_all_to_all");
            b.compute(Category::Fa3Fwd, attn_fwd);
            b.snapshot("attn_kernel");
            // out all-to-all (1 call)
            b.all_to_all(q.q_bytes * a2a_frac, true, 1, q.s as f64);
            b.snapshot("out_all_to_all");
            b.free(comm);
            b.free(qkv);
            ctx.emit_tp_allreduce(b);
            ac.store(b);
        }

        // ---------------- backward (reverse layer order) ----------------
        for _ in 0..l {
            if b.done() {
                return;
            }
            ac.fetch(b);
            if ac.recompute() {
                // recompute forward (same kernels; shows up in FA3-Fwd timing)
                b.compute(Category::Fa3Fwd, attn_fwd);
            }
            b.snapshot("before_bwd_attn");
            // dOut arrives via out_all_to_all
            let comm = b.alloc("a2a_buffer_bwd", q.q_bytes * f);
            b.all_to_all(q.q_bytes * a2a_frac, true, 1, q.s as f64);
            b.snapshot("bwd_out_all_to_all");
            // the β-set: Q,K,V,Out,dOut,dQ,dK,dV live during the bwd kernel,
            // plus the received full-head dOut in head layout.
            let beta_extra = (q.m.beta() - q.m.gamma()) * q.q_bytes; // beyond QKV
            let qkv = b.alloc("qkv_fullhead_bwd", q.qkv_bytes() * f);
            let dout = b.alloc("dout_heads", q.q_bytes * f);
            let grads = b.alloc("attn_bwd_set", beta_extra * f);
            b.compute(Category::Fa3Bwd, attn_fwd * flops::ATTN_BWD_FACTOR);
            b.snapshot("bwd_attn_kernel");
            // dQKV go back through the inp all-to-all (3 calls)
            b.all_to_all(q.qkv_bytes() * a2a_frac, true, 3, q.s as f64);
            b.snapshot("bwd_inp_all_to_all");
            b.free(grads);
            b.free(dout);
            b.free(qkv);
            b.free(comm);
            ctx.emit_tp_allreduce(b);
        }
        ac.finish(b);
    }

    // bulk "other": projections, tiled MLP/CE, norms, optimizer, offload
    // engine overhead.
    ctx.emit_other(b, 1.0);
    b.free_all(misc);
}

#[cfg(test)]
mod tests {
    use super::super::common::AcMode;
    use crate::config::presets::llama_single_node;
    use crate::config::CpMethod;
    use crate::engine::ops::validate_trace;
    use crate::schedule::{build_trace, simulate};

    fn run(s: u64, ac: AcMode) -> crate::engine::StepReport {
        let mut p = llama_single_node(CpMethod::Ulysses, s);
        p.parallel.ac_mode = ac;
        validate_trace(&build_trace(&p)).unwrap();
        simulate(&p)
    }

    #[test]
    fn table5_ulysses_1m_within_tolerance() {
        // Paper Table 5, DS-Ulysses @1M: a2a 4.93, fwd 103.49, bwd 146.86,
        // other 19.78, total 275.06. This is the calibration anchor — it
        // must land within a few percent.
        let r = run(1 << 20, AcMode::AcOffload);
        let c = &r.components;
        assert!((c.fa3_fwd - 103.49).abs() / 103.49 < 0.05, "fwd {}", c.fa3_fwd);
        assert!((c.fa3_bwd - 146.86).abs() / 146.86 < 0.05, "bwd {}", c.fa3_bwd);
        assert!((c.all_to_all - 4.93).abs() / 4.93 < 0.25, "a2a {}", c.all_to_all);
        assert!((c.other - 19.78).abs() / 19.78 < 0.15, "other {}", c.other);
        assert!((r.step_time - 275.06).abs() / 275.06 < 0.06, "total {}", r.step_time);
    }

    #[test]
    fn table4_ulysses_memory_anchors() {
        // Paper Table 4 Ulysses row: 21.26 GiB @128K, 34.35 @1M, 64.55 @3M.
        const GIB: f64 = 1024.0 * 1024.0 * 1024.0;
        for (s, expect) in [(1u64 << 17, 21.26), (1 << 20, 34.35), (3 << 20, 64.55)] {
            let r = run(s, AcMode::AcOffload);
            let got = r.peak_bytes / GIB;
            assert!(
                (got - expect).abs() / expect < 0.06,
                "S={s}: got {got:.2} GiB want {expect}"
            );
        }
    }

    #[test]
    fn ulysses_ooms_at_4m() {
        // Paper: Ulysses OOMs at 4M on the single node.
        assert!(!run(3 << 20, AcMode::AcOffload).oom);
        assert!(run(4 << 20, AcMode::AcOffload).oom);
    }

    #[test]
    fn noac_much_larger_than_offload() {
        let off = run(1 << 19, AcMode::AcOffload);
        let noac = run(1 << 19, AcMode::NoAc);
        assert!(noac.peak_bytes > 2.0 * off.peak_bytes);
        let acgpu = run(1 << 19, AcMode::AcGpu);
        assert!(acgpu.peak_bytes > off.peak_bytes);
        assert!(acgpu.peak_bytes < noac.peak_bytes);
    }

    #[test]
    fn throughput_matches_table3() {
        // Table 3 @1M: 475.33 tokens/s/GPU.
        let r = run(1 << 20, AcMode::AcOffload);
        let t = r.tokens_per_sec_per_gpu(1 << 20, 8).unwrap();
        assert!((t - 475.33).abs() / 475.33 < 0.06, "tput {t}");
    }

    #[test]
    fn microbatches_accumulate_time_not_memory() {
        let base = run(1 << 20, AcMode::AcOffload);
        let mut p = llama_single_node(CpMethod::Ulysses, 1 << 20);
        p.parallel.micro_batch = 2;
        validate_trace(&build_trace(&p)).unwrap();
        let mb2 = simulate(&p);
        assert!((mb2.step_time / base.step_time - 2.0).abs() < 0.01, "2x work");
        assert!((mb2.peak_bytes - base.peak_bytes).abs() < 1.0, "same peak");
    }
}
