//! UPipe's GQA scheduling (paper §4.1, Fig. 4): process query heads
//! out-of-order so that each KV group's K/V heads are communicated exactly
//! once, in the first stage where the group appears; subsequent stages
//! reuse the rank-local KV and communicate queries only.

/// One UPipe stage: which query heads are processed and which KV heads
/// must be communicated (empty ⇒ reuse).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Stage {
    pub q_heads: Vec<u64>,
    pub new_kv_heads: Vec<u64>,
}

/// Naive in-order schedule: stage t takes q-heads [tU, (t+1)U). A KV head
/// is re-communicated every time a stage touches its group without owning
/// its K/V from before — with U < g·Hkv this replicates KV sends across
/// devices (the Fig. 4 "K0, K0, K0, K0" pathology).
pub fn naive_schedule(h: u64, hkv: u64, u: u64) -> Vec<Stage> {
    assert!(h % u == 0);
    let g = h / hkv;
    (0..h / u)
        .map(|t| {
            let q_heads: Vec<u64> = (t * u..(t + 1) * u).collect();
            // Naive processing re-sends the KV head for every query head in
            // the stage (each device needs its own copy of its query's
            // group KV): one KV send per query head.
            let new_kv_heads = q_heads.iter().map(|&q| q / g).collect();
            Stage { q_heads, new_kv_heads }
        })
        .collect()
}

/// Out-of-order GQA schedule: stage t of each g-cycle takes the t-th query
/// of each group; all groups' unique KV heads are sent in the cycle's first
/// stage (one per device), none afterwards.
pub fn gqa_schedule(h: u64, hkv: u64, u: u64) -> Vec<Stage> {
    assert!(h % u == 0);
    let g = h / hkv;
    let n_groups = hkv;
    let mut stages = Vec::new();
    // Walk query-index-within-group (t), then split the groups into
    // U-head stages. Groups cycle in blocks of `u` so the KV sent in a
    // block's first stage covers exactly the groups revisited for g stages.
    let groups_per_stage = u.min(n_groups);
    let group_blocks = n_groups.div_ceil(groups_per_stage);
    for blk in 0..group_blocks {
        let groups: Vec<u64> = (blk * groups_per_stage
            ..((blk + 1) * groups_per_stage).min(n_groups))
            .collect();
        // q-indices within the group, `u / groups_per_stage` of them per
        // stage (u divides g·groups when u <= hkv; general case walks t).
        let per_group_per_stage = (u / groups.len() as u64).max(1);
        let mut t = 0;
        while t < g {
            let mut q_heads = Vec::new();
            for &grp in &groups {
                for dt in 0..per_group_per_stage.min(g - t) {
                    q_heads.push(grp * g + t + dt);
                }
            }
            let new_kv_heads = if t == 0 { groups.clone() } else { Vec::new() };
            stages.push(Stage { q_heads, new_kv_heads });
            t += per_group_per_stage;
        }
    }
    stages
}

/// Communication volume of a schedule in "head-sends" (full-sequence heads
/// communicated per device across all stages): queries + K and V sends.
/// The §4.1 comparison: naive O(3·H), GQA O((3 + g − 1)·H/g).
pub fn comm_volume_heads(stages: &[Stage]) -> u64 {
    stages
        .iter()
        .map(|s| s.q_heads.len() as u64 + 2 * s.new_kv_heads.len() as u64)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn covers_all_heads(stages: &[Stage], h: u64) -> bool {
        let mut seen: Vec<u64> = stages.iter().flat_map(|s| s.q_heads.clone()).collect();
        seen.sort();
        seen == (0..h).collect::<Vec<_>>()
    }

    #[test]
    fn paper_fig4_example() {
        // C=4, G=4, H=16, Hkv=4, U=4: stage 0 sends Q0,Q4,Q8,Q12 + K0..K3;
        // stages 1..3 send only queries.
        let stages = gqa_schedule(16, 4, 4);
        assert_eq!(stages.len(), 4);
        assert_eq!(stages[0].q_heads, vec![0, 4, 8, 12]);
        assert_eq!(stages[0].new_kv_heads, vec![0, 1, 2, 3]);
        assert_eq!(stages[1].q_heads, vec![1, 5, 9, 13]);
        assert!(stages[1].new_kv_heads.is_empty());
        assert!(covers_all_heads(&stages, 16));
    }

    #[test]
    fn volume_reduction_matches_section_41() {
        // naive: 3 sends per head = 3H; GQA: (3+g-1)·H/g.
        let (h, hkv, u) = (16u64, 4u64, 4u64);
        let g = h / hkv;
        let naive = comm_volume_heads(&naive_schedule(h, hkv, u));
        let gqa = comm_volume_heads(&gqa_schedule(h, hkv, u));
        assert_eq!(naive, 3 * h);
        assert_eq!(gqa, (3 + g - 1) * h / g);
        assert!(gqa < naive);
    }

    #[test]
    fn llama_schedule() {
        // Llama3-8B: H=32, Hkv=8, U=C=8 ⇒ 4 stages of 8 heads; 8 groups
        // split into blocks of 8 ⇒ KV sent once in stage 0 of each g-cycle.
        let stages = gqa_schedule(32, 8, 8);
        assert_eq!(stages.len(), 4);
        assert!(covers_all_heads(&stages, 32));
        let kv_sends: u64 = stages.iter().map(|s| s.new_kv_heads.len() as u64).sum();
        assert_eq!(kv_sends, 8); // each unique KV head exactly once
    }

    #[test]
    fn qwen_schedule() {
        // Qwen3-32B: H=64, Hkv=8, U=8 ⇒ 8 stages; g=8, one group-block.
        let stages = gqa_schedule(64, 8, 8);
        assert_eq!(stages.len(), 8);
        assert!(covers_all_heads(&stages, 64));
        assert_eq!(stages[0].new_kv_heads.len(), 8);
        assert!(stages[1..].iter().all(|s| s.new_kv_heads.is_empty()));
    }

    #[test]
    fn prop_gqa_covers_heads_and_never_resends_kv() {
        prop::check("gqa-cover", 200, &[(0, 3), (0, 4), (0, 3)], |a| {
            let hkv = 1u64 << a[0]; // 1..8
            let g = 1u64 << a[1]; // 1..16
            let h = hkv * g;
            let u = (1u64 << a[2]).min(h); // 1..8
            if h % u != 0 {
                return true; // invalid combo, skip
            }
            let stages = gqa_schedule(h, hkv, u);
            if !covers_all_heads(&stages, h) {
                return false;
            }
            let kv_sends: u64 = stages.iter().map(|s| s.new_kv_heads.len() as u64).sum();
            kv_sends == hkv
        });
    }

    #[test]
    fn prop_gqa_volume_le_naive() {
        prop::check("gqa<=naive", 200, &[(0, 3), (0, 4), (0, 3)], |a| {
            let hkv = 1u64 << a[0];
            let g = 1u64 << a[1];
            let h = hkv * g;
            let u = (1u64 << a[2]).min(h);
            if h % u != 0 {
                return true;
            }
            comm_volume_heads(&gqa_schedule(h, hkv, u))
                <= comm_volume_heads(&naive_schedule(h, hkv, u))
        });
    }
}
