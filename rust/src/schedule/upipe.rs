//! Untied Ulysses (UPipe) schedule — the paper's contribution (§3.3).
//!
//! Attention is untied into ν = H/U stages of U heads. Per stage only the
//! U-head QKV chunk and a chunk-sized comm buffer exist; they are freed at
//! stage end and the allocator's size-bucket cache re-serves the next
//! stage from the same blocks (the paper's "reuse the memory buffers from
//! the previous stage" — observable here as zero growth in reserved bytes
//! after stage 0). The full-head output buffer is initialized once and
//! filled in place (§3.3 "avoids the concatenation of individual chunks").
//!
//! With `gqa_schedule` the §4.1 out-of-order head order is used: KV heads
//! are all-to-all'd only in the first stage of each group cycle; the other
//! stages communicate queries only.

use super::common::ScheduleCtx;
use super::gqa::{gqa_schedule, naive_schedule, Stage};
use crate::engine::{Category, Op, OpSink, TraceBuilder};
use crate::model::flops;

/// Collect one training step as a `Vec<Op>` (the priced path).
pub fn trace(ctx: &ScheduleCtx, u: u32, gqa: bool, hybrid_ring: bool) -> Vec<Op> {
    let mut b = TraceBuilder::new();
    emit(ctx, &mut b, u, gqa, hybrid_ring);
    b.finish()
}

/// Emit one training step into any sink. `hybrid_ring` adds the inter-node
/// ring KV exchange of the UPipe-Hybrid setup (ulysses intra-node × ring
/// across).
pub fn emit<S: OpSink>(
    ctx: &ScheduleCtx,
    b: &mut TraceBuilder<S>,
    u: u32,
    gqa: bool,
    hybrid_ring: bool,
) {
    let q = &ctx.q;
    let cal = &ctx.cal;
    let m = &q.m;
    let stages = if gqa {
        gqa_schedule(m.n_heads, m.n_kv_heads, u as u64)
    } else {
        naive_schedule(m.n_heads, m.n_kv_heads, u as u64)
    };
    let nu = stages.len() as f64;
    let f = cal.attn_transient_factor;
    let attn_fwd = q.attn_flops_layer_fwd();
    // Intra-node ulysses group: the hybrid setup all-to-alls over the node
    // only; the ring dimension is handled separately.
    let a2a_c = if hybrid_ring { q.c / q.nodes } else { q.c };
    let a2a_frac = (a2a_c - 1) as f64 / a2a_c as f64;
    // One head's shard rows; under TP each rank owns 1/tp of every stage's
    // heads, so stage chunk/comm bytes shard like q_bytes/kv_bytes do.
    let head_bytes = 2.0 * q.sc as f64 * m.d_head as f64 / q.tp as f64;
    let misc = q.emit_misc(b);
    // IB-transport staging for the hybrid's inter-node ring (NCCL keeps
    // per-peer send/recv buffers pinned for the whole step).
    let ring_staging = hybrid_ring.then(|| {
        let peers = (q.nodes.min(8) - 1) as f64;
        b.alloc("ring_ib_staging", peers * 2.0 * q.kv_bytes * f)
    });
    let l = m.n_layers;

    let stage_bytes = |st: &Stage| -> (f64, f64, u64) {
        let qb = st.q_heads.len() as f64 * head_bytes;
        let kvb = 2.0 * st.new_kv_heads.len() as f64 * head_bytes;
        // sequential a2a calls: q (1) + k,v when sent (2)
        let calls = if st.new_kv_heads.is_empty() { 1 } else { 3 };
        (qb, kvb, calls)
    };

    for _ in 0..ctx.mb {
        let mut ac = ctx.ac_emitter();

        // ---------------- forward ----------------
        for _ in 0..l {
            if b.done() {
                return;
            }
            b.snapshot("before_attn");
            // full-head output buffer, initialized upfront, filled per stage
            let out_buf = b.alloc("upipe_out_fullhead", q.q_bytes);
            // KV kept across a group cycle for the GQA schedule: at most the
            // stage's unique KV heads (U/g per stage ≥ the resident set).
            let mut kv_resident: Option<usize> = None;
            for st in &stages {
                let (qb, kvb, calls) = stage_bytes(st);
                let chunk = b.alloc("upipe_qkv_chunk", (qb + kvb) * f);
                let comm = b.alloc("upipe_a2a_buffer", qb.max(kvb / 2.0).max(head_bytes) * f);
                b.all_to_all((qb + kvb) * a2a_frac, true, calls, q.s as f64);
                if !st.new_kv_heads.is_empty() {
                    // retain the received KV for the rest of the group cycle
                    if let Some(old) = kv_resident.take() {
                        b.free(old);
                    }
                    kv_resident = Some(b.alloc("upipe_kv_resident", kvb * f));
                }
                b.snapshot("inp_all_to_all");
                b.compute(Category::Fa3Fwd, attn_fwd / nu);
                b.snapshot("attn_kernel");
                b.all_to_all(qb * a2a_frac, true, 1, q.s as f64);
                b.snapshot("out_all_to_all");
                b.free(comm);
                b.free(chunk);
            }
            if let Some(kv) = kv_resident {
                b.free(kv);
            }
            if hybrid_ring {
                // inter-node ring exchange of the node's KV shards
                b.ring(q.nodes - 1, 2.0 * q.kv_bytes, true);
            }
            b.free(out_buf);
            ctx.emit_tp_allreduce(b);
            ac.store(b);
        }

        // ---------------- backward ----------------
        let beta_extra = m.beta() - m.gamma(); // dQ,dK,dV,Out,dOut beyond QKV
        for _ in 0..l {
            if b.done() {
                return;
            }
            ac.fetch(b);
            if ac.recompute() {
                b.compute(Category::Fa3Fwd, attn_fwd); // AC recompute
            }
            b.snapshot("before_bwd_attn");
            // The recomputed full-head block output ("Out" input of FA3-bwd,
            // regenerated by the AC recompute) stays live across the stages.
            let dout_buf = b.alloc("upipe_recomputed_out", q.q_bytes * f);
            let mut kv_resident: Option<usize> = None;
            for st in &stages {
                let (qb, kvb, calls) = stage_bytes(st);
                b.all_to_all(qb * a2a_frac, true, 1, q.s as f64); // dOut chunk in
                let chunk = b.alloc("upipe_bwd_chunk", (qb + kvb) * f);
                if !st.new_kv_heads.is_empty() {
                    if let Some(old) = kv_resident.take() {
                        b.free(old);
                    }
                    kv_resident = Some(b.alloc("upipe_kv_resident_bwd", kvb * f));
                }
                let grads = b.alloc("upipe_bwd_set", beta_extra / nu * q.q_bytes * f);
                b.snapshot("bwd_out_all_to_all");
                b.compute(Category::Fa3Bwd, attn_fwd * flops::ATTN_BWD_FACTOR / nu);
                b.snapshot("bwd_attn_kernel");
                // dQ (+dK,dV when the group cycle closes) back out
                b.all_to_all((qb + kvb) * a2a_frac, true, calls, q.s as f64);
                b.snapshot("bwd_inp_all_to_all");
                b.free(grads);
                b.free(chunk);
            }
            if let Some(kv) = kv_resident {
                b.free(kv);
            }
            if hybrid_ring {
                b.ring(q.nodes - 1, 2.0 * 2.0 * q.kv_bytes, true);
            }
            b.free(dout_buf);
            ctx.emit_tp_allreduce(b);
        }
        ac.finish(b);
    }

    if hybrid_ring {
        b.fixed(Category::Other, cal.hybrid_layer_fixed * l as f64 * ctx.mb as f64);
    }
    ctx.emit_other(b, 1.0);
    if let Some(rs) = ring_staging {
        b.free(rs);
    }
    b.free_all(misc);
}

#[cfg(test)]
mod tests {
    use crate::config::presets::llama_single_node;
    use crate::config::CpMethod;
    use crate::engine::ops::validate_trace;
    use crate::engine::{Calibration, Op};
    use crate::schedule::{build_trace, simulate, ScheduleCtx};

    const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

    fn run(s: u64) -> crate::engine::StepReport {
        let p = llama_single_node(CpMethod::Upipe { u: 8, gqa_schedule: true }, s);
        validate_trace(&build_trace(&p)).unwrap();
        simulate(&p)
    }

    #[test]
    fn table4_upipe_memory_anchors() {
        // Paper Table 4 UPipe row: 21.10 @128K, 29.90 @1M, 51.10 @3M,
        // 72.30 @5M.
        for (s, expect) in [
            (1u64 << 17, 21.10),
            (1 << 20, 29.90),
            (3 << 20, 51.10),
            (5 << 20, 72.30),
        ] {
            let r = run(s);
            let got = r.peak_bytes / GIB;
            assert!(
                (got - expect).abs() / expect < 0.07,
                "S={s}: got {got:.2} want {expect}"
            );
        }
    }

    #[test]
    fn upipe_reaches_5m_ooms_at_6m() {
        assert!(!run(5 << 20).oom, "paper: UPipe trains 5M on one node");
        assert!(run(6 << 20).oom, "paper: 5M is the single-node max");
    }

    #[test]
    fn table5_upipe_components_1m() {
        // Paper Table 5 UPipe @1M: a2a 5.52, fwd 103.92, bwd 147.37,
        // other 19.58, total 276.39.
        let r = run(1 << 20);
        let c = &r.components;
        assert!((c.fa3_fwd - 103.92).abs() / 103.92 < 0.05, "fwd {}", c.fa3_fwd);
        assert!((c.fa3_bwd - 147.37).abs() / 147.37 < 0.05, "bwd {}", c.fa3_bwd);
        assert!((c.all_to_all - 5.52).abs() / 5.52 < 0.3, "a2a {}", c.all_to_all);
        assert!((r.step_time - 276.39).abs() / 276.39 < 0.06, "total {}", r.step_time);
    }

    #[test]
    fn upipe_a2a_beats_ulysses_at_3m() {
        // Table 5 @3M: UPipe a2a 34.34 < Ulysses 42.21 (lower memory
        // pressure ⇒ fewer allocation retries), and total is lower.
        let ul = simulate(&llama_single_node(CpMethod::Ulysses, 3 << 20));
        let up = run(3 << 20);
        assert!(up.components.all_to_all < ul.components.all_to_all);
        assert!(up.step_time < ul.step_time);
        assert!(up.components.fa3_fwd < ul.components.fa3_fwd);
    }

    #[test]
    fn upipe_slightly_slower_at_short_context() {
        // Table 3 @128K: UPipe 2281.05 < Ulysses 2320.47 tokens/s/GPU
        // (stage launch overhead, amortized later).
        let ul = simulate(&llama_single_node(CpMethod::Ulysses, 1 << 17));
        let up = run(1 << 17);
        assert!(up.step_time > ul.step_time);
        // ...but by less than 5%.
        assert!(up.step_time < ul.step_time * 1.05);
    }

    #[test]
    fn memory_independent_of_u_equals_c_head_count() {
        // §3.4: at U=C the attention transient peak is head-count
        // independent; trace peak grows with ν only through the fixed
        // full-head out buffer, so the *transient* chunk sizes must match.
        let p4 = llama_single_node(CpMethod::Upipe { u: 8, gqa_schedule: true }, 1 << 20);
        let ctx = ScheduleCtx::new(&p4, &Calibration::default());
        let tr = super::trace(&ctx, 8, true, false);
        let max_chunk = tr
            .iter()
            .filter_map(|op| match op {
                Op::Alloc { bytes, name, .. } if name.contains("chunk") => Some(*bytes),
                _ => None,
            })
            .fold(0.0, f64::max);
        // one stage's chunk ≤ (q + 2·kv) heads = 3·U·head_bytes·1.3 (the
        // GQA schedule's stage 0 sends all U kv heads once)
        let head_bytes = 2.0 * ctx.q.sc as f64 * ctx.q.m.d_head as f64;
        assert!(max_chunk <= 3.0 * 8.0 * head_bytes * 1.3 + 1.0, "chunk {max_chunk}");
    }

    #[test]
    fn tp_shards_stage_buffers_like_quantities() {
        // tp=2 on the same 8-GPU world: C halves (2x tokens per rank) but
        // each rank owns half of every stage's heads — stage chunks must
        // stay the same size as tp=1, not double.
        let cal = Calibration::default();
        let max_chunk = |p: &crate::config::presets::RunPreset| -> f64 {
            let ctx = ScheduleCtx::new(p, &cal);
            super::trace(&ctx, 8, true, false)
                .iter()
                .filter_map(|op| match op {
                    Op::Alloc { bytes, name, .. } if name.contains("chunk") => Some(*bytes),
                    _ => None,
                })
                .fold(0.0, f64::max)
        };
        let p1 = llama_single_node(CpMethod::Upipe { u: 8, gqa_schedule: true }, 1 << 20);
        let mut p2 = p1.clone();
        p2.parallel.tp = 2;
        p2.parallel.cp_degree = 4;
        let (a, b) = (max_chunk(&p1), max_chunk(&p2));
        assert!((b / a - 1.0).abs() < 1e-9, "tp=2 chunk {b} vs tp=1 {a}");
    }

    #[test]
    fn gqa_schedule_reduces_comm_volume_vs_naive() {
        let p = llama_single_node(CpMethod::Upipe { u: 8, gqa_schedule: true }, 1 << 20);
        let ctx = ScheduleCtx::new(&p, &Calibration::default());
        let vol = |gqa: bool| -> f64 {
            super::trace(&ctx, 8, gqa, false)
                .iter()
                .map(|op| match op {
                    Op::AllToAll { bytes, .. } => *bytes,
                    _ => 0.0,
                })
                .sum()
        };
        assert!(vol(true) < vol(false));
    }
}
