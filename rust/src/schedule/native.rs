//! Native-PyTorch baseline: ring context parallelism with stock kernels —
//! SDPA instead of FA3 (lower attention efficiency), no tiled MLP (full
//! [S/C, d_ff] SwiGLU intermediates), no fused loss (chunked fp32 CE), and
//! fp32 RoPE / norm casts (§2.3 calls out both overheads).

use super::common::ScheduleCtx;
use crate::engine::{Category, Op, OpSink, TraceBuilder};
use crate::model::flops;

/// Collect one training step as a `Vec<Op>` (the priced path).
pub fn trace(ctx: &ScheduleCtx) -> Vec<Op> {
    let mut b = TraceBuilder::new();
    emit(ctx, &mut b);
    b.finish()
}

/// Emit one training step into any sink.
pub fn emit<S: OpSink>(ctx: &ScheduleCtx, b: &mut TraceBuilder<S>) {
    let q = &ctx.q;
    let cal = &ctx.cal;
    let f = cal.attn_transient_factor;
    let slow_path = q.m.q_width() != q.m.d_model;
    let attn_factor = if slow_path {
        cal.native_slowpath_attn_factor
    } else {
        cal.native_attn_eff_factor
    };
    let attn_fwd = q.attn_flops_layer_fwd() / attn_factor;
    let l = q.m.n_layers;
    let steps = q.c - 1;
    let misc = q.emit_misc(b);

    // Untiled per-layer transients resident while a layer executes:
    // 4 SwiGLU intermediates (8·(S/C)·d_ff bytes), chunked-CE workspace
    // (~8 x-units at the last layer; held here conservatively), fp32 RoPE
    // copies (Q+K at 2× bf16 = 6 x-units for llama ratios) and fp32 norm
    // casts (4 x-units).
    let untiled = b.alloc(
        "native_untiled_set",
        8.0 * q.sc as f64 * q.m.d_ff as f64 / q.tp as f64 + 8.0 * q.x_bytes
            + 2.0 * 2.0 * (q.q_bytes + q.kv_bytes)
            + 4.0 * q.x_bytes,
    );
    // Models with H·d_head != d_model (Qwen3's explicit head_dim=128) take
    // torch's slow attention path and materialize several full-head fp32
    // intermediates — fit against the paper's Qwen Native column.
    let unmodeled = (q.m.q_width() != q.m.d_model).then(|| {
        b.alloc("native_fullhead_fp32_set", cal.native_unmodeled_units * q.q_bytes)
    });
    let staging = (q.nodes > 1).then(|| {
        let peers = (q.c.min(8) - 1) as f64;
        b.alloc("ring_ib_staging", peers * 2.0 * q.kv_bytes * f)
    });

    for _ in 0..ctx.mb {
        let mut ac = ctx.ac_emitter();

        for _ in 0..l {
            if b.done() {
                return;
            }
            b.snapshot("before_attn");
            let qkv = b.alloc("native_qkv_local", q.qkv_bytes() * f);
            let inflight = b.alloc("native_kv_inflight", 2.0 * 2.0 * q.kv_bytes * f);
            b.ring(steps, 2.0 * q.kv_bytes, q.nodes > 1);
            b.compute(Category::Fa3Fwd, attn_fwd);
            b.snapshot("attn_kernel");
            b.free(inflight);
            b.free(qkv);
            ctx.emit_tp_allreduce(b);
            ac.store(b);
        }

        let beta_extra = (q.m.beta() - q.m.gamma()) * q.q_bytes;
        for _ in 0..l {
            if b.done() {
                return;
            }
            ac.fetch(b);
            if ac.recompute() {
                b.compute(Category::Fa3Fwd, attn_fwd);
            }
            b.snapshot("before_bwd_attn");
            let qkv = b.alloc("native_qkv_bwd", q.qkv_bytes() * f);
            let grads = b.alloc("native_bwd_set", beta_extra * f);
            let dkv = b.alloc("native_dkv_fp32", 2.0 * 2.0 * q.kv_bytes * f);
            let inflight = b.alloc("native_kv_inflight_bwd", 2.0 * 2.0 * q.kv_bytes * f);
            b.ring(steps, 2.0 * 2.0 * q.kv_bytes, q.nodes > 1);
            b.compute(Category::Fa3Bwd, attn_fwd * flops::ATTN_BWD_FACTOR);
            b.snapshot("bwd_attn_kernel");
            b.free(inflight);
            b.free(dkv);
            b.free(grads);
            b.free(qkv);
            ctx.emit_tp_allreduce(b);
        }
        ac.finish(b);
    }

    if slow_path {
        // fp32 full-head materialization is memory-bound: linear in S
        b.fixed(
            Category::Other,
            cal.native_slowpath_per_token * q.s as f64 * ctx.mb as f64,
        );
    }
    ctx.emit_other(b, cal.native_other_factor);
    if let Some(st) = staging {
        b.free(st);
    }
    if let Some(un) = unmodeled {
        b.free(un);
    }
    b.free(untiled);
    b.free_all(misc);
}

#[cfg(test)]
mod tests {
    use crate::config::presets::llama_single_node;
    use crate::config::CpMethod;
    use crate::engine::ops::validate_trace;
    use crate::schedule::{build_trace, simulate};

    const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

    fn run(s: u64) -> crate::engine::StepReport {
        let p = llama_single_node(CpMethod::NativePyTorch, s);
        validate_trace(&build_trace(&p)).unwrap();
        simulate(&p)
    }

    #[test]
    fn table4_native_memory_anchors() {
        // Paper: 25.32 @128K, 43.55 @512K, 67.86 @1M; OOM @2M.
        for (s, expect) in [(1u64 << 17, 25.32), (1 << 19, 43.55), (1 << 20, 67.86)] {
            let got = run(s).peak_bytes / GIB;
            assert!(
                (got - expect).abs() / expect < 0.12,
                "S={s}: got {got:.2} want {expect}"
            );
        }
        assert!(run(2 << 20).oom, "native OOMs at 2M");
    }

    #[test]
    fn native_slowest_method() {
        // Table 3: native is the slowest row everywhere it runs.
        let ring = simulate(&llama_single_node(CpMethod::Ring, 1 << 20));
        assert!(run(1 << 20).step_time > ring.step_time);
    }

    #[test]
    fn table3_native_throughput_order_of_magnitude() {
        // Paper @1M: 249.85 tokens/s/GPU (we model native's internals
        // coarsely; assert within 25%).
        let t = run(1 << 20).tokens_per_sec_per_gpu(1 << 20, 8).unwrap();
        assert!((t - 249.85).abs() / 249.85 < 0.25, "tput {t}");
    }
}
