//! Fully Pipelined Distributed Transformer (Yao et al. 2025) baseline:
//! attention chunked along the *sequence* dimension into π chunks with
//! online softmax, chunks offloaded to CPU and double-buffered back
//! (§2.1/§5.2). Orthogonal to UPipe's head chunking. The FPDT family
//! hard-requires offloaded AC ([`crate::config::CpMethod::supported_ac_modes`]).

use super::common::ScheduleCtx;
use crate::engine::{Category, Op, OpSink, TraceBuilder};
use crate::model::flops;

/// Collect one training step as a `Vec<Op>` (the priced path).
pub fn trace(ctx: &ScheduleCtx, pi: u32) -> Vec<Op> {
    let mut b = TraceBuilder::new();
    emit(ctx, &mut b, pi);
    b.finish()
}

/// Emit one training step into any sink.
pub fn emit<S: OpSink>(ctx: &ScheduleCtx, b: &mut TraceBuilder<S>, pi: u32) {
    let q = &ctx.q;
    let cal = &ctx.cal;
    let f = cal.attn_transient_factor;
    let p = pi as f64;
    let attn_fwd = q.attn_flops_layer_fwd();
    let l = q.m.n_layers;
    let a2a_frac = (q.c - 1) as f64 / q.c as f64;
    // FPDT runs Ulysses-style a2a; its qwen setup is 16-ulysses-1-ring, so
    // the a2a crosses nodes when the cluster does (§5.2.1).
    let intra = q.nodes == 1;
    let misc = q.emit_misc_chunked(b);
    // FPDT's extra persistent footprint: pinned double buffers + CPU
    // offload engine state (fit, see calibration provenance).
    let extra = b.alloc("fpdt_offload_engine", cal.fpdt_extra_base);
    let staging = b.alloc("fpdt_pinned_staging", 1.3 * q.x_bytes);

    for _ in 0..ctx.mb {
        let mut ac = ctx.ac_emitter();

        for _ in 0..l {
            if b.done() {
                return;
            }
            b.snapshot("before_attn");
            // double buffers for the in-flight chunk pair
            let dbuf = b.alloc("fpdt_double_buffer", 2.0 * (q.m.gamma() + 1.0) / p * q.q_bytes * f);
            for _ in 0..pi {
                if b.done() {
                    return;
                }
                let chunk = b.alloc("fpdt_chunk", (2.0 * q.m.gamma() + 1.0) / p * q.q_bytes * f);
                b.all_to_all((q.qkv_bytes() + q.q_bytes) / p * a2a_frac, intra, 4, q.s as f64);
                b.snapshot("inp_all_to_all");
                b.compute(Category::Fa3Fwd, attn_fwd / p);
                b.snapshot("attn_kernel");
                // offload the processed chunk's KV to host (overlapped)
                b.offload(2.0 * q.kv_bytes / p, true);
                b.free(chunk);
            }
            b.free(dbuf);
            ctx.emit_tp_allreduce(b);
            ac.store(b);
        }

        let beta = q.m.beta();
        for _ in 0..l {
            if b.done() {
                return;
            }
            ac.fetch(b);
            if ac.recompute() {
                b.compute(Category::Fa3Fwd, attn_fwd); // AC recompute
            }
            b.snapshot("before_bwd_attn");
            let dbuf =
                b.alloc("fpdt_double_buffer_bwd", 2.0 * (q.m.gamma() + 1.0) / p * q.q_bytes * f);
            for _ in 0..pi {
                if b.done() {
                    return;
                }
                // fetch the chunk's KV back from host (releases host RAM)
                b.offload(-(2.0 * q.kv_bytes) / p, true);
                let chunk = b.alloc("fpdt_bwd_chunk", (beta + 2.0) / p * q.q_bytes * f);
                b.all_to_all((q.qkv_bytes() + q.q_bytes) / p * a2a_frac, intra, 4, q.s as f64);
                b.compute(Category::Fa3Bwd, attn_fwd * flops::ATTN_BWD_FACTOR / p);
                b.snapshot("bwd_attn_kernel");
                b.free(chunk);
            }
            b.free(dbuf);
            ctx.emit_tp_allreduce(b);
        }
        ac.finish(b);
    }

    // CPU-side scheduler stalls: the throughput penalty §5.3 attributes to
    // "frequent CPU-GPU transfers"; partially amortized at long S.
    b.fixed(
        Category::Other,
        cal.fpdt_stall(q.s as f64, q.m.n_layers) * ctx.mb as f64,
    );
    ctx.emit_other(b, 1.0);
    b.free(staging);
    b.free(extra);
    b.free_all(misc);
}

#[cfg(test)]
mod tests {
    use crate::config::presets::llama_single_node;
    use crate::config::CpMethod;
    use crate::engine::ops::validate_trace;
    use crate::engine::{Calibration, Op};
    use crate::schedule::{build_trace, simulate, ScheduleCtx};

    const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

    fn run(s: u64) -> crate::engine::StepReport {
        let p = llama_single_node(CpMethod::Fpdt { pi: 16 }, s);
        validate_trace(&build_trace(&p)).unwrap();
        simulate(&p)
    }

    #[test]
    fn table4_fpdt_memory_anchors() {
        // Paper: 21.73 @128K, 27.09 @1M, 43.35 @3M, 51.42 @4M.
        for (s, expect) in [
            (1u64 << 17, 21.73),
            (1 << 20, 27.09),
            (3 << 20, 43.35),
            (4 << 20, 51.42),
        ] {
            let got = run(s).peak_bytes / GIB;
            assert!(
                (got - expect).abs() / expect < 0.12,
                "S={s}: got {got:.2} want {expect}"
            );
        }
    }

    #[test]
    fn fpdt_lowest_memory_but_slowest_of_modern() {
        let ul = simulate(&llama_single_node(CpMethod::Ulysses, 1 << 20));
        let fp = run(1 << 20);
        assert!(fp.peak_bytes < ul.peak_bytes, "FPDT uses least memory");
        assert!(fp.step_time > ul.step_time, "FPDT pays throughput");
    }

    #[test]
    fn table3_fpdt_throughput_1m() {
        // Paper @1M: 382.42 tokens/s/GPU.
        let t = run(1 << 20).tokens_per_sec_per_gpu(1 << 20, 8).unwrap();
        assert!((t - 382.42).abs() / 382.42 < 0.15, "tput {t}");
    }

    #[test]
    fn chunk_buffers_shrink_with_pi() {
        let p = llama_single_node(CpMethod::Fpdt { pi: 16 }, 1 << 20);
        let ctx = ScheduleCtx::new(&p, &Calibration::default());
        let max_chunk = |pi: u32| -> f64 {
            super::trace(&ctx, pi)
                .iter()
                .filter_map(|op| match op {
                    Op::Alloc { bytes, name, .. } if name.contains("chunk") => Some(*bytes),
                    _ => None,
                })
                .fold(0.0, f64::max)
        };
        assert!(max_chunk(32) < max_chunk(8));
    }
}
