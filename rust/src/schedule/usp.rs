//! USP-Hybrid (Fang & Zhao 2024): Ulysses all-to-all over the NVLink
//! intra-node group × Ring Attention across nodes over InfiniBand —
//! the paper's multi-node baseline (§5.2.1) — and the UPipe-Hybrid
//! extension that replaces the intra-node Ulysses with UPipe stages.

use super::common::ScheduleCtx;
use super::upipe;
use crate::engine::{Category, Op, OpSink, TraceBuilder};
use crate::model::flops;

/// USP-Hybrid trace: `cu`-way Ulysses intra-node, `cr`-way ring across.
pub fn trace(ctx: &ScheduleCtx, cu: u32, cr: u32) -> Vec<Op> {
    let mut b = TraceBuilder::new();
    emit(ctx, &mut b, cu, cr);
    b.finish()
}

/// Streaming form of [`trace`].
pub fn emit<S: OpSink>(ctx: &ScheduleCtx, b: &mut TraceBuilder<S>, cu: u32, cr: u32) {
    let q = &ctx.q;
    let cal = &ctx.cal;
    let f = cal.attn_transient_factor;
    let attn_fwd = q.attn_flops_layer_fwd();
    let l = q.m.n_layers;
    let a2a_frac = (cu as f64 - 1.0) / cu as f64;
    let ring_steps = (cr - 1) as u64;
    let misc = q.emit_misc(b);

    for _ in 0..ctx.mb {
        let mut ac = ctx.ac_emitter();

        for _ in 0..l {
            if b.done() {
                return;
            }
            b.snapshot("before_attn");
            let qkv = b.alloc("usp_qkv_fullhead", q.qkv_bytes() * f);
            let comm = b.alloc("usp_a2a_buffer", q.q_bytes * f);
            b.all_to_all(q.qkv_bytes() * a2a_frac, true, 3, q.s as f64);
            b.snapshot("inp_all_to_all");
            // ring dimension: the node-group's KV circulates over IB while
            // local attention proceeds (zigzag-balanced)
            let inflight = b.alloc("usp_kv_inflight", 2.0 * 2.0 * q.kv_bytes * f);
            b.ring(ring_steps, 2.0 * q.kv_bytes, true);
            b.compute(Category::Fa3Fwd, attn_fwd);
            b.snapshot("attn_kernel");
            b.all_to_all(q.q_bytes * a2a_frac, true, 1, q.s as f64);
            b.snapshot("out_all_to_all");
            b.free(inflight);
            b.free(comm);
            b.free(qkv);
            ctx.emit_tp_allreduce(b);
            ac.store(b);
        }

        let beta_extra = (q.m.beta() - q.m.gamma()) * q.q_bytes;
        for _ in 0..l {
            if b.done() {
                return;
            }
            ac.fetch(b);
            if ac.recompute() {
                b.compute(Category::Fa3Fwd, attn_fwd);
            }
            b.snapshot("before_bwd_attn");
            let comm = b.alloc("usp_a2a_buffer_bwd", q.q_bytes * f);
            b.all_to_all(q.q_bytes * a2a_frac, true, 1, q.s as f64);
            let qkv = b.alloc("usp_qkv_bwd", q.qkv_bytes() * f);
            let dout = b.alloc("usp_dout_heads", q.q_bytes * f);
            let grads = b.alloc("usp_bwd_set", beta_extra * f);
            let inflight = b.alloc("usp_kv_inflight_bwd", 2.0 * 2.0 * q.kv_bytes * f);
            b.ring(ring_steps, 2.0 * 2.0 * q.kv_bytes, true);
            b.compute(Category::Fa3Bwd, attn_fwd * flops::ATTN_BWD_FACTOR);
            b.snapshot("bwd_attn_kernel");
            b.all_to_all(q.qkv_bytes() * a2a_frac, true, 3, q.s as f64);
            b.snapshot("bwd_inp_all_to_all");
            b.free(inflight);
            b.free(grads);
            b.free(dout);
            b.free(qkv);
            b.free(comm);
            ctx.emit_tp_allreduce(b);
        }
        ac.finish(b);
    }

    // inter-node barriers + dual-fabric PG launches, once per layer
    b.fixed(Category::Other, cal.hybrid_layer_fixed * l as f64 * ctx.mb as f64);
    ctx.emit_other(b, 1.0);
    b.free_all(misc);
}

/// UPipe-Hybrid: UPipe headwise stages intra-node + ring across nodes.
pub fn upipe_hybrid_trace(ctx: &ScheduleCtx, u: u32, cu: u32, cr: u32) -> Vec<Op> {
    let mut b = TraceBuilder::new();
    upipe_hybrid_emit(ctx, &mut b, u, cu, cr);
    b.finish()
}

/// Streaming form of [`upipe_hybrid_trace`].
pub fn upipe_hybrid_emit<S: OpSink>(
    ctx: &ScheduleCtx,
    b: &mut TraceBuilder<S>,
    u: u32,
    _cu: u32,
    _cr: u32,
) {
    upipe::emit(ctx, b, u, true, true)
}

#[cfg(test)]
mod tests {
    use crate::config::presets::{llama_two_node, qwen_two_node};
    use crate::config::CpMethod;
    use crate::engine::ops::validate_trace;
    use crate::schedule::{build_trace, simulate};

    const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

    fn run_qwen(s: u64) -> crate::engine::StepReport {
        let p = qwen_two_node(CpMethod::UspHybrid { ulysses: 8, ring: 2 }, s);
        validate_trace(&build_trace(&p)).unwrap();
        simulate(&p)
    }

    #[test]
    fn table4_qwen_ulysses_memory_anchors() {
        // Paper Table 4 Qwen3-32B "Ulysses" (USP-hybrid) row:
        // 40.13 @128K, 50.27 @1M, 62.60 @2M; OOM @3M.
        for (s, expect) in [(1u64 << 17, 40.13), (1 << 20, 50.27), (2 << 20, 62.60)] {
            let got = run_qwen(s).peak_bytes / GIB;
            assert!(
                (got - expect).abs() / expect < 0.07,
                "S={s}: got {got:.2} want {expect}"
            );
        }
        assert!(run_qwen(3 << 20).oom, "qwen Ulysses OOMs at 3M");
    }

    #[test]
    fn table3_qwen_ulysses_throughput_1m() {
        // Paper @1M: 117.02 tokens/s/GPU over 16 GPUs.
        let t = run_qwen(1 << 20).tokens_per_sec_per_gpu(1 << 20, 16).unwrap();
        assert!((t - 117.02).abs() / 117.02 < 0.08, "tput {t}");
    }

    #[test]
    fn fig5_usp_vs_upipe_hybrid() {
        // Fig. 5: UPipe-Hybrid is more memory-efficient than USP-Hybrid at
        // every length, max context 8M vs 6M, comparable throughput.
        let run = |m: CpMethod, s: u64| {
            let p = llama_two_node(m, s);
            validate_trace(&build_trace(&p)).unwrap();
            simulate(&p)
        };
        let usp = CpMethod::UspHybrid { ulysses: 8, ring: 2 };
        let upi = CpMethod::UpipeHybrid { u: 8, ulysses: 8, ring: 2 };
        for s in [1u64 << 19, 1 << 20, 2 << 20, 4 << 20] {
            let a = run(usp, s);
            let b = run(upi, s);
            assert!(b.peak_bytes < a.peak_bytes, "S={s}");
            let (ta, tb) = (a.step_time, b.step_time);
            assert!((tb - ta).abs() / ta < 0.1, "throughput comparable S={s}");
        }
    }
}
