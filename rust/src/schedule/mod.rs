//! Context-parallelism schedules: one module per method in the paper's
//! evaluation. Each schedule turns (model, cluster, parallel layout, S)
//! into an op trace ([`crate::engine::ops::Op`]) describing one training
//! step on a representative device; the engine prices it.
//!
//! Schedules encode the *structural* behaviour — which buffers exist when
//! (Tables 2 & 6), what is communicated (Fig. 4), what overlaps — while
//! the engine's calibration holds the fitted hardware rates.

pub mod common;
pub mod compose;
pub mod fpdt;
pub mod gqa;
pub mod native;
pub mod ring_attn;
pub mod ulysses;
pub mod upipe;
pub mod usp;

use crate::config::presets::RunPreset;
use crate::config::CpMethod;
use crate::engine::{Calibration, Engine, Op, StepReport};

pub use common::{AcMode, Quantities};

/// Build the op trace for a preset.
pub fn build_trace(p: &RunPreset) -> Vec<Op> {
    let q = Quantities::new(p);
    match p.parallel.method {
        CpMethod::NativePyTorch => native::trace(&q),
        CpMethod::Ring => ring_attn::trace(&q),
        CpMethod::Ulysses => ulysses::trace(&q, AcMode::AcOffload),
        CpMethod::Fpdt { pi } => fpdt::trace(&q, pi),
        CpMethod::Upipe { u, gqa_schedule } => upipe::trace(&q, u, gqa_schedule, false),
        CpMethod::UspHybrid { ulysses: cu, ring: cr } => usp::trace(&q, cu, cr),
        CpMethod::UpipeHybrid { u, ulysses: cu, ring: cr } => {
            usp::upipe_hybrid_trace(&q, u, cu, cr)
        }
        CpMethod::UpipeFpdt { u, pi } => compose::trace(&q, u, pi),
    }
}

/// Simulate one training step for a preset.
pub fn simulate(p: &RunPreset) -> StepReport {
    simulate_with(p, &Calibration::default())
}

pub fn simulate_with(p: &RunPreset, calib: &Calibration) -> StepReport {
    let q = Quantities::new(p);
    let trace = build_trace(p);
    let mut engine = Engine::new(calib.clone(), q.hbm_limit, q.persistent_bytes(calib));
    engine.host_ram = q.host_ram_for_offload();
    let mut report = engine.run(&trace);
    // FPDT's published implementation fails beyond 4M tokens (§5.2 note);
    // reproduce the failure rather than extrapolating.
    if let CpMethod::Fpdt { .. } = p.parallel.method {
        if p.seq_len > 4 * 1024 * 1024 {
            report.failed = Some("FPDT execution fails at lengths > 4M (paper §5.2)");
        }
    }
    report
}
