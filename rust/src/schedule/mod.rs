//! Context-parallelism schedules: one module per method in the paper's
//! evaluation. Each schedule turns (model, cluster, parallel layout, S)
//! into an op trace ([`crate::engine::ops::Op`]) describing one training
//! step on a representative device; the engine prices it.
//!
//! Schedules encode the *structural* behaviour — which buffers exist when
//! (Tables 2 & 6), what is communicated (Fig. 4), what overlaps — while
//! the engine's calibration holds the fitted hardware rates.
//!
//! The planner sweeps thousands of (config, S) cells, many of them
//! repeatedly (bisection re-probes, frontier + report passes, pin-memory
//! variants that share a trace); [`TraceCache`] memoizes built traces so
//! those replays skip straight to pricing.

pub mod common;
pub mod compose;
pub mod fpdt;
pub mod gqa;
pub mod native;
pub mod ring_attn;
pub mod ulysses;
pub mod upipe;
pub mod usp;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::presets::RunPreset;
use crate::config::CpMethod;
use crate::engine::{Calibration, Engine, Op, StepReport};

pub use common::{AcMode, Quantities};

/// Build the op trace for a preset.
pub fn build_trace(p: &RunPreset) -> Vec<Op> {
    let q = Quantities::new(p);
    match p.parallel.method {
        CpMethod::NativePyTorch => native::trace(&q),
        CpMethod::Ring => ring_attn::trace(&q),
        CpMethod::Ulysses => ulysses::trace(&q, AcMode::AcOffload),
        CpMethod::Fpdt { pi } => fpdt::trace(&q, pi),
        CpMethod::Upipe { u, gqa_schedule } => upipe::trace(&q, u, gqa_schedule, false),
        CpMethod::UspHybrid { ulysses: cu, ring: cr } => usp::trace(&q, cu, cr),
        CpMethod::UpipeHybrid { u, ulysses: cu, ring: cr } => {
            usp::upipe_hybrid_trace(&q, u, cu, cr)
        }
        CpMethod::UpipeFpdt { u, pi } => compose::trace(&q, u, pi),
    }
}

/// Simulate one training step for a preset.
pub fn simulate(p: &RunPreset) -> StepReport {
    simulate_with(p, &Calibration::default())
}

pub fn simulate_with(p: &RunPreset, calib: &Calibration) -> StepReport {
    let trace = build_trace(p);
    run_trace(p, calib, &trace)
}

/// `simulate_with`, but fetching the op trace from (or inserting it into)
/// `cache` — the planner's hot path.
pub fn simulate_cached(p: &RunPreset, calib: &Calibration, cache: &TraceCache) -> StepReport {
    let trace = cache.trace(p);
    run_trace(p, calib, trace.as_slice())
}

/// Price an already-built trace for a preset (shared by the cached and
/// uncached simulation paths).
fn run_trace(p: &RunPreset, calib: &Calibration, trace: &[Op]) -> StepReport {
    let q = Quantities::new(p);
    let mut engine = Engine::new(calib.clone(), q.hbm_limit, q.persistent_bytes(calib));
    engine.host_ram = q.host_ram_for_offload();
    let mut report = engine.run(trace);
    // FPDT's published implementation fails beyond 4M tokens (§5.2 note);
    // reproduce the failure rather than extrapolating.
    if let CpMethod::Fpdt { .. } = p.parallel.method {
        if p.seq_len > 4 * 1024 * 1024 {
            report.failed = Some("FPDT execution fails at lengths > 4M (paper §5.2)");
        }
    }
    report
}

/// Thread-safe memo of built op traces, keyed by every input `build_trace`
/// reads. Traces are immutable once built, so they are shared as `Arc`s;
/// concurrent builders may race on a cold key, in which case one build is
/// discarded and the canonical entry wins.
#[derive(Default)]
pub struct TraceCache {
    traces: Mutex<HashMap<String, Arc<Vec<Op>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl TraceCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cache key: everything the trace depends on — the full model dims
    /// (not just the name: refit experiments build modified variants that
    /// keep it), cluster shape, layout and S. Note `pin_memory` is
    /// deliberately absent — pinning changes pricing (host-RAM budget),
    /// not trace structure, so pin variants share one trace.
    pub fn key(p: &RunPreset) -> String {
        format!(
            "{:?}|{:?}|{}n{}g|c{}|s{}|ac{}",
            p.parallel.method,
            p.model,
            p.cluster.nodes,
            p.cluster.gpus_per_node,
            p.parallel.cp_degree,
            p.seq_len,
            p.parallel.ac_offload
        )
    }

    /// Fetch (or build and insert) the trace for `p`.
    pub fn trace(&self, p: &RunPreset) -> Arc<Vec<Op>> {
        let key = Self::key(p);
        if let Some(t) = self.traces.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return t.clone();
        }
        // Build outside the lock: traces can be long and the planner's
        // workers build neighbouring cells concurrently.
        let built = Arc::new(build_trace(p));
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut map = self.traces.lock().unwrap();
        map.entry(key).or_insert(built).clone()
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.traces.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::llama_single_node;

    #[test]
    fn cached_simulation_matches_uncached() {
        let cache = TraceCache::new();
        let cal = Calibration::default();
        for m in [CpMethod::Ulysses, CpMethod::Upipe { u: 8, gqa_schedule: true }] {
            for s in [1u64 << 20, 2 << 20] {
                let p = llama_single_node(m, s);
                let a = simulate_with(&p, &cal);
                let b = simulate_cached(&p, &cal, &cache);
                assert_eq!(a.step_time, b.step_time, "{m:?} S={s}");
                assert_eq!(a.peak_bytes, b.peak_bytes, "{m:?} S={s}");
                assert_eq!(a.oom, b.oom, "{m:?} S={s}");
            }
        }
        assert_eq!((cache.hits(), cache.misses()), (0, 4));
        // Replaying a cell hits.
        let p = llama_single_node(CpMethod::Ulysses, 1 << 20);
        simulate_cached(&p, &cal, &cache);
        assert_eq!((cache.hits(), cache.len()), (1, 4));
    }

    #[test]
    fn pin_variants_share_a_trace() {
        let cache = TraceCache::new();
        let cal = Calibration::default();
        let mut a = llama_single_node(CpMethod::Ulysses, 1 << 20);
        a.parallel.pin_memory = true;
        let mut b = a.clone();
        b.parallel.pin_memory = false;
        simulate_cached(&a, &cal, &cache);
        simulate_cached(&b, &cal, &cache);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
    }

    #[test]
    fn fpdt_failure_rule_applies_on_cached_path() {
        let cache = TraceCache::new();
        let p = llama_single_node(CpMethod::Fpdt { pi: 16 }, 5 << 20);
        let r = simulate_cached(&p, &Calibration::default(), &cache);
        assert!(r.failed.is_some() || r.oom, "FPDT must not extrapolate past 4M");
    }
}
