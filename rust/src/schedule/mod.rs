//! Context-parallelism schedules: one module per method in the paper's
//! evaluation. Each schedule turns a [`ScheduleCtx`] — derived quantities
//! plus calibration, AC mode, micro-batching and TP, built from a
//! (model, cluster, parallel layout, S) preset — into an op stream
//! ([`crate::engine::ops::Op`]) describing one training step on a
//! representative device; the engine prices it.
//!
//! Schedules encode the *structural* behaviour — which buffers exist when
//! (Tables 2 & 6), what is communicated (Fig. 4), what overlaps — while
//! the engine's calibration holds the fitted hardware rates. No schedule
//! reads `Calibration::default()` on its own: the calibration always
//! arrives through the `ScheduleCtx`, so planner-driven refits flow into
//! every trace uniformly.
//!
//! Every schedule emits into a generic [`OpSink`], so one emission path
//! serves every evaluation phase: [`feasibility_with`] streams the ops
//! straight into the peak-only [`FeasibilityKernel`] (the planner's
//! bisection probes — no `Vec<Op>` is ever materialized),
//! [`timing_with`] streams them into the priced [`TimingKernel`]
//! (bitwise `Engine::run` step times, still no `Vec<Op>` and no
//! timeline — the symbolic pricer's workhorse), while [`simulate_with`]
//! / [`simulate_cached`] collect and fully price a trace (timeline +
//! Table-5 components) for the cells that end up in tables and figures.
//! [`TraceCache`] memoizes priced traces under hashed [`CellKey`]s
//! in a lock-striped map, so pin variants and report replays skip straight
//! to pricing without serializing the worker pool on one global mutex.
//! The cache is owned by whoever scopes the evaluation — a one-shot
//! `plan()` call builds a private one, while the planner service's
//! session caches ([`crate::planner::PlannerCaches`]) keep one alive
//! across requests; [`TraceCache::clear`] is the eviction valve for that
//! long-lived case.

pub mod common;
pub mod compose;
pub mod fpdt;
pub mod gqa;
pub mod native;
pub mod ring_attn;
pub mod ulysses;
pub mod upipe;
pub mod usp;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::config::presets::RunPreset;
use crate::config::CpMethod;
use crate::engine::{
    Calibration, Engine, Feasibility, FeasibilityKernel, Op, OpSink, PeakProbe, StepReport,
    TimeSample, TimingKernel, TraceBuilder,
};
use crate::util::stripe::{fx_hash_one, StripedMap};

pub use common::{AcEmitter, AcMode, Quantities, ScheduleCtx};

/// Build the op trace for a preset at the default calibration.
pub fn build_trace(p: &RunPreset) -> Vec<Op> {
    build_trace_with(p, &Calibration::default())
}

/// Build the op trace for a preset under a specific calibration — the
/// uniform builder contract: every schedule consumes calibration, AC mode,
/// micro-batch count and TP degree through one [`ScheduleCtx`].
pub fn build_trace_with(p: &RunPreset, calib: &Calibration) -> Vec<Op> {
    let mut ops = Vec::new();
    stream_trace_with(p, calib, &mut ops);
    ops
}

/// Stream the op trace for a preset into an arbitrary sink without ever
/// collecting it. This is the feasibility probes' entry point; collecting
/// sinks (`Vec<Op>`) get exactly the same sequence.
pub fn stream_trace_with<S: OpSink>(p: &RunPreset, calib: &Calibration, sink: &mut S) {
    let ctx = ScheduleCtx::new(p, calib);
    let mut b = TraceBuilder::over(sink);
    match p.parallel.method {
        CpMethod::NativePyTorch => native::emit(&ctx, &mut b),
        CpMethod::Ring => ring_attn::emit(&ctx, &mut b),
        CpMethod::Ulysses => ulysses::emit(&ctx, &mut b),
        CpMethod::Fpdt { pi } => fpdt::emit(&ctx, &mut b, pi),
        CpMethod::Upipe { u, gqa_schedule } => upipe::emit(&ctx, &mut b, u, gqa_schedule, false),
        CpMethod::UspHybrid { ulysses: cu, ring: cr } => usp::emit(&ctx, &mut b, cu, cr),
        CpMethod::UpipeHybrid { u, ulysses: cu, ring: cr } => {
            usp::upipe_hybrid_emit(&ctx, &mut b, u, cu, cr)
        }
        CpMethod::UpipeFpdt { u, pi } => compose::emit(&ctx, &mut b, u, pi),
    }
}

/// Simulate one training step for a preset.
pub fn simulate(p: &RunPreset) -> StepReport {
    simulate_with(p, &Calibration::default())
}

pub fn simulate_with(p: &RunPreset, calib: &Calibration) -> StepReport {
    let trace = build_trace_with(p, calib);
    run_trace(p, calib, &trace)
}

/// `simulate_with`, but fetching the op trace from (or inserting it into)
/// `cache` — the priced phase of the planner (final cells, reports).
pub fn simulate_cached(p: &RunPreset, calib: &Calibration, cache: &TraceCache) -> StepReport {
    let trace = cache.trace(p, calib);
    run_trace(p, calib, trace.as_slice())
}

/// Phase-1 evaluation: stream the preset's schedule straight into the
/// peak-only [`FeasibilityKernel`] — no `Vec<Op>`, no pricing, no
/// timeline. Agrees bitwise with [`simulate_with`] on `peak_bytes`, `oom`
/// and `failed` (the schedule-layer property tests enforce this).
pub fn feasibility_with(p: &RunPreset, calib: &Calibration) -> Feasibility {
    let q = Quantities::new(p);
    let mut kernel =
        FeasibilityKernel::new(q.hbm_limit, q.persistent_bytes(calib), q.host_ram_for_offload());
    stream_trace_with(p, calib, &mut kernel);
    let mut f = kernel.finish();
    if let Some(msg) = method_failure(p) {
        f.failed = Some(msg);
    }
    f
}

/// Phase-1 evaluation, pin-agnostic: stream the schedule into a kernel
/// with an **unbounded host budget**, reporting the host-occupancy peak
/// instead of failing at one budget. One probe answers feasibility for
/// every pin variant of the cell (`PeakProbe::feasible_with_host` is
/// provably equal to [`feasibility_with`]'s predicate at that budget —
/// see the type docs), and a clean probe's peaks are the exact sample
/// values the symbolic wall solver fits its polynomials from.
pub fn peak_probe_with(p: &RunPreset, calib: &Calibration) -> PeakProbe {
    let q = Quantities::new(p);
    let mut kernel = FeasibilityKernel::new(q.hbm_limit, q.persistent_bytes(calib), f64::INFINITY);
    stream_trace_with(p, calib, &mut kernel);
    let mut probe = kernel.probe();
    if let Some(msg) = method_failure(p) {
        probe.failed = Some(msg);
    }
    probe
}

/// Priced-streaming evaluation: stream the preset's schedule straight
/// into the [`TimingKernel`] — the full `Engine::run` pricing arithmetic
/// (clocks, penalties, Table-5 component breakdown) with no `Vec<Op>`
/// and no timeline. Agrees **bitwise** with [`simulate_with`] on
/// `step_time`, every component, `peak_bytes`, `oom` and `failed` (the
/// trace-invariant prop test enforces this); the report's timeline is
/// empty, which is the entire savings.
pub fn timing_with(p: &RunPreset, calib: &Calibration) -> StepReport {
    let q = Quantities::new(p);
    let mut kernel = TimingKernel::new(
        calib.clone(),
        q.hbm_limit,
        q.persistent_bytes(calib),
        q.host_ram_for_offload(),
    );
    stream_trace_with(p, calib, &mut kernel);
    let mut r = kernel.finish();
    if let Some(msg) = method_failure(p) {
        r.failed = Some(msg);
    }
    r
}

/// One [`TimeSample`] for the symbolic step-time fit: stream the preset
/// into the timing kernel and decompose its clocks at per-rank token
/// count `k`. `None` unless the run is clean (no OOM, no failure — a
/// truncated stream under-prices, so it is never a valid sample).
pub fn timing_sample_with(p: &RunPreset, calib: &Calibration, k: u64) -> Option<TimeSample> {
    if method_failure(p).is_some() {
        return None;
    }
    let q = Quantities::new(p);
    let mut kernel = TimingKernel::new(
        calib.clone(),
        q.hbm_limit,
        q.persistent_bytes(calib),
        q.host_ram_for_offload(),
    );
    stream_trace_with(p, calib, &mut kernel);
    kernel.sample(k)
}

/// Hard sequence-length ceiling a method imposes regardless of memory
/// (`None` = memory-limited only). The symbolic wall solver clamps its
/// closed-form solve to this, so a predicted memory wall beyond the
/// method ceiling does not send the verification probes galloping.
/// [`method_failure`] is derived from the same ceiling, so the two can
/// never disagree.
pub fn method_seq_cap(method: CpMethod) -> Option<u64> {
    // FPDT's published implementation fails beyond 4M tokens (§5.2 note);
    // reproduce the failure rather than extrapolating.
    match method {
        CpMethod::Fpdt { .. } => Some(4 * 1024 * 1024),
        _ => None,
    }
}

/// Method-level failure rules applied on top of the engine's own result
/// (shared by the priced and feasibility paths so they agree bitwise).
/// The ceiling comes from [`method_seq_cap`]; the message stays
/// per-method so a future capped method cannot inherit FPDT's label.
fn method_failure(p: &RunPreset) -> Option<&'static str> {
    let cap = method_seq_cap(p.parallel.method)?;
    if p.seq_len <= cap {
        return None;
    }
    Some(match p.parallel.method {
        CpMethod::Fpdt { .. } => "FPDT execution fails at lengths > 4M (paper §5.2)",
        _ => "method fails beyond its sequence-length ceiling",
    })
}

/// Price an already-built trace for a preset (shared by the cached and
/// uncached simulation paths). Host RAM comes from the cluster config so
/// offload-heavy schedules (FPDT, AC-offload, micro-batched runs) can OOM
/// on the host side too.
fn run_trace(p: &RunPreset, calib: &Calibration, trace: &[Op]) -> StepReport {
    let q = Quantities::new(p);
    let engine = Engine::new(
        calib.clone(),
        q.hbm_limit,
        q.persistent_bytes(calib),
        q.host_ram_for_offload(),
    );
    let mut report = engine.run(trace);
    if let Some(msg) = method_failure(p) {
        report.failed = Some(msg);
    }
    report
}

/// Hashed cache key for one evaluated cell: every input the trace builder
/// reads, as a flat `Copy` struct with derived hashing — no `format!`-built
/// Strings anywhere near the probe path. Covers the full model dims (as a
/// fingerprint: refit experiments build modified variants that keep the
/// name), cluster shape, layout and S, the AC/micro-batch/TP dims, and the
/// calibration fingerprint (refit calibrations change emitted op durations
/// and byte sizes, so they must not alias the default fit's traces), plus
/// the cluster's per-rank hardware fingerprint (HBM/host-RAM budgets reach
/// the probes through `Quantities`, so an H200's roomier walls must not
/// alias an H100's — while fleet pools of *identical* hardware hash equal
/// and share every memo tier across cluster shapes, which is what keeps
/// placement sweeps at O(distinct hardware × families) anchor work). Note
/// `pin_memory` is deliberately absent — pinning changes pricing (host-RAM
/// budget), not trace structure, so pin variants share one trace; pricing
/// memos append it separately.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CellKey {
    method: CpMethod,
    ac: AcMode,
    cp_degree: u64,
    tp: u64,
    micro_batch: u64,
    seq_len: u64,
    nodes: u64,
    gpus_per_node: u64,
    model_fp: u64,
    cal_fp: u64,
    hw_fp: u64,
}

impl CellKey {
    pub fn new(p: &RunPreset, calib: &Calibration) -> Self {
        CellKey {
            method: p.parallel.method,
            ac: p.parallel.ac_mode,
            cp_degree: p.parallel.cp_degree,
            tp: p.parallel.tp,
            micro_batch: p.parallel.micro_batch,
            seq_len: p.seq_len,
            nodes: p.cluster.nodes,
            gpus_per_node: p.cluster.gpus_per_node,
            // FxHash, not SipHash: deterministic across processes and an
            // order of magnitude cheaper — this fingerprint is computed
            // once per probe, which dominates per-cell overhead now that
            // the symbolic solver collapses probes to O(1) per cell.
            model_fp: fx_hash_one(&p.model),
            cal_fp: calib.fingerprint(),
            hw_fp: p.cluster.hardware_fingerprint(),
        }
    }

    /// The calibration fingerprint this cell was keyed under — the handle
    /// calibration-epoch invalidation matches on.
    pub fn cal_fp(&self) -> u64 {
        self.cal_fp
    }

    /// The cell's *family*: every dimension except the sequence length,
    /// the micro-batch count and (as in `CellKey` itself) pinning. One
    /// fitted [`crate::engine::PeakModel`] serves the whole family — the
    /// peaks are functions of `S/C` shared by all micro-batch variants
    /// (each micro-batch repeats an identical alloc/free + offload cycle),
    /// and pinning only changes the host budget the wall is solved
    /// against, never the trace.
    pub fn family(&self) -> FamilyKey {
        FamilyKey {
            method: self.method,
            ac: self.ac,
            cp_degree: self.cp_degree,
            tp: self.tp,
            nodes: self.nodes,
            gpus_per_node: self.gpus_per_node,
            model_fp: self.model_fp,
            cal_fp: self.cal_fp,
            hw_fp: self.hw_fp,
        }
    }
}

/// Hashed key for a family of sweep cells sharing one symbolic peak
/// model: [`CellKey`] minus `seq_len` and `micro_batch` (see
/// [`CellKey::family`] for why those collapse). The hardware fingerprint
/// stays: fitted models are exact only for the budgets and link rates
/// they were sampled under, and keeping it here is also what *shares*
/// fits across fleet shapes of identical hardware.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FamilyKey {
    method: CpMethod,
    ac: AcMode,
    cp_degree: u64,
    tp: u64,
    nodes: u64,
    gpus_per_node: u64,
    model_fp: u64,
    cal_fp: u64,
    hw_fp: u64,
}

impl FamilyKey {
    /// The calibration fingerprint this family was keyed under (see
    /// [`CellKey::cal_fp`]).
    pub fn cal_fp(&self) -> u64 {
        self.cal_fp
    }
}

/// Thread-safe memo of built op traces, keyed by hashed [`CellKey`]s in a
/// lock-striped map (planner workers probing different cells no longer
/// serialize on one global mutex). Traces are immutable once built, so
/// they are shared as `Arc`s; concurrent builders may race on a cold key,
/// in which case one build is discarded and the canonical entry wins.
#[derive(Default)]
pub struct TraceCache {
    traces: StripedMap<CellKey, Arc<Vec<Op>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl TraceCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cache key for a cell; see [`CellKey`] for exactly what it covers.
    pub fn key(p: &RunPreset, calib: &Calibration) -> CellKey {
        CellKey::new(p, calib)
    }

    /// Fetch (or build and insert) the trace for `p` under `calib`.
    pub fn trace(&self, p: &RunPreset, calib: &Calibration) -> Arc<Vec<Op>> {
        let key = Self::key(p, calib);
        if let Some(t) = self.traces.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return t;
        }
        // Build outside the lock: traces can be long and the planner's
        // workers build neighbouring cells concurrently.
        let built = Arc::new(build_trace_with(p, calib));
        self.misses.fetch_add(1, Ordering::Relaxed);
        // The ops vector is the entry's real footprint — the `Arc` itself
        // is 8 bytes; weigh it so the service's byte budget sees traces
        // as the dominant tier they are.
        let payload = built.len() * std::mem::size_of::<Op>();
        self.traces.insert_weighed(key, built, payload)
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.traces.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate resident bytes (keys + `Op` payloads).
    pub fn bytes(&self) -> usize {
        self.traces.bytes()
    }

    /// Lifetime count of traces dropped by [`TraceCache::evict_lru`].
    pub fn evictions(&self) -> u64 {
        self.traces.evicted()
    }

    /// Shed least-recently-used traces until the cache weighs at most
    /// `target_bytes`; returns how many were dropped. Only warmth is
    /// lost — an evicted cell rebuilds on its next miss.
    pub fn evict_lru(&self, target_bytes: usize) -> u64 {
        self.traces.evict_lru(target_bytes)
    }

    /// Drop exactly the traces built under calibration fingerprint `fp`
    /// (a stale epoch); traces under every other fingerprint stay warm.
    /// Returns how many were dropped.
    pub fn invalidate_fingerprint(&self, fp: u64) -> u64 {
        self.traces.remove_if(|k| k.cal_fp == fp)
    }

    /// Drop every memoized trace (hit/miss counters keep running — they
    /// are lifetime totals; per-request deltas are the caller's job).
    pub fn clear(&self) {
        self.traces.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::llama_single_node;
    use crate::engine::ops::validate_trace;
    use crate::util::prop;

    #[test]
    fn cached_simulation_matches_uncached() {
        let cache = TraceCache::new();
        let cal = Calibration::default();
        for m in [CpMethod::Ulysses, CpMethod::Upipe { u: 8, gqa_schedule: true }] {
            for s in [1u64 << 20, 2 << 20] {
                let p = llama_single_node(m, s);
                let a = simulate_with(&p, &cal);
                let b = simulate_cached(&p, &cal, &cache);
                assert_eq!(a.step_time, b.step_time, "{m:?} S={s}");
                assert_eq!(a.peak_bytes, b.peak_bytes, "{m:?} S={s}");
                assert_eq!(a.oom, b.oom, "{m:?} S={s}");
            }
        }
        assert_eq!((cache.hits(), cache.misses()), (0, 4));
        // Replaying a cell hits.
        let p = llama_single_node(CpMethod::Ulysses, 1 << 20);
        simulate_cached(&p, &cal, &cache);
        assert_eq!((cache.hits(), cache.len()), (1, 4));
    }

    #[test]
    fn streamed_trace_equals_collected_trace() {
        // `stream_trace_with` into a Vec sink must be byte-for-byte the
        // trace `build_trace_with` returns (same dispatch, same builder).
        let cal = Calibration::default();
        for m in [
            CpMethod::NativePyTorch,
            CpMethod::Ring,
            CpMethod::Ulysses,
            CpMethod::Fpdt { pi: 16 },
            CpMethod::Upipe { u: 8, gqa_schedule: true },
            CpMethod::UpipeFpdt { u: 8, pi: 8 },
        ] {
            let p = llama_single_node(m, 1 << 20);
            let collected = build_trace_with(&p, &cal);
            let mut streamed: Vec<Op> = Vec::new();
            stream_trace_with(&p, &cal, &mut streamed);
            assert_eq!(collected, streamed, "{m:?}");
        }
    }

    #[test]
    fn trace_cache_clear_evicts_but_keeps_counting() {
        let cache = TraceCache::new();
        let cal = Calibration::default();
        let p = llama_single_node(CpMethod::Ulysses, 1 << 20);
        simulate_cached(&p, &cal, &cache);
        assert_eq!((cache.hits(), cache.misses(), cache.len()), (0, 1, 1));
        cache.clear();
        assert!(cache.is_empty());
        // Replay rebuilds (a miss): counters are lifetime totals.
        simulate_cached(&p, &cal, &cache);
        assert_eq!((cache.hits(), cache.misses(), cache.len()), (0, 2, 1));
    }

    #[test]
    fn pin_variants_share_a_trace() {
        let cache = TraceCache::new();
        let cal = Calibration::default();
        let mut a = llama_single_node(CpMethod::Ulysses, 1 << 20);
        a.parallel.pin_memory = true;
        let mut b = a.clone();
        b.parallel.pin_memory = false;
        simulate_cached(&a, &cal, &cache);
        simulate_cached(&b, &cal, &cache);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
    }

    #[test]
    fn distinct_dims_and_calibrations_get_distinct_traces() {
        let cache = TraceCache::new();
        let cal = Calibration::default();
        let base = llama_single_node(CpMethod::Ulysses, 1 << 20);
        simulate_cached(&base, &cal, &cache);

        // A different AC mode must not alias the default trace.
        let mut ac = base.clone();
        ac.parallel.ac_mode = AcMode::AcGpu;
        simulate_cached(&ac, &cal, &cache);
        // Nor a different micro-batch count...
        let mut mb = base.clone();
        mb.parallel.micro_batch = 2;
        simulate_cached(&mb, &cal, &cache);
        // ...nor a refit-style calibration with different rates.
        let mut cal2 = cal.clone();
        cal2.fa3_fwd_flops *= 1.1;
        assert_ne!(cal.fingerprint(), cal2.fingerprint());
        simulate_cached(&base, &cal2, &cache);

        assert_eq!((cache.hits(), cache.misses()), (0, 4), "4 distinct keys");
    }

    #[test]
    fn cell_keys_are_hashed_structs_not_strings() {
        // The key type is Copy and distinct along every dimension the
        // trace depends on; pin variants collapse to one key.
        let cal = Calibration::default();
        let base = llama_single_node(CpMethod::Ulysses, 1 << 20);
        let k0 = CellKey::new(&base, &cal);
        let copied: CellKey = k0; // Copy, not Clone-of-String
        assert_eq!(k0, copied);

        let mut pin = base.clone();
        pin.parallel.pin_memory = !pin.parallel.pin_memory;
        assert_eq!(CellKey::new(&pin, &cal), k0, "pin variants share a key");

        let mut s2 = base.clone();
        s2.seq_len = 2 << 20;
        assert_ne!(CellKey::new(&s2, &cal), k0);
        let mut tp = base.clone();
        tp.parallel.tp = 2;
        assert_ne!(CellKey::new(&tp, &cal), k0);
        let mut model = base.clone();
        model.model.d_ff += 1; // refit-style dims variant, same name
        assert_ne!(CellKey::new(&model, &cal), k0);
        let mut cal2 = cal.clone();
        cal2.other_rate *= 1.5;
        assert_ne!(CellKey::new(&base, &cal2), k0);
        // Hardware variants re-key: an H200's HBM budget reaches the
        // probe via Quantities, so it must not alias H100 entries…
        let mut hw = base.clone();
        hw.cluster.hbm_bytes *= 141.0 / 80.0;
        assert_ne!(CellKey::new(&hw, &cal), k0);
        let mut ram = base.clone();
        ram.cluster.host_ram_bytes *= 2.0;
        assert_ne!(CellKey::new(&ram, &cal), k0);
        // …while identical hardware under a different display name (a
        // fleet pool of the paper's device) aliases on purpose.
        let mut renamed = base.clone();
        renamed.cluster.name = "H100";
        assert_eq!(CellKey::new(&renamed, &cal), k0);
    }

    #[test]
    fn feasibility_matches_pricing_on_hybrid_methods() {
        // The single-node prop test below cannot reach the hybrid families
        // (they only enumerate on multi-node clusters), so pin the bitwise
        // kernel/engine parity contract — and stream-vs-collect equality —
        // for them explicitly.
        use crate::config::presets::{llama_two_node, qwen_two_node};
        let cal = Calibration::default();
        for s in [1u64 << 19, 1 << 20, 3 << 20, 6 << 20] {
            for p in [
                llama_two_node(CpMethod::UspHybrid { ulysses: 8, ring: 2 }, s),
                llama_two_node(CpMethod::UpipeHybrid { u: 8, ulysses: 8, ring: 2 }, s),
                qwen_two_node(CpMethod::UspHybrid { ulysses: 8, ring: 2 }, s),
                qwen_two_node(CpMethod::Ring, s),
            ] {
                let m = p.parallel.method;
                let collected = build_trace_with(&p, &cal);
                let mut streamed: Vec<Op> = Vec::new();
                stream_trace_with(&p, &cal, &mut streamed);
                assert_eq!(collected, streamed, "{m:?} S={s}");
                let priced = simulate_with(&p, &cal);
                let feas = feasibility_with(&p, &cal);
                assert_eq!(
                    feas.peak_bytes.to_bits(),
                    priced.peak_bytes.to_bits(),
                    "{m:?} S={s}"
                );
                assert_eq!(feas.oom, priced.oom, "{m:?} S={s}");
                assert_eq!(feas.failed, priced.failed, "{m:?} S={s}");
            }
        }
    }

    #[test]
    fn method_seq_cap_agrees_with_failure_rule() {
        // The symbolic solver clamps to method_seq_cap; the probe paths
        // apply method_failure. If they ever disagreed, a solved wall
        // could contradict its own verification probes.
        let methods = [
            CpMethod::NativePyTorch,
            CpMethod::Ring,
            CpMethod::Ulysses,
            CpMethod::Fpdt { pi: 16 },
            CpMethod::Upipe { u: 8, gqa_schedule: true },
            CpMethod::UpipeFpdt { u: 8, pi: 8 },
        ];
        for m in methods {
            let cap = method_seq_cap(m);
            for s in [1u64 << 20, 4 << 20, (4 << 20) + 1, 8 << 20] {
                let p = llama_single_node(m, s);
                let failed = method_failure(&p).is_some();
                let beyond = cap.is_some_and(|c| s > c);
                assert_eq!(failed, beyond, "{m:?} S={s}");
            }
        }
        assert_eq!(method_seq_cap(CpMethod::Fpdt { pi: 4 }), Some(4 << 20));
        assert_eq!(method_seq_cap(CpMethod::Upipe { u: 8, gqa_schedule: true }), None);
    }

    #[test]
    fn peak_probe_predicate_matches_budgeted_feasibility_for_both_pins() {
        // The pin-sharing contract at the schedule layer: one unbounded-
        // host probe answers the budgeted predicate for every pin variant.
        let cal = Calibration::default();
        for m in [
            CpMethod::Ulysses,
            CpMethod::Fpdt { pi: 16 },
            CpMethod::Upipe { u: 8, gqa_schedule: true },
        ] {
            for s in [1u64 << 19, 1 << 20, 3 << 20, 6 << 20] {
                let mut p = llama_single_node(m, s);
                let probe = peak_probe_with(&p, &cal);
                for pin in [true, false] {
                    p.parallel.pin_memory = pin;
                    let budget = Quantities::new(&p).host_ram_for_offload();
                    let budgeted = feasibility_with(&p, &cal);
                    assert_eq!(
                        probe.feasible_with_host(budget),
                        budgeted.feasible(),
                        "{m:?} S={s} pin={pin}"
                    );
                }
            }
        }
    }

    #[test]
    fn family_key_collapses_mb_and_pin_but_not_ac_s_or_method() {
        let cal = Calibration::default();
        let base = llama_single_node(CpMethod::Ulysses, 1 << 20);
        let fam = |p: &RunPreset| CellKey::new(p, &cal).family();
        let f0 = fam(&base);

        let mut mb = base.clone();
        mb.parallel.micro_batch = 4;
        assert_eq!(fam(&mb), f0, "micro-batch variants share a model");
        let mut pin = base.clone();
        pin.parallel.pin_memory = !pin.parallel.pin_memory;
        assert_eq!(fam(&pin), f0, "pin variants share a model");
        let mut s2 = base.clone();
        s2.seq_len = 2 << 20;
        assert_eq!(fam(&s2), f0, "the model spans sequence lengths");

        let mut ac = base.clone();
        ac.parallel.ac_mode = AcMode::AcGpu;
        assert_ne!(fam(&ac), f0, "AC changes the peak polynomial");
        let mut tp = base.clone();
        tp.parallel.tp = 2;
        tp.parallel.cp_degree = 4;
        assert_ne!(fam(&tp), f0, "TP reshards the buffers");
        let other = llama_single_node(CpMethod::Ring, 1 << 20);
        assert_ne!(fam(&other), f0);
        // Per-rank hardware changes the fitted polynomial's budgets and
        // rates: it must split the family…
        let mut hw = base.clone();
        hw.cluster.hbm_bytes *= 141.0 / 80.0;
        assert_ne!(fam(&hw), f0, "an H200's walls are not an H100's");
        // …but a same-hardware pool shares fits across fleet shapes.
        use crate::config::DeviceSpec;
        let mut pool = base.clone();
        pool.cluster = DeviceSpec::h100().cluster(1, 8);
        assert_eq!(fam(&pool), f0, "identical hardware re-fits nothing");
    }

    #[test]
    fn fpdt_failure_rule_applies_on_cached_path() {
        let cache = TraceCache::new();
        let p = llama_single_node(CpMethod::Fpdt { pi: 16 }, 5 << 20);
        let r = simulate_cached(&p, &Calibration::default(), &cache);
        assert!(r.failed.is_some() || r.oom, "FPDT must not extrapolate past 4M");
    }

    #[test]
    fn fpdt_failure_rule_applies_on_feasibility_path() {
        let p = llama_single_node(CpMethod::Fpdt { pi: 16 }, 5 << 20);
        let f = feasibility_with(&p, &Calibration::default());
        assert!(!f.feasible(), "feasibility must reproduce the 4M wall");
    }

    /// Symbolic-solver invariants for one configuration: peaks monotone
    /// non-decreasing in S within the divisibility class, the pin-agnostic
    /// probe predicate equal to the budgeted one, and a degree-≤2 fit that
    /// reproduces the streamed kernel at *fresh* lattice points within the
    /// drift contract (bitwise-or-1e-9; every schedule's byte sizes are
    /// affine in S/C, so clean samples always admit a fit).
    fn symbolic_invariants_hold(p: &RunPreset, cal: &Calibration) -> bool {
        use crate::engine::symbolic::drift_ok;
        use crate::engine::{PeakModel, PeakSample};
        // 2^18 is a multiple of every swept C, so all probes share one
        // divisibility residue class (floor(S/C) steps exactly).
        let base = 1u64 << 18;
        let c = p.parallel.cp_degree;
        let probe_at = |i: u64| {
            let mut p2 = p.clone();
            p2.seq_len = i * base;
            peak_probe_with(&p2, cal)
        };
        let probes: Vec<PeakProbe> = (1..=6).map(probe_at).collect();
        for w in probes.windows(2) {
            if w[0].clean() && w[1].clean() {
                // Monotone peaks (the property bisection already relies on).
                if w[1].peak_bytes < w[0].peak_bytes || w[1].host_peak < w[0].host_peak {
                    return false;
                }
            } else if w[0].clean() != w[1].clean() && w[1].clean() {
                // Feasibility itself is monotone: a clean longer run
                // implies the shorter one was clean too.
                return false;
            }
        }
        // Pin-agnostic probe == budgeted predicate at this cell's own S.
        let probe_here = peak_probe_with(p, cal);
        for pin in [true, false] {
            let mut pp = p.clone();
            pp.parallel.pin_memory = pin;
            let budget = Quantities::new(&pp).host_ram_for_offload();
            if probe_here.feasible_with_host(budget) != feasibility_with(&pp, cal).feasible() {
                return false;
            }
        }
        // Fit on the first samples, check fresh points (the planner's
        // drift contract, extended beyond the held-out sample).
        if !probes[..4].iter().all(|pr| pr.clean()) {
            return true; // walls below the sample range: fallback territory
        }
        let sample = |i: usize| PeakSample {
            k: (i as u64 + 1) * base / c,
            peak_bytes: probes[i].peak_bytes,
            host_peak: probes[i].host_peak,
        };
        let linear: Vec<PeakSample> = (0..3).map(sample).collect();
        let quad: Vec<PeakSample> = (0..4).map(sample).collect();
        let Some(model) = PeakModel::fit(&linear).or_else(|| PeakModel::fit(&quad)) else {
            return false; // clean affine samples must always fit
        };
        for (i, pr) in probes.iter().enumerate().skip(3) {
            if !pr.clean() {
                continue;
            }
            let k = (i as u64 + 1) * base / c;
            if !drift_ok(model.predict_peak(k), pr.peak_bytes)
                || !drift_ok(model.predict_host(k), pr.host_peak)
            {
                return false;
            }
        }
        true
    }

    /// Timing-kernel invariants for one configuration: the streamed
    /// [`timing_with`] report must equal the collected-and-priced
    /// [`simulate_with`] report **bitwise** on `step_time`, all four
    /// Table-5 components, `peak_bytes`, `oom` and `failed` (with an
    /// empty timeline — that absence is the kernel's entire savings),
    /// and step time must be monotone nondecreasing in S within the
    /// divisibility class on clean runs (longer sequences never price
    /// faster: FLOPs, comm bytes and pressure penalties all grow with S).
    fn timing_invariants_hold(p: &RunPreset, cal: &Calibration, direct: &StepReport) -> bool {
        let timed = timing_with(p, cal);
        if timed.step_time.to_bits() != direct.step_time.to_bits()
            || timed.components.all_to_all.to_bits() != direct.components.all_to_all.to_bits()
            || timed.components.fa3_fwd.to_bits() != direct.components.fa3_fwd.to_bits()
            || timed.components.fa3_bwd.to_bits() != direct.components.fa3_bwd.to_bits()
            || timed.components.other.to_bits() != direct.components.other.to_bits()
            || timed.peak_bytes.to_bits() != direct.peak_bytes.to_bits()
            || timed.oom != direct.oom
            || timed.failed != direct.failed
            || !timed.timeline.samples().is_empty()
        {
            return false;
        }
        let clean = |r: &StepReport| !r.oom && r.failed.is_none();
        let base = 1u64 << 18; // one residue class: multiple of every swept C
        let steps: Vec<StepReport> = (1..=4)
            .map(|i| {
                let mut p2 = p.clone();
                p2.seq_len = i * base;
                timing_with(&p2, cal)
            })
            .collect();
        for w in steps.windows(2) {
            if clean(&w[0]) && clean(&w[1]) && w[1].step_time < w[0].step_time {
                return false;
            }
        }
        true
    }

    #[test]
    fn prop_traces_balanced_nonnegative_and_peak_stable_under_replay() {
        // Every method × S × AC mode × micro-batch × TP: the trace must
        // have balanced Alloc/Free pairs and non-negative bytes, its peak
        // must be invariant when replayed through the trace cache, the
        // streaming FeasibilityKernel must agree *bitwise* with the priced
        // engine on peak_bytes, oom and the failure value, the streamed
        // TimingKernel must agree *bitwise* with it on step_time and every
        // component (with monotone step times in S — see
        // `timing_invariants_hold`), and the symbolic wall solver's
        // invariants (monotone polynomial peaks, pin-agnostic probes) must
        // hold — see `symbolic_invariants_hold`.
        let methods = [
            CpMethod::NativePyTorch,
            CpMethod::Ring,
            CpMethod::Ulysses,
            CpMethod::Fpdt { pi: 16 },
            CpMethod::Upipe { u: 8, gqa_schedule: true },
            CpMethod::UpipeFpdt { u: 8, pi: 8 },
        ];
        let modes = [AcMode::AcOffload, AcMode::AcGpu, AcMode::NoAc];
        let cal = Calibration::default();
        let cache = TraceCache::new();
        prop::check(
            "trace-invariants",
            48,
            &[(0, 5), (1, 8), (0, 2), (0, 2), (0, 1)],
            |a| {
                let mut p = llama_single_node(methods[a[0] as usize], (a[1] as u64) << 18);
                p.parallel.ac_mode = modes[a[2] as usize];
                p.parallel.micro_batch = 1 << a[3];
                if a[4] == 1 {
                    // TP=2 on the same 8-GPU world (C halves).
                    p.parallel.tp = 2;
                    p.parallel.cp_degree = 4;
                }
                if p.parallel.validate_model(&p.model).is_err() {
                    return true; // e.g. FPDT × non-offload AC: not a valid cell
                }
                let trace = build_trace_with(&p, &cal);
                if validate_trace(&trace).is_err() {
                    return false;
                }
                // Allocs and comm volumes must be non-negative; offloads may be
                // negative (fetches release host RAM) but must net out >= 0 —
                // a trace can never fetch more than it stored.
                let mut host_net = 0.0f64;
                for op in &trace {
                    match op {
                        Op::Alloc { bytes, .. } | Op::AllToAll { bytes, .. } => {
                            if *bytes < 0.0 {
                                return false;
                            }
                        }
                        Op::Offload { bytes, .. } => host_net += bytes,
                        _ => {}
                    }
                }
                if host_net < -1e-6 {
                    return false;
                }
                let direct = simulate_with(&p, &cal);
                let replay1 = simulate_cached(&p, &cal, &cache);
                let replay2 = simulate_cached(&p, &cal, &cache);
                // Streaming feasibility must agree bitwise with pricing.
                let feas = feasibility_with(&p, &cal);
                feas.peak_bytes.to_bits() == direct.peak_bytes.to_bits()
                    && feas.oom == direct.oom
                    && feas.failed == direct.failed
                    && direct.peak_bytes == replay1.peak_bytes
                    && replay1.peak_bytes == replay2.peak_bytes
                    && direct.oom == replay2.oom
                    && timing_invariants_hold(&p, &cal, &direct)
                    && symbolic_invariants_hold(&p, &cal)
            },
        );
    }
}
