//! Context-parallelism schedules: one module per method in the paper's
//! evaluation. Each schedule turns a [`ScheduleCtx`] — derived quantities
//! plus calibration, AC mode, micro-batching and TP, built from a
//! (model, cluster, parallel layout, S) preset — into an op trace
//! ([`crate::engine::ops::Op`]) describing one training step on a
//! representative device; the engine prices it.
//!
//! Schedules encode the *structural* behaviour — which buffers exist when
//! (Tables 2 & 6), what is communicated (Fig. 4), what overlaps — while
//! the engine's calibration holds the fitted hardware rates. No schedule
//! reads `Calibration::default()` on its own: the calibration always
//! arrives through the `ScheduleCtx`, so planner-driven refits flow into
//! every trace uniformly.
//!
//! The planner sweeps thousands of (config, S) cells, many of them
//! repeatedly (bisection re-probes, frontier + report passes, pin-memory
//! variants that share a trace); [`TraceCache`] memoizes built traces so
//! those replays skip straight to pricing.

pub mod common;
pub mod compose;
pub mod fpdt;
pub mod gqa;
pub mod native;
pub mod ring_attn;
pub mod ulysses;
pub mod upipe;
pub mod usp;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::presets::RunPreset;
use crate::config::CpMethod;
use crate::engine::{Calibration, Engine, Op, StepReport};

pub use common::{AcEmitter, AcMode, Quantities, ScheduleCtx};

/// Build the op trace for a preset at the default calibration.
pub fn build_trace(p: &RunPreset) -> Vec<Op> {
    build_trace_with(p, &Calibration::default())
}

/// Build the op trace for a preset under a specific calibration — the
/// uniform builder contract: every schedule consumes calibration, AC mode,
/// micro-batch count and TP degree through one [`ScheduleCtx`].
pub fn build_trace_with(p: &RunPreset, calib: &Calibration) -> Vec<Op> {
    let ctx = ScheduleCtx::new(p, calib);
    match p.parallel.method {
        CpMethod::NativePyTorch => native::trace(&ctx),
        CpMethod::Ring => ring_attn::trace(&ctx),
        CpMethod::Ulysses => ulysses::trace(&ctx),
        CpMethod::Fpdt { pi } => fpdt::trace(&ctx, pi),
        CpMethod::Upipe { u, gqa_schedule } => upipe::trace(&ctx, u, gqa_schedule, false),
        CpMethod::UspHybrid { ulysses: cu, ring: cr } => usp::trace(&ctx, cu, cr),
        CpMethod::UpipeHybrid { u, ulysses: cu, ring: cr } => {
            usp::upipe_hybrid_trace(&ctx, u, cu, cr)
        }
        CpMethod::UpipeFpdt { u, pi } => compose::trace(&ctx, u, pi),
    }
}

/// Simulate one training step for a preset.
pub fn simulate(p: &RunPreset) -> StepReport {
    simulate_with(p, &Calibration::default())
}

pub fn simulate_with(p: &RunPreset, calib: &Calibration) -> StepReport {
    let trace = build_trace_with(p, calib);
    run_trace(p, calib, &trace)
}

/// `simulate_with`, but fetching the op trace from (or inserting it into)
/// `cache` — the planner's hot path.
pub fn simulate_cached(p: &RunPreset, calib: &Calibration, cache: &TraceCache) -> StepReport {
    let trace = cache.trace(p, calib);
    run_trace(p, calib, trace.as_slice())
}

/// Price an already-built trace for a preset (shared by the cached and
/// uncached simulation paths). Host RAM comes from the cluster config so
/// offload-heavy schedules (FPDT, AC-offload, micro-batched runs) can OOM
/// on the host side too.
fn run_trace(p: &RunPreset, calib: &Calibration, trace: &[Op]) -> StepReport {
    let q = Quantities::new(p);
    let engine = Engine::new(
        calib.clone(),
        q.hbm_limit,
        q.persistent_bytes(calib),
        q.host_ram_for_offload(),
    );
    let mut report = engine.run(trace);
    // FPDT's published implementation fails beyond 4M tokens (§5.2 note);
    // reproduce the failure rather than extrapolating.
    if let CpMethod::Fpdt { .. } = p.parallel.method {
        if p.seq_len > 4 * 1024 * 1024 {
            report.failed = Some("FPDT execution fails at lengths > 4M (paper §5.2)");
        }
    }
    report
}

/// Thread-safe memo of built op traces, keyed by every input the trace
/// builder reads. Traces are immutable once built, so they are shared as
/// `Arc`s; concurrent builders may race on a cold key, in which case one
/// build is discarded and the canonical entry wins.
#[derive(Default)]
pub struct TraceCache {
    traces: Mutex<HashMap<String, Arc<Vec<Op>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl TraceCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cache key: everything the trace depends on — the full model dims
    /// (not just the name: refit experiments build modified variants that
    /// keep it), cluster shape, layout and S, the AC/micro-batch/TP dims,
    /// and the calibration fingerprint (refit calibrations change emitted
    /// op durations and byte sizes, so they must not alias the default
    /// fit's traces). Note `pin_memory` is deliberately absent — pinning
    /// changes pricing (host-RAM budget), not trace structure, so pin
    /// variants share one trace.
    pub fn key(p: &RunPreset, calib: &Calibration) -> String {
        format!(
            "{:?}|{:?}|{}n{}g|c{}|s{}|{:?}|b{}|tp{}|cal{:016x}",
            p.parallel.method,
            p.model,
            p.cluster.nodes,
            p.cluster.gpus_per_node,
            p.parallel.cp_degree,
            p.seq_len,
            p.parallel.ac_mode,
            p.parallel.micro_batch,
            p.parallel.tp,
            calib.fingerprint()
        )
    }

    /// Fetch (or build and insert) the trace for `p` under `calib`.
    pub fn trace(&self, p: &RunPreset, calib: &Calibration) -> Arc<Vec<Op>> {
        let key = Self::key(p, calib);
        if let Some(t) = self.traces.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return t.clone();
        }
        // Build outside the lock: traces can be long and the planner's
        // workers build neighbouring cells concurrently.
        let built = Arc::new(build_trace_with(p, calib));
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut map = self.traces.lock().unwrap();
        map.entry(key).or_insert(built).clone()
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.traces.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::llama_single_node;
    use crate::engine::ops::validate_trace;
    use crate::util::prop;

    #[test]
    fn cached_simulation_matches_uncached() {
        let cache = TraceCache::new();
        let cal = Calibration::default();
        for m in [CpMethod::Ulysses, CpMethod::Upipe { u: 8, gqa_schedule: true }] {
            for s in [1u64 << 20, 2 << 20] {
                let p = llama_single_node(m, s);
                let a = simulate_with(&p, &cal);
                let b = simulate_cached(&p, &cal, &cache);
                assert_eq!(a.step_time, b.step_time, "{m:?} S={s}");
                assert_eq!(a.peak_bytes, b.peak_bytes, "{m:?} S={s}");
                assert_eq!(a.oom, b.oom, "{m:?} S={s}");
            }
        }
        assert_eq!((cache.hits(), cache.misses()), (0, 4));
        // Replaying a cell hits.
        let p = llama_single_node(CpMethod::Ulysses, 1 << 20);
        simulate_cached(&p, &cal, &cache);
        assert_eq!((cache.hits(), cache.len()), (1, 4));
    }

    #[test]
    fn pin_variants_share_a_trace() {
        let cache = TraceCache::new();
        let cal = Calibration::default();
        let mut a = llama_single_node(CpMethod::Ulysses, 1 << 20);
        a.parallel.pin_memory = true;
        let mut b = a.clone();
        b.parallel.pin_memory = false;
        simulate_cached(&a, &cal, &cache);
        simulate_cached(&b, &cal, &cache);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
    }

    #[test]
    fn distinct_dims_and_calibrations_get_distinct_traces() {
        let cache = TraceCache::new();
        let cal = Calibration::default();
        let base = llama_single_node(CpMethod::Ulysses, 1 << 20);
        simulate_cached(&base, &cal, &cache);

        // A different AC mode must not alias the default trace.
        let mut ac = base.clone();
        ac.parallel.ac_mode = AcMode::AcGpu;
        simulate_cached(&ac, &cal, &cache);
        // Nor a different micro-batch count...
        let mut mb = base.clone();
        mb.parallel.micro_batch = 2;
        simulate_cached(&mb, &cal, &cache);
        // ...nor a refit-style calibration with different rates.
        let mut cal2 = cal.clone();
        cal2.fa3_fwd_flops *= 1.1;
        assert_ne!(cal.fingerprint(), cal2.fingerprint());
        simulate_cached(&base, &cal2, &cache);

        assert_eq!((cache.hits(), cache.misses()), (0, 4), "4 distinct keys");
    }

    #[test]
    fn fpdt_failure_rule_applies_on_cached_path() {
        let cache = TraceCache::new();
        let p = llama_single_node(CpMethod::Fpdt { pi: 16 }, 5 << 20);
        let r = simulate_cached(&p, &Calibration::default(), &cache);
        assert!(r.failed.is_some() || r.oom, "FPDT must not extrapolate past 4M");
    }

    #[test]
    fn prop_traces_balanced_nonnegative_and_peak_stable_under_replay() {
        // Every method × S × AC mode × micro-batch: the trace must have
        // balanced Alloc/Free pairs and non-negative bytes, and its peak
        // must be invariant when replayed through the trace cache.
        let methods = [
            CpMethod::NativePyTorch,
            CpMethod::Ring,
            CpMethod::Ulysses,
            CpMethod::Fpdt { pi: 16 },
            CpMethod::Upipe { u: 8, gqa_schedule: true },
            CpMethod::UpipeFpdt { u: 8, pi: 8 },
        ];
        let modes = [AcMode::AcOffload, AcMode::AcGpu, AcMode::NoAc];
        let cal = Calibration::default();
        let cache = TraceCache::new();
        prop::check("trace-invariants", 40, &[(0, 5), (1, 8), (0, 2), (0, 2)], |a| {
            let mut p = llama_single_node(methods[a[0] as usize], (a[1] as u64) << 18);
            p.parallel.ac_mode = modes[a[2] as usize];
            p.parallel.micro_batch = 1 << a[3];
            if p.parallel.validate_model(&p.model).is_err() {
                return true; // e.g. FPDT × non-offload AC: not a valid cell
            }
            let trace = build_trace_with(&p, &cal);
            if validate_trace(&trace).is_err() {
                return false;
            }
            // Allocs and comm volumes must be non-negative; offloads may be
            // negative (fetches release host RAM) but must net out >= 0 —
            // a trace can never fetch more than it stored.
            let mut host_net = 0.0f64;
            for op in &trace {
                match op {
                    Op::Alloc { bytes, .. } | Op::AllToAll { bytes, .. } => {
                        if *bytes < 0.0 {
                            return false;
                        }
                    }
                    Op::Offload { bytes, .. } => host_net += bytes,
                    _ => {}
                }
            }
            if host_net < -1e-6 {
                return false;
            }
            let direct = simulate_with(&p, &cal);
            let replay1 = simulate_cached(&p, &cal, &cache);
            let replay2 = simulate_cached(&p, &cal, &cache);
            direct.peak_bytes == replay1.peak_bytes
                && replay1.peak_bytes == replay2.peak_bytes
                && direct.oom == replay2.oom
        });
    }
}
