//! Quantities shared by every schedule: tensor byte sizes, persistent
//! memory, the "misc" live set, and the bulk "other" time term.

use crate::config::presets::RunPreset;
use crate::engine::{Calibration, Category, TraceBuilder};
use crate::model::ModelDims;

/// Activation-checkpointing mode (Fig. 2 compares all three for Ulysses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AcMode {
    /// No checkpointing: every layer's intra-layer activations stay
    /// resident until backward.
    NoAc,
    /// Full AC, checkpoints (layer inputs) kept on GPU.
    AcGpu,
    /// Full AC with CPU offloading (paper default, "AO" in Fig. 2).
    AcOffload,
}

/// Byte sizes and derived quantities for one run.
#[derive(Debug, Clone)]
pub struct Quantities {
    pub m: ModelDims,
    pub s: u64,
    /// total CP degree C (== total GPUs)
    pub c: u64,
    /// tokens per device S/C
    pub sc: u64,
    /// bf16 [S/C, d_model] — the paper's "S/C" unit for the residual stream
    pub x_bytes: f64,
    /// bf16 [S/C, H·d_head] — the unit of Q and of Table 2/6 coefficients
    pub q_bytes: f64,
    /// bf16 [S/C, Hkv·d_head]
    pub kv_bytes: f64,
    pub hbm_limit: f64,
    pub nodes: u64,
    pub host_ram: f64,
    pub pin_memory: bool,
    pub ac_offload: bool,
}

impl Quantities {
    pub fn new(p: &RunPreset) -> Self {
        let m = p.model.clone();
        let c = p.parallel.cp_degree;
        let s = p.seq_len;
        let sc = s / c;
        Quantities {
            x_bytes: 2.0 * sc as f64 * m.d_model as f64,
            q_bytes: 2.0 * sc as f64 * m.q_width() as f64,
            kv_bytes: 2.0 * sc as f64 * m.kv_width() as f64,
            hbm_limit: p.cluster.hbm_bytes * 0.95,
            nodes: p.cluster.nodes,
            host_ram: p.cluster.host_ram_bytes,
            pin_memory: p.parallel.pin_memory,
            ac_offload: p.parallel.ac_offload,
            m,
            s,
            c,
            sc,
        }
    }

    /// γ·q_bytes — combined QKV bytes for one layer's full-head tensors.
    pub fn qkv_bytes(&self) -> f64 {
        self.q_bytes + 2.0 * self.kv_bytes
    }

    /// FSDP-sharded persistent state + framework base (CUDA context, NCCL,
    /// workspaces).
    pub fn persistent_bytes(&self, cal: &Calibration) -> f64 {
        let fsdp = cal.bytes_per_param_fsdp * self.m.params() as f64 / self.c as f64;
        let base = if self.nodes > 1 {
            cal.base_framework_2node
        } else {
            cal.base_framework_1node
        };
        fsdp + base
    }

    /// Host RAM available for offloaded activations: the node's RAM minus a
    /// reserve for the OS/dataloader; non-swappable (pinned) allocations
    /// cap out earlier (§5.1 flips PIN_MEMORY off at 5M for this reason).
    pub fn host_ram_for_offload(&self) -> f64 {
        let reserve = 0.15 * self.host_ram;
        if self.pin_memory {
            0.6 * self.host_ram
        } else {
            self.host_ram - reserve
        }
    }

    /// Per-device attention FLOPs for one forward pass of one layer.
    pub fn attn_flops_layer_fwd(&self) -> f64 {
        crate::model::flops::attn_fwd(&self.m, self.s) / (self.m.n_layers * self.c) as f64
    }

    /// The "misc" live set: gradient stream, recompute set and offload
    /// staging buffers that are resident while a layer is processed.
    /// Decomposition (see calibration provenance): dx 1, d_resid 1,
    /// checkpoint prefetch 1, normed input 1, staging 0.74 (all
    /// d_model-wide) plus the attention block's pre-projection output and
    /// its gradient, which are H·d_head-wide (equal for Llama, 1.6× for
    /// Qwen3's explicit head_dim) — total 6.74 units at H·d_head = d_model.
    pub fn emit_misc(&self, b: &mut TraceBuilder) -> Vec<crate::engine::ops::BufId> {
        let x = self.x_bytes;
        let q = self.q_bytes;
        vec![
            b.alloc("grad_dx", x),
            b.alloc("grad_dresid", x),
            b.alloc("ckpt_prefetch", x),
            b.alloc("grad_dout", q),
            b.alloc("norm_xn", x),
            b.alloc("attn_block_out", q),
            b.alloc("offload_staging", 0.74 * x),
        ]
    }

    /// Bulk "other" time (projections, MLP, norms, loss, optimizer, data):
    /// fitted rate, see calibration.
    pub fn emit_other(&self, b: &mut TraceBuilder, cal: &Calibration, factor: f64) {
        let secs = cal.other_fixed_per_layer * self.m.n_layers as f64
            + cal.other_rate * self.s as f64 * self.m.d_model as f64 * self.m.n_layers as f64
                / self.c as f64;
        b.fixed(Category::Other, secs * factor);
    }

    /// FPDT variant of the misc set: the attention-adjacent full-head
    /// buffers (block output + its gradient) only ever exist one sequence
    /// chunk at a time, so they drop out; the d_model-wide residual-stream
    /// buffers remain.
    pub fn emit_misc_chunked(&self, b: &mut TraceBuilder) -> Vec<crate::engine::ops::BufId> {
        let x = self.x_bytes;
        vec![
            b.alloc("grad_dx", x),
            b.alloc("grad_dresid", x),
            b.alloc("ckpt_prefetch", x),
            b.alloc("norm_xn", x),
            b.alloc("offload_staging", 0.74 * x),
        ]
    }

    /// AC offload volume for the whole step (store on fwd + fetch on bwd of
    /// every layer input).
    pub fn ac_offload_bytes(&self) -> f64 {
        2.0 * self.m.n_layers as f64 * self.x_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::llama_single_node;
    use crate::config::CpMethod;

    fn q() -> Quantities {
        Quantities::new(&llama_single_node(CpMethod::Ulysses, 1 << 20))
    }

    #[test]
    fn unit_sizes() {
        let q = q();
        assert_eq!(q.sc, (1 << 20) / 8);
        // llama: q_width == d_model so x == q
        assert_eq!(q.x_bytes, q.q_bytes);
        assert!((q.qkv_bytes() / q.q_bytes - 1.5).abs() < 1e-12); // γ = 1.5
    }

    #[test]
    fn qwen_q_larger_than_x() {
        use crate::config::presets::qwen_two_node;
        let p = qwen_two_node(CpMethod::UspHybrid { ulysses: 8, ring: 2 }, 1 << 20);
        let q = Quantities::new(&p);
        assert!((q.q_bytes / q.x_bytes - 1.6).abs() < 1e-12); // 8192/5120
    }

    #[test]
    fn persistent_matches_fit() {
        // Llama3-8B, C=8: 16·P/8 + 4.32 GiB ≈ 19.3 GiB (the Table 4 fit).
        let q = q();
        let cal = Calibration::default();
        let gib = q.persistent_bytes(&cal) / (1u64 << 30) as f64;
        assert!((gib - 19.3).abs() < 0.4, "persistent {gib} GiB");
    }

    #[test]
    fn misc_totals_674_units() {
        let q = q();
        let mut b = TraceBuilder::new();
        let ids = q.emit_misc(&mut b);
        assert_eq!(ids.len(), 7);
        let total: f64 = b
            .finish()
            .iter()
            .map(|op| match op {
                crate::engine::Op::Alloc { bytes, .. } => *bytes,
                _ => 0.0,
            })
            .sum();
        assert!((total / q.x_bytes - 6.74).abs() < 1e-9);
    }

    #[test]
    fn unpinned_host_ram_larger() {
        use crate::config::presets::qwen_two_node;
        let pinned = Quantities::new(&qwen_two_node(CpMethod::Ring, 1 << 20));
        let unpinned = Quantities::new(&qwen_two_node(CpMethod::Ring, 5 << 20));
        assert!(unpinned.host_ram_for_offload() > pinned.host_ram_for_offload());
    }
}
