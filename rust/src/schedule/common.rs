//! Quantities shared by every schedule — tensor byte sizes, persistent
//! memory, the "misc" live set, the bulk "other" time term — and the
//! [`ScheduleCtx`] builder contract that threads calibration, AC mode,
//! micro-batching and TP uniformly through every trace builder.

use crate::config::presets::RunPreset;
use crate::engine::ops::{BufId, OpSink};
use crate::engine::{Calibration, Category, TraceBuilder};
use crate::model::ModelDims;

pub use crate::config::parallel::AcMode;

/// Byte sizes and derived quantities for one run.
#[derive(Debug, Clone)]
pub struct Quantities {
    pub m: ModelDims,
    pub s: u64,
    /// context-parallel degree C (sequence sharding; == total GPUs at tp=1)
    pub c: u64,
    /// tensor-parallel degree sharing the mesh with CP (head sharding)
    pub tp: u64,
    /// tokens per CP rank S/C
    pub sc: u64,
    /// bf16 [S/C, d_model] — the paper's "S/C" unit for the residual stream
    /// (replicated across TP ranks)
    pub x_bytes: f64,
    /// bf16 [S/C, H·d_head / tp] — the unit of Q and of Table 2/6
    /// coefficients (heads sharded TP-wise)
    pub q_bytes: f64,
    /// bf16 [S/C, Hkv·d_head / tp]
    pub kv_bytes: f64,
    pub hbm_limit: f64,
    pub nodes: u64,
    pub host_ram: f64,
    pub pin_memory: bool,
}

impl Quantities {
    pub fn new(p: &RunPreset) -> Self {
        let m = p.model.clone();
        let c = p.parallel.cp_degree;
        let tp = p.parallel.tp.max(1);
        let s = p.seq_len;
        let sc = s / c;
        Quantities {
            x_bytes: 2.0 * sc as f64 * m.d_model as f64,
            q_bytes: 2.0 * sc as f64 * m.q_width() as f64 / tp as f64,
            kv_bytes: 2.0 * sc as f64 * m.kv_width() as f64 / tp as f64,
            hbm_limit: p.cluster.hbm_bytes * 0.95,
            nodes: p.cluster.nodes,
            host_ram: p.cluster.host_ram_bytes,
            pin_memory: p.parallel.pin_memory,
            m,
            s,
            c,
            tp,
            sc,
        }
    }

    /// Total ranks (CP × TP) sharing the FSDP parameter shard.
    pub fn world(&self) -> u64 {
        self.c * self.tp
    }

    /// γ·q_bytes — combined QKV bytes for one layer's full-head tensors.
    pub fn qkv_bytes(&self) -> f64 {
        self.q_bytes + 2.0 * self.kv_bytes
    }

    /// FSDP-sharded persistent state + framework base (CUDA context, NCCL,
    /// workspaces).
    pub fn persistent_bytes(&self, cal: &Calibration) -> f64 {
        let fsdp = cal.bytes_per_param_fsdp * self.m.params() as f64 / self.world() as f64;
        let base = if self.nodes > 1 {
            cal.base_framework_2node
        } else {
            cal.base_framework_1node
        };
        fsdp + base
    }

    /// Host RAM available for offloaded activations: the node's RAM minus a
    /// reserve for the OS/dataloader; non-swappable (pinned) allocations
    /// cap out earlier (§5.1 flips PIN_MEMORY off at 5M for this reason).
    pub fn host_ram_for_offload(&self) -> f64 {
        let reserve = 0.15 * self.host_ram;
        if self.pin_memory {
            0.6 * self.host_ram
        } else {
            self.host_ram - reserve
        }
    }

    /// Per-device attention FLOPs for one forward pass of one layer.
    pub fn attn_flops_layer_fwd(&self) -> f64 {
        crate::model::flops::attn_fwd(&self.m, self.s) / (self.m.n_layers * self.world()) as f64
    }

    /// The "misc" live set: gradient stream, recompute set and offload
    /// staging buffers that are resident while a layer is processed.
    /// Decomposition (see calibration provenance): dx 1, d_resid 1,
    /// checkpoint prefetch 1, normed input 1, staging 0.74 (all
    /// d_model-wide) plus the attention block's pre-projection output and
    /// its gradient, which are H·d_head-wide (equal for Llama, 1.6× for
    /// Qwen3's explicit head_dim) — total 6.74 units at H·d_head = d_model.
    pub fn emit_misc<S: OpSink>(&self, b: &mut TraceBuilder<S>) -> Vec<BufId> {
        let x = self.x_bytes;
        let q = self.q_bytes;
        vec![
            b.alloc("grad_dx", x),
            b.alloc("grad_dresid", x),
            b.alloc("ckpt_prefetch", x),
            b.alloc("grad_dout", q),
            b.alloc("norm_xn", x),
            b.alloc("attn_block_out", q),
            b.alloc("offload_staging", 0.74 * x),
        ]
    }

    /// Per-token share of the bulk "other" work (projections, MLP, loss):
    /// TP shards these matmuls, so the rate term divides by the whole
    /// CP×TP world, not just the CP degree.
    pub fn other_rate_secs(&self, cal: &Calibration) -> f64 {
        cal.other_rate * self.s as f64 * self.m.d_model as f64 * self.m.n_layers as f64
            / self.world() as f64
    }

    /// Bulk "other" time (projections, MLP, norms, loss, optimizer, data):
    /// fitted rate, see calibration.
    pub fn emit_other<S: OpSink>(&self, b: &mut TraceBuilder<S>, cal: &Calibration, factor: f64) {
        let secs = cal.other_fixed_per_layer * self.m.n_layers as f64 + self.other_rate_secs(cal);
        b.fixed(Category::Other, secs * factor);
    }

    /// FPDT variant of the misc set: the attention-adjacent full-head
    /// buffers (block output + its gradient) only ever exist one sequence
    /// chunk at a time, so they drop out; the d_model-wide residual-stream
    /// buffers remain.
    pub fn emit_misc_chunked<S: OpSink>(&self, b: &mut TraceBuilder<S>) -> Vec<BufId> {
        let x = self.x_bytes;
        vec![
            b.alloc("grad_dx", x),
            b.alloc("grad_dresid", x),
            b.alloc("ckpt_prefetch", x),
            b.alloc("norm_xn", x),
            b.alloc("offload_staging", 0.74 * x),
        ]
    }

}

/// Everything a schedule needs to build its trace: the derived byte/FLOP
/// quantities, the calibrated rates, and the run-shape configuration
/// (AC mode, micro-batch count, TP degree). One `ScheduleCtx` is the
/// uniform builder contract for all eight method modules — no schedule
/// reaches for `Calibration::default()` on its own.
#[derive(Debug, Clone)]
pub struct ScheduleCtx {
    /// Derived byte/FLOP quantities — including the TP degree, which lives
    /// here only (`q.tp`) so byte sharding can never disagree with it.
    pub q: Quantities,
    pub cal: Calibration,
    /// Activation-checkpointing mode for every layer.
    pub ac: AcMode,
    /// Micro-batches per optimizer step (sequential, gradient-accumulated).
    pub mb: u64,
}

impl ScheduleCtx {
    pub fn new(p: &RunPreset, cal: &Calibration) -> Self {
        ScheduleCtx {
            q: Quantities::new(p),
            cal: cal.clone(),
            ac: p.parallel.ac_mode,
            mb: p.parallel.micro_batch.max(1),
        }
    }

    /// Per-micro-batch activation-checkpoint emitter (one per micro-batch:
    /// retained checkpoints are released when its backward completes).
    pub fn ac_emitter(&self) -> AcEmitter {
        let q = &self.q;
        AcEmitter {
            mode: self.ac,
            x_bytes: q.x_bytes,
            // NoAc keeps the full intra-layer live set: input, normed
            // input, QKV, attention out, MLP intermediates (4·[S/C, d_ff],
            // d_ff sharded TP-wise like the head buffers).
            noac_bytes: 2.0 * q.x_bytes
                + q.qkv_bytes()
                + 8.0 * q.sc as f64 * q.m.d_ff as f64 / q.tp as f64,
            resident: Vec::new(),
        }
    }

    /// Bulk "other" time for the whole step: the first micro-batch carries
    /// the per-step fixed share (optimizer, data loader, launch floors),
    /// later micro-batches amortize it and add only the per-token work —
    /// the throughput benefit gradient accumulation actually buys.
    pub fn emit_other<S: OpSink>(&self, b: &mut TraceBuilder<S>, factor: f64) {
        self.q.emit_other(b, &self.cal, factor);
        if self.mb > 1 {
            let per_token = self.q.other_rate_secs(&self.cal);
            b.fixed(Category::Other, per_token * factor * (self.mb - 1) as f64);
        }
    }

    /// Megatron-style TP all-reduces for one layer direction: 2 calls of
    /// the [S/C, d_model] residual activation, ring cost 2·(tp-1)/tp per
    /// participant. No-op at tp == 1. Schedules call this *inside* their
    /// layer loops so the engine's comm-pressure penalty prices it against
    /// the allocations actually live when it runs — an end-of-trace
    /// aggregate would always see ample headroom.
    pub fn emit_tp_allreduce<S: OpSink>(&self, b: &mut TraceBuilder<S>) {
        let tp = self.q.tp;
        if tp > 1 {
            let per_ar = 2.0 * (tp - 1) as f64 / tp as f64 * self.q.x_bytes;
            b.all_to_all(2.0 * per_ar, true, 2, self.q.s as f64);
        }
    }
}

/// Emits the activation-checkpoint ops for one micro-batch, uniformly for
/// every schedule: offloaded checkpoints (paper default), GPU-resident
/// checkpoints, or no checkpointing at all.
#[derive(Debug)]
pub struct AcEmitter {
    mode: AcMode,
    x_bytes: f64,
    noac_bytes: f64,
    resident: Vec<BufId>,
}

impl AcEmitter {
    /// End of one layer's forward: checkpoint the layer input (offload /
    /// keep on GPU / keep the whole intra-layer live set).
    pub fn store<S: OpSink>(&mut self, b: &mut TraceBuilder<S>) {
        match self.mode {
            AcMode::AcOffload => b.offload(self.x_bytes, true),
            AcMode::AcGpu => self.resident.push(b.alloc("ckpt_gpu", self.x_bytes)),
            AcMode::NoAc => self.resident.push(b.alloc("noac_layer_acts", self.noac_bytes)),
        }
    }

    /// Start of one layer's backward: fetch the checkpoint if offloaded
    /// (negative bytes: the transfer is paid, the host RAM is released).
    pub fn fetch<S: OpSink>(&mut self, b: &mut TraceBuilder<S>) {
        if self.mode == AcMode::AcOffload {
            b.offload(-self.x_bytes, true);
        }
    }

    /// Does backward need the forward recompute pass?
    pub fn recompute(&self) -> bool {
        self.mode != AcMode::NoAc
    }

    /// End of the micro-batch's backward: release retained checkpoints.
    pub fn finish<S: OpSink>(&mut self, b: &mut TraceBuilder<S>) {
        for id in self.resident.drain(..) {
            b.free(id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::llama_single_node;
    use crate::config::CpMethod;

    fn q() -> Quantities {
        Quantities::new(&llama_single_node(CpMethod::Ulysses, 1 << 20))
    }

    #[test]
    fn unit_sizes() {
        let q = q();
        assert_eq!(q.sc, (1 << 20) / 8);
        // llama: q_width == d_model so x == q
        assert_eq!(q.x_bytes, q.q_bytes);
        assert!((q.qkv_bytes() / q.q_bytes - 1.5).abs() < 1e-12); // γ = 1.5
    }

    #[test]
    fn qwen_q_larger_than_x() {
        use crate::config::presets::qwen_two_node;
        let p = qwen_two_node(CpMethod::UspHybrid { ulysses: 8, ring: 2 }, 1 << 20);
        let q = Quantities::new(&p);
        assert!((q.q_bytes / q.x_bytes - 1.6).abs() < 1e-12); // 8192/5120
    }

    #[test]
    fn persistent_matches_fit() {
        // Llama3-8B, C=8: 16·P/8 + 4.32 GiB ≈ 19.3 GiB (the Table 4 fit).
        let q = q();
        let cal = Calibration::default();
        let gib = q.persistent_bytes(&cal) / (1u64 << 30) as f64;
        assert!((gib - 19.3).abs() < 0.4, "persistent {gib} GiB");
    }

    #[test]
    fn misc_totals_674_units() {
        let q = q();
        let mut b = TraceBuilder::new();
        let ids = q.emit_misc(&mut b);
        assert_eq!(ids.len(), 7);
        let total: f64 = b
            .finish()
            .iter()
            .map(|op| match op {
                crate::engine::Op::Alloc { bytes, .. } => *bytes,
                _ => 0.0,
            })
            .sum();
        assert!((total / q.x_bytes - 6.74).abs() < 1e-9);
    }

    #[test]
    fn unpinned_host_ram_larger() {
        use crate::config::presets::qwen_two_node;
        let pinned = Quantities::new(&qwen_two_node(CpMethod::Ring, 1 << 20));
        let unpinned = Quantities::new(&qwen_two_node(CpMethod::Ring, 5 << 20));
        assert!(unpinned.host_ram_for_offload() > pinned.host_ram_for_offload());
    }

    #[test]
    fn tp_shards_heads_but_not_residual() {
        let mut p = llama_single_node(CpMethod::Ulysses, 1 << 20);
        let base = Quantities::new(&p);
        p.parallel.tp = 2;
        p.parallel.cp_degree = 4; // same 8-GPU world
        let tp = Quantities::new(&p);
        assert_eq!(tp.world(), base.world());
        // S/C doubles (CP shrank), head buffers are halved per token.
        assert_eq!(tp.sc, 2 * base.sc);
        assert!((tp.q_bytes - base.q_bytes).abs() < 1e-6, "2x tokens / 2 tp");
        assert!((tp.x_bytes - 2.0 * base.x_bytes).abs() < 1e-6, "residual replicated");
        // FSDP persistent is sharded over the world, so it is unchanged.
        let cal = Calibration::default();
        assert!((tp.persistent_bytes(&cal) - base.persistent_bytes(&cal)).abs() < 1.0);
        // Per-device attention FLOPs are world-sharded, so unchanged too.
        assert!((tp.attn_flops_layer_fwd() - base.attn_flops_layer_fwd()).abs() < 1.0);
    }

    #[test]
    fn ac_emitter_modes() {
        use crate::engine::ops::validate_trace;
        let p = llama_single_node(CpMethod::Ulysses, 1 << 20);
        let cal = Calibration::default();
        let bytes_of = |mode: AcMode| -> (f64, usize) {
            let mut p2 = p.clone();
            p2.parallel.ac_mode = mode;
            let ctx = ScheduleCtx::new(&p2, &cal);
            let mut b = TraceBuilder::new();
            let mut ac = ctx.ac_emitter();
            for _ in 0..4 {
                ac.store(&mut b);
            }
            for _ in 0..4 {
                ac.fetch(&mut b);
            }
            ac.finish(&mut b);
            let ops = b.finish();
            validate_trace(&ops).unwrap();
            let total: f64 = ops
                .iter()
                .map(|op| match op {
                    crate::engine::Op::Alloc { bytes, .. } => *bytes,
                    _ => 0.0,
                })
                .sum();
            (total, ops.len())
        };
        let (off, off_ops) = bytes_of(AcMode::AcOffload);
        let (gpu, _) = bytes_of(AcMode::AcGpu);
        let (noac, _) = bytes_of(AcMode::NoAc);
        assert_eq!(off, 0.0, "offload mode allocates nothing on GPU");
        assert_eq!(off_ops, 8, "4 stores + 4 fetches");
        assert!(noac > 2.0 * gpu, "NoAc holds far more than checkpoints");
    }

    #[test]
    fn emit_other_scales_with_microbatch_and_tp() {
        let mut p = llama_single_node(CpMethod::Ulysses, 1 << 20);
        let cal = Calibration::default();
        let other_secs = |p: &RunPreset| -> (f64, f64) {
            let ctx = ScheduleCtx::new(p, &cal);
            let mut b = TraceBuilder::new();
            ctx.emit_other(&mut b, 1.0);
            let mut fixed = 0.0;
            let mut comm = 0.0;
            for op in b.finish() {
                match op {
                    crate::engine::Op::Fixed { secs, .. } => fixed += secs,
                    crate::engine::Op::AllToAll { bytes, .. } => comm += bytes,
                    _ => {}
                }
            }
            (fixed, comm)
        };
        let (base, base_comm) = other_secs(&p);
        assert_eq!(base_comm, 0.0, "emit_other never carries comm");
        p.parallel.micro_batch = 4;
        let (mb4, _) = other_secs(&p);
        // 4 micro-batches: 4x the per-token work, but the per-step fixed
        // share is paid once — strictly less than a naive 4x.
        assert!(mb4 > 3.0 * base, "mb4 {mb4} vs base {base}");
        assert!(mb4 < 4.0 * base, "fixed share amortizes: {mb4} vs {base}");
        p.parallel.micro_batch = 1;
        p.parallel.tp = 2;
        p.parallel.cp_degree = 4;
        let (tp_other, _) = other_secs(&p);
        // Same 8-GPU world: the TP-sharded rate term matches tp=1's.
        assert!((tp_other - base).abs() < 1e-9, "tp {tp_other} vs base {base}");
        // The per-layer TP all-reduce emitter carries the comm instead,
        // and is a no-op at tp=1.
        let cal2 = Calibration::default();
        let tp_comm = |p: &RunPreset| -> (f64, usize) {
            let ctx = ScheduleCtx::new(p, &cal2);
            let mut b = TraceBuilder::new();
            ctx.emit_tp_allreduce(&mut b);
            let ops = b.finish();
            let bytes = ops
                .iter()
                .map(|op| match op {
                    crate::engine::Op::AllToAll { bytes, .. } => *bytes,
                    _ => 0.0,
                })
                .sum();
            (bytes, ops.len())
        };
        let (b2, n2) = tp_comm(&p);
        assert!(b2 > 0.0 && n2 == 1, "tp=2 emits one all-reduce op per call");
        p.parallel.tp = 1;
        p.parallel.cp_degree = 8;
        assert_eq!(tp_comm(&p), (0.0, 0), "tp=1 is a no-op");
    }
}
