//! Parallelism layout: which context-parallelism method, with which degrees.

/// The context-parallelism methods compared in the paper's evaluation
/// (Table 3/4 rows, Fig. 1/2/5).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CpMethod {
    /// Native PyTorch ring CP: SDPA attention, no fused/tiled kernels.
    NativePyTorch,
    /// USP Ring Attention with zigzag load balancing.
    Ring,
    /// DeepSpeed-Ulysses (USP implementation) + offloaded AC + tiled
    /// MLP/CE — the paper's ALST-like "Ulysses" baseline.
    Ulysses,
    /// Fully Pipelined Distributed Transformer: sequence chunking + CPU
    /// offload, `pi` chunks.
    Fpdt { pi: u32 },
    /// Untied Ulysses with head-chunk size `u` (U heads per stage);
    /// `gqa_schedule` selects the §4.1 out-of-order head order.
    Upipe { u: u32, gqa_schedule: bool },
    /// USP-Hybrid: Ulysses over `ulysses` GPUs intra-node × Ring over
    /// `ring` groups inter-node.
    UspHybrid { ulysses: u32, ring: u32 },
    /// UPipe extended to the hybrid setup (paper §3.3 "extends to hybrid
    /// schemes such as USP").
    UpipeHybrid { u: u32, ulysses: u32, ring: u32 },
    /// UPipe composed with FPDT's sequence chunking (paper §5.3.2's
    /// anticipated composition: orthogonal chunking dimensions).
    UpipeFpdt { u: u32, pi: u32 },
}

impl CpMethod {
    pub fn label(&self) -> &'static str {
        match self {
            CpMethod::NativePyTorch => "Native PyTorch",
            CpMethod::Ring => "Ring",
            CpMethod::Ulysses => "Ulysses",
            CpMethod::Fpdt { .. } => "FPDT",
            CpMethod::Upipe { .. } => "UPipe",
            CpMethod::UspHybrid { .. } => "USP-Hybrid",
            CpMethod::UpipeHybrid { .. } => "UPipe-Hybrid",
            CpMethod::UpipeFpdt { .. } => "UPipe+FPDT",
        }
    }

    /// Does this method chunk attention headwise (UPipe family)?
    pub fn is_upipe(&self) -> bool {
        matches!(
            self,
            CpMethod::Upipe { .. } | CpMethod::UpipeHybrid { .. } | CpMethod::UpipeFpdt { .. }
        )
    }

    /// Compact parameter string for tables / JSON (empty for the
    /// parameter-free methods).
    pub fn params(&self) -> String {
        match *self {
            CpMethod::NativePyTorch | CpMethod::Ring | CpMethod::Ulysses => String::new(),
            CpMethod::Fpdt { pi } => format!("pi={pi}"),
            CpMethod::Upipe { u, gqa_schedule } => {
                format!("U={u},{}", if gqa_schedule { "gqa" } else { "naive" })
            }
            CpMethod::UspHybrid { ulysses, ring } => format!("uly={ulysses},ring={ring}"),
            CpMethod::UpipeHybrid { u, ulysses, ring } => {
                format!("U={u},uly={ulysses},ring={ring}")
            }
            CpMethod::UpipeFpdt { u, pi } => format!("U={u},pi={pi}"),
        }
    }
}

/// Divisors of `n` in ascending order (sweep-space enumeration helper:
/// head-chunk sizes U are the divisors of H).
pub fn divisors(n: u64) -> Vec<u64> {
    (1..=n).filter(|d| n % d == 0).collect()
}

/// All ordered factorizations `(a, b)` with `a * b == n`, `a` ascending
/// (sweep-space enumeration helper: ulysses×ring splits of the CP degree).
pub fn factor_pairs(n: u64) -> Vec<(u64, u64)> {
    divisors(n).into_iter().map(|a| (a, n / a)).collect()
}

/// Full parallel layout for a run.
#[derive(Debug, Clone, PartialEq)]
pub struct ParallelConfig {
    pub method: CpMethod,
    /// Total context-parallel degree C (= total GPUs here; FSDP shards
    /// params over the same group, as in the paper's setup).
    pub cp_degree: u64,
    /// Full activation checkpointing with CPU offload (paper default).
    pub ac_offload: bool,
    /// Pinned host memory for offloaded activations (paper: true below 5M).
    pub pin_memory: bool,
}

impl ParallelConfig {
    pub fn new(method: CpMethod, cp_degree: u64) -> Self {
        ParallelConfig { method, cp_degree, ac_offload: true, pin_memory: true }
    }

    /// UPipe stage count ν = H / U for a model with `h` query heads.
    pub fn upipe_nu(&self, h: u64) -> Option<u32> {
        match self.method {
            CpMethod::Upipe { u, .. }
            | CpMethod::UpipeHybrid { u, .. }
            | CpMethod::UpipeFpdt { u, .. } => Some((h as u32) / u),
            _ => None,
        }
    }

    /// Validate the layout against a model (paper §3.3: U must be divisible
    /// by C so each device processes an integer number of heads; H must be
    /// divisible by U).
    pub fn validate(&self, h: u64) -> Result<(), String> {
        match self.method {
            CpMethod::Upipe { u, .. } | CpMethod::UpipeFpdt { u, .. } => {
                let (u, c) = (u as u64, self.cp_degree);
                if u % c != 0 {
                    return Err(format!("U={u} must be divisible by C={c}"));
                }
                if h % u != 0 {
                    return Err(format!("H={h} must be divisible by U={u}"));
                }
                Ok(())
            }
            CpMethod::UpipeHybrid { u, ulysses, .. } => {
                let (u, cu) = (u as u64, ulysses as u64);
                if u % cu != 0 {
                    return Err(format!("U={u} must be divisible by ulysses degree {cu}"));
                }
                if h % u != 0 {
                    return Err(format!("H={h} must be divisible by U={u}"));
                }
                Ok(())
            }
            CpMethod::UspHybrid { ulysses, ring } => {
                if (ulysses as u64) * (ring as u64) != self.cp_degree {
                    return Err("ulysses*ring must equal cp_degree".into());
                }
                Ok(())
            }
            _ => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn upipe_validation() {
        let p = ParallelConfig::new(CpMethod::Upipe { u: 8, gqa_schedule: true }, 8);
        assert!(p.validate(32).is_ok());
        assert_eq!(p.upipe_nu(32), Some(4));
        let bad = ParallelConfig::new(CpMethod::Upipe { u: 6, gqa_schedule: true }, 8);
        assert!(bad.validate(32).is_err());
        let bad2 = ParallelConfig::new(CpMethod::Upipe { u: 24, gqa_schedule: true }, 8);
        assert!(bad2.validate(32).is_err());
    }

    #[test]
    fn hybrid_validation() {
        let p = ParallelConfig::new(CpMethod::UspHybrid { ulysses: 8, ring: 2 }, 16);
        assert!(p.validate(32).is_ok());
        let bad = ParallelConfig::new(CpMethod::UspHybrid { ulysses: 8, ring: 3 }, 16);
        assert!(bad.validate(32).is_err());
    }

    #[test]
    fn labels() {
        assert_eq!(CpMethod::Upipe { u: 8, gqa_schedule: true }.label(), "UPipe");
        assert!(CpMethod::UpipeHybrid { u: 8, ulysses: 8, ring: 2 }.is_upipe());
    }

    #[test]
    fn param_strings() {
        assert_eq!(CpMethod::Ulysses.params(), "");
        assert_eq!(CpMethod::Fpdt { pi: 16 }.params(), "pi=16");
        assert_eq!(CpMethod::Upipe { u: 8, gqa_schedule: true }.params(), "U=8,gqa");
        assert_eq!(CpMethod::Upipe { u: 8, gqa_schedule: false }.params(), "U=8,naive");
        assert_eq!(CpMethod::UspHybrid { ulysses: 8, ring: 2 }.params(), "uly=8,ring=2");
        assert_eq!(CpMethod::UpipeFpdt { u: 8, pi: 4 }.params(), "U=8,pi=4");
    }

    #[test]
    fn divisor_enumeration() {
        assert_eq!(divisors(32), vec![1, 2, 4, 8, 16, 32]);
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(factor_pairs(8), vec![(1, 8), (2, 4), (4, 2), (8, 1)]);
        for (a, b) in factor_pairs(64) {
            assert_eq!(a * b, 64);
        }
    }
}
