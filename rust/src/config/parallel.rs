//! Parallelism layout: which context-parallelism method, with which degrees,
//! plus the run-shape knobs every schedule consumes through
//! [`crate::schedule::ScheduleCtx`]: activation-checkpointing mode,
//! micro-batching and the tensor-parallel degree.

/// Activation-checkpointing mode (Fig. 2 compares all three for Ulysses;
/// the planner sweeps them per method).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AcMode {
    /// No checkpointing: every layer's intra-layer activations stay
    /// resident until backward.
    NoAc,
    /// Full AC, checkpoints (layer inputs) kept on GPU.
    AcGpu,
    /// Full AC with CPU offloading (paper default, "AO" in Fig. 2).
    AcOffload,
}

impl AcMode {
    /// Compact label for tables / JSON.
    pub fn label(&self) -> &'static str {
        match self {
            AcMode::NoAc => "noac",
            AcMode::AcGpu => "ac",
            AcMode::AcOffload => "ao",
        }
    }

    /// Parse a CLI spelling (`ao`/`offload`, `ac`/`gpu`, `noac`/`none`).
    pub fn parse(s: &str) -> Option<AcMode> {
        match s {
            "ao" | "offload" => Some(AcMode::AcOffload),
            "ac" | "gpu" => Some(AcMode::AcGpu),
            "noac" | "none" => Some(AcMode::NoAc),
            _ => None,
        }
    }
}

/// The context-parallelism methods compared in the paper's evaluation
/// (Table 3/4 rows, Fig. 1/2/5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CpMethod {
    /// Native PyTorch ring CP: SDPA attention, no fused/tiled kernels.
    NativePyTorch,
    /// USP Ring Attention with zigzag load balancing.
    Ring,
    /// DeepSpeed-Ulysses (USP implementation) + offloaded AC + tiled
    /// MLP/CE — the paper's ALST-like "Ulysses" baseline.
    Ulysses,
    /// Fully Pipelined Distributed Transformer: sequence chunking + CPU
    /// offload, `pi` chunks.
    Fpdt { pi: u32 },
    /// Untied Ulysses with head-chunk size `u` (U heads per stage);
    /// `gqa_schedule` selects the §4.1 out-of-order head order.
    Upipe { u: u32, gqa_schedule: bool },
    /// USP-Hybrid: Ulysses over `ulysses` GPUs intra-node × Ring over
    /// `ring` groups inter-node.
    UspHybrid { ulysses: u32, ring: u32 },
    /// UPipe extended to the hybrid setup (paper §3.3 "extends to hybrid
    /// schemes such as USP").
    UpipeHybrid { u: u32, ulysses: u32, ring: u32 },
    /// UPipe composed with FPDT's sequence chunking (paper §5.3.2's
    /// anticipated composition: orthogonal chunking dimensions).
    UpipeFpdt { u: u32, pi: u32 },
}

impl CpMethod {
    pub fn label(&self) -> &'static str {
        match self {
            CpMethod::NativePyTorch => "Native PyTorch",
            CpMethod::Ring => "Ring",
            CpMethod::Ulysses => "Ulysses",
            CpMethod::Fpdt { .. } => "FPDT",
            CpMethod::Upipe { .. } => "UPipe",
            CpMethod::UspHybrid { .. } => "USP-Hybrid",
            CpMethod::UpipeHybrid { .. } => "UPipe-Hybrid",
            CpMethod::UpipeFpdt { .. } => "UPipe+FPDT",
        }
    }

    /// Does this method chunk attention headwise (UPipe family)?
    pub fn is_upipe(&self) -> bool {
        matches!(
            self,
            CpMethod::Upipe { .. } | CpMethod::UpipeHybrid { .. } | CpMethod::UpipeFpdt { .. }
        )
    }

    /// AC modes a method's schedule can execute. The FPDT family
    /// hard-requires offloaded checkpoints (its sequence chunks round-trip
    /// through host memory); every other method supports all three Fig. 2
    /// variants.
    pub fn supported_ac_modes(&self) -> &'static [AcMode] {
        match self {
            CpMethod::Fpdt { .. } | CpMethod::UpipeFpdt { .. } => &[AcMode::AcOffload],
            _ => &[AcMode::AcOffload, AcMode::AcGpu, AcMode::NoAc],
        }
    }

    /// Compact parameter string for tables / JSON (empty for the
    /// parameter-free methods).
    pub fn params(&self) -> String {
        match *self {
            CpMethod::NativePyTorch | CpMethod::Ring | CpMethod::Ulysses => String::new(),
            CpMethod::Fpdt { pi } => format!("pi={pi}"),
            CpMethod::Upipe { u, gqa_schedule } => {
                format!("U={u},{}", if gqa_schedule { "gqa" } else { "naive" })
            }
            CpMethod::UspHybrid { ulysses, ring } => format!("uly={ulysses},ring={ring}"),
            CpMethod::UpipeHybrid { u, ulysses, ring } => {
                format!("U={u},uly={ulysses},ring={ring}")
            }
            CpMethod::UpipeFpdt { u, pi } => format!("U={u},pi={pi}"),
        }
    }
}

/// Divisors of `n` in ascending order (sweep-space enumeration helper:
/// head-chunk sizes U are the divisors of H).
pub fn divisors(n: u64) -> Vec<u64> {
    (1..=n).filter(|d| n % d == 0).collect()
}

/// All ordered factorizations `(a, b)` with `a * b == n`, `a` ascending
/// (sweep-space enumeration helper: ulysses×ring splits of the CP degree).
pub fn factor_pairs(n: u64) -> Vec<(u64, u64)> {
    divisors(n).into_iter().map(|a| (a, n / a)).collect()
}

/// Full parallel layout for a run.
#[derive(Debug, Clone, PartialEq)]
pub struct ParallelConfig {
    pub method: CpMethod,
    /// Context-parallel degree C (sequence sharding; with tp == 1 this is
    /// the total GPU count, as in the paper's setup — FSDP shards params
    /// over the whole world either way).
    pub cp_degree: u64,
    /// Activation-checkpointing mode (paper default: full AC + offload).
    pub ac_mode: AcMode,
    /// Pinned host memory for offloaded activations (paper: true below 5M).
    pub pin_memory: bool,
    /// Micro-batches per optimizer step (sequential gradient accumulation;
    /// the paper runs 1).
    pub micro_batch: u64,
    /// Tensor-parallel degree sharing the mesh with CP (USP-style TP×CP
    /// mix). Heads are sharded TP-wise, so `tp` must divide H and Hkv.
    pub tp: u64,
}

impl ParallelConfig {
    pub fn new(method: CpMethod, cp_degree: u64) -> Self {
        ParallelConfig {
            method,
            cp_degree,
            ac_mode: AcMode::AcOffload,
            pin_memory: true,
            micro_batch: 1,
            tp: 1,
        }
    }

    /// Total GPUs the layout occupies (CP ranks × TP ranks).
    pub fn world(&self) -> u64 {
        self.cp_degree * self.tp.max(1)
    }

    /// UPipe stage count ν = H / U for a model with `h` query heads.
    pub fn upipe_nu(&self, h: u64) -> Option<u32> {
        match self.method {
            CpMethod::Upipe { u, .. }
            | CpMethod::UpipeHybrid { u, .. }
            | CpMethod::UpipeFpdt { u, .. } => Some((h as u32) / u),
            _ => None,
        }
    }

    /// `validate` plus the constraints that need the full model: TP shards
    /// KV heads too, so `tp` must divide `Hkv` as well as `H`.
    pub fn validate_model(&self, m: &crate::model::ModelDims) -> Result<(), String> {
        if self.tp > 0 && m.n_kv_heads % self.tp != 0 {
            return Err(format!(
                "tp={} must divide Hkv={} (KV heads are sharded TP-wise)",
                self.tp, m.n_kv_heads
            ));
        }
        self.validate(m.n_heads)
    }

    /// Validate the layout against a model (paper §3.3: U must be divisible
    /// by C so each device processes an integer number of heads; H must be
    /// divisible by U), plus the run-shape dims: micro_batch/tp positive,
    /// tp dividing the head count, and an AC mode the method supports.
    /// Prefer [`Self::validate_model`] when the full model is at hand (it
    /// additionally checks the KV-head sharding).
    pub fn validate(&self, h: u64) -> Result<(), String> {
        if self.micro_batch == 0 {
            return Err("micro_batch must be >= 1".into());
        }
        if self.tp == 0 {
            return Err("tp must be >= 1".into());
        }
        if h % self.tp != 0 {
            return Err(format!("tp={} must divide H={h}", self.tp));
        }
        if !self.method.supported_ac_modes().contains(&self.ac_mode) {
            return Err(format!(
                "{} does not support AC mode `{}`",
                self.method.label(),
                self.ac_mode.label()
            ));
        }
        match self.method {
            CpMethod::Upipe { u, .. } | CpMethod::UpipeFpdt { u, .. } => {
                let (u, c) = (u as u64, self.cp_degree);
                if u % c != 0 {
                    return Err(format!("U={u} must be divisible by C={c}"));
                }
                if h % u != 0 {
                    return Err(format!("H={h} must be divisible by U={u}"));
                }
                Ok(())
            }
            CpMethod::UpipeHybrid { u, ulysses, .. } => {
                let (u, cu) = (u as u64, ulysses as u64);
                if u % cu != 0 {
                    return Err(format!("U={u} must be divisible by ulysses degree {cu}"));
                }
                if h % u != 0 {
                    return Err(format!("H={h} must be divisible by U={u}"));
                }
                Ok(())
            }
            CpMethod::UspHybrid { ulysses, ring } => {
                if (ulysses as u64) * (ring as u64) != self.cp_degree {
                    return Err("ulysses*ring must equal cp_degree".into());
                }
                Ok(())
            }
            _ => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn upipe_validation() {
        let p = ParallelConfig::new(CpMethod::Upipe { u: 8, gqa_schedule: true }, 8);
        assert!(p.validate(32).is_ok());
        assert_eq!(p.upipe_nu(32), Some(4));
        let bad = ParallelConfig::new(CpMethod::Upipe { u: 6, gqa_schedule: true }, 8);
        assert!(bad.validate(32).is_err());
        let bad2 = ParallelConfig::new(CpMethod::Upipe { u: 24, gqa_schedule: true }, 8);
        assert!(bad2.validate(32).is_err());
    }

    #[test]
    fn hybrid_validation() {
        let p = ParallelConfig::new(CpMethod::UspHybrid { ulysses: 8, ring: 2 }, 16);
        assert!(p.validate(32).is_ok());
        let bad = ParallelConfig::new(CpMethod::UspHybrid { ulysses: 8, ring: 3 }, 16);
        assert!(bad.validate(32).is_err());
    }

    #[test]
    fn dims_validation() {
        let mut p = ParallelConfig::new(CpMethod::Ulysses, 8);
        assert!(p.validate(32).is_ok());
        p.micro_batch = 0;
        assert!(p.validate(32).is_err());
        p.micro_batch = 2;
        p.tp = 0;
        assert!(p.validate(32).is_err());
        p.tp = 2;
        assert!(p.validate(32).is_ok());
        assert_eq!(p.world(), 16);
        p.tp = 3; // does not divide H=32
        assert!(p.validate(32).is_err());
    }

    #[test]
    fn model_validation_checks_kv_head_sharding() {
        // llama3-8b: H=32, Hkv=8 — tp=16 divides H but not Hkv.
        let m = crate::model::ModelDims::llama3_8b();
        let mut p = ParallelConfig::new(CpMethod::Ulysses, 2);
        p.tp = 16;
        assert!(p.validate(m.n_heads).is_ok(), "H-only check passes");
        assert!(p.validate_model(&m).is_err(), "Hkv check must reject");
        p.tp = 8;
        assert!(p.validate_model(&m).is_ok());
    }

    #[test]
    fn ac_mode_support() {
        let mut p = ParallelConfig::new(CpMethod::Fpdt { pi: 16 }, 8);
        assert!(p.validate(32).is_ok()); // default AcOffload
        p.ac_mode = AcMode::AcGpu;
        assert!(p.validate(32).is_err(), "FPDT requires offloaded AC");
        let mut u = ParallelConfig::new(CpMethod::Ulysses, 8);
        u.ac_mode = AcMode::NoAc;
        assert!(u.validate(32).is_ok());
        assert_eq!(AcMode::parse("ao"), Some(AcMode::AcOffload));
        assert_eq!(AcMode::parse("gpu"), Some(AcMode::AcGpu));
        assert_eq!(AcMode::parse("noac"), Some(AcMode::NoAc));
        assert_eq!(AcMode::parse("bogus"), None);
        assert_eq!(AcMode::AcOffload.label(), "ao");
    }

    #[test]
    fn labels() {
        assert_eq!(CpMethod::Upipe { u: 8, gqa_schedule: true }.label(), "UPipe");
        assert!(CpMethod::UpipeHybrid { u: 8, ulysses: 8, ring: 2 }.is_upipe());
    }

    #[test]
    fn param_strings() {
        assert_eq!(CpMethod::Ulysses.params(), "");
        assert_eq!(CpMethod::Fpdt { pi: 16 }.params(), "pi=16");
        assert_eq!(CpMethod::Upipe { u: 8, gqa_schedule: true }.params(), "U=8,gqa");
        assert_eq!(CpMethod::Upipe { u: 8, gqa_schedule: false }.params(), "U=8,naive");
        assert_eq!(CpMethod::UspHybrid { ulysses: 8, ring: 2 }.params(), "uly=8,ring=2");
        assert_eq!(CpMethod::UpipeFpdt { u: 8, pi: 4 }.params(), "U=8,pi=4");
    }

    #[test]
    fn divisor_enumeration() {
        assert_eq!(divisors(32), vec![1, 2, 4, 8, 16, 32]);
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(factor_pairs(8), vec![(1, 8), (2, 4), (4, 2), (8, 1)]);
        for (a, b) in factor_pairs(64) {
            assert_eq!(a * b, 64);
        }
    }
}
