//! Configuration: cluster hardware, parallelism layout, run presets.

pub mod cluster;
pub mod parallel;
pub mod presets;

pub use cluster::ClusterConfig;
pub use parallel::{AcMode, CpMethod, ParallelConfig};
