//! Configuration: cluster hardware, heterogeneous fleets, parallelism
//! layout, run presets.

pub mod cluster;
pub mod fleet;
pub mod parallel;
pub mod presets;

pub use cluster::ClusterConfig;
pub use fleet::{DeviceSpec, FleetPool, FleetSpec};
pub use parallel::{AcMode, CpMethod, ParallelConfig};
