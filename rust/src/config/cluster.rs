//! Cluster hardware description (the paper's §5.1 testbed, simulated).

/// Hardware description of the training cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub name: &'static str,
    pub nodes: u64,
    pub gpus_per_node: u64,
    /// HBM capacity per GPU in bytes (H100: 80 GiB, ~78 GiB usable after
    /// CUDA context/driver reservations).
    pub hbm_bytes: f64,
    /// Usable fraction of HBM before the allocator OOMs (expandable
    /// segments still reserve some headroom).
    pub hbm_usable_frac: f64,
    /// Intra-node NVLink bandwidth per GPU, bytes/s (4th-gen: 900 GB/s
    /// bidirectional).
    pub nvlink_bps: f64,
    /// Inter-node InfiniBand bandwidth per GPU pair, bytes/s (400 Gb/s).
    pub ib_bps: f64,
    /// CPU offload (PCIe gen5 x16) bandwidth, bytes/s, pinned memory.
    pub pcie_bps: f64,
    /// Host RAM per node, bytes (1.9 TiB in the paper's nodes).
    pub host_ram_bytes: f64,
    /// Per-GPU compute throughput relative to an H100 (the calibration's
    /// anchor device): 1.0 for H100/H200 (same GH100 die), ~2.25 for
    /// B200. Scales the calibration's kernel rates via
    /// `Calibration::scaled_for`.
    pub compute_scale: f64,
}

impl ClusterConfig {
    /// One 8×H100 NVLink node (paper's single-node testbed).
    pub fn h100_node() -> Self {
        ClusterConfig {
            name: "8xH100",
            nodes: 1,
            gpus_per_node: 8,
            hbm_bytes: 80.0 * 1024f64.powi(3),
            hbm_usable_frac: 0.975,
            nvlink_bps: 900.0e9,
            ib_bps: 50.0e9, // 400 Gb/s
            pcie_bps: 55.0e9,
            host_ram_bytes: 1.9 * 1024f64.powi(4),
            compute_scale: 1.0,
        }
    }

    /// Two 8×H100 nodes over 400 Gb/s InfiniBand (paper's multi-node
    /// testbed).
    pub fn h100_2nodes() -> Self {
        ClusterConfig { name: "16xH100", nodes: 2, ..Self::h100_node() }
    }

    /// `n` H100 GPUs on one node (e.g. the Fig. 6 ablation's 4×H100).
    /// Validated like [`Self::h100_cluster`]: an NVLink node holds 1–8
    /// GPUs, so `n = 0` and `n > 8` are errors instead of silently
    /// modeling an impossible single-node machine.
    pub fn h100_gpus(n: u64) -> Result<Self, String> {
        if n == 0 {
            return Err("cluster needs at least one GPU".into());
        }
        if n > 8 {
            return Err(format!("one NVLink node holds at most 8 GPUs (got {n})"));
        }
        Ok(ClusterConfig {
            name: "nxH100",
            gpus_per_node: n,
            ..Self::h100_node()
        })
    }

    /// A cluster of `total` H100s: up to 8 on one NVLink node, beyond that
    /// whole 8-GPU nodes over InfiniBand — the planner's generalization of
    /// the fixed paper testbeds.
    pub fn h100_cluster(total: u64) -> Result<Self, String> {
        if total == 0 {
            return Err("cluster needs at least one GPU".into());
        }
        if total <= 8 {
            return if total == 8 { Ok(Self::h100_node()) } else { Self::h100_gpus(total) };
        }
        if total % 8 != 0 {
            return Err(format!("multi-node clusters are whole 8-GPU nodes (got {total} GPUs)"));
        }
        // &'static str names can't be formatted per-size; 16 keeps its
        // paper-testbed label, larger clusters share the generic one (the
        // planner reports always print total_gpus() alongside).
        let name = if total == 16 { "16xH100" } else { "NxH100" };
        Ok(ClusterConfig { name, nodes: total / 8, ..Self::h100_node() })
    }

    pub fn total_gpus(&self) -> u64 {
        self.nodes * self.gpus_per_node
    }

    /// OOM threshold per GPU in bytes.
    pub fn hbm_limit(&self) -> f64 {
        self.hbm_bytes * self.hbm_usable_frac
    }

    /// 64-bit fingerprint of the *per-rank* hardware: HBM, host RAM,
    /// link generations, and compute scale — deliberately excluding the
    /// shape (`nodes`/`gpus_per_node`, which cache keys carry separately)
    /// and the display name. Two fleet pools with identical devices hash
    /// equal here, which is what lets `FamilyKey`/`TimeKey` share fitted
    /// symbolic models across cluster shapes; any hardware difference
    /// (an H200's HBM, a B200's NVLink) changes the keys and keeps
    /// memo tiers from aliasing.
    pub fn hardware_fingerprint(&self) -> u64 {
        // Exhaustive destructure: adding a hardware field without
        // extending the hash is a compile error.
        let ClusterConfig {
            name: _,
            nodes: _,
            gpus_per_node: _,
            hbm_bytes,
            hbm_usable_frac,
            nvlink_bps,
            ib_bps,
            pcie_bps,
            host_ram_bytes,
            compute_scale,
        } = self;
        let fields = [
            hbm_bytes,
            hbm_usable_frac,
            nvlink_bps,
            ib_bps,
            pcie_bps,
            host_ram_bytes,
            compute_scale,
        ];
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for f in fields {
            for b in f.to_bits().to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fmt::GIB;

    #[test]
    fn presets() {
        let n1 = ClusterConfig::h100_node();
        assert_eq!(n1.total_gpus(), 8);
        assert!((n1.hbm_bytes / GIB - 80.0).abs() < 1e-9);
        let n2 = ClusterConfig::h100_2nodes();
        assert_eq!(n2.total_gpus(), 16);
        assert!(n2.hbm_limit() < n2.hbm_bytes);
    }

    #[test]
    fn ablation_cluster() {
        assert_eq!(ClusterConfig::h100_gpus(4).unwrap().total_gpus(), 4);
        // The whole-node rule: no zero-GPU nodes, no >8-GPU NVLink nodes
        // (16 GPUs on one node would silently model NVLink for what is an
        // IB hop on real hardware).
        assert!(ClusterConfig::h100_gpus(0).is_err());
        assert!(ClusterConfig::h100_gpus(16).is_err());
        assert_eq!(ClusterConfig::h100_gpus(8).unwrap().gpus_per_node, 8);
    }

    #[test]
    fn hardware_fingerprint_ignores_shape_but_not_hardware() {
        let one = ClusterConfig::h100_node();
        let two = ClusterConfig::h100_2nodes();
        assert_eq!(one.hardware_fingerprint(), two.hardware_fingerprint());
        let four = ClusterConfig::h100_gpus(4).unwrap();
        assert_eq!(one.hardware_fingerprint(), four.hardware_fingerprint());
        let mut h200ish = ClusterConfig::h100_node();
        h200ish.hbm_bytes = 141.0e9;
        assert_ne!(one.hardware_fingerprint(), h200ish.hardware_fingerprint());
        let mut faster = ClusterConfig::h100_node();
        faster.compute_scale = 2.25;
        assert_ne!(one.hardware_fingerprint(), faster.hardware_fingerprint());
    }

    #[test]
    fn cluster_by_total_gpus() {
        assert_eq!(ClusterConfig::h100_cluster(8).unwrap(), ClusterConfig::h100_node());
        assert_eq!(ClusterConfig::h100_cluster(16).unwrap(), ClusterConfig::h100_2nodes());
        let c4 = ClusterConfig::h100_cluster(4).unwrap();
        assert_eq!((c4.nodes, c4.gpus_per_node), (1, 4));
        let c32 = ClusterConfig::h100_cluster(32).unwrap();
        assert_eq!((c32.nodes, c32.total_gpus()), (4, 32));
        assert!(ClusterConfig::h100_cluster(0).is_err());
        assert!(ClusterConfig::h100_cluster(12).is_err());
    }
}
