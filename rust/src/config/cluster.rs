//! Cluster hardware description (the paper's §5.1 testbed, simulated).

/// Hardware description of the training cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub name: &'static str,
    pub nodes: u64,
    pub gpus_per_node: u64,
    /// HBM capacity per GPU in bytes (H100: 80 GiB, ~78 GiB usable after
    /// CUDA context/driver reservations).
    pub hbm_bytes: f64,
    /// Usable fraction of HBM before the allocator OOMs (expandable
    /// segments still reserve some headroom).
    pub hbm_usable_frac: f64,
    /// Intra-node NVLink bandwidth per GPU, bytes/s (4th-gen: 900 GB/s
    /// bidirectional).
    pub nvlink_bps: f64,
    /// Inter-node InfiniBand bandwidth per GPU pair, bytes/s (400 Gb/s).
    pub ib_bps: f64,
    /// CPU offload (PCIe gen5 x16) bandwidth, bytes/s, pinned memory.
    pub pcie_bps: f64,
    /// Host RAM per node, bytes (1.9 TiB in the paper's nodes).
    pub host_ram_bytes: f64,
}

impl ClusterConfig {
    /// One 8×H100 NVLink node (paper's single-node testbed).
    pub fn h100_node() -> Self {
        ClusterConfig {
            name: "8xH100",
            nodes: 1,
            gpus_per_node: 8,
            hbm_bytes: 80.0 * 1024f64.powi(3),
            hbm_usable_frac: 0.975,
            nvlink_bps: 900.0e9,
            ib_bps: 50.0e9, // 400 Gb/s
            pcie_bps: 55.0e9,
            host_ram_bytes: 1.9 * 1024f64.powi(4),
        }
    }

    /// Two 8×H100 nodes over 400 Gb/s InfiniBand (paper's multi-node
    /// testbed).
    pub fn h100_2nodes() -> Self {
        ClusterConfig { name: "16xH100", nodes: 2, ..Self::h100_node() }
    }

    /// `n` H100 GPUs on one node (e.g. the Fig. 6 ablation's 4×H100).
    pub fn h100_gpus(n: u64) -> Self {
        ClusterConfig {
            name: "nxH100",
            gpus_per_node: n,
            ..Self::h100_node()
        }
    }

    /// A cluster of `total` H100s: up to 8 on one NVLink node, beyond that
    /// whole 8-GPU nodes over InfiniBand — the planner's generalization of
    /// the fixed paper testbeds.
    pub fn h100_cluster(total: u64) -> Result<Self, String> {
        if total == 0 {
            return Err("cluster needs at least one GPU".into());
        }
        if total <= 8 {
            return Ok(if total == 8 { Self::h100_node() } else { Self::h100_gpus(total) });
        }
        if total % 8 != 0 {
            return Err(format!("multi-node clusters are whole 8-GPU nodes (got {total} GPUs)"));
        }
        // &'static str names can't be formatted per-size; 16 keeps its
        // paper-testbed label, larger clusters share the generic one (the
        // planner reports always print total_gpus() alongside).
        let name = if total == 16 { "16xH100" } else { "NxH100" };
        Ok(ClusterConfig { name, nodes: total / 8, ..Self::h100_node() })
    }

    pub fn total_gpus(&self) -> u64 {
        self.nodes * self.gpus_per_node
    }

    /// OOM threshold per GPU in bytes.
    pub fn hbm_limit(&self) -> f64 {
        self.hbm_bytes * self.hbm_usable_frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fmt::GIB;

    #[test]
    fn presets() {
        let n1 = ClusterConfig::h100_node();
        assert_eq!(n1.total_gpus(), 8);
        assert!((n1.hbm_bytes / GIB - 80.0).abs() < 1e-9);
        let n2 = ClusterConfig::h100_2nodes();
        assert_eq!(n2.total_gpus(), 16);
        assert!(n2.hbm_limit() < n2.hbm_bytes);
    }

    #[test]
    fn ablation_cluster() {
        assert_eq!(ClusterConfig::h100_gpus(4).total_gpus(), 4);
    }

    #[test]
    fn cluster_by_total_gpus() {
        assert_eq!(ClusterConfig::h100_cluster(8).unwrap(), ClusterConfig::h100_node());
        assert_eq!(ClusterConfig::h100_cluster(16).unwrap(), ClusterConfig::h100_2nodes());
        let c4 = ClusterConfig::h100_cluster(4).unwrap();
        assert_eq!((c4.nodes, c4.gpus_per_node), (1, 4));
        let c32 = ClusterConfig::h100_cluster(32).unwrap();
        assert_eq!((c32.nodes, c32.total_gpus()), (4, 32));
        assert!(ClusterConfig::h100_cluster(0).is_err());
        assert!(ClusterConfig::h100_cluster(12).is_err());
    }
}
