//! Heterogeneous fleet description: named device pools (H100/H200/B200
//! generations, or custom hardware) that the placement sweep turns into
//! candidate cluster shapes.
//!
//! A [`FleetSpec`] is what `repro place --fleet fleet.json` and the
//! `/v1/placement` endpoint parse: a list of [`FleetPool`]s, each a
//! homogeneous island of `nodes` identical machines built from one
//! [`DeviceSpec`]. Placement evaluates a training job against every
//! viable shape *within* a pool (a job never straddles pools — mixed-
//! generation collectives run at the slowest member's rate and no
//! scheduler places that way on purpose), so heterogeneity lives
//! *across* candidates, exactly where the planner's dominance pruning
//! and hardware-fingerprint model sharing can exploit it.
//!
//! JSON schema (strict — unknown fields are errors, like the wire
//! protocol):
//!
//! ```json
//! {
//!   "pools": [
//!     {"name": "east-h100", "device": "h100", "nodes": 4},
//!     {"name": "new-h200", "device": "h200", "nodes": 2},
//!     {"name": "lab", "device": {"base": "b200", "hbm_gib": 192,
//!       "nvlink_gbps": 1800, "ib_gbps": 100, "pcie_gbps": 55,
//!       "host_ram_gib": 2560, "gpus_per_node": 8,
//!       "compute_scale": 2.25, "name": "B200-lab"}, "nodes": 1}
//!   ]
//! }
//! ```
//!
//! Memory fields are GiB, link fields GB/s (1e9 bytes/s) — the units the
//! vendor datasheets quote.

use crate::config::ClusterConfig;
use crate::util::fmt::GIB;
use crate::util::json::Json;

/// One device generation's per-rank hardware: everything
/// [`ClusterConfig`] carries except the shape.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    /// Display name ("H100", "H200", "B200", or a custom label).
    pub name: String,
    pub gpus_per_node: u64,
    pub hbm_bytes: f64,
    pub hbm_usable_frac: f64,
    pub nvlink_bps: f64,
    pub ib_bps: f64,
    pub pcie_bps: f64,
    pub host_ram_bytes: f64,
    /// Per-GPU compute relative to H100 (see
    /// [`ClusterConfig::compute_scale`]).
    pub compute_scale: f64,
}

impl DeviceSpec {
    /// The paper's testbed device: 8×H100-80GB NVLink nodes, 400 Gb/s IB
    /// — bit-identical hardware to [`ClusterConfig::h100_node`], so H100
    /// fleet pools alias the baseline planner's cache entries.
    pub fn h100() -> Self {
        Self::from_cluster("H100", &ClusterConfig::h100_node())
    }

    /// H200: the same GH100 die with 141 GiB HBM3e and roomier hosts —
    /// strictly ≥ H100 in every dimension, which is what makes the
    /// two-pool example fleet exercise dominance pruning.
    pub fn h200() -> Self {
        DeviceSpec {
            name: "H200".to_string(),
            hbm_bytes: 141.0 * GIB,
            host_ram_bytes: 2048.0 * GIB,
            ..Self::h100()
        }
    }

    /// B200: 192 GiB HBM3e, 5th-gen NVLink (1.8 TB/s), 800 Gb/s IB,
    /// ~2.25× H100 compute.
    pub fn b200() -> Self {
        DeviceSpec {
            name: "B200".to_string(),
            hbm_bytes: 192.0 * GIB,
            nvlink_bps: 1800.0e9,
            ib_bps: 100.0e9,
            host_ram_bytes: 2560.0 * GIB,
            compute_scale: 2.25,
            ..Self::h100()
        }
    }

    /// Preset lookup by case-insensitive name.
    pub fn by_name(name: &str) -> Option<DeviceSpec> {
        match name.to_ascii_lowercase().as_str() {
            "h100" => Some(Self::h100()),
            "h200" => Some(Self::h200()),
            "b200" => Some(Self::b200()),
            _ => None,
        }
    }

    /// The per-rank hardware of an existing cluster, under a new label.
    pub fn from_cluster(name: &str, c: &ClusterConfig) -> Self {
        DeviceSpec {
            name: name.to_string(),
            gpus_per_node: c.gpus_per_node,
            hbm_bytes: c.hbm_bytes,
            hbm_usable_frac: c.hbm_usable_frac,
            nvlink_bps: c.nvlink_bps,
            ib_bps: c.ib_bps,
            pcie_bps: c.pcie_bps,
            host_ram_bytes: c.host_ram_bytes,
            compute_scale: c.compute_scale,
        }
    }

    /// A cluster of `nodes` machines of this device (`gpus` per node —
    /// callers pass `self.gpus_per_node` except for sub-node single-node
    /// shapes). The `&'static str` cluster name is the device *kind*
    /// label; pool names ride alongside in placement results.
    pub fn cluster(&self, nodes: u64, gpus: u64) -> ClusterConfig {
        ClusterConfig {
            name: self.kind_label(),
            nodes,
            gpus_per_node: gpus,
            hbm_bytes: self.hbm_bytes,
            hbm_usable_frac: self.hbm_usable_frac,
            nvlink_bps: self.nvlink_bps,
            ib_bps: self.ib_bps,
            pcie_bps: self.pcie_bps,
            host_ram_bytes: self.host_ram_bytes,
            compute_scale: self.compute_scale,
        }
    }

    fn kind_label(&self) -> &'static str {
        match self.name.as_str() {
            "H100" => "H100",
            "H200" => "H200",
            "B200" => "B200",
            _ => "custom",
        }
    }

    /// Shape-free hardware fingerprint (see
    /// [`ClusterConfig::hardware_fingerprint`]).
    pub fn hardware_fingerprint(&self) -> u64 {
        self.cluster(1, self.gpus_per_node).hardware_fingerprint()
    }

    fn validate(&self) -> Result<(), String> {
        if self.gpus_per_node == 0 || self.gpus_per_node > 8 {
            return Err(format!(
                "device `{}`: gpus_per_node must be 1..=8 (one NVLink node), got {}",
                self.name, self.gpus_per_node
            ));
        }
        let positive = [
            ("hbm_gib", self.hbm_bytes),
            ("nvlink_gbps", self.nvlink_bps),
            ("ib_gbps", self.ib_bps),
            ("pcie_gbps", self.pcie_bps),
            ("host_ram_gib", self.host_ram_bytes),
            ("compute_scale", self.compute_scale),
        ];
        for (what, v) in positive {
            if !(v > 0.0 && v.is_finite()) {
                return Err(format!("device `{}`: {what} must be a positive number", self.name));
            }
        }
        if !(self.hbm_usable_frac > 0.0 && self.hbm_usable_frac <= 1.0) {
            return Err(format!("device `{}`: hbm_usable_frac must be in (0, 1]", self.name));
        }
        Ok(())
    }
}

/// Device fields a fleet JSON may set (besides `base`); unknown fields
/// are errors.
const DEVICE_FIELDS: [&str; 9] = [
    "base",
    "name",
    "gpus_per_node",
    "hbm_gib",
    "hbm_usable_frac",
    "nvlink_gbps",
    "ib_gbps",
    "pcie_gbps",
    "host_ram_gib",
];

fn device_from_json(v: &Json) -> Result<DeviceSpec, String> {
    match v {
        Json::Str(name) => DeviceSpec::by_name(name)
            .ok_or_else(|| format!("unknown device preset `{name}` (h100|h200|b200)")),
        Json::Obj(pairs) => {
            for (k, _) in pairs {
                if !DEVICE_FIELDS.contains(&k.as_str()) && k != "compute_scale" {
                    return Err(format!("unknown device field `{k}`"));
                }
            }
            let mut d = match v.get("base") {
                None => DeviceSpec::h100(),
                Some(b) => {
                    let name = b.as_str().ok_or("device `base` must be a preset name")?;
                    DeviceSpec::by_name(name)
                        .ok_or_else(|| format!("unknown device preset `{name}` (h100|h200|b200)"))?
                }
            };
            if let Some(n) = v.get("name") {
                d.name = n.as_str().ok_or("device `name` must be a string")?.to_string();
            }
            if let Some(g) = v.get("gpus_per_node") {
                d.gpus_per_node =
                    g.as_u64().ok_or("device `gpus_per_node` must be a whole number")?;
            }
            let mut num = |key: &str, dst: &mut f64, scale: f64| -> Result<(), String> {
                if let Some(x) = v.get(key) {
                    *dst = x.as_f64().ok_or_else(|| format!("device `{key}` must be a number"))?
                        * scale;
                }
                Ok(())
            };
            num("hbm_gib", &mut d.hbm_bytes, GIB)?;
            num("hbm_usable_frac", &mut d.hbm_usable_frac, 1.0)?;
            num("nvlink_gbps", &mut d.nvlink_bps, 1e9)?;
            num("ib_gbps", &mut d.ib_bps, 1e9)?;
            num("pcie_gbps", &mut d.pcie_bps, 1e9)?;
            num("host_ram_gib", &mut d.host_ram_bytes, GIB)?;
            num("compute_scale", &mut d.compute_scale, 1.0)?;
            Ok(d)
        }
        _ => Err("`device` must be a preset name or a device object".to_string()),
    }
}

/// One homogeneous pool of a fleet: `nodes` identical machines.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetPool {
    pub name: String,
    pub device: DeviceSpec,
    pub nodes: u64,
}

/// A heterogeneous fleet: the placement sweep's input.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    pub pools: Vec<FleetPool>,
}

impl FleetSpec {
    /// Parse and validate a fleet document (see the module docs for the
    /// schema). Strict like the wire protocol: unknown fields error.
    pub fn parse(text: &str, source: &str) -> Result<FleetSpec, String> {
        let j = Json::parse(text).map_err(|e| format!("{source}: {e}"))?;
        Self::from_json(&j).map_err(|e| format!("{source}: {e}"))
    }

    pub fn from_json(j: &Json) -> Result<FleetSpec, String> {
        let Json::Obj(top) = j else {
            return Err("fleet must be a JSON object".to_string());
        };
        for (k, _) in top {
            if k != "pools" {
                return Err(format!("unknown fleet field `{k}`"));
            }
        }
        let pools_j = j
            .get("pools")
            .and_then(Json::as_arr)
            .ok_or("fleet needs a `pools` array")?;
        if pools_j.is_empty() {
            return Err("fleet needs at least one pool".to_string());
        }
        let mut pools = Vec::with_capacity(pools_j.len());
        for (i, p) in pools_j.iter().enumerate() {
            let Json::Obj(pairs) = p else {
                return Err(format!("pool {i} must be an object"));
            };
            for (k, _) in pairs {
                if !["name", "device", "nodes"].contains(&k.as_str()) {
                    return Err(format!("pool {i}: unknown field `{k}`"));
                }
            }
            let name = match p.get("name") {
                None => format!("pool{i}"),
                Some(n) => n
                    .as_str()
                    .ok_or_else(|| format!("pool {i}: `name` must be a string"))?
                    .to_string(),
            };
            let device = device_from_json(
                p.get("device").ok_or_else(|| format!("pool `{name}`: missing `device`"))?,
            )
            .map_err(|e| format!("pool `{name}`: {e}"))?;
            device.validate().map_err(|e| format!("pool `{name}`: {e}"))?;
            let nodes = p
                .get("nodes")
                .ok_or_else(|| format!("pool `{name}`: missing `nodes`"))?
                .as_u64()
                .ok_or_else(|| format!("pool `{name}`: `nodes` must be a whole number"))?;
            if nodes == 0 {
                return Err(format!("pool `{name}`: needs at least one node"));
            }
            pools.push(FleetPool { name, device, nodes });
        }
        let mut names: Vec<&str> = pools.iter().map(|p| p.name.as_str()).collect();
        names.sort_unstable();
        if names.windows(2).any(|w| w[0] == w[1]) {
            return Err("pool names must be unique".to_string());
        }
        Ok(FleetSpec { pools })
    }

    /// Canonical echo of the fleet (fixed field order, bytes-per-field
    /// units normalized back to the schema's GiB / GB/s) — part of the
    /// `/v1/placement` canonical request, so equal fleets render equal
    /// bytes and key the service's placement memo.
    pub fn canonical(&self) -> Json {
        Json::obj(vec![(
            "pools",
            Json::Arr(
                self.pools
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("name", Json::string(&p.name)),
                            (
                                "device",
                                Json::obj(vec![
                                    ("name", Json::string(&p.device.name)),
                                    ("gpus_per_node", Json::int(p.device.gpus_per_node)),
                                    ("hbm_gib", Json::Num(p.device.hbm_bytes / GIB)),
                                    ("hbm_usable_frac", Json::Num(p.device.hbm_usable_frac)),
                                    ("nvlink_gbps", Json::Num(p.device.nvlink_bps / 1e9)),
                                    ("ib_gbps", Json::Num(p.device.ib_bps / 1e9)),
                                    ("pcie_gbps", Json::Num(p.device.pcie_bps / 1e9)),
                                    ("host_ram_gib", Json::Num(p.device.host_ram_bytes / GIB)),
                                    ("compute_scale", Json::Num(p.device.compute_scale)),
                                ]),
                            ),
                            ("nodes", Json::int(p.nodes)),
                        ])
                    })
                    .collect(),
            ),
        )])
    }

    pub fn total_gpus(&self) -> u64 {
        self.pools.iter().map(|p| p.nodes * p.device.gpus_per_node).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_and_dominance_ordering() {
        let h100 = DeviceSpec::h100();
        let h200 = DeviceSpec::h200();
        let b200 = DeviceSpec::b200();
        // H100 hardware is bit-identical to the paper testbed: the
        // cross-shape reuse story depends on it.
        assert_eq!(
            h100.hardware_fingerprint(),
            ClusterConfig::h100_node().hardware_fingerprint()
        );
        // H200 ≥ H100 in every dimension (the dominance-pruning example);
        // B200 ≥ H200.
        assert!(h200.hbm_bytes > h100.hbm_bytes);
        assert!(h200.host_ram_bytes > h100.host_ram_bytes);
        assert_eq!(h200.nvlink_bps, h100.nvlink_bps);
        assert!(b200.hbm_bytes > h200.hbm_bytes);
        assert!(b200.nvlink_bps > h200.nvlink_bps);
        assert!(b200.compute_scale > 1.0);
        assert_ne!(h100.hardware_fingerprint(), h200.hardware_fingerprint());
        assert!(DeviceSpec::by_name("H200").is_some(), "case-insensitive");
        assert!(DeviceSpec::by_name("mi300").is_none());
    }

    #[test]
    fn parse_pools_with_presets_and_overrides() {
        let f = FleetSpec::parse(
            r#"{"pools": [
                {"name": "east", "device": "h100", "nodes": 4},
                {"name": "lab", "device": {"base": "h200", "host_ram_gib": 4096,
                    "name": "H200-big"}, "nodes": 1}
            ]}"#,
            "test.json",
        )
        .unwrap();
        assert_eq!(f.pools.len(), 2);
        assert_eq!(f.pools[0].device.name, "H100");
        assert_eq!(f.pools[0].nodes, 4);
        assert_eq!(f.pools[1].device.name, "H200-big");
        assert_eq!(f.pools[1].device.host_ram_bytes, 4096.0 * GIB);
        assert_eq!(f.pools[1].device.hbm_bytes, DeviceSpec::h200().hbm_bytes, "base kept");
        assert_eq!(f.total_gpus(), 40);
        // Canonical echo is stable bytes and round-trips our parser.
        let c = f.canonical().render();
        assert_eq!(Json::parse(&c).unwrap().render(), c);
    }

    #[test]
    fn parse_rejects_bad_fleets() {
        let bad = [
            (r#"{"pools": []}"#, "at least one pool"),
            (r#"{"pools": [{"name":"a","device":"h100"}]}"#, "missing `nodes`"),
            (r#"{"pools": [{"name":"a","device":"mi300","nodes":1}]}"#, "unknown device preset"),
            (
                r#"{"pools": [{"name":"a","device":"h100","nodes":1},
                    {"name":"a","device":"h200","nodes":1}]}"#,
                "unique",
            ),
            (r#"{"pools": [{"name":"a","device":{"hbm_gib":-1},"nodes":1}]}"#, "positive"),
            (
                r#"{"pools": [{"name":"a","device":{"gpus_per_node":16},"nodes":1}]}"#,
                "1..=8",
            ),
            (r#"{"pools": [{"name":"a","device":"h100","nodes":1,"x":1}]}"#, "unknown field"),
            (r#"{"fleet": 1}"#, "unknown fleet field"),
        ];
        for (text, want) in bad {
            let err = FleetSpec::parse(text, "t").unwrap_err();
            assert!(err.contains(want), "`{text}` -> {err}");
        }
    }

    #[test]
    fn device_cluster_carries_hardware() {
        let c = DeviceSpec::b200().cluster(2, 8);
        assert_eq!(c.name, "B200");
        assert_eq!(c.total_gpus(), 16);
        assert_eq!(c.nvlink_bps, 1800.0e9);
        assert_eq!(c.compute_scale, 2.25);
        // Shape never enters the hardware fingerprint.
        assert_eq!(
            c.hardware_fingerprint(),
            DeviceSpec::b200().cluster(1, 4).hardware_fingerprint()
        );
    }
}
