//! Run presets reproducing the paper's experimental setups (§5.1–5.2).

use crate::config::{ClusterConfig, CpMethod, ParallelConfig};
use crate::model::ModelDims;

/// One experiment cell: model × cluster × parallel layout × sequence length.
#[derive(Debug, Clone)]
pub struct RunPreset {
    pub model: ModelDims,
    pub cluster: ClusterConfig,
    pub parallel: ParallelConfig,
    pub seq_len: u64,
}

impl RunPreset {
    /// Tokens processed per optimizer step (micro-batches × sequence).
    pub fn step_tokens(&self) -> u64 {
        self.parallel.micro_batch.max(1) * self.seq_len
    }
}

/// Sequence lengths of Table 3/4 columns.
pub fn table34_seq_lens() -> Vec<u64> {
    ["128K", "256K", "512K", "1M", "2M", "3M", "4M", "5M"]
        .iter()
        .map(|s| crate::util::fmt::parse_tokens(s).unwrap())
        .collect()
}

/// Fig. 5 sequence lengths (512K–8M on 16×H100).
pub fn fig5_seq_lens() -> Vec<u64> {
    ["512K", "1M", "2M", "3M", "4M", "5M", "6M", "7M", "8M"]
        .iter()
        .map(|s| crate::util::fmt::parse_tokens(s).unwrap())
        .collect()
}

/// The five single-node Llama3-8B methods of Table 3/4 (top half), in the
/// paper's row order. C = 8, U = C for UPipe (max memory efficiency, §5).
pub fn llama_single_node_methods() -> Vec<CpMethod> {
    vec![
        CpMethod::NativePyTorch,
        CpMethod::Ring,
        CpMethod::Ulysses,
        CpMethod::Fpdt { pi: 16 },
        CpMethod::Upipe { u: 8, gqa_schedule: true },
    ]
}

/// The Qwen3-32B 16×H100 methods of Table 3/4 (bottom half). Ulysses-family
/// methods restrict the Ulysses degree to 8 (intra-node) and use ring
/// across nodes (§5.1 "we always restrict Ulysses context parallelism
/// degree to 8 and use rest for ring"); FPDT uses 16-ulysses-1-ring.
pub fn qwen_two_node_methods() -> Vec<CpMethod> {
    vec![
        CpMethod::NativePyTorch,
        CpMethod::Ring,
        CpMethod::UspHybrid { ulysses: 8, ring: 2 },
        CpMethod::Fpdt { pi: 16 },
        CpMethod::UpipeHybrid { u: 8, ulysses: 8, ring: 2 },
    ]
}

/// Build the Llama3-8B single-node preset for a method × sequence length.
pub fn llama_single_node(method: CpMethod, seq_len: u64) -> RunPreset {
    RunPreset {
        model: ModelDims::llama3_8b(),
        cluster: ClusterConfig::h100_node(),
        parallel: ParallelConfig::new(method, 8),
        seq_len,
    }
}

/// Build the Qwen3-32B two-node preset for a method × sequence length.
pub fn qwen_two_node(method: CpMethod, seq_len: u64) -> RunPreset {
    let mut p = ParallelConfig::new(method, 16);
    // 5M on Llama used unpinned host memory due to RAM limits (§5.1); the
    // same applies to any >= 5M run here.
    p.pin_memory = seq_len < crate::util::fmt::parse_tokens("5M").unwrap();
    RunPreset {
        model: ModelDims::qwen3_32b(),
        cluster: ClusterConfig::h100_2nodes(),
        parallel: p,
        seq_len,
    }
}

/// Fig. 5: Llama3-8B on 16×H100, UPipe-Hybrid vs USP-Hybrid.
pub fn llama_two_node(method: CpMethod, seq_len: u64) -> RunPreset {
    RunPreset {
        model: ModelDims::llama3_8b(),
        cluster: ClusterConfig::h100_2nodes(),
        parallel: ParallelConfig::new(method, 16),
        seq_len,
    }
}

/// Fig. 6 ablation: Llama3-8B on 4×H100 at 512K, sweeping U.
pub fn llama_ablation(u: u32) -> RunPreset {
    RunPreset {
        model: ModelDims::llama3_8b(),
        cluster: ClusterConfig::h100_gpus(4).expect("4 GPUs fit one node"),
        parallel: ParallelConfig::new(CpMethod::Upipe { u, gqa_schedule: true }, 4),
        seq_len: crate::util::fmt::parse_tokens("512K").unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for m in llama_single_node_methods() {
            let p = llama_single_node(m, 1 << 20);
            assert!(p.parallel.validate(p.model.n_heads).is_ok(), "{m:?}");
        }
        for m in qwen_two_node_methods() {
            let p = qwen_two_node(m, 1 << 20);
            assert!(p.parallel.validate(p.model.n_heads).is_ok(), "{m:?}");
        }
    }

    #[test]
    fn seq_lens_ordered() {
        let s = table34_seq_lens();
        assert_eq!(s.len(), 8);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn ablation_sweeps_u() {
        for u in [4, 8, 16, 32] {
            let p = llama_ablation(u);
            assert!(p.parallel.validate(p.model.n_heads).is_ok(), "u={u}");
        }
    }

    #[test]
    fn pin_memory_off_at_5m() {
        let p = qwen_two_node(CpMethod::Ring, 5 * 1024 * 1024);
        assert!(!p.parallel.pin_memory);
    }

    #[test]
    fn step_tokens_scale_with_microbatch() {
        let mut p = llama_single_node(CpMethod::Ulysses, 1 << 20);
        assert_eq!(p.step_tokens(), 1 << 20);
        p.parallel.micro_batch = 4;
        assert_eq!(p.step_tokens(), 4 << 20);
    }
}
