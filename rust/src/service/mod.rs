//! Planner-as-a-service: the session API behind `repro serve-plan` (and,
//! one-shot, behind `repro plan`/`frontier` — the CLI is a thin client of
//! the same type).
//!
//! A [`PlannerService`] owns a [`crate::planner::PlannerCaches`] — the
//! trace cache, streamed-probe and pricing memos, fitted symbolic
//! [`crate::engine::PeakModel`]s and verified context walls — plus a
//! whole-plan memo keyed by the canonical request bytes. Everything is
//! fingerprint-keyed ([`crate::schedule::CellKey`] /
//! [`crate::schedule::FamilyKey`] embed the model dims and calibration),
//! so refit calibrations and different models/clusters never alias, and
//! sharing one session across arbitrary request mixes is always safe.
//!
//! The payoff is the warm path: a repeated identical request is answered
//! from the plan memo (zero streamed probes, zero priced sims,
//! byte-for-byte the cold response), and a point capacity query
//! ([`PlannerService::walls_point`]) against an already-swept family
//! answers from verified walls / fitted polynomials in microseconds —
//! the workload shape long-lived training-infrastructure services
//! (DeepSpeed Ulysses, USP deployments) actually see.
//!
//! [`wire`] defines the versioned JSON protocol, [`http`] the
//! `serve-plan` HTTP/1.1 daemon.

pub mod http;
pub mod wire;

use std::collections::HashMap;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::calib::{
    CalibrationSnapshot, DriftEntry, Observation, OnlineCalibrator, OnlineConfig, PublishedEpoch,
};
use crate::engine::{Calibration, Measurements, RefitInfo};
use crate::model::ModelDims;
use crate::planner::{
    place_with, plan_with, walls_at, PlacementOutcome, PlanOutcome, PlannerCaches, WallsAtOutcome,
};
use crate::util::cancel::CancelToken;
use crate::util::failpoint;
use crate::util::stripe::StripedMap;

pub use wire::{
    MeasurementsSource, ObserveParams, PlacementParams, PlanParams, RefitParams, WallsParams,
    API_VERSION,
};

/// Typed service failure: what went wrong, in a shape the HTTP layer can
/// map to a status code (400 / 504 / 503 / 500) and the CLI can print.
/// `Display` renders the same human-readable strings the service has
/// always returned, so error text stays wire-stable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServiceError {
    /// The request could not be validated or evaluated (the historical
    /// `Err(String)` paths, verbatim).
    BadRequest(String),
    /// The request's deadline expired mid-evaluation. Carries partial
    /// accounting — the work the request ran before expiry — and
    /// guarantees nothing reached any memo tier after the deadline
    /// passed (inserts are all-or-nothing per tier).
    DeadlineExceeded { probes_streamed: u64, sims_priced: u64, prices_modeled: u64 },
    /// A prior evaluation of this exact request panicked; the cell is
    /// tombstoned. Retry after the bounded backoff instead of poisoning
    /// a worker again.
    Quarantined { retry_after_s: u64 },
    /// A service-boundary failure (e.g. an injected memo-insert fault):
    /// the request computed but could not publish; nothing partial was
    /// left behind.
    Internal(String),
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::BadRequest(m) | ServiceError::Internal(m) => f.write_str(m),
            ServiceError::DeadlineExceeded { probes_streamed, sims_priced, prices_modeled } => {
                write!(
                    f,
                    "deadline exceeded before evaluation finished \
                     (ran {probes_streamed} probes, {sims_priced} priced sims, \
                     {prices_modeled} modeled prices; no partial state was published)"
                )
            }
            ServiceError::Quarantined { retry_after_s } => write!(
                f,
                "request is quarantined after a prior evaluation panic; \
                 retry after {retry_after_s}s"
            ),
        }
    }
}

impl std::error::Error for ServiceError {}

impl From<String> for ServiceError {
    fn from(m: String) -> Self {
        ServiceError::BadRequest(m)
    }
}

/// A quarantined cell's tombstone: requests for this canonical key are
/// refused until `until`, with exponentially growing (bounded) backoff
/// per consecutive panic.
struct Tombstone {
    until: Instant,
    strikes: u32,
}

/// One plan request's answer: the (possibly memoized) outcome plus the
/// request's deterministic notes. `memo_hit` is observability, never part
/// of the wire result — repeated requests must serialize identically.
pub struct PlanReply {
    pub outcome: Arc<PlanOutcome>,
    pub memo_hit: bool,
    pub warnings: Vec<String>,
    /// Calibration epoch the sweep was priced under: 0 for the boot
    /// calibration and measurements-pinned requests, the active online
    /// epoch otherwise. Memoized with the outcome, so a warm replay's
    /// accounting is byte-identical.
    pub epoch: u64,
    /// Fingerprint of the calibration the sweep was priced under.
    pub calibration_fingerprint: u64,
}

/// A placement request's answer: the (possibly memoized) fleet-wide
/// outcome plus the request's deterministic notes.
pub struct PlacementReply {
    pub outcome: Arc<PlacementOutcome>,
    pub memo_hit: bool,
    pub warnings: Vec<String>,
    /// See [`PlanReply::epoch`] — the *base* calibration's provenance
    /// (each shape prices under its hardware-scaled variant).
    pub epoch: u64,
    pub calibration_fingerprint: u64,
}

/// A refit request's answer: the provenance, the fitted calibration's
/// fingerprint (what plan cache keys embed), and deterministic notes.
pub struct RefitReply {
    pub info: RefitInfo,
    pub calibration_fingerprint: u64,
    pub warnings: Vec<String>,
}

/// One observe batch's answer (`POST /v1/observe`): ingestion accounting,
/// the post-batch drift vector, and — when the batch pushed drift over
/// the publish threshold — the published epoch plus exactly what it
/// invalidated.
pub struct ObserveReply {
    /// Records with at least one sample admitted past the MAD gate.
    pub accepted: u64,
    /// Records rejected whole (every inverted sample was an outlier, or
    /// nothing was invertible).
    pub rejected: u64,
    /// Per-constant drift of the running estimates vs the *now-active*
    /// calibration (all ~0 right after a publish).
    pub drift: Vec<DriftEntry>,
    /// The epoch this batch published, if any.
    pub published: Option<PublishedEpoch>,
    /// Active calibration epoch after the batch.
    pub epoch: u64,
    /// Active calibration fingerprint after the batch.
    pub fingerprint: u64,
    /// Deterministic skip/reject notes (bounded; see
    /// [`crate::calib::IngestReport`]).
    pub notes: Vec<String>,
    /// Per-tier evaluator-cache entries dropped by this batch's epoch
    /// publish, in [`PlannerCaches::sizes`] order; empty when nothing
    /// published.
    pub invalidated: Vec<(&'static str, u64)>,
    /// Whole-plan memo entries dropped by this batch's epoch publish.
    pub plans_invalidated: u64,
    /// Whole-placement memo entries dropped by this batch's epoch publish.
    pub placements_invalidated: u64,
}

/// Snapshot of the session's lifetime counters (surfaced by
/// `/v1/health`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStats {
    pub plan_requests: u64,
    pub plan_memo_hits: u64,
    pub placement_requests: u64,
    pub placement_memo_hits: u64,
    /// Fleet shapes skipped before any probe by dominance pruning,
    /// summed across placement requests (memo hits excluded).
    pub shapes_pruned: u64,
    pub point_queries: u64,
    pub refits: u64,
    /// Streamed kernel probes across all requests (memo hits excluded).
    pub probes_streamed: u64,
    /// Fully priced simulations across all requests (memo hits excluded).
    pub sims_priced: u64,
    /// Streamed timing-kernel prices across all requests (memo hits
    /// excluded) — phase-2 cells answered without a full simulation.
    pub prices_modeled: u64,
    /// Times the byte-budget valve ran and evicted at least one entry.
    pub cache_evictions: u64,
    /// Total entries dropped by the valve across every tier.
    pub entries_evicted: u64,
    /// Canonical request cells currently tombstoned after an evaluation
    /// panic (active quarantine entries at snapshot time).
    pub cells_quarantined: u64,
    /// Telemetry records accepted by `/v1/observe` (≥1 sample admitted).
    pub observations_accepted: u64,
    /// Telemetry records rejected whole by `/v1/observe`.
    pub observations_rejected: u64,
    /// Calibration epochs published by drift crossing the threshold.
    pub epochs_published: u64,
    /// The active calibration epoch (0 = the boot calibration).
    pub calibration_epoch: u64,
    /// Evaluator-cache entries dropped by epoch publishes, summed across
    /// every tier (distinct from `entries_evicted`, the LRU valve).
    pub entries_invalidated: u64,
    /// Whole-plan memo entries dropped by epoch publishes.
    pub plans_invalidated: u64,
    /// Whole-placement memo entries dropped by epoch publishes.
    pub placements_invalidated: u64,
}

/// A long-lived planner session: persistent cross-request caches behind
/// typed request/response methods. Thread-safe — the HTTP daemon calls
/// one instance from every worker; interleaved identical and distinct
/// requests return results bitwise-identical to sequential one-shot
/// `plan()` calls (the service-concurrency property test pins this).
/// One memoized plan: the outcome plus the request's deterministic notes
/// (refit provenance), so a memo hit replays both without re-running the
/// refit pipeline.
struct PlanMemoEntry {
    outcome: Arc<PlanOutcome>,
    warnings: Vec<String>,
    /// Calibration provenance the request was priced under, memoized so
    /// a warm replay's accounting is byte-identical to the cold reply.
    epoch: u64,
    calibration_fingerprint: u64,
}

/// One memoized placement, mirroring [`PlanMemoEntry`].
struct PlacementMemoEntry {
    outcome: Arc<PlacementOutcome>,
    warnings: Vec<String>,
    epoch: u64,
    calibration_fingerprint: u64,
}

pub struct PlannerService {
    caches: PlannerCaches,
    /// Whole-plan memo keyed by the canonical request bytes — exact for
    /// every field except `measurements`, which keys as a 64-bit content
    /// fingerprint (see `PlanParams::canonical`). A repeated request is
    /// one lookup.
    plans: StripedMap<String, Arc<PlanMemoEntry>>,
    /// Whole-placement memo, keyed like `plans` by canonical request
    /// bytes (which embed the fleet's canonical form).
    placements: StripedMap<String, Arc<PlacementMemoEntry>>,
    /// Byte budget for every cache tier combined (`usize::MAX` =
    /// unbounded); see [`PlannerService::enforce_budget`].
    cache_budget: usize,
    /// Server-wide evaluation deadline applied to every request (`None`
    /// = unbounded). A per-request `deadline_ms` tightens but never
    /// loosens this.
    request_timeout: Option<Duration>,
    /// Panic tombstones keyed by canonical request bytes: a cell whose
    /// evaluation panicked answers `Quarantined` (bounded retry-after)
    /// instead of poisoning another worker, until its tombstone lapses.
    quarantine: Mutex<HashMap<String, Tombstone>>,
    /// The live calibration object behind `/v1/observe` and
    /// `/v1/calibration`: ingests telemetry, tracks drift, and publishes
    /// a new calibration epoch when drift crosses the threshold. Requests
    /// without pinned measurements plan under its *active* calibration;
    /// their memo keys carry the epoch, so a publish makes exactly the
    /// stale entries unreachable (and `observe` drops them eagerly).
    calibrator: Mutex<OnlineCalibrator>,
    plan_requests: AtomicU64,
    plan_memo_hits: AtomicU64,
    placement_requests: AtomicU64,
    placement_memo_hits: AtomicU64,
    shapes_pruned: AtomicU64,
    point_queries: AtomicU64,
    refits: AtomicU64,
    probes_streamed: AtomicU64,
    sims_priced: AtomicU64,
    prices_modeled: AtomicU64,
    cache_evictions: AtomicU64,
    entries_evicted: AtomicU64,
    observations_accepted: AtomicU64,
    observations_rejected: AtomicU64,
    epochs_published: AtomicU64,
    entries_invalidated: AtomicU64,
    plans_invalidated: AtomicU64,
    placements_invalidated: AtomicU64,
}

/// Default byte budget for the session caches (all tiers plus the plan
/// memo): 1 GiB. Keeps a long-lived daemon serving arbitrarily varied
/// request shapes at bounded memory; the `repro serve-plan` CLI overrides
/// it with `--cache-budget`.
pub const DEFAULT_CACHE_BUDGET: usize = 1 << 30;

/// Ceiling on a quarantine tombstone's retry-after: backoff doubles per
/// consecutive panic (1s, 2s, 4s, ...) but never exceeds this.
pub const MAX_QUARANTINE_SECS: u64 = 60;

impl PlannerService {
    pub fn new() -> Self {
        Self::with_budget(DEFAULT_CACHE_BUDGET)
    }

    /// A session whose caches are evicted down to `cache_budget` bytes at
    /// the end of each state-growing request (`usize::MAX` = unbounded).
    pub fn with_budget(cache_budget: usize) -> Self {
        PlannerService {
            caches: PlannerCaches::new(),
            plans: StripedMap::default(),
            placements: StripedMap::default(),
            cache_budget,
            request_timeout: None,
            quarantine: Mutex::new(HashMap::new()),
            calibrator: Mutex::new(OnlineCalibrator::new(
                Calibration::default(),
                OnlineConfig::default(),
            )),
            plan_requests: AtomicU64::new(0),
            plan_memo_hits: AtomicU64::new(0),
            placement_requests: AtomicU64::new(0),
            placement_memo_hits: AtomicU64::new(0),
            shapes_pruned: AtomicU64::new(0),
            point_queries: AtomicU64::new(0),
            refits: AtomicU64::new(0),
            probes_streamed: AtomicU64::new(0),
            sims_priced: AtomicU64::new(0),
            prices_modeled: AtomicU64::new(0),
            cache_evictions: AtomicU64::new(0),
            entries_evicted: AtomicU64::new(0),
            observations_accepted: AtomicU64::new(0),
            observations_rejected: AtomicU64::new(0),
            epochs_published: AtomicU64::new(0),
            entries_invalidated: AtomicU64::new(0),
            plans_invalidated: AtomicU64::new(0),
            placements_invalidated: AtomicU64::new(0),
        }
    }

    /// Apply a server-wide evaluation deadline to every subsequent
    /// request (`None` = unbounded). The `repro serve-plan` CLI wires
    /// `--request-timeout` through this.
    pub fn with_request_timeout(mut self, timeout: Option<Duration>) -> Self {
        self.request_timeout = timeout;
        self
    }

    /// The cancel token for one request: the tighter of the server-wide
    /// timeout and the request's own `deadline_ms`.
    fn token_for(&self, deadline_ms: Option<u64>) -> CancelToken {
        let server = match self.request_timeout {
            Some(t) => CancelToken::with_deadline(t),
            None => CancelToken::none(),
        };
        let client = match deadline_ms {
            Some(ms) => CancelToken::with_deadline(Duration::from_millis(ms)),
            None => CancelToken::none(),
        };
        CancelToken::earliest(server, client)
    }

    /// Refuse a request whose canonical cell carries an active panic
    /// tombstone. A lapsed tombstone lets the retry through (strikes are
    /// kept, so a repeat panic backs off longer).
    fn quarantine_check(&self, key: &str) -> Result<(), ServiceError> {
        let q = self.quarantine.lock().unwrap();
        if let Some(t) = q.get(key) {
            let now = Instant::now();
            if now < t.until {
                let retry_after_s = (t.until - now).as_secs() + 1;
                return Err(ServiceError::Quarantined { retry_after_s });
            }
        }
        Ok(())
    }

    /// Record an evaluation panic for `key`: backoff doubles per
    /// consecutive strike, bounded at [`MAX_QUARANTINE_SECS`].
    fn quarantine_strike(&self, key: &str) {
        let mut q = self.quarantine.lock().unwrap();
        let now = Instant::now();
        let t = q.entry(key.to_string()).or_insert(Tombstone { until: now, strikes: 0 });
        t.strikes = t.strikes.saturating_add(1);
        let secs = if t.strikes >= 7 {
            MAX_QUARANTINE_SECS
        } else {
            (1u64 << (t.strikes - 1)).min(MAX_QUARANTINE_SECS)
        };
        t.until = now + Duration::from_secs(secs);
    }

    /// A clean recompute heals the cell: drop its tombstone (and strike
    /// history) entirely.
    fn quarantine_clear(&self, key: &str) {
        self.quarantine.lock().unwrap().remove(key);
    }

    /// Active panic tombstones right now (surfaced by `/v1/health` as
    /// `cells_quarantined`).
    pub fn cells_quarantined(&self) -> u64 {
        let now = Instant::now();
        self.quarantine.lock().unwrap().values().filter(|t| t.until > now).count() as u64
    }

    /// The size-aware pressure valve, called at the end of every request
    /// that grows session state: evicts least-recently-used entries,
    /// tier by tier, until the total footprint fits the budget again.
    /// Order — trace cache (dominant footprint, cheap rebuild) first,
    /// then priced reports, budgeted probes, peak probes, then the
    /// whole-plan memo; fitted models and verified walls are tiny,
    /// expensive-to-refit tiers evicted only if everything else is
    /// already gone. Mid-request the footprint may transiently exceed
    /// the budget (a cold sweep fills its caches before the valve runs);
    /// the budget is the steady-state bound between requests.
    fn enforce_budget(&self) {
        let budget = self.cache_budget;
        let memos = |s: &Self| s.plans.bytes() + s.placements.bytes();
        if self.caches.bytes() + memos(self) <= budget {
            return;
        }
        let mut dropped = self.caches.evict_bulk_to_fit(budget, memos(self));
        if self.caches.bytes() + memos(self) > budget {
            let keep = budget.saturating_sub(self.caches.bytes() + self.placements.bytes());
            dropped += self.plans.evict_lru(keep);
        }
        if self.caches.bytes() + memos(self) > budget {
            let keep = budget.saturating_sub(self.caches.bytes() + self.plans.bytes());
            dropped += self.placements.evict_lru(keep);
        }
        if self.caches.bytes() + memos(self) > budget {
            dropped += self.caches.evict_precious_to_fit(budget, memos(self));
        }
        if dropped > 0 {
            self.cache_evictions.fetch_add(1, Ordering::Relaxed);
            self.entries_evicted.fetch_add(dropped, Ordering::Relaxed);
        }
    }

    /// The calibration context one request plans under. Requests that pin
    /// a measurements file are epoch-independent (their refit calibration
    /// keys every cache by its own fingerprint): `(0, None)`. Requests
    /// without measurements plan under the *active* online calibration;
    /// at epoch 0 that is bitwise the boot default, so the pre-observe
    /// paths (keys and bytes) are exactly the historical ones.
    fn epoch_context(&self, measurements_pinned: bool) -> (u64, Option<Calibration>) {
        if measurements_pinned {
            return (0, None);
        }
        let cal = self.calibrator.lock().unwrap();
        if cal.epoch() == 0 {
            (0, None)
        } else {
            (cal.epoch(), Some(cal.active().clone()))
        }
    }

    /// The memo key for a request under `epoch`: epoch 0 keys are the
    /// bare canonical bytes (unchanged from every earlier release), later
    /// epochs append `#e{epoch}` — so entries priced under a stale epoch
    /// are never *hit* even before `observe` drops them.
    fn epoch_key(canonical: String, epoch: u64) -> String {
        if epoch == 0 {
            canonical
        } else {
            format!("{canonical}#e{epoch}")
        }
    }

    /// Is this memo key stale when the active epoch moves past
    /// `old_epoch`? Epoch-suffixed keys match exactly; bare keys are
    /// stale only if they planned under the boot calibration (epoch 0)
    /// *without* pinned measurements — a measurements fingerprint in the
    /// canonical bytes keeps the entry valid forever.
    fn memo_key_stale(key: &str, old_epoch: u64) -> bool {
        if old_epoch == 0 {
            !key.contains("#e") && key.contains("\"measurements\":null")
        } else {
            key.ends_with(&format!("#e{old_epoch}"))
        }
    }

    /// Ingest a telemetry batch (`POST /v1/observe`, and the CLI's
    /// `repro observe`): per-method records are structurally inverted to
    /// per-constant rate samples, MAD-gated, and folded into running
    /// estimates; when any sufficiently-observed constant drifts past the
    /// threshold, a new calibration epoch publishes and this method
    /// *surgically* invalidates exactly the stale fingerprint's entries —
    /// every evaluator tier plus the whole-plan/placement memos — while
    /// other fingerprints' warm state (measurements-pinned requests,
    /// other epochs) survives untouched. The calibrator lock is held
    /// across the invalidation, so a concurrent plan either keys under
    /// the old epoch (and its entry is dropped or unreachable) or the
    /// new one.
    pub fn observe(&self, observations: &[Observation]) -> ObserveReply {
        let mut cal = self.calibrator.lock().unwrap();
        let old_epoch = cal.epoch();
        let report = cal.ingest(observations);
        self.observations_accepted.fetch_add(report.accepted, Ordering::Relaxed);
        self.observations_rejected.fetch_add(report.rejected, Ordering::Relaxed);
        let mut invalidated = Vec::new();
        let (mut plans_dropped, mut placements_dropped) = (0u64, 0u64);
        if let Some(published) = &report.published {
            self.epochs_published.fetch_add(1, Ordering::Relaxed);
            invalidated = self.caches.invalidate_fingerprint(published.old_fingerprint).to_vec();
            plans_dropped = self.plans.remove_if(|k| Self::memo_key_stale(k, old_epoch));
            placements_dropped =
                self.placements.remove_if(|k| Self::memo_key_stale(k, old_epoch));
            let tier_total: u64 = invalidated.iter().map(|(_, n)| n).sum();
            self.entries_invalidated.fetch_add(tier_total, Ordering::Relaxed);
            self.plans_invalidated.fetch_add(plans_dropped, Ordering::Relaxed);
            self.placements_invalidated.fetch_add(placements_dropped, Ordering::Relaxed);
        }
        ObserveReply {
            accepted: report.accepted,
            rejected: report.rejected,
            drift: report.drift,
            published: report.published,
            epoch: cal.epoch(),
            fingerprint: cal.fingerprint(),
            notes: report.notes,
            invalidated,
            plans_invalidated: plans_dropped,
            placements_invalidated: placements_dropped,
        }
    }

    /// The active calibration's full snapshot (`GET /v1/calibration`):
    /// epoch, fingerprint, every constant, the current drift vector, and
    /// the bounded provenance chain of published epochs.
    pub fn calibration_snapshot(&self) -> CalibrationSnapshot {
        self.calibrator.lock().unwrap().snapshot()
    }

    /// The active calibration epoch and fingerprint (`/v1/health`).
    pub fn calibration_epoch(&self) -> (u64, u64) {
        let cal = self.calibrator.lock().unwrap();
        (cal.epoch(), cal.fingerprint())
    }

    /// Full sweep (`POST /v1/plan`, and the CLI's `repro plan`). Warm
    /// path: the canonical request bytes hit the plan memo and *nothing*
    /// is re-run — not the sweep, not a refit, not the anchor simulation
    /// (warnings are memoized with the outcome); otherwise the sweep runs
    /// against the session caches, reusing whatever earlier requests left
    /// behind. A memoized key implies the params validated when first
    /// computed, so the hit path skips `to_request` entirely.
    ///
    /// Failure modes beyond validation: an expired deadline answers
    /// [`ServiceError::DeadlineExceeded`] *before* any counter or memo
    /// insert for the partial work (the evaluator already refused to
    /// publish to its tiers); an evaluation panic records a quarantine
    /// strike and re-raises, so the caller's firewall sees the original
    /// panic while subsequent identical requests get
    /// [`ServiceError::Quarantined`] until the tombstone lapses.
    pub fn plan(&self, params: &PlanParams) -> Result<PlanReply, ServiceError> {
        self.plan_requests.fetch_add(1, Ordering::Relaxed);
        let (epoch, active) = self.epoch_context(params.measurements.is_some());
        let key = Self::epoch_key(params.canonical().render(), epoch);
        if let Some(hit) = self.plans.get(&key) {
            self.plan_memo_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(PlanReply {
                outcome: Arc::clone(&hit.outcome),
                memo_hit: true,
                warnings: hit.warnings.clone(),
                epoch: hit.epoch,
                calibration_fingerprint: hit.calibration_fingerprint,
            });
        }
        self.quarantine_check(&key)?;
        let (mut req, warnings) = params.to_request()?;
        if let Some(cal) = active {
            req.calibration = cal;
        }
        let calibration_fingerprint = req.calibration.fingerprint();
        req.cancel = self.token_for(params.deadline_ms);
        let out = match catch_unwind(AssertUnwindSafe(|| plan_with(&req, &self.caches))) {
            Ok(out) => out,
            Err(payload) => {
                self.quarantine_strike(&key);
                resume_unwind(payload);
            }
        };
        self.quarantine_clear(&key);
        if out.cancelled {
            // Cells evaluated *before* expiry did publish to their tiers
            // (they were complete); run the valve so the budget invariant
            // holds, then answer with partial accounting only.
            self.enforce_budget();
            return Err(ServiceError::DeadlineExceeded {
                probes_streamed: out.feasibility_probes,
                sims_priced: out.priced_sims,
                prices_modeled: out.modeled_prices,
            });
        }
        if out.configs.is_empty() {
            return Err(ServiceError::BadRequest(format!(
                "no valid configurations: the requested sweep dims (tp {:?}, mb {:?}, ac {:?}) \
                 fit neither {} nor the {}-GPU cluster",
                req.dims.tp_degrees,
                req.dims.micro_batches,
                req.dims.ac_modes.iter().map(|a| a.label()).collect::<Vec<_>>(),
                req.model.name,
                req.cluster.total_gpus()
            )));
        }
        self.probes_streamed.fetch_add(out.feasibility_probes, Ordering::Relaxed);
        self.sims_priced.fetch_add(out.priced_sims, Ordering::Relaxed);
        self.prices_modeled.fetch_add(out.modeled_prices, Ordering::Relaxed);
        if let Err(e) = failpoint::fire("service.memo_insert") {
            // The sweep ran but the answer cannot publish: keep the memo
            // all-or-nothing (no entry at all) and still run the valve so
            // the budget invariant holds between requests.
            self.enforce_budget();
            return Err(ServiceError::Internal(e));
        }
        // First writer wins on a racing key; both callers get the
        // canonical entry either way. The entry's weight is its heap
        // payload: the key bytes, the per-config rows, and the notes.
        let payload = key.len()
            + out.configs.len() * std::mem::size_of::<crate::planner::ConfigPlan>()
            + warnings.iter().map(String::len).sum::<usize>();
        let entry = self.plans.insert_weighed(
            key,
            Arc::new(PlanMemoEntry {
                outcome: Arc::new(out),
                warnings,
                epoch,
                calibration_fingerprint,
            }),
            payload,
        );
        let reply = PlanReply {
            outcome: Arc::clone(&entry.outcome),
            memo_hit: false,
            warnings: entry.warnings.clone(),
            epoch: entry.epoch,
            calibration_fingerprint: entry.calibration_fingerprint,
        };
        self.enforce_budget();
        Ok(reply)
    }

    /// Fleet placement sweep (`POST /v1/placement`, and the CLI's
    /// `repro place`). Memoized like [`PlannerService::plan`] on the
    /// canonical request bytes — a warm replay returns the identical
    /// outcome without enumerating a single shape. On a miss the
    /// evaluator runs against the session caches, so model fits laid
    /// down by earlier plan or placement requests on the same hardware
    /// are reused across requests, not just across shapes.
    pub fn place(&self, params: &PlacementParams) -> Result<PlacementReply, ServiceError> {
        self.placement_requests.fetch_add(1, Ordering::Relaxed);
        let (epoch, active) = self.epoch_context(params.plan.measurements.is_some());
        let key = Self::epoch_key(params.canonical().render(), epoch);
        if let Some(hit) = self.placements.get(&key) {
            self.placement_memo_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(PlacementReply {
                outcome: Arc::clone(&hit.outcome),
                memo_hit: true,
                warnings: hit.warnings.clone(),
                epoch: hit.epoch,
                calibration_fingerprint: hit.calibration_fingerprint,
            });
        }
        self.quarantine_check(&key)?;
        let (mut req, warnings) = params.to_request()?;
        if let Some(cal) = active {
            req.calibration = cal;
        }
        let calibration_fingerprint = req.calibration.fingerprint();
        req.cancel = self.token_for(params.plan.deadline_ms);
        let out = match catch_unwind(AssertUnwindSafe(|| place_with(&req, &self.caches))) {
            Ok(out) => out,
            Err(payload) => {
                self.quarantine_strike(&key);
                resume_unwind(payload);
            }
        };
        self.quarantine_clear(&key);
        if out.cancelled {
            self.enforce_budget();
            return Err(ServiceError::DeadlineExceeded {
                probes_streamed: out.feasibility_probes,
                sims_priced: out.anchor_sims,
                prices_modeled: out.modeled_prices,
            });
        }
        if out.placements.iter().all(|sp| sp.plan.as_ref().map_or(true, |p| p.configs.is_empty())) {
            return Err(ServiceError::BadRequest(format!(
                "no valid configurations on any fleet shape: the requested sweep dims \
                 (tp {:?}, mb {:?}) fit {} on none of the {} candidate shapes",
                req.dims.tp_degrees,
                req.dims.micro_batches,
                req.model.name,
                out.shapes_total
            )));
        }
        self.probes_streamed.fetch_add(out.feasibility_probes, Ordering::Relaxed);
        self.sims_priced.fetch_add(out.anchor_sims, Ordering::Relaxed);
        self.prices_modeled.fetch_add(out.modeled_prices, Ordering::Relaxed);
        self.shapes_pruned.fetch_add(out.shapes_pruned, Ordering::Relaxed);
        let rows: usize = out
            .placements
            .iter()
            .filter_map(|sp| sp.plan.as_ref())
            .map(|p| p.configs.len())
            .sum();
        let payload = key.len()
            + rows * std::mem::size_of::<crate::planner::ConfigPlan>()
            + warnings.iter().map(String::len).sum::<usize>();
        if let Err(e) = failpoint::fire("service.memo_insert") {
            self.enforce_budget();
            return Err(ServiceError::Internal(e));
        }
        let entry = self.placements.insert_weighed(
            key,
            Arc::new(PlacementMemoEntry {
                outcome: Arc::new(out),
                warnings,
                epoch,
                calibration_fingerprint,
            }),
            payload,
        );
        let reply = PlacementReply {
            outcome: Arc::clone(&entry.outcome),
            memo_hit: false,
            warnings: entry.warnings.clone(),
            epoch: entry.epoch,
            calibration_fingerprint: entry.calibration_fingerprint,
        };
        self.enforce_budget();
        Ok(reply)
    }

    /// Walls-only sweep (`POST /v1/walls` without `"at"`): the plan
    /// endpoint with pricing forced off.
    pub fn walls_sweep(&self, params: &PlanParams) -> Result<PlanReply, ServiceError> {
        let mut p = params.clone();
        p.feasibility_only = true;
        self.plan(&p)
    }

    /// Point capacity query (`POST /v1/walls` with a single `"at"`): "is
    /// sequence length `at` trainable?" per sweep configuration, answered
    /// from the session's verified walls / fitted models when warm — zero
    /// streamed probes after any full sweep on the same lattice.
    pub fn walls_point(
        &self,
        params: &PlanParams,
        at: u64,
    ) -> Result<(WallsAtOutcome, Vec<String>), ServiceError> {
        let (mut outs, warnings) = self.walls_batch(params, &[at])?;
        Ok((outs.pop().expect("one point per query"), warnings))
    }

    /// Batch point capacity query (`POST /v1/walls` with `"at": [...]`):
    /// one validated request, one response carrying a full capacity curve
    /// — each point answered independently, tier by tier, from the same
    /// memos a single-point query consults (so a dashboard's sweep is as
    /// warm as its hottest point).
    pub fn walls_batch(
        &self,
        params: &PlanParams,
        ats: &[u64],
    ) -> Result<(Vec<WallsAtOutcome>, Vec<String>), ServiceError> {
        let (epoch, active) = self.epoch_context(params.measurements.is_some());
        let (mut req, warnings) = params.to_request()?;
        if let Some(cal) = active {
            req.calibration = cal;
        }
        req.cancel = self.token_for(params.deadline_ms);
        let plan_key = Self::epoch_key(params.canonical().render(), epoch);
        let mut outs = Vec::with_capacity(ats.len());
        let mut probes_before_expiry = 0u64;
        for &at in ats {
            self.point_queries.fetch_add(1, Ordering::Relaxed);
            // Each point quarantines independently: a panic at one
            // sequence length must not fence off the whole curve.
            let key = format!("{plan_key}@{at}");
            self.quarantine_check(&key)?;
            let q = match catch_unwind(AssertUnwindSafe(|| walls_at(&req, at, &self.caches))) {
                Ok(q) => q,
                Err(payload) => {
                    self.quarantine_strike(&key);
                    resume_unwind(payload);
                }
            };
            self.quarantine_clear(&key);
            if q.cancelled {
                self.enforce_budget();
                return Err(ServiceError::DeadlineExceeded {
                    probes_streamed: probes_before_expiry + q.probes,
                    sims_priced: 0,
                    prices_modeled: 0,
                });
            }
            probes_before_expiry += q.probes;
            self.probes_streamed.fetch_add(q.probes, Ordering::Relaxed);
            outs.push(q);
        }
        self.enforce_budget();
        Ok((outs, warnings))
    }

    /// Fit a refit calibration from measurements without planning
    /// (`POST /v1/refit`). The model comes from the measurements payload;
    /// the returned fingerprint is what a follow-up plan request carrying
    /// the same measurements will key its caches under.
    pub fn refit(&self, params: &RefitParams) -> Result<RefitReply, ServiceError> {
        self.refits.fetch_add(1, Ordering::Relaxed);
        let m = Measurements::parse(&params.measurements.text, &params.measurements.source)?;
        let model = ModelDims::by_name(&m.model)
            .ok_or_else(|| format!("unknown model `{}` in measurements", m.model))?;
        let (cal, info, warnings) = wire::build_refit(&model, &m)?;
        Ok(RefitReply { info, calibration_fingerprint: cal.fingerprint(), warnings })
    }

    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            plan_requests: self.plan_requests.load(Ordering::Relaxed),
            plan_memo_hits: self.plan_memo_hits.load(Ordering::Relaxed),
            placement_requests: self.placement_requests.load(Ordering::Relaxed),
            placement_memo_hits: self.placement_memo_hits.load(Ordering::Relaxed),
            shapes_pruned: self.shapes_pruned.load(Ordering::Relaxed),
            point_queries: self.point_queries.load(Ordering::Relaxed),
            refits: self.refits.load(Ordering::Relaxed),
            probes_streamed: self.probes_streamed.load(Ordering::Relaxed),
            sims_priced: self.sims_priced.load(Ordering::Relaxed),
            prices_modeled: self.prices_modeled.load(Ordering::Relaxed),
            cache_evictions: self.cache_evictions.load(Ordering::Relaxed),
            entries_evicted: self.entries_evicted.load(Ordering::Relaxed),
            cells_quarantined: self.cells_quarantined(),
            observations_accepted: self.observations_accepted.load(Ordering::Relaxed),
            observations_rejected: self.observations_rejected.load(Ordering::Relaxed),
            epochs_published: self.epochs_published.load(Ordering::Relaxed),
            calibration_epoch: self.calibration_epoch().0,
            entries_invalidated: self.entries_invalidated.load(Ordering::Relaxed),
            plans_invalidated: self.plans_invalidated.load(Ordering::Relaxed),
            placements_invalidated: self.placements_invalidated.load(Ordering::Relaxed),
        }
    }

    /// The session's evaluator caches (observability: `/v1/health` sizes).
    pub fn caches(&self) -> &PlannerCaches {
        &self.caches
    }

    /// Memoized whole-plan count.
    pub fn plan_memo_len(&self) -> usize {
        self.plans.len()
    }

    /// Approximate resident bytes of the whole-plan memo.
    pub fn plan_memo_bytes(&self) -> usize {
        self.plans.bytes()
    }

    /// Entries the valve has dropped from the whole-plan memo.
    pub fn plan_memo_evictions(&self) -> u64 {
        self.plans.evicted()
    }

    /// Memoized whole-placement count.
    pub fn placement_memo_len(&self) -> usize {
        self.placements.len()
    }

    /// Approximate resident bytes of the whole-placement memo.
    pub fn placement_memo_bytes(&self) -> usize {
        self.placements.bytes()
    }

    /// Entries the valve has dropped from the whole-placement memo.
    pub fn placement_memo_evictions(&self) -> u64 {
        self.placements.evicted()
    }

    /// Approximate resident bytes across every tier plus the plan and
    /// placement memos — the quantity [`PlannerService::cache_budget`]
    /// bounds between requests.
    pub fn cache_bytes(&self) -> usize {
        self.caches.bytes() + self.plans.bytes() + self.placements.bytes()
    }

    /// The configured byte budget (`usize::MAX` = unbounded).
    pub fn cache_budget(&self) -> usize {
        self.cache_budget
    }

    /// Evict every cache. Invoked automatically by the size-triggered
    /// pressure valve on the daemon's request paths (and callable
    /// directly by embedders); counters keep running, the session stays
    /// usable.
    pub fn clear_caches(&self) {
        self.caches.clear();
        self.plans.clear();
        self.placements.clear();
    }

    /// The session's baseline calibration fingerprint (what cache keys
    /// embed for non-refit requests).
    pub fn default_calibration_fingerprint(&self) -> u64 {
        Calibration::default().fingerprint()
    }
}

impl Default for PlannerService {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::planner as planner_report;

    fn small_params() -> PlanParams {
        let mut p = PlanParams::defaults("llama3-8b", 8);
        p.quantum = 1 << 20;
        p.cap_s = 8 << 20;
        p.threads = 2;
        p.feasibility_only = true;
        p
    }

    #[test]
    fn repeated_plan_hits_memo_and_serializes_identically() {
        let service = PlannerService::new();
        let p = small_params();
        let first = service.plan(&p).unwrap();
        assert!(!first.memo_hit);
        let second = service.plan(&p).unwrap();
        assert!(second.memo_hit, "identical request must hit the plan memo");
        assert!(Arc::ptr_eq(&first.outcome, &second.outcome));
        let a = planner_report::plan_result_json(&first.outcome).render();
        let b = planner_report::plan_result_json(&second.outcome).render();
        assert_eq!(a, b);
        let st = service.stats();
        assert_eq!(st.plan_requests, 2);
        assert_eq!(st.plan_memo_hits, 1);
        assert!(st.probes_streamed > 0);
        assert_eq!(st.sims_priced, 0, "feasibility-only sweep never prices");
        // A *different* request (thread count aside) is a distinct key...
        let mut p2 = small_params();
        p2.cap_s = 4 << 20;
        assert!(!service.plan(&p2).unwrap().memo_hit);
        // ...but a thread-count variant is not.
        let mut p3 = small_params();
        p3.threads = 1;
        assert!(service.plan(&p3).unwrap().memo_hit);
    }

    #[test]
    fn frontier_and_walls_share_the_session() {
        let service = PlannerService::new();
        let mut p = small_params();
        p.feasibility_only = false;
        let probes_cold = {
            let reply = service.plan(&p).unwrap();
            assert!(reply.outcome.configs.iter().any(|c| c.pareto));
            service.stats().probes_streamed
        };
        // The walls sweep reuses the session's verified walls: no new
        // streamed probes at all.
        let walls = service.walls_sweep(&p).unwrap();
        assert!(walls.outcome.feasibility_only);
        assert!(!walls.memo_hit, "different canonical request");
        assert_eq!(service.stats().probes_streamed, probes_cold);
        // Warm point query: zero probes, every cell from a verified wall.
        let (q, _) = service.walls_point(&p, 6 << 20).unwrap();
        assert_eq!(q.probes, 0);
        assert_eq!(q.from_walls, q.cells.len() as u64);
        assert_eq!(service.stats().point_queries, 1);
        // Eviction keeps the session usable.
        service.clear_caches();
        assert_eq!(service.plan_memo_len(), 0);
        let again = service.plan(&p).unwrap();
        assert!(!again.memo_hit);
    }

    #[test]
    fn placement_requests_memoize_and_replay_byte_identically() {
        use crate::util::json::Json;
        let service = PlannerService::new();
        let body = r#"{"model":"llama3-8b","paper":true,"quantum":"1M","cap":"8M","threads":1,
            "fleet":{"pools":[{"name":"east","device":"h100","nodes":1},
                              {"name":"lab","device":"h200","nodes":1}]}}"#;
        let p = PlacementParams::from_json(&Json::parse(body).unwrap()).unwrap();
        let first = service.place(&p).unwrap();
        assert!(!first.memo_hit);
        assert_eq!(first.outcome.shapes_pruned, 1, "east/1x8 is dominated by the H200 pool");
        let second = service.place(&p).unwrap();
        assert!(second.memo_hit, "identical request must hit the placement memo");
        assert!(Arc::ptr_eq(&first.outcome, &second.outcome));
        let a = planner_report::placement_result_json(&first.outcome).render();
        assert_eq!(a, planner_report::placement_result_json(&second.outcome).render());
        let st = service.stats();
        assert_eq!(st.placement_requests, 2);
        assert_eq!(st.placement_memo_hits, 1);
        assert_eq!(st.shapes_pruned, 1, "memo hits do not re-count pruning");
        assert_eq!(st.plan_requests, 0, "placement does not ride the plan path");
        assert!(st.probes_streamed > 0);
        assert_eq!(service.placement_memo_len(), 1);
        assert!(service.placement_memo_bytes() > 0);
        // Eviction keeps the session usable, and a cold re-run of the
        // same request serializes to the same bytes.
        service.clear_caches();
        assert_eq!(service.placement_memo_len(), 0);
        let again = service.place(&p).unwrap();
        assert!(!again.memo_hit);
        assert_eq!(planner_report::placement_result_json(&again.outcome).render(), a);
    }

    #[test]
    fn service_errors_are_typed() {
        let service = PlannerService::new();
        let mut p = small_params();
        p.model = "nope".into();
        let err = service.plan(&p).unwrap_err();
        assert!(matches!(err, ServiceError::BadRequest(_)), "{err}");
        assert!(err.to_string().contains("unknown model"), "{err}");
        let mut p = small_params();
        p.gpus = 12; // not 1..=8 and not a whole number of 8-GPU nodes
        assert!(service.plan(&p).is_err());
        let bad = RefitParams {
            measurements: MeasurementsSource { source: "t".into(), text: "{]".into() },
        };
        assert!(matches!(service.refit(&bad).unwrap_err(), ServiceError::BadRequest(_)));
    }

    #[test]
    fn expired_deadline_answers_504_and_publishes_nothing() {
        let service = PlannerService::new();
        let mut p = small_params();
        // deadline_ms: 0 is the deterministic already-expired token — the
        // evaluator answers placeholders for every cell and publishes to
        // no tier.
        p.deadline_ms = Some(0);
        let err = service.plan(&p).unwrap_err();
        assert!(
            matches!(err, ServiceError::DeadlineExceeded { probes_streamed: 0, .. }),
            "an instantly expired sweep runs zero probes: {err}"
        );
        assert_eq!(service.plan_memo_len(), 0, "cancelled request must not memoize");
        let walls_entries = service
            .caches()
            .tiers()
            .iter()
            .find(|t| t.name == "walls")
            .map_or(0, |t| t.entries);
        assert_eq!(walls_entries, 0, "cancelled request must not publish verified walls");
        // The identical request (deadline_ms is outside the canonical
        // key) recomputes cold — no partial state survived — then warms.
        p.deadline_ms = None;
        assert!(!service.plan(&p).unwrap().memo_hit, "no partial state may satisfy a retry");
        assert!(service.plan(&p).unwrap().memo_hit);
        // Batch point queries cancel the same way, publishing nothing.
        let mut p = small_params();
        p.deadline_ms = Some(0);
        let err = service.walls_batch(&p, &[1 << 20, 2 << 20]).unwrap_err();
        assert!(matches!(err, ServiceError::DeadlineExceeded { .. }), "{err}");
    }

    // Consumable-failpoint tests (panic quarantine, memo-insert fault)
    // live in `tests/service_faults.rs`: arming `panic(1)`/`err(1)` on a
    // production site is process-global, and a concurrent unrelated
    // sweep in this binary could consume the charge.

    #[test]
    fn budget_evicts_bulk_tiers_but_never_walls_or_models() {
        // A budget far below one priced sweep's trace/report footprint,
        // but comfortably above the precious tiers' floor.
        const BUDGET: usize = 256 * 1024;
        let service = PlannerService::with_budget(BUDGET);
        let mut p = small_params();
        p.feasibility_only = false;
        let first = service.plan(&p).unwrap();
        // The valve ran at the end of the request: steady-state bytes fit.
        assert!(
            service.cache_bytes() <= BUDGET,
            "bytes {} over budget {BUDGET}",
            service.cache_bytes()
        );
        let st = service.stats();
        assert!(st.cache_evictions > 0, "a priced sweep must outgrow 256K");
        assert!(st.entries_evicted > 0);
        let tiers = service.caches().tiers();
        let by_name = |n: &str| tiers.iter().find(|t| t.name == n).copied().unwrap();
        assert!(by_name("traces").evictions + by_name("priced_reports").evictions > 0);
        assert_eq!(by_name("walls").evictions, 0, "verified walls are precious");
        assert_eq!(by_name("models").evictions, 0, "fitted models are precious");
        assert_eq!(by_name("time_models").evictions, 0, "step-time models are precious");
        assert!(by_name("walls").entries > 0, "walls survive the valve");
        // Eviction under budget leaves verified walls intact: a warm
        // point query still answers every cell from tier 1, probe-free.
        let (q, _) = service.walls_point(&p, 6 << 20).unwrap();
        assert_eq!(q.probes, 0);
        assert_eq!(q.from_walls, q.cells.len() as u64);
        // And a replayed plan stays byte-identical whether or not its
        // memo entry survived.
        let again = service.plan(&p).unwrap();
        let a = planner_report::plan_result_json(&first.outcome).render();
        let b = planner_report::plan_result_json(&again.outcome).render();
        assert_eq!(a, b);
        // Batch point queries answer tier-by-tier from the same memos.
        let (points, _) = service.walls_batch(&p, &[2 << 20, 4 << 20, 6 << 20]).unwrap();
        assert_eq!(points.len(), 3);
        assert!(points.iter().all(|q| q.probes == 0), "warm batch streams nothing");
    }

    /// Telemetry whose component times are what a `truth` calibration
    /// actually prices for each run shape (so inversion recovers `truth`
    /// exactly — same construction as the `calib::online` tests).
    fn telemetry(truth: &Calibration) -> Vec<Observation> {
        use crate::engine::TimingKernel;
        use crate::schedule::stream_trace_with;
        use crate::util::json::Json;
        let lines = [
            r#"{"method":"ulysses","model":"llama3-8b","gpus":8,"seq":1048576}"#,
            r#"{"method":"upipe","model":"llama3-8b","gpus":8,"seq":1048576}"#,
            r#"{"method":"ring","model":"llama3-8b","gpus":8,"seq":1048576}"#,
        ];
        let mut out = Vec::new();
        for _ in 0..4 {
            for line in lines {
                let mut o = Observation::from_json(&Json::parse(line).unwrap()).unwrap();
                let mut kernel = TimingKernel::new(truth.clone(), 1e18, 0.0, f64::INFINITY);
                stream_trace_with(&o.preset(), truth, &mut kernel);
                let r = kernel.finish();
                assert!(r.failed.is_none() && !r.oom);
                o.attn_fwd = Some(r.components.fa3_fwd);
                o.attn_bwd = Some(r.components.fa3_bwd);
                o.all_to_all = Some(r.components.all_to_all);
                o.other = Some(r.components.other);
                out.push(o);
            }
        }
        out
    }

    #[test]
    fn observe_publishes_epoch_and_invalidates_surgically() {
        let service = PlannerService::new();
        let p = small_params();
        let cold = service.plan(&p).unwrap();
        assert_eq!(cold.epoch, 0);
        assert_eq!(cold.calibration_fingerprint, Calibration::default().fingerprint());
        // A measurements-pinned request warms its own fingerprint's state.
        let text = std::fs::read_to_string(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../examples/table5_measurements.json"
        ))
        .unwrap();
        let mut pinned = small_params();
        pinned.measurements = Some(MeasurementsSource { source: "inline".into(), text });
        let pinned_cold = service.plan(&pinned).unwrap();
        assert_ne!(pinned_cold.calibration_fingerprint, cold.calibration_fingerprint);

        // Sub-threshold drift: samples ingest, nothing publishes, every
        // memo stays warm.
        let mut mild = Calibration::default();
        mild.fa3_fwd_flops *= 1.01;
        let r = service.observe(&telemetry(&mild));
        assert!(r.accepted > 0 && r.published.is_none());
        assert_eq!(r.epoch, 0);
        assert!(r.invalidated.is_empty());
        assert_eq!(service.stats().epochs_published, 0);
        assert!(service.plan(&p).unwrap().memo_hit, "no epoch, memo stays warm");

        // Real drift publishes epoch 1 and invalidates exactly the boot
        // fingerprint's state.
        let mut drifted = Calibration::default();
        drifted.fa3_fwd_flops *= 0.9;
        drifted.fa3_bwd_flops *= 1.1;
        drifted.a2a_eff0_bps *= 0.85;
        drifted.other_rate *= 1.2;
        let r = service.observe(&telemetry(&drifted));
        let published = r.published.expect("drift must cross the publish threshold");
        assert_eq!(r.epoch, 1);
        assert_eq!(published.old_fingerprint, Calibration::default().fingerprint());
        assert_eq!(r.fingerprint, published.new_fingerprint);
        assert!(
            r.invalidated.iter().any(|(name, n)| *name == "walls" && *n > 0),
            "the boot epoch's verified walls must drop: {:?}",
            r.invalidated
        );
        assert_eq!(r.plans_invalidated, 1, "exactly the boot-epoch default plan");
        let st = service.stats();
        assert_eq!(st.epochs_published, 1);
        assert_eq!(st.calibration_epoch, 1);
        assert!(st.entries_invalidated > 0);
        assert!(st.observations_accepted >= r.accepted);

        // The pinned request's state survived: replay is a memo hit on
        // the very same outcome.
        let pinned_again = service.plan(&pinned).unwrap();
        assert!(pinned_again.memo_hit, "pinned measurements are epoch-independent");
        assert!(Arc::ptr_eq(&pinned_again.outcome, &pinned_cold.outcome));
        // The default request recomputes under the new epoch, then warms.
        let fresh = service.plan(&p).unwrap();
        assert!(!fresh.memo_hit, "stale boot-epoch entry must not answer");
        assert_eq!(fresh.epoch, 1);
        assert_eq!(fresh.calibration_fingerprint, r.fingerprint);
        assert!(service.plan(&p).unwrap().memo_hit, "epoch-1 entry memoizes");

        // Provenance chains through the snapshot.
        let snap = service.calibration_snapshot();
        assert_eq!(snap.epoch, 1);
        assert_eq!(snap.history.len(), 1);
        assert_eq!(snap.fingerprint, r.fingerprint);
    }

    #[test]
    fn refit_reply_carries_fingerprint_and_provenance() {
        let text = std::fs::read_to_string(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../examples/table5_measurements.json"
        ))
        .unwrap();
        let service = PlannerService::new();
        let reply = service
            .refit(&RefitParams {
                measurements: MeasurementsSource { source: "inline".into(), text },
            })
            .unwrap();
        assert_eq!(reply.info.model, "llama3-8b");
        assert_ne!(reply.calibration_fingerprint, service.default_calibration_fingerprint());
        assert_eq!(service.stats().refits, 1);
    }
}
