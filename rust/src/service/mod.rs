//! Planner-as-a-service: the session API behind `repro serve-plan` (and,
//! one-shot, behind `repro plan`/`frontier` — the CLI is a thin client of
//! the same type).
//!
//! A [`PlannerService`] owns a [`crate::planner::PlannerCaches`] — the
//! trace cache, streamed-probe and pricing memos, fitted symbolic
//! [`crate::engine::PeakModel`]s and verified context walls — plus a
//! whole-plan memo keyed by the canonical request bytes. Everything is
//! fingerprint-keyed ([`crate::schedule::CellKey`] /
//! [`crate::schedule::FamilyKey`] embed the model dims and calibration),
//! so refit calibrations and different models/clusters never alias, and
//! sharing one session across arbitrary request mixes is always safe.
//!
//! The payoff is the warm path: a repeated identical request is answered
//! from the plan memo (zero streamed probes, zero priced sims,
//! byte-for-byte the cold response), and a point capacity query
//! ([`PlannerService::walls_point`]) against an already-swept family
//! answers from verified walls / fitted polynomials in microseconds —
//! the workload shape long-lived training-infrastructure services
//! (DeepSpeed Ulysses, USP deployments) actually see.
//!
//! [`wire`] defines the versioned JSON protocol, [`http`] the
//! `serve-plan` HTTP/1.1 daemon.

pub mod http;
pub mod wire;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::engine::{Calibration, Measurements, RefitInfo};
use crate::model::ModelDims;
use crate::planner::{plan_with, walls_at, PlanOutcome, PlannerCaches, WallsAtOutcome};
use crate::util::stripe::StripedMap;

pub use wire::{MeasurementsSource, PlanParams, RefitParams, WallsParams, API_VERSION};

/// One plan request's answer: the (possibly memoized) outcome plus the
/// request's deterministic notes. `memo_hit` is observability, never part
/// of the wire result — repeated requests must serialize identically.
pub struct PlanReply {
    pub outcome: Arc<PlanOutcome>,
    pub memo_hit: bool,
    pub warnings: Vec<String>,
}

/// A refit request's answer: the provenance, the fitted calibration's
/// fingerprint (what plan cache keys embed), and deterministic notes.
pub struct RefitReply {
    pub info: RefitInfo,
    pub calibration_fingerprint: u64,
    pub warnings: Vec<String>,
}

/// Snapshot of the session's lifetime counters (surfaced by
/// `/v1/health`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStats {
    pub plan_requests: u64,
    pub plan_memo_hits: u64,
    pub point_queries: u64,
    pub refits: u64,
    /// Streamed kernel probes across all requests (memo hits excluded).
    pub probes_streamed: u64,
    /// Fully priced simulations across all requests (memo hits excluded).
    pub sims_priced: u64,
    /// Times the automatic pressure valve evicted the session caches.
    pub cache_evictions: u64,
}

/// A long-lived planner session: persistent cross-request caches behind
/// typed request/response methods. Thread-safe — the HTTP daemon calls
/// one instance from every worker; interleaved identical and distinct
/// requests return results bitwise-identical to sequential one-shot
/// `plan()` calls (the service-concurrency property test pins this).
/// One memoized plan: the outcome plus the request's deterministic notes
/// (refit provenance), so a memo hit replays both without re-running the
/// refit pipeline.
struct PlanMemoEntry {
    outcome: Arc<PlanOutcome>,
    warnings: Vec<String>,
}

pub struct PlannerService {
    caches: PlannerCaches,
    /// Whole-plan memo keyed by the canonical request bytes — exact for
    /// every field except `measurements`, which keys as a 64-bit content
    /// fingerprint (see `PlanParams::canonical`). A repeated request is
    /// one lookup.
    plans: StripedMap<String, Arc<PlanMemoEntry>>,
    plan_requests: AtomicU64,
    plan_memo_hits: AtomicU64,
    point_queries: AtomicU64,
    refits: AtomicU64,
    probes_streamed: AtomicU64,
    sims_priced: AtomicU64,
    cache_evictions: AtomicU64,
}

/// Automatic pressure-valve bounds: when the session holds more memoized
/// plans or cache entries than this, everything is evicted and the next
/// requests rebuild (correctness is unaffected — only warmth). Keeps a
/// long-lived daemon serving arbitrarily varied request shapes at
/// bounded memory.
const MAX_MEMO_PLANS: usize = 1024;
const MAX_CACHE_ENTRIES: usize = 1 << 20;

impl PlannerService {
    pub fn new() -> Self {
        PlannerService {
            caches: PlannerCaches::new(),
            plans: StripedMap::default(),
            plan_requests: AtomicU64::new(0),
            plan_memo_hits: AtomicU64::new(0),
            point_queries: AtomicU64::new(0),
            refits: AtomicU64::new(0),
            probes_streamed: AtomicU64::new(0),
            sims_priced: AtomicU64::new(0),
            cache_evictions: AtomicU64::new(0),
        }
    }

    /// The automatic pressure valve (see [`MAX_MEMO_PLANS`] /
    /// [`MAX_CACHE_ENTRIES`]): called on the request paths that grow
    /// session state.
    fn pressure_valve(&self) {
        if self.plans.len() > MAX_MEMO_PLANS
            || self.caches.sizes().iter().sum::<usize>() > MAX_CACHE_ENTRIES
        {
            self.cache_evictions.fetch_add(1, Ordering::Relaxed);
            self.clear_caches();
        }
    }

    /// Full sweep (`POST /v1/plan`, and the CLI's `repro plan`). Warm
    /// path: the canonical request bytes hit the plan memo and *nothing*
    /// is re-run — not the sweep, not a refit, not the anchor simulation
    /// (warnings are memoized with the outcome); otherwise the sweep runs
    /// against the session caches, reusing whatever earlier requests left
    /// behind. A memoized key implies the params validated when first
    /// computed, so the hit path skips `to_request` entirely.
    pub fn plan(&self, params: &PlanParams) -> Result<PlanReply, String> {
        self.pressure_valve();
        self.plan_requests.fetch_add(1, Ordering::Relaxed);
        let key = params.canonical().render();
        if let Some(hit) = self.plans.get(&key) {
            self.plan_memo_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(PlanReply {
                outcome: Arc::clone(&hit.outcome),
                memo_hit: true,
                warnings: hit.warnings.clone(),
            });
        }
        let (req, warnings) = params.to_request()?;
        let out = plan_with(&req, &self.caches);
        if out.configs.is_empty() {
            return Err(format!(
                "no valid configurations: the requested sweep dims (tp {:?}, mb {:?}, ac {:?}) \
                 fit neither {} nor the {}-GPU cluster",
                req.dims.tp_degrees,
                req.dims.micro_batches,
                req.dims.ac_modes.iter().map(|a| a.label()).collect::<Vec<_>>(),
                req.model.name,
                req.cluster.total_gpus()
            ));
        }
        self.probes_streamed.fetch_add(out.feasibility_probes, Ordering::Relaxed);
        self.sims_priced.fetch_add(out.priced_sims, Ordering::Relaxed);
        // First writer wins on a racing key; both callers get the
        // canonical entry either way.
        let entry = self
            .plans
            .insert(key, Arc::new(PlanMemoEntry { outcome: Arc::new(out), warnings }));
        Ok(PlanReply {
            outcome: Arc::clone(&entry.outcome),
            memo_hit: false,
            warnings: entry.warnings.clone(),
        })
    }

    /// Walls-only sweep (`POST /v1/walls` without `"at"`): the plan
    /// endpoint with pricing forced off.
    pub fn walls_sweep(&self, params: &PlanParams) -> Result<PlanReply, String> {
        let mut p = params.clone();
        p.feasibility_only = true;
        self.plan(&p)
    }

    /// Point capacity query (`POST /v1/walls` with `"at"`): "is sequence
    /// length `at` trainable?" per sweep configuration, answered from the
    /// session's verified walls / fitted models when warm — zero streamed
    /// probes after any full sweep on the same lattice.
    pub fn walls_point(
        &self,
        params: &PlanParams,
        at: u64,
    ) -> Result<(WallsAtOutcome, Vec<String>), String> {
        self.pressure_valve();
        let (req, warnings) = params.to_request()?;
        self.point_queries.fetch_add(1, Ordering::Relaxed);
        let q = walls_at(&req, at, &self.caches);
        self.probes_streamed.fetch_add(q.probes, Ordering::Relaxed);
        Ok((q, warnings))
    }

    /// Fit a refit calibration from measurements without planning
    /// (`POST /v1/refit`). The model comes from the measurements payload;
    /// the returned fingerprint is what a follow-up plan request carrying
    /// the same measurements will key its caches under.
    pub fn refit(&self, params: &RefitParams) -> Result<RefitReply, String> {
        self.refits.fetch_add(1, Ordering::Relaxed);
        let m = Measurements::parse(&params.measurements.text, &params.measurements.source)?;
        let model = ModelDims::by_name(&m.model)
            .ok_or_else(|| format!("unknown model `{}` in measurements", m.model))?;
        let (cal, info, warnings) = wire::build_refit(&model, &m)?;
        Ok(RefitReply { info, calibration_fingerprint: cal.fingerprint(), warnings })
    }

    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            plan_requests: self.plan_requests.load(Ordering::Relaxed),
            plan_memo_hits: self.plan_memo_hits.load(Ordering::Relaxed),
            point_queries: self.point_queries.load(Ordering::Relaxed),
            refits: self.refits.load(Ordering::Relaxed),
            probes_streamed: self.probes_streamed.load(Ordering::Relaxed),
            sims_priced: self.sims_priced.load(Ordering::Relaxed),
            cache_evictions: self.cache_evictions.load(Ordering::Relaxed),
        }
    }

    /// The session's evaluator caches (observability: `/v1/health` sizes).
    pub fn caches(&self) -> &PlannerCaches {
        &self.caches
    }

    /// Memoized whole-plan count.
    pub fn plan_memo_len(&self) -> usize {
        self.plans.len()
    }

    /// Evict every cache. Invoked automatically by the size-triggered
    /// pressure valve on the daemon's request paths (and callable
    /// directly by embedders); counters keep running, the session stays
    /// usable.
    pub fn clear_caches(&self) {
        self.caches.clear();
        self.plans.clear();
    }

    /// The session's baseline calibration fingerprint (what cache keys
    /// embed for non-refit requests).
    pub fn default_calibration_fingerprint(&self) -> u64 {
        Calibration::default().fingerprint()
    }
}

impl Default for PlannerService {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::planner as planner_report;

    fn small_params() -> PlanParams {
        let mut p = PlanParams::defaults("llama3-8b", 8);
        p.quantum = 1 << 20;
        p.cap_s = 8 << 20;
        p.threads = 2;
        p.feasibility_only = true;
        p
    }

    #[test]
    fn repeated_plan_hits_memo_and_serializes_identically() {
        let service = PlannerService::new();
        let p = small_params();
        let first = service.plan(&p).unwrap();
        assert!(!first.memo_hit);
        let second = service.plan(&p).unwrap();
        assert!(second.memo_hit, "identical request must hit the plan memo");
        assert!(Arc::ptr_eq(&first.outcome, &second.outcome));
        let a = planner_report::plan_result_json(&first.outcome).render();
        let b = planner_report::plan_result_json(&second.outcome).render();
        assert_eq!(a, b);
        let st = service.stats();
        assert_eq!(st.plan_requests, 2);
        assert_eq!(st.plan_memo_hits, 1);
        assert!(st.probes_streamed > 0);
        assert_eq!(st.sims_priced, 0, "feasibility-only sweep never prices");
        // A *different* request (thread count aside) is a distinct key...
        let mut p2 = small_params();
        p2.cap_s = 4 << 20;
        assert!(!service.plan(&p2).unwrap().memo_hit);
        // ...but a thread-count variant is not.
        let mut p3 = small_params();
        p3.threads = 1;
        assert!(service.plan(&p3).unwrap().memo_hit);
    }

    #[test]
    fn frontier_and_walls_share_the_session() {
        let service = PlannerService::new();
        let mut p = small_params();
        p.feasibility_only = false;
        let probes_cold = {
            let reply = service.plan(&p).unwrap();
            assert!(reply.outcome.configs.iter().any(|c| c.pareto));
            service.stats().probes_streamed
        };
        // The walls sweep reuses the session's verified walls: no new
        // streamed probes at all.
        let walls = service.walls_sweep(&p).unwrap();
        assert!(walls.outcome.feasibility_only);
        assert!(!walls.memo_hit, "different canonical request");
        assert_eq!(service.stats().probes_streamed, probes_cold);
        // Warm point query: zero probes, every cell from a verified wall.
        let (q, _) = service.walls_point(&p, 6 << 20).unwrap();
        assert_eq!(q.probes, 0);
        assert_eq!(q.from_walls, q.cells.len() as u64);
        assert_eq!(service.stats().point_queries, 1);
        // Eviction keeps the session usable.
        service.clear_caches();
        assert_eq!(service.plan_memo_len(), 0);
        let again = service.plan(&p).unwrap();
        assert!(!again.memo_hit);
    }

    #[test]
    fn service_errors_are_typed_strings() {
        let service = PlannerService::new();
        let mut p = small_params();
        p.model = "nope".into();
        let err = service.plan(&p).unwrap_err();
        assert!(err.contains("unknown model"), "{err}");
        let mut p = small_params();
        p.gpus = 12; // not 1..=8 and not a whole number of 8-GPU nodes
        assert!(service.plan(&p).is_err());
        let bad = RefitParams {
            measurements: MeasurementsSource { source: "t".into(), text: "{]".into() },
        };
        assert!(service.refit(&bad).is_err());
    }

    #[test]
    fn refit_reply_carries_fingerprint_and_provenance() {
        let text = std::fs::read_to_string(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../examples/table5_measurements.json"
        ))
        .unwrap();
        let service = PlannerService::new();
        let reply = service
            .refit(&RefitParams {
                measurements: MeasurementsSource { source: "inline".into(), text },
            })
            .unwrap();
        assert_eq!(reply.info.model, "llama3-8b");
        assert_ne!(reply.calibration_fingerprint, service.default_calibration_fingerprint());
        assert_eq!(service.stats().refits, 1);
    }
}
