//! Planner-as-a-service: the session API behind `repro serve-plan` (and,
//! one-shot, behind `repro plan`/`frontier` — the CLI is a thin client of
//! the same type).
//!
//! A [`PlannerService`] owns a [`crate::planner::PlannerCaches`] — the
//! trace cache, streamed-probe and pricing memos, fitted symbolic
//! [`crate::engine::PeakModel`]s and verified context walls — plus a
//! whole-plan memo keyed by the canonical request bytes. Everything is
//! fingerprint-keyed ([`crate::schedule::CellKey`] /
//! [`crate::schedule::FamilyKey`] embed the model dims and calibration),
//! so refit calibrations and different models/clusters never alias, and
//! sharing one session across arbitrary request mixes is always safe.
//!
//! The payoff is the warm path: a repeated identical request is answered
//! from the plan memo (zero streamed probes, zero priced sims,
//! byte-for-byte the cold response), and a point capacity query
//! ([`PlannerService::walls_point`]) against an already-swept family
//! answers from verified walls / fitted polynomials in microseconds —
//! the workload shape long-lived training-infrastructure services
//! (DeepSpeed Ulysses, USP deployments) actually see.
//!
//! [`wire`] defines the versioned JSON protocol, [`http`] the
//! `serve-plan` HTTP/1.1 daemon.

pub mod http;
pub mod wire;

use std::collections::HashMap;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::engine::{Calibration, Measurements, RefitInfo};
use crate::model::ModelDims;
use crate::planner::{
    place_with, plan_with, walls_at, PlacementOutcome, PlanOutcome, PlannerCaches, WallsAtOutcome,
};
use crate::util::cancel::CancelToken;
use crate::util::failpoint;
use crate::util::stripe::StripedMap;

pub use wire::{
    MeasurementsSource, PlacementParams, PlanParams, RefitParams, WallsParams, API_VERSION,
};

/// Typed service failure: what went wrong, in a shape the HTTP layer can
/// map to a status code (400 / 504 / 503 / 500) and the CLI can print.
/// `Display` renders the same human-readable strings the service has
/// always returned, so error text stays wire-stable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServiceError {
    /// The request could not be validated or evaluated (the historical
    /// `Err(String)` paths, verbatim).
    BadRequest(String),
    /// The request's deadline expired mid-evaluation. Carries partial
    /// accounting — the work the request ran before expiry — and
    /// guarantees nothing reached any memo tier after the deadline
    /// passed (inserts are all-or-nothing per tier).
    DeadlineExceeded { probes_streamed: u64, sims_priced: u64, prices_modeled: u64 },
    /// A prior evaluation of this exact request panicked; the cell is
    /// tombstoned. Retry after the bounded backoff instead of poisoning
    /// a worker again.
    Quarantined { retry_after_s: u64 },
    /// A service-boundary failure (e.g. an injected memo-insert fault):
    /// the request computed but could not publish; nothing partial was
    /// left behind.
    Internal(String),
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::BadRequest(m) | ServiceError::Internal(m) => f.write_str(m),
            ServiceError::DeadlineExceeded { probes_streamed, sims_priced, prices_modeled } => {
                write!(
                    f,
                    "deadline exceeded before evaluation finished \
                     (ran {probes_streamed} probes, {sims_priced} priced sims, \
                     {prices_modeled} modeled prices; no partial state was published)"
                )
            }
            ServiceError::Quarantined { retry_after_s } => write!(
                f,
                "request is quarantined after a prior evaluation panic; \
                 retry after {retry_after_s}s"
            ),
        }
    }
}

impl std::error::Error for ServiceError {}

impl From<String> for ServiceError {
    fn from(m: String) -> Self {
        ServiceError::BadRequest(m)
    }
}

/// A quarantined cell's tombstone: requests for this canonical key are
/// refused until `until`, with exponentially growing (bounded) backoff
/// per consecutive panic.
struct Tombstone {
    until: Instant,
    strikes: u32,
}

/// One plan request's answer: the (possibly memoized) outcome plus the
/// request's deterministic notes. `memo_hit` is observability, never part
/// of the wire result — repeated requests must serialize identically.
pub struct PlanReply {
    pub outcome: Arc<PlanOutcome>,
    pub memo_hit: bool,
    pub warnings: Vec<String>,
}

/// A placement request's answer: the (possibly memoized) fleet-wide
/// outcome plus the request's deterministic notes.
pub struct PlacementReply {
    pub outcome: Arc<PlacementOutcome>,
    pub memo_hit: bool,
    pub warnings: Vec<String>,
}

/// A refit request's answer: the provenance, the fitted calibration's
/// fingerprint (what plan cache keys embed), and deterministic notes.
pub struct RefitReply {
    pub info: RefitInfo,
    pub calibration_fingerprint: u64,
    pub warnings: Vec<String>,
}

/// Snapshot of the session's lifetime counters (surfaced by
/// `/v1/health`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStats {
    pub plan_requests: u64,
    pub plan_memo_hits: u64,
    pub placement_requests: u64,
    pub placement_memo_hits: u64,
    /// Fleet shapes skipped before any probe by dominance pruning,
    /// summed across placement requests (memo hits excluded).
    pub shapes_pruned: u64,
    pub point_queries: u64,
    pub refits: u64,
    /// Streamed kernel probes across all requests (memo hits excluded).
    pub probes_streamed: u64,
    /// Fully priced simulations across all requests (memo hits excluded).
    pub sims_priced: u64,
    /// Streamed timing-kernel prices across all requests (memo hits
    /// excluded) — phase-2 cells answered without a full simulation.
    pub prices_modeled: u64,
    /// Times the byte-budget valve ran and evicted at least one entry.
    pub cache_evictions: u64,
    /// Total entries dropped by the valve across every tier.
    pub entries_evicted: u64,
    /// Canonical request cells currently tombstoned after an evaluation
    /// panic (active quarantine entries at snapshot time).
    pub cells_quarantined: u64,
}

/// A long-lived planner session: persistent cross-request caches behind
/// typed request/response methods. Thread-safe — the HTTP daemon calls
/// one instance from every worker; interleaved identical and distinct
/// requests return results bitwise-identical to sequential one-shot
/// `plan()` calls (the service-concurrency property test pins this).
/// One memoized plan: the outcome plus the request's deterministic notes
/// (refit provenance), so a memo hit replays both without re-running the
/// refit pipeline.
struct PlanMemoEntry {
    outcome: Arc<PlanOutcome>,
    warnings: Vec<String>,
}

/// One memoized placement, mirroring [`PlanMemoEntry`].
struct PlacementMemoEntry {
    outcome: Arc<PlacementOutcome>,
    warnings: Vec<String>,
}

pub struct PlannerService {
    caches: PlannerCaches,
    /// Whole-plan memo keyed by the canonical request bytes — exact for
    /// every field except `measurements`, which keys as a 64-bit content
    /// fingerprint (see `PlanParams::canonical`). A repeated request is
    /// one lookup.
    plans: StripedMap<String, Arc<PlanMemoEntry>>,
    /// Whole-placement memo, keyed like `plans` by canonical request
    /// bytes (which embed the fleet's canonical form).
    placements: StripedMap<String, Arc<PlacementMemoEntry>>,
    /// Byte budget for every cache tier combined (`usize::MAX` =
    /// unbounded); see [`PlannerService::enforce_budget`].
    cache_budget: usize,
    /// Server-wide evaluation deadline applied to every request (`None`
    /// = unbounded). A per-request `deadline_ms` tightens but never
    /// loosens this.
    request_timeout: Option<Duration>,
    /// Panic tombstones keyed by canonical request bytes: a cell whose
    /// evaluation panicked answers `Quarantined` (bounded retry-after)
    /// instead of poisoning another worker, until its tombstone lapses.
    quarantine: Mutex<HashMap<String, Tombstone>>,
    plan_requests: AtomicU64,
    plan_memo_hits: AtomicU64,
    placement_requests: AtomicU64,
    placement_memo_hits: AtomicU64,
    shapes_pruned: AtomicU64,
    point_queries: AtomicU64,
    refits: AtomicU64,
    probes_streamed: AtomicU64,
    sims_priced: AtomicU64,
    prices_modeled: AtomicU64,
    cache_evictions: AtomicU64,
    entries_evicted: AtomicU64,
}

/// Default byte budget for the session caches (all tiers plus the plan
/// memo): 1 GiB. Keeps a long-lived daemon serving arbitrarily varied
/// request shapes at bounded memory; the `repro serve-plan` CLI overrides
/// it with `--cache-budget`.
pub const DEFAULT_CACHE_BUDGET: usize = 1 << 30;

/// Ceiling on a quarantine tombstone's retry-after: backoff doubles per
/// consecutive panic (1s, 2s, 4s, ...) but never exceeds this.
pub const MAX_QUARANTINE_SECS: u64 = 60;

impl PlannerService {
    pub fn new() -> Self {
        Self::with_budget(DEFAULT_CACHE_BUDGET)
    }

    /// A session whose caches are evicted down to `cache_budget` bytes at
    /// the end of each state-growing request (`usize::MAX` = unbounded).
    pub fn with_budget(cache_budget: usize) -> Self {
        PlannerService {
            caches: PlannerCaches::new(),
            plans: StripedMap::default(),
            placements: StripedMap::default(),
            cache_budget,
            request_timeout: None,
            quarantine: Mutex::new(HashMap::new()),
            plan_requests: AtomicU64::new(0),
            plan_memo_hits: AtomicU64::new(0),
            placement_requests: AtomicU64::new(0),
            placement_memo_hits: AtomicU64::new(0),
            shapes_pruned: AtomicU64::new(0),
            point_queries: AtomicU64::new(0),
            refits: AtomicU64::new(0),
            probes_streamed: AtomicU64::new(0),
            sims_priced: AtomicU64::new(0),
            prices_modeled: AtomicU64::new(0),
            cache_evictions: AtomicU64::new(0),
            entries_evicted: AtomicU64::new(0),
        }
    }

    /// Apply a server-wide evaluation deadline to every subsequent
    /// request (`None` = unbounded). The `repro serve-plan` CLI wires
    /// `--request-timeout` through this.
    pub fn with_request_timeout(mut self, timeout: Option<Duration>) -> Self {
        self.request_timeout = timeout;
        self
    }

    /// The cancel token for one request: the tighter of the server-wide
    /// timeout and the request's own `deadline_ms`.
    fn token_for(&self, deadline_ms: Option<u64>) -> CancelToken {
        let server = match self.request_timeout {
            Some(t) => CancelToken::with_deadline(t),
            None => CancelToken::none(),
        };
        let client = match deadline_ms {
            Some(ms) => CancelToken::with_deadline(Duration::from_millis(ms)),
            None => CancelToken::none(),
        };
        CancelToken::earliest(server, client)
    }

    /// Refuse a request whose canonical cell carries an active panic
    /// tombstone. A lapsed tombstone lets the retry through (strikes are
    /// kept, so a repeat panic backs off longer).
    fn quarantine_check(&self, key: &str) -> Result<(), ServiceError> {
        let q = self.quarantine.lock().unwrap();
        if let Some(t) = q.get(key) {
            let now = Instant::now();
            if now < t.until {
                let retry_after_s = (t.until - now).as_secs() + 1;
                return Err(ServiceError::Quarantined { retry_after_s });
            }
        }
        Ok(())
    }

    /// Record an evaluation panic for `key`: backoff doubles per
    /// consecutive strike, bounded at [`MAX_QUARANTINE_SECS`].
    fn quarantine_strike(&self, key: &str) {
        let mut q = self.quarantine.lock().unwrap();
        let now = Instant::now();
        let t = q.entry(key.to_string()).or_insert(Tombstone { until: now, strikes: 0 });
        t.strikes = t.strikes.saturating_add(1);
        let secs = if t.strikes >= 7 {
            MAX_QUARANTINE_SECS
        } else {
            (1u64 << (t.strikes - 1)).min(MAX_QUARANTINE_SECS)
        };
        t.until = now + Duration::from_secs(secs);
    }

    /// A clean recompute heals the cell: drop its tombstone (and strike
    /// history) entirely.
    fn quarantine_clear(&self, key: &str) {
        self.quarantine.lock().unwrap().remove(key);
    }

    /// Active panic tombstones right now (surfaced by `/v1/health` as
    /// `cells_quarantined`).
    pub fn cells_quarantined(&self) -> u64 {
        let now = Instant::now();
        self.quarantine.lock().unwrap().values().filter(|t| t.until > now).count() as u64
    }

    /// The size-aware pressure valve, called at the end of every request
    /// that grows session state: evicts least-recently-used entries,
    /// tier by tier, until the total footprint fits the budget again.
    /// Order — trace cache (dominant footprint, cheap rebuild) first,
    /// then priced reports, budgeted probes, peak probes, then the
    /// whole-plan memo; fitted models and verified walls are tiny,
    /// expensive-to-refit tiers evicted only if everything else is
    /// already gone. Mid-request the footprint may transiently exceed
    /// the budget (a cold sweep fills its caches before the valve runs);
    /// the budget is the steady-state bound between requests.
    fn enforce_budget(&self) {
        let budget = self.cache_budget;
        let memos = |s: &Self| s.plans.bytes() + s.placements.bytes();
        if self.caches.bytes() + memos(self) <= budget {
            return;
        }
        let mut dropped = self.caches.evict_bulk_to_fit(budget, memos(self));
        if self.caches.bytes() + memos(self) > budget {
            let keep = budget.saturating_sub(self.caches.bytes() + self.placements.bytes());
            dropped += self.plans.evict_lru(keep);
        }
        if self.caches.bytes() + memos(self) > budget {
            let keep = budget.saturating_sub(self.caches.bytes() + self.plans.bytes());
            dropped += self.placements.evict_lru(keep);
        }
        if self.caches.bytes() + memos(self) > budget {
            dropped += self.caches.evict_precious_to_fit(budget, memos(self));
        }
        if dropped > 0 {
            self.cache_evictions.fetch_add(1, Ordering::Relaxed);
            self.entries_evicted.fetch_add(dropped, Ordering::Relaxed);
        }
    }

    /// Full sweep (`POST /v1/plan`, and the CLI's `repro plan`). Warm
    /// path: the canonical request bytes hit the plan memo and *nothing*
    /// is re-run — not the sweep, not a refit, not the anchor simulation
    /// (warnings are memoized with the outcome); otherwise the sweep runs
    /// against the session caches, reusing whatever earlier requests left
    /// behind. A memoized key implies the params validated when first
    /// computed, so the hit path skips `to_request` entirely.
    ///
    /// Failure modes beyond validation: an expired deadline answers
    /// [`ServiceError::DeadlineExceeded`] *before* any counter or memo
    /// insert for the partial work (the evaluator already refused to
    /// publish to its tiers); an evaluation panic records a quarantine
    /// strike and re-raises, so the caller's firewall sees the original
    /// panic while subsequent identical requests get
    /// [`ServiceError::Quarantined`] until the tombstone lapses.
    pub fn plan(&self, params: &PlanParams) -> Result<PlanReply, ServiceError> {
        self.plan_requests.fetch_add(1, Ordering::Relaxed);
        let key = params.canonical().render();
        if let Some(hit) = self.plans.get(&key) {
            self.plan_memo_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(PlanReply {
                outcome: Arc::clone(&hit.outcome),
                memo_hit: true,
                warnings: hit.warnings.clone(),
            });
        }
        self.quarantine_check(&key)?;
        let (mut req, warnings) = params.to_request()?;
        req.cancel = self.token_for(params.deadline_ms);
        let out = match catch_unwind(AssertUnwindSafe(|| plan_with(&req, &self.caches))) {
            Ok(out) => out,
            Err(payload) => {
                self.quarantine_strike(&key);
                resume_unwind(payload);
            }
        };
        self.quarantine_clear(&key);
        if out.cancelled {
            // Cells evaluated *before* expiry did publish to their tiers
            // (they were complete); run the valve so the budget invariant
            // holds, then answer with partial accounting only.
            self.enforce_budget();
            return Err(ServiceError::DeadlineExceeded {
                probes_streamed: out.feasibility_probes,
                sims_priced: out.priced_sims,
                prices_modeled: out.modeled_prices,
            });
        }
        if out.configs.is_empty() {
            return Err(ServiceError::BadRequest(format!(
                "no valid configurations: the requested sweep dims (tp {:?}, mb {:?}, ac {:?}) \
                 fit neither {} nor the {}-GPU cluster",
                req.dims.tp_degrees,
                req.dims.micro_batches,
                req.dims.ac_modes.iter().map(|a| a.label()).collect::<Vec<_>>(),
                req.model.name,
                req.cluster.total_gpus()
            )));
        }
        self.probes_streamed.fetch_add(out.feasibility_probes, Ordering::Relaxed);
        self.sims_priced.fetch_add(out.priced_sims, Ordering::Relaxed);
        self.prices_modeled.fetch_add(out.modeled_prices, Ordering::Relaxed);
        if let Err(e) = failpoint::fire("service.memo_insert") {
            // The sweep ran but the answer cannot publish: keep the memo
            // all-or-nothing (no entry at all) and still run the valve so
            // the budget invariant holds between requests.
            self.enforce_budget();
            return Err(ServiceError::Internal(e));
        }
        // First writer wins on a racing key; both callers get the
        // canonical entry either way. The entry's weight is its heap
        // payload: the key bytes, the per-config rows, and the notes.
        let payload = key.len()
            + out.configs.len() * std::mem::size_of::<crate::planner::ConfigPlan>()
            + warnings.iter().map(String::len).sum::<usize>();
        let entry = self.plans.insert_weighed(
            key,
            Arc::new(PlanMemoEntry { outcome: Arc::new(out), warnings }),
            payload,
        );
        let reply = PlanReply {
            outcome: Arc::clone(&entry.outcome),
            memo_hit: false,
            warnings: entry.warnings.clone(),
        };
        self.enforce_budget();
        Ok(reply)
    }

    /// Fleet placement sweep (`POST /v1/placement`, and the CLI's
    /// `repro place`). Memoized like [`PlannerService::plan`] on the
    /// canonical request bytes — a warm replay returns the identical
    /// outcome without enumerating a single shape. On a miss the
    /// evaluator runs against the session caches, so model fits laid
    /// down by earlier plan or placement requests on the same hardware
    /// are reused across requests, not just across shapes.
    pub fn place(&self, params: &PlacementParams) -> Result<PlacementReply, ServiceError> {
        self.placement_requests.fetch_add(1, Ordering::Relaxed);
        let key = params.canonical().render();
        if let Some(hit) = self.placements.get(&key) {
            self.placement_memo_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(PlacementReply {
                outcome: Arc::clone(&hit.outcome),
                memo_hit: true,
                warnings: hit.warnings.clone(),
            });
        }
        self.quarantine_check(&key)?;
        let (mut req, warnings) = params.to_request()?;
        req.cancel = self.token_for(params.plan.deadline_ms);
        let out = match catch_unwind(AssertUnwindSafe(|| place_with(&req, &self.caches))) {
            Ok(out) => out,
            Err(payload) => {
                self.quarantine_strike(&key);
                resume_unwind(payload);
            }
        };
        self.quarantine_clear(&key);
        if out.cancelled {
            self.enforce_budget();
            return Err(ServiceError::DeadlineExceeded {
                probes_streamed: out.feasibility_probes,
                sims_priced: out.anchor_sims,
                prices_modeled: out.modeled_prices,
            });
        }
        if out.placements.iter().all(|sp| sp.plan.as_ref().map_or(true, |p| p.configs.is_empty())) {
            return Err(ServiceError::BadRequest(format!(
                "no valid configurations on any fleet shape: the requested sweep dims \
                 (tp {:?}, mb {:?}) fit {} on none of the {} candidate shapes",
                req.dims.tp_degrees,
                req.dims.micro_batches,
                req.model.name,
                out.shapes_total
            )));
        }
        self.probes_streamed.fetch_add(out.feasibility_probes, Ordering::Relaxed);
        self.sims_priced.fetch_add(out.anchor_sims, Ordering::Relaxed);
        self.prices_modeled.fetch_add(out.modeled_prices, Ordering::Relaxed);
        self.shapes_pruned.fetch_add(out.shapes_pruned, Ordering::Relaxed);
        let rows: usize = out
            .placements
            .iter()
            .filter_map(|sp| sp.plan.as_ref())
            .map(|p| p.configs.len())
            .sum();
        let payload = key.len()
            + rows * std::mem::size_of::<crate::planner::ConfigPlan>()
            + warnings.iter().map(String::len).sum::<usize>();
        if let Err(e) = failpoint::fire("service.memo_insert") {
            self.enforce_budget();
            return Err(ServiceError::Internal(e));
        }
        let entry = self.placements.insert_weighed(
            key,
            Arc::new(PlacementMemoEntry { outcome: Arc::new(out), warnings }),
            payload,
        );
        let reply = PlacementReply {
            outcome: Arc::clone(&entry.outcome),
            memo_hit: false,
            warnings: entry.warnings.clone(),
        };
        self.enforce_budget();
        Ok(reply)
    }

    /// Walls-only sweep (`POST /v1/walls` without `"at"`): the plan
    /// endpoint with pricing forced off.
    pub fn walls_sweep(&self, params: &PlanParams) -> Result<PlanReply, ServiceError> {
        let mut p = params.clone();
        p.feasibility_only = true;
        self.plan(&p)
    }

    /// Point capacity query (`POST /v1/walls` with a single `"at"`): "is
    /// sequence length `at` trainable?" per sweep configuration, answered
    /// from the session's verified walls / fitted models when warm — zero
    /// streamed probes after any full sweep on the same lattice.
    pub fn walls_point(
        &self,
        params: &PlanParams,
        at: u64,
    ) -> Result<(WallsAtOutcome, Vec<String>), ServiceError> {
        let (mut outs, warnings) = self.walls_batch(params, &[at])?;
        Ok((outs.pop().expect("one point per query"), warnings))
    }

    /// Batch point capacity query (`POST /v1/walls` with `"at": [...]`):
    /// one validated request, one response carrying a full capacity curve
    /// — each point answered independently, tier by tier, from the same
    /// memos a single-point query consults (so a dashboard's sweep is as
    /// warm as its hottest point).
    pub fn walls_batch(
        &self,
        params: &PlanParams,
        ats: &[u64],
    ) -> Result<(Vec<WallsAtOutcome>, Vec<String>), ServiceError> {
        let (mut req, warnings) = params.to_request()?;
        req.cancel = self.token_for(params.deadline_ms);
        let plan_key = params.canonical().render();
        let mut outs = Vec::with_capacity(ats.len());
        let mut probes_before_expiry = 0u64;
        for &at in ats {
            self.point_queries.fetch_add(1, Ordering::Relaxed);
            // Each point quarantines independently: a panic at one
            // sequence length must not fence off the whole curve.
            let key = format!("{plan_key}@{at}");
            self.quarantine_check(&key)?;
            let q = match catch_unwind(AssertUnwindSafe(|| walls_at(&req, at, &self.caches))) {
                Ok(q) => q,
                Err(payload) => {
                    self.quarantine_strike(&key);
                    resume_unwind(payload);
                }
            };
            self.quarantine_clear(&key);
            if q.cancelled {
                self.enforce_budget();
                return Err(ServiceError::DeadlineExceeded {
                    probes_streamed: probes_before_expiry + q.probes,
                    sims_priced: 0,
                    prices_modeled: 0,
                });
            }
            probes_before_expiry += q.probes;
            self.probes_streamed.fetch_add(q.probes, Ordering::Relaxed);
            outs.push(q);
        }
        self.enforce_budget();
        Ok((outs, warnings))
    }

    /// Fit a refit calibration from measurements without planning
    /// (`POST /v1/refit`). The model comes from the measurements payload;
    /// the returned fingerprint is what a follow-up plan request carrying
    /// the same measurements will key its caches under.
    pub fn refit(&self, params: &RefitParams) -> Result<RefitReply, ServiceError> {
        self.refits.fetch_add(1, Ordering::Relaxed);
        let m = Measurements::parse(&params.measurements.text, &params.measurements.source)?;
        let model = ModelDims::by_name(&m.model)
            .ok_or_else(|| format!("unknown model `{}` in measurements", m.model))?;
        let (cal, info, warnings) = wire::build_refit(&model, &m)?;
        Ok(RefitReply { info, calibration_fingerprint: cal.fingerprint(), warnings })
    }

    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            plan_requests: self.plan_requests.load(Ordering::Relaxed),
            plan_memo_hits: self.plan_memo_hits.load(Ordering::Relaxed),
            placement_requests: self.placement_requests.load(Ordering::Relaxed),
            placement_memo_hits: self.placement_memo_hits.load(Ordering::Relaxed),
            shapes_pruned: self.shapes_pruned.load(Ordering::Relaxed),
            point_queries: self.point_queries.load(Ordering::Relaxed),
            refits: self.refits.load(Ordering::Relaxed),
            probes_streamed: self.probes_streamed.load(Ordering::Relaxed),
            sims_priced: self.sims_priced.load(Ordering::Relaxed),
            prices_modeled: self.prices_modeled.load(Ordering::Relaxed),
            cache_evictions: self.cache_evictions.load(Ordering::Relaxed),
            entries_evicted: self.entries_evicted.load(Ordering::Relaxed),
            cells_quarantined: self.cells_quarantined(),
        }
    }

    /// The session's evaluator caches (observability: `/v1/health` sizes).
    pub fn caches(&self) -> &PlannerCaches {
        &self.caches
    }

    /// Memoized whole-plan count.
    pub fn plan_memo_len(&self) -> usize {
        self.plans.len()
    }

    /// Approximate resident bytes of the whole-plan memo.
    pub fn plan_memo_bytes(&self) -> usize {
        self.plans.bytes()
    }

    /// Entries the valve has dropped from the whole-plan memo.
    pub fn plan_memo_evictions(&self) -> u64 {
        self.plans.evicted()
    }

    /// Memoized whole-placement count.
    pub fn placement_memo_len(&self) -> usize {
        self.placements.len()
    }

    /// Approximate resident bytes of the whole-placement memo.
    pub fn placement_memo_bytes(&self) -> usize {
        self.placements.bytes()
    }

    /// Entries the valve has dropped from the whole-placement memo.
    pub fn placement_memo_evictions(&self) -> u64 {
        self.placements.evicted()
    }

    /// Approximate resident bytes across every tier plus the plan and
    /// placement memos — the quantity [`PlannerService::cache_budget`]
    /// bounds between requests.
    pub fn cache_bytes(&self) -> usize {
        self.caches.bytes() + self.plans.bytes() + self.placements.bytes()
    }

    /// The configured byte budget (`usize::MAX` = unbounded).
    pub fn cache_budget(&self) -> usize {
        self.cache_budget
    }

    /// Evict every cache. Invoked automatically by the size-triggered
    /// pressure valve on the daemon's request paths (and callable
    /// directly by embedders); counters keep running, the session stays
    /// usable.
    pub fn clear_caches(&self) {
        self.caches.clear();
        self.plans.clear();
        self.placements.clear();
    }

    /// The session's baseline calibration fingerprint (what cache keys
    /// embed for non-refit requests).
    pub fn default_calibration_fingerprint(&self) -> u64 {
        Calibration::default().fingerprint()
    }
}

impl Default for PlannerService {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::planner as planner_report;

    fn small_params() -> PlanParams {
        let mut p = PlanParams::defaults("llama3-8b", 8);
        p.quantum = 1 << 20;
        p.cap_s = 8 << 20;
        p.threads = 2;
        p.feasibility_only = true;
        p
    }

    #[test]
    fn repeated_plan_hits_memo_and_serializes_identically() {
        let service = PlannerService::new();
        let p = small_params();
        let first = service.plan(&p).unwrap();
        assert!(!first.memo_hit);
        let second = service.plan(&p).unwrap();
        assert!(second.memo_hit, "identical request must hit the plan memo");
        assert!(Arc::ptr_eq(&first.outcome, &second.outcome));
        let a = planner_report::plan_result_json(&first.outcome).render();
        let b = planner_report::plan_result_json(&second.outcome).render();
        assert_eq!(a, b);
        let st = service.stats();
        assert_eq!(st.plan_requests, 2);
        assert_eq!(st.plan_memo_hits, 1);
        assert!(st.probes_streamed > 0);
        assert_eq!(st.sims_priced, 0, "feasibility-only sweep never prices");
        // A *different* request (thread count aside) is a distinct key...
        let mut p2 = small_params();
        p2.cap_s = 4 << 20;
        assert!(!service.plan(&p2).unwrap().memo_hit);
        // ...but a thread-count variant is not.
        let mut p3 = small_params();
        p3.threads = 1;
        assert!(service.plan(&p3).unwrap().memo_hit);
    }

    #[test]
    fn frontier_and_walls_share_the_session() {
        let service = PlannerService::new();
        let mut p = small_params();
        p.feasibility_only = false;
        let probes_cold = {
            let reply = service.plan(&p).unwrap();
            assert!(reply.outcome.configs.iter().any(|c| c.pareto));
            service.stats().probes_streamed
        };
        // The walls sweep reuses the session's verified walls: no new
        // streamed probes at all.
        let walls = service.walls_sweep(&p).unwrap();
        assert!(walls.outcome.feasibility_only);
        assert!(!walls.memo_hit, "different canonical request");
        assert_eq!(service.stats().probes_streamed, probes_cold);
        // Warm point query: zero probes, every cell from a verified wall.
        let (q, _) = service.walls_point(&p, 6 << 20).unwrap();
        assert_eq!(q.probes, 0);
        assert_eq!(q.from_walls, q.cells.len() as u64);
        assert_eq!(service.stats().point_queries, 1);
        // Eviction keeps the session usable.
        service.clear_caches();
        assert_eq!(service.plan_memo_len(), 0);
        let again = service.plan(&p).unwrap();
        assert!(!again.memo_hit);
    }

    #[test]
    fn placement_requests_memoize_and_replay_byte_identically() {
        use crate::util::json::Json;
        let service = PlannerService::new();
        let body = r#"{"model":"llama3-8b","paper":true,"quantum":"1M","cap":"8M","threads":1,
            "fleet":{"pools":[{"name":"east","device":"h100","nodes":1},
                              {"name":"lab","device":"h200","nodes":1}]}}"#;
        let p = PlacementParams::from_json(&Json::parse(body).unwrap()).unwrap();
        let first = service.place(&p).unwrap();
        assert!(!first.memo_hit);
        assert_eq!(first.outcome.shapes_pruned, 1, "east/1x8 is dominated by the H200 pool");
        let second = service.place(&p).unwrap();
        assert!(second.memo_hit, "identical request must hit the placement memo");
        assert!(Arc::ptr_eq(&first.outcome, &second.outcome));
        let a = planner_report::placement_result_json(&first.outcome).render();
        assert_eq!(a, planner_report::placement_result_json(&second.outcome).render());
        let st = service.stats();
        assert_eq!(st.placement_requests, 2);
        assert_eq!(st.placement_memo_hits, 1);
        assert_eq!(st.shapes_pruned, 1, "memo hits do not re-count pruning");
        assert_eq!(st.plan_requests, 0, "placement does not ride the plan path");
        assert!(st.probes_streamed > 0);
        assert_eq!(service.placement_memo_len(), 1);
        assert!(service.placement_memo_bytes() > 0);
        // Eviction keeps the session usable, and a cold re-run of the
        // same request serializes to the same bytes.
        service.clear_caches();
        assert_eq!(service.placement_memo_len(), 0);
        let again = service.place(&p).unwrap();
        assert!(!again.memo_hit);
        assert_eq!(planner_report::placement_result_json(&again.outcome).render(), a);
    }

    #[test]
    fn service_errors_are_typed() {
        let service = PlannerService::new();
        let mut p = small_params();
        p.model = "nope".into();
        let err = service.plan(&p).unwrap_err();
        assert!(matches!(err, ServiceError::BadRequest(_)), "{err}");
        assert!(err.to_string().contains("unknown model"), "{err}");
        let mut p = small_params();
        p.gpus = 12; // not 1..=8 and not a whole number of 8-GPU nodes
        assert!(service.plan(&p).is_err());
        let bad = RefitParams {
            measurements: MeasurementsSource { source: "t".into(), text: "{]".into() },
        };
        assert!(matches!(service.refit(&bad).unwrap_err(), ServiceError::BadRequest(_)));
    }

    #[test]
    fn expired_deadline_answers_504_and_publishes_nothing() {
        let service = PlannerService::new();
        let mut p = small_params();
        // deadline_ms: 0 is the deterministic already-expired token — the
        // evaluator answers placeholders for every cell and publishes to
        // no tier.
        p.deadline_ms = Some(0);
        let err = service.plan(&p).unwrap_err();
        assert!(
            matches!(err, ServiceError::DeadlineExceeded { probes_streamed: 0, .. }),
            "an instantly expired sweep runs zero probes: {err}"
        );
        assert_eq!(service.plan_memo_len(), 0, "cancelled request must not memoize");
        let walls_entries = service
            .caches()
            .tiers()
            .iter()
            .find(|t| t.name == "walls")
            .map_or(0, |t| t.entries);
        assert_eq!(walls_entries, 0, "cancelled request must not publish verified walls");
        // The identical request (deadline_ms is outside the canonical
        // key) recomputes cold — no partial state survived — then warms.
        p.deadline_ms = None;
        assert!(!service.plan(&p).unwrap().memo_hit, "no partial state may satisfy a retry");
        assert!(service.plan(&p).unwrap().memo_hit);
        // Batch point queries cancel the same way, publishing nothing.
        let mut p = small_params();
        p.deadline_ms = Some(0);
        let err = service.walls_batch(&p, &[1 << 20, 2 << 20]).unwrap_err();
        assert!(matches!(err, ServiceError::DeadlineExceeded { .. }), "{err}");
    }

    // Consumable-failpoint tests (panic quarantine, memo-insert fault)
    // live in `tests/service_faults.rs`: arming `panic(1)`/`err(1)` on a
    // production site is process-global, and a concurrent unrelated
    // sweep in this binary could consume the charge.

    #[test]
    fn budget_evicts_bulk_tiers_but_never_walls_or_models() {
        // A budget far below one priced sweep's trace/report footprint,
        // but comfortably above the precious tiers' floor.
        const BUDGET: usize = 256 * 1024;
        let service = PlannerService::with_budget(BUDGET);
        let mut p = small_params();
        p.feasibility_only = false;
        let first = service.plan(&p).unwrap();
        // The valve ran at the end of the request: steady-state bytes fit.
        assert!(
            service.cache_bytes() <= BUDGET,
            "bytes {} over budget {BUDGET}",
            service.cache_bytes()
        );
        let st = service.stats();
        assert!(st.cache_evictions > 0, "a priced sweep must outgrow 256K");
        assert!(st.entries_evicted > 0);
        let tiers = service.caches().tiers();
        let by_name = |n: &str| tiers.iter().find(|t| t.name == n).copied().unwrap();
        assert!(by_name("traces").evictions + by_name("priced_reports").evictions > 0);
        assert_eq!(by_name("walls").evictions, 0, "verified walls are precious");
        assert_eq!(by_name("models").evictions, 0, "fitted models are precious");
        assert_eq!(by_name("time_models").evictions, 0, "step-time models are precious");
        assert!(by_name("walls").entries > 0, "walls survive the valve");
        // Eviction under budget leaves verified walls intact: a warm
        // point query still answers every cell from tier 1, probe-free.
        let (q, _) = service.walls_point(&p, 6 << 20).unwrap();
        assert_eq!(q.probes, 0);
        assert_eq!(q.from_walls, q.cells.len() as u64);
        // And a replayed plan stays byte-identical whether or not its
        // memo entry survived.
        let again = service.plan(&p).unwrap();
        let a = planner_report::plan_result_json(&first.outcome).render();
        let b = planner_report::plan_result_json(&again.outcome).render();
        assert_eq!(a, b);
        // Batch point queries answer tier-by-tier from the same memos.
        let (points, _) = service.walls_batch(&p, &[2 << 20, 4 << 20, 6 << 20]).unwrap();
        assert_eq!(points.len(), 3);
        assert!(points.iter().all(|q| q.probes == 0), "warm batch streams nothing");
    }

    #[test]
    fn refit_reply_carries_fingerprint_and_provenance() {
        let text = std::fs::read_to_string(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../examples/table5_measurements.json"
        ))
        .unwrap();
        let service = PlannerService::new();
        let reply = service
            .refit(&RefitParams {
                measurements: MeasurementsSource { source: "inline".into(), text },
            })
            .unwrap();
        assert_eq!(reply.info.model, "llama3-8b");
        assert_ne!(reply.calibration_fingerprint, service.default_calibration_fingerprint());
        assert_eq!(service.stats().refits, 1);
    }
}
