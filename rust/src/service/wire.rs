//! The planner service's versioned JSON wire protocol: typed request
//! parameter structs with strict parsing, canonical request echoes (the
//! session's plan-memo keys), and the success/error envelopes every
//! endpoint answers with.
//!
//! **Stability contract (api_version 1).** Requests may carry an
//! `"api_version"` field; when present it must equal [`API_VERSION`]
//! (anything else is rejected, so a client never silently gets the wrong
//! dialect). Unknown request fields are errors — new fields only appear
//! together with a version bump, so a typo'd request fails loudly instead
//! of planning with defaults. Responses always carry `api_version`, a
//! `kind`, the canonical `request` echo, deterministic `warnings`, and
//! the `result`; errors are always `{"api_version", "error": {"code",
//! "message"}}`. The `result` of a plan/walls/frontier response is
//! *deterministic*: repeated identical requests render byte-for-byte
//! equal bytes whether answered cold or from session memos (run
//! accounting lives in `/v1/health`, never in results).

use crate::config::presets::RunPreset;
use crate::config::{AcMode, ClusterConfig, CpMethod, FleetSpec, ParallelConfig};
use crate::engine::{refit, Calibration, Measurements, RefitInfo};
use crate::model::ModelDims;
use crate::planner::{PlacementRequest, PlanRequest, SweepDims};
use crate::schedule::{simulate, Quantities};
use crate::util::fmt::{parse_tokens, tokens, GIB};
use crate::util::json::Json;
use crate::util::stripe::fx_hash_one;

/// The wire dialect this build speaks (see the module docs for the
/// stability contract).
pub const API_VERSION: u64 = 1;

/// Highest `"at"` / token-count value a request may carry — keeps the
/// lattice arithmetic far from u64 overflow while allowing any plausible
/// context length (2^40 tokens = 1T).
pub const MAX_TOKENS: u64 = 1 << 40;

/// A refit measurements payload: the raw JSON text plus where it came
/// from (a file path on the CLI, `"inline"` over HTTP) for provenance.
#[derive(Debug, Clone)]
pub struct MeasurementsSource {
    pub source: String,
    pub text: String,
}

/// Typed `/v1/plan` (and walls-sweep / frontier) request parameters —
/// also what the CLI's flag parser produces, so `repro plan` is a thin
/// client of the same service entry points.
#[derive(Debug, Clone)]
pub struct PlanParams {
    pub model: String,
    pub gpus: u64,
    pub reference_s: u64,
    pub quantum: u64,
    pub cap_s: u64,
    pub ac_modes: Vec<AcMode>,
    pub micro_batches: Vec<u64>,
    pub tp_degrees: Vec<u64>,
    pub compositions: bool,
    /// Disable the symbolic solver and warm starts (`--cold`).
    pub cold: bool,
    pub feasibility_only: bool,
    /// Worker threads (0 = auto). Never part of the canonical echo — it
    /// cannot change results, so thread-count variants share memos.
    pub threads: usize,
    /// Optional Table-5-style measurements: plan with a refit calibration.
    pub measurements: Option<MeasurementsSource>,
    /// Per-request deadline, milliseconds (additive in api_version 1).
    /// Like `threads`, never part of the canonical echo: a deadline
    /// cannot change result bytes — it only decides whether the request
    /// finishes (200) or answers a structured 504 — so deadline variants
    /// share memos and a memo hit still answers instantly.
    pub deadline_ms: Option<u64>,
}

/// Top-level fields `/v1/plan` accepts (walls adds `"at"` via
/// [`PlanParams::from_json_with`]).
const PLAN_FIELDS: [&str; 16] = [
    "api_version",
    "model",
    "gpus",
    "seq",
    "quantum",
    "cap",
    "ac",
    "mb",
    "tp",
    "paper",
    "compose",
    "cold",
    "feasibility_only",
    "threads",
    "measurements",
    "deadline_ms",
];

impl PlanParams {
    /// The CLI/service defaults: the full default sweep space at the
    /// default search lattice (mirrors `PlanRequest::new`).
    pub fn defaults(model: &str, gpus: u64) -> PlanParams {
        let dims = SweepDims::default();
        PlanParams {
            model: model.to_string(),
            gpus,
            reference_s: 1 << 20,
            quantum: 128 * 1024,
            cap_s: 32 << 20,
            ac_modes: dims.ac_modes,
            micro_batches: dims.micro_batches,
            tp_degrees: dims.tp_degrees,
            compositions: dims.compositions,
            cold: false,
            feasibility_only: false,
            threads: 0,
            measurements: None,
            deadline_ms: None,
        }
    }

    /// Restrict to the paper's §5.1 dims (the CLI's `--paper`).
    pub fn set_paper(&mut self) {
        let dims = SweepDims::paper();
        self.ac_modes = dims.ac_modes;
        self.micro_batches = dims.micro_batches;
        self.tp_degrees = dims.tp_degrees;
        self.compositions = dims.compositions;
    }

    /// Dedup the sweep lists the way the CLI always has: AC order is
    /// meaningful (kept, first occurrence wins), micro-batch and TP lists
    /// sort ascending.
    pub fn normalize(&mut self) {
        let mut deduped: Vec<AcMode> = Vec::new();
        for m in self.ac_modes.drain(..) {
            if !deduped.contains(&m) {
                deduped.push(m);
            }
        }
        self.ac_modes = deduped;
        self.micro_batches.sort_unstable();
        self.micro_batches.dedup();
        self.tp_degrees.sort_unstable();
        self.tp_degrees.dedup();
    }

    pub fn from_json(j: &Json) -> Result<PlanParams, String> {
        Self::from_json_with(j, &[])
    }

    /// Parse request params, additionally allowing `extra` top-level
    /// fields (the walls endpoint's `"at"`). Strict: unknown fields and
    /// foreign `api_version`s are errors (see the module docs).
    pub fn from_json_with(j: &Json, extra: &[&str]) -> Result<PlanParams, String> {
        let Json::Obj(pairs) = j else {
            return Err("request body must be a JSON object".to_string());
        };
        for (k, _) in pairs {
            if !PLAN_FIELDS.contains(&k.as_str()) && !extra.contains(&k.as_str()) {
                return Err(format!("unknown field `{k}` (this server speaks api_version {API_VERSION})"));
            }
        }
        check_api_version(j)?;
        let model = match j.get("model") {
            None => "llama3-8b".to_string(),
            Some(v) => v
                .as_str()
                .ok_or_else(|| "`model` must be a string".to_string())?
                .to_string(),
        };
        let gpus = match j.get("gpus") {
            None => 8,
            Some(v) => v.as_u64().ok_or_else(|| "`gpus` must be a whole number".to_string())?,
        };
        let mut p = PlanParams::defaults(&model, gpus);
        if bool_field(j, "paper")? {
            p.set_paper();
        }
        if let Some(s) = tokens_field(j, "seq")? {
            p.reference_s = s;
        }
        if let Some(q) = tokens_field(j, "quantum")? {
            p.quantum = q;
        }
        if let Some(c) = tokens_field(j, "cap")? {
            p.cap_s = c;
        }
        if let Some(v) = j.get("ac") {
            p.ac_modes = ac_modes_from_json(v)?;
        }
        if let Some(v) = j.get("mb") {
            p.micro_batches = u64_list_from_json(v, "mb")?;
        }
        if let Some(v) = j.get("tp") {
            p.tp_degrees = u64_list_from_json(v, "tp")?;
        }
        p.compositions = p.compositions || bool_field(j, "compose")?;
        p.cold = bool_field(j, "cold")?;
        p.feasibility_only = bool_field(j, "feasibility_only")?;
        if let Some(v) = j.get("threads") {
            let t = v.as_u64().ok_or_else(|| "`threads` must be a whole number".to_string())?;
            p.threads = t.min(1024) as usize;
        }
        if let Some(v) = j.get("deadline_ms") {
            // 0 is legal and deterministic: the deadline is already
            // expired, so any request that must compute answers 504
            // (memo hits still answer — they publish nothing new).
            let d = v
                .as_u64()
                .ok_or_else(|| "`deadline_ms` must be a whole number of milliseconds".to_string())?;
            p.deadline_ms = Some(d);
        }
        if let Some(m) = j.get("measurements") {
            if !matches!(m, Json::Obj(_)) {
                return Err("`measurements` must be a measurements object".to_string());
            }
            p.measurements =
                Some(MeasurementsSource { source: "inline".to_string(), text: m.render() });
        }
        p.normalize();
        Ok(p)
    }

    /// Canonical request echo: fixed field order, normalized lists, one
    /// spelling per request — equal requests render equal bytes, which is
    /// both the response's `request` field and the session's plan-memo
    /// key. Measurements appear as a content fingerprint, not the full
    /// payload; `threads` and `deadline_ms` are excluded — neither can
    /// change result bytes, so their variants share one memo entry.
    pub fn canonical(&self) -> Json {
        let mut p = self.clone();
        p.normalize();
        let measurements = match &p.measurements {
            None => Json::Null,
            Some(m) => Json::obj(vec![
                ("source", Json::string(&m.source)),
                ("fingerprint", Json::string(&format!("{:016x}", fx_hash_one(&m.text)))),
            ]),
        };
        Json::obj(vec![
            ("api_version", Json::int(API_VERSION)),
            ("model", Json::string(&p.model)),
            ("gpus", Json::int(p.gpus)),
            ("reference_s", Json::int(p.reference_s)),
            ("quantum", Json::int(p.quantum)),
            ("cap_s", Json::int(p.cap_s)),
            (
                "ac_modes",
                Json::Arr(p.ac_modes.iter().map(|m| Json::string(m.label())).collect()),
            ),
            (
                "micro_batches",
                Json::Arr(p.micro_batches.iter().map(|&v| Json::int(v)).collect()),
            ),
            (
                "tp_degrees",
                Json::Arr(p.tp_degrees.iter().map(|&v| Json::int(v)).collect()),
            ),
            ("compositions", Json::Bool(p.compositions)),
            ("cold", Json::Bool(p.cold)),
            ("feasibility_only", Json::Bool(p.feasibility_only)),
            ("measurements", measurements),
        ])
    }

    /// Convert to the evaluator's request, applying the refit calibration
    /// when measurements ride along. Returns deterministic human-readable
    /// notes (refit provenance and warnings) for the caller to surface.
    pub fn to_request(&self) -> Result<(PlanRequest, Vec<String>), String> {
        let model = ModelDims::by_name(&self.model)
            .ok_or_else(|| format!("unknown model `{}`", self.model))?;
        let cluster = ClusterConfig::h100_cluster(self.gpus)?;
        if self.quantum == 0 || self.quantum > MAX_TOKENS {
            return Err(format!("quantum must be in [1, {MAX_TOKENS}] tokens"));
        }
        if self.cap_s < self.quantum {
            return Err("cap must be at least the quantum".to_string());
        }
        if self.cap_s > MAX_TOKENS {
            return Err(format!("cap must be at most {MAX_TOKENS} tokens"));
        }
        let mut p = self.clone();
        p.normalize();
        if p.ac_modes.is_empty() {
            return Err("ac must name at least one mode (ao|gpu|noac)".to_string());
        }
        if p.micro_batches.is_empty() || p.micro_batches.contains(&0) {
            return Err("mb entries must be whole numbers >= 1".to_string());
        }
        if p.tp_degrees.is_empty() || p.tp_degrees.contains(&0) {
            return Err("tp entries must be whole numbers >= 1".to_string());
        }
        let mut req = PlanRequest::new(model, cluster);
        req.reference_s = p.reference_s;
        req.quantum = p.quantum;
        req.cap_s = p.cap_s;
        req.dims = SweepDims {
            compositions: p.compositions,
            ac_modes: p.ac_modes,
            micro_batches: p.micro_batches,
            tp_degrees: p.tp_degrees,
        };
        req.threads = self.threads;
        req.warm_start = !self.cold;
        req.symbolic = !self.cold;
        req.feasibility_only = self.feasibility_only;
        let mut warnings = Vec::new();
        if let Some(ms) = &self.measurements {
            let m = Measurements::parse(&ms.text, &ms.source)?;
            let (cal, info, notes) = build_refit(&req.model, &m)?;
            req.calibration = cal;
            req.refit = Some(info);
            warnings = notes;
        }
        Ok((req, warnings))
    }
}

/// Most points one batch `"at"` query may carry: a capacity-dashboard
/// curve, not a bulk export — keeps a single request's work (and its
/// response body) bounded.
pub const MAX_AT_POINTS: usize = 256;

/// The `/v1/walls` point query: one sequence length or an ordered batch.
/// A batch is answered point-by-point from the same three-tier lookup a
/// single query uses, in the order the client sent — one request framing
/// for a whole capacity curve.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AtQuery {
    One(u64),
    Many(Vec<u64>),
}

impl AtQuery {
    /// The points in request order (a `One` is a batch of one).
    pub fn points(&self) -> Vec<u64> {
        match self {
            AtQuery::One(s) => vec![*s],
            AtQuery::Many(v) => v.clone(),
        }
    }
}

/// `/v1/walls` parameters: the plan params plus an optional point query.
#[derive(Debug, Clone)]
pub struct WallsParams {
    pub plan: PlanParams,
    /// Point capacity query: "is this sequence length trainable?" for
    /// every sweep configuration, answered from session memos when warm.
    /// A scalar asks about one length, an array about a whole curve.
    /// Absent = a feasibility-only walls sweep.
    pub at: Option<AtQuery>,
}

impl WallsParams {
    pub fn from_json(j: &Json) -> Result<WallsParams, String> {
        let plan = PlanParams::from_json_with(j, &["at"])?;
        let at = match j.get("at") {
            None => None,
            Some(Json::Arr(items)) => {
                if items.is_empty() {
                    return Err("`at` array must name at least one point".to_string());
                }
                if items.len() > MAX_AT_POINTS {
                    return Err(format!(
                        "`at` array carries {} points (at most {MAX_AT_POINTS} per request)",
                        items.len()
                    ));
                }
                let points = items
                    .iter()
                    .map(|v| {
                        let s = tokens_value(v).ok_or_else(|| {
                            format!("bad `at` entry `{}` (a label like \"6M\" or a whole number)", v.render())
                        })?;
                        if s == 0 || s > MAX_TOKENS {
                            return Err(format!("`at` entries must be in [1, {MAX_TOKENS}] tokens"));
                        }
                        Ok(s)
                    })
                    .collect::<Result<Vec<u64>, String>>()?;
                Some(AtQuery::Many(points))
            }
            Some(v) => {
                let s = tokens_value(v)
                    .ok_or_else(|| "`at` must be a token count (e.g. \"6M\")".to_string())?;
                if s == 0 || s > MAX_TOKENS {
                    return Err(format!("`at` must be in [1, {MAX_TOKENS}] tokens"));
                }
                Some(AtQuery::One(s))
            }
        };
        Ok(WallsParams { plan, at })
    }

    pub fn canonical(&self) -> Json {
        let mut c = self.plan.canonical();
        if let Json::Obj(pairs) = &mut c {
            // A scalar echoes as an int (byte-compatible with every
            // api_version-1 client), a batch as the ordered int array.
            let at = match &self.at {
                None => Json::Null,
                Some(AtQuery::One(s)) => Json::int(*s),
                Some(AtQuery::Many(v)) => Json::Arr(v.iter().map(|&s| Json::int(s)).collect()),
            };
            pairs.push(("at".to_string(), at));
        }
        c
    }
}

/// `/v1/placement` parameters: the job's plan fields plus the fleet to
/// place it on. Two plan fields are deliberately *not* placement fields:
/// `gpus` (the fleet's pools size the candidate shapes) and `cold`
/// (placement always plans symbolically — the `--cold` reference path is
/// a single-cluster measurement switch).
#[derive(Debug, Clone)]
pub struct PlacementParams {
    pub fleet: FleetSpec,
    pub plan: PlanParams,
    /// Skip dominated shapes before any probe (default true); the ranked
    /// placements are identical either way.
    pub prune: bool,
}

impl PlacementParams {
    pub fn from_json(j: &Json) -> Result<PlacementParams, String> {
        if j.get("gpus").is_some() {
            return Err(
                "`gpus` is not a placement field — the fleet's pools size the shapes".to_string()
            );
        }
        if j.get("cold").is_some() {
            return Err(
                "`cold` is not a placement field — placement always plans symbolically"
                    .to_string(),
            );
        }
        let plan = PlanParams::from_json_with(j, &["fleet", "prune"])?;
        let fleet_j = j
            .get("fleet")
            .ok_or_else(|| "missing `fleet` (a {\"pools\": [...]} object)".to_string())?;
        let fleet = FleetSpec::from_json(fleet_j).map_err(|e| format!("fleet: {e}"))?;
        let prune = match j.get("prune") {
            None => true,
            Some(v) => v.as_bool().ok_or_else(|| "`prune` must be true or false".to_string())?,
        };
        Ok(PlacementParams { fleet, plan, prune })
    }

    /// Canonical echo: the plan canonical minus the non-placement fields,
    /// plus `prune` and the fleet's canonical form — equal fleets render
    /// equal bytes, so the session placement memo keys correctly.
    pub fn canonical(&self) -> Json {
        let mut c = self.plan.canonical();
        if let Json::Obj(pairs) = &mut c {
            pairs.retain(|(k, _)| k != "gpus" && k != "cold");
            pairs.push(("prune".to_string(), Json::Bool(self.prune)));
            pairs.push(("fleet".to_string(), self.fleet.canonical()));
        }
        c
    }

    /// Convert to the evaluator's placement request (reusing the plan
    /// validation — lattice bounds, sweep lists, refit build — wholesale).
    pub fn to_request(&self) -> Result<(PlacementRequest, Vec<String>), String> {
        let (p, warnings) = self.plan.to_request()?;
        let mut req = PlacementRequest::new(p.model, self.fleet.clone());
        req.reference_s = p.reference_s;
        req.quantum = p.quantum;
        req.cap_s = p.cap_s;
        req.dims = p.dims;
        req.calibration = p.calibration;
        req.refit = p.refit;
        req.threads = p.threads;
        req.prune = self.prune;
        req.feasibility_only = p.feasibility_only;
        Ok((req, warnings))
    }
}

/// Most telemetry records one `/v1/observe` batch may carry — bounds a
/// single request's inversion work and response body.
pub const MAX_OBSERVATIONS: usize = 1024;

/// `/v1/observe` parameters: a batch of per-method telemetry records for
/// the online calibrator. Each record is parsed strictly (unknown fields,
/// bad layouts, and non-finite times are errors naming the offending
/// record) before any ingestion happens — a bad batch changes nothing.
#[derive(Debug, Clone)]
pub struct ObserveParams {
    pub observations: Vec<crate::calib::Observation>,
}

impl ObserveParams {
    pub fn from_json(j: &Json) -> Result<ObserveParams, String> {
        let Json::Obj(pairs) = j else {
            return Err("request body must be a JSON object".to_string());
        };
        for (k, _) in pairs {
            if !["api_version", "observations"].contains(&k.as_str()) {
                return Err(format!("unknown field `{k}` (this server speaks api_version {API_VERSION})"));
            }
        }
        check_api_version(j)?;
        let Some(Json::Arr(items)) = j.get("observations") else {
            return Err("missing `observations` (an array of telemetry records)".to_string());
        };
        if items.is_empty() {
            return Err("`observations` must carry at least one record".to_string());
        }
        if items.len() > MAX_OBSERVATIONS {
            return Err(format!(
                "`observations` carries {} records (at most {MAX_OBSERVATIONS} per request)",
                items.len()
            ));
        }
        let observations = items
            .iter()
            .enumerate()
            .map(|(i, v)| {
                crate::calib::Observation::from_json(v).map_err(|e| format!("observations[{i}]: {e}"))
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(ObserveParams { observations })
    }

    /// Canonical echo: observe is not memoized (ingestion is stateful by
    /// design), so the echo carries the batch size, not the payload.
    pub fn canonical(&self) -> Json {
        Json::obj(vec![
            ("api_version", Json::int(API_VERSION)),
            ("observations", Json::int(self.observations.len() as u64)),
        ])
    }
}

/// `/v1/refit` parameters: fit a calibration from measurements without
/// planning. The model comes from the measurements file itself.
#[derive(Debug, Clone)]
pub struct RefitParams {
    pub measurements: MeasurementsSource,
}

impl RefitParams {
    pub fn from_json(j: &Json) -> Result<RefitParams, String> {
        let Json::Obj(pairs) = j else {
            return Err("request body must be a JSON object".to_string());
        };
        for (k, _) in pairs {
            if !["api_version", "measurements"].contains(&k.as_str()) {
                return Err(format!("unknown field `{k}` (this server speaks api_version {API_VERSION})"));
            }
        }
        check_api_version(j)?;
        let m = j
            .get("measurements")
            .ok_or_else(|| "missing `measurements`".to_string())?;
        if !matches!(m, Json::Obj(_)) {
            return Err("`measurements` must be a measurements object".to_string());
        }
        Ok(RefitParams {
            measurements: MeasurementsSource { source: "inline".to_string(), text: m.render() },
        })
    }

    pub fn canonical(&self) -> Json {
        Json::obj(vec![
            ("api_version", Json::int(API_VERSION)),
            (
                "measurements",
                Json::obj(vec![
                    ("source", Json::string(&self.measurements.source)),
                    (
                        "fingerprint",
                        Json::string(&format!("{:016x}", fx_hash_one(&self.measurements.text))),
                    ),
                ]),
            ),
        ])
    }
}

/// Versioned success envelope shared by every endpoint.
pub fn envelope(kind: &str, request: Json, warnings: &[String], result: Json) -> Json {
    Json::obj(vec![
        ("api_version", Json::int(API_VERSION)),
        ("kind", Json::string(kind)),
        ("request", request),
        (
            "warnings",
            Json::Arr(warnings.iter().map(|w| Json::string(w)).collect()),
        ),
        ("result", result),
    ])
}

/// Structured error envelope — the only non-2xx body shape the daemon
/// ever emits.
pub fn error_envelope(code: &str, message: &str) -> Json {
    Json::obj(vec![
        ("api_version", Json::int(API_VERSION)),
        (
            "error",
            Json::obj(vec![("code", Json::string(code)), ("message", Json::string(message))]),
        ),
    ])
}

/// Parse a comma-separated AC-mode list (the CLI's `--ac` spelling).
pub fn parse_ac_list(s: &str) -> Result<Vec<AcMode>, String> {
    s.split(',')
        .map(|m| {
            AcMode::parse(m.trim()).ok_or_else(|| format!("bad ac entry `{m}` (ao|gpu|noac)"))
        })
        .collect()
}

/// Parse a comma-separated list of whole numbers (the CLI's `--mb`/`--tp`
/// spelling); `what` names the flag in errors.
pub fn parse_u64_list(s: &str, what: &str) -> Result<Vec<u64>, String> {
    s.split(',')
        .map(|x| x.trim().parse::<u64>().map_err(|_| format!("bad {what} entry `{x}`")))
        .collect()
}

/// Token-count value: a label string ("1M", "512K") or a whole number.
pub fn tokens_value(v: &Json) -> Option<u64> {
    match v {
        Json::Str(s) => parse_tokens(s),
        _ => v.as_u64(),
    }
}

fn tokens_field(j: &Json, key: &str) -> Result<Option<u64>, String> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => {
            let s = tokens_value(v).ok_or_else(|| {
                format!("`{key}` must be a token count (a label like \"1M\" or a whole number)")
            })?;
            if s == 0 || s > MAX_TOKENS {
                return Err(format!("`{key}` must be in [1, {MAX_TOKENS}] tokens"));
            }
            Ok(Some(s))
        }
    }
}

fn bool_field(j: &Json, key: &str) -> Result<bool, String> {
    match j.get(key) {
        None => Ok(false),
        Some(v) => v.as_bool().ok_or_else(|| format!("`{key}` must be true or false")),
    }
}

fn ac_modes_from_json(v: &Json) -> Result<Vec<AcMode>, String> {
    match v {
        Json::Str(s) => parse_ac_list(s),
        Json::Arr(items) => items
            .iter()
            .map(|i| {
                i.as_str()
                    .and_then(AcMode::parse)
                    .ok_or_else(|| format!("bad ac entry `{}` (ao|gpu|noac)", i.render()))
            })
            .collect(),
        _ => Err("`ac` must be a list of modes or a comma-separated string".to_string()),
    }
}

fn u64_list_from_json(v: &Json, what: &str) -> Result<Vec<u64>, String> {
    match v {
        Json::Str(s) => parse_u64_list(s, what),
        Json::Arr(items) => items
            .iter()
            .map(|i| i.as_u64().ok_or_else(|| format!("bad {what} entry `{}`", i.render())))
            .collect(),
        _ => Err(format!("`{what}` must be a list of whole numbers")),
    }
}

fn check_api_version(j: &Json) -> Result<(), String> {
    match j.get("api_version") {
        None => Ok(()),
        Some(v) if v.as_u64() == Some(API_VERSION) => Ok(()),
        Some(v) => Err(format!(
            "unsupported api_version {} (this server speaks {API_VERSION})",
            v.render()
        )),
    }
}

/// Fit a refit calibration from parsed measurements, with the same
/// sanity analysis the CLI has always run: model match, unusable-rate
/// skips, and the anchor-pressure check (an anchor cell simulated with
/// sub-threshold HBM headroom means its measured times already include
/// allocator-pressure penalties). Returns the calibration, its
/// provenance, and deterministic notes — the first is informational,
/// the rest are prefixed `WARNING:`.
pub fn build_refit(
    model: &ModelDims,
    m: &Measurements,
) -> Result<(Calibration, RefitInfo, Vec<String>), String> {
    if m.model != model.name {
        return Err(format!(
            "measurements are for `{}` but the request plans `{}`",
            m.model, model.name
        ));
    }
    let (cal, mut info) = refit(&Calibration::default(), m, model)?;
    let mut notes = Vec::new();
    notes.push(format!(
        "refit from {}: {} cells, anchored at {} tokens;{}",
        m.source,
        info.cells,
        tokens(info.anchor_seq),
        info.fields.iter().fold(String::new(), |mut s, f| {
            s.push_str(&format!(" {} {:.3e} -> {:.3e};", f.name, f.old, f.new));
            s
        })
    ));
    if !info.skipped.is_empty() {
        notes.push(format!(
            "WARNING: refit kept defaults for {} (measurements at or below the modelled \
             overhead floor)",
            info.skipped.join(", ")
        ));
    }
    // Pressure sanity: simulate the measured anchor cell. If it runs with
    // headroom below the pressure threshold, its measured times already
    // include the allocator-pressure penalties the engine re-applies
    // during the sweep — the refit rates absorb them. refit guarantees a
    // single-node (<= 8 GPU) Ulysses anchor.
    let anchor_cluster = ClusterConfig::h100_cluster(m.gpus)?;
    let anchor_preset = RunPreset {
        model: model.clone(),
        parallel: ParallelConfig::new(CpMethod::Ulysses, anchor_cluster.total_gpus()),
        cluster: anchor_cluster,
        seq_len: info.anchor_seq,
    };
    let q = Quantities::new(&anchor_preset);
    let anchor_report = simulate(&anchor_preset);
    let headroom = q.hbm_limit - anchor_report.peak_bytes;
    if headroom < cal.pressure_h0_gib * GIB {
        info.pressured_anchor = true;
        notes.push(format!(
            "WARNING: anchor cell ({} tokens) runs with only {:.1} GiB of predicted headroom \
             — its measured times include memory-pressure penalties, so the refit rates are \
             pessimistic near the memory walls; prefer an anchor at shorter context",
            tokens(info.anchor_seq),
            headroom.max(0.0) / GIB
        ));
    }
    Ok((cal, info, notes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{ConfigPlan, PlanOutcome};

    #[test]
    fn parse_defaults_and_overrides() {
        let p = PlanParams::from_json(&Json::obj(vec![])).unwrap();
        assert_eq!(p.model, "llama3-8b");
        assert_eq!(p.gpus, 8);
        assert_eq!(p.quantum, 128 * 1024);
        assert_eq!(p.cap_s, 32 << 20);
        assert_eq!(p.ac_modes, vec![AcMode::AcOffload, AcMode::AcGpu]);
        assert!(!p.cold && !p.feasibility_only && p.measurements.is_none());

        let j = Json::parse(
            r#"{"model":"qwen3-32b","gpus":16,"seq":"2M","quantum":"256K","cap":"16M",
                "ac":["ao"],"mb":[4,1,1],"tp":"2,1","cold":true,"feasibility_only":true,
                "threads":3}"#,
        )
        .unwrap();
        let p = PlanParams::from_json(&j).unwrap();
        assert_eq!(p.model, "qwen3-32b");
        assert_eq!(p.gpus, 16);
        assert_eq!(p.reference_s, 2 << 20);
        assert_eq!(p.quantum, 256 * 1024);
        assert_eq!(p.cap_s, 16 << 20);
        assert_eq!(p.ac_modes, vec![AcMode::AcOffload]);
        assert_eq!(p.micro_batches, vec![1, 4], "sorted + deduped");
        assert_eq!(p.tp_degrees, vec![1, 2]);
        assert!(p.cold && p.feasibility_only);
        assert_eq!(p.threads, 3);
    }

    #[test]
    fn parse_paper_flag_and_walls_at() {
        let j = Json::parse(r#"{"paper":true,"at":"6M"}"#).unwrap();
        let w = WallsParams::from_json(&j).unwrap();
        assert_eq!(w.at, Some(AtQuery::One(6 << 20)));
        assert_eq!(w.plan.ac_modes, vec![AcMode::AcOffload]);
        assert_eq!(w.plan.micro_batches, vec![1]);
        let c = w.canonical().render();
        assert!(c.ends_with("\"at\":6291456}"), "{c}");
    }

    #[test]
    fn parse_batch_at_preserves_order_and_bounds() {
        let j = Json::parse(r#"{"at":["6M","4M",5242880]}"#).unwrap();
        let w = WallsParams::from_json(&j).unwrap();
        // Request order is answer order — no sorting, no dedup.
        assert_eq!(w.at, Some(AtQuery::Many(vec![6 << 20, 4 << 20, 5 << 20])));
        let c = w.canonical().render();
        assert!(c.ends_with("\"at\":[6291456,4194304,5242880]}"), "{c}");

        let empty = Json::parse(r#"{"at":[]}"#).unwrap();
        let err = WallsParams::from_json(&empty).unwrap_err();
        assert!(err.contains("at least one point"), "{err}");

        let over: Vec<Json> = (0..=MAX_AT_POINTS as u64).map(|i| Json::int(i + 1)).collect();
        let big = Json::obj(vec![("at", Json::Arr(over))]);
        let err = WallsParams::from_json(&big).unwrap_err();
        assert!(err.contains("at most 256"), "{err}");

        let zero = Json::parse(r#"{"at":["1M",0]}"#).unwrap();
        assert!(WallsParams::from_json(&zero).is_err());
        let bad = Json::parse(r#"{"at":[true]}"#).unwrap();
        let err = WallsParams::from_json(&bad).unwrap_err();
        assert!(err.contains("bad `at` entry"), "{err}");
    }

    #[test]
    fn parse_placement_params_and_canonical() {
        let body = r#"{"model":"llama3-8b","paper":true,"quantum":"1M","cap":"4M",
            "fleet":{"pools":[{"name":"east","device":"h100","nodes":2},
                              {"name":"lab","device":"h200","nodes":1}]}}"#;
        let p = PlacementParams::from_json(&Json::parse(body).unwrap()).unwrap();
        assert_eq!(p.fleet.pools.len(), 2);
        assert!(p.prune, "pruning defaults on");
        let c = p.canonical().render();
        assert!(!c.contains("\"gpus\""), "gpus is not a placement input: {c}");
        assert!(!c.contains("\"cold\""), "{c}");
        assert!(c.contains("\"prune\":true"), "{c}");
        assert!(c.contains("\"fleet\":{\"pools\":["), "{c}");
        // An explicit prune:true spells the same canonical bytes — the
        // placement memo must not split on default-vs-explicit.
        let explicit = body.replacen("{\"model\"", "{\"prune\":true,\"model\"", 1);
        let q = PlacementParams::from_json(&Json::parse(&explicit).unwrap()).unwrap();
        assert_eq!(q.canonical().render(), c);

        let (req, warnings) = p.to_request().unwrap();
        assert!(warnings.is_empty());
        assert!(req.prune);
        assert_eq!(req.quantum, 1 << 20);
        assert_eq!(req.cap_s, 4 << 20);
        assert_eq!(req.fleet.total_gpus(), 24);

        // Non-placement and malformed fields fail loudly.
        for (bad, want) in [
            (r#"{"gpus":8,"fleet":{"pools":[{"device":"h100","nodes":1}]}}"#, "not a placement"),
            (r#"{"cold":true,"fleet":{"pools":[{"device":"h100","nodes":1}]}}"#, "not a placement"),
            (r#"{"model":"llama3-8b"}"#, "missing `fleet`"),
            (r#"{"fleet":{"pools":[]}}"#, "at least one pool"),
            (r#"{"fleet":{"pools":[{"device":"h100","nodes":1}]},"prune":"yes"}"#, "true or false"),
        ] {
            let err = PlacementParams::from_json(&Json::parse(bad).unwrap()).unwrap_err();
            assert!(err.contains(want), "`{bad}` -> {err}");
        }
    }

    #[test]
    fn parse_rejects_unknowns_and_foreign_versions() {
        let unknown = Json::parse(r#"{"modle":"llama3-8b"}"#).unwrap();
        let err = PlanParams::from_json(&unknown).unwrap_err();
        assert!(err.contains("unknown field `modle`"), "{err}");
        let v99 = Json::parse(r#"{"api_version":99}"#).unwrap();
        let err = PlanParams::from_json(&v99).unwrap_err();
        assert!(err.contains("unsupported api_version 99"), "{err}");
        let v1 = Json::parse(r#"{"api_version":1}"#).unwrap();
        assert!(PlanParams::from_json(&v1).is_ok());
        assert!(PlanParams::from_json(&Json::Arr(vec![])).is_err());
        let bad_ac = Json::parse(r#"{"ac":"turbo"}"#).unwrap();
        assert!(PlanParams::from_json(&bad_ac).is_err());
        let zero = Json::parse(r#"{"quantum":0}"#).unwrap();
        assert!(PlanParams::from_json(&zero).is_err());
    }

    #[test]
    fn to_request_validates_and_maps() {
        let mut p = PlanParams::defaults("llama3-8b", 8);
        p.cold = true;
        let (req, warnings) = p.to_request().unwrap();
        assert!(warnings.is_empty());
        assert_eq!(req.model.name, "llama3-8b");
        assert_eq!(req.cluster.total_gpus(), 8);
        assert!(!req.symbolic && !req.warm_start, "cold maps to both switches");

        assert!(PlanParams::defaults("nope", 8).to_request().is_err());
        assert!(PlanParams::defaults("llama3-8b", 7).to_request().is_err(), "7 GPUs multi-node");
        let mut bad = PlanParams::defaults("llama3-8b", 8);
        bad.cap_s = bad.quantum / 2;
        assert!(bad.to_request().is_err());
        let mut bad = PlanParams::defaults("llama3-8b", 8);
        bad.micro_batches = vec![0];
        assert!(bad.to_request().is_err());
    }

    #[test]
    fn canonical_is_stable_and_ignores_threads() {
        let mut a = PlanParams::defaults("llama3-8b", 8);
        a.threads = 1;
        let mut b = PlanParams::defaults("llama3-8b", 8);
        b.threads = 7;
        // Unnormalized duplicates collapse to the same canonical bytes.
        b.micro_batches = vec![4, 1, 2, 1];
        assert_eq!(a.canonical().render(), b.canonical().render());
        let mut c = a.clone();
        c.feasibility_only = true;
        assert_ne!(a.canonical().render(), c.canonical().render());
    }

    /// The byte-for-byte golden for a `/v1/plan` response: a handcrafted
    /// outcome through the full serializer stack (canonical request echo,
    /// envelope, deterministic result core). If this changes, the wire
    /// format changed — bump [`API_VERSION`].
    #[test]
    fn golden_plan_response_bytes() {
        let outcome = PlanOutcome {
            model: ModelDims::llama3_8b(),
            cluster: ClusterConfig::h100_node(),
            reference_s: 1 << 20,
            quantum: 128 * 1024,
            configs: vec![
                ConfigPlan {
                    parallel: ParallelConfig::new(
                        CpMethod::Upipe { u: 8, gqa_schedule: true },
                        8,
                    ),
                    max_context: Some(5 << 20),
                    hit_cap: false,
                    max_ctx_peak_gib: Some(68.5),
                    max_ctx_tok_s_gpu: Some(1234.0),
                    ref_peak_gib: Some(21.25),
                    ref_tok_s_gpu: Some(4321.5),
                    pareto: true,
                },
                ConfigPlan {
                    parallel: {
                        let mut p = ParallelConfig::new(CpMethod::Ulysses, 8);
                        p.pin_memory = false;
                        p
                    },
                    max_context: None,
                    hit_cap: false,
                    max_ctx_peak_gib: None,
                    max_ctx_tok_s_gpu: None,
                    ref_peak_gib: None,
                    ref_tok_s_gpu: None,
                    pareto: false,
                },
            ],
            refit: None,
            simulations: 999, // accounting: must NOT appear in the result
            feasibility_probes: 999,
            priced_sims: 999,
            modeled_prices: 999,
            symbolic_models: 9,
            symbolic_fallbacks: 9,
            time_models: 9,
            time_fallbacks: 9,
            feasibility_only: false,
            cancelled: false,
            cache_hits: 9,
            cache_misses: 9,
            wall_s: 123.456,
        };
        let params = PlanParams::defaults("llama3-8b", 8);
        let resp = envelope(
            "plan",
            params.canonical(),
            &[],
            crate::report::planner::plan_result_json(&outcome),
        );
        let want = concat!(
            "{\"api_version\":1,\"kind\":\"plan\",",
            "\"request\":{\"api_version\":1,\"model\":\"llama3-8b\",\"gpus\":8,",
            "\"reference_s\":1048576,\"quantum\":131072,\"cap_s\":33554432,",
            "\"ac_modes\":[\"ao\",\"gpu\"],\"micro_batches\":[1,2,4],",
            "\"tp_degrees\":[1,2],\"compositions\":false,\"cold\":false,",
            "\"feasibility_only\":false,\"measurements\":null},",
            "\"warnings\":[],",
            "\"result\":{\"model\":\"llama3-8b\",\"cluster\":\"8xH100\",\"gpus\":8,",
            "\"reference_s\":1048576,\"quantum\":131072,\"refit\":null,",
            "\"feasibility_only\":false,\"configs\":[",
            "{\"method\":\"UPipe\",\"params\":\"U=8,gqa\",\"ac_mode\":\"ao\",",
            "\"micro_batch\":1,\"tp\":1,\"pin_memory\":true,\"cp_degree\":8,",
            "\"max_context\":5242880,\"max_context_label\":\"5M\",",
            "\"max_context_capped\":false,\"max_ctx_peak_gib\":68.5,",
            "\"max_ctx_tok_s_per_gpu\":1234,\"ref_peak_gib\":21.25,",
            "\"ref_tok_s_per_gpu\":4321.5,\"pareto\":true},",
            "{\"method\":\"Ulysses\",\"params\":\"\",\"ac_mode\":\"ao\",",
            "\"micro_batch\":1,\"tp\":1,\"pin_memory\":false,\"cp_degree\":8,",
            "\"max_context\":null,\"max_context_label\":null,",
            "\"max_context_capped\":false,\"max_ctx_peak_gib\":null,",
            "\"max_ctx_tok_s_per_gpu\":null,\"ref_peak_gib\":null,",
            "\"ref_tok_s_per_gpu\":null,\"pareto\":false}]}}",
        );
        assert_eq!(resp.render(), want);
        // The envelope round-trips through our own parser.
        let parsed = Json::parse(&resp.render()).unwrap();
        assert_eq!(parsed.get("api_version").and_then(Json::as_u64), Some(1));
        assert_eq!(parsed.render(), want);
    }

    #[test]
    fn parse_observe_batches_strictly() {
        let body = r#"{"api_version":1,"observations":[
            {"method":"ulysses","model":"llama3-8b","gpus":8,"seq":"1M","attn_fwd":2.5},
            {"method":"upipe","model":"llama3-8b","gpus":8,"seq":1048576,"u":8}
        ]}"#;
        let p = ObserveParams::from_json(&Json::parse(body).unwrap()).unwrap();
        assert_eq!(p.observations.len(), 2);
        assert_eq!(p.observations[0].seq, 1 << 20);
        assert_eq!(p.canonical().render(), r#"{"api_version":1,"observations":2}"#);

        for (bad, want) in [
            (r#"{"observation":[]}"#, "unknown field `observation`"),
            (r#"{"observations":{}}"#, "missing `observations`"),
            (r#"{"observations":[]}"#, "at least one record"),
            (
                r#"{"observations":[{"method":"warp","model":"llama3-8b","gpus":8,"seq":"1M"}]}"#,
                "observations[0]",
            ),
        ] {
            let err = ObserveParams::from_json(&Json::parse(bad).unwrap()).unwrap_err();
            assert!(err.contains(want), "`{bad}` -> {err}");
        }
        let over: Vec<String> = (0..=MAX_OBSERVATIONS)
            .map(|_| r#"{"method":"ring","model":"llama3-8b","gpus":8,"seq":"1M"}"#.to_string())
            .collect();
        let big = format!("{{\"observations\":[{}]}}", over.join(","));
        let err = ObserveParams::from_json(&Json::parse(&big).unwrap()).unwrap_err();
        assert!(err.contains("at most 1024"), "{err}");
    }

    #[test]
    fn error_envelope_shape() {
        let e = error_envelope("bad_request", "unknown field `x`");
        assert_eq!(
            e.render(),
            "{\"api_version\":1,\"error\":{\"code\":\"bad_request\",\
             \"message\":\"unknown field `x`\"}}"
        );
    }

    #[test]
    fn build_refit_matches_cli_semantics() {
        let text = std::fs::read_to_string(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../examples/table5_measurements.json"
        ))
        .expect("example measurements present");
        let model = ModelDims::llama3_8b();
        let m = Measurements::parse(&text, "table5.json").unwrap();
        let (cal, info, notes) = build_refit(&model, &m).unwrap();
        assert_ne!(cal.fingerprint(), Calibration::default().fingerprint());
        assert_eq!(info.model, "llama3-8b");
        assert!(!notes.is_empty());
        assert!(notes[0].starts_with("refit from table5.json:"), "{}", notes[0]);
        // Mismatched model is refused.
        let qwen = ModelDims::qwen3_32b();
        assert!(build_refit(&qwen, &m).is_err());
    }
}
