//! `repro serve-plan`: a dependency-free HTTP/1.1 front-end over one
//! [`PlannerService`] session. A std `TcpListener` accept loop feeds a
//! [`JobQueue`] drained by N worker threads (the same pool philosophy as
//! the sweep evaluator: no async runtime, no framework — the offline
//! vendor set has neither). Each worker owns its connection for the
//! connection's whole life: requests are served in a keep-alive loop
//! (read → route → respond → read again), pipelined requests are drained
//! from the same buffer in order, and the connection closes on
//! `Connection: close`, an idle timeout, a per-connection request cap,
//! or an unrecoverable framing error. Routed errors (400/404/405) answer
//! and keep the connection alive — the stream is still in sync; framing
//! errors (truncated head, bad `Content-Length`) answer and close,
//! because resynchronizing an unparseable stream is guesswork. An
//! oversized-but-declared body is the exception: the server drains and
//! discards it, so the 413 keeps the (still framed) connection.
//!
//! Fault tolerance: every request may carry a deadline (server-wide
//! `--request-timeout` and/or per-request `deadline_ms`); expiry answers
//! a structured 504 with partial accounting and publishes nothing. A
//! panicking cell answers 500, is tombstoned, and repeats answer 503
//! `quarantined` until the bounded retry-after lapses. [`ServeHandle::
//! drain`] flips the listener into drain mode (new connections get a
//! `Connection: close` 503) and waits for in-flight requests to finish.
//! With [`ServeOptions::access_log`] set, every request appends one JSON
//! line (endpoint, status, ms, bytes, memo tier, shed/deadline/
//! quarantine flags) to the log file.
//!
//! Endpoints (wire dialect: [`super::wire`], `api_version 1`):
//!
//! | method + path      | body                          | result            |
//! |--------------------|-------------------------------|-------------------|
//! | `POST /v1/plan`    | plan params                   | ranked plan       |
//! | `POST /v1/walls`   | plan params (+ `"at"`)        | walls sweep / point query / batch curve |
//! | `POST /v1/frontier`| plan params                   | Pareto frontier (+ envelope `accounting`: zeros when memo-warm) |
//! | `POST /v1/refit`   | `{"measurements": {...}}`     | refit provenance  |
//! | `POST /v1/placement`| placement params (`fleet` + plan fields) | ranked fleet placements (+ envelope `accounting`: zeros when memo-warm) |
//! | `POST /v1/observe` | `{"observations": [...]}`     | accept/reject counts, drift vector, published epoch + invalidations |
//! | `GET  /v1/calibration` | —                         | active epoch, constants, drift, provenance chain |
//! | `GET  /v1/health`  | —                             | status, per-endpoint p50/p95, per-tier cache bytes + evictions |
//! | `GET  /metrics`    | —                             | the health counters as Prometheus text exposition (`text/plain`) |
//!
//! Every error is a structured JSON envelope (`error.code` /
//! `error.message`) with a matching status code; handler panics are
//! caught and answered as 500s so one bad request cannot take the daemon
//! down.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use crate::report::planner as planner_report;
use crate::util::failpoint;
use crate::util::json::Json;
use crate::util::pool::{default_threads, JobQueue};

use super::wire::{
    self, AtQuery, ObserveParams, PlacementParams, PlanParams, RefitParams, WallsParams,
    API_VERSION,
};
use super::{PlannerService, ServiceError};

/// Request-size ceilings: a header block or body beyond these is refused
/// with a structured error rather than buffered without bound.
const MAX_HEAD_BYTES: usize = 16 * 1024;
const MAX_BODY_BYTES: usize = 1024 * 1024;

/// Mid-request socket timeout — a peer that stalls halfway through a
/// head or body releases its worker. The *between*-requests wait on a
/// kept-alive connection uses [`ServeOptions::keep_alive_timeout`]
/// instead.
const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// Connection-queue depth bound: handlers can hold workers for seconds
/// (a cold sweep), so without a bound a connection burst would buffer
/// sockets — and file descriptors — without limit. Beyond this depth the
/// accept loop answers 503 inline and drops the connection.
const MAX_QUEUED_CONNECTIONS: usize = 128;

/// Idle keep-alive waits poll in slices this long so a worker parked
/// between requests notices a drain within one slice instead of holding
/// the connection for the whole idle window.
const IDLE_SLICE: Duration = Duration::from_millis(250);

/// A declared body longer than this is refused *without* draining it —
/// reading gigabytes to keep one connection alive is the wrong trade, so
/// past this bound the 413 closes the connection instead.
const MAX_DRAIN_BYTES: usize = 8 * MAX_BODY_BYTES;

/// How the daemon serves connections. `Default` is the production shape:
/// auto worker count, 5 s keep-alive idle window, and a per-connection
/// request cap so one client cannot monopolize a worker forever.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Worker threads (0 = auto, capped — handlers hold the planner's
    /// own worker pool busy, so a few are plenty).
    pub threads: usize,
    /// How long a kept-alive connection may sit idle between requests
    /// before the server closes it. `Duration::ZERO` disables keep-alive
    /// entirely: every response carries `Connection: close`.
    pub keep_alive_timeout: Duration,
    /// Requests served on one connection before the server closes it
    /// (fairness under sustained traffic; 0 behaves like 1).
    pub max_requests_per_connection: u64,
    /// Append one JSON line per request (endpoint, status, ms, bytes,
    /// memo tier, shed/deadline/quarantine flags) to this file. `None`
    /// disables access logging.
    pub access_log: Option<PathBuf>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            threads: 0,
            keep_alive_timeout: Duration::from_secs(5),
            max_requests_per_connection: 1000,
            access_log: None,
        }
    }
}

/// Endpoint identities for the latency/hit-rate stats (index = slot).
const ENDPOINTS: [&str; 10] = [
    "plan",
    "walls",
    "frontier",
    "refit",
    "placement",
    "observe",
    "calibration",
    "health",
    "metrics",
    "other",
];
const EP_PLAN: usize = 0;
const EP_WALLS: usize = 1;
const EP_FRONTIER: usize = 2;
const EP_REFIT: usize = 3;
const EP_PLACEMENT: usize = 4;
const EP_OBSERVE: usize = 5;
const EP_CALIBRATION: usize = 6;
const EP_HEALTH: usize = 7;
const EP_METRICS: usize = 8;
const EP_OTHER: usize = 9;

/// Per-endpoint request accounting, `coordinator::server::ServerStats`
/// style: served/error counts plus latency percentiles.
#[derive(Default)]
struct EndpointAgg {
    served: u64,
    errors: u64,
    latencies_ms: Vec<f64>,
}

impl EndpointAgg {
    fn percentile(&self, q: f64) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        let mut ls = self.latencies_ms.clone();
        ls.sort_by(f64::total_cmp);
        ls[((ls.len() as f64 - 1.0) * q) as usize]
    }
}

struct HttpStats {
    endpoints: [Mutex<EndpointAgg>; 10],
    /// Connections accepted and handed to a worker.
    connections: AtomicU64,
    /// Requests served on an already-used connection — the keep-alive
    /// win: `keepalive_reuses / total served` is the fraction of requests
    /// that skipped a TCP handshake.
    keepalive_reuses: AtomicU64,
    /// Connections answered 503 inline because the queue was full.
    sheds: AtomicU64,
    /// Connections refused with a `Connection: close` 503 during drain.
    drain_refusals: AtomicU64,
    started: Instant,
}

impl HttpStats {
    fn new() -> Self {
        HttpStats {
            endpoints: std::array::from_fn(|_| Mutex::new(EndpointAgg::default())),
            connections: AtomicU64::new(0),
            keepalive_reuses: AtomicU64::new(0),
            sheds: AtomicU64::new(0),
            drain_refusals: AtomicU64::new(0),
            started: Instant::now(),
        }
    }

    fn record(&self, ep: usize, ok: bool, ms: f64) {
        let mut agg = self.endpoints[ep].lock().unwrap();
        agg.served += 1;
        if !ok {
            agg.errors += 1;
        }
        agg.latencies_ms.push(ms);
        // Bound memory on a long-lived daemon: keep the recent half.
        if agg.latencies_ms.len() > 4096 {
            agg.latencies_ms.drain(..2048);
        }
    }

    fn json(&self) -> Json {
        let eps = ENDPOINTS
            .iter()
            .enumerate()
            .map(|(i, name)| {
                let agg = self.endpoints[i].lock().unwrap();
                let body = Json::obj(vec![
                    ("served", Json::int(agg.served)),
                    ("errors", Json::int(agg.errors)),
                    ("p50_ms", Json::Num(agg.percentile(0.5))),
                    ("p95_ms", Json::Num(agg.percentile(0.95))),
                ]);
                (name.to_string(), body)
            })
            .collect();
        Json::Obj(eps)
    }
}

/// What a graceful drain accomplished before its timeout.
#[derive(Debug, Clone, Copy)]
pub struct DrainStats {
    /// Every in-flight request finished inside the drain window.
    pub drained: bool,
    /// Requests still running when the window expired (0 when drained).
    pub in_flight_at_deadline: usize,
    /// Connections refused with the `draining` 503 while winding down.
    pub refused: u64,
}

/// A running daemon: its bound address plus the handles needed to stop
/// it cleanly (tests), drain it gracefully (SIGTERM), or block on it
/// forever (the CLI daemon).
pub struct ServeHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
    in_flight: Arc<AtomicUsize>,
    stats: Arc<HttpStats>,
    queue: Arc<JobQueue<TcpStream>>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl ServeHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, drain in-flight connections, join every thread.
    /// Clients must drop their kept-alive connections for the workers to
    /// come home (they will, within the idle timeout, regardless).
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Wake the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Flip the listener into drain mode without blocking: new
    /// connections answer a `Connection: close` 503 (`draining`), idle
    /// kept-alive connections close within one [`IDLE_SLICE`], and
    /// in-flight requests keep running. Call [`ServeHandle::drain`] to
    /// wait for them.
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::Relaxed);
    }

    /// Graceful shutdown: begin draining, wait up to `timeout` for every
    /// in-flight request to finish, then stop the listener and join what
    /// can be joined. Workers still grinding a request past the deadline
    /// are detached — they die with the process — so the caller always
    /// gets control back within roughly `timeout`.
    pub fn drain(mut self, timeout: Duration) -> DrainStats {
        self.begin_drain();
        let t0 = Instant::now();
        while self.in_flight.load(Ordering::Relaxed) > 0 && t0.elapsed() < timeout {
            std::thread::sleep(Duration::from_millis(10));
        }
        let leftover = self.in_flight.load(Ordering::Relaxed);
        self.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        self.queue.close();
        if leftover == 0 {
            for w in self.workers.drain(..) {
                let _ = w.join();
            }
        } else {
            // Timed out: detach the stuck workers instead of blocking
            // shutdown on them.
            self.workers.clear();
        }
        DrainStats {
            drained: leftover == 0,
            in_flight_at_deadline: leftover,
            refused: self.stats.drain_refusals.load(Ordering::Relaxed),
        }
    }

    /// Block until the process dies — the `repro serve-plan` foreground
    /// path.
    pub fn join(mut self) {
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
    }
}

/// Bind `addr` (e.g. `127.0.0.1:8077`; port 0 picks a free one) and serve
/// the session per `opts`.
pub fn serve(
    service: Arc<PlannerService>,
    addr: &str,
    opts: ServeOptions,
) -> std::io::Result<ServeHandle> {
    let listener = TcpListener::bind(addr)?;
    let bound = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let draining = Arc::new(AtomicBool::new(false));
    let in_flight = Arc::new(AtomicUsize::new(0));
    let queue: Arc<JobQueue<TcpStream>> = Arc::new(JobQueue::new());
    let stats = Arc::new(HttpStats::new());
    let threads = if opts.threads == 0 { default_threads().min(4) } else { opts.threads };
    let access_log = match &opts.access_log {
        Some(path) => {
            let file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
            Some(Arc::new(Mutex::new(file)))
        }
        None => None,
    };
    let shared = Arc::new(ConnShared {
        service,
        stats: Arc::clone(&stats),
        opts,
        draining: Arc::clone(&draining),
        in_flight: Arc::clone(&in_flight),
        log: access_log.clone(),
    });
    let mut workers = Vec::new();
    for _ in 0..threads.max(1) {
        let q = Arc::clone(&queue);
        let sh = Arc::clone(&shared);
        workers.push(std::thread::spawn(move || {
            while let Some(stream) = q.pop() {
                handle_connection(&sh, stream);
            }
        }));
    }
    let accept = {
        let q = Arc::clone(&queue);
        let stop = Arc::clone(&stop);
        let draining = Arc::clone(&draining);
        let st = Arc::clone(&stats);
        let log = access_log;
        Some(std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                if let Ok(mut stream) = conn {
                    if draining.load(Ordering::Relaxed) {
                        // Winding down: refuse new connections fast so a
                        // load balancer retries elsewhere; in-flight
                        // requests keep their workers.
                        st.drain_refusals.fetch_add(1, Ordering::Relaxed);
                        let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
                        let body = wire::error_envelope(
                            "draining",
                            "server is draining; connection refused",
                        );
                        let n = write_response(&mut stream, 503, &Payload::Json(body), false);
                        log_line(&log, EP_OTHER, 503, 0.0, n, ReqFlags::shed(), false);
                        continue;
                    }
                    // Backpressure: shed load with a fast 503 instead of
                    // buffering sockets (= file descriptors) unboundedly
                    // while the workers grind long sweeps.
                    if q.len() >= MAX_QUEUED_CONNECTIONS {
                        st.sheds.fetch_add(1, Ordering::Relaxed);
                        let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
                        let body = wire::error_envelope(
                            "overloaded",
                            "request queue is full; retry later",
                        );
                        let n = write_response(&mut stream, 503, &Payload::Json(body), false);
                        log_line(&log, EP_OTHER, 503, 0.0, n, ReqFlags::shed(), false);
                        continue;
                    }
                    q.push(stream);
                }
            }
        }))
    };
    Ok(ServeHandle { addr: bound, stop, draining, in_flight, stats, queue, accept, workers })
}

/// Everything a worker needs to serve connections, bundled so the
/// per-connection loop takes one argument.
struct ConnShared {
    service: Arc<PlannerService>,
    stats: Arc<HttpStats>,
    opts: ServeOptions,
    draining: Arc<AtomicBool>,
    in_flight: Arc<AtomicUsize>,
    log: Option<Arc<Mutex<std::fs::File>>>,
}

/// A response body with its content type: every API endpoint answers a
/// JSON envelope; `GET /metrics` answers the Prometheus text exposition
/// format, which scrapers require as `text/plain`.
enum Payload {
    Json(Json),
    Text(String),
}

struct HttpError {
    status: u16,
    code: &'static str,
    message: String,
    /// The stream is still framed after answering (the oversized-body
    /// 413 drains the declared body first); framing errors close.
    keep: bool,
}

impl HttpError {
    fn bad(message: impl Into<String>) -> Self {
        HttpError { status: 400, code: "bad_request", message: message.into(), keep: false }
    }
}

/// Per-request facts for the access log that only the handler knows.
#[derive(Debug, Default, Clone, Copy)]
struct ReqFlags {
    /// `Some(true)` = answered from a whole-request memo, `Some(false)`
    /// = computed cold, `None` = no memo on this path.
    memo_hit: Option<bool>,
    /// Refused before routing (queue full, or draining).
    shed: bool,
    /// Answered 504 after the request deadline expired.
    deadline: bool,
    /// Answered 503 because the cell is quarantined after a panic.
    quarantined: bool,
}

impl ReqFlags {
    fn shed() -> Self {
        ReqFlags { shed: true, ..ReqFlags::default() }
    }
}

/// Append one JSON line for a served (or refused) request. Log I/O
/// failures are swallowed: observability must never take a request down.
fn log_line(
    log: &Option<Arc<Mutex<std::fs::File>>>,
    ep: usize,
    status: u16,
    ms: f64,
    bytes: usize,
    flags: ReqFlags,
    keep: bool,
) {
    let Some(log) = log else { return };
    let ts_ms = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map_or(0, |d| d.as_millis() as u64);
    let memo = match flags.memo_hit {
        Some(true) => "hit",
        Some(false) => "miss",
        None => "none",
    };
    let line = format!(
        "{{\"ts_ms\":{ts_ms},\"endpoint\":\"{}\",\"status\":{status},\"ms\":{ms:.3},\
         \"bytes\":{bytes},\"memo\":\"{memo}\",\"shed\":{},\"deadline\":{},\
         \"quarantined\":{},\"keep\":{keep}}}\n",
        ENDPOINTS[ep], flags.shed, flags.deadline, flags.quarantined,
    );
    if let Ok(mut f) = log.lock() {
        let _ = f.write_all(line.as_bytes());
    }
}

/// One parsed request off a connection's stream.
struct Request {
    method: String,
    path: String,
    body: Vec<u8>,
    /// The client asked for this to be the connection's last request
    /// (`Connection: close`, or HTTP/1.0 without `keep-alive`).
    close: bool,
}

/// The per-connection request loop. Each iteration reads one request
/// from the shared buffer (pipelined successors are already there),
/// routes it, and answers with the right `Connection` header. `Ok(None)`
/// from the reader is a clean end (peer EOF, idle timeout between
/// requests, or a drain began while idle); a framing error answers and
/// closes, while a still-framed error (the drained 413) keeps going.
fn handle_connection(shared: &ConnShared, mut stream: TcpStream) {
    let (service, stats, opts) = (&*shared.service, &*shared.stats, &shared.opts);
    stats.connections.fetch_add(1, Ordering::Relaxed);
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let keep_alive_enabled = !opts.keep_alive_timeout.is_zero();
    let idle = if keep_alive_enabled { opts.keep_alive_timeout } else { IO_TIMEOUT };
    let mut buf: Vec<u8> = Vec::new();
    let mut served: u64 = 0;
    loop {
        match read_request(&mut stream, &mut buf, idle, &shared.draining) {
            Ok(None) => break,
            Ok(Some(req)) => {
                shared.in_flight.fetch_add(1, Ordering::Relaxed);
                let t0 = Instant::now();
                let (ep, status, body, flags) =
                    route(service, stats, &req.method, &req.path, &req.body);
                let ms = t0.elapsed().as_secs_f64() * 1e3;
                stats.record(ep, status < 400, ms);
                served += 1;
                if served > 1 {
                    stats.keepalive_reuses.fetch_add(1, Ordering::Relaxed);
                }
                let keep = keep_alive_enabled
                    && !req.close
                    && served < opts.max_requests_per_connection.max(1)
                    && status < 500
                    && !shared.draining.load(Ordering::Relaxed);
                let bytes = write_response(&mut stream, status, &body, keep);
                log_line(&shared.log, ep, status, ms, bytes, flags, keep);
                shared.in_flight.fetch_sub(1, Ordering::Relaxed);
                // `bytes == 0` is an injected (or real) write fault: the
                // peer never got the response, so the stream is dead.
                if !keep || bytes == 0 {
                    break;
                }
            }
            Err(e) => {
                // Unreadable/oversized requests never reach routing; count
                // them under "other" so /v1/health still sees the errors.
                stats.record(EP_OTHER, false, 0.0);
                let body = wire::error_envelope(e.code, &e.message);
                let bytes =
                    write_response(&mut stream, e.status, &Payload::Json(body), e.keep);
                log_line(&shared.log, EP_OTHER, e.status, 0.0, bytes, ReqFlags::default(), e.keep);
                if !e.keep || bytes == 0 {
                    break;
                }
            }
        }
    }
}

fn known_path(path: &str) -> bool {
    [
        "/v1/plan",
        "/v1/walls",
        "/v1/frontier",
        "/v1/refit",
        "/v1/placement",
        "/v1/observe",
        "/v1/calibration",
        "/v1/health",
        "/metrics",
    ]
    .contains(&path)
}

fn route(
    service: &PlannerService,
    stats: &HttpStats,
    method: &str,
    path: &str,
    body: &[u8],
) -> (usize, u16, Payload, ReqFlags) {
    let with = |(ep, (status, payload, flags)): (usize, (u16, Payload, ReqFlags))| {
        (ep, status, payload, flags)
    };
    match (method, path) {
        ("GET", "/v1/health") => (
            EP_HEALTH,
            200,
            Payload::Json(health_json(service, stats)),
            ReqFlags::default(),
        ),
        ("GET", "/metrics") => (
            EP_METRICS,
            200,
            Payload::Text(metrics_text(service, stats)),
            ReqFlags::default(),
        ),
        ("GET", "/v1/calibration") => (
            EP_CALIBRATION,
            200,
            Payload::Json(calibration_json(service)),
            ReqFlags::default(),
        ),
        ("POST", "/v1/plan") => with((EP_PLAN, guarded(|| plan_endpoint(service, body, false)))),
        ("POST", "/v1/frontier") => {
            with((EP_FRONTIER, guarded(|| plan_endpoint(service, body, true))))
        }
        ("POST", "/v1/walls") => with((EP_WALLS, guarded(|| walls_endpoint(service, body)))),
        ("POST", "/v1/refit") => with((EP_REFIT, guarded(|| refit_endpoint(service, body)))),
        ("POST", "/v1/placement") => {
            with((EP_PLACEMENT, guarded(|| placement_endpoint(service, body))))
        }
        ("POST", "/v1/observe") => {
            with((EP_OBSERVE, guarded(|| observe_endpoint(service, body))))
        }
        (_, p) if known_path(p) => {
            let msg = format!("{method} not supported on {p}");
            (
                EP_OTHER,
                405,
                Payload::Json(wire::error_envelope("method_not_allowed", &msg)),
                ReqFlags::default(),
            )
        }
        (_, p) => {
            let msg = format!("no such endpoint `{p}` (api_version {API_VERSION})");
            (
                EP_OTHER,
                404,
                Payload::Json(wire::error_envelope("not_found", &msg)),
                ReqFlags::default(),
            )
        }
    }
}

/// Run a JSON handler with a panic firewall: a panicking request answers
/// 500 and the daemon lives on (the service layer has already recorded
/// a quarantine strike for the cell before re-raising).
fn guarded(f: impl FnOnce() -> (u16, Json, ReqFlags)) -> (u16, Payload, ReqFlags) {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok((status, body, flags)) => (status, Payload::Json(body), flags),
        Err(_) => (
            500,
            Payload::Json(wire::error_envelope("internal", "request handler panicked")),
            ReqFlags::default(),
        ),
    }
}

/// Map a typed service failure to its wire shape. The 504 carries the
/// partial accounting structurally; the quarantine 503 carries its
/// bounded retry-after.
fn service_error(e: &ServiceError, mut flags: ReqFlags) -> (u16, Json, ReqFlags) {
    match e {
        ServiceError::BadRequest(m) => (400, wire::error_envelope("bad_request", m), flags),
        ServiceError::DeadlineExceeded { probes_streamed, sims_priced, prices_modeled } => {
            flags.deadline = true;
            let mut env = wire::error_envelope("deadline_exceeded", &e.to_string());
            if let Json::Obj(pairs) = &mut env {
                pairs.push((
                    "accounting".to_string(),
                    Json::obj(vec![
                        ("probes_streamed", Json::int(*probes_streamed)),
                        ("sims_priced", Json::int(*sims_priced)),
                        ("prices_modeled", Json::int(*prices_modeled)),
                    ]),
                ));
            }
            (504, env, flags)
        }
        ServiceError::Quarantined { retry_after_s } => {
            flags.quarantined = true;
            let mut env = wire::error_envelope("quarantined", &e.to_string());
            if let Json::Obj(pairs) = &mut env {
                pairs.push(("retry_after_s".to_string(), Json::int(*retry_after_s)));
            }
            (503, env, flags)
        }
        ServiceError::Internal(m) => (500, wire::error_envelope("internal", m), flags),
    }
}

fn parse_body(body: &[u8]) -> Result<Json, String> {
    let text =
        std::str::from_utf8(body).map_err(|_| "request body is not valid UTF-8".to_string())?;
    if text.trim().is_empty() {
        // An explicitly empty body (Content-Length: 0, e.g. `curl -d ''`)
        // means "all defaults"; a POST with *unknown* length is rejected
        // upstream in `read_request`.
        return Ok(Json::obj(vec![]));
    }
    Json::parse(text)
}

fn plan_endpoint(service: &PlannerService, body: &[u8], frontier: bool) -> (u16, Json, ReqFlags) {
    let mut flags = ReqFlags::default();
    let params = match parse_body(body).and_then(|j| PlanParams::from_json(&j)) {
        Ok(p) => p,
        Err(e) => return (400, wire::error_envelope("bad_request", &e), flags),
    };
    match service.plan(&params) {
        Ok(reply) => {
            flags.memo_hit = Some(reply.memo_hit);
            let (kind, result) = if frontier {
                ("frontier", planner_report::frontier_result_json(&reply.outcome))
            } else {
                ("plan", planner_report::plan_result_json(&reply.outcome))
            };
            let mut resp = wire::envelope(kind, params.canonical(), &reply.warnings, result);
            push_calibration(&mut resp, reply.epoch, reply.calibration_fingerprint);
            if frontier {
                // Additive envelope field (api_version 1): what this
                // request actually ran. The deterministic `result` never
                // carries accounting, so a memo hit reports zeros while
                // the frontier bytes stay identical to the cold reply.
                let o = &reply.outcome;
                let pick = |v: u64| if reply.memo_hit { 0 } else { v };
                let acct = Json::obj(vec![
                    ("feasibility_probes", Json::int(pick(o.feasibility_probes))),
                    ("priced_sims", Json::int(pick(o.priced_sims))),
                    ("modeled_prices", Json::int(pick(o.modeled_prices))),
                    ("time_models", Json::int(pick(o.time_models))),
                    ("time_fallbacks", Json::int(pick(o.time_fallbacks))),
                ]);
                if let Json::Obj(pairs) = &mut resp {
                    pairs.push(("accounting".to_string(), acct));
                }
            }
            (200, resp, flags)
        }
        Err(e) => service_error(&e, flags),
    }
}

fn walls_endpoint(service: &PlannerService, body: &[u8]) -> (u16, Json, ReqFlags) {
    let mut flags = ReqFlags::default();
    let mut params = match parse_body(body).and_then(|j| WallsParams::from_json(&j)) {
        Ok(p) => p,
        Err(e) => return (400, wire::error_envelope("bad_request", &e), flags),
    };
    match params.at.clone() {
        Some(AtQuery::One(at)) => match service.walls_point(&params.plan, at) {
            Ok((q, warnings)) => {
                let result = planner_report::walls_at_json(&q);
                (200, wire::envelope("walls_at", params.canonical(), &warnings, result), flags)
            }
            Err(e) => service_error(&e, flags),
        },
        Some(AtQuery::Many(points)) => match service.walls_batch(&params.plan, &points) {
            Ok((qs, warnings)) => {
                let result = planner_report::walls_batch_json(&qs);
                (200, wire::envelope("walls_batch", params.canonical(), &warnings, result), flags)
            }
            Err(e) => service_error(&e, flags),
        },
        None => {
            // A walls sweep *is* a feasibility-only plan; force the flag
            // before both execution and the echo, so the canonical
            // `request` matches what was actually memoized and a client
            // replaying the echo gets the same sweep back.
            params.plan.feasibility_only = true;
            match service.walls_sweep(&params.plan) {
                Ok(reply) => {
                    flags.memo_hit = Some(reply.memo_hit);
                    let result = planner_report::plan_result_json(&reply.outcome);
                    let mut resp =
                        wire::envelope("walls", params.canonical(), &reply.warnings, result);
                    push_calibration(&mut resp, reply.epoch, reply.calibration_fingerprint);
                    (200, resp, flags)
                }
                Err(e) => service_error(&e, flags),
            }
        }
    }
}

fn refit_endpoint(service: &PlannerService, body: &[u8]) -> (u16, Json, ReqFlags) {
    let flags = ReqFlags::default();
    let params = match parse_body(body).and_then(|j| RefitParams::from_json(&j)) {
        Ok(p) => p,
        Err(e) => return (400, wire::error_envelope("bad_request", &e), flags),
    };
    match service.refit(&params) {
        Ok(reply) => {
            let result = Json::obj(vec![
                ("refit", planner_report::refit_json(&reply.info)),
                (
                    "calibration_fingerprint",
                    Json::string(&format!("{:016x}", reply.calibration_fingerprint)),
                ),
            ]);
            (200, wire::envelope("refit", params.canonical(), &reply.warnings, result), flags)
        }
        Err(e) => service_error(&e, flags),
    }
}

fn placement_endpoint(service: &PlannerService, body: &[u8]) -> (u16, Json, ReqFlags) {
    let mut flags = ReqFlags::default();
    let params = match parse_body(body).and_then(|j| PlacementParams::from_json(&j)) {
        Ok(p) => p,
        Err(e) => return (400, wire::error_envelope("bad_request", &e), flags),
    };
    match service.place(&params) {
        Ok(reply) => {
            flags.memo_hit = Some(reply.memo_hit);
            let result = planner_report::placement_result_json(&reply.outcome);
            let mut resp =
                wire::envelope("placement", params.canonical(), &reply.warnings, result);
            push_calibration(&mut resp, reply.epoch, reply.calibration_fingerprint);
            // Additive envelope field (api_version 1), mirroring the
            // frontier endpoint: what this request actually ran. A memo
            // hit reports zeros while the ranked placements stay
            // byte-identical to the cold reply.
            let o = &reply.outcome;
            let pick = |v: u64| if reply.memo_hit { 0 } else { v };
            let acct = Json::obj(vec![
                ("shapes_reused", Json::int(pick(o.shapes_reused))),
                ("distinct_hardware", Json::int(pick(o.distinct_hardware))),
                ("feasibility_probes", Json::int(pick(o.feasibility_probes))),
                ("anchor_sims", Json::int(pick(o.anchor_sims))),
                ("modeled_prices", Json::int(pick(o.modeled_prices))),
            ]);
            if let Json::Obj(pairs) = &mut resp {
                pairs.push(("accounting".to_string(), acct));
            }
            (200, resp, flags)
        }
        Err(e) => service_error(&e, flags),
    }
}

/// Append the additive `calibration` envelope field (api_version 1):
/// the epoch and fingerprint this reply was priced under. Memoized with
/// the outcome, so a warm replay's provenance is byte-identical to the
/// cold reply.
fn push_calibration(resp: &mut Json, epoch: u64, fingerprint: u64) {
    if let Json::Obj(pairs) = resp {
        pairs.push((
            "calibration".to_string(),
            Json::obj(vec![
                ("epoch", Json::int(epoch)),
                ("fingerprint", Json::string(&crate::calib::epoch::fingerprint_hex(fingerprint))),
            ]),
        ));
    }
}

/// `POST /v1/observe`: fold a telemetry batch into the online
/// calibrator. A parseable batch always answers 200 with its
/// accept/reject accounting — an all-rejected batch is signal (the MAD
/// gate working), not a request failure.
fn observe_endpoint(service: &PlannerService, body: &[u8]) -> (u16, Json, ReqFlags) {
    let flags = ReqFlags::default();
    let params = match parse_body(body).and_then(|j| ObserveParams::from_json(&j)) {
        Ok(p) => p,
        Err(e) => return (400, wire::error_envelope("bad_request", &e), flags),
    };
    let reply = service.observe(&params.observations);
    let hex = crate::calib::epoch::fingerprint_hex;
    let published = match &reply.published {
        None => Json::Null,
        Some(p) => Json::obj(vec![
            ("epoch", Json::int(p.epoch)),
            ("old_fingerprint", Json::string(&hex(p.old_fingerprint))),
            ("new_fingerprint", Json::string(&hex(p.new_fingerprint))),
            ("fields", Json::Arr(p.fields.iter().map(|f| f.to_json()).collect())),
        ]),
    };
    let invalidated = Json::Obj(
        reply
            .invalidated
            .iter()
            .map(|(tier, n)| (tier.to_string(), Json::int(*n)))
            .collect(),
    );
    let result = Json::obj(vec![
        ("accepted", Json::int(reply.accepted)),
        ("rejected", Json::int(reply.rejected)),
        ("epoch", Json::int(reply.epoch)),
        ("fingerprint", Json::string(&hex(reply.fingerprint))),
        ("drift", Json::Arr(reply.drift.iter().map(|d| d.to_json()).collect())),
        ("published", published),
        ("invalidated", invalidated),
        ("plans_invalidated", Json::int(reply.plans_invalidated)),
        ("placements_invalidated", Json::int(reply.placements_invalidated)),
    ]);
    (200, wire::envelope("observe", params.canonical(), &reply.notes, result), flags)
}

/// `GET /v1/calibration`: the active calibration document, health-style
/// (a bare object rather than a request/result envelope — there is no
/// request to echo).
fn calibration_json(service: &PlannerService) -> Json {
    match service.calibration_snapshot().to_json() {
        Json::Obj(mut pairs) => {
            pairs.insert(0, ("api_version".to_string(), Json::int(API_VERSION)));
            Json::Obj(pairs)
        }
        other => other,
    }
}

fn health_json(service: &PlannerService, stats: &HttpStats) -> Json {
    let st = service.stats();
    let sizes = service.caches().sizes();
    let tiers = service.caches().tiers();
    let mut tier_bytes = vec![
        ("budget", Json::int(service.cache_budget() as u64)),
        ("total", Json::int(service.cache_bytes() as u64)),
        ("plans", Json::int(service.plan_memo_bytes() as u64)),
        ("placements", Json::int(service.placement_memo_bytes() as u64)),
    ];
    for t in &tiers {
        tier_bytes.push((t.name, Json::int(t.bytes as u64)));
    }
    let mut tier_evictions = vec![
        ("plans", Json::int(service.plan_memo_evictions())),
        ("placements", Json::int(service.placement_memo_evictions())),
    ];
    for t in &tiers {
        tier_evictions.push((t.name, Json::int(t.evictions)));
    }
    // Epoch invalidations are correctness drops, reported separately
    // from the capacity-driven LRU evictions above.
    let mut tier_invalidations = vec![
        ("plans", Json::int(st.plans_invalidated)),
        ("placements", Json::int(st.placements_invalidated)),
    ];
    for t in &tiers {
        tier_invalidations.push((t.name, Json::int(t.invalidations)));
    }
    let (cal_epoch, cal_fp) = service.calibration_epoch();
    Json::obj(vec![
        ("api_version", Json::int(API_VERSION)),
        ("status", Json::string("ok")),
        ("uptime_s", Json::Num(stats.started.elapsed().as_secs_f64())),
        ("endpoints", stats.json()),
        (
            "http",
            Json::obj(vec![
                ("connections", Json::int(stats.connections.load(Ordering::Relaxed))),
                (
                    "keepalive_reuses",
                    Json::int(stats.keepalive_reuses.load(Ordering::Relaxed)),
                ),
                ("sheds", Json::int(stats.sheds.load(Ordering::Relaxed))),
                ("drain_refusals", Json::int(stats.drain_refusals.load(Ordering::Relaxed))),
            ]),
        ),
        (
            "service",
            Json::obj(vec![
                ("plan_requests", Json::int(st.plan_requests)),
                ("plan_memo_hits", Json::int(st.plan_memo_hits)),
                ("placement_requests", Json::int(st.placement_requests)),
                ("placement_memo_hits", Json::int(st.placement_memo_hits)),
                ("shapes_pruned", Json::int(st.shapes_pruned)),
                ("point_queries", Json::int(st.point_queries)),
                ("refits", Json::int(st.refits)),
                ("probes_streamed", Json::int(st.probes_streamed)),
                ("sims_priced", Json::int(st.sims_priced)),
                ("prices_modeled", Json::int(st.prices_modeled)),
                ("cache_evictions", Json::int(st.cache_evictions)),
                ("entries_evicted", Json::int(st.entries_evicted)),
                ("cells_quarantined", Json::int(st.cells_quarantined)),
                ("observations_accepted", Json::int(st.observations_accepted)),
                ("observations_rejected", Json::int(st.observations_rejected)),
                ("epochs_published", Json::int(st.epochs_published)),
                ("entries_invalidated", Json::int(st.entries_invalidated)),
            ]),
        ),
        (
            "calibration",
            Json::obj(vec![
                ("epoch", Json::int(cal_epoch)),
                (
                    "fingerprint",
                    Json::string(&crate::calib::epoch::fingerprint_hex(cal_fp)),
                ),
                ("epochs_published", Json::int(st.epochs_published)),
            ]),
        ),
        (
            "caches",
            Json::obj(vec![
                ("plans", Json::int(service.plan_memo_len() as u64)),
                ("placements", Json::int(service.placement_memo_len() as u64)),
                ("traces", Json::int(sizes[0] as u64)),
                ("peak_probes", Json::int(sizes[1] as u64)),
                ("budgeted_probes", Json::int(sizes[2] as u64)),
                ("priced_reports", Json::int(sizes[3] as u64)),
                ("models", Json::int(sizes[4] as u64)),
                ("time_models", Json::int(sizes[5] as u64)),
                ("walls", Json::int(sizes[6] as u64)),
            ]),
        ),
        ("cache_bytes", Json::obj(tier_bytes)),
        ("evictions", Json::obj(tier_evictions)),
        ("invalidations", Json::obj(tier_invalidations)),
    ])
}

/// `GET /metrics`: the `/v1/health` counters in the Prometheus text
/// exposition format, so a scrape job needs no JSON relabeling. Families
/// mirror the health document — per-endpoint served/error counts and
/// latency quantiles, service counters, and per-tier cache bytes /
/// entries / evictions — under a stable `repro_` prefix.
fn metrics_text(service: &PlannerService, stats: &HttpStats) -> String {
    let mut out = String::new();
    let mut family = |name: &str, kind: &str, help: &str, rows: &[(String, String)]| {
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
        for (labels, value) in rows {
            out.push_str(&format!("{name}{labels} {value}\n"));
        }
    };
    let (mut served, mut errors, mut latency) = (Vec::new(), Vec::new(), Vec::new());
    for (i, name) in ENDPOINTS.iter().enumerate() {
        let agg = stats.endpoints[i].lock().unwrap();
        served.push((format!("{{endpoint=\"{name}\"}}"), agg.served.to_string()));
        errors.push((format!("{{endpoint=\"{name}\"}}"), agg.errors.to_string()));
        for (q, label) in [(0.5, "0.5"), (0.95, "0.95")] {
            latency.push((
                format!("{{endpoint=\"{name}\",quantile=\"{label}\"}}"),
                format!("{}", agg.percentile(q)),
            ));
        }
    }
    family("repro_http_requests_total", "counter", "Requests served, by endpoint.", &served);
    family("repro_http_request_errors_total", "counter", "Error responses, by endpoint.", &errors);
    family(
        "repro_http_request_latency_ms",
        "gauge",
        "Request latency quantiles over the recent window, by endpoint.",
        &latency,
    );
    let scalar = |v: u64| vec![(String::new(), v.to_string())];
    family(
        "repro_http_connections_total",
        "counter",
        "Connections accepted and handed to a worker.",
        &scalar(stats.connections.load(Ordering::Relaxed)),
    );
    family(
        "repro_http_keepalive_reuses_total",
        "counter",
        "Requests served on an already-used connection.",
        &scalar(stats.keepalive_reuses.load(Ordering::Relaxed)),
    );
    family(
        "repro_http_sheds_total",
        "counter",
        "Connections answered 503 inline because the queue was full.",
        &scalar(stats.sheds.load(Ordering::Relaxed)),
    );
    family(
        "repro_http_drain_refusals_total",
        "counter",
        "Connections refused while the daemon was draining.",
        &scalar(stats.drain_refusals.load(Ordering::Relaxed)),
    );
    family(
        "repro_uptime_seconds",
        "gauge",
        "Seconds since the daemon started.",
        &[(String::new(), format!("{}", stats.started.elapsed().as_secs_f64()))],
    );
    let st = service.stats();
    for (name, help, v) in [
        ("repro_plan_requests_total", "Plan/walls/frontier sweeps requested.", st.plan_requests),
        (
            "repro_plan_memo_hits_total",
            "Sweeps answered from the whole-plan memo.",
            st.plan_memo_hits,
        ),
        (
            "repro_placement_requests_total",
            "Fleet placement sweeps requested.",
            st.placement_requests,
        ),
        (
            "repro_placement_memo_hits_total",
            "Placements answered from the whole-placement memo.",
            st.placement_memo_hits,
        ),
        (
            "repro_shapes_pruned_total",
            "Fleet shapes skipped before any probe by dominance pruning.",
            st.shapes_pruned,
        ),
        ("repro_point_queries_total", "Point capacity queries answered.", st.point_queries),
        ("repro_refits_total", "Calibration refits fitted.", st.refits),
        ("repro_probes_streamed_total", "Feasibility probes streamed.", st.probes_streamed),
        ("repro_sims_priced_total", "Anchor simulations priced.", st.sims_priced),
        (
            "repro_prices_modeled_total",
            "Prices answered from fitted step-time models.",
            st.prices_modeled,
        ),
        ("repro_cache_evictions_total", "Pressure-valve eviction passes.", st.cache_evictions),
        (
            "repro_cache_entries_evicted_total",
            "Entries dropped by the pressure valve.",
            st.entries_evicted,
        ),
        (
            "repro_epochs_published_total",
            "Calibration epochs published by drift crossing the threshold.",
            st.epochs_published,
        ),
        (
            "repro_cache_entries_invalidated_total",
            "Entries dropped by calibration-epoch invalidation, all tiers.",
            st.entries_invalidated,
        ),
    ] {
        family(name, "counter", help, &scalar(v));
    }
    family(
        "repro_observations_total",
        "counter",
        "Telemetry records ingested via /v1/observe, by gate outcome.",
        &[
            ("{status=\"accepted\"}".to_string(), st.observations_accepted.to_string()),
            ("{status=\"rejected\"}".to_string(), st.observations_rejected.to_string()),
        ],
    );
    family(
        "repro_calibration_epoch",
        "gauge",
        "The active calibration epoch (0 = the boot calibration).",
        &scalar(st.calibration_epoch),
    );
    family(
        "repro_cells_quarantined",
        "gauge",
        "Request cells currently tombstoned after an evaluation panic.",
        &scalar(st.cells_quarantined),
    );
    let tiers = service.caches().tiers();
    let tier_row = |tier: &str, v: u64| (format!("{{tier=\"{tier}\"}}"), v.to_string());
    let mut bytes = vec![
        tier_row("plans", service.plan_memo_bytes() as u64),
        tier_row("placements", service.placement_memo_bytes() as u64),
    ];
    let mut entries = vec![
        tier_row("plans", service.plan_memo_len() as u64),
        tier_row("placements", service.placement_memo_len() as u64),
    ];
    let mut evictions = vec![
        tier_row("plans", service.plan_memo_evictions()),
        tier_row("placements", service.placement_memo_evictions()),
    ];
    for t in &tiers {
        bytes.push(tier_row(t.name, t.bytes as u64));
        entries.push(tier_row(t.name, t.entries as u64));
        evictions.push(tier_row(t.name, t.evictions));
    }
    family("repro_cache_bytes", "gauge", "Approximate resident bytes, by cache tier.", &bytes);
    family("repro_cache_entries", "gauge", "Resident entries, by cache tier.", &entries);
    family(
        "repro_cache_tier_evictions_total",
        "counter",
        "Entries evicted, by cache tier.",
        &evictions,
    );
    let mut invalidations = vec![
        tier_row("plans", st.plans_invalidated),
        tier_row("placements", st.placements_invalidated),
    ];
    for t in &tiers {
        invalidations.push(tier_row(t.name, t.invalidations));
    }
    family(
        "repro_cache_tier_invalidations_total",
        "counter",
        "Entries dropped by calibration-epoch invalidation, by cache tier.",
        &invalidations,
    );
    family(
        "repro_cache_budget_bytes",
        "gauge",
        "Configured cache byte budget (0 = unbounded).",
        &scalar(if service.cache_budget() == usize::MAX {
            0
        } else {
            service.cache_budget() as u64
        }),
    );
    family(
        "repro_cache_total_bytes",
        "gauge",
        "Approximate resident bytes across every tier plus the request memos.",
        &scalar(service.cache_bytes() as u64),
    );
    out
}

fn find_subslice(hay: &[u8], needle: &[u8]) -> Option<usize> {
    hay.windows(needle.len()).position(|w| w == needle)
}

fn timed_out(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Read one request from `stream`, carrying leftover bytes across calls
/// in `buf` so pipelined requests are served in order without touching
/// the socket. Returns `Ok(None)` for a clean end between requests (peer
/// closed, nothing arrived within `idle`, or a drain began while the
/// connection was idle — the wait polls in [`IDLE_SLICE`]s so draining
/// workers come home promptly); a timeout or EOF *mid*-request is a
/// framing error — the stream cannot be resynced.
fn read_request(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    idle: Duration,
    draining: &AtomicBool,
) -> Result<Option<Request>, HttpError> {
    let mut chunk = [0u8; 4096];
    let idle_deadline = Instant::now() + idle;
    let head_end = loop {
        if let Some(pos) = find_subslice(buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Err(HttpError {
                status: 431,
                code: "headers_too_large",
                message: format!("request head exceeds {MAX_HEAD_BYTES} bytes"),
                keep: false,
            });
        }
        // Between requests the connection may sit idle for the keep-alive
        // window (sliced, so a drain is noticed); once the first byte of
        // a head arrives, the peer must finish it within the ordinary
        // I/O timeout.
        let wait = if buf.is_empty() {
            let remaining = idle_deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Ok(None);
            }
            remaining.min(IDLE_SLICE)
        } else {
            IO_TIMEOUT
        };
        let _ = stream.set_read_timeout(Some(wait));
        match stream.read(&mut chunk) {
            Ok(0) => {
                return if buf.is_empty() {
                    Ok(None)
                } else {
                    Err(HttpError::bad("truncated request"))
                };
            }
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if timed_out(&e) => {
                if buf.is_empty() {
                    if draining.load(Ordering::Relaxed) {
                        return Ok(None);
                    }
                    continue; // next slice of the idle window
                }
                return Err(HttpError::bad("timed out reading request"));
            }
            Err(e) => return Err(HttpError::bad(format!("reading request: {e}"))),
        }
    };
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| HttpError::bad("request head is not valid UTF-8"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("HTTP/1.1");
    // Ignore any query string: routing is by path.
    let path = target.split('?').next().unwrap_or("").to_string();
    if method.is_empty() || !path.starts_with('/') {
        return Err(HttpError::bad(format!("malformed request line `{request_line}`")));
    }
    let mut content_length: Option<usize> = None;
    // HTTP/1.0 defaults to one-shot; HTTP/1.1 to persistent.
    let mut close = version.eq_ignore_ascii_case("HTTP/1.0");
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            let key = k.trim();
            if key.eq_ignore_ascii_case("content-length") {
                let n = v
                    .trim()
                    .parse()
                    .map_err(|_| HttpError::bad(format!("bad Content-Length `{}`", v.trim())))?;
                content_length = Some(n);
            } else if key.eq_ignore_ascii_case("transfer-encoding") {
                // Chunked framing is not implemented; silently reading an
                // empty body would plan with defaults, which the wire
                // contract forbids ("a typo fails loudly").
                return Err(HttpError::bad(
                    "Transfer-Encoding is not supported; send Content-Length",
                ));
            } else if key.eq_ignore_ascii_case("connection") {
                let v = v.trim();
                if v.eq_ignore_ascii_case("close") {
                    close = true;
                } else if v.eq_ignore_ascii_case("keep-alive") {
                    close = false;
                }
            }
        }
    }
    // A POST whose body length is unknown must not default to empty for
    // the same reason; `-d ''` (Content-Length: 0) still means defaults.
    let content_length = match (method.as_str(), content_length) {
        (_, Some(n)) => n,
        ("POST", None) => {
            return Err(HttpError::bad("POST requires a Content-Length header"));
        }
        (_, None) => 0,
    };
    if content_length > MAX_BODY_BYTES {
        // The body is oversized but *declared*, so the stream is still
        // framed: drain and discard it into a fixed scratch buffer and
        // keep the connection for the next request. Past MAX_DRAIN_BYTES
        // (or if the peer stalls) give up and close instead.
        let mut keep = false;
        if content_length <= MAX_DRAIN_BYTES {
            let total = head_end + 4 + content_length;
            let mut remaining = total.saturating_sub(buf.len());
            buf.clear();
            let mut scratch = [0u8; 4096];
            let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
            keep = loop {
                if remaining == 0 {
                    break true;
                }
                let want = remaining.min(scratch.len());
                match stream.read(&mut scratch[..want]) {
                    Ok(0) => break false,
                    Ok(n) => remaining -= n,
                    Err(_) => break false,
                }
            };
        }
        return Err(HttpError {
            status: 413,
            code: "payload_too_large",
            message: format!("request body exceeds {MAX_BODY_BYTES} bytes"),
            keep,
        });
    }
    let total = head_end + 4 + content_length;
    while buf.len() < total {
        let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
        match stream.read(&mut chunk) {
            Ok(0) => return Err(HttpError::bad("truncated request body")),
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if timed_out(&e) => {
                return Err(HttpError::bad("timed out reading request body"));
            }
            Err(e) => return Err(HttpError::bad(format!("reading request body: {e}"))),
        }
    }
    let body = buf[head_end + 4..total].to_vec();
    // Keep any pipelined successor bytes for the next iteration.
    buf.drain(..total);
    Ok(Some(Request { method, path, body, close }))
}

/// Write one framed response; returns the bytes put on the wire (0 when
/// the write failed or was refused by the `http.write` failpoint — the
/// caller must treat the stream as dead either way).
fn write_response(stream: &mut TcpStream, status: u16, body: &Payload, keep_alive: bool) -> usize {
    if failpoint::fire("http.write").is_err() {
        // Injected socket fault: drop the response on the floor, exactly
        // like a peer that vanished mid-write.
        return 0;
    }
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Error",
    };
    let connection = if keep_alive { "keep-alive" } else { "close" };
    let (content_type, payload) = match body {
        Payload::Json(j) => ("application/json", j.pretty() + "\n"),
        // Prometheus text exposition format, version 0.0.4.
        Payload::Text(t) => ("text/plain; version=0.0.4", t.clone()),
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: {connection}\r\n\r\n",
        payload.len()
    );
    if stream.write_all(head.as_bytes()).is_err() {
        return 0;
    }
    if stream.write_all(payload.as_bytes()).is_err() {
        return head.len();
    }
    let _ = stream.flush();
    head.len() + payload.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One-shot helper: asks for `Connection: close` so `read_to_string`
    /// sees EOF right after the response.
    fn request(addr: SocketAddr, raw: &str) -> (u16, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        let status: u16 = resp.split_whitespace().nth(1).unwrap().parse().unwrap();
        let body = resp.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
        (status, body)
    }

    fn post(addr: SocketAddr, path: &str, body: &str) -> (u16, String) {
        let raw = format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\
             Content-Length: {}\r\n\r\n{body}",
            body.len()
        );
        request(addr, &raw)
    }

    /// A keep-alive POST: no `Connection` header, so the connection
    /// stays open for the next request.
    fn write_post(s: &mut TcpStream, path: &str, body: &str) {
        let raw = format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        s.write_all(raw.as_bytes()).unwrap();
    }

    /// Read exactly one framed response off a persistent connection,
    /// carrying pipelined leftover bytes in `buf`.
    fn read_one_response(s: &mut TcpStream, buf: &mut Vec<u8>) -> (u16, String, String) {
        let mut chunk = [0u8; 4096];
        let head_end = loop {
            if let Some(p) = find_subslice(buf, b"\r\n\r\n") {
                break p;
            }
            let n = s.read(&mut chunk).unwrap();
            assert!(n > 0, "connection closed mid-response");
            buf.extend_from_slice(&chunk[..n]);
        };
        let head = String::from_utf8(buf[..head_end].to_vec()).unwrap();
        let status: u16 = head.split_whitespace().nth(1).unwrap().parse().unwrap();
        let len: usize = head
            .lines()
            .find_map(|l| {
                let (k, v) = l.split_once(':')?;
                if k.trim().eq_ignore_ascii_case("content-length") {
                    v.trim().parse().ok()
                } else {
                    None
                }
            })
            .expect("response has Content-Length");
        let total = head_end + 4 + len;
        while buf.len() < total {
            let n = s.read(&mut chunk).unwrap();
            assert!(n > 0, "connection closed mid-body");
            buf.extend_from_slice(&chunk[..n]);
        }
        let body = String::from_utf8(buf[head_end + 4..total].to_vec()).unwrap();
        buf.drain(..total);
        (status, head, body)
    }

    const WARM_BODY: &str = r#"{"model":"llama3-8b","gpus":8,"quantum":"1M","cap":"8M",
                       "feasibility_only":true,"threads":2}"#;

    #[test]
    fn daemon_serves_plan_walls_health_and_errors() {
        let service = Arc::new(PlannerService::new());
        let handle = serve(Arc::clone(&service), "127.0.0.1:0", ServeOptions::default()).unwrap();
        let addr = handle.addr();
        let body = WARM_BODY;
        let (st, first) = post(addr, "/v1/plan", body);
        assert_eq!(st, 200, "{first}");
        assert!(first.contains("\"api_version\": 1"), "{first}");
        assert!(first.contains("\"kind\": \"plan\""));
        assert!(first.contains("\"configs\""));
        assert!(!first.contains("\"wall_s\""), "no run accounting in results");
        // The acceptance gate end to end: a repeated identical request is
        // served from the session memo, byte-for-byte identical.
        let (st2, second) = post(addr, "/v1/plan", body);
        assert_eq!(st2, 200);
        assert_eq!(first, second);
        // Warm point query on the same lattice: zero streamed probes.
        let at = r#"{"model":"llama3-8b","gpus":8,"quantum":"1M","cap":"8M",
                     "feasibility_only":true,"at":"6M"}"#;
        let (st3, walls) = post(addr, "/v1/walls", at);
        assert_eq!(st3, 200, "{walls}");
        assert!(walls.contains("\"kind\": \"walls_at\""));
        assert!(walls.contains("\"probes\": 0"), "{walls}");
        // Frontier shares the plan memo (same canonical request) and its
        // envelope accounting reports a memo-warm reply as zeros.
        let (st4, frontier) = post(addr, "/v1/frontier", body);
        assert_eq!(st4, 200);
        assert!(frontier.contains("\"kind\": \"frontier\""));
        assert!(frontier.contains("\"accounting\""), "{frontier}");
        assert!(frontier.contains("\"priced_sims\": 0"), "{frontier}");
        assert!(frontier.contains("\"modeled_prices\": 0"), "{frontier}");
        // Health: status, memo hit-rate, latency percentiles, cache sizes.
        let (st5, health) =
            request(addr, "GET /v1/health HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
        assert_eq!(st5, 200);
        assert!(health.contains("\"status\": \"ok\""), "{health}");
        assert!(health.contains("\"plan_memo_hits\": 2"), "{health}");
        assert!(health.contains("\"p95_ms\""));
        assert!(health.contains("\"walls\""));
        assert!(health.contains("\"cache_bytes\""), "{health}");
        assert!(health.contains("\"evictions\""), "{health}");
        assert!(health.contains("\"keepalive_reuses\""), "{health}");
        // Structured errors: 404 / 405 / 400 (parse, unknown field,
        // foreign api_version).
        let (s404, e404) =
            request(addr, "GET /v1/nope HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
        assert_eq!(s404, 404);
        assert!(e404.contains("\"code\": \"not_found\""), "{e404}");
        let (s405, e405) =
            request(addr, "GET /v1/plan HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
        assert_eq!(s405, 405);
        assert!(e405.contains("\"code\": \"method_not_allowed\""));
        let (s400, e400) = post(addr, "/v1/plan", "{not json");
        assert_eq!(s400, 400);
        assert!(e400.contains("\"code\": \"bad_request\""), "{e400}");
        let (su, eu) = post(addr, "/v1/plan", r#"{"modle":"x"}"#);
        assert_eq!(su, 400);
        assert!(eu.contains("unknown field"), "{eu}");
        let (sv, ev) = post(addr, "/v1/plan", r#"{"api_version":99}"#);
        assert_eq!(sv, 400);
        assert!(ev.contains("unsupported api_version"), "{ev}");
        handle.stop();
    }

    #[test]
    fn keep_alive_serves_identical_bytes_and_honors_close() {
        let service = Arc::new(PlannerService::new());
        let handle = serve(Arc::clone(&service), "127.0.0.1:0", ServeOptions::default()).unwrap();
        let addr = handle.addr();
        // One-shot reference response (also warms the session memo).
        let (st, oneshot) = post(addr, "/v1/plan", WARM_BODY);
        assert_eq!(st, 200, "{oneshot}");
        // Two sequential requests on ONE connection: both keep-alive,
        // both byte-identical to the one-shot body.
        let mut s = TcpStream::connect(addr).unwrap();
        let mut buf = Vec::new();
        write_post(&mut s, "/v1/plan", WARM_BODY);
        let (st1, head1, body1) = read_one_response(&mut s, &mut buf);
        assert_eq!(st1, 200);
        assert!(head1.contains("Connection: keep-alive"), "{head1}");
        assert_eq!(body1, oneshot);
        write_post(&mut s, "/v1/plan", WARM_BODY);
        let (st2, _, body2) = read_one_response(&mut s, &mut buf);
        assert_eq!(st2, 200);
        assert_eq!(body2, oneshot);
        // `Connection: close` is honored: response says close, then EOF.
        let raw = format!(
            "POST /v1/plan HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\
             Content-Length: {}\r\n\r\n{}",
            WARM_BODY.len(),
            WARM_BODY
        );
        s.write_all(raw.as_bytes()).unwrap();
        let (st3, head3, body3) = read_one_response(&mut s, &mut buf);
        assert_eq!(st3, 200);
        assert!(head3.contains("Connection: close"), "{head3}");
        assert_eq!(body3, oneshot);
        let mut rest = Vec::new();
        s.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty(), "server must close after Connection: close");
        // The daemon observed the reuse.
        let (_, health) =
            request(addr, "GET /v1/health HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
        assert!(health.contains("\"keepalive_reuses\": 2"), "{health}");
        handle.stop();
    }

    #[test]
    fn pipelined_requests_survive_an_early_routed_error() {
        let service = Arc::new(PlannerService::new());
        let handle = serve(Arc::clone(&service), "127.0.0.1:0", ServeOptions::default()).unwrap();
        let addr = handle.addr();
        let (_, warm) = post(addr, "/v1/plan", WARM_BODY);
        // Both requests written before reading anything: the first is a
        // routed 400 (bad JSON body, stream still framed), the second
        // must still answer — in order, from the same buffer.
        let mut s = TcpStream::connect(addr).unwrap();
        let bad = "{oops";
        let raw = format!(
            "POST /v1/plan HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{bad}\
             POST /v1/plan HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\
             Content-Length: {}\r\n\r\n{}",
            bad.len(),
            WARM_BODY.len(),
            WARM_BODY
        );
        s.write_all(raw.as_bytes()).unwrap();
        let mut buf = Vec::new();
        let (st1, _, err) = read_one_response(&mut s, &mut buf);
        assert_eq!(st1, 400);
        assert!(err.contains("\"code\": \"bad_request\""), "{err}");
        let (st2, _, body) = read_one_response(&mut s, &mut buf);
        assert_eq!(st2, 200);
        assert_eq!(body, warm, "pipelined warm reply matches the one-shot bytes");
        handle.stop();
    }

    #[test]
    fn idle_keep_alive_connections_are_closed_by_the_server() {
        let service = Arc::new(PlannerService::new());
        let opts = ServeOptions {
            keep_alive_timeout: Duration::from_millis(300),
            ..ServeOptions::default()
        };
        let handle = serve(Arc::clone(&service), "127.0.0.1:0", opts).unwrap();
        let addr = handle.addr();
        let mut s = TcpStream::connect(addr).unwrap();
        let mut buf = Vec::new();
        write_post(&mut s, "/v1/plan", WARM_BODY);
        let (st, head, _) = read_one_response(&mut s, &mut buf);
        assert_eq!(st, 200);
        assert!(head.contains("Connection: keep-alive"), "{head}");
        // Say nothing: the server hangs up within the idle window.
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut rest = Vec::new();
        s.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty(), "idle close sends no bytes");
        // keep_alive_timeout zero disables keep-alive outright.
        let svc2 = Arc::new(PlannerService::new());
        let opts2 = ServeOptions { keep_alive_timeout: Duration::ZERO, ..ServeOptions::default() };
        let h2 = serve(Arc::clone(&svc2), "127.0.0.1:0", opts2).unwrap();
        let mut s2 = TcpStream::connect(h2.addr()).unwrap();
        // No Connection: close, yet the response closes the connection.
        s2.write_all(b"GET /v1/health HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
        let mut resp = String::new();
        s2.read_to_string(&mut resp).unwrap();
        assert!(resp.contains("Connection: close"), "{resp}");
        h2.stop();
        handle.stop();
    }

    #[test]
    fn batch_walls_answers_a_curve_in_one_request() {
        let service = Arc::new(PlannerService::new());
        let handle = serve(Arc::clone(&service), "127.0.0.1:0", ServeOptions::default()).unwrap();
        let addr = handle.addr();
        // Warm the lattice with a sweep, then ask for a three-point curve.
        let (st, _) = post(addr, "/v1/plan", WARM_BODY);
        assert_eq!(st, 200);
        let batch = r#"{"model":"llama3-8b","gpus":8,"quantum":"1M","cap":"8M",
                        "feasibility_only":true,"at":["4M","5M","6M"]}"#;
        let (st2, resp) = post(addr, "/v1/walls", batch);
        assert_eq!(st2, 200, "{resp}");
        assert!(resp.contains("\"kind\": \"walls_batch\""), "{resp}");
        // Canonical echo keeps the batch in request order.
        for seq in ["4194304", "5242880", "6291456"] {
            assert!(resp.contains(seq), "{resp}");
        }
        assert_eq!(resp.matches("\"seq_lattice\"").count(), 3, "{resp}");
        // All three points answered from session memos: zero probes.
        assert!(resp.contains("\"probes\": 0"), "{resp}");
        // Batch edge cases are structured 400s.
        let (se, ee) = post(addr, "/v1/walls", r#"{"at":[]}"#);
        assert_eq!(se, 400);
        assert!(ee.contains("at least one point"), "{ee}");
        let over: Vec<String> = (1..=257).map(|i| i.to_string()).collect();
        let (so, eo) = post(addr, "/v1/walls", &format!("{{\"at\":[{}]}}", over.join(",")));
        assert_eq!(so, 400);
        assert!(eo.contains("at most 256"), "{eo}");
        handle.stop();
    }

    #[test]
    fn placement_endpoint_serves_ranked_fleet_and_memoizes() {
        let service = Arc::new(PlannerService::new());
        let handle = serve(Arc::clone(&service), "127.0.0.1:0", ServeOptions::default()).unwrap();
        let addr = handle.addr();
        let body = r#"{"model":"llama3-8b","paper":true,"quantum":"1M","cap":"8M","threads":2,
            "feasibility_only":true,
            "fleet":{"pools":[{"name":"east","device":"h100","nodes":1},
                              {"name":"lab","device":"h200","nodes":1}]}}"#;
        let (st, first) = post(addr, "/v1/placement", body);
        assert_eq!(st, 200, "{first}");
        assert!(first.contains("\"kind\": \"placement\""), "{first}");
        assert!(first.contains("\"placements\""), "{first}");
        assert!(first.contains("\"pruned_by\": \"lab/1x8\""), "{first}");
        assert!(first.contains("\"shapes_pruned\": 1"), "{first}");
        assert!(first.contains("\"accounting\""), "{first}");
        // Warm replay: identical request, byte-identical ranked result,
        // zeroed accounting (nothing ran).
        let (st2, second) = post(addr, "/v1/placement", body);
        assert_eq!(st2, 200);
        let result_of = |resp: &str| resp.split("\"accounting\"").next().unwrap().to_string();
        assert_eq!(result_of(&first), result_of(&second));
        assert!(second.contains("\"feasibility_probes\": 0"), "{second}");
        // Structured errors: a plan-only field is rejected loudly.
        let (se, ee) = post(addr, "/v1/placement", r#"{"gpus":8}"#);
        assert_eq!(se, 400);
        assert!(ee.contains("not a placement field"), "{ee}");
        // Health sees the placement counters (the 400 never reached the
        // service, so only the two routed requests count).
        let (_, health) =
            request(addr, "GET /v1/health HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
        assert!(health.contains("\"placement_requests\": 2"), "{health}");
        assert!(health.contains("\"placement_memo_hits\": 1"), "{health}");
        assert!(health.contains("\"shapes_pruned\": 1"), "{health}");
        handle.stop();
    }

    #[test]
    fn metrics_endpoint_exports_prometheus_text() {
        let service = Arc::new(PlannerService::new());
        let handle = serve(Arc::clone(&service), "127.0.0.1:0", ServeOptions::default()).unwrap();
        let addr = handle.addr();
        let (st, _) = post(addr, "/v1/plan", WARM_BODY);
        assert_eq!(st, 200);
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GET /metrics HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n").unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        let status: u16 = resp.split_whitespace().nth(1).unwrap().parse().unwrap();
        assert_eq!(status, 200, "{resp}");
        assert!(resp.contains("Content-Type: text/plain"), "{resp}");
        let body = resp.split("\r\n\r\n").nth(1).unwrap_or("");
        assert!(body.contains("# TYPE repro_http_requests_total counter"), "{body}");
        assert!(body.contains("repro_http_requests_total{endpoint=\"plan\"} 1"), "{body}");
        assert!(
            body.contains("repro_http_request_latency_ms{endpoint=\"plan\",quantile=\"0.95\"}"),
            "{body}"
        );
        assert!(body.contains("repro_plan_requests_total 1"), "{body}");
        assert!(body.contains("repro_placement_requests_total 0"), "{body}");
        assert!(body.contains("repro_shapes_pruned_total 0"), "{body}");
        assert!(body.contains("repro_cache_bytes{tier=\"walls\"}"), "{body}");
        assert!(body.contains("repro_cache_bytes{tier=\"placements\"}"), "{body}");
        assert!(body.contains("repro_cache_tier_evictions_total{tier=\"plans\"}"), "{body}");
        assert!(body.contains("repro_http_keepalive_reuses_total"), "{body}");
        // GET-only: a POST to the scrape path is a structured 405.
        let (sm, em) = post(addr, "/metrics", "{}");
        assert_eq!(sm, 405);
        assert!(em.contains("method_not_allowed"), "{em}");
        handle.stop();
    }

    #[test]
    fn observe_and_calibration_endpoints_round_trip() {
        let service = Arc::new(PlannerService::new());
        let handle = serve(Arc::clone(&service), "127.0.0.1:0", ServeOptions::default()).unwrap();
        let addr = handle.addr();
        // A component-less record parses and routes, but contributes no
        // invertible sample: the batch counts it rejected and publishes
        // nothing.
        let body =
            r#"{"observations":[{"method":"ulysses","model":"llama3-8b","gpus":8,"seq":"1M"}]}"#;
        let (st, resp) = post(addr, "/v1/observe", body);
        assert_eq!(st, 200, "{resp}");
        assert!(resp.contains("\"kind\": \"observe\""), "{resp}");
        assert!(resp.contains("\"accepted\": 0"), "{resp}");
        assert!(resp.contains("\"rejected\": 1"), "{resp}");
        assert!(resp.contains("\"epoch\": 0"), "{resp}");
        assert!(resp.contains("\"published\": null"), "{resp}");
        // The calibration document: boot epoch, every constant visible.
        let (st2, cal) =
            request(addr, "GET /v1/calibration HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
        assert_eq!(st2, 200, "{cal}");
        assert!(cal.contains("\"epoch\": 0"), "{cal}");
        assert!(cal.contains("\"fa3_fwd_flops\""), "{cal}");
        assert!(cal.contains("\"history\""), "{cal}");
        // Structured errors: a bad record names its index; the document
        // path is GET-only.
        let (se, ee) = post(addr, "/v1/observe", r#"{"observations":[{"method":"warp"}]}"#);
        assert_eq!(se, 400);
        assert!(ee.contains("observations[0]"), "{ee}");
        let (sm, em) = post(addr, "/v1/calibration", "{}");
        assert_eq!(sm, 405);
        assert!(em.contains("method_not_allowed"), "{em}");
        // Health and metrics surface the new counters.
        let (_, health) =
            request(addr, "GET /v1/health HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
        assert!(health.contains("\"observations_rejected\": 1"), "{health}");
        assert!(health.contains("\"invalidations\""), "{health}");
        assert!(health.contains("\"calibration\""), "{health}");
        let (_, metrics) =
            request(addr, "GET /metrics HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
        assert!(
            metrics.contains("repro_observations_total{status=\"rejected\"} 1"),
            "{metrics}"
        );
        assert!(metrics.contains("repro_calibration_epoch 0"), "{metrics}");
        assert!(
            metrics.contains("repro_cache_tier_invalidations_total{tier=\"walls\"} 0"),
            "{metrics}"
        );
        handle.stop();
    }

    #[test]
    fn error_envelopes_are_byte_for_byte_stable() {
        let service = Arc::new(PlannerService::new());
        let handle = serve(Arc::clone(&service), "127.0.0.1:0", ServeOptions::default()).unwrap();
        let addr = handle.addr();
        // Every error body must be exactly what the envelope builder
        // renders — clients pin these bytes.
        let golden = |code: &str, msg: &str| wire::error_envelope(code, msg).pretty() + "\n";
        // 404.
        let (st, body) =
            request(addr, "GET /v1/nope HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
        assert_eq!(st, 404);
        assert_eq!(body, golden("not_found", "no such endpoint `/v1/nope` (api_version 1)"));
        // 400 parse (message comes from the JSON parser itself).
        let parse_err = parse_body(b"{nope").unwrap_err();
        let (st, body) = post(addr, "/v1/plan", "{nope");
        assert_eq!(st, 400);
        assert_eq!(body, golden("bad_request", &parse_err));
        // 504 deadline: `deadline_ms: 0` is deterministic — zero work ran,
        // and the envelope carries the partial accounting structurally.
        let deadline_body = r#"{"model":"llama3-8b","gpus":8,"quantum":"1M","cap":"8M",
                       "feasibility_only":true,"threads":2,"deadline_ms":0}"#;
        let (st, body) = post(addr, "/v1/plan", deadline_body);
        assert_eq!(st, 504, "{body}");
        let e = ServiceError::DeadlineExceeded {
            probes_streamed: 0,
            sims_priced: 0,
            prices_modeled: 0,
        };
        let (_, env, _) = service_error(&e, ReqFlags::default());
        assert_eq!(body, env.pretty() + "\n");
        assert!(body.contains("\"accounting\""), "{body}");
        // The 500-panic and 503-quarantined envelopes are pinned in
        // `tests/service_faults.rs` — arming a consumable failpoint on a
        // production site must not share a process with unrelated
        // concurrent sweeps. 503 shed envelope, pinned at builder level
        // (the queue-full path needs real overload to trigger).
        assert!(golden("overloaded", "request queue is full; retry later")
            .contains("\"code\": \"overloaded\""));
        handle.stop();
    }

    #[test]
    fn oversized_body_answers_413_and_keeps_the_connection() {
        let service = Arc::new(PlannerService::new());
        let handle = serve(Arc::clone(&service), "127.0.0.1:0", ServeOptions::default()).unwrap();
        let addr = handle.addr();
        let (_, warm) = post(addr, "/v1/plan", WARM_BODY);
        let mut s = TcpStream::connect(addr).unwrap();
        // Declare (and actually send) a body one byte over the cap.
        let oversized = MAX_BODY_BYTES + 1;
        let head =
            format!("POST /v1/plan HTTP/1.1\r\nHost: t\r\nContent-Length: {oversized}\r\n\r\n");
        s.write_all(head.as_bytes()).unwrap();
        let chunk = [b'x'; 4096];
        let mut sent = 0;
        while sent < oversized {
            let n = chunk.len().min(oversized - sent);
            s.write_all(&chunk[..n]).unwrap();
            sent += n;
        }
        let mut buf = Vec::new();
        let (st, head1, body) = read_one_response(&mut s, &mut buf);
        assert_eq!(st, 413, "{body}");
        assert!(head1.contains("Connection: keep-alive"), "drained 413 keeps: {head1}");
        let msg = format!("request body exceeds {MAX_BODY_BYTES} bytes");
        let golden = wire::error_envelope("payload_too_large", &msg).pretty() + "\n";
        assert_eq!(body, golden);
        // The same connection serves the next request normally.
        write_post(&mut s, "/v1/plan", WARM_BODY);
        let (st2, _, body2) = read_one_response(&mut s, &mut buf);
        assert_eq!(st2, 200);
        assert_eq!(body2, warm, "reply after a drained 413 matches the warm bytes");
        handle.stop();
    }

    #[test]
    fn drain_finishes_in_flight_and_refuses_new_connections() {
        let _g = crate::util::failpoint::test_serial();
        failpoint::clear_all();
        // Stretch the cold sweep so it is provably in flight when the
        // drain begins.
        failpoint::set("planner.probe", failpoint::Policy::Delay(2));
        let service = Arc::new(PlannerService::new());
        let handle = serve(Arc::clone(&service), "127.0.0.1:0", ServeOptions::default()).unwrap();
        let addr = handle.addr();
        let slow = std::thread::spawn(move || post(addr, "/v1/plan", WARM_BODY));
        // Wait until the worker has started evaluating it.
        let t0 = Instant::now();
        while service.stats().plan_requests == 0 && t0.elapsed() < Duration::from_secs(10) {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(service.stats().plan_requests, 1, "slow request never started");
        handle.begin_drain();
        // New connections are refused with a Connection: close 503.
        let (st, body) =
            request(addr, "GET /v1/health HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
        assert_eq!(st, 503, "{body}");
        assert!(body.contains("\"code\": \"draining\""), "{body}");
        // The drain waits for the in-flight sweep and comes home clean.
        let stats = handle.drain(Duration::from_secs(60));
        assert!(stats.drained, "in-flight request outlived the drain window");
        assert_eq!(stats.in_flight_at_deadline, 0);
        assert!(stats.refused >= 1, "the probe connection was refused");
        let (st, body) = slow.join().unwrap();
        assert_eq!(st, 200, "in-flight request completed during drain: {body}");
        failpoint::clear_all();
    }

    #[test]
    fn access_log_writes_one_jsonl_line_per_request() {
        let path =
            std::env::temp_dir().join(format!("repro_access_log_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let service = Arc::new(PlannerService::new());
        let opts = ServeOptions { access_log: Some(path.clone()), ..ServeOptions::default() };
        let handle = serve(Arc::clone(&service), "127.0.0.1:0", opts).unwrap();
        let addr = handle.addr();
        let (st, _) = post(addr, "/v1/plan", WARM_BODY);
        assert_eq!(st, 200);
        let (st, _) = post(addr, "/v1/plan", WARM_BODY);
        assert_eq!(st, 200);
        let (st, _) =
            request(addr, "GET /v1/health HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
        assert_eq!(st, 200);
        let (st, _) = post(addr, "/v1/plan", "{nope");
        assert_eq!(st, 400);
        handle.stop();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4, "one line per request:\n{text}");
        for l in &lines {
            let j = Json::parse(l).unwrap_or_else(|e| panic!("bad JSONL `{l}`: {e}"));
            for key in [
                "ts_ms",
                "endpoint",
                "status",
                "ms",
                "bytes",
                "memo",
                "shed",
                "deadline",
                "quarantined",
                "keep",
            ] {
                assert!(j.get(key).is_some(), "line missing `{key}`: {l}");
            }
        }
        assert!(lines[0].contains("\"endpoint\":\"plan\""), "{}", lines[0]);
        assert!(lines[0].contains("\"memo\":\"miss\""), "{}", lines[0]);
        assert!(lines[1].contains("\"memo\":\"hit\""), "{}", lines[1]);
        assert!(lines[2].contains("\"endpoint\":\"health\""), "{}", lines[2]);
        assert!(lines[3].contains("\"status\":400"), "{}", lines[3]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn refit_endpoint_round_trips_measurements() {
        let service = Arc::new(PlannerService::new());
        let handle = serve(Arc::clone(&service), "127.0.0.1:0", ServeOptions::default()).unwrap();
        let addr = handle.addr();
        let text = std::fs::read_to_string(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../examples/table5_measurements.json"
        ))
        .unwrap();
        let body = format!("{{\"measurements\": {}}}", text.trim());
        let (st, resp) = post(addr, "/v1/refit", &body);
        assert_eq!(st, 200, "{resp}");
        assert!(resp.contains("\"kind\": \"refit\""));
        assert!(resp.contains("\"calibration_fingerprint\""));
        assert!(resp.contains("fa3_fwd_flops"), "{resp}");
        // Missing payload is a structured 400.
        let (sm, em) = post(addr, "/v1/refit", "{}");
        assert_eq!(sm, 400);
        assert!(em.contains("missing `measurements`"), "{em}");
        handle.stop();
    }
}
