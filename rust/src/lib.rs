//! # untied-ulysses
//!
//! Reproduction of *Untied Ulysses: Memory-Efficient Context Parallelism via
//! Headwise Chunking* (UPipe) as a three-layer Rust + JAX + Pallas stack.
//!
//! Layer map (see `DESIGN.md`):
//! - **L3 (this crate)** — the paper's coordination contribution: context-
//!   parallel schedules ([`schedule`]), a calibrated cluster/memory/collective
//!   simulator ([`cluster`], [`memory`], [`collectives`], [`engine`]) that
//!   regenerates every table/figure ([`report`]), a capacity planner
//!   ([`planner`]) served as a long-lived session API with persistent
//!   cross-request caches and an HTTP daemon ([`service`]), and a
//!   *functional* multi-rank UPipe pipeline ([`coordinator`]) that moves
//!   real tensors between rank buffers and executes AOT-compiled
//!   JAX/Pallas programs through PJRT ([`runtime`]).
//! - **L2/L1 (python/, build-time only)** — the JAX transformer and Pallas
//!   kernels, lowered once to HLO text in `artifacts/` by `make artifacts`.
//!   Python never runs on the request path.

pub mod calib;
pub mod cluster;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod memory;
pub mod model;
pub mod planner;
pub mod report;
pub mod runtime;
pub mod schedule;
pub mod service;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
