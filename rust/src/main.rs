//! `repro` — the leader CLI.
//!
//! Paper artifacts:
//!   repro table1|table2|table3|table4|table5|table6   regenerate tables
//!   repro fig1|fig2|fig4|fig5|fig6                    regenerate figures
//!   repro savings                                     §3.4 headline
//!   repro all                                         everything above
//! Simulation:
//!   repro simulate --model llama3-8b --method upipe --seq 1M
//! Planning:
//!   repro plan --model llama3-8b --gpus 8 [--json]    sweep every valid
//!       config, bisect max trainable context, rank (the "5M" search)
//!   repro frontier --model ... [--json]               Pareto frontier only
//! Functional runtime (needs `make artifacts`):
//!   repro parity        distributed UPipe vs monolithic logits check
//!   repro train N       N training steps of the SMALL model (AOT step)
//!   repro serve N       serve N random requests, report latency
//! Meta:
//!   repro deviation     mean |sim - paper| over Tables 3+4

use untied_ulysses::config::presets::{llama_single_node, qwen_two_node};
use untied_ulysses::config::{AcMode, CpMethod};
use untied_ulysses::coordinator::trainer::{MarkovCorpus, Trainer};
use untied_ulysses::coordinator::{AttnMode, Pipeline};
use untied_ulysses::model::ModelDims;
use untied_ulysses::report::{figures, savings, tables};
use untied_ulysses::runtime::Runtime;
use untied_ulysses::schedule::simulate;
use untied_ulysses::util::fmt::{parse_tokens, GIB};
use untied_ulysses::util::rng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let code = match run(cmd, &args[1.min(args.len())..]) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, rest: &[String]) -> anyhow::Result<()> {
    match cmd {
        "table1" => {
            tables::table1_report(&ModelDims::llama3_8b(), 1 << 20).print();
            tables::table1_report(&ModelDims::qwen3_32b(), 1 << 20).print();
        }
        "table2" => {
            tables::table2_report(&ModelDims::llama3_8b(), 8).print();
            tables::table2_report(&ModelDims::qwen3_32b(), 8).print();
        }
        "table3" => {
            tables::table3_report(false).print();
            tables::table3_report(true).print();
        }
        "table4" => {
            tables::table4_report(false).print();
            tables::table4_report(true).print();
        }
        "table5" => tables::table5_report().print(),
        "table6" => {
            tables::table6_report(&ModelDims::llama3_8b(), 8).print();
            tables::table6_report(&ModelDims::qwen3_32b(), 8).print();
        }
        "fig1" => figures::fig1_report().print(),
        "fig2" => figures::fig2_report().print(),
        "fig4" => figures::fig4_report().print(),
        "fig5" => figures::fig5_report().print(),
        "fig6" => figures::fig6_report().print(),
        "savings" => savings::savings_report(1 << 20).print(),
        "all" => {
            for c in [
                "table1", "table2", "table3", "table4", "table5", "table6", "fig1",
                "fig2", "fig4", "fig5", "fig6", "savings", "deviation",
            ] {
                run(c, &[])?;
                println!();
            }
        }
        "deviation" => {
            let (d_l, n_l) = tables::grid_deviation(false);
            let (d_q, n_q) = tables::grid_deviation(true);
            println!(
                "mean |sim-paper|/paper: llama {:.1}% ({n_l} cells), qwen {:.1}% ({n_q} cells)",
                100.0 * d_l,
                100.0 * d_q
            );
        }
        "compose" => cmd_compose()?,
        "plan" => cmd_plan(rest, false)?,
        "frontier" => cmd_plan(rest, true)?,
        "simulate" => cmd_simulate(rest)?,
        "parity" => cmd_parity()?,
        "train" => cmd_train(rest)?,
        "serve" => cmd_serve(rest)?,
        "help" | "--help" | "-h" => print!("{HELP}"),
        other => anyhow::bail!("unknown command `{other}` (see `repro help`)"),
    }
    Ok(())
}

const HELP: &str = "\
repro — Untied Ulysses (UPipe) reproduction

  repro table1..table6 | fig1 | fig2 | fig4 | fig5 | fig6 | savings | all
  repro deviation
  repro simulate --model llama3-8b|qwen3-32b --method native|ring|ulysses|fpdt|upipe --seq 1M
                 [--ac ao|gpu|noac] [--mb N]
  repro plan --model llama3-8b --gpus 8 [--seq 1M] [--quantum 128K] [--cap 32M]
             [--ac ao,gpu,noac] [--mb 1,2,4] [--tp 1,2] [--paper] [--compose]
             [--refit measurements.json] [--threads N] [--feasibility-only]
             [--cold] [--json]
      sweep every valid parallel config for the model/cluster — method
      families x AC modes x micro-batches x TP mixes x pinning — solve
      each one's max trainable context (sampled-polynomial peak models,
      walls verified with two streamed probes), rank, and mark the Pareto
      frontier. --paper restricts to the paper's §5.1 dims (offloaded AC,
      batch 1, no TP); --refit re-derives the fitted calibration rates
      from a Table-5-style measurements file and replans with them
      (provenance is echoed into the table notes / JSON `refit` field);
      --feasibility-only skips all reference-length pricing and reports
      walls only (multi-node N x 8 frontier sweeps become near-free);
      --cold disables the symbolic solver and warm starts (probe-per-
      bisection reference path, identical results)
  repro frontier ...  same flags; print only the Pareto frontier
  repro compose       UPipe x FPDT composition study (paper §5.3.2)
  repro parity
  repro train [steps=100]
  repro serve [requests=20]
";

fn flag(rest: &[String], name: &str) -> Option<String> {
    rest.iter()
        .position(|a| a == name)
        .and_then(|i| rest.get(i + 1))
        .cloned()
}

fn cmd_compose() -> anyhow::Result<()> {
    use untied_ulysses::util::fmt::tokens;
    use untied_ulysses::util::table::Table;
    let mut t = Table::new(
        "UPipe x FPDT composition (Llama3-8B, 8xH100) — paper §5.3.2",
        &["S", "UPipe GiB", "FPDT GiB", "UPipe+FPDT GiB", "UPipe tok/s", "UPipe+FPDT tok/s"],
    );
    let upipe = CpMethod::Upipe { u: 8, gqa_schedule: true };
    let fpdt = CpMethod::Fpdt { pi: 16 };
    let comp = CpMethod::UpipeFpdt { u: 8, pi: 16 };
    for label in ["1M", "3M", "5M", "6M", "8M", "10M"] {
        let s = parse_tokens(label).unwrap();
        let cell = |m: CpMethod| {
            let r = simulate(&llama_single_node(m, s));
            if r.oom || r.failed.is_some() {
                ("OOM".to_string(), "-".to_string())
            } else {
                (
                    format!("{:.1}", r.peak_bytes / GIB),
                    r.tokens_per_sec_per_gpu(s, 8)
                        .map(|v| format!("{v:.0}"))
                        .unwrap_or_else(|| "-".into()),
                )
            }
        };
        let (mu, tu) = cell(upipe);
        let (mf, _) = cell(fpdt);
        let (mc, tc) = cell(comp);
        t.row(vec![tokens(s), mu, mf, mc, tu, tc]);
    }
    t.note("composition keeps FPDT-level memory with UPipe's GQA comm schedule;");
    t.note("it inherits FPDT's CPU-stall throughput cost — the paper's anticipated tradeoff");
    t.print();
    Ok(())
}

fn parse_u64_list(s: &str, what: &str) -> anyhow::Result<Vec<u64>> {
    s.split(',')
        .map(|x| {
            x.trim()
                .parse::<u64>()
                .map_err(|_| anyhow::anyhow!("bad {what} entry `{x}`"))
        })
        .collect()
}

fn cmd_plan(rest: &[String], frontier_only: bool) -> anyhow::Result<()> {
    use untied_ulysses::config::ClusterConfig;
    use untied_ulysses::engine::{refit, Calibration, Measurements};
    use untied_ulysses::planner::{plan, PlanRequest, SweepDims};
    use untied_ulysses::report::planner as planner_report;

    let model_name = flag(rest, "--model").unwrap_or_else(|| "llama3-8b".into());
    let model = ModelDims::by_name(&model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown --model {model_name}"))?;
    let gpus: u64 = match flag(rest, "--gpus") {
        Some(g) => g.parse().map_err(|_| anyhow::anyhow!("bad --gpus {g}"))?,
        None => 8,
    };
    let cluster = ClusterConfig::h100_cluster(gpus).map_err(anyhow::Error::msg)?;
    let mut req = PlanRequest::new(model, cluster);
    if let Some(s) = flag(rest, "--seq") {
        req.reference_s = parse_tokens(&s).ok_or_else(|| anyhow::anyhow!("bad --seq {s}"))?;
    }
    if let Some(q) = flag(rest, "--quantum") {
        req.quantum = parse_tokens(&q).ok_or_else(|| anyhow::anyhow!("bad --quantum {q}"))?;
    }
    if let Some(c) = flag(rest, "--cap") {
        req.cap_s = parse_tokens(&c).ok_or_else(|| anyhow::anyhow!("bad --cap {c}"))?;
    }
    if let Some(t) = flag(rest, "--threads") {
        req.threads = t.parse().map_err(|_| anyhow::anyhow!("bad --threads {t}"))?;
    }
    if rest.iter().any(|a| a == "--paper") {
        req.dims = SweepDims::paper();
    }
    if let Some(ac) = flag(rest, "--ac") {
        let modes = ac
            .split(',')
            .map(|m| {
                AcMode::parse(m.trim())
                    .ok_or_else(|| anyhow::anyhow!("bad --ac entry `{m}` (ao|gpu|noac)"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        // Dedup (order-preserving): repeated entries would enumerate
        // duplicate configs.
        let mut deduped: Vec<AcMode> = Vec::new();
        for m in modes {
            if !deduped.contains(&m) {
                deduped.push(m);
            }
        }
        req.dims.ac_modes = deduped;
    }
    if let Some(mb) = flag(rest, "--mb") {
        let mut v = parse_u64_list(&mb, "--mb")?;
        v.sort_unstable();
        v.dedup();
        req.dims.micro_batches = v;
    }
    if let Some(tp) = flag(rest, "--tp") {
        let mut v = parse_u64_list(&tp, "--tp")?;
        v.sort_unstable();
        v.dedup();
        req.dims.tp_degrees = v;
    }
    req.dims.compositions = req.dims.compositions || rest.iter().any(|a| a == "--compose");
    // --cold disables the symbolic wall solver *and* the warm-started
    // fallback bisections, restoring the probe-per-bisection reference
    // path end to end (identical results, O(log S) more probes) — a
    // debugging/benchmarking switch.
    let cold = rest.iter().any(|a| a == "--cold");
    req.warm_start = !cold;
    req.symbolic = !cold;
    // --feasibility-only skips phase-2 pricing: walls-only tables/JSON.
    req.feasibility_only = rest.iter().any(|a| a == "--feasibility-only");
    if let Some(path) = flag(rest, "--refit") {
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading --refit {path}: {e}"))?;
        let m = Measurements::parse(&text, &path).map_err(anyhow::Error::msg)?;
        anyhow::ensure!(
            m.model == req.model.name,
            "--refit file measures `{}` but --model is `{}`",
            m.model,
            req.model.name
        );
        let (cal, mut info) = refit(&Calibration::default(), &m, &req.model)
            .map_err(anyhow::Error::msg)?;
        eprintln!(
            "refit from {path}: {} cells, anchored at {} tokens;{}",
            info.cells,
            untied_ulysses::util::fmt::tokens(info.anchor_seq),
            info.fields.iter().fold(String::new(), |mut s, f| {
                s.push_str(&format!(" {} {:.3e} -> {:.3e};", f.name, f.old, f.new));
                s
            })
        );
        if !info.skipped.is_empty() {
            eprintln!(
                "WARNING: refit kept defaults for {} (measurements at or below the \
                 modelled overhead floor)",
                info.skipped.join(", ")
            );
        }
        // Pressure sanity: simulate the measured anchor cell. If it runs
        // with headroom below the pressure threshold, its measured times
        // already include the allocator-pressure penalties the engine
        // re-applies during the sweep — the refit rates absorb them.
        // refit guarantees a single-node (<= 8 GPU) Ulysses anchor.
        let anchor_cluster = ClusterConfig::h100_cluster(m.gpus).map_err(anyhow::Error::msg)?;
        let anchor_preset = untied_ulysses::config::presets::RunPreset {
            model: req.model.clone(),
            parallel: untied_ulysses::config::ParallelConfig::new(
                CpMethod::Ulysses,
                anchor_cluster.total_gpus(),
            ),
            cluster: anchor_cluster,
            seq_len: info.anchor_seq,
        };
        let q = untied_ulysses::schedule::Quantities::new(&anchor_preset);
        let anchor_report = simulate(&anchor_preset);
        let headroom = q.hbm_limit - anchor_report.peak_bytes;
        if headroom < cal.pressure_h0_gib * GIB {
            info.pressured_anchor = true;
            eprintln!(
                "WARNING: anchor cell ({} tokens) runs with only {:.1} GiB of predicted \
                 headroom — its measured times include memory-pressure penalties, so the \
                 refit rates are pessimistic near the memory walls; prefer an anchor at \
                 shorter context",
                untied_ulysses::util::fmt::tokens(info.anchor_seq),
                headroom.max(0.0) / GIB
            );
        }
        req.calibration = cal;
        req.refit = Some(info);
    }
    anyhow::ensure!(req.cap_s >= req.quantum, "--cap must be at least --quantum");

    let out = plan(&req);
    anyhow::ensure!(
        !out.configs.is_empty(),
        "no valid configurations: the requested sweep dims (--tp {:?}, --mb {:?}, --ac {:?}) \
         fit neither {} nor the {}-GPU cluster",
        req.dims.tp_degrees,
        req.dims.micro_batches,
        req.dims.ac_modes.iter().map(|a| a.label()).collect::<Vec<_>>(),
        req.model.name,
        req.cluster.total_gpus()
    );
    let json = rest.iter().any(|a| a == "--json");
    match (json, frontier_only) {
        (true, true) => println!("{}", planner_report::frontier_json(&out).pretty()),
        (true, false) => println!("{}", planner_report::plan_json(&out).pretty()),
        (false, true) => planner_report::frontier_table(&out).print(),
        (false, false) => planner_report::plan_table(&out).print(),
    }
    Ok(())
}

fn cmd_simulate(rest: &[String]) -> anyhow::Result<()> {
    let model = flag(rest, "--model").unwrap_or_else(|| "llama3-8b".into());
    let method = flag(rest, "--method").unwrap_or_else(|| "upipe".into());
    let seq = flag(rest, "--seq").unwrap_or_else(|| "1M".into());
    let s = parse_tokens(&seq).ok_or_else(|| anyhow::anyhow!("bad --seq {seq}"))?;
    let qwen = model == "qwen3-32b";
    let m = match method.as_str() {
        "native" => CpMethod::NativePyTorch,
        "ring" => CpMethod::Ring,
        "ulysses" if qwen => CpMethod::UspHybrid { ulysses: 8, ring: 2 },
        "ulysses" => CpMethod::Ulysses,
        "fpdt" => CpMethod::Fpdt { pi: 16 },
        "upipe" if qwen => CpMethod::UpipeHybrid { u: 8, ulysses: 8, ring: 2 },
        "upipe" => CpMethod::Upipe { u: 8, gqa_schedule: true },
        other => anyhow::bail!("unknown method {other}"),
    };
    let mut preset = if qwen {
        qwen_two_node(m, s)
    } else {
        llama_single_node(m, s)
    };
    if let Some(ac) = flag(rest, "--ac") {
        preset.parallel.ac_mode =
            AcMode::parse(&ac).ok_or_else(|| anyhow::anyhow!("bad --ac {ac} (ao|gpu|noac)"))?;
    }
    if let Some(mb) = flag(rest, "--mb") {
        preset.parallel.micro_batch =
            mb.parse().map_err(|_| anyhow::anyhow!("bad --mb {mb}"))?;
    }
    preset
        .parallel
        .validate_model(&preset.model)
        .map_err(anyhow::Error::msg)?;
    let gpus = preset.parallel.world();
    let r = simulate(&preset);
    println!(
        "model={model} method={method} S={seq} gpus={gpus} ac={} mb={}",
        preset.parallel.ac_mode.label(),
        preset.parallel.micro_batch
    );
    if r.oom {
        println!("result: OOM (peak would exceed HBM)");
        return Ok(());
    }
    if let Some(why) = r.failed {
        println!("result: FAILED ({why})");
        return Ok(());
    }
    println!("  step time    : {:.2} s", r.step_time);
    println!(
        "  throughput   : {:.1} tokens/s/GPU",
        r.tokens_per_sec_per_gpu(preset.step_tokens(), gpus).unwrap()
    );
    println!("  peak memory  : {:.2} GiB", r.peak_bytes / GIB);
    println!(
        "  breakdown    : a2a {:.2}s fwd {:.2}s bwd {:.2}s other {:.2}s",
        r.components.all_to_all, r.components.fa3_fwd, r.components.fa3_bwd, r.components.other
    );
    println!("  peak phase   : {}", r.timeline.peak_label().unwrap_or("-"));
    println!("  alloc retries: {}", r.alloc_retries);
    Ok(())
}

fn cmd_parity() -> anyhow::Result<()> {
    let rt = Runtime::load(&Runtime::default_dir())?;
    let p = Pipeline::new(&rt, 1)?;
    let mut rng = Rng::new(2);
    let toks: Vec<i32> = (0..p.s).map(|_| rng.below(p.vocab as u64) as i32).collect();
    println!(
        "UPipe functional pipeline: C={} ranks, U={} heads/stage, S={}, model=TINY",
        p.c, p.u, p.s
    );
    let mono = p.forward_monolithic(&toks)?;
    for mode in [AttnMode::UpipeGqa, AttnMode::UpipeNaive, AttnMode::FullHead] {
        let mut p2 = Pipeline::new(&rt, 1)?;
        let shards = p2.forward(&toks, mode)?;
        let dist = untied_ulysses::runtime::HostTensor::concat_rows(&shards)?;
        let diff = dist.max_abs_diff(&mono)?;
        println!(
            "  {mode:?}: max|Δlogits| = {diff:.2e}  (stages {}, transient peak {} KiB, a2a {} KiB)",
            p2.stats.stages_run,
            p2.stats.transient_peak_bytes / 1024,
            p2.stats.a2a_bytes / 1024
        );
        anyhow::ensure!(diff < 2e-3, "parity failure in {mode:?}");
    }
    println!("parity OK — distributed == monolithic for all modes");
    Ok(())
}

fn cmd_train(rest: &[String]) -> anyhow::Result<()> {
    let steps: usize = rest.first().and_then(|s| s.parse().ok()).unwrap_or(100);
    let rt = Runtime::load(&Runtime::default_dir())?;
    let mut tr = Trainer::new(&rt, 42)?;
    let mut corpus = MarkovCorpus::new(tr.vocab, 0.9, 7);
    println!(
        "training SMALL model: S={}, V={}, floor {:.2} nats, ln(V) {:.2}",
        tr.seq_len,
        tr.vocab,
        corpus.entropy(),
        (tr.vocab as f64).ln()
    );
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let (toks, tgts) = corpus.sample(tr.seq_len);
        let loss = tr.step(&toks, &tgts)?;
        if step % 10 == 0 || step + 1 == steps {
            println!("step {step:>4}: loss {loss:.4}");
        }
    }
    let first = tr.losses.first().copied().unwrap_or(0.0);
    let last = tr.losses.last().copied().unwrap_or(0.0);
    println!(
        "done: {} steps in {:.1?} ({:.2?}/step), loss {first:.3} -> {last:.3}",
        steps,
        t0.elapsed(),
        t0.elapsed() / steps as u32
    );
    Ok(())
}

fn cmd_serve(rest: &[String]) -> anyhow::Result<()> {
    let n: usize = rest.first().and_then(|s| s.parse().ok()).unwrap_or(20);
    let rt = Runtime::load(&Runtime::default_dir())?;
    let mut server = untied_ulysses::coordinator::server::Server::new(&rt, 3)?;
    let mut rng = Rng::new(4);
    for i in 0..n {
        let toks: Vec<i32> = (0..server.seq_len)
            .map(|_| rng.below(server.vocab as u64) as i32)
            .collect();
        let resp = server.serve(&toks)?;
        if i < 3 {
            println!(
                "req {i}: next_token={} latency={:.1}ms",
                resp.next_token,
                resp.latency_s * 1e3
            );
        }
    }
    let st = server.stats();
    println!(
        "served {} requests ({} tokens) in {:.2}s — p50 {:.1}ms p95 {:.1}ms, {:.0} tokens/s",
        st.served,
        st.total_tokens,
        st.total_time_s,
        st.p50_latency_s * 1e3,
        st.p95_latency_s * 1e3,
        st.total_tokens as f64 / st.total_time_s
    );
    Ok(())
}
