//! `repro` — the leader CLI.
//!
//! Paper artifacts:
//!   repro table1|table2|table3|table4|table5|table6   regenerate tables
//!   repro fig1|fig2|fig4|fig5|fig6                    regenerate figures
//!   repro savings                                     §3.4 headline
//!   repro all                                         everything above
//! Simulation:
//!   repro simulate --model llama3-8b --method upipe --seq 1M
//!                  [--ac ao|gpu|noac] [--mb N]
//! Planning (thin clients of the planner service):
//!   repro plan --model llama3-8b --gpus 8 [--seq 1M] [--quantum 128K]
//!       [--cap 32M] [--ac ao,gpu] [--mb 1,2,4] [--tp 1,2] [--paper]
//!       [--compose] [--refit measurements.json] [--threads N]
//!       [--feasibility-only] [--cold] [--json]
//!       sweep every valid config, solve max trainable context, rank
//!   repro frontier ... [--at-lengths 512K,2M]         Pareto frontier only;
//!       --at-lengths re-prices the sweep at extra reference lengths on
//!       the same warm session (near-free via fitted step-time models)
//!   repro place --fleet fleet.json [--no-prune] [--json] ...
//!       sweep a heterogeneous fleet's cluster shapes as one more planner
//!       dimension: dominated shapes skipped before any probe, model fits
//!       shared across identical hardware, shapes ranked by context wall
//!   repro observe telemetry.jsonl [--drift-threshold 0.05] [--json]
//!       stream measured step telemetry through the online calibrator:
//!       MAD-gated ingestion, per-constant drift, epochs published
//!       mid-stream when drift crosses the threshold
//!   repro serve-plan [--port 8077] [--bind 127.0.0.1] [--threads N]
//!       [--cache-budget 1G] [--keep-alive-timeout 5] [--request-timeout 0]
//!       [--drain-timeout 30] [--access-log access.jsonl]
//!       planner-service daemon: POST /v1/plan | /v1/walls | /v1/frontier
//!       | /v1/refit | /v1/placement | /v1/observe, GET /v1/calibration
//!       | /v1/health | /metrics — persistent cross-request caches under
//!       a tiered-LRU byte budget, online calibration with surgical
//!       epoch invalidation, HTTP/1.1 keep-alive, request deadlines
//!       (504, nothing partial published), SIGTERM graceful drain, JSONL
//!       access logs
//! Functional runtime (needs `make artifacts`):
//!   repro parity        distributed UPipe vs monolithic logits check
//!   repro train N       N training steps of the SMALL model (AOT step)
//!   repro serve N       serve N random requests, report latency
//! Meta:
//!   repro deviation     mean |sim - paper| over Tables 3+4

use untied_ulysses::config::presets::{llama_single_node, qwen_two_node};
use untied_ulysses::config::{AcMode, CpMethod};
use untied_ulysses::coordinator::trainer::{MarkovCorpus, Trainer};
use untied_ulysses::coordinator::{AttnMode, Pipeline};
use untied_ulysses::model::ModelDims;
use untied_ulysses::report::{figures, savings, tables};
use untied_ulysses::runtime::Runtime;
use untied_ulysses::schedule::simulate;
use untied_ulysses::service::wire;
use untied_ulysses::service::{MeasurementsSource, PlanParams};
use untied_ulysses::util::fmt::{parse_tokens, GIB};
use untied_ulysses::util::rng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let code = match run(cmd, &args[1.min(args.len())..]) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, rest: &[String]) -> anyhow::Result<()> {
    match cmd {
        "table1" => {
            tables::table1_report(&ModelDims::llama3_8b(), 1 << 20).print();
            tables::table1_report(&ModelDims::qwen3_32b(), 1 << 20).print();
        }
        "table2" => {
            tables::table2_report(&ModelDims::llama3_8b(), 8).print();
            tables::table2_report(&ModelDims::qwen3_32b(), 8).print();
        }
        "table3" => {
            tables::table3_report(false).print();
            tables::table3_report(true).print();
        }
        "table4" => {
            tables::table4_report(false).print();
            tables::table4_report(true).print();
        }
        "table5" => tables::table5_report().print(),
        "table6" => {
            tables::table6_report(&ModelDims::llama3_8b(), 8).print();
            tables::table6_report(&ModelDims::qwen3_32b(), 8).print();
        }
        "fig1" => figures::fig1_report().print(),
        "fig2" => figures::fig2_report().print(),
        "fig4" => figures::fig4_report().print(),
        "fig5" => figures::fig5_report().print(),
        "fig6" => figures::fig6_report().print(),
        "savings" => savings::savings_report(1 << 20).print(),
        "all" => {
            for c in [
                "table1", "table2", "table3", "table4", "table5", "table6", "fig1",
                "fig2", "fig4", "fig5", "fig6", "savings", "deviation",
            ] {
                run(c, &[])?;
                println!();
            }
        }
        "deviation" => {
            let (d_l, n_l) = tables::grid_deviation(false);
            let (d_q, n_q) = tables::grid_deviation(true);
            println!(
                "mean |sim-paper|/paper: llama {:.1}% ({n_l} cells), qwen {:.1}% ({n_q} cells)",
                100.0 * d_l,
                100.0 * d_q
            );
        }
        "compose" => cmd_compose()?,
        "plan" => cmd_plan(rest, false)?,
        "frontier" => cmd_plan(rest, true)?,
        "place" => cmd_place(rest)?,
        "observe" => cmd_observe(rest)?,
        "serve-plan" => cmd_serve_plan(rest)?,
        "simulate" => cmd_simulate(rest)?,
        "parity" => cmd_parity()?,
        "train" => cmd_train(rest)?,
        "serve" => cmd_serve(rest)?,
        "help" | "--help" | "-h" => print!("{HELP}"),
        other => anyhow::bail!("unknown command `{other}` (see `repro help`)"),
    }
    Ok(())
}

const HELP: &str = "\
repro — Untied Ulysses (UPipe) reproduction

  repro table1..table6 | fig1 | fig2 | fig4 | fig5 | fig6 | savings | all
  repro deviation
  repro simulate --model llama3-8b|qwen3-32b --method native|ring|ulysses|fpdt|upipe --seq 1M
                 [--ac ao|gpu|noac] [--mb N]
  repro plan --model llama3-8b --gpus 8 [--seq 1M] [--quantum 128K] [--cap 32M]
             [--ac ao,gpu,noac] [--mb 1,2,4] [--tp 1,2] [--paper] [--compose]
             [--refit measurements.json] [--threads N] [--feasibility-only]
             [--cold] [--json]
      sweep every valid parallel config for the model/cluster — method
      families x AC modes x micro-batches x TP mixes x pinning — solve
      each one's max trainable context (sampled-polynomial peak models,
      walls verified with two streamed probes), rank, and mark the Pareto
      frontier. --paper restricts to the paper's §5.1 dims (offloaded AC,
      batch 1, no TP); --refit re-derives the fitted calibration rates
      from a Table-5-style measurements file and replans with them
      (provenance is echoed into the table notes / JSON `refit` field);
      --feasibility-only skips all reference-length pricing and reports
      walls only (multi-node N x 8 frontier sweeps become near-free);
      --cold disables the symbolic solver and warm starts (probe-per-
      bisection reference path, identical results)
  repro frontier ...  same flags; print only the Pareto frontier
      [--at-lengths 512K,2M]  re-price the sweep at extra reference
      lengths on the same warm session (fitted step-time models + memos
      make each extra length near-free); --json emits one deterministic
      plan core per length plus combined accounting
  repro place --fleet fleet.json [--model llama3-8b] [--seq 1M]
              [--quantum 128K] [--cap 32M] [--ac ao,gpu] [--mb 1,2]
              [--tp 1,2] [--paper] [--compose] [--refit measurements.json]
              [--threads N] [--feasibility-only] [--no-prune] [--json]
      sweep every cluster shape a heterogeneous fleet offers (per-pool
      power-of-two node slices x full nodes) and rank them by max
      trainable context, then reference throughput, then GPU count.
      Shapes dominated in every per-rank hardware dimension at the same
      grid are skipped before any probe (--no-prune evaluates them too —
      the ranked placements are identical either way), and peak/step-time
      model fits are shared across shapes with identical hardware, so
      duplicate pools re-fit nothing. The fleet file is a
      {\"pools\": [{\"name\", \"device\"|per-device fields, \"nodes\",
      \"gpus_per_node\"}]} JSON document (devices: h100, h200, b200);
      see examples/fleet_h100_h200.json
  repro observe telemetry.jsonl [--drift-threshold 0.05] [--json]
      stream measured step telemetry (one JSON record per line: method,
      model, gpus, seq + measured component seconds — see
      examples/telemetry_upipe.jsonl) through the online calibrator.
      Each record is inverted against the schedule's structural op
      counts into fitted-constant samples, MAD-gated against its
      method's recent window, and folded into exponentially-weighted
      estimates; when any constant's relative drift crosses
      --drift-threshold a new calibration epoch publishes mid-stream
      (old -> new per constant, with observation counts). Prints the
      final drift table, or --json the `/v1/calibration` document.
      Deterministic: replaying the same file yields byte-identical
      output
  repro serve-plan [--port 8077] [--bind 127.0.0.1] [--threads N]
                   [--cache-budget 1G] [--keep-alive-timeout 5]
                   [--request-timeout 0] [--drain-timeout 30]
                   [--access-log access.jsonl]
      planner-as-a-service daemon over one warm session: POST /v1/plan,
      /v1/walls (add \"at\" for a point query, or \"at\": [s1, s2, ...]
      for a whole capacity curve), /v1/frontier, /v1/refit, /v1/placement
      (a fleet placement sweep — same dialect, `fleet` instead of `gpus`),
      /v1/observe (a telemetry batch: accept/reject counts, the drift
      vector, and any published epoch with its per-tier invalidation
      counts); GET /v1/calibration (active epoch, constants, drift,
      provenance chain), /v1/health, /metrics (Prometheus text exposition
      of the health counters). Epoch publishes drop exactly the cache
      entries priced under the stale calibration — measurements-pinned
      requests and other fingerprints survive untouched; restart the
      daemon to roll back to the boot calibration (epoch 0). Persistent
      cross-request caches under a byte
      budget (tiered LRU: bulky trace/report tiers evict first, verified
      walls and fitted models last; 0 = unbounded): a repeated request
      is served from memos byte-for-byte, and a warm walls query streams
      zero probes. HTTP/1.1 keep-alive with pipelining
      (--keep-alive-timeout seconds idle, 0 = one-shot connections).
      --request-timeout N answers 504 deadline_exceeded after N seconds
      of evaluation (partial accounting in the envelope, no partial
      state published; 0 = no deadline); clients may tighten per request
      with \"deadline_ms\". SIGTERM drains gracefully: new connections
      answer 503 `draining`, in-flight requests get up to
      --drain-timeout seconds, then a final stats JSON line prints and
      the process exits 0 on a clean drain. --access-log appends one
      JSON line per request. REPRO_FAILPOINTS=site=policy;... arms
      deterministic fault injection (testing only). api_version 1; see
      README and docs/OPERATIONS.md.
  repro compose       UPipe x FPDT composition study (paper §5.3.2)
  repro parity
  repro train [steps=100]
  repro serve [requests=20]
";

/// The one shared argument parser (every subcommand reads its flags
/// through this instead of ad-hoc scanning).
struct Args<'a> {
    rest: &'a [String],
}

impl<'a> Args<'a> {
    fn new(rest: &'a [String]) -> Self {
        Args { rest }
    }

    /// `--flag value` lookup.
    fn str(&self, name: &str) -> Option<String> {
        self.rest.iter().position(|a| a == name).and_then(|i| self.rest.get(i + 1)).cloned()
    }

    /// Bare `--flag` presence.
    fn has(&self, name: &str) -> bool {
        self.rest.iter().any(|a| a == name)
    }

    fn u64(&self, name: &str) -> anyhow::Result<Option<u64>> {
        match self.str(name) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| anyhow::anyhow!("bad {name} {v}")),
        }
    }

    /// Token-count flag: a label ("1M", "128K") or a raw count.
    fn tokens(&self, name: &str) -> anyhow::Result<Option<u64>> {
        match self.str(name) {
            None => Ok(None),
            Some(v) => match parse_tokens(&v) {
                Some(t) => Ok(Some(t)),
                None => Err(anyhow::anyhow!("bad {name} {v}")),
            },
        }
    }

    /// First positional argument, parsed, with a default.
    fn positional_usize(&self, default: usize) -> usize {
        self.rest.first().and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

fn cmd_compose() -> anyhow::Result<()> {
    use untied_ulysses::util::fmt::tokens;
    use untied_ulysses::util::table::Table;
    let mut t = Table::new(
        "UPipe x FPDT composition (Llama3-8B, 8xH100) — paper §5.3.2",
        &["S", "UPipe GiB", "FPDT GiB", "UPipe+FPDT GiB", "UPipe tok/s", "UPipe+FPDT tok/s"],
    );
    let upipe = CpMethod::Upipe { u: 8, gqa_schedule: true };
    let fpdt = CpMethod::Fpdt { pi: 16 };
    let comp = CpMethod::UpipeFpdt { u: 8, pi: 16 };
    for label in ["1M", "3M", "5M", "6M", "8M", "10M"] {
        let s = parse_tokens(label).unwrap();
        let cell = |m: CpMethod| {
            let r = simulate(&llama_single_node(m, s));
            if r.oom || r.failed.is_some() {
                ("OOM".to_string(), "-".to_string())
            } else {
                (
                    format!("{:.1}", r.peak_bytes / GIB),
                    r.tokens_per_sec_per_gpu(s, 8)
                        .map(|v| format!("{v:.0}"))
                        .unwrap_or_else(|| "-".into()),
                )
            }
        };
        let (mu, tu) = cell(upipe);
        let (mf, _) = cell(fpdt);
        let (mc, tc) = cell(comp);
        t.row(vec![tokens(s), mu, mf, mc, tu, tc]);
    }
    t.note("composition keeps FPDT-level memory with UPipe's GQA comm schedule;");
    t.note("it inherits FPDT's CPU-stall throughput cost — the paper's anticipated tradeoff");
    t.print();
    Ok(())
}

/// Build the service request from CLI flags — the same [`PlanParams`] an
/// HTTP client would POST, so `repro plan` and the daemon cannot drift.
fn parse_plan_params(args: &Args) -> anyhow::Result<PlanParams> {
    let model = args.str("--model").unwrap_or_else(|| "llama3-8b".into());
    let gpus = args.u64("--gpus")?.unwrap_or(8);
    let mut p = PlanParams::defaults(&model, gpus);
    if args.has("--paper") {
        p.set_paper();
    }
    if let Some(s) = args.tokens("--seq")? {
        p.reference_s = s;
    }
    if let Some(q) = args.tokens("--quantum")? {
        p.quantum = q;
    }
    if let Some(c) = args.tokens("--cap")? {
        p.cap_s = c;
    }
    if let Some(t) = args.u64("--threads")? {
        p.threads = t as usize;
    }
    if let Some(ac) = args.str("--ac") {
        p.ac_modes = wire::parse_ac_list(&ac).map_err(anyhow::Error::msg)?;
    }
    if let Some(mb) = args.str("--mb") {
        p.micro_batches = wire::parse_u64_list(&mb, "--mb").map_err(anyhow::Error::msg)?;
    }
    if let Some(tp) = args.str("--tp") {
        p.tp_degrees = wire::parse_u64_list(&tp, "--tp").map_err(anyhow::Error::msg)?;
    }
    p.compositions = p.compositions || args.has("--compose");
    p.cold = args.has("--cold");
    p.feasibility_only = args.has("--feasibility-only");
    if let Some(path) = args.str("--refit") {
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading --refit {path}: {e}"))?;
        p.measurements = Some(MeasurementsSource { source: path, text });
    }
    anyhow::ensure!(
        !args.has("--at-lengths") || args.str("--at-lengths").is_some(),
        "--at-lengths needs a comma-separated list of lengths"
    );
    Ok(p)
}

fn cmd_plan(rest: &[String], frontier_only: bool) -> anyhow::Result<()> {
    use untied_ulysses::report::planner as planner_report;
    use untied_ulysses::service::PlannerService;

    let args = Args::new(rest);
    let params = parse_plan_params(&args)?;
    // One-shot session: the CLI is a thin client of the same service type
    // the daemon runs — same params, same evaluator, same JSON.
    let service = PlannerService::new();
    let reply = service.plan(&params).map_err(anyhow::Error::msg)?;
    for note in &reply.warnings {
        eprintln!("{note}");
    }
    let out = &reply.outcome;
    let json = args.has("--json");
    if let Some(spec) = args.str("--at-lengths") {
        // Re-price the sweep at extra reference lengths on the SAME warm
        // session: the walls, fitted models and streamed-price memos from
        // the base sweep carry over, so each extra length is near-free.
        let mut lengths: Vec<u64> = Vec::new();
        for tok in spec.split(',') {
            let s = parse_tokens(tok.trim())
                .ok_or_else(|| anyhow::anyhow!("bad --at-lengths entry `{tok}`"))?;
            if s != params.reference_s && !lengths.contains(&s) {
                lengths.push(s);
            }
        }
        let mut rows = vec![(params.reference_s, std::sync::Arc::clone(out))];
        for &s in &lengths {
            let mut p2 = params.clone();
            p2.reference_s = s;
            let r = service.plan(&p2).map_err(anyhow::Error::msg)?;
            for note in &r.warnings {
                eprintln!("{note}");
            }
            rows.push((s, r.outcome));
        }
        if json {
            let refs: Vec<(u64, &untied_ulysses::planner::PlanOutcome)> =
                rows.iter().map(|(s, o)| (*s, o.as_ref())).collect();
            println!("{}", planner_report::frontier_at_lengths_json(&refs).pretty());
        } else {
            for (_, o) in &rows {
                if frontier_only {
                    planner_report::frontier_table(o).print();
                } else {
                    planner_report::plan_table(o).print();
                }
                println!();
            }
        }
        return Ok(());
    }
    match (json, frontier_only) {
        (true, true) => println!("{}", planner_report::frontier_json(out).pretty()),
        (true, false) => println!("{}", planner_report::plan_json(out).pretty()),
        (false, true) => planner_report::frontier_table(out).print(),
        (false, false) => planner_report::plan_table(out).print(),
    }
    Ok(())
}

/// Fleet placement sweep: the cluster itself as a planner dimension.
/// Like `cmd_plan`, a thin client of the same service type the daemon
/// runs — the params are exactly what a `/v1/placement` client POSTs.
fn cmd_place(rest: &[String]) -> anyhow::Result<()> {
    use untied_ulysses::config::FleetSpec;
    use untied_ulysses::report::planner as planner_report;
    use untied_ulysses::service::{PlacementParams, PlannerService};

    let args = Args::new(rest);
    anyhow::ensure!(
        args.str("--gpus").is_none(),
        "--gpus is not a placement flag — the fleet's pools size the shapes"
    );
    anyhow::ensure!(
        !args.has("--cold"),
        "--cold is not a placement flag: placement always plans symbolically"
    );
    let path = args.str("--fleet").ok_or_else(|| {
        anyhow::anyhow!("--fleet fleet.json is required (see examples/fleet_h100_h200.json)")
    })?;
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("reading --fleet {path}: {e}"))?;
    let fleet = FleetSpec::parse(&text, &path).map_err(anyhow::Error::msg)?;
    let plan = parse_plan_params(&args)?;
    let params = PlacementParams { fleet, plan, prune: !args.has("--no-prune") };
    let service = PlannerService::new();
    let reply = service.place(&params).map_err(anyhow::Error::msg)?;
    for note in &reply.warnings {
        eprintln!("{note}");
    }
    if args.has("--json") {
        println!("{}", planner_report::placement_json(&reply.outcome).pretty());
    } else {
        planner_report::placement_table(&reply.outcome).print();
    }
    Ok(())
}

/// Stream a telemetry JSONL file through the online calibrator, one
/// record at a time — the same [`untied_ulysses::calib::Observation`]
/// dialect a client POSTs to `/v1/observe`, so the CLI and the daemon
/// cannot drift. Epochs publish mid-stream as drift crosses the
/// threshold; the final snapshot prints as a drift table or (`--json`)
/// the `/v1/calibration` document.
fn cmd_observe(rest: &[String]) -> anyhow::Result<()> {
    use untied_ulysses::calib::epoch::fingerprint_hex;
    use untied_ulysses::calib::{Observation, OnlineCalibrator, OnlineConfig};
    use untied_ulysses::engine::Calibration;
    use untied_ulysses::util::json::Json;
    use untied_ulysses::util::table::Table;

    let args = Args::new(rest);
    let path = rest.first().filter(|a| !a.starts_with("--")).cloned().ok_or_else(|| {
        anyhow::anyhow!("usage: repro observe telemetry.jsonl [--drift-threshold 0.05] [--json]")
    })?;
    let mut config = OnlineConfig::default();
    if let Some(t) = args.str("--drift-threshold") {
        config.drift_threshold =
            t.parse().map_err(|_| anyhow::anyhow!("bad --drift-threshold {t}"))?;
    }
    let threshold = config.drift_threshold;
    let text =
        std::fs::read_to_string(&path).map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let mut cal = OnlineCalibrator::new(Calibration::default(), config);
    let (mut accepted, mut rejected) = (0u64, 0u64);
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let obs = Json::parse(line)
            .and_then(|j| Observation::from_json(&j))
            .map_err(|e| anyhow::anyhow!("{path}:{}: {e}", i + 1))?;
        let report = cal.ingest(std::slice::from_ref(&obs));
        accepted += report.accepted;
        rejected += report.rejected;
        for note in &report.notes {
            eprintln!("{path}:{}: {note}", i + 1);
        }
        if let Some(p) = &report.published {
            println!(
                "epoch {} published at {path}:{} (fingerprint {} -> {})",
                p.epoch,
                i + 1,
                fingerprint_hex(p.old_fingerprint),
                fingerprint_hex(p.new_fingerprint)
            );
            for f in &p.fields {
                println!(
                    "  {:<20} {:>12.5e} -> {:>12.5e}  ({} observations)",
                    f.constant.name(),
                    f.old,
                    f.new,
                    f.observations
                );
            }
        }
    }
    if args.has("--json") {
        println!("{}", cal.snapshot().to_json().pretty());
        return Ok(());
    }
    let snap = cal.snapshot();
    let mut t = Table::new(
        &format!(
            "online calibration — epoch {} (fingerprint {})",
            snap.epoch,
            fingerprint_hex(snap.fingerprint)
        ),
        &["constant", "active", "estimate", "rel drift", "obs"],
    );
    for d in &snap.drift {
        t.row(vec![
            d.constant.name().to_string(),
            format!("{:.5e}", d.active),
            format!("{:.5e}", d.estimate),
            format!("{:.2}%", 100.0 * d.rel_drift),
            d.observations.to_string(),
        ]);
    }
    t.note(&format!("{accepted} records accepted, {rejected} rejected (MAD gate / floor skips)"));
    t.note(&format!(
        "publish threshold: {:.1}% relative drift; {} epoch(s) in provenance history",
        100.0 * threshold,
        snap.history.len()
    ));
    t.print();
    Ok(())
}

/// Set by the C signal handler on SIGTERM; the serve-plan poll loop
/// notices and starts a graceful drain. A relaxed atomic store is
/// async-signal-safe.
static TERM: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

extern "C" fn on_sigterm(_sig: i32) {
    TERM.store(true, std::sync::atomic::Ordering::Relaxed);
}

extern "C" {
    fn signal(signum: i32, handler: extern "C" fn(i32)) -> isize;
}

const SIGTERM: i32 = 15;

fn cmd_serve_plan(rest: &[String]) -> anyhow::Result<()> {
    use untied_ulysses::service::{http, PlannerService};
    use untied_ulysses::util::failpoint;
    use untied_ulysses::util::fmt::gib;

    // A malformed REPRO_FAILPOINTS spec refuses to start — a daemon
    // running a fault schedule it did not understand is worse than none.
    failpoint::init_from_env().map_err(anyhow::Error::msg)?;
    let args = Args::new(rest);
    let port = args.u64("--port")?.unwrap_or(8077);
    anyhow::ensure!(port <= u16::MAX as u64, "bad --port {port}");
    let bind = args.str("--bind").unwrap_or_else(|| "127.0.0.1".into());
    let threads = args.u64("--threads")?.unwrap_or(0) as usize;
    // `--cache-budget 2G` style; 0 = unbounded (never evict).
    let budget = match args.tokens("--cache-budget")? {
        None => untied_ulysses::service::DEFAULT_CACHE_BUDGET,
        Some(0) => usize::MAX,
        Some(b) => b as usize,
    };
    // Seconds of keep-alive idle window; 0 disables keep-alive.
    let keep_alive = args.u64("--keep-alive-timeout")?.unwrap_or(5);
    // Seconds before an in-flight evaluation answers 504; 0 = no deadline.
    let request_timeout = args.u64("--request-timeout")?.unwrap_or(0);
    // Seconds SIGTERM waits for in-flight requests before detaching them.
    let drain_timeout = args.u64("--drain-timeout")?.unwrap_or(30);
    let access_log = args.str("--access-log").map(std::path::PathBuf::from);
    let opts = http::ServeOptions {
        threads,
        keep_alive_timeout: std::time::Duration::from_secs(keep_alive),
        access_log: access_log.clone(),
        ..http::ServeOptions::default()
    };
    let timeout = (request_timeout > 0).then(|| std::time::Duration::from_secs(request_timeout));
    let service =
        std::sync::Arc::new(PlannerService::with_budget(budget).with_request_timeout(timeout));
    let handle = http::serve(std::sync::Arc::clone(&service), &format!("{bind}:{port}"), opts)?;
    println!("repro planner service listening on http://{}", handle.addr());
    println!(
        "  POST /v1/plan | /v1/walls | /v1/frontier | /v1/refit | /v1/placement \
         | /v1/observe   GET /v1/calibration | /v1/health | /metrics   (api_version {})",
        untied_ulysses::service::API_VERSION
    );
    if budget == usize::MAX {
        println!("  cache budget: unbounded");
    } else {
        println!(
            "  cache budget: {} GiB (tiered LRU; walls/models evicted last)",
            gib(budget as f64)
        );
    }
    if keep_alive == 0 {
        println!("  keep-alive: disabled (one request per connection)");
    } else {
        println!("  keep-alive: {keep_alive}s idle timeout");
    }
    if request_timeout > 0 {
        println!(
            "  request timeout: {request_timeout}s (504 deadline_exceeded; \
             no partial state published)"
        );
    }
    if let Some(p) = &access_log {
        println!("  access log: {} (JSONL, one line per request)", p.display());
    }
    if failpoint::enabled() {
        println!("  failpoints: armed from REPRO_FAILPOINTS (testing only)");
    }
    use std::io::Write;
    std::io::stdout().flush().ok();
    // Graceful lifecycle: instead of joining forever, poll a SIGTERM
    // flag so `kill -TERM` drains (finish in-flight requests, refuse new
    // connections with 503 `draining`) and exits 0 within roughly
    // --drain-timeout.
    unsafe { signal(SIGTERM, on_sigterm) };
    while !TERM.load(std::sync::atomic::Ordering::Relaxed) {
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    eprintln!("SIGTERM: draining (up to {drain_timeout}s for in-flight requests)");
    let d = handle.drain(std::time::Duration::from_secs(drain_timeout));
    let s = service.stats();
    println!(
        "{{\"event\":\"shutdown\",\"drained\":{},\"in_flight_at_deadline\":{},\
         \"drain_refusals\":{},\"plan_requests\":{},\"plan_memo_hits\":{},\
         \"placement_requests\":{},\"point_queries\":{},\"probes_streamed\":{},\
         \"cells_quarantined\":{}}}",
        d.drained,
        d.in_flight_at_deadline,
        d.refused,
        s.plan_requests,
        s.plan_memo_hits,
        s.placement_requests,
        s.point_queries,
        s.probes_streamed,
        s.cells_quarantined
    );
    std::io::stdout().flush().ok();
    anyhow::ensure!(
        d.drained,
        "drain timeout: {} requests still in flight",
        d.in_flight_at_deadline
    );
    Ok(())
}

fn cmd_simulate(rest: &[String]) -> anyhow::Result<()> {
    let args = Args::new(rest);
    let model = args.str("--model").unwrap_or_else(|| "llama3-8b".into());
    let method = args.str("--method").unwrap_or_else(|| "upipe".into());
    let seq = args.str("--seq").unwrap_or_else(|| "1M".into());
    let s = parse_tokens(&seq).ok_or_else(|| anyhow::anyhow!("bad --seq {seq}"))?;
    let qwen = model == "qwen3-32b";
    let m = match method.as_str() {
        "native" => CpMethod::NativePyTorch,
        "ring" => CpMethod::Ring,
        "ulysses" if qwen => CpMethod::UspHybrid { ulysses: 8, ring: 2 },
        "ulysses" => CpMethod::Ulysses,
        "fpdt" => CpMethod::Fpdt { pi: 16 },
        "upipe" if qwen => CpMethod::UpipeHybrid { u: 8, ulysses: 8, ring: 2 },
        "upipe" => CpMethod::Upipe { u: 8, gqa_schedule: true },
        other => anyhow::bail!("unknown method {other}"),
    };
    let mut preset = if qwen {
        qwen_two_node(m, s)
    } else {
        llama_single_node(m, s)
    };
    if let Some(ac) = args.str("--ac") {
        preset.parallel.ac_mode =
            AcMode::parse(&ac).ok_or_else(|| anyhow::anyhow!("bad --ac {ac} (ao|gpu|noac)"))?;
    }
    if let Some(mb) = args.u64("--mb")? {
        preset.parallel.micro_batch = mb;
    }
    preset
        .parallel
        .validate_model(&preset.model)
        .map_err(anyhow::Error::msg)?;
    let gpus = preset.parallel.world();
    let r = simulate(&preset);
    println!(
        "model={model} method={method} S={seq} gpus={gpus} ac={} mb={}",
        preset.parallel.ac_mode.label(),
        preset.parallel.micro_batch
    );
    if r.oom {
        println!("result: OOM (peak would exceed HBM)");
        return Ok(());
    }
    if let Some(why) = r.failed {
        println!("result: FAILED ({why})");
        return Ok(());
    }
    println!("  step time    : {:.2} s", r.step_time);
    println!(
        "  throughput   : {:.1} tokens/s/GPU",
        r.tokens_per_sec_per_gpu(preset.step_tokens(), gpus).unwrap()
    );
    println!("  peak memory  : {:.2} GiB", r.peak_bytes / GIB);
    println!(
        "  breakdown    : a2a {:.2}s fwd {:.2}s bwd {:.2}s other {:.2}s",
        r.components.all_to_all, r.components.fa3_fwd, r.components.fa3_bwd, r.components.other
    );
    println!("  peak phase   : {}", r.timeline.peak_label().unwrap_or("-"));
    println!("  alloc retries: {}", r.alloc_retries);
    Ok(())
}

fn cmd_parity() -> anyhow::Result<()> {
    let rt = Runtime::load(&Runtime::default_dir())?;
    let p = Pipeline::new(&rt, 1)?;
    let mut rng = Rng::new(2);
    let toks: Vec<i32> = (0..p.s).map(|_| rng.below(p.vocab as u64) as i32).collect();
    println!(
        "UPipe functional pipeline: C={} ranks, U={} heads/stage, S={}, model=TINY",
        p.c, p.u, p.s
    );
    let mono = p.forward_monolithic(&toks)?;
    for mode in [AttnMode::UpipeGqa, AttnMode::UpipeNaive, AttnMode::FullHead] {
        let mut p2 = Pipeline::new(&rt, 1)?;
        let shards = p2.forward(&toks, mode)?;
        let dist = untied_ulysses::runtime::HostTensor::concat_rows(&shards)?;
        let diff = dist.max_abs_diff(&mono)?;
        println!(
            "  {mode:?}: max|Δlogits| = {diff:.2e}  (stages {}, transient peak {} KiB, a2a {} KiB)",
            p2.stats.stages_run,
            p2.stats.transient_peak_bytes / 1024,
            p2.stats.a2a_bytes / 1024
        );
        anyhow::ensure!(diff < 2e-3, "parity failure in {mode:?}");
    }
    println!("parity OK — distributed == monolithic for all modes");
    Ok(())
}

fn cmd_train(rest: &[String]) -> anyhow::Result<()> {
    let steps = Args::new(rest).positional_usize(100);
    let rt = Runtime::load(&Runtime::default_dir())?;
    let mut tr = Trainer::new(&rt, 42)?;
    let mut corpus = MarkovCorpus::new(tr.vocab, 0.9, 7);
    println!(
        "training SMALL model: S={}, V={}, floor {:.2} nats, ln(V) {:.2}",
        tr.seq_len,
        tr.vocab,
        corpus.entropy(),
        (tr.vocab as f64).ln()
    );
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let (toks, tgts) = corpus.sample(tr.seq_len);
        let loss = tr.step(&toks, &tgts)?;
        if step % 10 == 0 || step + 1 == steps {
            println!("step {step:>4}: loss {loss:.4}");
        }
    }
    let first = tr.losses.first().copied().unwrap_or(0.0);
    let last = tr.losses.last().copied().unwrap_or(0.0);
    println!(
        "done: {} steps in {:.1?} ({:.2?}/step), loss {first:.3} -> {last:.3}",
        steps,
        t0.elapsed(),
        t0.elapsed() / steps as u32
    );
    Ok(())
}

fn cmd_serve(rest: &[String]) -> anyhow::Result<()> {
    let n = Args::new(rest).positional_usize(20);
    let rt = Runtime::load(&Runtime::default_dir())?;
    let mut server = untied_ulysses::coordinator::server::Server::new(&rt, 3)?;
    let mut rng = Rng::new(4);
    for i in 0..n {
        let toks: Vec<i32> = (0..server.seq_len)
            .map(|_| rng.below(server.vocab as u64) as i32)
            .collect();
        let resp = server.serve(&toks)?;
        if i < 3 {
            println!(
                "req {i}: next_token={} latency={:.1}ms",
                resp.next_token,
                resp.latency_s * 1e3
            );
        }
    }
    let st = server.stats();
    println!(
        "served {} requests ({} tokens) in {:.2}s — p50 {:.1}ms p95 {:.1}ms, {:.0} tokens/s",
        st.served,
        st.total_tokens,
        st.total_time_s,
        st.p50_latency_s * 1e3,
        st.p95_latency_s * 1e3,
        st.total_tokens as f64 / st.total_time_s
    );
    Ok(())
}
