//! Node topology: rank → node placement and link selection between ranks.
//!
//! Two construction paths: [`Topology::from_cluster`] keeps the original
//! homogeneous model (every node identical, O(1) rank math), and
//! [`Topology::from_fleet`] generalizes it to heterogeneous fleets —
//! each rank maps to a node in a device pool, intra-node links run at
//! that pool's NVLink generation, and inter-node links resolve per pair
//! (the slower endpoint's NIC bounds the transfer).

use super::link::{Link, LinkKind};
use crate::config::{ClusterConfig, DeviceSpec, FleetSpec};

/// Per-pool link rates of a heterogeneous topology.
#[derive(Debug, Clone)]
struct PoolLinks {
    nvlink: Link,
    ib: Link,
    pcie: Link,
}

/// Heterogeneous rank map: global rank → (node, pool) plus per-pool
/// links. Nodes number globally across pools in declaration order.
#[derive(Debug, Clone)]
struct FleetMap {
    /// rank → (global node index, pool index)
    ranks: Vec<(u64, usize)>,
    pools: Vec<PoolLinks>,
}

#[derive(Debug, Clone)]
pub struct Topology {
    pub nodes: u64,
    pub gpus_per_node: u64,
    nvlink: Link,
    ib: Link,
    pcie: Link,
    /// Present when built from a fleet; `None` keeps the homogeneous
    /// fast path bit-identical to the original model.
    fleet: Option<FleetMap>,
}

impl Topology {
    pub fn from_cluster(c: &ClusterConfig) -> Self {
        Topology {
            nodes: c.nodes,
            gpus_per_node: c.gpus_per_node,
            nvlink: Link::nvlink(c.nvlink_bps),
            ib: Link::infiniband(c.ib_bps),
            pcie: Link::pcie(c.pcie_bps),
            fleet: None,
        }
    }

    /// A whole fleet as one topology: ranks number pool by pool in
    /// declaration order, nodes globally. `gpus_per_node` reports the
    /// first pool's width (callers needing per-rank truth use
    /// [`Topology::node_of`] / [`Topology::link_between`], which consult
    /// the per-rank table).
    pub fn from_fleet(f: &FleetSpec) -> Self {
        let mut ranks = Vec::new();
        let mut pools = Vec::new();
        let mut node = 0u64;
        for p in &f.pools {
            pools.push(PoolLinks {
                nvlink: Link::nvlink(p.device.nvlink_bps),
                ib: Link::infiniband(p.device.ib_bps),
                pcie: Link::pcie(p.device.pcie_bps),
            });
            for _ in 0..p.nodes {
                for _ in 0..p.device.gpus_per_node {
                    ranks.push((node, pools.len() - 1));
                }
                node += 1;
            }
        }
        let first = &f.pools[0].device;
        Topology {
            nodes: node,
            gpus_per_node: first.gpus_per_node,
            nvlink: Link::nvlink(first.nvlink_bps),
            ib: Link::infiniband(first.ib_bps),
            pcie: Link::pcie(first.pcie_bps),
            fleet: Some(FleetMap { ranks, pools }),
        }
    }

    pub fn total_gpus(&self) -> u64 {
        match &self.fleet {
            Some(f) => f.ranks.len() as u64,
            None => self.nodes * self.gpus_per_node,
        }
    }

    pub fn node_of(&self, rank: u64) -> u64 {
        match &self.fleet {
            Some(f) => f.ranks[rank as usize].0,
            None => rank / self.gpus_per_node,
        }
    }

    /// Link connecting two ranks: same node → that node's NVLink
    /// generation; different nodes → InfiniBand at the slower endpoint's
    /// NIC rate (a cross-pool pair cannot beat its weaker member).
    pub fn link_between(&self, a: u64, b: u64) -> Link {
        let Some(f) = &self.fleet else {
            return if self.node_of(a) == self.node_of(b) { self.nvlink } else { self.ib };
        };
        let (na, pa) = f.ranks[a as usize];
        let (nb, pb) = f.ranks[b as usize];
        if na == nb {
            return f.pools[pa].nvlink;
        }
        let (ia, ib) = (f.pools[pa].ib, f.pools[pb].ib);
        if ia.bandwidth <= ib.bandwidth {
            ia
        } else {
            ib
        }
    }

    pub fn link(&self, kind: LinkKind) -> Link {
        match kind {
            LinkKind::NvLink => self.nvlink,
            LinkKind::InfiniBand => self.ib,
            LinkKind::Pcie => self.pcie,
        }
    }

    /// The offload link of one rank's node (per-pool PCIe generation).
    pub fn pcie_of(&self, rank: u64) -> Link {
        match &self.fleet {
            Some(f) => f.pools[f.ranks[rank as usize].1].pcie,
            None => self.pcie,
        }
    }

    /// The device spec of one rank's pool within `fleet` (placement
    /// reporting; panics if `rank` is out of range).
    pub fn device_of<'a>(&self, fleet: &'a FleetSpec, rank: u64) -> &'a DeviceSpec {
        match &self.fleet {
            Some(f) => &fleet.pools[f.ranks[rank as usize].1].device,
            None => &fleet.pools[0].device,
        }
    }

    /// Are all ranks of a group on one node (⇒ collectives run on
    /// NVLink)? Every member must match the *first* rank's node — not
    /// just its predecessor — so strided groups like `[0, 8, 1]` can
    /// never sneak an NVLink rate for what includes an IB hop.
    pub fn group_intra_node(&self, ranks: &[u64]) -> bool {
        match ranks.split_first() {
            None => true,
            Some((first, rest)) => {
                let node = self.node_of(*first);
                rest.iter().all(|&r| self.node_of(r) == node)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FleetSpec;

    #[test]
    fn placement_and_links() {
        let t = Topology::from_cluster(&ClusterConfig::h100_2nodes());
        assert_eq!(t.total_gpus(), 16);
        assert_eq!(t.node_of(7), 0);
        assert_eq!(t.node_of(8), 1);
        assert_eq!(t.link_between(0, 7).kind, LinkKind::NvLink);
        assert_eq!(t.link_between(7, 8).kind, LinkKind::InfiniBand);
    }

    #[test]
    fn group_detection() {
        let t = Topology::from_cluster(&ClusterConfig::h100_2nodes());
        assert!(t.group_intra_node(&[0, 1, 2, 3, 4, 5, 6, 7]));
        assert!(!t.group_intra_node(&[6, 7, 8]));
        assert!(t.group_intra_node(&[]));
        assert!(t.group_intra_node(&[9]));
    }

    #[test]
    fn strided_group_cannot_fake_intra_node() {
        // Regression: the old pairwise windows(2) scan compared only
        // neighbours; a strided CP group visiting another node and
        // coming back must still be inter-node.
        let t = Topology::from_cluster(&ClusterConfig::h100_2nodes());
        assert!(!t.group_intra_node(&[0, 8, 1]));
        assert!(!t.group_intra_node(&[0, 1, 8, 9]));
        assert!(t.group_intra_node(&[3, 0, 7, 1]), "order within one node is free");
    }

    fn two_pool_fleet() -> FleetSpec {
        FleetSpec::parse(
            r#"{"pools": [
                {"name": "h100", "device": "h100", "nodes": 2},
                {"name": "b200", "device": "b200", "nodes": 1}
            ]}"#,
            "test",
        )
        .unwrap()
    }

    #[test]
    fn fleet_topology_maps_ranks_across_pools() {
        let f = two_pool_fleet();
        let t = Topology::from_fleet(&f);
        assert_eq!(t.total_gpus(), 24);
        assert_eq!(t.nodes, 3);
        // Ranks 0..16 are the H100 pool's two nodes, 16..24 the B200 node.
        assert_eq!(t.node_of(15), 1);
        assert_eq!(t.node_of(16), 2);
        assert_eq!(t.device_of(&f, 0).name, "H100");
        assert_eq!(t.device_of(&f, 16).name, "B200");
        // Intra-node links run at the pool's own NVLink generation.
        assert_eq!(t.link_between(0, 1).bandwidth, 900.0e9);
        assert_eq!(t.link_between(16, 17).bandwidth, 1800.0e9);
        // A cross-pool pair is IB at the slower endpoint's NIC.
        let x = t.link_between(0, 16);
        assert_eq!(x.kind, LinkKind::InfiniBand);
        assert_eq!(x.bandwidth, 50.0e9, "H100's 400 Gb/s NIC bounds the pair");
        // Same-pool inter-node keeps the pool's rate.
        assert_eq!(t.link_between(0, 8).bandwidth, 50.0e9);
        assert_eq!(t.pcie_of(16).kind, LinkKind::Pcie);
        // Strided groups across the pool boundary are inter-node.
        assert!(!t.group_intra_node(&[0, 16, 1]));
        assert!(t.group_intra_node(&[16, 18, 17]));
    }
}
