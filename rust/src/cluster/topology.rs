//! Node topology: rank → node placement and link selection between ranks.

use super::link::{Link, LinkKind};
use crate::config::ClusterConfig;

#[derive(Debug, Clone)]
pub struct Topology {
    pub nodes: u64,
    pub gpus_per_node: u64,
    nvlink: Link,
    ib: Link,
    pcie: Link,
}

impl Topology {
    pub fn from_cluster(c: &ClusterConfig) -> Self {
        Topology {
            nodes: c.nodes,
            gpus_per_node: c.gpus_per_node,
            nvlink: Link::nvlink(c.nvlink_bps),
            ib: Link::infiniband(c.ib_bps),
            pcie: Link::pcie(c.pcie_bps),
        }
    }

    pub fn total_gpus(&self) -> u64 {
        self.nodes * self.gpus_per_node
    }

    pub fn node_of(&self, rank: u64) -> u64 {
        rank / self.gpus_per_node
    }

    /// Link connecting two ranks.
    pub fn link_between(&self, a: u64, b: u64) -> Link {
        if self.node_of(a) == self.node_of(b) {
            self.nvlink
        } else {
            self.ib
        }
    }

    pub fn link(&self, kind: LinkKind) -> Link {
        match kind {
            LinkKind::NvLink => self.nvlink,
            LinkKind::InfiniBand => self.ib,
            LinkKind::Pcie => self.pcie,
        }
    }

    /// Are all ranks of a group on one node (⇒ collectives run on NVLink)?
    pub fn group_intra_node(&self, ranks: &[u64]) -> bool {
        ranks
            .windows(2)
            .all(|w| self.node_of(w[0]) == self.node_of(w[1]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_and_links() {
        let t = Topology::from_cluster(&ClusterConfig::h100_2nodes());
        assert_eq!(t.total_gpus(), 16);
        assert_eq!(t.node_of(7), 0);
        assert_eq!(t.node_of(8), 1);
        assert_eq!(t.link_between(0, 7).kind, LinkKind::NvLink);
        assert_eq!(t.link_between(7, 8).kind, LinkKind::InfiniBand);
    }

    #[test]
    fn group_detection() {
        let t = Topology::from_cluster(&ClusterConfig::h100_2nodes());
        assert!(t.group_intra_node(&[0, 1, 2, 3, 4, 5, 6, 7]));
        assert!(!t.group_intra_node(&[6, 7, 8]));
    }
}
