//! A simulated accelerator: HBM allocator + per-stream busy-until clocks.

use crate::memory::{Allocator, MemoryTimeline};

/// Execution streams a device schedules work on (CUDA-stream analogue).
/// The paper's methods overlap compute with offload (FPDT) and comm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stream {
    Compute,
    Comm,
    Offload,
}

#[derive(Debug)]
pub struct Device {
    pub id: u64,
    pub node: u64,
    pub hbm: Allocator,
    pub timeline: MemoryTimeline,
    busy_until: [f64; 3],
}

impl Device {
    pub fn new(id: u64, node: u64, hbm_limit: f64) -> Self {
        Device {
            id,
            node,
            hbm: Allocator::new(hbm_limit),
            timeline: MemoryTimeline::new(),
            busy_until: [0.0; 3],
        }
    }

    fn idx(s: Stream) -> usize {
        match s {
            Stream::Compute => 0,
            Stream::Comm => 1,
            Stream::Offload => 2,
        }
    }

    /// Schedule `dur` seconds of work on `stream`, starting no earlier than
    /// `ready` (dependency time). Returns the finish time.
    pub fn schedule(&mut self, stream: Stream, ready: f64, dur: f64) -> f64 {
        let i = Self::idx(stream);
        let start = self.busy_until[i].max(ready);
        self.busy_until[i] = start + dur;
        self.busy_until[i]
    }

    pub fn stream_time(&self, stream: Stream) -> f64 {
        self.busy_until[Self::idx(stream)]
    }

    /// Wall-clock when every stream has drained.
    pub fn finish_time(&self) -> f64 {
        self.busy_until.iter().copied().fold(0.0, f64::max)
    }

    /// Record the current allocation level on the timeline.
    pub fn snapshot(&mut self, t: f64, label: &'static str) {
        self.timeline.record(t, self.hbm.allocated(), label);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streams_are_independent() {
        let mut d = Device::new(0, 0, 1e12);
        let t1 = d.schedule(Stream::Compute, 0.0, 5.0);
        let t2 = d.schedule(Stream::Comm, 0.0, 2.0);
        assert_eq!(t1, 5.0);
        assert_eq!(t2, 2.0);
        // Second compute op queues behind the first.
        let t3 = d.schedule(Stream::Compute, 0.0, 1.0);
        assert_eq!(t3, 6.0);
        assert_eq!(d.finish_time(), 6.0);
    }

    #[test]
    fn dependency_delays_start() {
        let mut d = Device::new(0, 0, 1e12);
        let t = d.schedule(Stream::Comm, 10.0, 1.0);
        assert_eq!(t, 11.0);
    }

    #[test]
    fn snapshot_records_allocated() {
        let mut d = Device::new(0, 0, 1e12);
        let id = d.hbm.alloc(100.0).unwrap();
        d.snapshot(0.0, "x");
        d.hbm.free(id);
        assert_eq!(d.timeline.peak(), 100.0);
    }
}
