//! Simulated cluster: devices with HBM allocators, links with α–β costs,
//! and the node topology that decides whether a collective crosses NVLink
//! or InfiniBand.

pub mod device;
pub mod link;
pub mod topology;

pub use device::Device;
pub use link::{Link, LinkKind};
pub use topology::Topology;
