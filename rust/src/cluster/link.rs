//! Interconnect links with an α–β cost model (latency + bytes/bandwidth).

/// Kind of interconnect a transfer crosses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    /// Intra-node NVLink (4th gen, 900 GB/s bidirectional).
    NvLink,
    /// Inter-node InfiniBand (400 Gb/s).
    InfiniBand,
    /// Host offload over PCIe gen5.
    Pcie,
}

#[derive(Debug, Clone, Copy)]
pub struct Link {
    pub kind: LinkKind,
    /// effective bandwidth, bytes/s
    pub bandwidth: f64,
    /// per-message launch latency, s (NCCL call overhead)
    pub alpha: f64,
}

impl Link {
    pub fn nvlink(bw: f64) -> Self {
        Link { kind: LinkKind::NvLink, bandwidth: bw, alpha: 20e-6 }
    }

    pub fn infiniband(bw: f64) -> Self {
        Link { kind: LinkKind::InfiniBand, bandwidth: bw, alpha: 60e-6 }
    }

    pub fn pcie(bw: f64) -> Self {
        Link { kind: LinkKind::Pcie, bandwidth: bw, alpha: 10e-6 }
    }

    /// α–β transfer time for `bytes`.
    pub fn xfer_time(&self, bytes: f64) -> f64 {
        self.alpha + bytes / self.bandwidth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_beta() {
        let l = Link::nvlink(900e9);
        let t = l.xfer_time(900e9);
        assert!((t - 1.0).abs() < 1e-3);
        // Small messages are latency-bound.
        assert!(l.xfer_time(1.0) >= l.alpha);
    }

    #[test]
    fn ib_slower_than_nvlink() {
        let nv = Link::nvlink(900e9);
        let ib = Link::infiniband(50e9);
        assert!(ib.xfer_time(1e9) > nv.xfer_time(1e9));
    }
}
