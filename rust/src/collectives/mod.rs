//! Collectives, in two guises:
//!
//! - [`functional`] — real data movement between in-process rank buffers:
//!   the seq↔head reshard all-to-alls of DS-Ulysses/UPipe (§3.1's
//!   `inp_all_to_all` / `out_all_to_all`), used by the functional
//!   coordinator. Correctness is proptested (reshard ∘ unreshard = id).
//! - [`cost`] — α–β time models for all-to-all / ring / all-gather /
//!   reduce-scatter, used by the simulation engine.

pub mod cost;
pub mod functional;

pub use functional::{all_to_all_head_to_seq, all_to_all_seq_to_head};
