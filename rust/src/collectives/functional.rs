//! Functional all-to-all reshards over in-process rank buffers.
//!
//! Layout convention (matches the AOT artifacts): a rank's local tensor is
//! a dense f32 `[heads, rows, d]` buffer, heads-major. Before
//! `inp_all_to_all` each of the `C` ranks holds `[u, s/c, d]` (all `u`
//! stage heads, its own sequence shard); after, rank `j` holds
//! `[u/c, s, d]` (its `u/c` heads, the full sequence) — paper Fig. 3.

/// inp_all_to_all: seq-sharded → head-sharded.
///
/// `inputs[r]` is rank r's `[u, sc, d]` buffer. Returns `out[j]` =
/// `[u/c, u_rows = sc*c, d]` where head block `j*u/c + h` rows are ordered
/// by source rank (i.e. global sequence order).
pub fn all_to_all_seq_to_head(
    inputs: &[Vec<f32>],
    u: usize,
    sc: usize,
    d: usize,
) -> Vec<Vec<f32>> {
    let mut out = vec![Vec::new(); inputs.len()];
    all_to_all_seq_to_head_into(inputs, u, sc, d, &mut out);
    out
}

/// Buffer-reusing variant of [`all_to_all_seq_to_head`]: writes into `out`,
/// growing it only on first use. Freshly allocated pages (and their faults)
/// dominate the reshard cost, so reusing the stage buffers — exactly the
/// paper's §3.3 buffer-reuse insight, applied host-side — is ~2× faster
/// (see EXPERIMENTS.md §Perf).
pub fn all_to_all_seq_to_head_into(
    inputs: &[Vec<f32>],
    u: usize,
    sc: usize,
    d: usize,
    out: &mut [Vec<f32>],
) {
    let c = inputs.len();
    assert!(u % c == 0, "U={u} must be divisible by C={c}");
    let u_loc = u / c;
    let s = sc * c;
    for (r, buf) in inputs.iter().enumerate() {
        assert_eq!(buf.len(), u * sc * d, "rank {r} buffer size");
    }
    assert_eq!(out.len(), c);
    for (j, out_j) in out.iter_mut().enumerate() {
        out_j.clear();
        out_j.reserve(u_loc * s * d);
        for h_loc in 0..u_loc {
            let h = j * u_loc + h_loc; // global stage-head index
            for input in inputs {
                out_j.extend_from_slice(&input[(h * sc) * d..(h * sc + sc) * d]);
            }
        }
    }
}

/// out_all_to_all: head-sharded → seq-sharded (inverse of the above).
///
/// `inputs[j]` is rank j's `[u/c, s, d]`; returns `out[r]` = `[u, sc, d]`.
pub fn all_to_all_head_to_seq(
    inputs: &[Vec<f32>],
    u: usize,
    sc: usize,
    d: usize,
) -> Vec<Vec<f32>> {
    let c = inputs.len();
    assert!(u % c == 0);
    let u_loc = u / c;
    let s = sc * c;
    for (j, buf) in inputs.iter().enumerate() {
        assert_eq!(buf.len(), u_loc * s * d, "rank {j} buffer size");
    }
    let mut out = vec![Vec::new(); c];
    all_to_all_head_to_seq_into(inputs, u, sc, d, &mut out);
    out
}

/// Buffer-reusing variant of [`all_to_all_head_to_seq`].
pub fn all_to_all_head_to_seq_into(
    inputs: &[Vec<f32>],
    u: usize,
    sc: usize,
    d: usize,
    out: &mut [Vec<f32>],
) {
    let c = inputs.len();
    assert!(u % c == 0);
    let u_loc = u / c;
    let s = sc * c;
    for (j, buf) in inputs.iter().enumerate() {
        assert_eq!(buf.len(), u_loc * s * d, "rank {j} buffer size");
    }
    assert_eq!(out.len(), c);
    for (r, out_r) in out.iter_mut().enumerate() {
        out_r.clear();
        out_r.reserve(u * sc * d);
        for h in 0..u {
            let src_off = ((h % u_loc) * s + r * sc) * d;
            out_r.extend_from_slice(&inputs[h / u_loc][src_off..src_off + sc * d]);
        }
    }
}

/// Gather one full-sequence head on one destination rank from per-rank
/// sequence shards (the KV path when a KV head serves several query ranks).
/// `inputs[r]` is `[heads, sc, d]`; returns `[1, s, d]` for `head`.
pub fn gather_head(inputs: &[Vec<f32>], head: usize, heads: usize, sc: usize, d: usize) -> Vec<f32> {
    let c = inputs.len();
    let mut out = vec![0.0f32; c * sc * d];
    for r in 0..c {
        assert_eq!(inputs[r].len(), heads * sc * d);
        let src = &inputs[r][(head * sc) * d..(head * sc + sc) * d];
        out[r * sc * d..(r + 1) * sc * d].copy_from_slice(src);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn mk_inputs(c: usize, u: usize, sc: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..c)
            .map(|_| (0..u * sc * d).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    #[test]
    fn roundtrip_identity() {
        let (c, u, sc, d) = (4, 4, 8, 16);
        let inputs = mk_inputs(c, u, sc, d, 7);
        let hs = all_to_all_seq_to_head(&inputs, u, sc, d);
        let back = all_to_all_head_to_seq(&hs, u, sc, d);
        assert_eq!(inputs, back);
    }

    #[test]
    fn head_ownership_layout() {
        // rank j must own heads [j*u/c, (j+1)*u/c) in global seq order.
        let (c, u, sc, d) = (2, 4, 2, 1);
        // rank r value for head h, row t = 100*r + 10*h + t
        let inputs: Vec<Vec<f32>> = (0..c)
            .map(|r| {
                let mut v = Vec::new();
                for h in 0..u {
                    for t in 0..sc {
                        v.push((100 * r + 10 * h + t) as f32);
                    }
                }
                v
            })
            .collect();
        let hs = all_to_all_seq_to_head(&inputs, u, sc, d);
        // rank 0, head 0 (global head 0), full sequence = rank0 rows then rank1 rows
        assert_eq!(&hs[0][0..4], &[0.0, 1.0, 100.0, 101.0]);
        // rank 1, local head 0 = global head 2
        assert_eq!(&hs[1][0..4], &[20.0, 21.0, 120.0, 121.0]);
    }

    #[test]
    fn gather_head_assembles_sequence() {
        let (c, heads, sc, d) = (3, 2, 2, 1);
        let inputs: Vec<Vec<f32>> = (0..c)
            .map(|r| (0..heads * sc).map(|i| (r * 10 + i) as f32).collect())
            .collect();
        let g = gather_head(&inputs, 1, heads, sc, d);
        assert_eq!(g, vec![2.0, 3.0, 12.0, 13.0, 22.0, 23.0]);
    }

    #[test]
    fn prop_roundtrip_many_shapes() {
        prop::check("a2a-roundtrip", 40, &[(1, 3), (1, 4), (1, 6), (1, 4), (0, 1000)], |a| {
            let c = 1usize << a[0]; // 2,4,8
            let mult = a[1] as usize;
            let u = c * mult;
            let sc = a[2] as usize;
            let d = a[3] as usize;
            let inputs = mk_inputs(c, u, sc, d, a[4] as u64);
            let hs = all_to_all_seq_to_head(&inputs, u, sc, d);
            let back = all_to_all_head_to_seq(&hs, u, sc, d);
            back == inputs
        });
    }

    #[test]
    #[should_panic(expected = "must be divisible")]
    fn rejects_indivisible_u() {
        let inputs = mk_inputs(4, 6, 2, 2, 0);
        all_to_all_seq_to_head(&inputs, 6, 2, 2);
    }
}
