//! α–β cost models for the collectives the CP schedules issue.

use crate::cluster::Link;

/// All-to-all over `c` ranks: each rank exchanges `bytes_per_rank` with
/// every peer; on a fully-connected NVLink fabric the transfers overlap, so
/// time ≈ α + (c-1)/c · total/bandwidth.
pub fn all_to_all(link: &Link, c: u64, bytes_per_rank: f64) -> f64 {
    if c <= 1 {
        return 0.0;
    }
    let frac = (c - 1) as f64 / c as f64;
    link.alpha + frac * bytes_per_rank / link.bandwidth
}

/// Ring exchange: `steps` p2p rounds of `bytes_per_step` each (Ring
/// Attention does C-1 rounds). Latency is paid per round — the O(C)
/// communication-call cost §2.1 attributes to Ring Attention.
pub fn ring(link: &Link, steps: u64, bytes_per_step: f64) -> f64 {
    steps as f64 * (link.alpha + bytes_per_step / link.bandwidth)
}

/// All-gather of `bytes` total result over `c` ranks (ring algorithm).
pub fn all_gather(link: &Link, c: u64, bytes: f64) -> f64 {
    if c <= 1 {
        return 0.0;
    }
    let steps = c - 1;
    steps as f64 * link.alpha + (c - 1) as f64 / c as f64 * bytes / link.bandwidth
}

/// Reduce-scatter of `bytes` total input over `c` ranks (ring algorithm,
/// same volume as all-gather).
pub fn reduce_scatter(link: &Link, c: u64, bytes: f64) -> f64 {
    all_gather(link, c, bytes)
}

/// Host offload (PCIe) transfer; `pinned=false` (paper's 5M setup) pays a
/// pageable-memory penalty.
pub fn offload(link: &Link, bytes: f64, pinned: bool) -> f64 {
    let bw = if pinned { link.bandwidth } else { link.bandwidth * 0.35 };
    link.alpha + bytes / bw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Link;

    fn nv() -> Link {
        Link::nvlink(900e9)
    }

    #[test]
    fn a2a_scales_with_bytes_and_saturates_with_c() {
        let t1 = all_to_all(&nv(), 8, 1e9);
        let t2 = all_to_all(&nv(), 8, 2e9);
        assert!(t2 > 1.9 * t1 && t2 < 2.1 * t1);
        // (c-1)/c factor: volume-bound limit is flat in c
        let t8 = all_to_all(&nv(), 8, 1e9);
        let t16 = all_to_all(&nv(), 16, 1e9);
        assert!((t16 / t8 - (15.0 / 16.0) / (7.0 / 8.0)).abs() < 0.05);
    }

    #[test]
    fn single_rank_is_free() {
        assert_eq!(all_to_all(&nv(), 1, 1e9), 0.0);
        assert_eq!(all_gather(&nv(), 1, 1e9), 0.0);
    }

    #[test]
    fn ring_pays_latency_per_step() {
        let l = nv();
        let t = ring(&l, 7, 0.0);
        assert!((t - 7.0 * l.alpha).abs() < 1e-12);
    }

    #[test]
    fn unpinned_offload_slower() {
        let l = Link::pcie(55e9);
        assert!(offload(&l, 1e9, false) > 2.0 * offload(&l, 1e9, true));
    }
}
